//! Head-to-head method comparison, in two parts.
//!
//! Part 1 (always runs, no artifacts needed): MXFP4 vs NVFP4 on the
//! packed serve substrate — same synthetic weights quantized at both
//! group geometries (1x32 E8M0 vs 1x16 E4M3 + outlier clamp), compared
//! on reconstruction error, packed footprint, and fused-forward
//! throughput. Entries are merged into `results/BENCH_<pr>.json` via
//! `benchio::merge_bench` so the perf trajectory tracks both formats.
//!
//! Part 2 (a mini Table 2, skipped gracefully when the XLA artifacts
//! are absent): FP32 vs Microscaling vs TetraJet vs TetraJet+Q-EMA vs
//! TetraJet+Q-Ramping, trained from the same initialization on the
//! same data stream.
//!
//! ```bash
//! cargo run --release --example compare_methods -- --steps 150
//! ```

use std::time::Instant;

use anyhow::Result;
use tetrajet::config::{MetricsCfg, Policy};
use tetrajet::experiments::common::{print_table, ExpOpts, Runner};
use tetrajet::quant::{e2m1, MxQuantizer, NvQuantizer, PackedMx, Quantizer, ScaleEnc, Scaling};
use tetrajet::runtime::artifacts;
use tetrajet::serve::{ActQuant, PackedVit, ServeGeom, WeightQuant};
use tetrajet::util::benchio;
use tetrajet::util::cli::Args;
use tetrajet::util::json::{num, obj, s, Json};
use tetrajet::util::rng::Rng;

/// One method's packed head-to-head measurements.
struct HeadToHead {
    method: &'static str,
    group_size: usize,
    scale_enc: &'static str,
    rel_rmse: f64,
    packed_bytes: usize,
    imgs_per_s: f64,
    wall_ms: f64,
}

fn head_to_head(q: &dyn Quantizer, method: &'static str, wq: WeightQuant) -> HeadToHead {
    // Reconstruction error on a synthetic weight matrix (the serve
    // substrate guarantees dequantize(quantize_packed(x)) is bit-exact
    // to the fake-quant mirror, so this is the training-side error too).
    let (rows, cols) = (96, 256);
    let mut rng = Rng::new(17);
    let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal() * 0.05).collect();
    let mut p = PackedMx::default();
    q.quantize_packed(&w, cols, &mut p);
    let y = p.dequantize();
    let (mut se, mut ss) = (0.0f64, 0.0f64);
    for (a, b) in w.iter().zip(&y) {
        se += f64::from(a - b).powi(2);
        ss += f64::from(*a).powi(2);
    }
    let geom = p.geom();

    // Fused-forward throughput on a small-but-real PackedVit.
    let vit_geom = ServeGeom::new(16, 4, 64, 2, 4, 5, 4);
    let mut rng = Rng::new(23);
    let params: Vec<f32> =
        (0..vit_geom.total_params()).map(|_| rng.normal() * 0.05).collect();
    let aq = match wq {
        WeightQuant::Nvfp4 => ActQuant::Nvfp4,
        _ => ActQuant::Mx { fmt: e2m1(), scaling: Scaling::TruncationFree },
    };
    let vit = PackedVit::build(vit_geom, &params, None, wq, aq).unwrap();
    let n = 16;
    let px = vit_geom.img * vit_geom.img * 3;
    let x: Vec<f32> = (0..n * px).map(|_| rng.normal()).collect();
    vit.forward(&x, n, 1); // warm up
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        vit.forward(&x, n, 1);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    HeadToHead {
        method,
        group_size: geom.group_size(),
        scale_enc: match geom.scale_enc() {
            ScaleEnc::E8m0 => "e8m0",
            ScaleEnc::E4m3 => "e4m3",
        },
        rel_rmse: (se / ss).sqrt(),
        packed_bytes: p.bytes(),
        imgs_per_s: n as f64 / best,
        wall_ms: best * 1e3,
    }
}

fn run_head_to_head(args: &Args) -> Result<()> {
    let mx = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
    let results = vec![
        head_to_head(
            &mx,
            "mxfp4",
            WeightQuant::Mx { fmt: e2m1(), scaling: Scaling::TruncationFree },
        ),
        head_to_head(&NvQuantizer::nvfp4(), "nvfp4", WeightQuant::Nvfp4),
    ];

    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.method.to_string(),
                format!("1x{} {}", r.group_size, r.scale_enc),
                format!("{:.4}", r.rel_rmse),
                format!("{}", r.packed_bytes),
                format!("{:.0}", r.imgs_per_s),
            ]
        })
        .collect();
    print_table(
        "packed substrate head-to-head (96x256 weights, fused serve forward)",
        &["method", "geometry", "rel rmse", "packed bytes", "imgs/s"],
        &rows,
    );

    let pr = args.get_u64("bench-pr", 9)?;
    let default_out = format!("results/BENCH_{pr}.json");
    let out = std::path::PathBuf::from(args.get_or("bench-out", &default_out));
    let entries: Vec<Json> = results
        .iter()
        .map(|r| {
            obj(vec![
                ("case", s("quant-compare")),
                ("method", s(r.method)),
                ("group_size", num(r.group_size as f64)),
                ("scale_enc", s(r.scale_enc)),
                ("rel_rmse", num(r.rel_rmse)),
                ("packed_bytes", num(r.packed_bytes as f64)),
                ("imgs_per_s", num(r.imgs_per_s)),
                ("wall_ms", num(r.wall_ms)),
            ])
        })
        .collect();
    benchio::merge_bench(&out, pr, entries)?;
    println!("BENCH json merged into {}", out.display());
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse_tokens(&std::env::args().skip(1).collect::<Vec<_>>(), false)?;

    run_head_to_head(&args)?;

    let mut opts = ExpOpts::new(true);
    opts.steps = args.get_usize("steps", 150)?;
    opts.eval_samples = args.get_usize("eval-samples", 512)?;
    let have = |v: &str| artifacts::variant_dir(&opts.root, &opts.model, opts.batch, v).exists();
    if !have("tetrajet") {
        println!(
            "note: no compiled artifacts under {} — skipping the training comparison \
             (run `make artifacts` first)",
            opts.root.display()
        );
        return Ok(());
    }
    let mut runner = Runner::new(&opts)?;

    let m = MetricsCfg::off;
    let mut runs = vec![
        runner.run_one("FP32", "fp32", Policy::None, m(), |_| {})?,
        runner.run_one("Microscaling", "microscaling", Policy::None, m(), |_| {})?,
        runner.run_one("TetraJet", "tetrajet", Policy::None, m(), |_| {})?,
        runner.run_one("TetraJet+Q-EMA", "tetrajet_qema", Policy::None, m(), |_| {})?,
        runner.run_one(
            "TetraJet+Q-Ramping",
            "tetrajet",
            Policy::qramping_default(),
            m(),
            |_| {},
        )?,
    ];
    // NVFP4 artifacts are non-core (`make artifacts-full`); include the
    // row when they are present.
    if have("nvfp4") {
        runs.push(runner.run_one("NVFP4", "nvfp4", Policy::None, m(), |_| {})?);
    }
    let rows: Vec<Vec<String>> = runs
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                format!("{:.2}", r.final_acc),
                format!("{:.4}", r.final_loss),
            ]
        })
        .collect();
    print_table(
        &format!("method comparison ({} steps, vit-micro)", opts.steps),
        &["method", "top-1 %", "val loss"],
        &rows,
    );
    Ok(())
}
