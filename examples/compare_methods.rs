//! Head-to-head method comparison (a mini Table 2): FP32 vs
//! Microscaling vs TetraJet vs TetraJet+Q-EMA vs TetraJet+Q-Ramping,
//! trained from the same initialization on the same data stream.
//!
//! ```bash
//! cargo run --release --example compare_methods -- --steps 150
//! ```

use anyhow::Result;
use tetrajet::config::{MetricsCfg, Policy};
use tetrajet::experiments::common::{print_table, ExpOpts, Runner};
use tetrajet::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_tokens(&std::env::args().skip(1).collect::<Vec<_>>(), false)?;
    let mut opts = ExpOpts::new(true);
    opts.steps = args.get_usize("steps", 150)?;
    opts.eval_samples = args.get_usize("eval-samples", 512)?;
    let mut runner = Runner::new(&opts)?;

    let m = MetricsCfg::off;
    let runs = vec![
        runner.run_one("FP32", "fp32", Policy::None, m(), |_| {})?,
        runner.run_one("Microscaling", "microscaling", Policy::None, m(), |_| {})?,
        runner.run_one("TetraJet", "tetrajet", Policy::None, m(), |_| {})?,
        runner.run_one("TetraJet+Q-EMA", "tetrajet_qema", Policy::None, m(), |_| {})?,
        runner.run_one(
            "TetraJet+Q-Ramping",
            "tetrajet",
            Policy::qramping_default(),
            m(),
            |_| {},
        )?,
    ];
    let rows: Vec<Vec<String>> = runs
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                format!("{:.2}", r.final_acc),
                format!("{:.4}", r.final_loss),
            ]
        })
        .collect();
    print_table(
        &format!("method comparison ({} steps, vit-micro)", opts.steps),
        &["method", "top-1 %", "val loss"],
        &rows,
    );
    Ok(())
}
