//! End-to-end driver: full MXFP4 pre-training run on SynthVision with
//! periodic evaluation, a logged loss curve, checkpointing, and a final
//! FP32-vs-MXFP4 comparison — the repository's proof that all three
//! layers compose (L1 Pallas quantizers -> L2 AOT ViT step -> L3
//! coordinator). Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! cargo run --release --example train_vit_e2e            # 400 steps
//! cargo run --release --example train_vit_e2e -- --steps 800
//! ```

use anyhow::Result;
use tetrajet::config::{MetricsCfg, TrainConfig};
use tetrajet::coordinator::Trainer;
use tetrajet::runtime::{artifacts, cpu_client, ModelArtifacts};
use tetrajet::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_tokens(&std::env::args().skip(1).collect::<Vec<_>>(), false)?;
    let steps = args.get_usize("steps", 400)?;
    let variant = args.get_or("variant", "tetrajet").to_string();
    let root = artifacts::default_root();
    let client = cpu_client()?;

    let out_dir = std::path::PathBuf::from("results/e2e");
    std::fs::create_dir_all(&out_dir)?;

    let mut results = Vec::new();
    for v in ["fp32", &variant] {
        println!("=== {v}: loading + compiling artifacts ===");
        let arts = ModelArtifacts::load(&client, &root, "vit-micro", 16, v)?;
        let mut cfg = TrainConfig::default_run(v);
        cfg.steps = steps;
        cfg.warmup = (steps / 10).max(1);
        cfg.eval_every = (steps / 8).max(1);
        cfg.eval_samples = 512;
        cfg.metrics = MetricsCfg::standard(); // oscillating-weight series
        let params = artifacts::run_init(&client, &root, "vit-micro", cfg.init_seed)?;
        let mut tr = Trainer::new(&arts, cfg, params)?;
        let t0 = std::time::Instant::now();
        let ev = tr.run()?;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "=== {v}: top-1 {:.2}% | {:.1}s total | {:.0} ms/step | {:.1} img/s ===",
            ev.acc_pct,
            dt,
            1000.0 * dt / steps as f64,
            (steps * 16) as f64 / dt,
        );
        // Persist the loss curve + eval points + checkpoint.
        std::fs::write(out_dir.join(format!("{v}_loss.csv")), tr.rec.loss_csv())?;
        tr.rec.save_json(&out_dir.join(format!("{v}_run.json")))?;
        tr.state.save(&out_dir.join(format!("{v}.ckpt")))?;
        results.push((v.to_string(), ev.acc_pct, tr.rec.clone()));
    }

    println!("\n## e2e summary ({steps} steps, vit-micro, SynthVision)");
    for (v, acc, rec) in &results {
        let evs: Vec<String> = rec
            .evals
            .iter()
            .map(|(s, a, _)| format!("{s}:{a:.1}%"))
            .collect();
        println!("{v:<14} final {acc:.2}%   curve [{}]", evs.join(" "));
    }
    let gap = results[0].1 - results[1].1;
    println!(
        "FP32 -> {} gap: {gap:.2} points (paper DeiT-T: 63.73 -> 59.75 = 3.98)",
        results[1].0
    );
    println!("loss curves in results/e2e/*.csv, checkpoints in results/e2e/*.ckpt");
    Ok(())
}
