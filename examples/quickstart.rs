//! Quickstart: load the TetraJet artifacts, train a few steps, evaluate.
//!
//! ```bash
//! make artifacts && cargo build --release
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use tetrajet::config::TrainConfig;
use tetrajet::coordinator::Trainer;
use tetrajet::runtime::{artifacts, cpu_client, ModelArtifacts};

fn main() -> Result<()> {
    let root = artifacts::default_root();
    let client = cpu_client()?;
    println!("loading AOT artifacts (compiles HLO once, ~30 s)...");
    let arts = ModelArtifacts::load(&client, &root, "vit-micro", 16, "tetrajet")?;
    println!(
        "model {} | {} params ({} quantized) | batch {}",
        arts.manifest.model.name,
        arts.manifest.total_params,
        arts.manifest.qw_total,
        arts.manifest.batch
    );

    let mut cfg = TrainConfig::default_run("tetrajet");
    cfg.steps = 40;
    cfg.warmup = 4;
    cfg.eval_samples = 256;
    let params = artifacts::run_init(&client, &root, "vit-micro", cfg.init_seed)?;
    let mut tr = Trainer::new(&arts, cfg, params)?;

    println!("training 40 steps of MXFP4 (E2M1 + E8M0/32) ViT...");
    for step in 0..40 {
        let (loss, acc) = tr.step()?;
        if step % 5 == 0 {
            println!("  step {step:>3}  loss {loss:.4}  batch-acc {acc:.2}");
        }
    }
    let ev = tr.eval()?;
    println!(
        "done: top-1 {:.2}% on {} held-out samples (val loss {:.4})",
        ev.acc_pct, ev.samples, ev.mean_loss
    );

    // Peek at the paper's §4 oscillation statistics.
    let (_, conf) = tr.snapshot_latents();
    let mean_conf = tetrajet::util::stats::mean_f32(&conf);
    println!("mean quantization confidence of weights: {mean_conf:.4}");
    Ok(())
}
