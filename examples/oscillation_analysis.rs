//! Oscillation analysis (paper §4): train MXFP4, then inspect
//!
//!  * the rate-of-change instability signature (Fig. 2),
//!  * latent-weight / quantization-confidence distributions (Fig. 4),
//!  * concrete oscillating elements flipping across a threshold (Fig. 3).
//!
//! ```bash
//! cargo run --release --example oscillation_analysis -- --steps 200
//! # or skip training and inspect/serve an existing packed checkpoint:
//! cargo run --release --example oscillation_analysis -- --ckpt results/oscillation.ckpt
//! # or run without HLO artifacts at all (synthetic random walk):
//! cargo run --release --example oscillation_analysis -- --synthetic tiny
//! ```
//!
//! With `--ckpt` pointing at a TJCKPT02 file (written below, or by
//! `tetrajet train --ckpt-packed`), the example loads the model through
//! the packed serving path — codes + E8M0 scales straight into the
//! fused dequant-matmul engine, no HLO artifacts and no f32 weight
//! mirror — and reports serving accuracy/latency.
//!
//! Every mode records per-segment telemetry through the oscillation
//! observatory into `results/oscillation.osclog` and then *replays the
//! artifact* with [`tetrajet::report`] — the printed per-layer tables
//! come from the OSCLOG bytes, not from trainer internals, so the
//! example and `tetrajet report` agree by construction (the replayed
//! oscillating fraction is asserted bit-equal to the live
//! `train.osc.ratio` gauge).

use anyhow::Result;
use tetrajet::config::{MetricsCfg, TrainConfig};
use tetrajet::coordinator::{SynthTrainer, Trainer, TrainState};
use tetrajet::data::{EvalSet, SynthVision};
use tetrajet::obs::osclog::OscLogWriter;
use tetrajet::report;
use tetrajet::runtime::{artifacts, cpu_client, Manifest, ModelArtifacts};
use tetrajet::serve::{PackedVit, ServeConfig, ServeEngine};
use tetrajet::util::cli::Args;
use tetrajet::util::stats::Histogram;

const OSCLOG_PATH: &str = "results/oscillation.osclog";

/// Replay the OSCLOG artifact offline and print the per-layer report;
/// when a window closed, the replayed fraction must equal the live
/// `train.osc.ratio` gauge bit-exactly.
fn replay_osclog(live_ratio: Option<f64>) -> Result<()> {
    let log = report::load_osclog(std::path::Path::new(OSCLOG_PATH))?;
    let rep = report::analyze(&log, 5);
    println!();
    print!("{}", rep.to_markdown());
    if let (Some(live), true) = (live_ratio, rep.windows > 0) {
        assert_eq!(
            rep.osc_fraction, live,
            "offline replay must recover the live gauge bit-exactly"
        );
        println!("replayed osc fraction == live train.osc.ratio gauge ({live})");
    }
    Ok(())
}

/// No-artifacts mode: the synthetic random-walk trainer drives the
/// identical quantize/track/record machinery.
fn synthetic_observatory(model: &str, seed: u64, steps: usize) -> Result<()> {
    let mut m = MetricsCfg::standard();
    m.osc_window = 10;
    let mut t = SynthTrainer::new(model, "mx", seed, m)?;
    t.attach_osclog(OscLogWriter::to_file(std::path::Path::new(OSCLOG_PATH))?);
    let rep = t.run(steps)?;
    let (lines, digest) = rep.osclog.expect("osclog was attached");
    println!("synthetic[{model}]: {steps} steps, OSCLOG lines={lines} digest={digest}");
    let live = (!rep.windows.is_empty())
        .then(|| t.registry().gauge("train.osc.ratio").get());
    replay_osclog(live)
}

/// Serve a packed checkpoint: the demonstration of the TJCKPT02 ->
/// PackedVit -> ServeEngine API from example code. `variant` must be
/// the one the checkpoint was trained with — its manifest supplies the
/// layer geometry and the forward quant recipe.
fn serve_packed(ckpt: &str, model: &str, batch: usize, variant: &str) -> Result<()> {
    let root = artifacts::default_root();
    let dir = artifacts::variant_dir(&root, model, batch, variant);
    let man = Manifest::load(&dir.join("manifest.json"))?;
    let (state, segs) = TrainState::load_with_packed(std::path::Path::new(ckpt))?;
    println!(
        "loaded {} (step {}): {} packed segments, {} f32 params",
        ckpt,
        state.step,
        segs.len(),
        state.params.len()
    );
    let vit = PackedVit::from_checkpoint(&man, &state.params, Some(&state.ema), &segs)?;
    println!(
        "resident quantized weights: {:.1} KiB packed vs {:.1} KiB f32 mirror \
         (fully packed: {})",
        vit.quantized_weight_bytes() as f64 / 1024.0,
        vit.f32_mirror_bytes() as f64 / 1024.0,
        vit.is_fully_packed()
    );
    let engine = ServeEngine::new(vit, ServeConfig::default())?;
    let cfg = TrainConfig::default_run(variant);
    let ds = SynthVision::new(
        man.model.img,
        man.model.classes,
        cfg.data_seed,
        cfg.train_size,
        cfg.val_size,
    );
    let t0 = std::time::Instant::now();
    let ev = engine.eval(&EvalSet::new(ds, man.batch, 256));
    println!(
        "packed serve eval: top-1 {:.2}%  val-loss {:.4}  ({} samples in {:.1} ms)",
        ev.acc_pct,
        ev.mean_loss,
        ev.samples,
        t0.elapsed().as_secs_f64() * 1e3
    );
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse_tokens(&std::env::args().skip(1).collect::<Vec<_>>(), false)?;
    if let Some(ckpt) = args.get("ckpt") {
        return serve_packed(
            ckpt,
            args.get_or("model", "vit-micro"),
            args.get_usize("batch", 16)?,
            args.get_or("variant", "tetrajet"),
        );
    }
    if let Some(name) = args.get("synthetic") {
        let name = name.to_string();
        return synthetic_observatory(&name, args.get_u64("seed", 0)?, args.get_usize("steps", 60)?);
    }
    let steps = args.get_usize("steps", 200)?;
    let root = artifacts::default_root();
    let client = cpu_client()?;
    let arts = ModelArtifacts::load(&client, &root, "vit-micro", 16, "tetrajet")?;

    let mut cfg = TrainConfig::default_run("tetrajet");
    cfg.steps = steps;
    cfg.warmup = (steps / 10).max(1);
    let mut m = MetricsCfg::standard();
    m.rate_window = (steps / 8).max(10);
    m.probe_every = (m.rate_window / 5).max(1);
    m.conf_every = (steps / 4).max(1);
    cfg.metrics = m;
    let params = artifacts::run_init(&client, &root, "vit-micro", cfg.init_seed)?;
    let seed = cfg.init_seed as u64;
    let mut tr = Trainer::new(&arts, cfg, params)?;
    tr.make_observatory(OscLogWriter::to_file(std::path::Path::new(OSCLOG_PATH))?, seed)?;

    println!("training {steps} steps with full oscillation metrics on...");
    for _ in 0..steps {
        tr.step()?;
    }

    println!("\n-- Fig.2-style rate of change (per window) --");
    println!("{:>6} {:>10} {:>10} {:>10}", "step", "r(W)", "r(W_Q)", "r(Y)");
    for &(s, rw, rq, ry) in &tr.rec.rate_series {
        println!("{s:>6} {rw:>10.5} {rq:>10.5} {ry:>10.5}");
    }

    println!("\n-- Fig.4-style confidence evolution --");
    for snap in &tr.rec.conf_snaps {
        let mut h = Histogram::new(0.0, 1.0, 20);
        h.counts = snap.conf_hist.iter().map(|&f| (f * 1e6) as u64).collect();
        println!(
            "step {:>5}  mean conf {:.4}  [0..1] {}",
            snap.step,
            snap.mean_conf,
            h.sparkline()
        );
    }

    println!("\n-- Fig.6-style oscillating weights (R_w > 16) --");
    for &(s, count, win) in &tr.rec.osc_series {
        println!("step {s:>5}: {count} oscillating / window {win}");
    }

    // The mirror the metrics above ran on is packed 4-bit codes, not a
    // second f32 copy of the weights; show what that buys.
    tr.mirror_wq();
    let packed_bytes: usize = tr.packed_wq().iter().map(|p| p.bytes()).sum();
    let f32_bytes = tr.wq().len() * std::mem::size_of::<f32>();
    if packed_bytes > 0 {
        println!(
            "\n-- packed quant mirror --\n{} segments, {:.1} KiB packed codes+scales \
             vs {:.1} KiB f32 mirror ({:.1}x smaller)",
            tr.packed_wq().len(),
            packed_bytes as f64 / 1024.0,
            f32_bytes as f64 / 1024.0,
            f32_bytes as f64 / packed_bytes as f64
        );
    }

    // Fig.3: concrete flipping elements across more steps.
    let (_, conf) = tr.snapshot_latents();
    let mut idx: Vec<usize> = (0..conf.len()).collect();
    idx.sort_by(|&a, &b| conf[a].partial_cmp(&conf[b]).unwrap());
    let tracked = &idx[..4];
    println!("\n-- Fig.3-style trajectories (4 least-confident elements, 12 steps) --");
    println!("{:>6} {:>32}", "step", "latent w/S (per element)");
    for _ in 0..12 {
        tr.step()?;
        let (lat, _) = tr.snapshot_latents();
        let vals: Vec<String> = tracked.iter().map(|&i| format!("{:+.4}", lat[i])).collect();
        println!("{:>6} {}", tr.state.step, vals.join("  "));
    }
    tr.rec.save_json(std::path::Path::new("results/oscillation_analysis.json"))?;
    println!("\nfull series saved to results/oscillation_analysis.json");

    // Flush the observatory and replay its artifact through the same
    // analyzer `tetrajet report` uses.
    let (lines, digest) = match tr.observatory_mut() {
        Some(ob) => {
            ob.finish()?;
            (ob.lines(), ob.digest())
        }
        None => unreachable!("observatory attached above"),
    };
    println!("OSCLOG lines={lines} digest={digest} ({OSCLOG_PATH})");
    let live = tr.registry().gauge("train.osc.ratio").get();
    replay_osclog(Some(live))?;

    // Export the packed mirror as a TJCKPT02 checkpoint and round-trip
    // it through the serving subsystem.
    let ckpt = std::path::Path::new("results/oscillation.ckpt");
    tr.save_packed_checkpoint(ckpt)?;
    println!("packed checkpoint saved to {} — serving it:", ckpt.display());
    serve_packed("results/oscillation.ckpt", "vit-micro", 16, "tetrajet")?;
    Ok(())
}
