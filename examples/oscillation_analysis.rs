//! Oscillation analysis (paper §4): train MXFP4, then inspect
//!
//!  * the rate-of-change instability signature (Fig. 2),
//!  * latent-weight / quantization-confidence distributions (Fig. 4),
//!  * concrete oscillating elements flipping across a threshold (Fig. 3).
//!
//! ```bash
//! cargo run --release --example oscillation_analysis -- --steps 200
//! ```

use anyhow::Result;
use tetrajet::config::{MetricsCfg, TrainConfig};
use tetrajet::coordinator::Trainer;
use tetrajet::runtime::{artifacts, cpu_client, ModelArtifacts};
use tetrajet::util::cli::Args;
use tetrajet::util::stats::Histogram;

fn main() -> Result<()> {
    let args = Args::parse_tokens(&std::env::args().skip(1).collect::<Vec<_>>(), false)?;
    let steps = args.get_usize("steps", 200)?;
    let root = artifacts::default_root();
    let client = cpu_client()?;
    let arts = ModelArtifacts::load(&client, &root, "vit-micro", 16, "tetrajet")?;

    let mut cfg = TrainConfig::default_run("tetrajet");
    cfg.steps = steps;
    cfg.warmup = (steps / 10).max(1);
    let mut m = MetricsCfg::standard();
    m.rate_window = (steps / 8).max(10);
    m.probe_every = (m.rate_window / 5).max(1);
    m.conf_every = (steps / 4).max(1);
    cfg.metrics = m;
    let params = artifacts::run_init(&client, &root, "vit-micro", cfg.init_seed)?;
    let mut tr = Trainer::new(&arts, cfg, params)?;

    println!("training {steps} steps with full oscillation metrics on...");
    for _ in 0..steps {
        tr.step()?;
    }

    println!("\n-- Fig.2-style rate of change (per window) --");
    println!("{:>6} {:>10} {:>10} {:>10}", "step", "r(W)", "r(W_Q)", "r(Y)");
    for &(s, rw, rq, ry) in &tr.rec.rate_series {
        println!("{s:>6} {rw:>10.5} {rq:>10.5} {ry:>10.5}");
    }

    println!("\n-- Fig.4-style confidence evolution --");
    for snap in &tr.rec.conf_snaps {
        let mut h = Histogram::new(0.0, 1.0, 20);
        h.counts = snap.conf_hist.iter().map(|&f| (f * 1e6) as u64).collect();
        println!(
            "step {:>5}  mean conf {:.4}  [0..1] {}",
            snap.step,
            snap.mean_conf,
            h.sparkline()
        );
    }

    println!("\n-- Fig.6-style oscillating weights (R_w > 16) --");
    for &(s, count, win) in &tr.rec.osc_series {
        println!("step {s:>5}: {count} oscillating / window {win}");
    }

    // The mirror the metrics above ran on is packed 4-bit codes, not a
    // second f32 copy of the weights; show what that buys.
    tr.mirror_wq();
    let packed_bytes: usize = tr.packed_wq().iter().map(|p| p.bytes()).sum();
    let f32_bytes = tr.wq().len() * std::mem::size_of::<f32>();
    if packed_bytes > 0 {
        println!(
            "\n-- packed quant mirror --\n{} segments, {:.1} KiB packed codes+scales \
             vs {:.1} KiB f32 mirror ({:.1}x smaller)",
            tr.packed_wq().len(),
            packed_bytes as f64 / 1024.0,
            f32_bytes as f64 / 1024.0,
            f32_bytes as f64 / packed_bytes as f64
        );
    }

    // Fig.3: concrete flipping elements across more steps.
    let (_, conf) = tr.snapshot_latents();
    let mut idx: Vec<usize> = (0..conf.len()).collect();
    idx.sort_by(|&a, &b| conf[a].partial_cmp(&conf[b]).unwrap());
    let tracked = &idx[..4];
    println!("\n-- Fig.3-style trajectories (4 least-confident elements, 12 steps) --");
    println!("{:>6} {:>32}", "step", "latent w/S (per element)");
    for _ in 0..12 {
        tr.step()?;
        let (lat, _) = tr.snapshot_latents();
        let vals: Vec<String> = tracked.iter().map(|&i| format!("{:+.4}", lat[i])).collect();
        println!("{:>6} {}", tr.state.step, vals.join("  "));
    }
    tr.rec.save_json(std::path::Path::new("results/oscillation_analysis.json"))?;
    println!("\nfull series saved to results/oscillation_analysis.json");
    Ok(())
}
