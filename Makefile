# Builder entry points. `make tier1` is the repo's tier-1 verify plus
# the format gate, in one command.

RUST_DIR := rust

.PHONY: tier1 build test fmt fmt-check bench loadtest-smoke obs-smoke report-smoke artifacts

# `cargo bench --no-run` keeps the bench code compiling without paying
# for a full measurement sweep. The second test run forces the scalar
# kernel (`TJ_SIMD=off`) so the dispatch fallback path stays green on
# hosts where it would otherwise never execute, and widens the packed /
# serve property tests to extra group geometries (`TJ_GEOM_SWEEP=1`
# adds 1x8/1x16 E8M0 and 1x32 E4M3 to the default MX + NVFP4 pair).
tier1:
	cd $(RUST_DIR) && cargo build --release && cargo test -q && cargo bench --no-run && cargo fmt --check
	cd $(RUST_DIR) && TJ_SIMD=off TJ_GEOM_SWEEP=1 cargo test -q
	$(MAKE) loadtest-smoke
	$(MAKE) obs-smoke
	$(MAKE) report-smoke

# 2-engine continuous-batching smoke: ~200 virtual-pace Poisson
# requests against a seeded synthetic model (no artifacts needed),
# emitting the BENCH json + regression comparison in a few seconds.
loadtest-smoke:
	cd $(RUST_DIR) && cargo run --release --quiet -- serve --synthetic tiny \
	  --engines 2 --micro-batch 8 --workers 2 --queue-depth 64 \
	  --requests 200 --request-size 2 --rate 400 --seed 0 \
	  --pace virtual --service-ms 0.5 --load-test

# Same deterministic load test but with the observability surface on:
# request trace JSONL + metrics snapshot, then schema-validate both
# (parseable trace lines, stable metric names, recomputed digest).
obs-smoke:
	cd $(RUST_DIR) && cargo run --release --quiet -- serve --synthetic tiny \
	  --engines 2 --micro-batch 8 --workers 2 --queue-depth 64 \
	  --requests 200 --request-size 2 --rate 400 --seed 0 \
	  --pace virtual --service-ms 0.5 --load-test \
	  --trace-out results/obs_smoke_trace.jsonl \
	  --metrics-out results/obs_smoke_metrics.json
	cd $(RUST_DIR) && cargo run --release --quiet -- obs-validate \
	  --trace results/obs_smoke_trace.jsonl \
	  --snapshot results/obs_smoke_metrics.json

# Oscillation-observatory smoke (no artifacts needed): two identical
# tiny synthetic train runs must produce byte-identical OSCLOG01 files
# (the digest-stability gate), an NVFP4 run exercises the second group
# geometry, `report` replays the artifact offline, and obs-validate
# schema-checks both the OSCLOG and the OSCREPORT01 json.
report-smoke:
	cd $(RUST_DIR) && cargo run --release --quiet -- train --synthetic tiny \
	  --variant mx --steps 60 --osc-window 10 --seed 0 \
	  --osc-out results/report_smoke_a.osclog \
	  --trace-out results/report_smoke_trace.jsonl
	cd $(RUST_DIR) && cargo run --release --quiet -- train --synthetic tiny \
	  --variant mx --steps 60 --osc-window 10 --seed 0 \
	  --osc-out results/report_smoke_b.osclog
	cmp $(RUST_DIR)/results/report_smoke_a.osclog $(RUST_DIR)/results/report_smoke_b.osclog
	cd $(RUST_DIR) && cargo run --release --quiet -- train --synthetic tiny \
	  --variant nvfp4 --steps 60 --osc-window 10 --seed 0 \
	  --osc-out results/report_smoke_nvfp4.osclog
	cd $(RUST_DIR) && cargo run --release --quiet -- report \
	  --osclog results/report_smoke_a.osclog \
	  --compare results/report_smoke_nvfp4.osclog \
	  --top 5 --json results/report_smoke.json > results/report_smoke.md
	cd $(RUST_DIR) && cargo run --release --quiet -- obs-validate \
	  --osclog results/report_smoke_a.osclog \
	  --report results/report_smoke.json \
	  --trace results/report_smoke_trace.jsonl

build:
	cd $(RUST_DIR) && cargo build --release

test:
	cd $(RUST_DIR) && cargo test -q

fmt:
	cd $(RUST_DIR) && cargo fmt

fmt-check:
	cd $(RUST_DIR) && cargo fmt --check

bench:
	cd $(RUST_DIR) && cargo bench

# AOT-export HLO artifacts + golden vectors (needs python with jax).
artifacts:
	cd python && python -m compile.aot --core --out ../$(RUST_DIR)/artifacts
