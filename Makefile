# Builder entry points. `make tier1` is the repo's tier-1 verify plus
# the format gate, in one command.

RUST_DIR := rust

.PHONY: tier1 build test fmt fmt-check bench artifacts

# `cargo bench --no-run` keeps the bench code compiling without paying
# for a full measurement sweep.
tier1:
	cd $(RUST_DIR) && cargo build --release && cargo test -q && cargo bench --no-run && cargo fmt --check

build:
	cd $(RUST_DIR) && cargo build --release

test:
	cd $(RUST_DIR) && cargo test -q

fmt:
	cd $(RUST_DIR) && cargo fmt

fmt-check:
	cd $(RUST_DIR) && cargo fmt --check

bench:
	cd $(RUST_DIR) && cargo bench

# AOT-export HLO artifacts + golden vectors (needs python with jax).
artifacts:
	cd python && python -m compile.aot --core --out ../$(RUST_DIR)/artifacts
