//! Integration tests for the oscillation observatory: OSCLOG01
//! artifacts written through the synthetic trainer, the offline
//! `report` analyzer recovering the trainer's gauges bit-exactly, and
//! boundedness of the telemetry surface over long runs.

use std::path::{Path, PathBuf};

use tetrajet::config::MetricsCfg;
use tetrajet::coordinator::SynthTrainer;
use tetrajet::obs::osclog::OscLogWriter;
use tetrajet::obs::{MetricsRegistry, SERIES_DEFAULT_CAP};
use tetrajet::report;

fn metrics(window: usize) -> MetricsCfg {
    MetricsCfg {
        rate_window: 0,
        probe_every: 0,
        osc_window: window,
        rw_threshold: 16.0,
        conf_every: 0,
    }
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("tj-osclog-it-{}-{name}", std::process::id()))
}

#[test]
fn osclog_artifact_is_byte_identical_across_reruns_for_both_mirrors() {
    for variant in ["mx", "nvfp4"] {
        let run = |path: &Path| {
            let mut t = SynthTrainer::new("tiny", variant, 42, metrics(10)).unwrap();
            t.attach_osclog(OscLogWriter::to_file(path).unwrap());
            t.run(30).unwrap().osclog.unwrap()
        };
        let (pa, pb) = (tmp(&format!("{variant}-a.osclog")), tmp(&format!("{variant}-b.osclog")));
        let (la, da) = run(&pa);
        let (lb, db) = run(&pb);
        assert_eq!((la, &da), (lb, &db), "{variant}: fixed (seed, config) must be stable");
        assert_eq!(
            std::fs::read(&pa).unwrap(),
            std::fs::read(&pb).unwrap(),
            "{variant}: the files themselves must be byte-identical"
        );
        // The offline loader recomputes the same digest from the bytes.
        let log = report::load_osclog(&pa).unwrap();
        assert_eq!(log.digest, da, "{variant}: loader digest must match the writer's");
        assert_eq!(log.lines, la);
        let _ = std::fs::remove_file(&pa);
        let _ = std::fs::remove_file(&pb);
    }
}

#[test]
fn report_recovers_the_trainer_osc_ratio_bit_exactly() {
    let path = tmp("ratio.osclog");
    let mut t = SynthTrainer::new("tiny", "nvfp4", 7, metrics(10)).unwrap();
    t.attach_osclog(OscLogWriter::to_file(&path).unwrap());
    let run = t.run(35).unwrap();
    assert!(!run.windows.is_empty(), "35 steps at window 10 must close windows");
    let gauge = t.registry().gauge("train.osc.ratio").get();

    let log = report::load_osclog(&path).unwrap();
    let rep = report::analyze(&log, 4);
    assert_eq!(rep.osc_fraction, gauge, "artifact replay must equal the live gauge bit-exactly");
    assert_eq!(rep.windows, run.windows.len());
    assert_eq!(rep.osc_count, run.windows.last().unwrap().1);
    assert_eq!(rep.total, run.qw_total);
    // The distributions partition the same flips: every segment is in
    // exactly one depth and one kind bucket.
    assert_eq!(rep.segs.len(), run.segments);
    assert!(rep.by_depth.iter().map(|(d, _)| d).all(|&d| d >= 0));
    assert!(!rep.by_kind.is_empty());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn observatory_keeps_step_flip_telemetry_in_a_bounded_ring() {
    let mut t = SynthTrainer::new("tiny", "mx", 3, metrics(8)).unwrap();
    t.attach_osclog(OscLogWriter::in_memory());
    t.run(60).unwrap();
    let ring = t.registry().ring("train.osc.step_flips", 1);
    // Step 0 seeds the tracker; every later step records one sample.
    assert_eq!(ring.count(), 59);
    assert!(ring.len() <= ring.capacity());
}

#[test]
fn telemetry_surface_does_not_grow_with_run_length() {
    // The 10k-step boundedness gate: rings and series are fixed-size,
    // so the registry snapshot stops growing once windows fill.
    let reg = MetricsRegistry::new();
    let ring = reg.ring("train.osc.step_flips", 256);
    let series = reg.series("train.step_ms");
    let mut mid = 0usize;
    for i in 0..10_000u64 {
        ring.push(i as f64);
        series.record(i as f64);
        if i == 4_999 {
            mid = reg.snapshot_json().to_string().len();
        }
    }
    assert_eq!(ring.count(), 10_000);
    assert!(ring.len() <= 256);
    assert!(series.len() <= SERIES_DEFAULT_CAP);
    let end = reg.snapshot_json().to_string().len();
    assert!(
        end.abs_diff(mid) < 64,
        "snapshot size must not scale with steps: {mid} bytes at 5k vs {end} at 10k"
    );
}
