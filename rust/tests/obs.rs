//! Integration tests for the observability subsystem: deterministic
//! trace digests under virtual pace, bit-exactness of the instrumented
//! fleet forward, registry snapshot schema stability (the contract
//! `obs-validate` checks), and the live TCP metrics endpoint.

use tetrajet::obs::{spawn_metrics_endpoint, MetricsRegistry, TraceSink};
use tetrajet::serve::{
    run_load_test, ActQuant, LatencySummary, LoadReport, LoadSpec, Pace, PackedVit,
    ServeConfig, ServeFleet, ServeGeom, WeightQuant,
};
use tetrajet::util::rng::Rng;

fn tiny_vit(seed: u64) -> PackedVit {
    let geom = ServeGeom::new(8, 4, 32, 2, 4, 3, 4);
    let mut rng = Rng::new(seed);
    let params: Vec<f32> = (0..geom.total_params()).map(|_| rng.normal() * 0.05).collect();
    let fmt = tetrajet::quant::e2m1();
    let scaling = tetrajet::quant::Scaling::TruncationFree;
    PackedVit::build(
        geom,
        &params,
        None,
        WeightQuant::Mx { fmt, scaling },
        ActQuant::Mx { fmt, scaling },
    )
    .unwrap()
}

fn fleet_cfg(engines: usize) -> ServeConfig {
    ServeConfig::builder()
        .micro_batch(8)
        .workers(1)
        .engines(engines)
        .queue_depth(32)
        .build()
        .unwrap()
}

/// One traced virtual-pace load run; everything returned must be a
/// pure function of the arguments.
fn traced_run(model_seed: u64, load_seed: u64) -> (String, u64, LatencySummary, LoadReport) {
    let vit = tiny_vit(model_seed);
    let px = vit.geom.img * vit.geom.img * 3;
    let mut fleet = ServeFleet::new(vit, fleet_cfg(2)).unwrap();
    fleet.set_trace(TraceSink::in_memory(true));
    let spec = LoadSpec {
        seed: load_seed,
        requests: 60,
        request_size: 2,
        rate_rps: 500.0,
        deadline_ms: Some(40.0),
        pace: Pace::Virtual { ms_per_image: 0.5 },
    };
    let base = Rng::new(load_seed).fold_in(0x494d47);
    let report = run_load_test(&mut fleet, &spec, |i| {
        let mut rng = base.fold_in(i as u64);
        ((0..2 * px).map(|_| rng.uniform() * 2.0 - 1.0).collect(), Vec::new())
    })
    .unwrap();
    let trace = fleet.take_trace().unwrap();
    (trace.digest(), trace.events(), fleet.stats(), report)
}

#[test]
fn virtual_pace_trace_digest_is_byte_identical_across_runs() {
    let (d1, e1, s1, r1) = traced_run(3, 11);
    let (d2, e2, s2, r2) = traced_run(3, 11);
    assert!(e1 > 0, "a 60-request run must emit trace events");
    assert_eq!(d1, d2, "same (seed, config) must replay to the same trace bytes");
    assert_eq!(e1, e2);
    assert_eq!(s1, s2, "latency summary must be deterministic too");
    assert_eq!(
        (r1.accepted, r1.rejected, r1.expired, r1.completed),
        (r2.accepted, r2.rejected, r2.expired, r2.completed)
    );
    // A different arrival seed must perturb the trace.
    let (d3, _, _, _) = traced_run(3, 12);
    assert_ne!(d1, d3);
}

#[test]
fn instrumented_fleet_logits_stay_bit_exact_to_single_engine() {
    let vit = tiny_vit(4);
    let px = vit.geom.img * vit.geom.img * 3;
    let n = 5;
    let mut rng = Rng::new(21);
    let x: Vec<f32> = (0..n * px).map(|_| rng.normal()).collect();
    let want = vit.forward(&x, n, 1);

    let mut fleet = ServeFleet::new(vit, fleet_cfg(2)).unwrap();
    fleet.set_trace(TraceSink::in_memory(false));
    fleet.set_snapshot_every(0);
    let got = fleet.infer_logits(x, n).unwrap();
    assert_eq!(got, want, "tracing + metrics must not perturb the forward");
    assert!(fleet.registry().counter("kernel.qkv.calls").get() > 0);
}

#[test]
fn registry_snapshot_has_the_stable_obs_validate_schema() {
    let vit = tiny_vit(5);
    let px = vit.geom.img * vit.geom.img * 3;
    let mut fleet = ServeFleet::new(vit, fleet_cfg(2)).unwrap();
    fleet.infer_logits(vec![0.1; 3 * px], 3).unwrap();

    let snap = fleet.registry().snapshot_json();
    for section in ["counters", "gauges", "hists", "series", "rings"] {
        assert!(snap.get(section).is_some(), "snapshot missing {section}");
    }
    // The names `tetrajet obs-validate --snapshot` requires.
    let counters = snap.get("counters").unwrap();
    for name in [
        "sched.admits",
        "sched.rejects",
        "sched.expiries",
        "serve.images",
        "serve.batches",
        "serve.busy_ms",
        "fleet.steps",
        "fleet.gather_wait_ms",
        "kernel.qkv.calls",
    ] {
        assert!(counters.get(name).is_some(), "snapshot missing counters.{name}");
    }
    assert!(snap.get("gauges").unwrap().get("sched.queue_depth").is_some());
    assert!(snap.get("hists").unwrap().get("fleet.batch_images").is_some());
    assert!(snap.get("series").unwrap().get("serve.latency_ms").is_some());
    let rings = snap.get("rings").unwrap();
    assert!(rings.get("fleet.engine0.busy_ratio").is_some());
    assert!(rings.get("sched.queue_depth.recent").is_some());
    // And the summary view over those cells agrees with fleet.stats().
    assert_eq!(fleet.stats(), LatencySummary::from_registry(fleet.registry(), "serve"));
}

#[test]
fn metrics_endpoint_serves_the_live_registry() {
    use std::io::{Read, Write};

    let reg = MetricsRegistry::new();
    reg.counter("fleet.steps").add(3);
    let addr = spawn_metrics_endpoint("127.0.0.1:0", reg.clone()).unwrap();
    reg.counter("fleet.steps").add(4);

    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    conn.write_all(b"GET / HTTP/1.0\r\n\r\n").unwrap();
    let mut resp = String::new();
    conn.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.0 200"), "{resp}");
    assert!(resp.contains("fleet.steps 7"), "endpoint must see live updates: {resp}");
}
