//! Cross-layer golden test: the pure-Rust quant mirror must reproduce
//! the python oracle (`kernels/ref.py`) bit-for-bit on the vectors
//! exported by `python -m compile.aot` (`make artifacts`).
//!
//! This is the contract that lets the coordinator compute quantized-
//! weight trajectories (R_w, confidence, rate-of-change) without
//! bouncing through XLA.

use std::path::PathBuf;

use tetrajet::quant::{
    fp4_format, int4_quantize, mx_quantize_cols, mx_quantize_stoch_cols,
    qema_quantize_cols, Scaling,
};
use tetrajet::util::json::Json;

fn golden_path() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/golden/quant_vectors.json");
    p.exists().then_some(p)
}

#[test]
fn golden_vectors_match_python_oracle() {
    let Some(path) = golden_path() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let cases = j.req("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 18, "unexpectedly few golden cases: {}", cases.len());
    let mut checked = 0usize;
    for c in cases {
        let kind = c.req("kind").unwrap().as_str().unwrap();
        let shape = c.req("shape").unwrap().as_usize_vec().unwrap();
        let cols = shape[1];
        let x = c.req("x").unwrap().as_f32_vec().unwrap();
        let u = c.req("u").unwrap().as_f32_vec().unwrap();
        let want = c.req("q").unwrap().as_f32_vec().unwrap();
        let rounding = c.req("rounding").unwrap().as_str().unwrap();
        let tag = c.req("tag").unwrap().as_str().unwrap();
        let got: Vec<f32> = match kind {
            "mx" => {
                let fmt = fp4_format(c.req("fmt").unwrap().as_str().unwrap()).unwrap();
                let scaling =
                    Scaling::parse(c.req("scaling").unwrap().as_str().unwrap()).unwrap();
                if rounding == "det" {
                    mx_quantize_cols(&x, cols, fmt, scaling)
                } else {
                    mx_quantize_stoch_cols(&x, &u, cols, fmt, scaling)
                }
            }
            "qema" => {
                let fmt = fp4_format(c.req("fmt").unwrap().as_str().unwrap()).unwrap();
                // the 'u' slot carries the EMA weights for qema cases
                qema_quantize_cols(&x, &u, cols, fmt, Scaling::TruncationFree)
            }
            "int4" => {
                if rounding == "det" {
                    int4_quantize(&x, None)
                } else {
                    int4_quantize(&x, Some(&u))
                }
            }
            other => panic!("unknown golden kind {other}"),
        };
        assert_eq!(got.len(), want.len());
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            // Bit-exact comparison modulo -0.0 == 0.0 (json strips the
            // sign of negative zero anyway).
            assert!(
                g == w || (g == 0.0 && w == 0.0),
                "case kind={kind} rounding={rounding} tag={tag} idx={i}: \
                 rust {g:?} != python {w:?} (x={})",
                x[i]
            );
        }
        checked += 1;
    }
    println!("verified {checked} golden cases bit-exactly");
}
