//! Integration tests for the packed serving subsystem: fused-kernel
//! bit-exactness (property-tested over random/ragged shapes), the
//! TJCKPT02 checkpoint -> manifest -> engine path, eval parity between
//! the fused and dequant-mirror forwards, and the no-f32-mirror memory
//! guarantee.

use tetrajet::coordinator::{PackedSeg, TrainState};
use tetrajet::data::{EvalSet, SynthVision};
use tetrajet::quant::{
    e2m1, e3m0, GroupGeom, Int4Quantizer, MxQuantizer, NvQuantizer, PackedMx, Quantizer, Scaling,
};
use tetrajet::runtime::Manifest;
use tetrajet::serve::{
    dense_matmul_at, fused_matmul, fused_matmul_at, matmul_ref, simd, PackedVit, ServeConfig,
    ServeEngine, ServeGeom, ServeSession, SimdLevel,
};
use tetrajet::testing::{check, gen_f32_vec};
use tetrajet::util::json::Json;
use tetrajet::util::rng::Rng;

#[test]
fn prop_fused_matmul_equals_dequant_then_matmul() {
    // Random (n, d, rows) including ragged d (non-multiple-of-32
    // contraction axes) and random row sub-ranges of a stacked weight.
    check(
        "fused == dequant+matmul",
        60,
        |r| {
            let d = [32usize, 48, 57, 64, 96][r.below(5)];
            let n = 1 + r.below(5);
            let rows = 1 + r.below(12);
            let x = gen_f32_vec(r, n * d, 1.0);
            let w = gen_f32_vec(r, rows * d, 0.5);
            let bias = gen_f32_vec(r, rows, 0.1);
            let with_bias = r.below(2) == 0;
            let row0 = r.below(rows);
            (d, n, rows, x, w, bias, with_bias, row0)
        },
        |(d, n, rows, x, w, bias, with_bias, row0)| {
            let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
            let mut p = PackedMx::default();
            q.quantize_packed(w, *d, &mut p);
            let wq = p.dequantize();
            let sub = *rows - *row0;
            let b = with_bias.then_some(&bias[*row0..]);
            let want = matmul_ref(x, *n, *d, &wq[row0 * d..rows * d], sub, b);
            (1..=3).all(|workers| {
                fused_matmul(x, *n, &p, *row0, sub, b, workers) == want
            })
        },
    );
}

#[test]
fn prop_every_dispatch_level_is_bit_identical() {
    // The same seeded (x, w, bias) through the scalar, SSSE3, and AVX2
    // kernels (skipping levels the host lacks) over ragged contraction
    // dims, row sub-ranges, and MX (both formats) + INT4 packings —
    // all dispatch levels and both kernels must agree byte for byte.
    check(
        "scalar == ssse3 == avx2 (fused and dense)",
        48,
        |r| {
            let d = [32usize, 48, 57, 64, 96][r.below(5)];
            let n = 1 + r.below(4);
            let rows = 1 + r.below(10);
            let x = gen_f32_vec(r, n * d, 1.0);
            let w = gen_f32_vec(r, rows * d, 0.5);
            let bias = gen_f32_vec(r, rows, 0.1);
            let with_bias = r.below(2) == 0;
            let row0 = r.below(rows);
            let packing = r.below(3); // 0 = e2m1, 1 = e3m0, 2 = int4
            (d, n, rows, x, w, bias, with_bias, row0, packing)
        },
        |(d, n, rows, x, w, bias, with_bias, row0, packing)| {
            let mut p = PackedMx::default();
            match *packing {
                0 => MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree }
                    .quantize_packed(w, *d, &mut p),
                1 => MxQuantizer { fmt: e3m0(), scaling: Scaling::Floor }
                    .quantize_packed(w, *d, &mut p),
                _ => Int4Quantizer.quantize_packed(w, *d, &mut p),
            }
            let sub = *rows - *row0;
            let b = with_bias.then_some(&bias[*row0..]);
            let want = fused_matmul_at(SimdLevel::Off, x, *n, &p, *row0, sub, b, 1);
            let wq = p.dequantize();
            let wsub = &wq[row0 * d..rows * d];
            let dense_off = dense_matmul_at(SimdLevel::Off, x, *n, *d, wsub, sub, b, 1);
            // Scalar fused == scalar dense over the dequantized rows.
            if want != dense_off {
                return false;
            }
            [SimdLevel::Ssse3, SimdLevel::Avx2].iter().all(|&l| {
                !simd::available(l)
                    || (fused_matmul_at(l, x, *n, &p, *row0, sub, b, 2) == want
                        && dense_matmul_at(l, x, *n, *d, wsub, sub, b, 2) == want)
            })
        },
    );
}

#[test]
fn tj_simd_env_override_is_respected() {
    // `make tier1` runs this suite a second time under TJ_SIMD=off; in
    // that run this asserts the scalar fallback is what dispatches. In
    // a plain run it asserts the probe's answer is what dispatches.
    match std::env::var("TJ_SIMD") {
        Ok(v) => {
            if let Some(want) = SimdLevel::parse(&v) {
                assert_eq!(simd::active(), want.min(simd::detected()));
            }
        }
        Err(_) => assert_eq!(simd::active(), simd::detected()),
    }
    // The scalar fallback is reachable on any host, env var or not.
    assert!(simd::available(SimdLevel::Off));
}

fn tiny_geom() -> ServeGeom {
    ServeGeom::new(8, 4, 32, 2, 4, 3, 4)
}

fn random_params(geom: &ServeGeom, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut p: Vec<f32> = (0..geom.total_params()).map(|_| rng.normal() * 0.08).collect();
    // Layer-norm gains near 1 keep activations in a sane range.
    for spec in geom.param_spec() {
        if spec.name.ends_with(".g") {
            for v in &mut p[spec.range()] {
                *v = 1.0 + *v * 0.1;
            }
        }
    }
    p
}

/// Serialize a [`ServeGeom`]'s layout as a manifest JSON (what aot.py
/// would emit for this model), so the manifest-driven serving path is
/// testable without artifacts.
fn manifest_for(geom: &ServeGeom, kind: &str, qema: bool) -> Manifest {
    let segs: Vec<String> = geom
        .param_spec()
        .iter()
        .map(|s| {
            format!(
                r#"{{"name":"{}","shape":[{}],"offset":{},"size":{},"quantized":{}}}"#,
                s.name,
                s.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(","),
                s.offset,
                s.size,
                s.quantized
            )
        })
        .collect();
    let text = format!(
        r#"{{
          "model": {{"name":"vit-nano","img":{img},"patch":{patch},"dim":{dim},
                    "depth":{depth},"heads":{heads},"classes":{classes},"seq":{seq}}},
          "variant": {{"name":"tetrajet","kind":"{kind}","fwd_fmt":"e2m1",
                      "bwd_fmt":"e2m1","scaling":"tf","bwd_rounding":"stoch",
                      "flow":"double","qema":{qema},
                      "enabled":[true,true,true,true,true,true],"impl":"ref"}},
          "batch": 4,
          "probe_block": 0,
          "params": {{"total": {total}, "qw_total": {qw}, "segments": [{segs}]}},
          "train_step": {{"inputs":[],"outputs":[]}},
          "eval_step": {{"inputs":[],"outputs":[]}},
          "probe": {{"inputs":[],"outputs":[]}}
        }}"#,
        img = geom.img,
        patch = geom.patch,
        dim = geom.dim,
        depth = geom.depth,
        heads = geom.heads,
        classes = geom.classes,
        seq = geom.seq,
        total = geom.total_params(),
        qw = geom.qw_total(),
        segs = segs.join(","),
    );
    Manifest::from_json(&Json::parse(&text).unwrap()).unwrap()
}

#[test]
fn geom_roundtrips_through_manifest() {
    let geom = tiny_geom();
    let man = manifest_for(&geom, "mx", false);
    let back = ServeGeom::from_manifest(&man).unwrap();
    assert_eq!(back.total_params(), geom.total_params());
    assert_eq!(back.qw_total(), geom.qw_total());
    assert_eq!(back.hidden, geom.hidden);
    assert_eq!(back.seq, geom.seq);
}

/// Quantize a parameter vector's quantized prefix the way the trainer
/// mirror does: one PackedMx per stacked weight segment.
fn trainer_style_packed_with(
    geom: &ServeGeom,
    params: &[f32],
    q: &dyn Quantizer,
) -> Vec<PackedSeg> {
    geom.param_spec()
        .iter()
        .filter(|s| s.quantized)
        .map(|s| {
            let mut p = PackedMx::default();
            q.quantize_packed(&params[s.range()], s.cols(), &mut p);
            PackedSeg { name: s.name.to_string(), offset: s.offset, packed: p }
        })
        .collect()
}

fn trainer_style_packed(geom: &ServeGeom, params: &[f32]) -> Vec<PackedSeg> {
    let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
    trainer_style_packed_with(geom, params, &q)
}

#[test]
fn tjckpt02_to_engine_end_to_end() {
    let geom = tiny_geom();
    let man = manifest_for(&geom, "mx", false);
    let params = random_params(&geom, 1);
    let packed = trainer_style_packed(&geom, &params);

    let mut state = TrainState::new(params.clone(), geom.qw_total());
    state.step = 123;
    let dir = std::env::temp_dir().join("tj_serve_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("e2e.ckpt");
    state.save_packed(&path, &packed).unwrap();

    let (loaded, segs) = TrainState::load_with_packed(&path).unwrap();
    assert_eq!(loaded.step, 123);
    assert_eq!(segs.len(), 4);
    let from_codes =
        PackedVit::from_checkpoint(&man, &loaded.params, Some(&loaded.ema), &segs).unwrap();
    assert!(from_codes.is_fully_packed());

    // The codes loaded from disk must drive the exact same forward as
    // re-quantizing the f32 parameters from scratch.
    let from_params = PackedVit::from_checkpoint(&man, &params, None, &[]).unwrap();
    let mut rng = Rng::new(5);
    let x: Vec<f32> = (0..2 * geom.img * geom.img * 3).map(|_| rng.normal()).collect();
    assert_eq!(from_codes.forward(&x, 2, 2), from_params.forward(&x, 2, 1));
    std::fs::remove_file(&path).ok();
}

#[test]
fn packed_eval_matches_mirror_eval_bit_exact() {
    let geom = tiny_geom();
    let man = manifest_for(&geom, "mx", false);
    let params = random_params(&geom, 2);
    let vit = PackedVit::from_checkpoint(&man, &params, None, &[]).unwrap();
    let cfg = ServeConfig::builder().micro_batch(4).workers(2).build().unwrap();
    let fused = ServeEngine::new(vit.clone(), cfg).unwrap();
    let mirror = ServeEngine::new(vit.to_dense(), cfg).unwrap();

    let ds = SynthVision::new(geom.img, geom.classes, 7, 128, 64);
    let evalset = EvalSet::new(ds, 4, 32);
    let a = fused.eval(&evalset);
    let b = mirror.eval(&evalset);
    assert_eq!(a.samples, 32);
    assert_eq!(
        (a.acc_pct, a.mean_loss),
        (b.acc_pct, b.mean_loss),
        "fused/packed eval must be bit-identical to the f32-mirror eval"
    );
}

#[test]
fn engine_never_materializes_f32_weight_mirror() {
    let geom = tiny_geom();
    let man = manifest_for(&geom, "mx", false);
    let params = random_params(&geom, 3);
    let vit = PackedVit::from_checkpoint(&man, &params, None, &[]).unwrap();
    let engine =
        ServeEngine::new(vit, ServeConfig::builder().micro_batch(2).workers(1).build().unwrap())
            .unwrap();
    // Resident quantized-weight state is exactly codes + scale bytes:
    // 0.5 B/element + 1 B per 32-element group (dims here are multiples
    // of 32, so no ragged groups).
    let qw = geom.qw_total();
    assert_eq!(engine.resident_weight_bytes(), qw / 2 + qw / 32);
    assert!(
        engine.resident_weight_bytes() * 7 < qw * std::mem::size_of::<f32>(),
        "packed resident size must stay >7x below an f32 mirror"
    );
    // ...and a forward pass does not change that.
    let x = vec![0.25f32; geom.img * geom.img * 3];
    let logits = engine.infer_logits(&x, 1);
    assert_eq!(logits.len(), geom.classes);
    assert_eq!(engine.resident_weight_bytes(), qw / 2 + qw / 32);
}

#[test]
#[allow(deprecated)] // exercises the PR 5 submit/flush shim end to end
fn session_micro_batches_across_requests() {
    let geom = tiny_geom();
    let man = manifest_for(&geom, "mx", false);
    let params = random_params(&geom, 4);
    let vit = PackedVit::from_checkpoint(&man, &params, None, &[]).unwrap();
    let cfg = ServeConfig::builder().micro_batch(4).workers(2).build().unwrap();
    let engine = ServeEngine::new(vit.clone(), cfg).unwrap();
    let oracle = ServeEngine::new(vit, cfg).unwrap();

    let px = 8 * 8 * 3;
    let mut rng = Rng::new(9);
    let mut sess = ServeSession::new(engine);
    let mut all = Vec::new();
    for n in [1usize, 5, 2] {
        let imgs: Vec<f32> = (0..n * px).map(|_| rng.normal()).collect();
        all.extend_from_slice(&imgs);
        sess.submit(imgs, n).unwrap();
    }
    let rs = sess.flush();
    let flat: Vec<usize> = rs.iter().flat_map(|r| r.preds.clone()).collect();
    assert_eq!(flat, oracle.predict(&all, 8));
    assert_eq!(sess.stats().batches, 2); // ceil(8 / 4)
    assert_eq!(sess.stats().images, 8);
}

#[test]
fn qema_and_int4_variants_serve() {
    let geom = tiny_geom();
    let params = random_params(&geom, 6);
    let ema: Vec<f32> = params[..geom.qw_total()].iter().map(|v| v * 0.95).collect();

    let man = manifest_for(&geom, "mx", true); // tetrajet_qema-style
    let vit = PackedVit::from_checkpoint(&man, &params, Some(&ema), &[]).unwrap();
    assert!(vit.is_fully_packed());
    let x = vec![0.1f32; geom.img * geom.img * 3];
    assert_eq!(vit.forward(&x, 1, 1), vit.to_dense().forward(&x, 1, 2));

    let man = manifest_for(&geom, "int4", false);
    let vit = PackedVit::from_checkpoint(&man, &params, None, &[]).unwrap();
    assert!(vit.is_fully_packed());
    assert_eq!(vit.forward(&x, 1, 1), vit.to_dense().forward(&x, 1, 2));

    let man = manifest_for(&geom, "fp32", false);
    let vit = PackedVit::from_checkpoint(&man, &params, None, &[]).unwrap();
    assert!(!vit.is_fully_packed(), "fp32 variant has no packed form");
    assert!(vit.forward(&x, 1, 1).iter().all(|v| v.is_finite()));
}

#[test]
fn nvfp4_variant_serves_end_to_end() {
    // The full NVFP4 path: trainer-style 16-element/E4M3 packed mirror
    // -> TJCKPT02 (with geometry byte) -> from_checkpoint -> engine,
    // bit-exact to re-quantizing from f32 and to the dense mirror.
    let geom = tiny_geom();
    let man = manifest_for(&geom, "nvfp4", false);
    let params = random_params(&geom, 11);
    let packed = trainer_style_packed_with(&geom, &params, &NvQuantizer::nvfp4());
    assert!(packed.iter().all(|s| s.packed.geom() == GroupGeom::nvfp4()));

    let mut state = TrainState::new(params.clone(), geom.qw_total());
    state.step = 321;
    let dir = std::env::temp_dir().join("tj_serve_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("nv_e2e.ckpt");
    state.save_packed(&path, &packed).unwrap();

    let (loaded, segs) = TrainState::load_with_packed(&path).unwrap();
    assert_eq!(segs.len(), 4);
    assert!(segs.iter().all(|s| s.packed.geom() == GroupGeom::nvfp4()));
    let from_codes =
        PackedVit::from_checkpoint(&man, &loaded.params, None, &segs).unwrap();
    assert!(from_codes.is_fully_packed());
    // 16-element groups: 0.5 B/element codes + 1 B per 16 elements.
    let qw = geom.qw_total();
    assert_eq!(from_codes.quantized_weight_bytes(), qw / 2 + qw / 16);

    let from_params = PackedVit::from_checkpoint(&man, &params, None, &[]).unwrap();
    let mut rng = Rng::new(13);
    let x: Vec<f32> = (0..2 * geom.img * geom.img * 3).map(|_| rng.normal()).collect();
    let logits = from_codes.forward(&x, 2, 2);
    assert_eq!(logits, from_params.forward(&x, 2, 1));
    assert_eq!(logits, from_codes.to_dense().forward(&x, 2, 2));
    assert!(logits.iter().all(|v| v.is_finite()));
    std::fs::remove_file(&path).ok();
}

#[test]
fn nvfp4_group_geometry_mismatch_is_rejected() {
    // Same e2m1 level table on both sides — only the group geometry
    // differs — so this exercises the geometry check specifically.
    let geom = tiny_geom();
    let params = random_params(&geom, 12);
    let mx_packed = trainer_style_packed(&geom, &params);
    let nv_packed = trainer_style_packed_with(&geom, &params, &NvQuantizer::nvfp4());
    let man_nv = manifest_for(&geom, "nvfp4", false);
    let man_mx = manifest_for(&geom, "mx", false);
    assert!(PackedVit::from_checkpoint(&man_nv, &params, None, &mx_packed).is_err());
    assert!(PackedVit::from_checkpoint(&man_mx, &params, None, &nv_packed).is_err());
    // Matching pairs both load.
    assert!(PackedVit::from_checkpoint(&man_nv, &params, None, &nv_packed).is_ok());
    assert!(PackedVit::from_checkpoint(&man_mx, &params, None, &mx_packed).is_ok());
}

#[test]
fn wrong_variant_for_packed_checkpoint_is_rejected() {
    // e2m1 MX codes served under an int4 (different level table) or
    // fp32 (no packed form at all) manifest must fail loudly instead
    // of reporting silently wrong accuracy.
    let geom = tiny_geom();
    let params = random_params(&geom, 9);
    let packed = trainer_style_packed(&geom, &params);
    let man = manifest_for(&geom, "int4", false);
    assert!(PackedVit::from_checkpoint(&man, &params, None, &packed).is_err());
    let man = manifest_for(&geom, "fp32", false);
    assert!(PackedVit::from_checkpoint(&man, &params, None, &packed).is_err());
}

#[test]
fn checkpoint_with_wrong_geometry_is_rejected() {
    let geom = tiny_geom();
    let man = manifest_for(&geom, "mx", false);
    let params = random_params(&geom, 8);
    let mut packed = trainer_style_packed(&geom, &params);
    // Corrupt one segment's geometry: wrong cols.
    let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
    let spec = geom.param_spec();
    let s0 = &spec[0];
    let mut p = PackedMx::default();
    q.quantize_packed(&params[s0.range()], s0.cols() * 2, &mut p);
    packed[0].packed = p;
    assert!(PackedVit::from_checkpoint(&man, &params, None, &packed).is_err());
    // Missing segment.
    let missing = trainer_style_packed(&geom, &params)[1..].to_vec();
    assert!(PackedVit::from_checkpoint(&man, &params, None, &missing).is_err());
}
