//! Integration tests over the real AOT artifacts (skipped with a notice
//! if `make artifacts` hasn't been run). These exercise the manifest
//! contract, the runtime marshalling, a short live training run with
//! every coordinator policy, and the bit-exactness of the Rust weight
//! mirror against the HLO's own EMA/quantizer outputs.

use std::path::PathBuf;

use tetrajet::config::{MetricsCfg, Policy, TrainConfig};
use tetrajet::coordinator::Trainer;
use tetrajet::runtime::{artifacts, cpu_client, Manifest, ModelArtifacts};

fn root() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("vit-micro/b16/tetrajet/manifest.json").exists().then_some(p)
}

// PjRtClient is Rc-based (not Sync), so every test owns its client.
fn client() -> xla::PjRtClient {
    cpu_client().expect("pjrt client")
}

fn arts_with(client: &xla::PjRtClient, variant: &str) -> Option<ModelArtifacts> {
    let root = root()?;
    Some(
        ModelArtifacts::load(client, &root, "vit-micro", 16, variant)
            .expect("artifact load"),
    )
}

fn quick_cfg(variant: &str, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default_run(variant);
    cfg.steps = steps;
    cfg.warmup = 2;
    cfg.eval_samples = 64;
    cfg.train_size = 512;
    cfg
}

#[test]
fn manifest_matches_compiled_programs() {
    let Some(root) = root() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    for variant in ["tetrajet", "fp32"] {
        let man = Manifest::load(
            &root.join(format!("vit-micro/b16/{variant}/manifest.json")),
        )
        .unwrap();
        assert_eq!(man.variant.name, variant);
        assert_eq!(man.batch, 16);
        assert_eq!(man.train_step.inputs.len(), 16);
        assert_eq!(man.train_step.outputs.len(), 7);
        assert_eq!(man.eval_step.inputs.len(), 4);
        // Quantized prefix covers exactly the 4 stacked weight tensors.
        assert_eq!(man.quantized_segments().count(), 4);
        let qsum: usize = man.quantized_segments().map(|s| s.size).sum();
        assert_eq!(qsum, man.qw_total);
    }
}

#[test]
fn variant_names_match_python_registry() {
    // config::all_variants() must agree with the artifact tree layout
    // produced by the python registry (full build).
    let Some(root) = root() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let mut missing = Vec::new();
    for v in tetrajet::config::all_variants() {
        if !root.join(format!("vit-micro/b16/{v}/manifest.json")).exists() {
            missing.push(v);
        }
    }
    // Core is guaranteed; the ablation set needs `make artifacts-full`.
    let core_missing: Vec<_> = missing
        .iter()
        .filter(|v| tetrajet::config::CORE_VARIANTS.contains(&v.as_str()))
        .collect();
    assert!(core_missing.is_empty(), "core variants missing: {core_missing:?}");
    if !missing.is_empty() {
        eprintln!("note: ablation variants absent (run `make artifacts-full`): {missing:?}");
    }
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let Some(root) = root() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let client = client();
    let a = artifacts::run_init(&client, &root, "vit-micro", 0).unwrap();
    let b = artifacts::run_init(&client, &root, "vit-micro", 0).unwrap();
    let c = artifacts::run_init(&client, &root, "vit-micro", 1).unwrap();
    assert_eq!(a, b);
    assert_ne!(a, c);
    assert!(a.iter().all(|x| x.is_finite()));
    // LN gains initialized to 1 -> the vector is not all-near-zero.
    assert!(a.iter().filter(|&&x| x == 1.0).count() > 100);
}

#[test]
fn short_training_run_reduces_loss_and_is_deterministic() {
    let client = client();
    let Some(a) = arts_with(&client, "tetrajet") else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let root = root().unwrap();
    let params = artifacts::run_init(&client, &root, "vit-micro", 0).unwrap();

    // 30 steps: enough for a robust loss drop on the (deliberately
    // hard) SynthVision task; 12 was within batch noise.
    let run = |params: Vec<f32>| {
        // Long schedule horizon keeps the LR near base for all 30
        // steps; a stronger base LR gives a robust drop on the hard
        // SynthVision task.
        let mut cfg = quick_cfg("tetrajet", 1000);
        cfg.base_lr = 2e-3;
        let mut tr = Trainer::new(&a, cfg, params).unwrap();
        let mut losses = Vec::new();
        for _ in 0..30 {
            losses.push(tr.step().unwrap().0);
        }
        (losses, tr.state.params.clone())
    };
    let (l1, p1) = run(params.clone());
    let (l2, p2) = run(params);
    assert_eq!(l1, l2, "training must be bit-deterministic");
    assert_eq!(p1, p2);
    let first = l1[..5].iter().sum::<f32>() / 5.0;
    let last = l1[l1.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(last < first, "loss should drop: {first} -> {last}");
    assert!(l1.iter().all(|x| x.is_finite()));
}

#[test]
fn every_policy_trains_without_nans() {
    let client = client();
    let Some(a) = arts_with(&client, "tetrajet") else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let root = root().unwrap();
    let params = artifacts::run_init(&client, &root, "vit-micro", 0).unwrap();
    for policy in [
        Policy::None,
        Policy::QRamping { k1: 16.0, k2: 5.0, n_max: 16.0, t0: 3, t_update: 6 },
        Policy::Dampen { lambda: 1e-4 },
        Policy::Freeze { f_th: 0.2, t0: 3, t_update: 6 },
    ] {
        let mut cfg = quick_cfg("tetrajet", 14);
        cfg.policy = policy.clone();
        cfg.metrics = MetricsCfg::standard();
        let mut tr = Trainer::new(&a, cfg, params.clone()).unwrap();
        for _ in 0..14 {
            let (loss, _) = tr.step().unwrap();
            assert!(loss.is_finite(), "{policy:?} produced NaN loss");
        }
        let ev = tr.eval().unwrap();
        assert!(ev.acc_pct >= 0.0 && ev.acc_pct <= 100.0);
        if let Policy::QRamping { .. } = policy {
            assert!(tr.qramping_ref().unwrap().windows_completed >= 1);
        }
    }
}

#[test]
fn qramping_nw_reaches_the_hlo_and_slows_updates() {
    // With N_w = 4 for all elements (forced), quantized weights must
    // update only every 4th step.
    let client = client();
    let Some(a) = arts_with(&client, "tetrajet") else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let root = root().unwrap();
    let params = artifacts::run_init(&client, &root, "vit-micro", 0).unwrap();
    let mut tr = Trainer::new(&a, quick_cfg("tetrajet", 8), params).unwrap();
    tr.state.nw.iter_mut().for_each(|x| *x = 4.0);
    let mut changed = Vec::new();
    for _ in 0..8 {
        let before = tr.state.qw().to_vec();
        tr.step().unwrap();
        changed.push(tr.state.qw() != &before[..]);
    }
    // Steps are 0-indexed; (t+1) % 4 == 0 -> updates after steps 3, 7.
    assert_eq!(changed, vec![false, false, false, true, false, false, false, true]);
}

#[test]
fn freeze_mask_pins_elements_through_the_hlo() {
    let client = client();
    let Some(a) = arts_with(&client, "tetrajet") else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let root = root().unwrap();
    let params = artifacts::run_init(&client, &root, "vit-micro", 0).unwrap();
    let mut tr = Trainer::new(&a, quick_cfg("tetrajet", 4), params).unwrap();
    tr.state.freeze_mask[..100].iter_mut().for_each(|x| *x = 1.0);
    tr.state.freeze_value[..100]
        .iter_mut()
        .enumerate()
        .for_each(|(i, x)| *x = 0.123 + i as f32 * 1e-4);
    let want: Vec<f32> = tr.state.freeze_value[..100].to_vec();
    for _ in 0..3 {
        tr.step().unwrap();
    }
    assert_eq!(&tr.state.params[..100], &want[..]);
}

#[test]
fn rust_qema_mirror_matches_hlo_ema_dynamics() {
    // The EMA returned by the qema train step must follow
    // ema' = beta*ema + (1-beta)*w' elementwise (the same recurrence the
    // Rust coordinator assumes when mirroring Q-EMA quantization).
    let client = client();
    let Some(a) = arts_with(&client, "tetrajet_qema") else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let root = root().unwrap();
    let params = artifacts::run_init(&client, &root, "vit-micro", 0).unwrap();
    let mut cfg = quick_cfg("tetrajet_qema", 3);
    cfg.ema_beta = 0.9;
    let mut tr = Trainer::new(&a, cfg, params).unwrap();
    let ema_before = tr.state.ema.clone();
    tr.step().unwrap();
    let w_after = tr.state.qw().to_vec();
    for i in 0..200 {
        let want = 0.9 * ema_before[i] + 0.1 * w_after[i];
        let got = tr.state.ema[i];
        assert!(
            (want - got).abs() <= 1e-6 * want.abs().max(1e-3),
            "ema mismatch at {i}: want {want}, got {got}"
        );
    }
}

#[test]
fn eval_accuracy_of_untrained_model_is_near_chance() {
    let client = client();
    let Some(a) = arts_with(&client, "fp32") else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let root = root().unwrap();
    let params = artifacts::run_init(&client, &root, "vit-micro", 0).unwrap();
    let mut cfg = quick_cfg("fp32", 1);
    cfg.eval_samples = 256;
    let tr = Trainer::new(&a, cfg, params).unwrap();
    let ev = tr.eval().unwrap();
    // 10 classes -> chance = 10%; untrained should be within noise.
    assert!(ev.acc_pct < 35.0, "untrained acc suspiciously high: {}", ev.acc_pct);
}
