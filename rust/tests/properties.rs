//! Property-based tests over the quant mirror and metric invariants
//! (using the in-repo property-test driver; proptest is unavailable
//! offline — DESIGN.md §Substitutions).

use tetrajet::metrics::{quant_confidence, OscTracker, PackedOscTracker};
use tetrajet::quant::{
    bracket, e2m1, e3m0, e4m3_decode, e4m3_encode_ceil, group_ranges,
    mx_quantize_cols, nvfp4_quantize_cols, qema_quantize_cols, round_det,
    GroupGeom, MxQuantizer, NvQuantizer, PackedMx, QemaQuantizer, Quantizer,
    Scaling, E4M3_MAX_BYTE,
};
use tetrajet::testing::{check, gen_f32_vec, geom_sweep};

#[test]
fn prop_round_det_is_nearest_or_tie_up() {
    for fmt in [e2m1(), e3m0()] {
        check(
            "round_det nearest",
            3000,
            |r| r.range(fmt.qn(), fmt.qp()),
            |&y| {
                let q = round_det(y, fmt);
                // q must be a grid level...
                if !fmt.levels.iter().any(|&l| l == q) {
                    return false;
                }
                // ...and no level may be strictly closer.
                let d = (y - q).abs();
                fmt.levels.iter().all(|&l| (y - l).abs() >= d - 1e-7)
            },
        );
    }
}

#[test]
fn prop_bracket_contains_value() {
    for fmt in [e2m1(), e3m0()] {
        check(
            "bracket contains",
            3000,
            |r| r.range(fmt.qn(), fmt.qp()),
            |&y| {
                let (q1, q2) = bracket(y, fmt);
                let ok_levels = fmt.levels.iter().any(|&l| l == q1)
                    && fmt.levels.iter().any(|&l| l == q2);
                // Consecutive levels with q1 <= y <= q2 (except at Qp
                // where q1 is clamped one level down).
                ok_levels && q1 < q2 && y >= q1 - 1e-6 && y <= q2 + 1e-6
            },
        );
    }
}

#[test]
fn prop_quantization_idempotent_and_bounded() {
    check(
        "mx idempotent",
        200,
        |r| gen_f32_vec(r, 64, 2.0),
        |x| {
            for fmt in [e2m1(), e3m0()] {
                for sc in [Scaling::TruncationFree, Scaling::Floor] {
                    let q = mx_quantize_cols(x, 64, fmt, sc);
                    if mx_quantize_cols(&q, 64, fmt, sc) != q {
                        return false;
                    }
                    // Truncation-free never amplifies the group max by
                    // more than one rounding step (<= 2x is a loose
                    // bound; floor scaling truncates instead).
                    if sc == Scaling::TruncationFree {
                        let xm = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                        let qm = q.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                        if qm > 2.0 * xm.max(f32::MIN_POSITIVE) {
                            return false;
                        }
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_qema_output_bracketed_by_neighbors() {
    check(
        "qema picks bracket candidate",
        200,
        |r| {
            let w = gen_f32_vec(r, 32, 1.0);
            let ema: Vec<f32> = w.iter().map(|&v| v + r.normal() * 0.1).collect();
            (w, ema)
        },
        |(w, ema)| {
            let fmt = e2m1();
            let q = qema_quantize_cols(w, ema, 32, fmt, Scaling::TruncationFree);
            // Exact invariant (paper Alg. 1): each output is one of the
            // two scaled bracket candidates around the latent weight.
            let max_abs = w.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let scale = {
                use tetrajet::quant::formats::exp2i;
                exp2i(tetrajet::quant::scale_exponent(
                    max_abs,
                    fmt,
                    Scaling::TruncationFree,
                ))
            };
            for i in 0..w.len() {
                let y = (w[i] / scale).clamp(fmt.qn(), fmt.qp());
                let (q1, q2) = bracket(y, fmt);
                if q[i] != q1 * scale && q[i] != q2 * scale {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_confidence_in_unit_interval() {
    check(
        "confidence bounded",
        300,
        |r| gen_f32_vec(r, 64, 3.0),
        |x| {
            let mut conf = Vec::new();
            for fmt in [e2m1(), e3m0()] {
                quant_confidence(x, 64, fmt, Scaling::TruncationFree, &mut conf);
                if !conf.iter().all(|&c| (0.0..=1.0).contains(&c) && c.is_finite()) {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_packed_roundtrip_is_bit_exact() {
    // dequantize(quantize_packed(x)) == mx_quantize_cols(x) for both
    // formats, both scalings, and ragged tails (cols % 32 != 0).
    for fmt in [e2m1(), e3m0()] {
        for scaling in [Scaling::TruncationFree, Scaling::Floor] {
            for cols in [32usize, 48, 7] {
                check(
                    "packed roundtrip",
                    120,
                    |r| gen_f32_vec(r, cols * 2, 2.0),
                    |x| {
                        let q = MxQuantizer { fmt, scaling };
                        let mut p = PackedMx::default();
                        q.quantize_packed(x, cols, &mut p);
                        let mut deq = vec![0.0; x.len()];
                        q.dequantize(&p, &mut deq);
                        deq == mx_quantize_cols(x, cols, fmt, scaling)
                    },
                );
            }
        }
    }
}

#[test]
fn prop_packed_roundtrip_all_zero_groups() {
    // All-zero groups use the epsilon scale; codes must still decode to
    // exact zeros.
    check(
        "packed zero groups",
        200,
        |r| {
            let mut x = gen_f32_vec(r, 96, 1.0);
            // Zero out a whole group and a ragged tail group.
            for v in &mut x[..32] {
                *v = 0.0;
            }
            for v in &mut x[64..] {
                *v = 0.0;
            }
            x
        },
        |x| {
            let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
            let mut p = PackedMx::default();
            q.quantize_packed(x, 96, &mut p);
            let deq = p.dequantize();
            deq == mx_quantize_cols(x, 96, e2m1(), Scaling::TruncationFree)
                && deq[..32].iter().all(|&v| v == 0.0)
        },
    );
}

#[test]
fn prop_packed_qema_roundtrip_is_bit_exact() {
    check(
        "packed qema roundtrip",
        150,
        |r| {
            let w = gen_f32_vec(r, 64, 1.0);
            let ema: Vec<f32> = w.iter().map(|&v| v + r.normal() * 0.1).collect();
            (w, ema)
        },
        |(w, ema)| {
            let fmt = e2m1();
            let q = QemaQuantizer { fmt, scaling: Scaling::TruncationFree, ema };
            let mut p = PackedMx::default();
            q.quantize_packed(w, 32, &mut p);
            p.dequantize() == qema_quantize_cols(w, ema, 32, fmt, Scaling::TruncationFree)
        },
    );
}

#[test]
fn prop_packed_flip_counts_match_f32_tracker() {
    // Recorded random-walk trajectory over two segments with different
    // (ragged) cols: the code-comparing tracker must report exactly the
    // flip frequencies, ratios and oscillating counts of the f32 one.
    const COLS_A: usize = 32;
    const LEN_A: usize = 64;
    const COLS_B: usize = 17;
    const LEN_B: usize = 34;
    const STEPS: usize = 6;
    check(
        "packed flip parity",
        40,
        |r| {
            let mut traj = vec![gen_f32_vec(r, LEN_A + LEN_B, 1.0)];
            for _ in 0..STEPS {
                let last = traj.last().unwrap().clone();
                let next: Vec<f32> =
                    last.iter().map(|&v| v + r.normal() * 0.05).collect();
                traj.push(next);
            }
            traj
        },
        |traj| {
            let fmt = e2m1();
            let q = MxQuantizer { fmt, scaling: Scaling::TruncationFree };
            let fake = |w: &[f32]| {
                let mut out = mx_quantize_cols(&w[..LEN_A], COLS_A, fmt, Scaling::TruncationFree);
                out.extend(mx_quantize_cols(&w[LEN_A..], COLS_B, fmt, Scaling::TruncationFree));
                out
            };
            let pack = |w: &[f32]| {
                let (mut pa, mut pb) = (PackedMx::default(), PackedMx::default());
                q.quantize_packed(&w[..LEN_A], COLS_A, &mut pa);
                q.quantize_packed(&w[LEN_A..], COLS_B, &mut pb);
                vec![pa, pb]
            };
            let mut tf = OscTracker::new(&traj[0], &fake(&traj[0]));
            let mut tp = PackedOscTracker::new(&traj[0], &pack(&traj[0]));
            for w in &traj[1..] {
                tf.observe(w, &fake(w));
                tp.observe(w, &pack(w));
            }
            let (mut ff, mut fp) = (Vec::new(), Vec::new());
            tf.flip_freq_into(&mut ff);
            tp.flip_freq_into(&mut fp);
            if ff != fp || tf.ratios() != tp.ratios() {
                return false;
            }
            [0.0f32, 1.0, 16.0]
                .iter()
                .all(|&th| tf.oscillating_count(th) == tp.oscillating_count(th))
        },
    );
}

#[test]
fn prop_group_ranges_tile_rows_at_every_geometry() {
    // For every geometry in the sweep (MX, NVFP4, and with
    // TJ_GEOM_SWEEP=1 the off-registry combinations), the 1xG layout
    // tiles each row contiguously, never crosses a row boundary, and
    // produces exactly rows * groups_per_row sequentially-indexed
    // groups.
    for geom in geom_sweep() {
        check(
            "group_ranges tiling",
            300,
            |r| {
                let cols = 1 + r.below(70) as usize;
                let rows = 1 + r.below(5) as usize;
                (rows * cols, cols)
            },
            |&(len, cols)| {
                let gs = geom.group_size();
                let mut next_g = 0usize;
                let mut next_start = 0usize;
                let mut ok = true;
                group_ranges(len, cols, gs, |g, a, b| {
                    ok &= g == next_g && a == next_start && b > a && b - a <= gs;
                    // Groups stay inside one row.
                    ok &= a / cols == (b - 1) / cols;
                    // Only a group at the row end may be short.
                    ok &= b - a == gs || b % cols == 0;
                    next_g += 1;
                    next_start = b;
                });
                ok && next_start == len
                    && next_g == (len / cols) * geom.groups_per_row(cols)
            },
        );
    }
}

#[test]
fn prop_e4m3_encode_ceil_is_truncation_free() {
    check(
        "e4m3 ceil encode",
        3000,
        |r| (r.normal() * 50.0).abs().min(500.0),
        |&v| {
            let b = e4m3_encode_ceil(v);
            if b > E4M3_MAX_BYTE {
                return false;
            }
            let d = e4m3_decode(b);
            if v <= 0.0 {
                return b == 0;
            }
            if v > 448.0 {
                return b == E4M3_MAX_BYTE;
            }
            // decode(b) is the smallest representable value >= v.
            d >= v && (b == 0 || e4m3_decode(b - 1) < v)
        },
    );
}

#[test]
fn prop_nvfp4_packed_roundtrip_is_bit_exact() {
    // Packed dequant == fake-quant reference at the NVFP4 geometry,
    // including ragged tails (cols % 16 != 0).
    for cols in [16usize, 24, 7] {
        check(
            "nvfp4 packed roundtrip",
            120,
            |r| gen_f32_vec(r, cols * 3, 2.0),
            |x| {
                let q = NvQuantizer::nvfp4();
                let mut p = PackedMx::default();
                q.quantize_packed(x, cols, &mut p);
                if p.geom() != GroupGeom::nvfp4() {
                    return false;
                }
                let mut deq = vec![0.0; x.len()];
                q.dequantize(&p, &mut deq);
                deq == nvfp4_quantize_cols(x, cols)
            },
        );
    }
}

#[test]
fn prop_nv_quantizer_at_mx_geometry_matches_mx_quantizer() {
    // With MX geometry and the outlier clamp disabled, the generalized
    // quantizer IS the MX quantizer, bit for bit — fake-quant output,
    // codes and scale bytes alike.
    check(
        "nv==mx at mx geometry",
        150,
        |r| gen_f32_vec(r, 96, 2.0),
        |x| {
            for fmt in [e2m1(), e3m0()] {
                for scaling in [Scaling::TruncationFree, Scaling::Floor] {
                    let nv = NvQuantizer::with_geom(fmt, scaling, GroupGeom::mx());
                    let mx = MxQuantizer { fmt, scaling };
                    let (mut pn, mut pm) = (PackedMx::default(), PackedMx::default());
                    nv.quantize_packed(x, 48, &mut pn);
                    mx.quantize_packed(x, 48, &mut pm);
                    if pn.codes() != pm.codes() || pn.scale_bytes() != pm.scale_bytes() {
                        return false;
                    }
                    let mut a = vec![0.0; x.len()];
                    let mut b = vec![0.0; x.len()];
                    nv.quantize_f32(x, 48, &mut a);
                    mx.quantize_f32(x, 48, &mut b);
                    if a != b {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_packed_flip_counts_match_f32_tracker_at_nvfp4() {
    // Flip parity at the NVFP4 geometry (code compare vs f32 compare),
    // ragged 16-element groups included.
    const COLS: usize = 21;
    const LEN: usize = 63;
    const STEPS: usize = 6;
    check(
        "nvfp4 flip parity",
        40,
        |r| {
            let mut traj = vec![gen_f32_vec(r, LEN, 1.0)];
            for _ in 0..STEPS {
                let last = traj.last().unwrap().clone();
                let next: Vec<f32> = last.iter().map(|&v| v + r.normal() * 0.05).collect();
                traj.push(next);
            }
            traj
        },
        |traj| {
            let q = NvQuantizer::nvfp4();
            let fake = |w: &[f32]| nvfp4_quantize_cols(w, COLS);
            let pack = |w: &[f32]| {
                let mut p = PackedMx::default();
                q.quantize_packed(w, COLS, &mut p);
                vec![p]
            };
            let mut tf = OscTracker::new(&traj[0], &fake(&traj[0]));
            let mut tp = PackedOscTracker::new(&traj[0], &pack(&traj[0]));
            for w in &traj[1..] {
                tf.observe(w, &fake(w));
                tp.observe(w, &pack(w));
            }
            let (mut ff, mut fp) = (Vec::new(), Vec::new());
            tf.flip_freq_into(&mut ff);
            tp.flip_freq_into(&mut fp);
            if ff != fp || tf.ratios() != tp.ratios() {
                return false;
            }
            [0.0f32, 1.0, 16.0]
                .iter()
                .all(|&th| tf.oscillating_count(th) == tp.oscillating_count(th))
        },
    );
}

#[test]
fn prop_osc_ratio_nonnegative_and_walk_has_small_ratio() {
    check(
        "osc ratio sane",
        100,
        |r| {
            // A smooth random walk quantized on a coarse grid.
            let mut w = vec![r.normal()];
            for _ in 0..40 {
                let last = *w.last().unwrap();
                w.push(last + r.normal() * 0.3);
            }
            w
        },
        |walk| {
            let q: Vec<f32> = walk.iter().map(|&v| round_det(v.clamp(-6.0, 6.0), e2m1())).collect();
            let mut t = OscTracker::new(&[walk[0]], &[q[0]]);
            for i in 1..walk.len() {
                t.observe(&[walk[i]], &[q[i]]);
            }
            let r = t.ratios()[0];
            // Ratios are nonnegative; a real random walk with step 0.3
            // on a >= 0.5-spaced grid can't reach the paper's oscillation
            // threshold of 16.
            r >= 0.0 && r < 16.0
        },
    );
}
