//! Integration tests for the continuous-batching serve fleet: N-way
//! row-sharded bit-exactness (ragged splits and both packed variants
//! included), cross-request micro-batching through the ticket API,
//! queue-depth backpressure, deadline expiry on the virtual clock, and
//! seeded open-loop load-test determinism.

use tetrajet::quant::{e2m1, Scaling};
use tetrajet::serve::{
    run_load_test, ActQuant, LoadReport, LoadSpec, Outcome, Pace, PackedVit, Reject, ServeConfig,
    ServeFleet, ServeGeom, WeightQuant,
};
use tetrajet::util::rng::Rng;

fn tiny_geom() -> ServeGeom {
    ServeGeom::new(8, 4, 32, 2, 4, 3, 4)
}

#[derive(Debug, Clone, Copy)]
enum Variant {
    Mx,
    Int4,
    Nvfp4,
}

fn tiny_vit_variant(seed: u64, variant: Variant) -> PackedVit {
    let geom = tiny_geom();
    let mut rng = Rng::new(seed);
    let params: Vec<f32> = (0..geom.total_params()).map(|_| rng.normal() * 0.05).collect();
    let (wq, aq) = match variant {
        Variant::Int4 => (WeightQuant::Int4, ActQuant::Int4),
        Variant::Nvfp4 => (WeightQuant::Nvfp4, ActQuant::Nvfp4),
        Variant::Mx => {
            let fmt = e2m1();
            (
                WeightQuant::Mx { fmt, scaling: Scaling::TruncationFree },
                ActQuant::Mx { fmt, scaling: Scaling::TruncationFree },
            )
        }
    };
    PackedVit::build(geom, &params, None, wq, aq).unwrap()
}

fn tiny_vit(seed: u64, int4: bool) -> PackedVit {
    tiny_vit_variant(seed, if int4 { Variant::Int4 } else { Variant::Mx })
}

fn cfg(engines: usize, micro: usize, depth: usize) -> ServeConfig {
    ServeConfig::builder()
        .micro_batch(micro)
        .workers(2)
        .engines(engines)
        .queue_depth(depth)
        .build()
        .unwrap()
}

fn px() -> usize {
    let g = tiny_geom();
    g.img * g.img * 3
}

#[test]
fn prop_fleet_logits_bit_exact_across_engine_counts_and_variants() {
    // The tiny geometry's stores have 192/64/128/64 rows, so 3 and 4
    // engines exercise ragged row splits (and odd-offset nibble
    // repacks) on every store — at group size 32 (MX), per-tensor
    // (INT4), and group size 16 with E4M3 scales (NVFP4).
    for (i, variant) in [Variant::Mx, Variant::Int4, Variant::Nvfp4].into_iter().enumerate() {
        let vit = tiny_vit_variant(11 + i as u64, variant);
        let mut rng = Rng::new(33);
        let n = 5;
        let x: Vec<f32> = (0..n * px()).map(|_| rng.normal()).collect();
        let want = vit.forward(&x, n, 1);
        for engines in 1..=4 {
            let mut fleet = ServeFleet::new(vit.clone(), cfg(engines, 8, 32)).unwrap();
            assert_eq!(fleet.engines(), engines);
            let got = fleet.infer_logits(x.clone(), n).unwrap();
            assert_eq!(got, want, "fleet must be bit-exact (engines={engines}, {variant:?})");
        }
    }
}

#[test]
fn fleet_micro_batches_across_requests_and_drains_in_id_order() {
    let vit = tiny_vit(3, false);
    let mut rng = Rng::new(21);
    let mut all = Vec::new();
    let mut reqs = Vec::new();
    for n in [1usize, 5, 2] {
        let imgs: Vec<f32> = (0..n * px()).map(|_| rng.normal()).collect();
        all.extend_from_slice(&imgs);
        reqs.push((imgs, n));
    }
    let want = vit.forward(&all, 8, 1);
    let mut fleet = ServeFleet::new(vit, cfg(2, 4, 64)).unwrap();
    let mut tickets = Vec::new();
    for (imgs, n) in reqs {
        tickets.push(fleet.submit(imgs, n, None).unwrap());
    }
    // Malformed submissions are rejected with a reason, not queued.
    assert!(matches!(fleet.submit(vec![0.0; 5], 2, None), Err(Reject::BadRequest(_))));
    // Nothing is resolved before the fleet steps.
    assert!(fleet.poll(tickets[0]).is_none());
    let outs = fleet.wait_all();
    assert_eq!(outs.len(), 3);
    assert!(outs.windows(2).all(|w| w[0].id() < w[1].id()), "drain order is ticket-id order");
    let got: Vec<f32> = outs
        .into_iter()
        .map(|o| o.response().expect("deadline-less requests complete"))
        .flat_map(|r| r.logits)
        .collect();
    assert_eq!(got, want, "reassembled per-request logits must match one big batch");
    let st = fleet.stats();
    assert_eq!((st.count, st.images, st.batches), (3, 8, 2)); // ceil(8 / 4)
    // Redemption is at most once: wait_all already consumed them.
    assert!(fleet.poll(tickets[1]).is_none());
}

#[test]
fn deadlines_expire_unstarted_requests_on_the_virtual_clock() {
    let vit = tiny_vit(4, false);
    let mut fleet = ServeFleet::new(vit, cfg(2, 4, 64)).unwrap();
    let t0 = fleet.submit_at(vec![0.1; 2 * px()], 2, Some(5.0), 0.0).unwrap();
    let t1 = fleet.submit_at(vec![0.2; 2 * px()], 2, Some(1000.0), 0.5).unwrap();
    // The first batch forms at t=10: t0's deadline (5.0) has passed
    // before any of its images ran, so it expires; t1 runs.
    let info = fleet.step_at(10.0, Some(1.0)).unwrap();
    assert_eq!(info.m, 2);
    assert_eq!(info.done_ms, 12.0); // 10 + 2 images * 1 ms/image
    match fleet.poll(t0) {
        Some(Outcome::Expired { id, deadline_ms }) => {
            assert_eq!((id, deadline_ms), (t0.id, 5.0));
        }
        o => panic!("t0 should have expired, got {o:?}"),
    }
    match fleet.poll(t1) {
        Some(Outcome::Done(r)) => {
            assert_eq!(r.id, t1.id);
            assert_eq!(r.preds.len(), 2);
            assert!((r.latency_ms - 11.5).abs() < 1e-12); // 12.0 - arrival 0.5
        }
        o => panic!("t1 should be done, got {o:?}"),
    }
    let st = fleet.stats();
    assert_eq!((st.count, st.expired, st.images), (1, 1, 2));
}

/// One virtual-pace load-test run at a rate that guarantees queue-full
/// rejections (arrivals every ~0.5 ms vs 4 ms of service per batch).
fn overload_run(seed: u64, deadline_ms: Option<f64>) -> LoadReport {
    let vit = tiny_vit(2, false);
    let mut fleet = ServeFleet::new(vit, cfg(2, 4, 8)).unwrap();
    let spec = LoadSpec {
        seed,
        requests: 120,
        request_size: 4,
        rate_rps: 2000.0,
        deadline_ms,
        pace: Pace::Virtual { ms_per_image: 1.0 },
    };
    let n_px = px();
    run_load_test(&mut fleet, &spec, |i| {
        let mut r = Rng::new(seed).fold_in(0x494d47).fold_in(i as u64);
        ((0..4 * n_px).map(|_| r.uniform() * 2.0 - 1.0).collect(), Vec::new())
    })
    .unwrap()
}

#[test]
fn load_test_applies_backpressure_and_is_seed_deterministic() {
    let a = overload_run(7, None);
    assert_eq!(a.accepted + a.rejected, 120);
    assert!(a.rejected > 0, "open-loop overload must trip queue-depth backpressure");
    assert_eq!(a.completed + a.expired, a.accepted);
    assert_eq!(a.expired, 0, "no deadlines -> nothing expires");
    assert_eq!(a.summary.rejected, a.rejected);
    assert_eq!(a.summary.count, a.completed);
    assert_eq!(a.summary.images, a.accepted * 4);
    // Every request costs at least its own 4 ms of service; tails are
    // ordered.
    assert!(a.summary.p50_ms >= 4.0);
    assert!(a.summary.p50_ms <= a.summary.p95_ms);
    assert!(a.summary.p95_ms <= a.summary.p99_ms);
    assert!(a.summary.p99_ms <= a.summary.max_ms);

    // Same seed -> identical schedule, admissions, and virtual-clock
    // latency digest. (busy/compute times are wall-measured and NOT
    // compared; determinism is over the simulated quantities.)
    let b = overload_run(7, None);
    assert_eq!(
        (a.accepted, a.rejected, a.expired, a.completed),
        (b.accepted, b.rejected, b.expired, b.completed)
    );
    let digest = |r: &LoadReport| {
        (
            r.summary.count,
            r.summary.images,
            r.summary.batches,
            r.summary.rejected,
            r.summary.expired,
            r.summary.wall_ms.to_bits(),
            r.summary.mean_ms.to_bits(),
            r.summary.p50_ms.to_bits(),
            r.summary.p95_ms.to_bits(),
            r.summary.p99_ms.to_bits(),
            r.summary.max_ms.to_bits(),
        )
    };
    assert_eq!(digest(&a), digest(&b), "virtual-pace load test must be bit-deterministic");

    // A different seed draws a different Poisson schedule.
    let spec = |seed| LoadSpec {
        seed,
        requests: 120,
        request_size: 4,
        rate_rps: 2000.0,
        deadline_ms: None,
        pace: Pace::Virtual { ms_per_image: 1.0 },
    };
    assert_ne!(spec(7).schedule(), spec(8).schedule());
}

#[test]
fn load_test_deadlines_expire_queued_requests_under_overload() {
    // Queued requests wait multiple 4 ms service slots before starting;
    // a 2 ms deadline therefore expires some of them (deterministically,
    // on the virtual clock).
    let a = overload_run(5, Some(2.0));
    assert!(a.expired > 0, "tight deadlines under overload must expire requests");
    assert_eq!(a.completed + a.expired, a.accepted);
    assert_eq!(a.summary.expired, a.expired);
    let b = overload_run(5, Some(2.0));
    assert_eq!(
        (a.accepted, a.rejected, a.expired, a.completed),
        (b.accepted, b.rejected, b.expired, b.completed)
    );
}
