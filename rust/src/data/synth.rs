//! SynthVision: class-conditional procedural images.
//!
//! Every class owns a deterministic prototype drawn from the dataset
//! seed: an oriented sinusoidal grating (frequency + orientation), a
//! Gaussian blob in one of the cells of a 3x3 layout grid, and two RGB
//! colour vectors. Every sample perturbs the prototype: random grating
//! phase, blob-position jitter, amplitude scaling and dense Gaussian
//! pixel noise. Classifying a sample therefore requires combining
//! colour, spatial-frequency and layout cues — a miniature stand-in for
//! "real" image statistics that a ViT learns comfortably while leaving
//! a visible gap between FP32 and 4-bit training.
//!
//! Samples are pure functions of (dataset seed, split, index): the
//! pipeline needs no storage and is exactly reproducible.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
}

impl Split {
    fn id(self) -> u64 {
        match self {
            Split::Train => 1,
            Split::Val => 2,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SynthVision {
    pub img: usize,
    pub classes: usize,
    pub seed: u64,
    pub train_size: usize,
    pub val_size: usize,
    protos: Vec<ClassProto>,
}

#[derive(Debug, Clone)]
struct ClassProto {
    freq: f32,
    theta: f32,
    blob_x: f32,
    blob_y: f32,
    blob_r: f32,
    col_grating: [f32; 3],
    col_blob: [f32; 3],
}

fn unit_color(rng: &mut Rng) -> [f32; 3] {
    let mut c = [rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(-1.0, 1.0)];
    let n = (c[0] * c[0] + c[1] * c[1] + c[2] * c[2]).sqrt().max(1e-6);
    c.iter_mut().for_each(|x| *x /= n);
    c
}

impl SynthVision {
    pub fn new(img: usize, classes: usize, seed: u64, train_size: usize, val_size: usize) -> SynthVision {
        let protos = (0..classes)
            .map(|c| {
                let mut r = Rng::new(seed ^ 0xC1A5_5EED).fold_in(c as u64);
                // 3x3 layout grid for the blob centre.
                let cell = r.below(9);
                let (gx, gy) = ((cell % 3) as f32, (cell / 3) as f32);
                // Difficulty tuning: narrow frequency band (classes can
                // collide), small dim blobs in a shared 3x3 layout, so
                // no single cue separates all 10 classes — calibrated so
                // short FP32 runs land well below ceiling and 4-bit
                // noise visibly hurts (DESIGN.md §Substitutions).
                ClassProto {
                    freq: 2.0 + r.uniform() * 3.0,
                    theta: r.range(0.0, std::f32::consts::PI),
                    blob_x: (gx + 0.5) / 3.0,
                    blob_y: (gy + 0.5) / 3.0,
                    blob_r: 0.07 + 0.03 * r.uniform(),
                    col_grating: unit_color(&mut r),
                    col_blob: unit_color(&mut r),
                }
            })
            .collect();
        SynthVision { img, classes, seed, train_size, val_size, protos }
    }

    /// Default experiment-suite dataset (matches the examples & benches).
    pub fn default_cfg(seed: u64) -> SynthVision {
        SynthVision::new(32, 10, seed, 8192, 1024)
    }

    pub fn size(&self, split: Split) -> usize {
        match split {
            Split::Train => self.train_size,
            Split::Val => self.val_size,
        }
    }

    pub fn label(&self, index: usize) -> i32 {
        (index % self.classes) as i32
    }

    /// Generate sample `index` of `split`: (HWC f32 pixels, label).
    pub fn sample(&self, split: Split, index: usize) -> (Vec<f32>, i32) {
        let mut px = vec![0.0f32; self.img * self.img * 3];
        let label = self.sample_into(split, index, &mut px);
        (px, label)
    }

    /// Allocation-free variant for the batch assembly hot path.
    pub fn sample_into(&self, split: Split, index: usize, out: &mut [f32]) -> i32 {
        let n = self.img;
        assert_eq!(out.len(), n * n * 3);
        let label = self.label(index);
        let p = &self.protos[label as usize];
        let mut rng = Rng::new(self.seed).fold_in(split.id()).fold_in(index as u64);

        let phase = rng.range(0.0, 2.0 * std::f32::consts::PI);
        let bx = (p.blob_x + rng.range(-0.12, 0.12)) * n as f32;
        let by = (p.blob_y + rng.range(-0.12, 0.12)) * n as f32;
        let br = p.blob_r * n as f32 * rng.range(0.8, 1.25);
        let amp_g = 0.40 * rng.range(0.7, 1.3);
        let amp_b = 0.55 * rng.range(0.7, 1.3);
        // Per-sample frequency/orientation jitter blurs class boundaries.
        let freq = p.freq * rng.range(0.93, 1.07);
        let theta = p.theta + rng.range(-0.08, 0.08);
        let (st, ct) = theta.sin_cos();
        let k = 2.0 * std::f32::consts::PI * freq / n as f32;
        let inv2r2 = 1.0 / (2.0 * br * br);

        let mut i = 0;
        for y in 0..n {
            for x in 0..n {
                let (xf, yf) = (x as f32, y as f32);
                let g = (k * (xf * ct + yf * st) + phase).sin() * amp_g;
                let d2 = (xf - bx) * (xf - bx) + (yf - by) * (yf - by);
                let b = (-d2 * inv2r2).exp() * amp_b;
                for ch in 0..3 {
                    let noise = rng.normal() * 0.55;
                    out[i] = g * p.col_grating[ch] + b * p.col_blob[ch] + noise;
                    i += 1;
                }
            }
        }
        label
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_split_disjoint() {
        let ds = SynthVision::default_cfg(7);
        let (a, la) = ds.sample(Split::Train, 5);
        let (b, lb) = ds.sample(Split::Train, 5);
        assert_eq!(a, b);
        assert_eq!(la, lb);
        let (c, _) = ds.sample(Split::Val, 5);
        assert_ne!(a, c, "train/val streams must differ");
        let (d, _) = ds.sample(Split::Train, 6);
        assert_ne!(a, d);
    }

    #[test]
    fn labels_balanced() {
        let ds = SynthVision::default_cfg(7);
        let mut counts = vec![0usize; ds.classes];
        for i in 0..100 {
            counts[ds.label(i) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn pixel_statistics_reasonable() {
        let ds = SynthVision::default_cfg(7);
        let (px, _) = ds.sample(Split::Train, 0);
        let mean = px.iter().sum::<f32>() / px.len() as f32;
        let var = px.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / px.len() as f32;
        assert!(mean.abs() < 0.6, "mean {mean}");
        assert!(var > 0.05 && var < 4.0, "var {var}");
        assert!(px.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn classes_are_distinguishable_by_nearest_prototype() {
        // Nearest-centroid over raw pixels should already beat chance by
        // a lot; if this fails the task carries no signal.
        let ds = SynthVision::new(32, 10, 3, 4096, 512);
        let dim = 32 * 32 * 3;
        let per_class = 20;
        let mut centroids = vec![vec![0.0f64; dim]; 10];
        let mut counts = vec![0usize; 10];
        for i in 0..10 * per_class {
            let (px, l) = ds.sample(Split::Train, i);
            let c = &mut centroids[l as usize];
            px.iter().enumerate().for_each(|(j, &v)| c[j] += v as f64);
            counts[l as usize] += 1;
        }
        for (c, n) in centroids.iter_mut().zip(&counts) {
            c.iter_mut().for_each(|v| *v /= *n as f64);
        }
        let mut correct = 0;
        let total = 200;
        for i in 0..total {
            let (px, l) = ds.sample(Split::Val, i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = px.iter().enumerate().map(|(j, &v)| (v as f64 - centroids[a][j]).powi(2)).sum();
                    let db: f64 = px.iter().enumerate().map(|(j, &v)| (v as f64 - centroids[b][j]).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == l as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / total as f64;
        // Harder than the first iteration of this dataset (which let
        // every training method saturate at ~99%): linear-in-pixels
        // evidence must exist but stay below ceiling.
        assert!(acc > 0.2, "nearest-centroid acc {acc} too low — task has no signal");
        assert!(acc < 0.95, "nearest-centroid acc {acc} too high — task trivial");
    }
}
