//! Batch assembly: shuffled continuous training stream + deterministic
//! eval coverage. Sample synthesis is parallelized across a scoped
//! thread pool (util::parallel).

use super::synth::{Split, SynthVision};
use crate::util::parallel::{default_workers, parallel_map_indexed};
use crate::util::rng::Rng;

/// Continuous shuffled training batch stream (reshuffles every epoch).
pub struct Batcher {
    ds: SynthVision,
    batch: usize,
    order: Vec<usize>,
    pos: usize,
    rng: Rng,
    workers: usize,
    pub epochs_completed: usize,
}

impl Batcher {
    pub fn new(ds: SynthVision, batch: usize, shuffle_seed: u64) -> Batcher {
        let mut rng = Rng::new(shuffle_seed ^ 0xBA7C_4E2);
        let mut order: Vec<usize> = (0..ds.train_size).collect();
        rng.shuffle(&mut order);
        Batcher { ds, batch, order, pos: 0, rng, workers: default_workers(), epochs_completed: 0 }
    }

    /// Next (pixels, labels) batch; pixels are B*H*W*3 row-major.
    pub fn next_batch(&mut self) -> (Vec<f32>, Vec<i32>) {
        let idxs: Vec<usize> = (0..self.batch)
            .map(|_| {
                if self.pos >= self.order.len() {
                    self.rng.shuffle(&mut self.order);
                    self.pos = 0;
                    self.epochs_completed += 1;
                }
                let i = self.order[self.pos];
                self.pos += 1;
                i
            })
            .collect();
        self.assemble(Split::Train, &idxs)
    }

    fn assemble(&self, split: Split, idxs: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let px_per = self.ds.img * self.ds.img * 3;
        let results = parallel_map_indexed(idxs.len(), self.workers, |i| {
            let mut buf = vec![0.0f32; px_per];
            let label = self.ds.sample_into(split, idxs[i], &mut buf);
            (buf, label)
        });
        let mut xs = Vec::with_capacity(idxs.len() * px_per);
        let mut ys = Vec::with_capacity(idxs.len());
        for (buf, label) in results {
            xs.extend_from_slice(&buf);
            ys.push(label);
        }
        (xs, ys)
    }

    /// A fixed probe batch (deterministic; used by the activation
    /// instability metrics so r(Y) is measured on constant input).
    pub fn fixed_batch(&self, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed ^ 0xF1);
        let idxs: Vec<usize> = (0..self.batch).map(|_| rng.below(self.ds.train_size)).collect();
        self.assemble(Split::Train, &idxs)
    }
}

/// Deterministic eval-set iterator in fixed batch chunks.
pub struct EvalSet {
    ds: SynthVision,
    batch: usize,
    limit: usize,
    workers: usize,
}

impl EvalSet {
    pub fn new(ds: SynthVision, batch: usize, limit: usize) -> EvalSet {
        let limit = limit.min(ds.val_size);
        EvalSet { ds, batch, limit, workers: default_workers() }
    }

    pub fn num_batches(&self) -> usize {
        self.limit / self.batch
    }

    /// Total samples actually evaluated (whole batches only).
    pub fn num_samples(&self) -> usize {
        self.num_batches() * self.batch
    }

    pub fn batch(&self, b: usize) -> (Vec<f32>, Vec<i32>) {
        assert!(b < self.num_batches());
        let px_per = self.ds.img * self.ds.img * 3;
        let results = parallel_map_indexed(self.batch, self.workers, |i| {
            let mut buf = vec![0.0f32; px_per];
            let label = self.ds.sample_into(Split::Val, b * self.batch + i, &mut buf);
            (buf, label)
        });
        let mut xs = Vec::with_capacity(self.batch * px_per);
        let mut ys = Vec::with_capacity(self.batch);
        for (buf, label) in results {
            xs.extend_from_slice(&buf);
            ys.push(label);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_have_right_shape_and_reshuffle() {
        let ds = SynthVision::new(32, 10, 1, 64, 32);
        let mut b = Batcher::new(ds.clone(), 16, 9);
        let (x1, y1) = b.next_batch();
        assert_eq!(x1.len(), 16 * 32 * 32 * 3);
        assert_eq!(y1.len(), 16);
        for _ in 0..4 {
            b.next_batch();
        }
        // 64/16 = 4 batches per epoch; the reshuffle happens lazily when
        // the 5th batch starts.
        assert_eq!(b.epochs_completed, 1);
    }

    #[test]
    fn epochs_differ_but_runs_reproduce() {
        let ds = SynthVision::new(32, 10, 1, 64, 32);
        let mut b1 = Batcher::new(ds.clone(), 32, 5);
        let mut b2 = Batcher::new(ds.clone(), 32, 5);
        let (xa, ya) = b1.next_batch();
        let (xb, yb) = b2.next_batch();
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
        let (xc, _) = b1.next_batch();
        assert_ne!(xa, xc);
    }

    #[test]
    fn eval_set_is_deterministic_and_covers() {
        let ds = SynthVision::new(32, 10, 1, 64, 40);
        let ev = EvalSet::new(ds.clone(), 16, 512);
        assert_eq!(ev.num_batches(), 2); // limited by val_size 40 -> 2 full
        let (x1, _) = ev.batch(0);
        let (x2, _) = ev.batch(0);
        assert_eq!(x1, x2);
        let (x3, _) = ev.batch(1);
        assert_ne!(x1, x3);
    }

    #[test]
    fn fixed_batch_stable() {
        let ds = SynthVision::new(32, 10, 1, 64, 32);
        let b = Batcher::new(ds.clone(), 8, 0);
        assert_eq!(b.fixed_batch(3), b.fixed_batch(3));
    }
}
