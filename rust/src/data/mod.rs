//! Synthetic vision data pipeline (the ImageNet substitution).
//!
//! The paper pre-trains on ImageNet-1K, which is unavailable here; per
//! DESIGN.md §Substitutions we train on **SynthVision**, a deterministic
//! procedural image-classification corpus whose difficulty is tuned so
//! that (a) FP32 training strongly beats chance and (b) 4-bit
//! quantization measurably hurts — which is all the paper's experiments
//! need from the task.

pub mod batcher;
pub mod synth;

pub use batcher::{Batcher, EvalSet};
pub use synth::{Split, SynthVision};
