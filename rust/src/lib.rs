//! # TetraJet — Oscillation-Reduced MXFP4 Training for Vision Transformers
//!
//! Rust coordinator (Layer 3) of the three-layer reproduction of
//! *"Oscillation-Reduced MXFP4 Training for Vision Transformers"*
//! (Chen, Xi, Zhu, Chen — ICML 2025).
//!
//! Layering:
//! * **L1 (Pallas, build-time python)** — MXFP4 quantization kernels
//!   (`python/compile/kernels/`), lowered with `interpret=True`.
//! * **L2 (JAX, build-time python)** — quantized ViT forward/backward +
//!   AdamW/EMA/Q-Ramping optimizer step (`python/compile/`), AOT-exported
//!   to HLO text artifacts.
//! * **L3 (this crate)** — owns *all* training state between steps, the
//!   synthetic data pipeline, the Q-Ramping oscillation-detection
//!   coordinator, metric collection (rate-of-change, quantization
//!   confidence, oscillation ratio), checkpoints, CLI and the experiment
//!   harness that regenerates every table and figure of the paper.
//!
//! On top of training sits the packed-native serving subsystem
//! ([`serve`]): TJCKPT02 checkpoints carry the packed codes, and a
//! fused group-wise dequant-matmul drives a forward-only ViT engine
//! that never materializes an f32 weight mirror.
//!
//! Inside L3 the quant stack ([`quant`]) has two faces behind one
//! [`quant::Quantizer`] trait: the legacy f32 fake-quant mirror
//! (golden-tested against the python oracle) and the packed 4-bit core
//! ([`quant::PackedMx`]: two level codes per byte + one E8M0 scale byte
//! per 32-group). The trainer mirrors weights as packed codes per
//! manifest segment in parallel; oscillation metrics compare codes
//! ([`metrics::PackedOscTracker`]) and controllers observe a bit-exact
//! f32 dequant view. The packed layout is the substrate for packed
//! checkpoints and a native FP4 serving path (ROADMAP).
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! model once; afterwards the `tetrajet` binary is self-contained.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod obs;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod testing;
pub mod util;
