//! # TetraJet — Oscillation-Reduced MXFP4 Training for Vision Transformers
//!
//! Rust coordinator (Layer 3) of the three-layer reproduction of
//! *"Oscillation-Reduced MXFP4 Training for Vision Transformers"*
//! (Chen, Xi, Zhu, Chen — ICML 2025).
//!
//! Layering:
//! * **L1 (Pallas, build-time python)** — MXFP4 quantization kernels
//!   (`python/compile/kernels/`), lowered with `interpret=True`.
//! * **L2 (JAX, build-time python)** — quantized ViT forward/backward +
//!   AdamW/EMA/Q-Ramping optimizer step (`python/compile/`), AOT-exported
//!   to HLO text artifacts.
//! * **L3 (this crate)** — owns *all* training state between steps, the
//!   synthetic data pipeline, the Q-Ramping oscillation-detection
//!   coordinator, metric collection (rate-of-change, quantization
//!   confidence, oscillation ratio), checkpoints, CLI and the experiment
//!   harness that regenerates every table and figure of the paper.
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! model once; afterwards the `tetrajet` binary is self-contained.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod quant;
pub mod runtime;
pub mod testing;
pub mod util;
