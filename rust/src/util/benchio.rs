//! BENCH json persistence + cross-PR regression comparison (the
//! ROADMAP's "perf trajectory" item).
//!
//! `serve --load-test` (and the bench harness) write their
//! [`crate::serve::LatencySummary`]-schema entries to
//! `results/BENCH_<pr>.json`; at the next PR, [`find_previous`] locates
//! the newest earlier file and [`compare`] flags entries whose
//! throughput dropped or tail latency rose by more than the tolerance.
//! Entries are matched by their *configuration* keys (everything that
//! is not a measured metric), so adding new cases never produces false
//! regressions — only matching cases are compared.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{num, obj, Json};

/// Keys that carry measurements (everything else identifies the case).
const MEASURED: [&str; 17] = [
    "imgs_per_s",
    "p50_ms",
    "p95_ms",
    "p99_ms",
    "max_ms",
    "mean_ms",
    "min_ms",
    "med_ms",
    "melem_per_s",
    "wall_ms",
    "busy_ms",
    "requests",
    "images",
    "batches",
    "rejected",
    "expired",
    "accepted",
];

/// Write `entries` to `path` as `{"pr": pr, "entries": [...]}`.
pub fn write_bench(path: &Path, pr: u64, entries: Vec<Json>) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    let doc = obj(vec![("pr", num(pr as f64)), ("entries", Json::Arr(entries))]);
    std::fs::write(path, doc.to_string())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Merge `new_entries` into an existing `BENCH_<pr>.json` (or create
/// it): an existing entry describing the [`same_case`] is replaced in
/// place, anything else is appended. This is what lets the serve load
/// test and several `cargo bench` harness runs accumulate into the one
/// per-PR BENCH file instead of overwriting each other.
pub fn merge_bench(path: &Path, pr: u64, new_entries: Vec<Json>) -> Result<()> {
    let mut entries: Vec<Json> = match std::fs::read_to_string(path) {
        Ok(text) => Json::parse(&text)
            .with_context(|| format!("parsing existing {}", path.display()))?
            .get("entries")
            .and_then(|e| e.as_arr().ok().map(<[Json]>::to_vec))
            .unwrap_or_default(),
        Err(_) => Vec::new(),
    };
    for ne in new_entries {
        match entries.iter_mut().find(|e| same_case(&ne, e)) {
            Some(slot) => *slot = ne,
            None => entries.push(ne),
        }
    }
    write_bench(path, pr, entries)
}

/// Newest `BENCH_<n>.json` in `dir` with `n < pr`, parsed.
pub fn find_previous(dir: &Path, pr: u64) -> Option<(PathBuf, Json)> {
    let mut best: Option<(u64, PathBuf)> = None;
    for entry in std::fs::read_dir(dir).ok()?.flatten() {
        let name = entry.file_name();
        let Some(n) = name
            .to_str()
            .and_then(|s| s.strip_prefix("BENCH_"))
            .and_then(|s| s.strip_suffix(".json"))
            .and_then(|s| s.parse::<u64>().ok())
        else {
            continue;
        };
        if n < pr && best.as_ref().map_or(true, |(b, _)| n > *b) {
            best = Some((n, entry.path()));
        }
    }
    let (_, path) = best?;
    let doc = Json::parse(&std::fs::read_to_string(&path).ok()?).ok()?;
    Some((path, doc))
}

/// Two entries describe the same benchmark case when every
/// configuration key they share agrees (and they share at least one).
fn same_case(a: &Json, b: &Json) -> bool {
    let Json::Obj(am) = a else { return false };
    let mut shared = 0;
    for (k, av) in am {
        if MEASURED.contains(&k.as_str()) {
            continue;
        }
        match b.get(k) {
            Some(bv) if bv == av => shared += 1,
            Some(_) => return false,
            None => {}
        }
    }
    shared > 0
}

/// Compare matched entries of two BENCH docs; a regression is an
/// `imgs_per_s` drop below `prev * (1 - tol)` or a `p95_ms` rise above
/// `prev * (1 + tol)`. Returns human-readable flag lines (empty = ok).
pub fn compare(prev: &Json, cur: &Json, tol: f64) -> Vec<String> {
    let empty: Vec<Json> = Vec::new();
    let prev_entries = prev.get("entries").and_then(|e| e.as_arr().ok()).unwrap_or(&empty);
    let cur_entries = cur.get("entries").and_then(|e| e.as_arr().ok()).unwrap_or(&empty);
    let mut flags = Vec::new();
    for ce in cur_entries {
        let Some(pe) = prev_entries.iter().find(|pe| same_case(ce, pe)) else {
            continue;
        };
        let case = ce
            .get("case")
            .and_then(|c| c.as_str().ok())
            .unwrap_or("entry")
            .to_string();
        let metric = |e: &Json, k: &str| e.get(k).and_then(|v| v.as_f64().ok());
        if let (Some(p), Some(c)) = (metric(pe, "imgs_per_s"), metric(ce, "imgs_per_s")) {
            if p > 0.0 && c < p * (1.0 - tol) {
                flags.push(format!(
                    "{case}: imgs_per_s {c:.1} fell >{:.0}% below previous {p:.1}",
                    tol * 100.0
                ));
            }
        }
        if let (Some(p), Some(c)) = (metric(pe, "p95_ms"), metric(ce, "p95_ms")) {
            if p > 0.0 && c > p * (1.0 + tol) {
                flags.push(format!(
                    "{case}: p95_ms {c:.2} rose >{:.0}% above previous {p:.2}",
                    tol * 100.0
                ));
            }
        }
        // Harness-persisted (non-serving) benches report throughput as
        // melem_per_s and latency as med_ms; gate those the same way.
        if let (Some(p), Some(c)) = (metric(pe, "melem_per_s"), metric(ce, "melem_per_s")) {
            if p > 0.0 && c < p * (1.0 - tol) {
                flags.push(format!(
                    "{case}: melem_per_s {c:.1} fell >{:.0}% below previous {p:.1}",
                    tol * 100.0
                ));
            }
        }
        if let (Some(p), Some(c)) = (metric(pe, "med_ms"), metric(ce, "med_ms")) {
            if p > 0.0 && c > p * (1.0 + tol) {
                flags.push(format!(
                    "{case}: med_ms {c:.3} rose >{:.0}% above previous {p:.3}",
                    tol * 100.0
                ));
            }
        }
    }
    flags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::s;

    fn entry(case: &str, ips: f64, p95: f64) -> Json {
        obj(vec![
            ("case", s(case)),
            ("engines", num(2.0)),
            ("imgs_per_s", num(ips)),
            ("p95_ms", num(p95)),
        ])
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tj-benchio-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn write_find_and_compare_roundtrip() {
        let dir = tmpdir("roundtrip");
        write_bench(&dir.join("BENCH_4.json"), 4, vec![entry("smoke", 1000.0, 10.0)]).unwrap();
        write_bench(&dir.join("BENCH_5.json"), 5, vec![entry("smoke", 900.0, 12.0)]).unwrap();
        // PR 6 sees PR 5 (the newest earlier), not PR 4.
        let (path, prev) = find_previous(&dir, 6).unwrap();
        assert!(path.ends_with("BENCH_5.json"));
        assert_eq!(prev.get("pr").unwrap().as_i64().unwrap(), 5);
        // Within 10%: clean.
        let cur =
            obj(vec![("pr", num(6.0)), ("entries", Json::Arr(vec![entry("smoke", 880.0, 12.5)]))]);
        assert!(compare(&prev, &cur, 0.10).is_empty());
        // Throughput collapse + tail blowup: both flagged.
        let bad =
            obj(vec![("pr", num(6.0)), ("entries", Json::Arr(vec![entry("smoke", 500.0, 30.0)]))]);
        let flags = compare(&prev, &bad, 0.10);
        assert_eq!(flags.len(), 2, "{flags:?}");
        assert!(flags[0].contains("imgs_per_s"));
        assert!(flags[1].contains("p95_ms"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unmatched_cases_are_not_compared() {
        let prev =
            obj(vec![("pr", num(5.0)), ("entries", Json::Arr(vec![entry("a", 100.0, 1.0)]))]);
        let cur = obj(vec![("pr", num(6.0)), ("entries", Json::Arr(vec![entry("b", 1.0, 99.0)]))]);
        assert!(compare(&prev, &cur, 0.10).is_empty());
        // Same case name but different config key -> no match either.
        let mut e = entry("a", 1.0, 99.0);
        if let Json::Obj(m) = &mut e {
            m[1].1 = num(4.0); // engines: 2 -> 4
        }
        let cur2 = obj(vec![("pr", num(6.0)), ("entries", Json::Arr(vec![e]))]);
        assert!(compare(&prev, &cur2, 0.10).is_empty());
    }

    #[test]
    fn merge_replaces_matching_cases_and_appends_new_ones() {
        let dir = tmpdir("merge");
        let p = dir.join("BENCH_7.json");
        merge_bench(&p, 7, vec![entry("smoke", 1000.0, 10.0)]).unwrap();
        // Second run of the same case replaces it; a new case appends.
        merge_bench(&p, 7, vec![entry("smoke", 1100.0, 9.0), entry("quantizer", 50.0, 0.2)])
            .unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        let entries = doc.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 2, "{doc:?}");
        let smoke = entries.iter().find(|e| {
            e.get("case").and_then(|c| c.as_str().ok()) == Some("smoke")
        });
        let ips = smoke.unwrap().get("imgs_per_s").unwrap().as_f64().unwrap();
        assert_eq!(ips, 1100.0, "matched case must be replaced, not duplicated");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compare_gates_harness_metrics_too() {
        let bench = |m: f64, med: f64| {
            obj(vec![
                ("case", s("quantizer/pack")),
                ("items", num(4096.0)),
                ("melem_per_s", num(m)),
                ("med_ms", num(med)),
            ])
        };
        let prev = obj(vec![("pr", num(6.0)), ("entries", Json::Arr(vec![bench(200.0, 1.0)]))]);
        let ok = obj(vec![("pr", num(7.0)), ("entries", Json::Arr(vec![bench(195.0, 1.05)]))]);
        assert!(compare(&prev, &ok, 0.10).is_empty());
        let bad = obj(vec![("pr", num(7.0)), ("entries", Json::Arr(vec![bench(100.0, 3.0)]))]);
        let flags = compare(&prev, &bad, 0.10);
        assert_eq!(flags.len(), 2, "{flags:?}");
        assert!(flags[0].contains("melem_per_s"));
        assert!(flags[1].contains("med_ms"));
    }

    #[test]
    fn find_previous_ignores_foreign_files() {
        let dir = tmpdir("foreign");
        std::fs::write(dir.join("BENCH_notanumber.json"), "{}").unwrap();
        std::fs::write(dir.join("other.txt"), "x").unwrap();
        assert!(find_previous(&dir, 6).is_none());
        write_bench(&dir.join("BENCH_6.json"), 6, vec![]).unwrap();
        // Only files strictly earlier than the requested PR count.
        assert!(find_previous(&dir, 6).is_none());
        assert!(find_previous(&dir, 7).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
