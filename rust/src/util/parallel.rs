//! Scoped thread-pool helpers (rayon is unavailable offline).
//!
//! Used by the data pipeline (batch synthesis) and the metric trackers
//! (per-segment scans). XLA's CPU backend already multi-threads the HLO
//! execution, so the default worker count is deliberately modest.

/// Map `f` over `0..n` with up to `workers` threads, preserving order.
pub fn parallel_map_indexed<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let out_ptr = SendPtr(out.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            let out_ptr: SendPtr<Option<T>> = out_ptr;
            scope.spawn(move || {
                // Bind the wrapper itself so 2021 precise capture moves
                // the Send-able SendPtr, not its raw-pointer field.
                let out_ptr = out_ptr;
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(i);
                    // SAFETY: each index i is claimed by exactly one
                    // worker, so writes to out[i] never alias; the scope
                    // join provides the happens-before edge back to the
                    // caller.
                    unsafe { *out_ptr.0.add(i) = Some(v) };
                }
            });
        }
    });
    out.into_iter().map(|v| v.expect("worker wrote all slots")).collect()
}

/// Apply `f(i, &mut items[i])` over all elements with up to `workers`
/// threads. In-place sibling of [`parallel_map_indexed`] for callers
/// that own per-index buffers to refill (e.g. the trainer's per-segment
/// packed quant mirror) rather than values to produce.
pub fn parallel_for_each_mut<T, F>(items: &mut [T], workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let ptr = SendPtr(items.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            let ptr: SendPtr<T> = ptr;
            scope.spawn(move || {
                // Bind the wrapper itself so 2021 precise capture moves
                // the Send-able SendPtr, not its raw-pointer field.
                let ptr = ptr;
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // SAFETY: each index i is claimed by exactly one
                    // worker, so the &mut references never alias; the
                    // scope join provides the happens-before edge back
                    // to the caller.
                    unsafe { f(i, &mut *ptr.0.add(i)) };
                }
            });
        }
    });
}

struct SendPtr<T>(*mut T);
// Manual impls: derive(Copy) would add a spurious `T: Copy` bound.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Default worker count: half the cores, clamped to [1, 8].
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get() / 2)
        .unwrap_or(2)
        .clamp(1, 8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_covers_all() {
        let out = parallel_map_indexed(1000, 4, |i| i * i);
        assert_eq!(out.len(), 1000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(parallel_map_indexed(3, 1, |i| i), vec![0, 1, 2]);
        assert_eq!(parallel_map_indexed(0, 4, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn for_each_mut_updates_every_slot() {
        let mut v: Vec<usize> = (0..500).collect();
        parallel_for_each_mut(&mut v, 4, |i, x| *x = i * 3 + *x);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * 4);
        }
        let mut small = vec![7usize];
        parallel_for_each_mut(&mut small, 8, |_, x| *x += 1);
        assert_eq!(small, vec![8]);
        let mut empty: Vec<usize> = Vec::new();
        parallel_for_each_mut(&mut empty, 4, |_, _| unreachable!());
    }

    #[test]
    fn heavy_closure_parallel_consistency() {
        let serial = parallel_map_indexed(64, 1, |i| (0..1000).map(|j| (i * j) % 97).sum::<usize>());
        let par = parallel_map_indexed(64, 8, |i| (0..1000).map(|j| (i * j) % 97).sum::<usize>());
        assert_eq!(serial, par);
    }
}
