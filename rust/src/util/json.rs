//! Minimal JSON parser/serializer (manifest + config + results I/O).
//!
//! Supports the full JSON grammar we produce and consume: objects,
//! arrays, strings (with escapes incl. \uXXXX), numbers (incl. exponent
//! forms), booleans and null. Object key order is preserved.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    /// Member lookup on an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Member lookup that errors with the key name (manifest loading).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let x = self.as_f64()?;
        if x.fract() != 0.0 {
            bail!("expected integer, got {x}");
        }
        Ok(x as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_i64()?;
        if x < 0 {
            bail!("expected unsigned, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// Array of numbers -> Vec<f32> (golden-vector loading).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Inf; emit null (readers treat
                    // missing metric points as gaps).
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    // 17 significant digits: f64 round-trip safe.
                    let _ = write!(out, "{:?}", x);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builder for result/metadata objects.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr_f32(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, lit: &str) -> Result<()> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(())
        } else {
            bail!("expected {lit:?} at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.eat("true").map(|_| Json::Bool(true)),
            b'f' => self.eat("false").map(|_| Json::Bool(false)),
            b'n' => self.eat("null").map(|_| Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat("{")?;
        let mut m = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string().context("object key")?;
            self.ws();
            self.eat(":")?;
            self.ws();
            let v = self.value()?;
            m.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat("[")?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat("\"")?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i..self.i + 4)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            self.i += 4;
                            let cp = u32::from_str_radix(hex, 16)?;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint {cp:#x}"))?,
                            );
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy raw bytes.
                    let start = self.i - 1;
                    let len = if c >= 0xf0 {
                        4
                    } else if c >= 0xe0 {
                        3
                    } else {
                        2
                    };
                    let bytes = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated utf-8"))?;
                    s.push_str(std::str::from_utf8(bytes)?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        let x: f64 = txt
            .parse()
            .with_context(|| format!("bad number {txt:?} at {start}"))?;
        Ok(Json::Num(x))
    }
}

/// Map helper used by config code.
pub fn to_map(j: &Json) -> Result<BTreeMap<String, Json>> {
    match j {
        Json::Obj(m) => Ok(m.iter().cloned().collect()),
        _ => bail!("expected object"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e3, "x\ny"], "c": {"d": []}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(
            v.get("b").unwrap().as_arr().unwrap()[2].as_f64().unwrap(),
            -2500.0
        );
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""é\t\"ok\" café 日本""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é\t\"ok\" café 日本");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn float_roundtrip_precision() {
        let v = Json::Num(0.1234567890123456789);
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let v = Json::Num(1e-30);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn f32_vec_roundtrip() {
        let xs: Vec<f32> = vec![1.5, -2.25, 1e-8, 3.4e38, -0.0];
        let j = arr_f32(&xs);
        let back = Json::parse(&j.to_string()).unwrap().as_f32_vec().unwrap();
        assert_eq!(xs, back);
    }
}
