//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `tetrajet <subcommand> [--key value]... [--flag]...` with
//! positional arguments collected in order. Unknown options are errors;
//! every consumer declares its options up front so `--help` output can
//! be generated.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    known: Vec<OptSpec>,
}

#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

impl Args {
    /// Parse from an explicit token list (tests) — no option validation
    /// until `finish()`.
    pub fn parse_tokens(tokens: &[String], expect_subcommand: bool) -> Result<Args> {
        let mut a = Args::default();
        let mut it = tokens.iter().peekable();
        if expect_subcommand {
            if let Some(t) = it.peek() {
                if !t.starts_with("--") {
                    a.subcommand = Some(it.next().unwrap().clone());
                }
            }
        }
        while let Some(t) = it.next() {
            if let Some(name) = t.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        a.opts.insert(name.to_string(), it.next().unwrap().clone());
                    }
                    _ => {
                        a.flags.push(name.to_string());
                    }
                }
            } else {
                a.positional.push(t.clone());
            }
        }
        Ok(a)
    }

    pub fn from_env() -> Result<Args> {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Args::parse_tokens(&tokens, true)
    }

    /// Declare an option (for validation + help).
    pub fn opt(&mut self, name: &str, default: Option<&str>, help: &str) -> &mut Self {
        self.known.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(|s| s.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn flag_opt(&mut self, name: &str, help: &str) -> &mut Self {
        self.known.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Validate that all provided options were declared.
    pub fn finish(&self) -> Result<()> {
        for k in self.opts.keys() {
            if !self.known.iter().any(|o| &o.name == k) {
                bail!("unknown option --{k}\n{}", self.help_text());
            }
        }
        for k in &self.flags {
            if !self.known.iter().any(|o| &o.name == k) {
                bail!("unknown flag --{k}\n{}", self.help_text());
            }
        }
        Ok(())
    }

    pub fn help_text(&self) -> String {
        let mut s = String::from("options:\n");
        for o in &self.known {
            let d = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let name = if o.is_flag { format!("{} (flag)", o.name) } else { o.name.clone() };
            s.push_str(&format!("  --{:<18} {}{}\n", name, o.help, d));
        }
        s
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        // NOTE: a bare token after `--name` binds as its value, so flags
        // go last (or before another --option). Positionals come first.
        let a = Args::parse_tokens(&toks("train pos1 --steps 100 --quick"), true).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("steps"), Some("100"));
        assert!(a.has_flag("quick"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse_tokens(&toks("--lr 0.001 --steps 42"), false).unwrap();
        assert_eq!(a.get_usize("steps", 7).unwrap(), 42);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!((a.get_f32("lr", 0.0).unwrap() - 0.001).abs() < 1e-9);
        assert!(a.get_usize("lr", 1).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let mut a = Args::parse_tokens(&toks("--bogus 1"), false).unwrap();
        a.opt("steps", Some("100"), "number of steps");
        assert!(a.finish().is_err());
        let mut b = Args::parse_tokens(&toks("--steps 5"), false).unwrap();
        b.opt("steps", Some("100"), "number of steps");
        assert!(b.finish().is_ok());
    }
}
