//! Deterministic RNG: SplitMix64 seeding + xoshiro256++ stream.
//!
//! Used by the data pipeline and the property-test driver. Everything
//! downstream (dataset contents, batch order) is a pure function of the
//! seeds, so every experiment is exactly reproducible.

/// xoshiro256++ with SplitMix64 seed expansion.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (hierarchical seeding, like
    /// jax.random.fold_in).
    pub fn fold_in(&self, data: u64) -> Rng {
        let mut sm = self.s[0] ^ data.wrapping_mul(0xa24baed4963ee407);
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 mantissa bits.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire reduction on the high 32 bits.
        let x = (self.next_u64() >> 32) as u64;
        ((x * n as u64) >> 32) as usize
    }

    /// Standard normal (Box-Muller; one value per call for simplicity).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-9 {
                let u2 = self.uniform();
                let r = (-2.0 * (u1 as f64).ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2 as f64).cos()) as f32;
            }
        }
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_ne!(Rng::new(7).next_u64(), Rng::new(8).next_u64());
    }

    #[test]
    fn fold_in_independence() {
        let base = Rng::new(1);
        let mut a = base.fold_in(0);
        let mut b = base.fold_in(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
            sum2 += (x as f64) * (x as f64);
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let (mut sum, mut sum2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 2e-2, "mean {mean}");
        assert!((var - 1.0).abs() < 3e-2, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
