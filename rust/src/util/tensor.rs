//! Host-side tensor buffer: shape + contiguous f32 data (row-major).
//!
//! This is the coordinator's view of model state; device transfer is
//! handled by runtime::exec. Only the small set of ops the coordinator
//! actually needs lives here (the heavy math is in the AOT HLO).

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} needs {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Scalar extraction (rank-0 or single-element).
    pub fn item(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("item() on tensor with {} elements", self.data.len());
        }
        Ok(self.data[0])
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// ||a - b||_F without allocating.
    pub fn dist(&self, other: &Tensor) -> f64 {
        debug_assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }

    /// 2-D accessor helpers (row-major).
    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[1]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_checks() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        let t = Tensor::zeros(vec![4, 4]);
        assert_eq!(t.numel(), 16);
        assert_eq!(Tensor::scalar(2.5).item().unwrap(), 2.5);
        assert!(Tensor::zeros(vec![2]).item().is_err());
    }

    #[test]
    fn norms() {
        let t = Tensor::new(vec![2, 2], vec![3.0, 4.0, 0.0, 0.0]).unwrap();
        assert!((t.norm() - 5.0).abs() < 1e-12);
        let u = Tensor::zeros(vec![2, 2]);
        assert!((t.dist(&u) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn row_access() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }
}
