//! Tiny leveled logger with wall-clock-relative timestamps.
//!
//! Verbosity is an [`crate::obs::Level`]: `quiet` silences everything,
//! `warn` keeps warnings, `info` (default) keeps both. The initial
//! level comes from the `TJ_LOG` environment variable (read once,
//! lazily); explicit [`set_level`]/[`set_quiet`] calls override it.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Once;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::obs::Level;

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static LEVEL_FROM_ENV: Once = Once::new();
static START_MS: AtomicU64 = AtomicU64::new(0);

fn now_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

fn level() -> Level {
    LEVEL_FROM_ENV.call_once(|| {
        if let Some(l) = std::env::var("TJ_LOG").ok().as_deref().and_then(Level::parse) {
            LEVEL.store(l as u8, Ordering::Relaxed);
        }
    });
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Quiet,
        1 => Level::Warn,
        _ => Level::Info,
    }
}

/// Set the log level explicitly (wins over `TJ_LOG`).
pub fn set_level(l: Level) {
    // Consume the env read first so it can't overwrite this later.
    LEVEL_FROM_ENV.call_once(|| {});
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Back-compat shim: `quiet=true` maps to [`Level::Warn`] (the old
/// behaviour — info silenced, warnings kept).
pub fn set_quiet(q: bool) {
    set_level(if q { Level::Warn } else { Level::Info });
}

fn elapsed() -> f64 {
    let mut start = START_MS.load(Ordering::Relaxed);
    if start == 0 {
        // First caller claims the epoch; a racing thread keeps the
        // winner's value instead of storing its own. now_ms() is
        // clamped away from the 0 sentinel.
        let n = now_ms().max(1);
        start = match START_MS.compare_exchange(0, n, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => n,
            Err(existing) => existing,
        };
    }
    (now_ms().saturating_sub(start)) as f64 / 1000.0
}

pub fn info(msg: &str) {
    if level() >= Level::Info {
        println!("[{:8.1}s] {}", elapsed(), msg);
    }
}

pub fn warn(msg: &str) {
    if level() >= Level::Warn {
        eprintln!("[{:8.1}s] WARN {}", elapsed(), msg);
    }
}

#[macro_export]
macro_rules! loginfo {
    ($($arg:tt)*) => { $crate::util::log::info(&format!($($arg)*)) };
}

#[macro_export]
macro_rules! logwarn {
    ($($arg:tt)*) => { $crate::util::log::warn(&format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn start_epoch_is_claimed_once_across_threads() {
        // Hammer elapsed() from many threads; every observed epoch must
        // be identical (the CAS winner's), never a mix.
        let hs: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(|| {
                    let _ = elapsed();
                    START_MS.load(Ordering::Relaxed)
                })
            })
            .collect();
        let seen: Vec<u64> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(seen.iter().all(|&s| s == seen[0] && s != 0), "{seen:?}");
    }
}
