//! Tiny leveled logger with wall-clock-relative timestamps.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

static QUIET: AtomicBool = AtomicBool::new(false);
static START_MS: AtomicU64 = AtomicU64::new(0);

fn now_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

pub fn set_quiet(q: bool) {
    QUIET.store(q, Ordering::Relaxed);
}

fn elapsed() -> f64 {
    let start = START_MS.load(Ordering::Relaxed);
    let start = if start == 0 {
        let n = now_ms();
        START_MS.store(n, Ordering::Relaxed);
        n
    } else {
        start
    };
    (now_ms().saturating_sub(start)) as f64 / 1000.0
}

pub fn info(msg: &str) {
    if !QUIET.load(Ordering::Relaxed) {
        println!("[{:8.1}s] {}", elapsed(), msg);
    }
}

pub fn warn(msg: &str) {
    eprintln!("[{:8.1}s] WARN {}", elapsed(), msg);
}

#[macro_export]
macro_rules! loginfo {
    ($($arg:tt)*) => { $crate::util::log::info(&format!($($arg)*)) };
}

#[macro_export]
macro_rules! logwarn {
    ($($arg:tt)*) => { $crate::util::log::warn(&format!($($arg)*)) };
}
