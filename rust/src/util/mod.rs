//! Self-contained substrate utilities.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the coordinator ships its own minimal
//! JSON codec, deterministic RNG, CLI parser, stats helpers and thread
//! pool instead of serde_json / rand / clap / rayon (DESIGN.md
//! §Substitutions).

pub mod benchio;
pub mod cli;
pub mod json;
pub mod log;
pub mod parallel;
pub mod rng;
pub mod stats;
pub mod tensor;
