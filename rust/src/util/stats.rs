//! Small statistics helpers for the metric recorders and bench harness.

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn mean_f32(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len().max(1) as f64).sqrt()
}

/// Percentile via linear interpolation on a sorted copy; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (p / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Fixed-width histogram over [lo, hi); values outside clamp to the
/// first/last bin (matches how the paper's confidence histograms are
/// plotted over the bounded [0, 1] domain).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Histogram {
        assert!(bins > 0 && hi > lo);
        Histogram { lo, hi, counts: vec![0; bins] }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64).floor() as i64).clamp(0, bins as i64 - 1);
        self.counts[idx as usize] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Normalized bin fractions.
    pub fn fractions(&self) -> Vec<f64> {
        let n = self.total().max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / n).collect()
    }

    /// Render a compact unicode sparkline (for terminal experiment output).
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.counts.iter().copied().max().unwrap_or(0).max(1) as f64;
        self.counts
            .iter()
            .map(|&c| BARS[((c as f64 / max) * 7.0).round() as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins_and_clamping() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        for x in [0.1, 0.3, 0.6, 0.9, -5.0, 5.0] {
            h.add(x);
        }
        assert_eq!(h.counts, vec![2, 1, 1, 2]);
        assert_eq!(h.total(), 6);
        let f = h.fractions();
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(h.sparkline().chars().count(), 4);
    }
}
