//! Table 7 — FP4 data-format selection: E2M1 vs E3M0 for the forward
//! (A&W) and backward (grad) quantizers.
//!
//! Paper shape: E2M1 wins on both axes; E3M0 forward is much worse
//! (coarse mantissa-free grid hurts weights/activations most).
//! Requires `make artifacts-full` (fmt_* variants).

use anyhow::Result;

use super::common::{fmt_acc, print_table, save_results, ExpOpts, Runner};
use crate::config::Policy;

pub fn run(opts: &ExpOpts, runner: &mut Runner) -> Result<()> {
    let mut acc = std::collections::BTreeMap::new();
    let mut runs = Vec::new();
    for ff in ["e2m1", "e3m0"] {
        for bf in ["e2m1", "e3m0"] {
            let v = format!("fmt_{ff}_{bf}");
            let r = runner.run_cached(
                &format!("A&W {ff} / Grad {bf}"),
                &v,
                Policy::None,
            )?;
            acc.insert((ff, bf), r.final_acc);
            runs.push(r);
        }
    }
    let rows: Vec<Vec<String>> = ["e2m1", "e3m0"]
        .iter()
        .map(|bf| {
            vec![
                format!("grad {bf}"),
                fmt_acc(acc[&("e2m1", *bf)]),
                fmt_acc(acc[&("e3m0", *bf)]),
            ]
        })
        .collect();
    print_table(
        "Table 7 — FP4 format selection (rows: grad fmt, cols: A&W fmt)",
        &["", "A&W e2m1", "A&W e3m0"],
        &rows,
    );
    save_results(opts, "table7", &["grad_fmt", "aw_e2m1", "aw_e3m0"], &rows, &runs)
}
