//! Figure 3 — trajectories of oscillating latent weights.
//!
//! Trains TetraJet, then for the final stretch records the latent
//! weight (w/S) and dequantized forward weight of the lowest-confidence
//! elements: the paper's picture of latents hovering around a
//! quantization threshold (e.g. -0.75) while the FP4 value flips
//! between the two neighbouring grid points.

use anyhow::Result;

use super::common::{print_table, save_results, ExpOpts, Runner, RunSummary};
use crate::config::{MetricsCfg, Policy};
use crate::coordinator::Trainer;
use crate::runtime::ModelArtifacts;

const TRACKED: usize = 6;

pub fn run(opts: &ExpOpts, runner: &mut Runner) -> Result<()> {
    // Own the artifacts locally (this harness drives the trainer
    // manually instead of using Runner::run_one).
    let client = crate::runtime::cpu_client()?;
    let arts = ModelArtifacts::load(&client, &opts.root, &opts.model, opts.batch, "tetrajet")?;
    let params = runner.initial_params(0)?;

    let mut cfg = opts.base_config("tetrajet");
    cfg.metrics = MetricsCfg::off();
    cfg.policy = Policy::None;
    let tail = (opts.steps / 5).clamp(20, 60);
    let warm_steps = opts.steps.saturating_sub(tail);

    let mut tr = Trainer::new(&arts, cfg, params)?;
    crate::loginfo!("fig3: warmup {warm_steps} steps, then track {tail} steps");
    for _ in 0..warm_steps {
        tr.step()?;
    }
    // Pick the lowest-confidence (most oscillation-prone) elements.
    let (_, conf) = tr.snapshot_latents();
    let mut idx: Vec<usize> = (0..conf.len()).collect();
    idx.sort_by(|&a, &b| conf[a].partial_cmp(&conf[b]).unwrap());
    let tracked: Vec<usize> = idx.into_iter().take(TRACKED).collect();

    let mut rows = Vec::new();
    for t in 0..tail {
        tr.step()?;
        let (lat, _) = tr.snapshot_latents();
        tr.mirror_wq();
        let wq = tr.wq();
        for (k, &i) in tracked.iter().enumerate() {
            rows.push(vec![
                k.to_string(),
                (warm_steps + t).to_string(),
                format!("{:.5}", lat[i]),
                format!("{:.5}", wq[i]),
            ]);
        }
    }
    // Count how many tracked elements actually flipped (the point of
    // the figure).
    let mut flips = 0usize;
    for k in 0..TRACKED {
        let vals: Vec<&str> = rows
            .iter()
            .filter(|r| r[0] == k.to_string())
            .map(|r| r[3].as_str())
            .collect();
        if vals.windows(2).any(|w| w[0] != w[1]) {
            flips += 1;
        }
    }
    crate::loginfo!("fig3: {flips}/{TRACKED} tracked low-confidence elements flipped FP4 value");

    let summary = RunSummary {
        label: "tetrajet-trajectories".into(),
        variant: "tetrajet".into(),
        policy: "none".into(),
        final_acc: tr.eval()?.acc_pct,
        final_loss: 0.0,
        rec: tr.rec.clone(),
    };
    print_table(
        &format!(
            "Figure 3 — latent & quantized trajectories, {TRACKED} least-confident elements (first 12 of {} rows)",
            rows.len()
        ),
        &["elem", "step", "latent w/S", "w_Q (dequant)"],
        &rows[..rows.len().min(12)],
    );
    save_results(opts, "fig3", &["elem", "step", "latent", "wq"], &rows, &[summary])
}
