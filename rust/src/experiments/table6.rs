//! Table 6 — stability ablation: remove the forward weight quantizer
//! (oscillation-free forward) and additionally the activation quantizer
//! (fully stable forward); compare with Q-EMA / Q-Ramping.
//!
//! Paper shape: w/o WQ > TetraJet; w/o WQ&AQ > w/o WQ; Q-EMA and
//! Q-Ramping recover (or beat) the oscillation-free forward accuracy.
//! Requires `make artifacts-full` (tj_no_wq, tj_no_wq_aq variants).

use anyhow::Result;

use super::common::{fmt_acc, print_table, save_results, ExpOpts, Runner};
use crate::config::Policy;

pub fn run(opts: &ExpOpts, runner: &mut Runner) -> Result<()> {
    let runs = vec![
        runner.run_cached("TetraJet", "tetrajet", Policy::None)?,
        runner.run_cached("TetraJet w/o WQ", "tj_no_wq", Policy::None)?,
        runner.run_cached("TetraJet w/o WQ & AQ", "tj_no_wq_aq", Policy::None)?,
        runner.run_cached("TetraJet + Q-EMA", "tetrajet_qema", Policy::None)?,
        runner.run_cached("TetraJet + Q-Ramping", "tetrajet", Policy::qramping_default())?,
    ];
    let rows: Vec<Vec<String>> =
        runs.iter().map(|r| vec![r.label.clone(), fmt_acc(r.final_acc)]).collect();
    print_table(
        "Table 6 — forward-stability ablation (top-1 %)",
        &["config", "top-1 %"],
        &rows,
    );
    save_results(opts, "table6", &["config", "acc"], &rows, &runs)
}
