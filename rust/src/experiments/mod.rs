//! Experiment harness: one module per paper table/figure (DESIGN.md §6).
//!
//! Every harness builds `TrainConfig`s, runs them through the
//! coordinator against the AOT artifacts, prints the paper-style rows /
//! series, and writes machine-readable results under `results/`.

pub mod common;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;
pub mod table7;

use anyhow::{bail, Result};

use crate::experiments::common::{ExpOpts, Runner};

/// Dispatch an experiment by id (`table1`..`table7`, `fig2`..`fig6`, `all`).
/// One artifact-caching Runner is shared across experiments so each
/// variant's HLO is compiled at most once per process.
pub fn run(id: &str, opts: &ExpOpts) -> Result<()> {
    let mut runner = Runner::new(opts)?;
    run_with(id, opts, &mut runner)
}

pub fn run_with(id: &str, opts: &ExpOpts, runner: &mut Runner) -> Result<()> {
    match id {
        "table1" => table1::run(opts, runner),
        "table2" => table2::run(opts, runner),
        "table3" => table3::run(opts, runner),
        "table4" => table4::run(opts, runner),
        "table5" => table5::run(opts, runner),
        "table6" => table6::run(opts, runner),
        "table7" => table7::run(opts, runner),
        "fig2" => fig2::run(opts, runner),
        "fig3" => fig3::run(opts, runner),
        "fig4" => fig4::run(opts, runner),
        "fig5" => fig5::run(opts, runner),
        "fig6" => fig6::run(opts, runner),
        "all" => {
            for id in [
                "table2", "table3", "table4", "table5", "table6", "table7",
                "table1", "fig2", "fig3", "fig4", "fig5", "fig6",
            ] {
                crate::loginfo!("=== experiment {id} ===");
                run_with(id, opts, runner)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?}"),
    }
}
