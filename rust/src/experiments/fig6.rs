//! Figure 6 — number of oscillating weights (R_w > 16) over training.
//!
//! Paper shape: Q-EMA reduces oscillating weights the most, Q-Ramping
//! clearly helps, Dampen is ≈ indistinguishable from plain TetraJet.

use anyhow::Result;

use super::common::{print_table, save_results, ExpOpts, Runner};
use crate::config::Policy;

pub fn run(opts: &ExpOpts, runner: &mut Runner) -> Result<()> {
    let runs = vec![
        runner.run_cached("TetraJet", "tetrajet", Policy::None)?,
        runner.run_cached("TetraJet + Dampen", "tetrajet", Policy::Dampen { lambda: 1e-4 })?,
        runner.run_cached("TetraJet + Q-EMA", "tetrajet_qema", Policy::None)?,
        runner.run_cached("TetraJet + Q-Ramping", "tetrajet", Policy::qramping_default())?,
    ];
    let mut rows = Vec::new();
    for r in &runs {
        for &(step, count, win) in &r.rec.osc_series {
            rows.push(vec![r.label.clone(), step.to_string(), count.to_string(), win.to_string()]);
        }
    }
    // Also a compact summary: mean oscillating count over the last half.
    let mut summary_rows = Vec::new();
    for r in &runs {
        let n = r.rec.osc_series.len();
        let tail = &r.rec.osc_series[n / 2..];
        let mean =
            tail.iter().map(|&(_, c, _)| c as f64).sum::<f64>() / tail.len().max(1) as f64;
        summary_rows.push(vec![r.label.clone(), format!("{mean:.1}")]);
    }
    print_table(
        "Figure 6 — oscillating weights (R_w > 16), mean over late training",
        &["method", "mean #oscillating (late)"],
        &summary_rows,
    );
    save_results(opts, "fig6", &["method", "step", "count", "window"], &rows, &runs)
}
