//! Figure 2 — rate of change across training stages for FP32 vs MXFP4.
//!
//! Paper shape: for FP32, r(W), r(W_Q), r(Y) all decay toward zero with
//! the cosine LR; for MXFP4 (TetraJet), r(W_Q) and r(Y) plateau well
//! above zero at the end of training — the oscillation signature.

use anyhow::Result;

use super::common::{print_table, save_results, ExpOpts, Runner};
use crate::config::Policy;

pub fn run(opts: &ExpOpts, runner: &mut Runner) -> Result<()> {
    let runs = vec![
        runner.run_cached("Full Precision", "fp32", Policy::None)?,
        runner.run_cached("TetraJet (MXFP4)", "tetrajet", Policy::None)?,
    ];
    let mut rows = Vec::new();
    for r in &runs {
        for &(step, rw, rq, ry) in &r.rec.rate_series {
            rows.push(vec![
                r.label.clone(),
                step.to_string(),
                format!("{rw:.5}"),
                format!("{rq:.5}"),
                format!("{ry:.5}"),
            ]);
        }
    }
    print_table(
        "Figure 2 — rate of change by training stage",
        &["method", "step", "r(W)", "r(W_Q)", "r(Y)"],
        &rows,
    );
    save_results(opts, "fig2", &["method", "step", "r_w", "r_wq", "r_y"], &rows, &runs)
}
