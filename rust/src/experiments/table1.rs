//! Table 1 — impact analysis of the six quantizers: activate one
//! quantizer Q^(i) at a time (all others identity) and train.
//!
//! Paper shape: forward quantizers Q1 (activation) and Q2 (weight)
//! account for most of the degradation; backward quantizers Q3..Q6 are
//! nearly free. Requires `make artifacts-full` (q1..q6 variants).

use anyhow::Result;

use super::common::{fmt_acc, print_table, save_results, ExpOpts, Runner};
use crate::config::Policy;

pub fn run(opts: &ExpOpts, runner: &mut Runner) -> Result<()> {
    let mut runs = vec![runner.run_cached("Full Precision", "fp32", Policy::None)?];
    for i in 1..=6 {
        let v = format!("q{i}");
        runs.push(runner.run_cached(&format!("Q{i}"), &v, Policy::None)?);
    }
    runs.push(runner.run_cached("All Quantizers (TetraJet)", "tetrajet", Policy::None)?);
    let fp = runs[0].final_acc;
    let rows: Vec<Vec<String>> = runs
        .iter()
        .map(|r| vec![r.label.clone(), fmt_acc(r.final_acc), format!("{:.2}", fp - r.final_acc)])
        .collect();
    print_table(
        "Table 1 — per-quantizer impact (only Q^(i) active)",
        &["config", "top-1 %", "drop vs FP32"],
        &rows,
    );
    save_results(opts, "table1", &["config", "acc", "drop"], &rows, &runs)
}
