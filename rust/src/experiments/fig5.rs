//! Figure 5 — Q-Ramping's effect on the final quantization-confidence
//! distribution.
//!
//! Paper shape: Q-Ramping shifts mass away from the low-confidence
//! (near-threshold) bins relative to plain TetraJet — it updated the
//! oscillating weights away from thresholds.

use anyhow::Result;

use super::common::{print_table, save_results, ExpOpts, Runner};
use crate::config::Policy;
use crate::util::stats::Histogram;

pub fn run(opts: &ExpOpts, runner: &mut Runner) -> Result<()> {
    let runs = vec![
        runner.run_cached("TetraJet", "tetrajet", Policy::None)?,
        runner.run_cached("TetraJet + Q-Ramping", "tetrajet", Policy::qramping_default())?,
        runner.run_cached("TetraJet + Q-EMA", "tetrajet_qema", Policy::None)?,
    ];
    let mut rows = Vec::new();
    for r in &runs {
        if let Some(snap) = r.rec.conf_snaps.last() {
            let mut h = Histogram::new(0.0, 1.0, snap.conf_hist.len());
            h.counts = snap.conf_hist.iter().map(|&f| (f * 1e6) as u64).collect();
            let low_frac: f64 = snap.conf_hist[..snap.conf_hist.len() / 4].iter().sum();
            rows.push(vec![
                r.label.clone(),
                format!("{:.4}", snap.mean_conf),
                format!("{:.3}", low_frac),
                h.sparkline(),
            ]);
        }
    }
    print_table(
        "Figure 5 — final confidence distribution (low-conf mass = bottom quartile bins)",
        &["method", "mean QuantConf", "low-conf mass", "conf hist [0..1]"],
        &rows,
    );
    save_results(opts, "fig5", &["method", "mean_conf", "low_mass", "hist"], &rows, &runs)
}
