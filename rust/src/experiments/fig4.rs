//! Figure 4 — evolution of the latent-weight distribution and the
//! quantization-confidence distribution over training (TetraJet).
//!
//! Paper shape: latents concentrate near grid points early and spread
//! toward thresholds late; mean confidence declines as training
//! progresses (oscillation becomes more prevalent).

use anyhow::Result;

use super::common::{print_table, save_results, ExpOpts, Runner};
use crate::config::Policy;
use crate::util::stats::Histogram;

pub fn run(opts: &ExpOpts, runner: &mut Runner) -> Result<()> {
    let runs =
        vec![runner.run_cached("TetraJet", "tetrajet", Policy::None)?];
    let mut rows = Vec::new();
    for snap in &runs[0].rec.conf_snaps {
        let mut ch = Histogram::new(0.0, 1.0, snap.conf_hist.len());
        ch.counts = snap
            .conf_hist
            .iter()
            .map(|&f| (f * 1e6) as u64)
            .collect();
        let mut lh = Histogram::new(-6.0, 6.0, snap.latent_hist.len());
        lh.counts = snap
            .latent_hist
            .iter()
            .map(|&f| (f * 1e6) as u64)
            .collect();
        rows.push(vec![
            snap.step.to_string(),
            format!("{:.4}", snap.mean_conf),
            ch.sparkline(),
            lh.sparkline(),
        ]);
    }
    print_table(
        "Figure 4 — confidence & latent distributions over training",
        &["step", "mean QuantConf", "conf hist [0..1]", "latent hist [Qn..Qp]"],
        &rows,
    );
    save_results(opts, "fig4", &["step", "mean_conf", "conf_hist", "latent_hist"], &rows, &runs)
}
