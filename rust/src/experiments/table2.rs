//! Table 2 — main result: 90-epoch ViT pre-training top-1 accuracy.
//!
//! Paper shape to reproduce (per column): FP32 > TetraJet+Q-EMA ≈
//! TetraJet+Q-Ramping > TetraJet > Microscaling > INT4 per-tensor, with
//! TetraJet cutting the FP32 gap vs Microscaling and Q-EMA/Q-Ramping
//! cutting it further (>50% reduction vs the Microscaling baseline).

use anyhow::Result;

use super::common::{fmt_acc, print_table, save_results, ExpOpts, Runner};
use crate::config::Policy;

pub fn run(opts: &ExpOpts, runner: &mut Runner) -> Result<()> {
    let runs = vec![
        runner.run_cached("Full Precision", "fp32", Policy::None)?,
        runner.run_cached("INT4 (per-tensor)", "int4", Policy::None)?,
        runner.run_cached("Microscaling", "microscaling", Policy::None)?,
        runner.run_cached("TetraJet (ours)", "tetrajet", Policy::None)?,
        runner.run_cached("TetraJet + Q-EMA (ours)", "tetrajet_qema", Policy::None)?,
        runner.run_cached("TetraJet + Q-Ramping (ours)", "tetrajet", Policy::qramping_default())?,
    ];
    let fp = runs[0].final_acc;
    let ms = runs[2].final_acc;
    let rows: Vec<Vec<String>> = runs
        .iter()
        .map(|r| {
            let gap = fp - r.final_acc;
            let closed = if (fp - ms) > 0.0 {
                format!("{:.0}%", 100.0 * (1.0 - gap / (fp - ms)))
            } else {
                "-".into()
            };
            vec![
                r.label.clone(),
                fmt_acc(r.final_acc),
                format!("{:.2}", gap),
                closed,
            ]
        })
        .collect();
    print_table(
        "Table 2 — pre-training top-1 accuracy (SynthVision proxy)",
        &["method", "top-1 %", "gap to FP32", "MS-gap closed"],
        &rows,
    );
    save_results(opts, "table2", &["method", "acc", "gap", "gap_closed"], &rows, &runs)
}
