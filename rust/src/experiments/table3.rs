//! Table 3 — end-of-training stability: rate of change of the forward
//! quantized weights r(W_Q) and of a fixed-input block activation r(Y).
//!
//! Paper shape: Q-EMA < Q-Ramping < Dampen ≈ TetraJet on both columns.

use anyhow::Result;

use super::common::{print_table, save_results, ExpOpts, Runner};
use crate::config::Policy;

pub fn run(opts: &ExpOpts, runner: &mut Runner) -> Result<()> {
    let runs = vec![
        runner.run_cached("TetraJet", "tetrajet", Policy::None)?,
        runner.run_cached("TetraJet + Dampen", "tetrajet", Policy::Dampen { lambda: 1e-4 })?,
        runner.run_cached("TetraJet + Q-EMA (ours)", "tetrajet_qema", Policy::None)?,
        runner.run_cached("TetraJet + Q-Ramping (ours)", "tetrajet", Policy::qramping_default())?,
    ];
    let rows: Vec<Vec<String>> = runs
        .iter()
        .map(|r| {
            let last = r.rec.rate_series.last();
            let (rw, rq, ry) = last.map(|&(_, w, q, y)| (w, q, y)).unwrap_or((0.0, 0.0, 0.0));
            vec![
                r.label.clone(),
                format!("{rw:.4}"),
                format!("{rq:.4}"),
                format!("{ry:.4}"),
            ]
        })
        .collect();
    print_table(
        "Table 3 — end-of-training rate of change (lower = stabler)",
        &["method", "r(W)", "r(W_Q)", "r(Y)"],
        &rows,
    );
    save_results(opts, "table3", &["method", "r_w", "r_wq", "r_y"], &rows, &runs)
}
