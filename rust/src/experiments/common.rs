//! Shared experiment-harness machinery: artifact/run caching, result
//! records, table rendering and results/ output.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::Result;
use xla::PjRtClient;

use crate::config::{MetricsCfg, Policy, TrainConfig};
use crate::coordinator::{Recorder, Trainer};
use crate::runtime::{artifacts, ModelArtifacts};
use crate::util::json::{num, obj, s, Json};

/// Options shared by every experiment (CLI-controlled).
#[derive(Debug, Clone)]
pub struct ExpOpts {
    pub root: PathBuf,
    pub results: PathBuf,
    pub model: String,
    pub batch: usize,
    pub steps: usize,
    pub eval_samples: usize,
    pub quick: bool,
}

impl ExpOpts {
    pub fn new(quick: bool) -> ExpOpts {
        ExpOpts {
            root: artifacts::default_root(),
            results: PathBuf::from("results"),
            model: "vit-micro".into(),
            batch: 16,
            steps: if quick { 120 } else { 400 },
            eval_samples: if quick { 256 } else { 512 },
            quick,
        }
    }

    pub fn base_config(&self, variant: &str) -> TrainConfig {
        let mut c = TrainConfig::default_run(variant);
        c.model = self.model.clone();
        c.batch = self.batch;
        c.steps = self.steps;
        c.warmup = (self.steps / 10).max(1);
        c.eval_samples = self.eval_samples;
        c
    }
}

/// One finished run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub label: String,
    pub variant: String,
    pub policy: String,
    pub final_acc: f64,
    pub final_loss: f64,
    pub rec: Recorder,
}

/// Variants whose quantization recipe is *identical by construction* to
/// another artifact (asserted by python/tests/test_model.py); the run
/// driver aliases them to avoid recompiling/retraining the same math.
pub fn variant_alias(v: &str) -> &str {
    match v {
        "abl_stoch_double_tf" => "tetrajet",
        "abl_det_naive_floor" => "microscaling",
        "fmt_e2m1_e2m1" => "tetrajet",
        other => other,
    }
}

/// Artifact-caching run driver (loads/compiles each variant once, and
/// caches finished runs keyed by (variant, policy, steps) — the suite
/// uses one shared metrics configuration so e.g. the plain TetraJet run
/// feeds Table 2/3/4 and Figures 2/4/5/6 alike).
pub struct Runner {
    client: PjRtClient,
    opts: ExpOpts,
    cache: HashMap<String, ModelArtifacts>,
    init_cache: HashMap<i32, Vec<f32>>,
    run_cache: HashMap<String, RunSummary>,
}

impl Runner {
    pub fn new(opts: &ExpOpts) -> Result<Runner> {
        Ok(Runner {
            client: crate::runtime::cpu_client()?,
            opts: opts.clone(),
            cache: HashMap::new(),
            init_cache: HashMap::new(),
            run_cache: HashMap::new(),
        })
    }

    pub fn opts(&self) -> &ExpOpts {
        &self.opts
    }

    pub fn artifacts(&mut self, variant: &str) -> Result<&ModelArtifacts> {
        if !self.cache.contains_key(variant) {
            crate::loginfo!("loading artifacts for {variant}");
            let arts = ModelArtifacts::load(
                &self.client,
                &self.opts.root,
                &self.opts.model,
                self.opts.batch,
                variant,
            )?;
            self.cache.insert(variant.to_string(), arts);
        }
        Ok(&self.cache[variant])
    }

    pub fn initial_params(&mut self, seed: i32) -> Result<Vec<f32>> {
        if !self.init_cache.contains_key(&seed) {
            let p = artifacts::run_init(&self.client, &self.opts.root, &self.opts.model, seed)?;
            self.init_cache.insert(seed, p);
        }
        Ok(self.init_cache[&seed].clone())
    }

    /// Metrics collected for every cached suite run: rate windows, the
    /// Fig. 6 oscillation series and confidence snapshots. Slightly
    /// superset of what any single table needs; overhead is a few ms of
    /// host work per step plus one probe forward per probe_every steps.
    pub fn suite_metrics(&self) -> MetricsCfg {
        let steps = self.opts.steps;
        MetricsCfg {
            rate_window: (steps / 8).max(10),
            probe_every: ((steps / 8).max(10) / 8).max(2),
            osc_window: (steps / 8).clamp(10, 50),
            rw_threshold: 16.0,
            conf_every: (steps / 4).max(1),
        }
    }

    /// Cached run: returns the previously trained summary when the same
    /// (variant, policy, steps) was already executed this process.
    pub fn run_cached(
        &mut self,
        label: &str,
        variant: &str,
        policy: Policy,
    ) -> Result<RunSummary> {
        let variant = variant_alias(variant);
        let key = format!("{variant}|{}|{}", policy.to_json().to_string(), self.opts.steps);
        if let Some(hit) = self.run_cache.get(&key) {
            let mut r = hit.clone();
            r.label = label.to_string();
            return Ok(r);
        }
        let m = self.suite_metrics();
        let r = self.run_one(label, variant, policy, m, |_| {})?;
        self.run_cache.insert(key, r.clone());
        Ok(r)
    }

    /// Train one configuration to completion and summarize.
    pub fn run_one(
        &mut self,
        label: &str,
        variant: &str,
        policy: Policy,
        metrics: MetricsCfg,
        tweak: impl FnOnce(&mut TrainConfig),
    ) -> Result<RunSummary> {
        let mut cfg = self.opts.base_config(variant);
        cfg.policy = policy;
        cfg.metrics = metrics;
        tweak(&mut cfg);
        let params = self.initial_params(cfg.init_seed)?;
        // Split borrows: artifacts() caches into self.cache.
        self.artifacts(variant)?;
        let arts = &self.cache[variant];
        let policy_name = cfg.policy.name().to_string();
        let mut tr = Trainer::new(arts, cfg, params)?;
        let t0 = std::time::Instant::now();
        let ev = tr.run()?;
        crate::loginfo!(
            "{label}: acc {:.2}% loss {:.4} ({} steps, {:.1}s)",
            ev.acc_pct,
            ev.mean_loss,
            tr.state.step,
            t0.elapsed().as_secs_f64()
        );
        Ok(RunSummary {
            label: label.to_string(),
            variant: variant.to_string(),
            policy: policy_name,
            final_acc: ev.acc_pct,
            final_loss: ev.mean_loss,
            rec: tr.rec.clone(),
        })
    }
}

/// Fixed-width terminal table (paper-style rows).
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n## {title}");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
        }
        s
    };
    println!("{}", line(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>()));
    println!(
        "|{}|",
        widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    );
    for row in rows {
        println!("{}", line(row));
    }
    println!();
}

/// Persist experiment output (rows + per-run recorders) to results/.
pub fn save_results(
    opts: &ExpOpts,
    id: &str,
    headers: &[&str],
    rows: &[Vec<String>],
    runs: &[RunSummary],
) -> Result<()> {
    std::fs::create_dir_all(&opts.results)?;
    // CSV of the table.
    let mut csv = headers.join(",");
    csv.push('\n');
    for r in rows {
        csv.push_str(&r.join(","));
        csv.push('\n');
    }
    std::fs::write(opts.results.join(format!("{id}.csv")), &csv)?;
    // Full JSON (configs echoed + curves).
    let j = obj(vec![
        ("experiment", s(id)),
        ("model", s(&opts.model)),
        ("steps", num(opts.steps as f64)),
        (
            "runs",
            Json::Arr(
                runs.iter()
                    .map(|r| {
                        obj(vec![
                            ("label", s(&r.label)),
                            ("variant", s(&r.variant)),
                            ("policy", s(&r.policy)),
                            ("final_acc", num(r.final_acc)),
                            ("final_loss", num(r.final_loss)),
                            ("recorder", r.rec.to_json()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(opts.results.join(format!("{id}.json")), j.to_string())?;
    crate::loginfo!("results saved to {}/{id}.{{csv,json}}", opts.results.display());
    Ok(())
}

pub fn fmt_acc(x: f64) -> String {
    format!("{x:.2}")
}
