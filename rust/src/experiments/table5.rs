//! Table 5 — quantization-method ablation: backward rounding
//! (stochastic/deterministic) x gradient flow (double-quantization /
//! Microscaling's fresh-tensor design) x shared-scale rule
//! (truncation-free / floor). 8 combos; TetraJet = stoch+double+tf,
//! Microscaling = det+naive+floor.
//!
//! Paper shape: the unbiased corner (stoch, double, tf) is best, and
//! stochastic rounding only pays off when the gradient is unbiased.
//! Requires `make artifacts-full`.

use anyhow::Result;

use super::common::{fmt_acc, print_table, save_results, ExpOpts, Runner};
use crate::config::Policy;

pub fn run(opts: &ExpOpts, runner: &mut Runner) -> Result<()> {
    let mut runs = Vec::new();
    for rnd in ["stoch", "det"] {
        for flow in ["double", "naive"] {
            for sc in ["tf", "floor"] {
                let v = format!("abl_{rnd}_{flow}_{sc}");
                let note = match (rnd, flow, sc) {
                    ("stoch", "double", "tf") => " <- TetraJet (unbiased)",
                    ("det", "naive", "floor") => " <- Microscaling",
                    _ => "",
                };
                let label = format!("{rnd}/{flow}/{sc}{note}");
                runs.push(runner.run_cached(&label, &v, Policy::None)?);
            }
        }
    }
    let rows: Vec<Vec<String>> =
        runs.iter().map(|r| vec![r.label.clone(), fmt_acc(r.final_acc)]).collect();
    print_table(
        "Table 5 — rounding x grad-flow x scaling ablation (top-1 %)",
        &["backward quant / XW for grad / scale", "top-1 %"],
        &rows,
    );
    save_results(opts, "table5", &["combo", "acc"], &rows, &runs)
}
