//! Table 4 — oscillation-reduction method comparison: final accuracy of
//! TetraJet vs +Dampen / +Freeze / +Q-EMA / +Q-Ramping.
//!
//! Paper shape: Dampen ≈ no change, Freeze catastrophic (frozen weights
//! can't recover during pre-training), Q-EMA & Q-Ramping best.

use anyhow::Result;

use super::common::{fmt_acc, print_table, save_results, ExpOpts, Runner};
use crate::config::Policy;

pub fn run(opts: &ExpOpts, runner: &mut Runner) -> Result<()> {
    let runs = vec![
        runner.run_cached("TetraJet", "tetrajet", Policy::None)?,
        runner.run_cached("TetraJet + Dampen", "tetrajet", Policy::Dampen { lambda: 1e-4 })?,
        runner.run_cached("TetraJet + Freeze", "tetrajet", Policy::freeze_default())?,
        runner.run_cached("TetraJet + Q-EMA (ours)", "tetrajet_qema", Policy::None)?,
        runner.run_cached("TetraJet + Q-Ramping (ours)", "tetrajet", Policy::qramping_default())?,
    ];
    let rows: Vec<Vec<String>> =
        runs.iter().map(|r| vec![r.label.clone(), fmt_acc(r.final_acc)]).collect();
    print_table(
        "Table 4 — oscillation reduction methods (final top-1 %)",
        &["method", "top-1 %"],
        &rows,
    );
    save_results(opts, "table4", &["method", "acc"], &rows, &runs)
}
