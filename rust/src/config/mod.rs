//! Training / experiment configuration.
//!
//! `TrainConfig` fully determines a run (model + variant + data seeds +
//! schedule + coordinator policy); `Policy` selects the L3 oscillation-
//! reduction controller layered on top of the AOT artifact. Configs
//! round-trip through JSON so experiment harnesses can log exactly what
//! they ran.

use anyhow::{bail, Result};

use crate::util::json::{num, obj, s, Json};

/// The variant names baked by `python/compile/model.py` (`_registry`).
/// Kept in sync by rust/tests integration test `variant_names_match`.
pub const CORE_VARIANTS: &[&str] =
    &["fp32", "microscaling", "tetrajet", "tetrajet_qema", "int4"];

pub fn all_variants() -> Vec<String> {
    let mut v: Vec<String> = CORE_VARIANTS.iter().map(|s| s.to_string()).collect();
    for i in 1..=6 {
        v.push(format!("q{i}"));
    }
    for rnd in ["stoch", "det"] {
        for flow in ["double", "naive"] {
            for sc in ["tf", "floor"] {
                v.push(format!("abl_{rnd}_{flow}_{sc}"));
            }
        }
    }
    for ff in ["e2m1", "e3m0"] {
        for bf in ["e2m1", "e3m0"] {
            v.push(format!("fmt_{ff}_{bf}"));
        }
    }
    v.push("tj_no_wq".into());
    v.push("tj_no_wq_aq".into());
    // NVFP4 variant (TetraJet-v2 recipe): 16-element groups, E4M3
    // scales, outlier clamp. Not in CORE_VARIANTS — like the ablation
    // set, its artifacts come from `make artifacts-full`.
    v.push("nvfp4".into());
    v
}

/// Coordinator-side oscillation policy (paper §5/§6 + Table 4 baselines).
#[derive(Debug, Clone, PartialEq)]
pub enum Policy {
    /// Plain training (the artifact's own quantizers only).
    None,
    /// Adaptive Ramping Optimizer (paper §6 / Alg. 2): every `t_update`
    /// steps run a `t0`-step detection window with ramping disabled,
    /// then set N_w = min(k2 * floor(R_w / k1) + 1, n_max).
    QRamping { k1: f32, k2: f32, n_max: f32, t0: usize, t_update: usize },
    /// Dampen baseline (Nagel et al. 2022): loss += lambda * ||W - Q(W)||^2.
    Dampen { lambda: f32 },
    /// Freeze baseline (Nagel et al. 2022): permanently pin elements
    /// whose flipping frequency exceeds `f_th` to their running average.
    Freeze { f_th: f32, t0: usize, t_update: usize },
}

impl Policy {
    pub fn qramping_default() -> Policy {
        // Paper App. C.3: k1 = 16, k2 = 5 are the default choices.
        Policy::QRamping { k1: 16.0, k2: 5.0, n_max: 16.0, t0: 30, t_update: 200 }
    }

    pub fn freeze_default() -> Policy {
        // Nagel et al. configuration adapted to pre-training scale.
        Policy::Freeze { f_th: 0.1, t0: 30, t_update: 200 }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::None => "none",
            Policy::QRamping { .. } => "qramping",
            Policy::Dampen { .. } => "dampen",
            Policy::Freeze { .. } => "freeze",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Policy::None => obj(vec![("name", s("none"))]),
            Policy::QRamping { k1, k2, n_max, t0, t_update } => obj(vec![
                ("name", s("qramping")),
                ("k1", num(*k1 as f64)),
                ("k2", num(*k2 as f64)),
                ("n_max", num(*n_max as f64)),
                ("t0", num(*t0 as f64)),
                ("t_update", num(*t_update as f64)),
            ]),
            Policy::Dampen { lambda } => {
                obj(vec![("name", s("dampen")), ("lambda", num(*lambda as f64))])
            }
            Policy::Freeze { f_th, t0, t_update } => obj(vec![
                ("name", s("freeze")),
                ("f_th", num(*f_th as f64)),
                ("t0", num(*t0 as f64)),
                ("t_update", num(*t_update as f64)),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Policy> {
        Ok(match j.req("name")?.as_str()? {
            "none" => Policy::None,
            "qramping" => Policy::QRamping {
                k1: j.req("k1")?.as_f64()? as f32,
                k2: j.req("k2")?.as_f64()? as f32,
                n_max: j.req("n_max")?.as_f64()? as f32,
                t0: j.req("t0")?.as_usize()?,
                t_update: j.req("t_update")?.as_usize()?,
            },
            "dampen" => Policy::Dampen { lambda: j.req("lambda")?.as_f64()? as f32 },
            "freeze" => Policy::Freeze {
                f_th: j.req("f_th")?.as_f64()? as f32,
                t0: j.req("t0")?.as_usize()?,
                t_update: j.req("t_update")?.as_usize()?,
            },
            other => bail!("unknown policy {other:?}"),
        })
    }
}

/// Metric-collection knobs (0 = disabled).
#[derive(Debug, Clone)]
pub struct MetricsCfg {
    /// Track r(W)/r(W_Q) every step within windows of this length,
    /// reporting at window ends (Fig. 2 / Table 3).
    pub rate_window: usize,
    /// Run the fixed-batch activation probe every N steps (r(Y)).
    pub probe_every: usize,
    /// Oscillation-ratio window length for the Fig. 6 series.
    pub osc_window: usize,
    /// R_w threshold for "oscillating" (paper: 16).
    pub rw_threshold: f32,
    /// Snapshot confidence/latent histograms every N steps (Fig. 4/5).
    pub conf_every: usize,
}

impl MetricsCfg {
    pub fn off() -> MetricsCfg {
        MetricsCfg { rate_window: 0, probe_every: 0, osc_window: 0, rw_threshold: 16.0, conf_every: 0 }
    }

    pub fn standard() -> MetricsCfg {
        MetricsCfg { rate_window: 0, probe_every: 0, osc_window: 50, rw_threshold: 16.0, conf_every: 0 }
    }

    pub fn full() -> MetricsCfg {
        MetricsCfg { rate_window: 20, probe_every: 5, osc_window: 50, rw_threshold: 16.0, conf_every: 100 }
    }
}

/// Everything that determines one training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub variant: String,
    pub batch: usize,
    pub steps: usize,
    pub base_lr: f32,
    pub min_lr: f32,
    pub warmup: usize,
    pub weight_decay: f32,
    pub ema_beta: f32,
    pub init_seed: i32,
    pub train_seed: u64,
    pub data_seed: u64,
    pub train_size: usize,
    pub val_size: usize,
    pub eval_every: usize,
    pub eval_samples: usize,
    pub policy: Policy,
    pub metrics: MetricsCfg,
}

impl TrainConfig {
    /// Experiment-suite defaults (vit-micro proxy; DESIGN.md §6).
    pub fn default_run(variant: &str) -> TrainConfig {
        TrainConfig {
            model: "vit-micro".into(),
            variant: variant.into(),
            batch: 16,
            steps: 400,
            base_lr: 1e-3,
            min_lr: 1e-5,
            warmup: 40,
            weight_decay: 0.05,
            ema_beta: 0.998,
            init_seed: 0,
            train_seed: 42,
            data_seed: 7,
            train_size: 8192,
            val_size: 1024,
            eval_every: 0,
            eval_samples: 512,
            policy: Policy::None,
            metrics: MetricsCfg::off(),
        }
    }

    /// Cosine schedule with linear warmup (the DeiT recipe's shape).
    pub fn lr_at(&self, step: usize) -> f32 {
        if self.warmup > 0 && step < self.warmup {
            return self.base_lr * (step + 1) as f32 / self.warmup as f32;
        }
        let t = (step - self.warmup) as f32 / (self.steps - self.warmup).max(1) as f32;
        let t = t.clamp(0.0, 1.0);
        self.min_lr
            + 0.5 * (self.base_lr - self.min_lr) * (1.0 + (std::f32::consts::PI * t).cos())
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", s(&self.model)),
            ("variant", s(&self.variant)),
            ("batch", num(self.batch as f64)),
            ("steps", num(self.steps as f64)),
            ("base_lr", num(self.base_lr as f64)),
            ("min_lr", num(self.min_lr as f64)),
            ("warmup", num(self.warmup as f64)),
            ("weight_decay", num(self.weight_decay as f64)),
            ("ema_beta", num(self.ema_beta as f64)),
            ("init_seed", num(self.init_seed as f64)),
            ("train_seed", num(self.train_seed as f64)),
            ("data_seed", num(self.data_seed as f64)),
            ("train_size", num(self.train_size as f64)),
            ("val_size", num(self.val_size as f64)),
            ("eval_every", num(self.eval_every as f64)),
            ("eval_samples", num(self.eval_samples as f64)),
            ("policy", self.policy.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let mut c = TrainConfig::default_run("tetrajet");
        c.base_lr = 1.0;
        c.min_lr = 0.0;
        c.warmup = 10;
        c.steps = 110;
        assert!((c.lr_at(0) - 0.1).abs() < 1e-6);
        assert!((c.lr_at(9) - 1.0).abs() < 1e-6);
        assert!((c.lr_at(10) - 1.0).abs() < 1e-6);
        assert!(c.lr_at(60) < c.lr_at(20));
        assert!(c.lr_at(109) < 0.01);
        // Past the end it clamps at min_lr.
        assert!(c.lr_at(1000) <= 1e-6 + 0.0);
    }

    #[test]
    fn policy_json_roundtrip() {
        for p in [
            Policy::None,
            Policy::qramping_default(),
            Policy::Dampen { lambda: 1e-4 },
            Policy::freeze_default(),
        ] {
            let j = p.to_json();
            let back = Policy::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
            assert_eq!(p, back);
        }
    }

    #[test]
    fn variant_list_contains_paper_sets() {
        let v = all_variants();
        assert_eq!(v.len(), 5 + 6 + 8 + 4 + 2 + 1);
        assert!(v.contains(&"abl_det_naive_floor".to_string())); // Microscaling combo
        assert!(v.contains(&"fmt_e3m0_e2m1".to_string()));
        assert!(v.contains(&"nvfp4".to_string()));
    }
}
