//! Observability: global-free metrics, deterministic request tracing,
//! and snapshot/exposition surfaces.
//!
//! Three pieces, all std-only:
//!
//! - [`metrics`] — a [`MetricsRegistry`] of named counters / gauges /
//!   fixed-bucket histograms / raw series, handed out as `Arc`-backed
//!   handles and threaded *by handle* through the scheduler, fleet,
//!   kernel layer and trainer. No global state: the registry lives
//!   with the loop it measures, and [`crate::serve::LatencySummary::from_registry`]
//!   derives the end-of-run summary from the same cells the loop
//!   incremented.
//! - [`trace`] — a [`TraceSink`] emitting Chrome trace-event JSONL
//!   (load into `chrome://tracing` / Perfetto). Every timestamp comes
//!   from the caller's injected clock, so a `--pace virtual` load test
//!   replays to a byte-identical file; the FNV-1a [`TraceDigest`] over
//!   the emitted bytes is the determinism witness asserted in tests.
//! - [`export`] — [`spawn_metrics_endpoint`], a std::net text
//!   exposition endpoint for `serve --metrics-addr`, plus the periodic
//!   one-line `METRICS {...}` snapshots the fleet loop prints.
//! - [`osclog`] — the `OSCLOG01` oscillation-telemetry artifact:
//!   segment naming ([`OscSegment`], [`split_segments`]) and the
//!   digest-carrying JSONL writer ([`OscLogWriter`]) used by
//!   `train --osc-out` and replayed by `tetrajet report`.
//!
//! Request lifecycle as traced (tid 0 = scheduler/request events,
//! tid 1 = fleet execution):
//!
//! ```text
//! admit (i) ── queued (X: arrival→batch formation) ── batched (i)
//!          └─ shard-forward (X) ── gather (X) ── redeemed (i)
//! ```

pub mod export;
pub mod metrics;
pub mod osclog;
pub mod trace;

pub use export::spawn_metrics_endpoint;
pub use metrics::{
    Counter, FCounter, Gauge, Histo, KernelMetrics, LAYER_NAMES, MetricsRegistry, RingAgg, Series,
    TsRing, SERIES_DEFAULT_CAP,
};
pub use osclog::{split_segments, OscLogWriter, OscSegment, OSCLOG_FORMAT};
pub use trace::{TraceDigest, TraceSink};

/// Log verbosity, ordered: `Quiet` < `Warn` < `Info`. Routed through
/// `util::log` and settable via the `TJ_LOG` environment variable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Silence everything, warnings included.
    Quiet = 0,
    /// Warnings only.
    Warn = 1,
    /// Warnings and info lines (default).
    Info = 2,
}

impl Level {
    /// Parse a `TJ_LOG` value; unknown strings yield `None`.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "quiet" | "off" | "silent" => Some(Level::Quiet),
            "warn" | "warning" => Some(Level::Warn),
            "info" | "on" => Some(Level::Info),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("quiet"), Some(Level::Quiet));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse(" info "), Some(Level::Info));
        assert_eq!(Level::parse("debug"), None);
        assert!(Level::Quiet < Level::Warn && Level::Warn < Level::Info);
    }
}
