//! `OSCLOG01` — versioned JSONL artifact for per-segment oscillation
//! telemetry (`train --osc-out PATH`).
//!
//! Layout (one JSON object per line):
//!
//! ```text
//! {"format":"OSCLOG01","variant":...,"mirror":...,"group_size":...,
//!  "scale_enc":...,"threshold":...,"osc_window":...,"seed":...,
//!  "total":N,"segments":[{seg},...]}            <- header, line 1
//! {"t":S,"flips":[..],"conf":[..],"wdist":[..]} <- one per step
//! {"window_end":S,"len":W,"osc":[..],"osc_total":K}
//!                                               <- one per osc window
//! ```
//!
//! Per-step arrays are indexed by the header's `segments` order. The
//! writer folds every emitted byte (newline included) into the same
//! FNV-1a [`TraceDigest`] the trace sink uses, so a fixed (seed,
//! config) run is witnessed by one 16-hex-digit digest; `tetrajet
//! report` and `obs-validate --osclog` recompute it from the file.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{num, s, Json};

use super::trace::TraceDigest;

/// Format tag carried in the header line of every artifact.
pub const OSCLOG_FORMAT: &str = "OSCLOG01";

/// One observed slice of the quantized weight vector: a manifest
/// segment, split per transformer depth when the segment is
/// depth-stacked (shape `[d, r, c]`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OscSegment {
    /// `blocks.qkv_w.d3` for depth 3 of a stacked segment, else the
    /// manifest name itself.
    pub name: String,
    /// Layer kind: `qkv` / `proj` / `fc1` / `fc2` / `other`.
    pub kind: String,
    /// Transformer depth, or -1 when the segment is not depth-stacked.
    pub depth: i64,
    /// Element offset into the concatenated quantized weight vector.
    pub offset: usize,
    /// Element count of this slice.
    pub size: usize,
    /// Row width (the quantization group axis), inherited from the
    /// manifest segment.
    pub cols: usize,
}

impl OscSegment {
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("name".to_string(), s(&self.name)),
            ("kind".to_string(), s(&self.kind)),
            ("depth".to_string(), num(self.depth as f64)),
            ("offset".to_string(), num(self.offset as f64)),
            ("size".to_string(), num(self.size as f64)),
            ("cols".to_string(), num(self.cols as f64)),
        ])
    }
}

/// Classify a segment name into the four quantized ViT layer kinds.
pub fn layer_kind(name: &str) -> &'static str {
    for k in super::metrics::LAYER_NAMES {
        if name.contains(k) {
            return k;
        }
    }
    "other"
}

/// Split one manifest segment (name, tensor shape, element offset into
/// the quantized weight vector) into [`OscSegment`]s: depth-stacked
/// tensors (`[d, r, c]`) become one slice per depth, anything else is
/// a single slice. Slices tile the segment exactly in offset order.
pub fn split_segments(name: &str, shape: &[usize], offset: usize) -> Vec<OscSegment> {
    let kind = layer_kind(name).to_string();
    if shape.len() == 3 {
        let (d, rows, cols) = (shape[0], shape[1], shape[2]);
        let per = rows * cols;
        (0..d)
            .map(|i| OscSegment {
                name: format!("{name}.d{i}"),
                kind: kind.clone(),
                depth: i as i64,
                offset: offset + i * per,
                size: per,
                cols,
            })
            .collect()
    } else {
        let size: usize = shape.iter().product();
        let cols = shape.last().copied().unwrap_or(1).max(1);
        vec![OscSegment { name: name.to_string(), kind, depth: -1, offset, size, cols }]
    }
}

/// Writes OSCLOG lines to an optional file while hashing them —
/// the oscillation analogue of [`super::TraceSink`].
pub struct OscLogWriter {
    out: Option<Box<dyn Write + Send>>,
    digest: TraceDigest,
    lines: u64,
}

impl std::fmt::Debug for OscLogWriter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OscLogWriter")
            .field("lines", &self.lines)
            .field("digest", &self.digest.hex())
            .finish()
    }
}

impl OscLogWriter {
    /// Buffered file sink at `path` (parent directories are created).
    pub fn to_file(path: &Path) -> Result<OscLogWriter> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating osclog file {}", path.display()))?;
        Ok(OscLogWriter {
            out: Some(Box::new(std::io::BufWriter::new(f))),
            digest: TraceDigest::new(),
            lines: 0,
        })
    }

    /// Digest-only sink: lines are hashed but written nowhere (tests,
    /// determinism checks without artifacts).
    pub fn in_memory() -> OscLogWriter {
        OscLogWriter { out: None, digest: TraceDigest::new(), lines: 0 }
    }

    /// Emit one JSONL line.
    pub fn line(&mut self, j: &Json) {
        let line = j.to_string();
        self.digest.update(line.as_bytes());
        self.digest.update(b"\n");
        self.lines += 1;
        if let Some(out) = &mut self.out {
            let _ = writeln!(out, "{line}");
        }
    }

    pub fn lines(&self) -> u64 {
        self.lines
    }

    /// 16-hex-digit FNV-1a digest over all emitted bytes.
    pub fn digest(&self) -> String {
        self.digest.hex()
    }

    /// Flush the underlying writer (call before reading the file).
    pub fn finish(&mut self) -> Result<()> {
        if let Some(out) = &mut self.out {
            out.flush().context("flushing osclog sink")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_tiles_depth_stacked_segments_exactly() {
        let segs = split_segments("blocks.qkv_w", &[3, 96, 32], 128);
        assert_eq!(segs.len(), 3);
        for (i, seg) in segs.iter().enumerate() {
            assert_eq!(seg.name, format!("blocks.qkv_w.d{i}"));
            assert_eq!(seg.kind, "qkv");
            assert_eq!(seg.depth, i as i64);
            assert_eq!(seg.size, 96 * 32);
            assert_eq!(seg.cols, 32);
        }
        // Contiguous tiling from the segment offset.
        assert_eq!(segs[0].offset, 128);
        for w in segs.windows(2) {
            assert_eq!(w[0].offset + w[0].size, w[1].offset);
        }

        let flat = split_segments("head_w", &[10, 64], 0);
        assert_eq!(flat.len(), 1);
        assert_eq!(flat[0].kind, "other");
        assert_eq!(flat[0].depth, -1);
        assert_eq!(flat[0].size, 640);
        assert_eq!(flat[0].cols, 64);
    }

    #[test]
    fn layer_kinds_cover_the_block_names() {
        assert_eq!(layer_kind("blocks.qkv_w"), "qkv");
        assert_eq!(layer_kind("blocks.proj_w"), "proj");
        assert_eq!(layer_kind("blocks.fc1_w"), "fc1");
        assert_eq!(layer_kind("blocks.fc2_w"), "fc2");
        assert_eq!(layer_kind("embed.patch_w"), "other");
    }

    #[test]
    fn writer_digest_matches_reference_fold() {
        let mut w = OscLogWriter::in_memory();
        let j = Json::Obj(vec![("t".to_string(), num(0.0))]);
        w.line(&j);
        let mut d = TraceDigest::new();
        d.update(j.to_string().as_bytes());
        d.update(b"\n");
        assert_eq!(w.digest(), d.hex());
        assert_eq!(w.lines(), 1);
        // Identical streams share a digest; any perturbation moves it.
        let mut w2 = OscLogWriter::in_memory();
        w2.line(&j);
        assert_eq!(w2.digest(), w.digest());
        w2.line(&j);
        assert_ne!(w2.digest(), w.digest());
    }
}
