//! Chrome trace-event JSONL sink with a running content digest.
//!
//! One event per line (not the array form), so a crashed run still
//! leaves a loadable prefix and `obs-validate` can stream it. Each
//! line is a Chrome `trace_event`:
//!
//! ```text
//! {"name":"queued","ph":"X","ts":1234,"dur":500,"pid":1,"tid":0,"args":{...}}
//! ```
//!
//! `ph` is `"X"` (complete span with `dur`) or `"i"` (instant);
//! timestamps are microseconds (the emitting code passes milliseconds
//! from its injected clock and they are scaled here). Nothing in this
//! module reads a wall clock: determinism is entirely the caller's —
//! under `--pace virtual` every ts/dur is derived from the simulated
//! clock, so a fixed (seed, config) run produces byte-identical lines.
//!
//! The sink folds every emitted byte (newline included) into an
//! FNV-1a 64-bit [`TraceDigest`], which is what the determinism tests
//! and `serve --trace-out` assert/report on.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{num, s, Json};

/// Incremental FNV-1a 64-bit hash over emitted bytes.
#[derive(Debug, Clone)]
pub struct TraceDigest(u64);

impl Default for TraceDigest {
    fn default() -> TraceDigest {
        TraceDigest(0xcbf2_9ce4_8422_2325)
    }
}

impl TraceDigest {
    pub fn new() -> TraceDigest {
        TraceDigest::default()
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    /// 16-hex-digit digest of everything folded in so far.
    pub fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

/// Writes trace-event lines to an optional sink while hashing them.
pub struct TraceSink {
    out: Option<Box<dyn Write + Send>>,
    deterministic: bool,
    digest: TraceDigest,
    events: u64,
}

impl std::fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceSink")
            .field("deterministic", &self.deterministic)
            .field("events", &self.events)
            .field("digest", &self.digest.hex())
            .finish()
    }
}

impl TraceSink {
    /// Buffered file sink. `deterministic` records whether the feeding
    /// clock is virtual — emitters consult it to substitute simulated
    /// durations for measured ones.
    pub fn to_file(path: &Path, deterministic: bool) -> Result<TraceSink> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        Ok(TraceSink {
            out: Some(Box::new(std::io::BufWriter::new(f))),
            deterministic,
            digest: TraceDigest::new(),
            events: 0,
        })
    }

    /// Digest-only sink (tests): events are hashed but written nowhere.
    pub fn in_memory(deterministic: bool) -> TraceSink {
        TraceSink { out: None, deterministic, digest: TraceDigest::new(), events: 0 }
    }

    /// Whether emitters should keep measured wall durations out of the
    /// trace (virtual pace) to preserve byte-identical replays.
    pub fn deterministic(&self) -> bool {
        self.deterministic
    }

    /// Complete span (`ph:"X"`): starts `ts_ms`, lasts `dur_ms`.
    pub fn duration(
        &mut self,
        name: &str,
        ts_ms: f64,
        dur_ms: f64,
        tid: u64,
        args: Vec<(&str, Json)>,
    ) {
        self.emit(name, "X", ts_ms, Some(dur_ms), tid, args);
    }

    /// Instant event (`ph:"i"`) at `ts_ms`.
    pub fn instant(&mut self, name: &str, ts_ms: f64, tid: u64, args: Vec<(&str, Json)>) {
        self.emit(name, "i", ts_ms, None, tid, args);
    }

    fn emit(
        &mut self,
        name: &str,
        ph: &str,
        ts_ms: f64,
        dur_ms: Option<f64>,
        tid: u64,
        args: Vec<(&str, Json)>,
    ) {
        let mut fields = vec![
            ("name".to_string(), s(name)),
            ("ph".to_string(), s(ph)),
            ("ts".to_string(), num(ts_ms * 1000.0)),
        ];
        if let Some(d) = dur_ms {
            fields.push(("dur".to_string(), num(d * 1000.0)));
        }
        fields.push(("pid".to_string(), num(1.0)));
        fields.push(("tid".to_string(), num(tid as f64)));
        if !args.is_empty() {
            let a = args.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
            fields.push(("args".to_string(), Json::Obj(a)));
        }
        let line = Json::Obj(fields).to_string();
        self.digest.update(line.as_bytes());
        self.digest.update(b"\n");
        self.events += 1;
        if let Some(out) = &mut self.out {
            let _ = writeln!(out, "{line}");
        }
    }

    pub fn events(&self) -> u64 {
        self.events
    }

    pub fn digest(&self) -> String {
        self.digest.hex()
    }

    /// Flush the underlying writer (call before reading the file).
    pub fn finish(&mut self) -> Result<()> {
        if let Some(out) = &mut self.out {
            out.flush().context("flushing trace sink")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_reference_vectors() {
        // Standard FNV-1a 64 vectors.
        let mut d = TraceDigest::new();
        assert_eq!(d.hex(), "cbf29ce484222325"); // empty
        d.update(b"a");
        assert_eq!(d.hex(), "af63dc4c8601ec8c");
        let mut d2 = TraceDigest::new();
        d2.update(b"foobar");
        assert_eq!(d2.hex(), "85944171f73967e8");
    }

    #[test]
    fn identical_event_streams_share_a_digest() {
        let run = || {
            let mut t = TraceSink::in_memory(true);
            t.instant("admit", 1.0, 0, vec![("id", num(1.0))]);
            t.duration("queued", 1.0, 2.5, 0, vec![("id", num(1.0)), ("n", num(4.0))]);
            t.duration("shard-forward", 3.5, 1.0, 1, vec![("batch", num(0.0))]);
            (t.digest(), t.events())
        };
        let (d1, e1) = run();
        let (d2, e2) = run();
        assert_eq!(d1, d2);
        assert_eq!(e1, 2 + 1);
        assert_eq!(e2, 3);
        // Any perturbation moves the digest.
        let mut t = TraceSink::in_memory(true);
        t.instant("admit", 1.0, 0, vec![("id", num(2.0))]);
        t.duration("queued", 1.0, 2.5, 0, vec![("id", num(1.0)), ("n", num(4.0))]);
        t.duration("shard-forward", 3.5, 1.0, 1, vec![("batch", num(0.0))]);
        assert_ne!(t.digest(), d1);
    }

    #[test]
    fn file_sink_writes_parseable_jsonl_matching_digest() {
        let path = std::env::temp_dir()
            .join(format!("tj-trace-{}.jsonl", std::process::id()));
        let mut t = TraceSink::to_file(&path, true).unwrap();
        t.instant("admit", 0.0, 0, vec![]);
        t.duration("queued", 0.0, 1.5, 0, vec![("id", num(7.0))]);
        let digest = t.digest();
        t.finish().unwrap();
        drop(t);

        let text = std::fs::read_to_string(&path).unwrap();
        let mut redigest = TraceDigest::new();
        let mut n = 0;
        for line in text.lines() {
            let j = Json::parse(line).unwrap();
            assert!(j.get("name").is_some() && j.get("ph").is_some());
            assert!(j.get("ts").unwrap().as_f64().unwrap() >= 0.0);
            redigest.update(line.as_bytes());
            redigest.update(b"\n");
            n += 1;
        }
        assert_eq!(n, 2);
        assert_eq!(redigest.hex(), digest, "file bytes must reproduce the sink digest");
        // ts is microseconds: 1.5 ms span -> dur 1500.
        let span = Json::parse(text.lines().nth(1).unwrap()).unwrap();
        assert_eq!(span.get("dur").unwrap().as_i64().unwrap(), 1500);
        let _ = std::fs::remove_file(&path);
    }
}
