//! Global-free metrics registry: named counters, gauges, fixed-bucket
//! histograms, raw-sample series and windowed time-series rings,
//! handed out as cheap atomic handles.
//!
//! There are deliberately no statics: a [`MetricsRegistry`] is owned by
//! whoever runs the loop being measured (a `ServeFleet`, a
//! `ServeSession`, a `Trainer`) and handles are threaded to the code
//! that increments them. Registration is idempotent by name, so a
//! summary view ([`crate::serve::LatencySummary::from_registry`]) can
//! re-resolve the same handles instead of keeping a parallel
//! accumulator. All handles are `Clone + Send + Sync` (an `Arc` around
//! atomics) and safe to bump from engine worker threads.
//!
//! Snapshots come in two stable shapes: [`MetricsRegistry::snapshot_json`]
//! (one JSON object with `counters` / `gauges` / `hists` / `series` /
//! `rings` sections, names sorted) and [`MetricsRegistry::text_exposition`]
//! (one `name value` line per scalar, Prometheus-flavoured histogram
//! lines), served over TCP by [`crate::obs::spawn_metrics_endpoint`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::{num, Json};
use crate::util::stats::{mean, percentile};

/// Monotonic integer counter (events, calls, items).
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Monotonic float accumulator (milliseconds of busy/wait time).
/// Adds via a compare-exchange loop on the f64 bit pattern, so it is
/// exact (identical to sequential `+=`) whenever writers don't race.
#[derive(Debug, Clone)]
pub struct FCounter(Arc<AtomicU64>);

impl Default for FCounter {
    fn default() -> FCounter {
        FCounter(Arc::new(AtomicU64::new(0.0f64.to_bits())))
    }
}

impl FCounter {
    pub fn add(&self, v: f64) {
        let _ = self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
            Some((f64::from_bits(bits) + v).to_bits())
        });
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Last-value gauge with min/max update modes. Starts *unset* (NaN,
/// serialized as `null`), so "no sample yet" is distinguishable from 0.
#[derive(Debug, Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    fn unset() -> Gauge {
        Gauge(Arc::new(AtomicU64::new(f64::NAN.to_bits())))
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Keep the minimum of the current value and `v` (NaN = unset).
    pub fn min_of(&self, v: f64) {
        let _ = self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
            let cur = f64::from_bits(bits);
            Some(if cur.is_nan() || v < cur { v } else { cur }.to_bits())
        });
    }

    /// Keep the maximum of the current value and `v` (NaN = unset).
    pub fn max_of(&self, v: f64) {
        let _ = self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
            let cur = f64::from_bits(bits);
            Some(if cur.is_nan() || v > cur { v } else { cur }.to_bits())
        });
    }

    /// Raw value; NaN while unset.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    /// `None` while unset.
    pub fn get_opt(&self) -> Option<f64> {
        let v = self.get();
        (!v.is_nan()).then_some(v)
    }
}

/// Fixed-bucket histogram: `bounds` are inclusive upper bucket edges,
/// plus one implicit overflow bucket (`+Inf`).
#[derive(Debug, Clone)]
pub struct Histo {
    bounds: Arc<Vec<f64>>,
    counts: Arc<Vec<AtomicU64>>,
    sum: FCounter,
}

impl Histo {
    fn new(bounds: &[f64]) -> Histo {
        assert!(!bounds.is_empty(), "histogram needs at least one bucket bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must strictly increase"
        );
        Histo {
            bounds: Arc::new(bounds.to_vec()),
            counts: Arc::new((0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect()),
            sum: FCounter::default(),
        }
    }

    pub fn observe(&self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.sum.add(v);
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn sum(&self) -> f64 {
        self.sum.get()
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket counts, overflow bucket last.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("le".to_string(), Json::Arr(self.bounds.iter().map(|&b| num(b)).collect())),
            (
                "counts".to_string(),
                Json::Arr(self.bucket_counts().iter().map(|&c| num(c as f64)).collect()),
            ),
            ("count".to_string(), num(self.count() as f64)),
            ("sum".to_string(), num(self.sum())),
        ])
    }
}

/// Shared ring storage: the newest `cap` samples in push order, plus a
/// monotonic total of everything ever pushed. Backs both [`Series`]
/// (percentile store) and [`TsRing`] (windowed aggregates).
#[derive(Debug, Clone)]
struct RingBuf {
    buf: Vec<f64>,
    cap: usize,
    /// Overwrite cursor, meaningful once `buf.len() == cap`.
    next: usize,
    total: u64,
}

impl RingBuf {
    fn new(cap: usize) -> RingBuf {
        assert!(cap >= 1, "ring capacity must be >= 1");
        RingBuf { buf: Vec::new(), cap, next: 0, total: 0 }
    }

    fn push(&mut self, v: f64) {
        self.total += 1;
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Retained samples, oldest first.
    fn window(&self) -> Vec<f64> {
        if self.buf.len() < self.cap {
            self.buf.clone()
        } else {
            let mut out = Vec::with_capacity(self.cap);
            out.extend_from_slice(&self.buf[self.next..]);
            out.extend_from_slice(&self.buf[..self.next]);
            out
        }
    }

    fn last(&self) -> Option<f64> {
        if self.buf.is_empty() {
            None
        } else if self.buf.len() < self.cap {
            self.buf.last().copied()
        } else {
            Some(self.buf[(self.next + self.cap - 1) % self.cap])
        }
    }
}

/// Default retained-sample bound for [`Series`] — large enough that a
/// whole load-test run keeps exact percentiles, small enough that a
/// per-step push path cannot grow without limit.
pub const SERIES_DEFAULT_CAP: usize = 65_536;

/// Raw-sample store for exact percentiles (latency distributions).
/// Bounded: ring semantics keep only the newest `capacity` samples
/// (percentiles are computed over that window) while `count()` stays
/// the monotonic total ever recorded. Long-running loops that only need
/// coarse distributions should still prefer [`Histo`]; per-step window
/// aggregates belong in [`TsRing`].
#[derive(Debug, Clone)]
pub struct Series(Arc<Mutex<RingBuf>>);

impl Default for Series {
    fn default() -> Series {
        Series::with_capacity(SERIES_DEFAULT_CAP)
    }
}

impl Series {
    /// A series retaining at most `cap >= 1` samples.
    pub fn with_capacity(cap: usize) -> Series {
        Series(Arc::new(Mutex::new(RingBuf::new(cap))))
    }

    pub fn record(&self, v: f64) {
        self.0.lock().unwrap().push(v);
    }

    /// Retained samples (<= capacity).
    pub fn len(&self) -> usize {
        self.0.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total samples ever recorded (monotonic; survives ring overwrite).
    pub fn count(&self) -> u64 {
        self.0.lock().unwrap().total
    }

    pub fn capacity(&self) -> usize {
        self.0.lock().unwrap().cap
    }

    /// Retained samples, oldest first.
    pub fn values(&self) -> Vec<f64> {
        self.0.lock().unwrap().window()
    }

    fn to_json(&self) -> Json {
        let total = self.count();
        let xs = self.values();
        let max = xs.iter().fold(0.0f64, |a, &b| a.max(b));
        Json::Obj(vec![
            ("count".to_string(), num(total as f64)),
            ("mean".to_string(), num(mean(&xs))),
            ("p50".to_string(), num(percentile(&xs, 50.0))),
            ("p95".to_string(), num(percentile(&xs, 95.0))),
            ("p99".to_string(), num(percentile(&xs, 99.0))),
            ("max".to_string(), num(max)),
        ])
    }
}

/// Windowed aggregates of a [`TsRing`]. `count` is the monotonic total
/// pushed; `min`/`mean`/`max` cover the non-NaN samples of the retained
/// window and `last` is the newest sample. NaN means "no finite sample
/// yet" and serializes as `null` (same convention as an unset [`Gauge`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RingAgg {
    pub count: u64,
    pub min: f64,
    pub mean: f64,
    pub max: f64,
    pub last: f64,
}

/// Bounded windowed time series: a fixed-capacity ring of the newest
/// samples with O(window) memory and min/mean/max/last aggregates —
/// the per-step recording primitive (trainer step times, fleet
/// busy-ratios, queue-depth samples) that replaces unbounded [`Series`]
/// pushes on hot paths. NaN samples are retained (they advance the
/// window) but excluded from the min/mean/max aggregates.
#[derive(Debug, Clone)]
pub struct TsRing(Arc<Mutex<RingBuf>>);

impl TsRing {
    /// A ring retaining at most `cap >= 1` samples.
    pub fn with_capacity(cap: usize) -> TsRing {
        TsRing(Arc::new(Mutex::new(RingBuf::new(cap))))
    }

    pub fn push(&self, v: f64) {
        self.0.lock().unwrap().push(v);
    }

    /// Total samples ever pushed (monotonic).
    pub fn count(&self) -> u64 {
        self.0.lock().unwrap().total
    }

    /// Retained samples (<= capacity).
    pub fn len(&self) -> usize {
        self.0.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.0.lock().unwrap().cap
    }

    /// Retained samples, oldest first.
    pub fn window(&self) -> Vec<f64> {
        self.0.lock().unwrap().window()
    }

    /// Newest sample, if any.
    pub fn last(&self) -> Option<f64> {
        self.0.lock().unwrap().last()
    }

    /// Window aggregates; empty / all-NaN windows yield NaN fields.
    pub fn agg(&self) -> RingAgg {
        let inner = self.0.lock().unwrap();
        let mut min = f64::NAN;
        let mut max = f64::NAN;
        let (mut sum, mut n) = (0.0f64, 0u64);
        for &v in &inner.buf {
            if v.is_nan() {
                continue;
            }
            if min.is_nan() || v < min {
                min = v;
            }
            if max.is_nan() || v > max {
                max = v;
            }
            sum += v;
            n += 1;
        }
        RingAgg {
            count: inner.total,
            min,
            mean: if n > 0 { sum / n as f64 } else { f64::NAN },
            max,
            last: inner.last().unwrap_or(f64::NAN),
        }
    }

    fn to_json(&self) -> Json {
        let a = self.agg();
        Json::Obj(vec![
            ("count".to_string(), num(a.count as f64)),
            ("min".to_string(), num(a.min)),
            ("mean".to_string(), num(a.mean)),
            ("max".to_string(), num(a.max)),
            ("last".to_string(), num(a.last)),
        ])
    }
}

#[derive(Debug, Clone)]
enum Metric {
    Counter(Counter),
    FCounter(FCounter),
    Gauge(Gauge),
    Histo(Histo),
    Series(Series),
    Ring(TsRing),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::FCounter(_) => "fcounter",
            Metric::Gauge(_) => "gauge",
            Metric::Histo(_) => "histogram",
            Metric::Series(_) => "series",
            Metric::Ring(_) => "ring",
        }
    }
}

/// Named metric store. Cloning shares the underlying metrics (it is an
/// `Arc`), which is how the TCP exposition thread observes a live
/// registry owned by a serving loop.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    inner: Arc<Mutex<Vec<(String, Metric)>>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    fn get_or_insert(&self, name: &str, make: impl FnOnce() -> Metric) -> Metric {
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, m)) = inner.iter().find(|(n, _)| n == name) {
            return m.clone();
        }
        let m = make();
        inner.push((name.to_string(), m.clone()));
        m
    }

    /// Register (or re-resolve) a counter named `name`.
    pub fn counter(&self, name: &str) -> Counter {
        match self.get_or_insert(name, || Metric::Counter(Counter::default())) {
            Metric::Counter(c) => c,
            m => panic!("metric {name:?} is a {}, not a counter", m.kind()),
        }
    }

    /// Register (or re-resolve) a float counter named `name`.
    pub fn fcounter(&self, name: &str) -> FCounter {
        match self.get_or_insert(name, || Metric::FCounter(FCounter::default())) {
            Metric::FCounter(c) => c,
            m => panic!("metric {name:?} is a {}, not an fcounter", m.kind()),
        }
    }

    /// Register (or re-resolve) a gauge named `name` (starts unset).
    pub fn gauge(&self, name: &str) -> Gauge {
        match self.get_or_insert(name, || Metric::Gauge(Gauge::unset())) {
            Metric::Gauge(g) => g,
            m => panic!("metric {name:?} is a {}, not a gauge", m.kind()),
        }
    }

    /// Register (or re-resolve) a fixed-bucket histogram named `name`.
    /// `bounds` are ignored when the name already exists.
    pub fn histogram(&self, name: &str, bounds: &[f64]) -> Histo {
        match self.get_or_insert(name, || Metric::Histo(Histo::new(bounds))) {
            Metric::Histo(h) => h,
            m => panic!("metric {name:?} is a {}, not a histogram", m.kind()),
        }
    }

    /// Register (or re-resolve) a raw-sample series named `name` with
    /// the default capacity ([`SERIES_DEFAULT_CAP`]).
    pub fn series(&self, name: &str) -> Series {
        match self.get_or_insert(name, || Metric::Series(Series::default())) {
            Metric::Series(s) => s,
            m => panic!("metric {name:?} is a {}, not a series", m.kind()),
        }
    }

    /// Register (or re-resolve) a raw-sample series named `name`.
    /// `cap` is ignored when the name already exists.
    pub fn series_with_capacity(&self, name: &str, cap: usize) -> Series {
        match self.get_or_insert(name, || Metric::Series(Series::with_capacity(cap))) {
            Metric::Series(s) => s,
            m => panic!("metric {name:?} is a {}, not a series", m.kind()),
        }
    }

    /// Register (or re-resolve) a windowed time-series ring named
    /// `name`. `cap` is ignored when the name already exists.
    pub fn ring(&self, name: &str, cap: usize) -> TsRing {
        match self.get_or_insert(name, || Metric::Ring(TsRing::with_capacity(cap))) {
            Metric::Ring(r) => r,
            m => panic!("metric {name:?} is a {}, not a ring", m.kind()),
        }
    }

    /// Sorted `(name, metric)` snapshot of the registration table.
    fn sorted(&self) -> Vec<(String, Metric)> {
        let mut v = self.inner.lock().unwrap().clone();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// One JSON object with stable sections: `counters` (integer and
    /// float counters), `gauges`, `hists`, `series`, `rings`. Names are
    /// sorted, unset gauges serialize as `null`.
    pub fn snapshot_json(&self) -> Json {
        let mut counters = Vec::new();
        let mut gauges = Vec::new();
        let mut hists = Vec::new();
        let mut series = Vec::new();
        let mut rings = Vec::new();
        for (name, m) in self.sorted() {
            match m {
                Metric::Counter(c) => counters.push((name, num(c.get() as f64))),
                Metric::FCounter(c) => counters.push((name, num(c.get()))),
                Metric::Gauge(g) => gauges.push((name, num(g.get()))),
                Metric::Histo(h) => hists.push((name, h.to_json())),
                Metric::Series(s) => series.push((name, s.to_json())),
                Metric::Ring(r) => rings.push((name, r.to_json())),
            }
        }
        Json::Obj(vec![
            ("counters".to_string(), Json::Obj(counters)),
            ("gauges".to_string(), Json::Obj(gauges)),
            ("hists".to_string(), Json::Obj(hists)),
            ("series".to_string(), Json::Obj(series)),
            ("rings".to_string(), Json::Obj(rings)),
        ])
    }

    /// Plain-text exposition: `name value` per scalar, histogram bucket
    /// lines as `name_bucket{le="B"} count` plus `_count`/`_sum`, series
    /// as `_count`/`_p50`/`_p95`/`_p99`/`_max`, rings as
    /// `_count`/`_min`/`_mean`/`_max`/`_last`.
    pub fn text_exposition(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (name, m) in self.sorted() {
            match m {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "{name} {}", c.get());
                }
                Metric::FCounter(c) => {
                    let _ = writeln!(out, "{name} {}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "{name} {}", g.get());
                }
                Metric::Histo(h) => {
                    let counts = h.bucket_counts();
                    let mut cum = 0u64;
                    for (i, &b) in h.bounds().iter().enumerate() {
                        cum += counts[i];
                        let _ = writeln!(out, "{name}_bucket{{le=\"{b}\"}} {cum}");
                    }
                    cum += counts[counts.len() - 1];
                    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
                    let _ = writeln!(out, "{name}_count {}", h.count());
                    let _ = writeln!(out, "{name}_sum {}", h.sum());
                }
                Metric::Series(s) => {
                    let xs = s.values();
                    let _ = writeln!(out, "{name}_count {}", s.count());
                    let _ = writeln!(out, "{name}_p50 {}", percentile(&xs, 50.0));
                    let _ = writeln!(out, "{name}_p95 {}", percentile(&xs, 95.0));
                    let _ = writeln!(out, "{name}_p99 {}", percentile(&xs, 99.0));
                    let _ =
                        writeln!(out, "{name}_max {}", xs.iter().fold(0.0f64, |a, &b| a.max(b)));
                }
                Metric::Ring(r) => {
                    let a = r.agg();
                    let _ = writeln!(out, "{name}_count {}", a.count);
                    let _ = writeln!(out, "{name}_min {}", a.min);
                    let _ = writeln!(out, "{name}_mean {}", a.mean);
                    let _ = writeln!(out, "{name}_max {}", a.max);
                    let _ = writeln!(out, "{name}_last {}", a.last);
                }
            }
        }
        out
    }
}

/// The four quantized linear layer types of a ViT block, in store
/// order (the [`crate::serve::LinearExec`] `store` index).
pub const LAYER_NAMES: [&str; 4] = ["qkv", "proj", "fc1", "fc2"];

/// Per-layer fused-GEMM instrumentation handles: call counts and
/// cumulative forward milliseconds, one pair per quantized layer type,
/// plus a gauge recording which SIMD dispatch level the kernels ran at
/// (`SimdLevel::id()`: 0 = scalar, 1 = ssse3, 2 = avx2; NaN until the
/// first instrumented GEMM).
#[derive(Debug, Clone)]
pub struct KernelMetrics {
    pub calls: [Counter; 4],
    pub ms: [FCounter; 4],
    pub dispatch: Gauge,
}

impl KernelMetrics {
    /// Register under `kernel.{qkv,proj,fc1,fc2}.{calls,ms}` plus
    /// `kernel.dispatch_level`.
    pub fn in_registry(reg: &MetricsRegistry) -> KernelMetrics {
        KernelMetrics {
            calls: std::array::from_fn(|i| {
                reg.counter(&format!("kernel.{}.calls", LAYER_NAMES[i]))
            }),
            ms: std::array::from_fn(|i| reg.fcounter(&format!("kernel.{}.ms", LAYER_NAMES[i]))),
            dispatch: reg.gauge("kernel.dispatch_level"),
        }
    }

    /// Handles not attached to any shared registry (no-op-ish default).
    pub fn detached() -> KernelMetrics {
        KernelMetrics::in_registry(&MetricsRegistry::new())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_and_idempotent_registration() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("a.calls");
        c.inc();
        c.add(4);
        // Same name resolves to the same underlying cell.
        assert_eq!(reg.counter("a.calls").get(), 5);

        let f = reg.fcounter("a.ms");
        f.add(1.5);
        f.add(2.25);
        assert_eq!(reg.fcounter("a.ms").get(), 3.75);

        let g = reg.gauge("a.depth");
        assert!(g.get_opt().is_none(), "gauges start unset");
        g.set(7.0);
        assert_eq!(reg.gauge("a.depth").get_opt(), Some(7.0));
        g.min_of(3.0);
        g.min_of(5.0);
        assert_eq!(g.get(), 3.0);
        g.max_of(9.0);
        g.max_of(4.0);
        assert_eq!(g.get(), 9.0);
    }

    #[test]
    #[should_panic(expected = "not a gauge")]
    fn kind_mismatch_panics() {
        let reg = MetricsRegistry::new();
        reg.counter("x");
        reg.gauge("x");
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("batch", &[1.0, 2.0, 4.0]);
        for v in [0.5, 1.0, 1.5, 4.0, 100.0] {
            h.observe(v);
        }
        // le=1: {0.5, 1.0}; le=2: {1.5}; le=4: {4.0}; +Inf: {100.0}.
        assert_eq!(h.bucket_counts(), vec![2, 1, 1, 1]);
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 107.0).abs() < 1e-12);
    }

    #[test]
    fn series_percentiles_and_snapshot_schema() {
        let reg = MetricsRegistry::new();
        reg.counter("n.calls").add(3);
        reg.gauge("n.depth").set(2.0);
        reg.histogram("n.hist", &[1.0]).observe(0.5);
        let s = reg.series("n.lat");
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.record(v);
        }
        let j = reg.snapshot_json();
        for sect in ["counters", "gauges", "hists", "series"] {
            assert!(j.get(sect).is_some(), "snapshot missing section {sect}");
        }
        assert_eq!(j.get("counters").unwrap().get("n.calls").unwrap().as_i64().unwrap(), 3);
        let lat = j.get("series").unwrap().get("n.lat").unwrap();
        assert_eq!(lat.get("count").unwrap().as_i64().unwrap(), 4);
        assert!((lat.get("p50").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-12);
        // Snapshot is parseable back (it is how obs-validate reads it).
        let rt = Json::parse(&j.to_string()).unwrap();
        assert_eq!(rt.to_string(), j.to_string());
    }

    #[test]
    fn text_exposition_lists_scalars_and_buckets() {
        let reg = MetricsRegistry::new();
        reg.counter("serve.images").add(12);
        let h = reg.histogram("fleet.batch_images", &[1.0, 8.0]);
        h.observe(1.0);
        h.observe(6.0);
        let text = reg.text_exposition();
        assert!(text.contains("serve.images 12"), "{text}");
        assert!(text.contains("fleet.batch_images_bucket{le=\"1\"} 1"), "{text}");
        assert!(text.contains("fleet.batch_images_bucket{le=\"+Inf\"} 2"), "{text}");
        assert!(text.contains("fleet.batch_images_count 2"), "{text}");
    }

    #[test]
    fn series_is_bounded_but_counts_everything() {
        let s = Series::with_capacity(4);
        for v in 0..10 {
            s.record(v as f64);
        }
        assert_eq!(s.count(), 10, "total is monotonic");
        assert_eq!(s.len(), 4, "retention is capped");
        assert_eq!(s.capacity(), 4);
        // Window keeps the newest samples, oldest first.
        assert_eq!(s.values(), vec![6.0, 7.0, 8.0, 9.0]);
        // Snapshot `count` reports the total, not the retained window.
        let reg = MetricsRegistry::new();
        let s2 = reg.series_with_capacity("b.lat", 2);
        for v in [1.0, 2.0, 3.0] {
            s2.record(v);
        }
        let j = reg.snapshot_json();
        let lat = j.get("series").unwrap().get("b.lat").unwrap();
        assert_eq!(lat.get("count").unwrap().as_i64().unwrap(), 3);
        assert!((lat.get("p50").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ring_window_and_aggregates() {
        let reg = MetricsRegistry::new();
        let r = reg.ring("t.step_ms", 3);
        assert_eq!(reg.ring("t.step_ms", 99).capacity(), 3, "cap fixed at first registration");

        // Empty window: count 0, NaN aggregates -> null in JSON.
        let a = r.agg();
        assert_eq!(a.count, 0);
        assert!(a.min.is_nan() && a.mean.is_nan() && a.max.is_nan() && a.last.is_nan());
        let j = reg.snapshot_json();
        let rj = j.get("rings").unwrap().get("t.step_ms").unwrap();
        assert!(matches!(rj.get("mean"), Some(Json::Null)), "NaN mean serializes as null");

        // Single sample: all aggregates collapse to it.
        r.push(2.0);
        let a = r.agg();
        assert_eq!((a.count, a.min, a.mean, a.max, a.last), (1, 2.0, 2.0, 2.0, 2.0));

        // Overflow: window slides, count keeps the total.
        for v in [4.0, 6.0, 8.0] {
            r.push(v);
        }
        assert_eq!(r.window(), vec![4.0, 6.0, 8.0]);
        assert_eq!(r.len(), 3);
        let a = r.agg();
        assert_eq!((a.count, a.min, a.mean, a.max, a.last), (4, 4.0, 6.0, 8.0, 8.0));

        let text = reg.text_exposition();
        assert!(text.contains("t.step_ms_count 4"), "{text}");
        assert!(text.contains("t.step_ms_mean 6"), "{text}");
        assert!(text.contains("t.step_ms_last 8"), "{text}");
    }

    #[test]
    fn capacity_one_ring_tracks_last_sample_only() {
        let r = TsRing::with_capacity(1);
        for v in [5.0, 1.0, 3.0] {
            r.push(v);
        }
        assert_eq!(r.len(), 1);
        assert_eq!(r.window(), vec![3.0]);
        let a = r.agg();
        assert_eq!((a.count, a.min, a.mean, a.max, a.last), (3, 3.0, 3.0, 3.0, 3.0));
    }

    #[test]
    fn nan_samples_advance_window_but_skip_aggregates() {
        let r = TsRing::with_capacity(4);
        r.push(1.0);
        r.push(f64::NAN);
        r.push(3.0);
        let a = r.agg();
        assert_eq!(a.count, 3);
        assert_eq!((a.min, a.mean, a.max, a.last), (1.0, 2.0, 3.0, 3.0));
        // All-NaN window: aggregates are unset again.
        let r2 = TsRing::with_capacity(2);
        r2.push(f64::NAN);
        r2.push(f64::NAN);
        let a2 = r2.agg();
        assert_eq!(a2.count, 2);
        assert!(a2.min.is_nan() && a2.mean.is_nan() && a2.max.is_nan() && a2.last.is_nan());
    }

    #[test]
    fn percentiles_monotone_under_seeded_random_fills() {
        let mut rng = crate::util::rng::Rng::new(0x0b5e_7ab1e);
        let s = Series::with_capacity(256);
        for _ in 0..1000 {
            s.record(rng.uniform() * 100.0);
        }
        assert_eq!(s.count(), 1000);
        assert_eq!(s.len(), 256);
        let xs = s.values();
        let (p50, p95, p99) =
            (percentile(&xs, 50.0), percentile(&xs, 95.0), percentile(&xs, 99.0));
        let max = xs.iter().fold(f64::MIN, |a, &b| a.max(b));
        assert!(p50 <= p95 && p95 <= p99 && p99 <= max, "{p50} {p95} {p99} {max}");
        // Window min/max bound every retained sample.
        let r = TsRing::with_capacity(256);
        for &v in &xs {
            r.push(v);
        }
        let a = r.agg();
        assert!(xs.iter().all(|&v| a.min <= v && v <= a.max));
    }

    #[test]
    fn handles_are_send_sync_across_threads() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("t.calls");
        let f = reg.fcounter("t.ms");
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let (c, f) = (c.clone(), f.clone());
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                        f.add(0.5);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
        assert!((f.get() - 2000.0).abs() < 1e-9);
    }
}
