//! Plain-TCP metrics exposition (`serve --metrics-addr HOST:PORT`).
//!
//! std::net only: a detached acceptor thread answers every connection
//! with an HTTP/1.0 `200 text/plain` whose body is
//! [`MetricsRegistry::text_exposition`] at the moment of the request.
//! The thread holds a clone of the registry (shared `Arc`), so it sees
//! live values without any coordination with the serving loop; it runs
//! until the process exits, which matches the CLI's lifetime.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener};

use anyhow::{Context, Result};

use super::metrics::MetricsRegistry;

/// Bind `addr` (e.g. `127.0.0.1:9200`, port 0 for ephemeral) and serve
/// `reg`'s text exposition to every connection on a background thread.
/// Returns the bound address (useful with port 0).
pub fn spawn_metrics_endpoint(addr: &str, reg: MetricsRegistry) -> Result<SocketAddr> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding metrics endpoint {addr}"))?;
    let bound = listener.local_addr().context("resolving metrics endpoint addr")?;
    std::thread::Builder::new()
        .name("tj-metrics".to_string())
        .spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { continue };
                // Drain whatever request line arrives (best effort —
                // we answer any request the same way).
                let mut buf = [0u8; 1024];
                let _ = stream.read(&mut buf);
                let body = reg.text_exposition();
                let resp = format!(
                    "HTTP/1.0 200 OK\r\nContent-Type: text/plain; charset=utf-8\r\n\
                     Content-Length: {}\r\n\r\n{}",
                    body.len(),
                    body
                );
                let _ = stream.write_all(resp.as_bytes());
            }
        })
        .context("spawning tj-metrics thread")?;
    Ok(bound)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpStream;

    #[test]
    fn endpoint_serves_live_registry_text() {
        let reg = MetricsRegistry::new();
        reg.counter("serve.images").add(42);
        let addr = spawn_metrics_endpoint("127.0.0.1:0", reg.clone()).unwrap();

        let fetch = || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
            let mut text = String::new();
            stream.read_to_string(&mut text).unwrap();
            text
        };
        let first = fetch();
        assert!(first.starts_with("HTTP/1.0 200 OK"), "{first}");
        assert!(first.contains("serve.images 42"), "{first}");

        // The endpoint observes the live registry, not a snapshot.
        reg.counter("serve.images").add(8);
        assert!(fetch().contains("serve.images 50"));
    }

    fn scrape(addr: SocketAddr) -> String {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut text = String::new();
        stream.read_to_string(&mut text).unwrap();
        text
    }

    /// `name value` lines of the body, keyed verbatim (histogram bucket
    /// keys keep their `{le="..."}` suffix).
    fn parse_exposition(resp: &str) -> std::collections::HashMap<String, f64> {
        let body = resp.split("\r\n\r\n").nth(1).expect("response has a body");
        body.lines()
            .filter(|l| !l.is_empty())
            .map(|l| {
                let (k, v) = l.rsplit_once(' ').expect("line is `name value`");
                (k.to_string(), v.parse::<f64>().expect("value parses as a number"))
            })
            .collect()
    }

    #[test]
    fn scrape_round_trips_every_metric_kind_over_a_real_socket() {
        let reg = MetricsRegistry::new();
        reg.counter("sched.admits").add(3);
        reg.fcounter("serve.busy_ms").add(2.5);
        reg.gauge("sched.queue_depth").set(7.0);
        let h = reg.histogram("fleet.batch_images", &[1.0, 2.0, 4.0]);
        for v in [1.0, 3.0, 5.0] {
            h.observe(v);
        }
        let s = reg.series_with_capacity("serve.latency_ms", 2);
        for v in [10.0, 20.0, 30.0] {
            s.record(v);
        }
        let r = reg.ring("fleet.engine0.busy_ratio", 2);
        for v in [1.0, 2.0, 3.0] {
            r.push(v);
        }

        let addr = spawn_metrics_endpoint("127.0.0.1:0", reg.clone()).unwrap();
        let resp = scrape(addr);
        assert!(resp.starts_with("HTTP/1.0 200 OK"), "{resp}");
        // The declared Content-Length frames the body exactly.
        let body = resp.split("\r\n\r\n").nth(1).unwrap();
        let clen: usize = resp
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert_eq!(clen, body.len());

        let m = parse_exposition(&resp);
        assert_eq!(m["sched.admits"], 3.0);
        assert_eq!(m["serve.busy_ms"], 2.5);
        assert_eq!(m["sched.queue_depth"], 7.0);
        // Histogram: cumulative buckets + count + sum.
        assert_eq!(m["fleet.batch_images_bucket{le=\"1\"}"], 1.0);
        assert_eq!(m["fleet.batch_images_bucket{le=\"2\"}"], 1.0);
        assert_eq!(m["fleet.batch_images_bucket{le=\"4\"}"], 2.0);
        assert_eq!(m["fleet.batch_images_bucket{le=\"+Inf\"}"], 3.0);
        assert_eq!(m["fleet.batch_images_count"], 3.0);
        assert_eq!(m["fleet.batch_images_sum"], 9.0);
        // Series: total count survives ring eviction (cap 2, 3 recorded);
        // percentiles run over the retained window [20, 30].
        assert_eq!(m["serve.latency_ms_count"], 3.0);
        assert_eq!(m["serve.latency_ms_max"], 30.0);
        assert!(m["serve.latency_ms_p50"] >= 20.0);
        // Ring: total count + window aggregates over [2, 3].
        assert_eq!(m["fleet.engine0.busy_ratio_count"], 3.0);
        assert_eq!(m["fleet.engine0.busy_ratio_min"], 2.0);
        assert_eq!(m["fleet.engine0.busy_ratio_mean"], 2.5);
        assert_eq!(m["fleet.engine0.busy_ratio_max"], 3.0);
        assert_eq!(m["fleet.engine0.busy_ratio_last"], 3.0);

        // A second scrape after a live update sees the new totals.
        reg.counter("sched.admits").add(1);
        assert_eq!(parse_exposition(&scrape(addr))["sched.admits"], 4.0);
    }
}
