//! Plain-TCP metrics exposition (`serve --metrics-addr HOST:PORT`).
//!
//! std::net only: a detached acceptor thread answers every connection
//! with an HTTP/1.0 `200 text/plain` whose body is
//! [`MetricsRegistry::text_exposition`] at the moment of the request.
//! The thread holds a clone of the registry (shared `Arc`), so it sees
//! live values without any coordination with the serving loop; it runs
//! until the process exits, which matches the CLI's lifetime.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener};

use anyhow::{Context, Result};

use super::metrics::MetricsRegistry;

/// Bind `addr` (e.g. `127.0.0.1:9200`, port 0 for ephemeral) and serve
/// `reg`'s text exposition to every connection on a background thread.
/// Returns the bound address (useful with port 0).
pub fn spawn_metrics_endpoint(addr: &str, reg: MetricsRegistry) -> Result<SocketAddr> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding metrics endpoint {addr}"))?;
    let bound = listener.local_addr().context("resolving metrics endpoint addr")?;
    std::thread::Builder::new()
        .name("tj-metrics".to_string())
        .spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { continue };
                // Drain whatever request line arrives (best effort —
                // we answer any request the same way).
                let mut buf = [0u8; 1024];
                let _ = stream.read(&mut buf);
                let body = reg.text_exposition();
                let resp = format!(
                    "HTTP/1.0 200 OK\r\nContent-Type: text/plain; charset=utf-8\r\n\
                     Content-Length: {}\r\n\r\n{}",
                    body.len(),
                    body
                );
                let _ = stream.write_all(resp.as_bytes());
            }
        })
        .context("spawning tj-metrics thread")?;
    Ok(bound)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpStream;

    #[test]
    fn endpoint_serves_live_registry_text() {
        let reg = MetricsRegistry::new();
        reg.counter("serve.images").add(42);
        let addr = spawn_metrics_endpoint("127.0.0.1:0", reg.clone()).unwrap();

        let fetch = || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
            let mut text = String::new();
            stream.read_to_string(&mut text).unwrap();
            text
        };
        let first = fetch();
        assert!(first.starts_with("HTTP/1.0 200 OK"), "{first}");
        assert!(first.contains("serve.images 42"), "{first}");

        // The endpoint observes the live registry, not a snapshot.
        reg.counter("serve.images").add(8);
        assert!(fetch().contains("serve.images 50"));
    }
}
