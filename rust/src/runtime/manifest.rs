//! Artifact manifest: the interchange contract with `compile/aot.py`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            _ => bail!("unsupported dtype {s:?}"),
        }
    }
}

/// One named slice of the flat parameter vector.
#[derive(Debug, Clone)]
pub struct ParamSegment {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub quantized: bool,
}

impl ParamSegment {
    /// Trailing (contiguous) dimension — the 1x32 group axis of a
    /// quantized (C, D) weight.
    pub fn cols(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }

    pub fn range(&self) -> std::ops::Range<usize> {
        self.offset..self.offset + self.size
    }
}

/// Model geometry (mirrors vit.ModelCfg).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub img: usize,
    pub patch: usize,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub classes: usize,
    pub seq: usize,
}

/// Variant configuration echo (mirrors model.VariantCfg).
#[derive(Debug, Clone)]
pub struct VariantInfo {
    pub name: String,
    pub kind: String,
    pub fwd_fmt: String,
    pub bwd_fmt: String,
    pub scaling: String,
    pub bwd_rounding: String,
    pub flow: String,
    pub qema: bool,
    pub impl_: String,
    /// Per-quantizer toggles Q1..Q6 (Table 1 / Table 6 variants).
    pub enabled: Vec<bool>,
}

#[derive(Debug, Clone)]
pub struct StepIo {
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelInfo,
    pub variant: VariantInfo,
    pub batch: usize,
    pub probe_block: usize,
    pub total_params: usize,
    pub qw_total: usize,
    pub segments: Vec<ParamSegment>,
    pub train_step: StepIo,
    pub eval_step: StepIo,
    pub probe: StepIo,
}

fn io_list(j: &Json) -> Result<Vec<IoSpec>> {
    j.as_arr()?
        .iter()
        .map(|e| {
            Ok(IoSpec {
                name: e.req("name")?.as_str()?.to_string(),
                dtype: Dtype::parse(e.req("dtype")?.as_str()?)?,
                shape: e.req("shape")?.as_usize_vec()?,
            })
        })
        .collect()
}

fn step_io(j: &Json) -> Result<StepIo> {
    Ok(StepIo { inputs: io_list(j.req("inputs")?)?, outputs: io_list(j.req("outputs")?)? })
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Manifest::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let m = j.req("model")?;
        let model = ModelInfo {
            name: m.req("name")?.as_str()?.to_string(),
            img: m.req("img")?.as_usize()?,
            patch: m.req("patch")?.as_usize()?,
            dim: m.req("dim")?.as_usize()?,
            depth: m.req("depth")?.as_usize()?,
            heads: m.req("heads")?.as_usize()?,
            classes: m.req("classes")?.as_usize()?,
            seq: m.req("seq")?.as_usize()?,
        };
        let v = j.req("variant")?;
        let variant = VariantInfo {
            name: v.req("name")?.as_str()?.to_string(),
            kind: v.req("kind")?.as_str()?.to_string(),
            fwd_fmt: v.req("fwd_fmt")?.as_str()?.to_string(),
            bwd_fmt: v.req("bwd_fmt")?.as_str()?.to_string(),
            scaling: v.req("scaling")?.as_str()?.to_string(),
            bwd_rounding: v.req("bwd_rounding")?.as_str()?.to_string(),
            flow: v.req("flow")?.as_str()?.to_string(),
            qema: v.req("qema")?.as_bool()?,
            impl_: v.req("impl")?.as_str()?.to_string(),
            enabled: v
                .req("enabled")?
                .as_arr()?
                .iter()
                .map(|b| b.as_bool())
                .collect::<Result<_>>()?,
        };
        let p = j.req("params")?;
        let segments: Vec<ParamSegment> = p
            .req("segments")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(ParamSegment {
                    name: s.req("name")?.as_str()?.to_string(),
                    shape: s.req("shape")?.as_usize_vec()?,
                    offset: s.req("offset")?.as_usize()?,
                    size: s.req("size")?.as_usize()?,
                    quantized: s.req("quantized")?.as_bool()?,
                })
            })
            .collect::<Result<_>>()?;
        let man = Manifest {
            model,
            variant,
            batch: j.req("batch")?.as_usize()?,
            probe_block: j.req("probe_block")?.as_usize()?,
            total_params: p.req("total")?.as_usize()?,
            qw_total: p.req("qw_total")?.as_usize()?,
            segments,
            train_step: step_io(j.req("train_step")?)?,
            eval_step: step_io(j.req("eval_step")?)?,
            probe: step_io(j.req("probe")?)?,
        };
        man.validate()?;
        Ok(man)
    }

    fn validate(&self) -> Result<()> {
        // Quantized weights must form the [0, qw_total) prefix.
        let mut off = 0usize;
        let mut qw = 0usize;
        for s in &self.segments {
            if s.offset != off {
                bail!("segment {} offset {} != running {}", s.name, s.offset, off);
            }
            if s.size != s.shape.iter().product::<usize>() {
                bail!("segment {} size mismatch", s.name);
            }
            if s.quantized {
                if s.offset != qw {
                    bail!("quantized segment {} not in prefix", s.name);
                }
                qw += s.size;
            }
            off += s.size;
        }
        if off != self.total_params || qw != self.qw_total {
            bail!(
                "manifest totals mismatch: params {off}/{} qw {qw}/{}",
                self.total_params,
                self.qw_total
            );
        }
        Ok(())
    }

    pub fn quantized_segments(&self) -> impl Iterator<Item = &ParamSegment> {
        self.segments.iter().filter(|s| s.quantized)
    }

    pub fn segment(&self, name: &str) -> Option<&ParamSegment> {
        self.segments.iter().find(|s| s.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest_json() -> String {
        r#"{
          "model": {"name":"m","img":8,"patch":4,"dim":8,"depth":1,"heads":2,
                    "classes":2,"seq":5,"mlp_ratio":4,"patch_dim":48},
          "variant": {"name":"tetrajet","kind":"mx","fwd_fmt":"e2m1",
                      "bwd_fmt":"e2m1","scaling":"tf","bwd_rounding":"stoch",
                      "flow":"double","qema":false,"enabled":[true,true,true,true,true,true],
                      "impl":"pallas"},
          "batch": 4,
          "probe_block": 0,
          "params": {"total": 20, "qw_total": 12, "segments": [
            {"name":"w1","shape":[3,4],"offset":0,"size":12,"quantized":true,"weight_decay":true},
            {"name":"b1","shape":[8],"offset":12,"size":8,"quantized":false,"weight_decay":false}
          ]},
          "train_step": {"inputs":[{"name":"params","dtype":"f32","shape":[20]}],
                         "outputs":[{"name":"loss","dtype":"f32","shape":[]}]},
          "eval_step": {"inputs":[],"outputs":[]},
          "probe": {"inputs":[],"outputs":[]}
        }"#
        .to_string()
    }

    #[test]
    fn parses_and_validates() {
        let j = Json::parse(&mini_manifest_json()).unwrap();
        let m = Manifest::from_json(&j).unwrap();
        assert_eq!(m.total_params, 20);
        assert_eq!(m.qw_total, 12);
        assert_eq!(m.quantized_segments().count(), 1);
        assert_eq!(m.segment("w1").unwrap().cols(), 4);
        assert_eq!(m.train_step.inputs[0].numel(), 20);
        assert_eq!(m.train_step.outputs[0].numel(), 1);
    }

    #[test]
    fn rejects_wrong_totals() {
        let bad = mini_manifest_json().replace("\"total\": 20", "\"total\": 21");
        let j = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn rejects_non_prefix_quantized() {
        let bad = mini_manifest_json()
            .replace("\"quantized\":true", "\"quantized\":false")
            .replace("\"quantized\":false,\"weight_decay\":false", "\"quantized\":true,\"weight_decay\":false")
            .replace("\"qw_total\": 12", "\"qw_total\": 8");
        let j = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }
}
