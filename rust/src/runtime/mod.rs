//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! `python -m compile.aot` (build time, never on the training path)
//! lowers each variant's train/eval/probe steps to HLO text plus a JSON
//! manifest describing the ordered inputs/outputs and the flat parameter
//! layout. This module loads those artifacts onto the PJRT CPU client
//! and exposes typed step functions over host buffers.

pub mod artifacts;
pub mod client;
pub mod exec;
pub mod manifest;

pub use artifacts::ModelArtifacts;
pub use client::cpu_client;
pub use exec::{Arg, StepFn};
pub use manifest::{IoSpec, Manifest, ParamSegment};
