//! Artifact discovery + loading: one `ModelArtifacts` per (model,
//! batch, variant) directory produced by `make artifacts`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use xla::PjRtClient;

use super::exec::{Arg, StepFn};
use super::manifest::Manifest;
use crate::util::json::Json;

/// Root layout helper: artifacts/<model>/b<batch>/<variant>/...
pub fn variant_dir(root: &Path, model: &str, batch: usize, variant: &str) -> PathBuf {
    root.join(model).join(format!("b{batch}")).join(variant)
}

/// All compiled entry points of one variant.
pub struct ModelArtifacts {
    pub manifest: Manifest,
    pub train_step: StepFn,
    pub eval_step: StepFn,
    pub probe: StepFn,
}

impl ModelArtifacts {
    pub fn load(
        client: &PjRtClient,
        root: &Path,
        model: &str,
        batch: usize,
        variant: &str,
    ) -> Result<ModelArtifacts> {
        let dir = variant_dir(root, model, batch, variant);
        if !dir.exists() {
            bail!(
                "artifact dir {} missing — run `make artifacts` (or \
                 `make artifacts-full` for ablation variants)",
                dir.display()
            );
        }
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        if manifest.batch != batch || manifest.variant.name != variant {
            bail!("manifest/dir mismatch in {}", dir.display());
        }
        let train_step = StepFn::load(
            client,
            &dir.join("train_step.hlo.txt"),
            &format!("{variant}/train_step"),
            manifest.train_step.inputs.clone(),
            manifest.train_step.outputs.clone(),
        )?;
        let eval_step = StepFn::load(
            client,
            &dir.join("eval_step.hlo.txt"),
            &format!("{variant}/eval_step"),
            manifest.eval_step.inputs.clone(),
            manifest.eval_step.outputs.clone(),
        )?;
        let probe = StepFn::load(
            client,
            &dir.join("probe.hlo.txt"),
            &format!("{variant}/probe"),
            manifest.probe.inputs.clone(),
            manifest.probe.outputs.clone(),
        )?;
        Ok(ModelArtifacts { manifest, train_step, eval_step, probe })
    }
}

/// Run the per-model init HLO: seed -> flat parameter vector.
pub fn run_init(client: &PjRtClient, root: &Path, model: &str, seed: i32) -> Result<Vec<f32>> {
    let dir = root.join(model);
    let mj = Json::parse(
        &std::fs::read_to_string(dir.join("init_manifest.json"))
            .with_context(|| format!("init manifest in {}", dir.display()))?,
    )?;
    let total = mj.req("outputs")?.as_arr()?[0]
        .req("shape")?
        .as_usize_vec()?
        .iter()
        .product::<usize>();
    let init = StepFn::load(
        client,
        &dir.join("init.hlo.txt"),
        &format!("{model}/init"),
        vec![super::manifest::IoSpec {
            name: "seed".into(),
            dtype: super::manifest::Dtype::I32,
            shape: vec![],
        }],
        vec![super::manifest::IoSpec {
            name: "params".into(),
            dtype: super::manifest::Dtype::F32,
            shape: vec![total],
        }],
    )?;
    let out = init.call(&[Arg::ScalarI32(seed)])?;
    Ok(out.into_iter().next().unwrap().data)
}

/// Default artifacts root (repo-relative, overridable via CLI/env).
pub fn default_root() -> PathBuf {
    if let Ok(p) = std::env::var("TETRAJET_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from("artifacts")
}
