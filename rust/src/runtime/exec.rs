//! HLO executable wrapper: manifest-checked marshalling host <-> device.

use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

use super::manifest::{Dtype, IoSpec};
use crate::util::tensor::Tensor;

/// A host-side argument for one HLO input.
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    ScalarF32(f32),
    ScalarI32(i32),
}

impl<'a> Arg<'a> {
    fn dtype(&self) -> Dtype {
        match self {
            Arg::F32(_) | Arg::ScalarF32(_) => Dtype::F32,
            Arg::I32(_) | Arg::ScalarI32(_) => Dtype::I32,
        }
    }

    fn numel(&self) -> usize {
        match self {
            Arg::F32(v) => v.len(),
            Arg::I32(v) => v.len(),
            Arg::ScalarF32(_) | Arg::ScalarI32(_) => 1,
        }
    }

    fn to_literal(&self, spec: &IoSpec) -> Result<Literal> {
        let dims: Vec<usize> = spec.shape.clone();
        let lit = match self {
            Arg::F32(v) => Literal::create_from_shape_and_untyped_data(
                ElementType::F32,
                &dims,
                bytes_of_f32(v),
            )?,
            Arg::ScalarF32(x) => Literal::create_from_shape_and_untyped_data(
                ElementType::F32,
                &dims,
                bytes_of_f32(&[*x]),
            )?,
            Arg::I32(v) => Literal::create_from_shape_and_untyped_data(
                ElementType::S32,
                &dims,
                bytes_of_i32(v),
            )?,
            Arg::ScalarI32(x) => Literal::create_from_shape_and_untyped_data(
                ElementType::S32,
                &dims,
                bytes_of_i32(&[*x]),
            )?,
        };
        Ok(lit)
    }
}

fn bytes_of_f32(v: &[f32]) -> &[u8] {
    // SAFETY: f32 has no invalid bit patterns; alignment of u8 is 1.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytes_of_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// One compiled HLO entry point with its manifest-declared signature.
pub struct StepFn {
    pub name: String,
    exe: PjRtLoadedExecutable,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl StepFn {
    pub fn load(
        client: &PjRtClient,
        path: &Path,
        name: &str,
        inputs: Vec<IoSpec>,
        outputs: Vec<IoSpec>,
    ) -> Result<StepFn> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(StepFn { name: name.to_string(), exe, inputs, outputs })
    }

    /// Execute with manifest-order arguments; returns host tensors in
    /// manifest output order. I32 outputs are widened to f32 (none of
    /// our step outputs are integral, checked at load).
    pub fn call(&self, args: &[Arg]) -> Result<Vec<Tensor>> {
        if args.len() != self.inputs.len() {
            bail!(
                "{}: got {} args, manifest wants {}",
                self.name,
                args.len(),
                self.inputs.len()
            );
        }
        let mut lits = Vec::with_capacity(args.len());
        for (a, spec) in args.iter().zip(&self.inputs) {
            if a.dtype() != spec.dtype || a.numel() != spec.numel() {
                bail!(
                    "{}: arg {:?} expects {:?}{:?} (got {} elems of {:?})",
                    self.name,
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    a.numel(),
                    a.dtype()
                );
            }
            lits.push(a.to_literal(spec)?);
        }
        let result = self.exe.execute::<Literal>(&lits)?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("device->host transfer")?
            .to_tuple()?;
        if tuple.len() != self.outputs.len() {
            bail!(
                "{}: HLO returned {} outputs, manifest wants {}",
                self.name,
                tuple.len(),
                self.outputs.len()
            );
        }
        tuple
            .into_iter()
            .zip(&self.outputs)
            .map(|(lit, spec)| {
                let data = lit
                    .to_vec::<f32>()
                    .with_context(|| format!("output {}", spec.name))?;
                Tensor::new(spec.shape.clone(), data)
            })
            .collect()
    }
}
