//! PJRT CPU client construction with tuned compile flags.
//!
//! xla_extension 0.5.1 compiles HLO single-threaded through the full
//! LLVM pipeline. Compile/runtime trade-off measured on the vit-micro
//! train step (EXPERIMENTS.md §Perf):
//!
//! | backend opt level | compile | execute/step |
//! |---|---|---|
//! | default (pre-scan, unrolled blocks) | > 16 min | — |
//! | 0 | 4 s | 2678 ms |
//! | 2 (with lax.scan over blocks) | 22 s | 314 ms |
//!
//! Level 2 plus the scan-over-blocks L2 structure is the sweet spot; we
//! default to it and let users override via TETRAJET_XLA_OPT=<level>
//! (or their own XLA_FLAGS).

use anyhow::{Context, Result};
use xla::PjRtClient;

/// Create the PJRT CPU client, defaulting XLA_FLAGS to the fast-compile
/// configuration unless the user already set XLA_FLAGS or chose a level
/// via `TETRAJET_XLA_OPT` (`0`..`3` or `full`).
pub fn cpu_client() -> Result<PjRtClient> {
    let user_flags = std::env::var("XLA_FLAGS").ok();
    let mode = std::env::var("TETRAJET_XLA_OPT").unwrap_or_default();
    if user_flags.is_none() && mode != "full" {
        let level = match mode.as_str() {
            "0" | "1" | "2" | "3" => mode.as_str(),
            _ => "2",
        };
        // Safe: set before the first XLA call in this process.
        std::env::set_var(
            "XLA_FLAGS",
            format!("--xla_backend_optimization_level={level}"),
        );
    }
    PjRtClient::cpu().context("creating PJRT CPU client")
}
