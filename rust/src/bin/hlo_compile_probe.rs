//! HLO compile+execute timing probe (perf-pass tooling).
//!
//! Usage: hlo_compile_probe <variant-dir> <train_step|eval_step|probe> [reps]
//! Respects XLA_FLAGS; reports compile time and per-call execute time
//! with zero-filled inputs.

use anyhow::Result;
use tetrajet::runtime::manifest::{Dtype, Manifest};
use tetrajet::runtime::{Arg, StepFn};

fn main() -> Result<()> {
    let mut args = std::env::args().skip(1);
    let dir = std::path::PathBuf::from(args.next().expect("variant dir"));
    let step = args.next().unwrap_or_else(|| "train_step".into());
    let reps: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(5);

    let client = xla::PjRtClient::cpu()?;
    let man = Manifest::load(&dir.join("manifest.json"))?;
    let io = match step.as_str() {
        "train_step" => &man.train_step,
        "eval_step" => &man.eval_step,
        "probe" => &man.probe,
        other => anyhow::bail!("unknown step {other}"),
    };
    let t0 = std::time::Instant::now();
    let f = StepFn::load(
        &client,
        &dir.join(format!("{step}.hlo.txt")),
        &step,
        io.inputs.clone(),
        io.outputs.clone(),
    )?;
    eprintln!("load+compile: {:.1}s", t0.elapsed().as_secs_f64());

    // Zero-filled inputs (nw/ema_beta filled with 1 to stay in-domain).
    let fbufs: Vec<Vec<f32>> = io
        .inputs
        .iter()
        .map(|s| {
            let fill = if s.name == "nw" || s.name == "ema_beta" { 1.0 } else { 0.0 };
            vec![fill; s.numel()]
        })
        .collect();
    let ibufs: Vec<Vec<i32>> = io.inputs.iter().map(|s| vec![0; s.numel()]).collect();
    let call_args: Vec<Arg> = io
        .inputs
        .iter()
        .enumerate()
        .map(|(i, s)| match s.dtype {
            Dtype::F32 => Arg::F32(&fbufs[i]),
            Dtype::I32 => Arg::I32(&ibufs[i]),
        })
        .collect();
    f.call(&call_args)?; // warmup
    let t1 = std::time::Instant::now();
    for _ in 0..reps {
        f.call(&call_args)?;
    }
    eprintln!(
        "execute: {:.1}ms/call over {reps} reps",
        t1.elapsed().as_secs_f64() * 1000.0 / reps as f64
    );
    Ok(())
}
