//! Rate of change r(X) (paper App. A.3):
//!
//!   r(X) = (1/T0) * sum_t ||X^t - X^{t-1}||_F / ||X^{t-1}||_F
//!
//! Streaming: the tracker keeps the previous snapshot and accumulates
//! the per-step normalized deltas over a window.

#[derive(Debug, Clone)]
pub struct RateTracker {
    prev: Option<Vec<f32>>,
    sum: f64,
    n: usize,
}

impl RateTracker {
    pub fn new() -> RateTracker {
        RateTracker { prev: None, sum: 0.0, n: 0 }
    }

    /// Feed the next snapshot X^t.
    pub fn observe(&mut self, x: &[f32]) {
        if let Some(prev) = &self.prev {
            debug_assert_eq!(prev.len(), x.len());
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for (&a, &b) in x.iter().zip(prev.iter()) {
                let d = (a - b) as f64;
                num += d * d;
                den += (b as f64) * (b as f64);
            }
            if den > 0.0 {
                self.sum += (num / den).sqrt();
                self.n += 1;
            }
            // Reuse the buffer.
            let prev = self.prev.as_mut().unwrap();
            prev.copy_from_slice(x);
        } else {
            self.prev = Some(x.to_vec());
        }
    }

    /// Mean rate over the current window (0 if fewer than 2 snapshots).
    pub fn rate(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn steps(&self) -> usize {
        self.n
    }

    /// Start a new window; the last snapshot is kept as the new base.
    pub fn reset_window(&mut self) {
        self.sum = 0.0;
        self.n = 0;
    }
}

impl Default for RateTracker {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_sequence_has_zero_rate() {
        let mut t = RateTracker::new();
        for _ in 0..5 {
            t.observe(&[1.0, 2.0, 3.0]);
        }
        assert_eq!(t.rate(), 0.0);
        assert_eq!(t.steps(), 4);
    }

    #[test]
    fn known_rate() {
        let mut t = RateTracker::new();
        t.observe(&[3.0, 4.0]); // norm 5
        t.observe(&[3.0, 4.0 + 5.0]); // delta norm 5 -> rate 1
        assert!((t.rate() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn window_reset_keeps_base() {
        let mut t = RateTracker::new();
        t.observe(&[1.0, 0.0]);
        t.observe(&[2.0, 0.0]); // rate 1
        t.reset_window();
        assert_eq!(t.rate(), 0.0);
        t.observe(&[4.0, 0.0]); // |4-2|/2 = 1
        assert!((t.rate() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn zero_base_is_skipped() {
        let mut t = RateTracker::new();
        t.observe(&[0.0, 0.0]);
        t.observe(&[1.0, 1.0]);
        assert_eq!(t.steps(), 0);
        assert_eq!(t.rate(), 0.0);
    }
}
