//! Per-element oscillation windows (paper §6.1 / App. A.1).
//!
//! Over a window of T0 steps the tracker accumulates, for every weight
//! element, the master-trajectory length dist_W = Σ|w^t − w^{t−1}| and
//! the quantized-trajectory length dist_Q = Σ|w_Q^t − w_Q^{t−1}|; the
//! oscillation ratio is R_w = dist_Q / dist_W. Oscillating elements
//! have small master moves but frequent grid flips, so R_w ≫ 1.
//!
//! The tracker also counts quantized-value flips (Nagel et al. 2022's
//! flipping frequency f), which drives the Freeze baseline, and keeps a
//! running average of the master weight (Freeze's pin value).
//!
//! Two trackers share the accumulators and statistics ([`OscWindow`]):
//!
//! * [`OscTracker`] — observes f32 snapshots (master + fake-quant
//!   mirror); still used by the Freeze/Q-Ramping controllers and the
//!   fp32-identity variant.
//! * [`PackedOscTracker`] — observes [`PackedMx`] snapshots from the
//!   packed quant mirror. Flips are detected by comparing 4-bit codes
//!   (a byte memcmp per unchanged group instead of 32 f32 compares,
//!   and 8x less previous-snapshot state); dist_Q only dequantizes the
//!   elements that actually flipped, since an unflipped element
//!   contributes |q_t - q_{t-1}| = 0. Counts and ratios are exactly
//!   equal to the f32 tracker's (property-tested).

use crate::quant::packed::PackedMx;

/// Shared per-element window accumulators + statistics: dist_W, dist_Q,
/// flip counts and the step counter. Both trackers feed this, so the
/// R_w conventions live in exactly one place.
#[derive(Debug, Clone)]
pub struct OscWindow {
    dist_w: Vec<f32>,
    dist_q: Vec<f32>,
    flips: Vec<u32>,
    steps: usize,
}

impl OscWindow {
    fn new(n: usize) -> OscWindow {
        OscWindow {
            dist_w: vec![0.0; n],
            dist_q: vec![0.0; n],
            flips: vec![0; n],
            steps: 0,
        }
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Oscillation ratio R_w per element. dist_W == 0 with dist_Q > 0
    /// maps to +inf (treated as "oscillating" by any finite threshold);
    /// a fully static element maps to 0.
    pub fn ratios_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.extend(self.dist_w.iter().zip(&self.dist_q).map(|(&dw, &dq)| {
            if dw > 0.0 {
                dq / dw
            } else if dq > 0.0 {
                f32::INFINITY
            } else {
                0.0
            }
        }));
    }

    pub fn ratios(&self) -> Vec<f32> {
        let mut v = Vec::new();
        self.ratios_into(&mut v);
        v
    }

    /// Count of elements with R_w > threshold (paper uses 16, Fig. 6).
    pub fn oscillating_count(&self, threshold: f32) -> usize {
        self.oscillating_count_in(threshold, 0, self.dist_w.len())
    }

    /// [`Self::oscillating_count`] restricted to elements `lo..hi` —
    /// the same per-element predicate, so partition sums over disjoint
    /// ranges equal the global count exactly (the observatory relies on
    /// this for bit-exact per-segment / aggregate agreement).
    pub fn oscillating_count_in(&self, threshold: f32, lo: usize, hi: usize) -> usize {
        self.dist_w[lo..hi]
            .iter()
            .zip(&self.dist_q[lo..hi])
            .filter(|(&dw, &dq)| {
                if dw > 0.0 {
                    dq / dw > threshold
                } else {
                    dq > 0.0
                }
            })
            .count()
    }

    /// Cumulative per-element flip counts for the current window.
    pub fn flips(&self) -> &[u32] {
        &self.flips
    }

    /// Flipping frequency f per element (flips per window step).
    pub fn flip_freq_into(&self, out: &mut Vec<f32>) {
        out.clear();
        let n = self.steps.max(1) as f32;
        out.extend(self.flips.iter().map(|&f| f as f32 / n));
    }

    fn reset(&mut self) {
        self.dist_w.iter_mut().for_each(|x| *x = 0.0);
        self.dist_q.iter_mut().for_each(|x| *x = 0.0);
        self.flips.iter_mut().for_each(|x| *x = 0);
        self.steps = 0;
    }
}

#[derive(Debug, Clone)]
pub struct OscTracker {
    prev_w: Vec<f32>,
    prev_q: Vec<f32>,
    win: OscWindow,
    /// Running mean of the master weight over the window (Freeze value).
    run_avg: Vec<f32>,
}

impl OscTracker {
    /// Start a window at snapshot (w0, q0).
    pub fn new(w0: &[f32], q0: &[f32]) -> OscTracker {
        assert_eq!(w0.len(), q0.len());
        OscTracker {
            prev_w: w0.to_vec(),
            prev_q: q0.to_vec(),
            win: OscWindow::new(w0.len()),
            run_avg: w0.to_vec(),
        }
    }

    /// Feed the post-step snapshot (w^t, w_Q^t).
    pub fn observe(&mut self, w: &[f32], q: &[f32]) {
        debug_assert_eq!(w.len(), self.prev_w.len());
        debug_assert_eq!(q.len(), self.prev_q.len());
        self.win.steps += 1;
        let inv = 1.0 / (self.win.steps + 1) as f32;
        for i in 0..w.len() {
            self.win.dist_w[i] += (w[i] - self.prev_w[i]).abs();
            self.win.dist_q[i] += (q[i] - self.prev_q[i]).abs();
            if q[i] != self.prev_q[i] {
                self.win.flips[i] += 1;
            }
            self.run_avg[i] += (w[i] - self.run_avg[i]) * inv;
            self.prev_w[i] = w[i];
            self.prev_q[i] = q[i];
        }
    }

    pub fn steps(&self) -> usize {
        self.win.steps()
    }

    pub fn ratios_into(&self, out: &mut Vec<f32>) {
        self.win.ratios_into(out);
    }

    pub fn ratios(&self) -> Vec<f32> {
        self.win.ratios()
    }

    pub fn oscillating_count(&self, threshold: f32) -> usize {
        self.win.oscillating_count(threshold)
    }

    pub fn flip_freq_into(&self, out: &mut Vec<f32>) {
        self.win.flip_freq_into(out);
    }

    /// Running average of the master weight (Freeze pin value).
    pub fn running_avg(&self) -> &[f32] {
        &self.run_avg
    }

    /// The shared window accumulators (read-only).
    pub fn window(&self) -> &OscWindow {
        &self.win
    }

    /// Start a new window from the current snapshots.
    pub fn reset_window(&mut self) {
        self.win.reset();
        self.run_avg.copy_from_slice(&self.prev_w);
    }
}

/// Per-element oscillation windows over the *packed* quant mirror: same
/// accumulators as [`OscTracker`], but the quantized trajectory arrives
/// as per-segment [`PackedMx`] snapshots and the previous quantized
/// state is kept as codes, not floats.
#[derive(Debug, Clone)]
pub struct PackedOscTracker {
    prev_w: Vec<f32>,
    /// Previous packed snapshot, one entry per manifest segment.
    prev: Vec<PackedMx>,
    win: OscWindow,
}

impl PackedOscTracker {
    /// Start a window at snapshot (w0, q0); `q0` is the packed mirror,
    /// segment by segment, covering exactly `w0.len()` elements.
    pub fn new(w0: &[f32], q0: &[PackedMx]) -> PackedOscTracker {
        let n: usize = q0.iter().map(|p| p.len()).sum();
        assert_eq!(w0.len(), n, "packed segments must cover the master slice");
        PackedOscTracker {
            prev_w: w0.to_vec(),
            prev: q0.to_vec(),
            win: OscWindow::new(n),
        }
    }

    /// Feed the post-step snapshot (w^t, packed w_Q^t).
    pub fn observe(&mut self, w: &[f32], q: &[PackedMx]) {
        debug_assert_eq!(w.len(), self.prev_w.len());
        debug_assert_eq!(q.len(), self.prev.len());
        self.win.steps += 1;
        for i in 0..w.len() {
            self.win.dist_w[i] += (w[i] - self.prev_w[i]).abs();
            self.prev_w[i] = w[i];
        }
        let mut base = 0usize;
        for (cur, prev) in q.iter().zip(&mut self.prev) {
            assert_eq!(cur.len(), prev.len());
            observe_segment(cur, prev, base, &mut self.win.dist_q, &mut self.win.flips);
            prev.clone_from(cur);
            base += cur.len();
        }
        debug_assert_eq!(base, w.len());
    }

    pub fn steps(&self) -> usize {
        self.win.steps()
    }

    pub fn ratios_into(&self, out: &mut Vec<f32>) {
        self.win.ratios_into(out);
    }

    pub fn ratios(&self) -> Vec<f32> {
        self.win.ratios()
    }

    pub fn oscillating_count(&self, threshold: f32) -> usize {
        self.win.oscillating_count(threshold)
    }

    pub fn flip_freq_into(&self, out: &mut Vec<f32>) {
        self.win.flip_freq_into(out);
    }

    /// The shared window accumulators (read-only).
    pub fn window(&self) -> &OscWindow {
        &self.win
    }

    /// Start a new window from the current snapshots.
    pub fn reset_window(&mut self) {
        self.win.reset();
    }
}

/// Accumulate flips + dist_Q for one segment transition `prev -> cur`.
/// Group-granular: an unchanged (scale byte, code bytes) pair skips the
/// whole group with one memcmp; only flipped elements dequantize.
fn observe_segment(
    cur: &PackedMx,
    prev: &PackedMx,
    base: usize,
    dist_q: &mut [f32],
    flips: &mut [u32],
) {
    if cur.num_groups() == 0 {
        // Per-tensor scale (INT4): the scale moves with the tensor max,
        // so compare dequantized values directly.
        for i in 0..cur.len() {
            let (a, b) = (cur.value(i), prev.value(i));
            if a != b {
                flips[base + i] += 1;
                dist_q[base + i] += (a - b).abs();
            }
        }
        return;
    }
    cur.for_each_group(|g, a, b| {
        cur.group_flips(prev, g, a, b, |i, delta| {
            flips[base + i] += 1;
            dist_q[base + i] += delta;
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oscillating_element_gets_large_ratio() {
        // Element 0 oscillates across a grid flip with tiny master moves;
        // element 1 walks monotonically with matching quantized moves.
        let mut t = OscTracker::new(&[-0.751, 0.0], &[-1.0, 0.0]);
        let w_seq = [[-0.749, 0.1], [-0.751, 0.2], [-0.749, 0.3], [-0.751, 0.4]];
        let q_seq = [[-0.5, 0.0], [-1.0, 0.0], [-0.5, 0.5], [-1.0, 0.5]];
        for (w, q) in w_seq.iter().zip(&q_seq) {
            t.observe(w, q);
        }
        let r = t.ratios();
        assert!(r[0] > 16.0, "oscillating ratio {}", r[0]);
        assert!(r[1] < 16.0, "walking ratio {}", r[1]);
        assert_eq!(t.oscillating_count(16.0), 1);
    }

    #[test]
    fn flip_frequency_counts_changes() {
        let mut t = OscTracker::new(&[0.0], &[0.0]);
        for (w, q) in [(0.1, 0.5), (0.1, 0.0), (0.1, 0.0), (0.1, 0.5)] {
            t.observe(&[w], &[q]);
        }
        let mut f = Vec::new();
        t.flip_freq_into(&mut f);
        assert!((f[0] - 3.0 / 4.0).abs() < 1e-6);
    }

    #[test]
    fn static_element_ratio_zero_and_inf_case() {
        let mut t = OscTracker::new(&[1.0, 1.0], &[1.0, 1.0]);
        // Element 0 fully static; element 1: q flips while w frozen.
        t.observe(&[1.0, 1.0], &[1.0, 0.5]);
        let r = t.ratios();
        assert_eq!(r[0], 0.0);
        assert!(r[1].is_infinite());
        assert_eq!(t.oscillating_count(1e6), 1);
    }

    #[test]
    fn running_avg_tracks_mean() {
        let mut t = OscTracker::new(&[0.0], &[0.0]);
        t.observe(&[1.0], &[1.0]);
        t.observe(&[2.0], &[2.0]);
        assert!((t.running_avg()[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn reset_window_clears_accumulators() {
        let mut t = OscTracker::new(&[0.0], &[0.0]);
        t.observe(&[1.0], &[0.5]);
        t.reset_window();
        assert_eq!(t.steps(), 0);
        assert_eq!(t.ratios()[0], 0.0);
    }

    mod packed {
        use super::super::*;
        use crate::quant::{e2m1, mx_quantize_cols, MxQuantizer, Quantizer, Scaling};

        /// Drive both trackers over the same master trajectory and check
        /// every window statistic matches exactly.
        fn parity(traj: &[Vec<f32>], cols: usize) {
            let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
            let pack = |w: &[f32]| {
                let mut p = PackedMx::default();
                q.quantize_packed(w, cols, &mut p);
                p
            };
            let fake = |w: &[f32]| mx_quantize_cols(w, cols, e2m1(), Scaling::TruncationFree);

            let mut tf = OscTracker::new(&traj[0], &fake(&traj[0]));
            let mut tp = PackedOscTracker::new(&traj[0], &[pack(&traj[0])]);
            for w in &traj[1..] {
                tf.observe(w, &fake(w));
                tp.observe(w, &[pack(w)]);
            }
            assert_eq!(tf.steps(), tp.steps());
            let (mut ff, mut fp) = (Vec::new(), Vec::new());
            tf.flip_freq_into(&mut ff);
            tp.flip_freq_into(&mut fp);
            assert_eq!(ff, fp, "flip frequencies diverge");
            assert_eq!(tf.ratios(), tp.ratios(), "oscillation ratios diverge");
            for th in [0.0, 1.0, 16.0, 1e6] {
                assert_eq!(tf.oscillating_count(th), tp.oscillating_count(th));
            }
        }

        #[test]
        fn matches_f32_tracker_on_oscillating_trajectory() {
            // Element 0 oscillates across the 0.75 threshold; element 1
            // walks; the rest of the group drifts slowly. Ragged cols.
            let n = 48;
            let mut traj = Vec::new();
            for t in 0..8 {
                let mut w: Vec<f32> = (0..n).map(|i| (i as f32 * 0.13).sin()).collect();
                w[0] = if t % 2 == 0 { 0.749 } else { 0.751 };
                w[1] = 0.1 * t as f32;
                w[5] = 6.0; // pins the group scale
                traj.push(w);
            }
            parity(&traj, n);
        }

        #[test]
        fn matches_f32_tracker_across_scale_shift() {
            // Whole-group magnitude doubling flips every nonzero element
            // while codes stay identical — the case a naive code compare
            // would miss.
            let n = 32;
            let base: Vec<f32> = (0..n).map(|i| (i as f32 * 0.21).cos() * 2.0).collect();
            let traj: Vec<Vec<f32>> = (0..4)
                .map(|t| base.iter().map(|&v| v * (1 << t) as f32).collect())
                .collect();
            parity(&traj, n);
        }

        #[test]
        fn matches_f32_tracker_at_nvfp4_geometry() {
            // Same parity harness, but the packed mirror is NVFP4:
            // 16-element groups with E4M3 scale bytes. The tracker is
            // geometry-agnostic because it only speaks for_each_group /
            // group_flips.
            use crate::quant::NvQuantizer;
            let q = NvQuantizer::nvfp4();
            let cols = 24; // ragged at group size 16 (16 + 8)
            let pack = |w: &[f32]| {
                let mut p = PackedMx::default();
                q.quantize_packed(w, cols, &mut p);
                p
            };
            let fake = |w: &[f32]| {
                let mut out = vec![0.0; w.len()];
                q.quantize_f32(w, cols, &mut out);
                out
            };
            let mut traj = Vec::new();
            for t in 0..8 {
                let mut w: Vec<f32> = (0..cols * 2).map(|i| (i as f32 * 0.13).sin()).collect();
                w[0] = if t % 2 == 0 { 0.749 } else { 0.751 };
                w[1] = 0.1 * t as f32;
                w[5] = 6.0;
                traj.push(w);
            }
            let mut tf = OscTracker::new(&traj[0], &fake(&traj[0]));
            let mut tp = PackedOscTracker::new(&traj[0], &[pack(&traj[0])]);
            for w in &traj[1..] {
                tf.observe(w, &fake(w));
                tp.observe(w, &[pack(w)]);
            }
            let (mut ff, mut fp) = (Vec::new(), Vec::new());
            tf.flip_freq_into(&mut ff);
            tp.flip_freq_into(&mut fp);
            assert_eq!(ff, fp, "flip frequencies diverge at nvfp4 geometry");
            assert_eq!(tf.ratios(), tp.ratios(), "ratios diverge at nvfp4 geometry");
        }

        #[test]
        fn static_packed_window_counts_nothing() {
            let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
            let w: Vec<f32> = (0..32).map(|i| i as f32 * 0.1).collect();
            let mut p = PackedMx::default();
            q.quantize_packed(&w, 32, &mut p);
            let mut t = PackedOscTracker::new(&w, std::slice::from_ref(&p));
            t.observe(&w, std::slice::from_ref(&p));
            t.observe(&w, std::slice::from_ref(&p));
            assert_eq!(t.steps(), 2);
            assert!(t.ratios().iter().all(|&r| r == 0.0));
            assert_eq!(t.oscillating_count(0.0), 0);
            t.reset_window();
            assert_eq!(t.steps(), 0);
        }
    }
}
