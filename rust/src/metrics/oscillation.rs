//! Per-element oscillation windows (paper §6.1 / App. A.1).
//!
//! Over a window of T0 steps the tracker accumulates, for every weight
//! element, the master-trajectory length dist_W = Σ|w^t − w^{t−1}| and
//! the quantized-trajectory length dist_Q = Σ|w_Q^t − w_Q^{t−1}|; the
//! oscillation ratio is R_w = dist_Q / dist_W. Oscillating elements
//! have small master moves but frequent grid flips, so R_w ≫ 1.
//!
//! The tracker also counts quantized-value flips (Nagel et al. 2022's
//! flipping frequency f), which drives the Freeze baseline, and keeps a
//! running average of the master weight (Freeze's pin value).

#[derive(Debug, Clone)]
pub struct OscTracker {
    prev_w: Vec<f32>,
    prev_q: Vec<f32>,
    dist_w: Vec<f32>,
    dist_q: Vec<f32>,
    flips: Vec<u32>,
    /// Running mean of the master weight over the window (Freeze value).
    run_avg: Vec<f32>,
    steps: usize,
}

impl OscTracker {
    /// Start a window at snapshot (w0, q0).
    pub fn new(w0: &[f32], q0: &[f32]) -> OscTracker {
        assert_eq!(w0.len(), q0.len());
        OscTracker {
            prev_w: w0.to_vec(),
            prev_q: q0.to_vec(),
            dist_w: vec![0.0; w0.len()],
            dist_q: vec![0.0; w0.len()],
            flips: vec![0; w0.len()],
            run_avg: w0.to_vec(),
            steps: 0,
        }
    }

    /// Feed the post-step snapshot (w^t, w_Q^t).
    pub fn observe(&mut self, w: &[f32], q: &[f32]) {
        debug_assert_eq!(w.len(), self.prev_w.len());
        debug_assert_eq!(q.len(), self.prev_q.len());
        self.steps += 1;
        let inv = 1.0 / (self.steps + 1) as f32;
        for i in 0..w.len() {
            self.dist_w[i] += (w[i] - self.prev_w[i]).abs();
            self.dist_q[i] += (q[i] - self.prev_q[i]).abs();
            if q[i] != self.prev_q[i] {
                self.flips[i] += 1;
            }
            self.run_avg[i] += (w[i] - self.run_avg[i]) * inv;
            self.prev_w[i] = w[i];
            self.prev_q[i] = q[i];
        }
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Oscillation ratio R_w per element. dist_W == 0 with dist_Q > 0
    /// maps to +inf (treated as "oscillating" by any finite threshold);
    /// a fully static element maps to 0.
    pub fn ratios_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.extend(self.dist_w.iter().zip(&self.dist_q).map(|(&dw, &dq)| {
            if dw > 0.0 {
                dq / dw
            } else if dq > 0.0 {
                f32::INFINITY
            } else {
                0.0
            }
        }));
    }

    pub fn ratios(&self) -> Vec<f32> {
        let mut v = Vec::new();
        self.ratios_into(&mut v);
        v
    }

    /// Count of elements with R_w > threshold (paper uses 16, Fig. 6).
    pub fn oscillating_count(&self, threshold: f32) -> usize {
        self.dist_w
            .iter()
            .zip(&self.dist_q)
            .filter(|(&dw, &dq)| {
                if dw > 0.0 {
                    dq / dw > threshold
                } else {
                    dq > 0.0
                }
            })
            .count()
    }

    /// Flipping frequency f per element (flips per window step).
    pub fn flip_freq_into(&self, out: &mut Vec<f32>) {
        out.clear();
        let n = self.steps.max(1) as f32;
        out.extend(self.flips.iter().map(|&f| f as f32 / n));
    }

    /// Running average of the master weight (Freeze pin value).
    pub fn running_avg(&self) -> &[f32] {
        &self.run_avg
    }

    /// Start a new window from the current snapshots.
    pub fn reset_window(&mut self) {
        self.dist_w.iter_mut().for_each(|x| *x = 0.0);
        self.dist_q.iter_mut().for_each(|x| *x = 0.0);
        self.flips.iter_mut().for_each(|x| *x = 0);
        self.run_avg.copy_from_slice(&self.prev_w);
        self.steps = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oscillating_element_gets_large_ratio() {
        // Element 0 oscillates across a grid flip with tiny master moves;
        // element 1 walks monotonically with matching quantized moves.
        let mut t = OscTracker::new(&[-0.751, 0.0], &[-1.0, 0.0]);
        let w_seq = [[-0.749, 0.1], [-0.751, 0.2], [-0.749, 0.3], [-0.751, 0.4]];
        let q_seq = [[-0.5, 0.0], [-1.0, 0.0], [-0.5, 0.5], [-1.0, 0.5]];
        for (w, q) in w_seq.iter().zip(&q_seq) {
            t.observe(w, q);
        }
        let r = t.ratios();
        assert!(r[0] > 16.0, "oscillating ratio {}", r[0]);
        assert!(r[1] < 16.0, "walking ratio {}", r[1]);
        assert_eq!(t.oscillating_count(16.0), 1);
    }

    #[test]
    fn flip_frequency_counts_changes() {
        let mut t = OscTracker::new(&[0.0], &[0.0]);
        for (w, q) in [(0.1, 0.5), (0.1, 0.0), (0.1, 0.0), (0.1, 0.5)] {
            t.observe(&[w], &[q]);
        }
        let mut f = Vec::new();
        t.flip_freq_into(&mut f);
        assert!((f[0] - 3.0 / 4.0).abs() < 1e-6);
    }

    #[test]
    fn static_element_ratio_zero_and_inf_case() {
        let mut t = OscTracker::new(&[1.0, 1.0], &[1.0, 1.0]);
        // Element 0 fully static; element 1: q flips while w frozen.
        t.observe(&[1.0, 1.0], &[1.0, 0.5]);
        let r = t.ratios();
        assert_eq!(r[0], 0.0);
        assert!(r[1].is_infinite());
        assert_eq!(t.oscillating_count(1e6), 1);
    }

    #[test]
    fn running_avg_tracks_mean() {
        let mut t = OscTracker::new(&[0.0], &[0.0]);
        t.observe(&[1.0], &[1.0]);
        t.observe(&[2.0], &[2.0]);
        assert!((t.running_avg()[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn reset_window_clears_accumulators() {
        let mut t = OscTracker::new(&[0.0], &[0.0]);
        t.observe(&[1.0], &[0.5]);
        t.reset_window();
        assert_eq!(t.steps(), 0);
        assert_eq!(t.ratios()[0], 0.0);
    }
}
