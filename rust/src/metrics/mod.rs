//! Oscillation / instability statistics (paper §4 + Appendix A).
//!
//! All metrics are computed by the coordinator in pure Rust over the
//! state it already owns, using the quant mirror for quantized-weight
//! trajectories:
//!
//! * [`rate::RateTracker`] — rate of change r(X) (App. A.3, Fig. 2,
//!   Table 3),
//! * [`oscillation::OscTracker`] — per-element dist_W / dist_Q windows,
//!   oscillation ratio R_w (App. A.1, §6.1, Fig. 6) and Nagel et al.'s
//!   flipping frequency f (used by the Freeze baseline),
//! * [`oscillation::PackedOscTracker`] — the same windows over the
//!   packed 4-bit quant mirror: flips by code compare, dist_Q by
//!   dequantizing only flipped elements,
//! * [`confidence`] — latent weights and quantization confidence
//!   (§4.2 / App. A.2, Fig. 4/5).

pub mod confidence;
pub mod oscillation;
pub mod rate;

pub use confidence::{latents, latents_geom, quant_confidence, quant_confidence_geom};
pub use oscillation::{OscTracker, OscWindow, PackedOscTracker};
pub use rate::RateTracker;
