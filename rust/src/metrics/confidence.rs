//! Latent weights + quantization confidence (paper §4.2 / App. A.2).
//!
//!   QuantConf(w) = min_i |latent − thrd_i| / MaxDist(latent's level)
//!
//! latent = w / S with S the element's shared group scale. Confidence
//! near 0 means the latent sits on a decision threshold (prone to
//! oscillate); confidence 1 means it sits as far from any threshold as
//! its level allows.

use crate::quant::formats::{Fp4Format, GroupGeom, Scaling};

/// Latent weights w/S (clamped to [Qn, Qp] like the quantizer input)
/// for a 1x32-grouped matrix. Used for the Fig. 4 latent distribution.
pub fn latents(
    w: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
    out: &mut Vec<f32>,
) {
    latents_geom(w, cols, fmt, scaling, GroupGeom::mx(), out);
}

/// [`latents`] at an explicit group geometry: the shared scale S is the
/// geometry's encoded-then-decoded scale byte (E8M0 power of two for
/// MX, E4M3 for NVFP4), so the latent matches what the quantizer of
/// that geometry actually divides by. An all-zero group (E4M3 scale 0)
/// has latent 0 everywhere.
pub fn latents_geom(
    w: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
    geom: GroupGeom,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(w.len());
    for row in w.chunks_exact(cols) {
        for g in row.chunks(geom.group_size()) {
            let max_abs = g.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let scale = geom.decode_scale(geom.encode_scale(max_abs, fmt, scaling));
            let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
            for &v in g {
                out.push((v * inv).clamp(fmt.qn(), fmt.qp()));
            }
        }
    }
}

/// Per-element quantization confidence in [0, 1].
pub fn quant_confidence(
    w: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
    out: &mut Vec<f32>,
) {
    quant_confidence_geom(w, cols, fmt, scaling, GroupGeom::mx(), out);
}

/// [`quant_confidence`] at an explicit group geometry (see
/// [`latents_geom`] for the scale convention).
pub fn quant_confidence_geom(
    w: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
    geom: GroupGeom,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(w.len());
    let nb = fmt.boundaries.len();
    for row in w.chunks_exact(cols) {
        for g in row.chunks(geom.group_size()) {
            let max_abs = g.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let scale = geom.decode_scale(geom.encode_scale(max_abs, fmt, scaling));
            let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
            for &v in g {
                let y = (v * inv).clamp(fmt.qn(), fmt.qp());
                let j = fmt.level_index(y); // level y rounds to
                // Nearest threshold is one of the level's cell edges.
                let d = match j {
                    0 => (y - fmt.boundaries[0]).abs(),
                    j if j == nb => (y - fmt.boundaries[nb - 1]).abs(),
                    j => (y - fmt.boundaries[j - 1])
                        .abs()
                        .min((y - fmt.boundaries[j]).abs()),
                };
                out.push((d / fmt.maxdist[j]).min(1.0));
            }
        }
    }
}

/// Mean confidence of a matrix (paper's per-matrix aggregate).
pub fn mean_confidence(w: &[f32], cols: usize, fmt: &Fp4Format, scaling: Scaling) -> f64 {
    let mut confs = Vec::new();
    quant_confidence(w, cols, fmt, scaling, &mut confs);
    crate::util::stats::mean_f32(&confs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::formats::e2m1;

    #[test]
    fn confidence_zero_on_threshold_one_on_level() {
        let fmt = e2m1();
        // Group max 6 -> scale 1 (tf: ceil(log2(6/6)) = 0).
        let mut w = vec![0.0f32; 32];
        w[0] = 6.0;
        w[1] = -0.75; // exactly the -1/-0.5 threshold
        w[2] = 2.0; // exactly on a level; cell [1.75, 2.5], maxdist 0.375
        let mut c = Vec::new();
        quant_confidence(&w, 32, fmt, Scaling::TruncationFree, &mut c);
        assert_eq!(c[1], 0.0);
        // 2.0: min dist = 0.25 (to 1.75... wait |2-1.75|=0.25, |2-2.5|=0.5)
        assert!((c[2] - 0.25 / 0.375).abs() < 1e-6, "got {}", c[2]);
        // 6.0: dist to threshold 5 is 1 = maxdist -> confidence 1.
        assert_eq!(c[0], 1.0);
    }

    #[test]
    fn latents_are_scaled_and_clamped() {
        let fmt = e2m1();
        let mut w = vec![0.0f32; 32];
        w[0] = 31.0; // tf scale 8
        w[1] = 4.0;
        let mut l = Vec::new();
        latents(&w, 32, fmt, Scaling::TruncationFree, &mut l);
        assert_eq!(l[0], 31.0 / 8.0);
        assert_eq!(l[1], 0.5);
        // floor scaling of the same block truncates to Qp.
        latents(&w, 32, fmt, Scaling::Floor, &mut l);
        assert_eq!(l[0], 6.0); // 31/4 = 7.75 clamped to 6
    }

    #[test]
    fn geom_variants_match_legacy_at_mx_and_stay_bounded_at_nvfp4() {
        let fmt = e2m1();
        let w: Vec<f32> = (0..192).map(|i| ((i * 29) % 97) as f32 / 13.0 - 3.5).collect();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        // MX geometry reproduces the legacy functions bit-for-bit.
        latents(&w, 48, fmt, Scaling::TruncationFree, &mut a);
        latents_geom(&w, 48, fmt, Scaling::TruncationFree, GroupGeom::mx(), &mut b);
        assert_eq!(a, b);
        quant_confidence(&w, 48, fmt, Scaling::TruncationFree, &mut a);
        quant_confidence_geom(&w, 48, fmt, Scaling::TruncationFree, GroupGeom::mx(), &mut b);
        assert_eq!(a, b);
        // NVFP4 geometry: latents clamped to the grid range, confidence
        // still in [0, 1].
        latents_geom(&w, 48, fmt, Scaling::TruncationFree, GroupGeom::nvfp4(), &mut a);
        assert_eq!(a.len(), w.len());
        assert!(a.iter().all(|&l| (fmt.qn()..=fmt.qp()).contains(&l)));
        quant_confidence_geom(&w, 48, fmt, Scaling::TruncationFree, GroupGeom::nvfp4(), &mut b);
        assert!(b.iter().all(|&c| (0.0..=1.0).contains(&c)));
        // All-zero group at E4M3 scale 0 maps to latent 0, not NaN.
        let z = vec![0.0f32; 16];
        latents_geom(&z, 16, fmt, Scaling::TruncationFree, GroupGeom::nvfp4(), &mut a);
        assert!(a.iter().all(|&l| l == 0.0));
    }

    #[test]
    fn confidence_bounded() {
        let fmt = e2m1();
        let w: Vec<f32> = (0..256).map(|i| ((i * 31) % 101) as f32 / 17.0 - 3.0).collect();
        let mut c = Vec::new();
        quant_confidence(&w, 64, fmt, Scaling::TruncationFree, &mut c);
        assert!(c.iter().all(|&x| (0.0..=1.0).contains(&x)));
        let m = mean_confidence(&w, 64, fmt, Scaling::TruncationFree);
        assert!(m > 0.0 && m < 1.0);
    }
}
