//! `tetrajet` — leader binary: train / eval / experiment harness CLI.
//!
//! The binary is self-contained once `make artifacts` has produced the
//! AOT HLO artifacts; Python never runs on the training path.

use anyhow::{bail, Result};
use tetrajet::config::{MetricsCfg, Policy, TrainConfig};
use tetrajet::coordinator::Trainer;
use tetrajet::experiments::{self, common::ExpOpts};
use tetrajet::loginfo;
use tetrajet::runtime::{artifacts, ModelArtifacts};
use tetrajet::util::cli::Args;

const USAGE: &str = "\
tetrajet — Oscillation-Reduced MXFP4 Training (TetraJet, ICML 2025)

subcommands:
  train          train one configuration
  eval           evaluate a checkpoint
  serve          packed-native inference over a checkpoint (no XLA)
  report         analyze an OSCLOG01 artifact offline (markdown + json)
  obs-validate   validate a --trace-out JSONL / --metrics-out snapshot
                 / --osc-out OSCLOG / report json
  exp <id>       run an experiment harness (table1..table7, fig2..fig6, all)
  list-variants  print all known method variants
  help           this text

common options:
  --artifacts DIR   artifacts root (default: artifacts/, or $TETRAJET_ARTIFACTS)
  --model NAME      model config (default vit-micro)
  --batch N         batch size baked into the artifacts (default 16)

train options:
  --variant NAME    method variant (default tetrajet)
  --policy NAME     none | qramping | dampen | freeze (default none)
  --steps N         training steps (default 400)
  --lr F            base learning rate (default 1e-3)
  --ema-beta F      Q-EMA momentum (default 0.998)
  --dampen-lambda F Dampen strength (default 1e-4, with --policy dampen)
  --k1 F --k2 F     Q-Ramping coefficients (defaults 16, 5)
  --eval-every N    evaluate every N steps (default 0 = end only)
  --eval-samples N  validation samples (default 512)
  --seed N          init seed (default 0)
  --ckpt-out PATH   save final checkpoint
  --ckpt-packed     write a TJCKPT02 checkpoint carrying the packed
                    4-bit quant mirror (input of `serve`/`eval --packed`)
  --metrics LEVEL   off | standard | full (default off)
  --metrics-out PATH  write the trainer's metrics-registry snapshot
                    (phase timings, oscillation gauges) as json
  --osc-out PATH    stream per-segment oscillation telemetry (flips,
                    confidence, |W-Wq|, window counts) as an OSCLOG01
                    JSONL artifact (input of `report`); enables an
                    oscillation window (default 50) if none is set
  --osc-window N    override the oscillation-window length
  --trace-out PATH  write a Chrome trace-event JSONL of per-step phase
                    spans (hlo/mirror/controllers/metrics/eval) — the
                    same format `serve --trace-out` emits
  --synthetic NAME  no-artifacts observatory run: a seeded random walk
                    over a synthetic layout (tiny | micro) through the
                    identical quantize/track/record machinery; variant
                    selects the mirror (mx | nvfp4). Deterministic —
                    the `make report-smoke` path

eval options:
  --variant NAME    method variant artifact to evaluate with
  --ckpt PATH       checkpoint produced by train --ckpt-out
  --packed          evaluate through the packed serving engine (fused
                    dequant-matmul over codes; needs only the manifest,
                    not the compiled HLO)
  --verify-mirror   with --packed: also run the dequantize-then-matmul
                    mirror interleaved per batch (sharing the fused
                    engine's activation-quant cache) and assert
                    bit-identical logits
  --simd LEVEL      kernel dispatch override: auto | off | ssse3 | avx2
                    (default auto = highest the host supports; the
                    TJ_SIMD env var does the same)

serve options:
  --ckpt PATH       checkpoint (TJCKPT02 serves codes directly;
                    TJCKPT01 re-quantizes the f32 params)
  --variant NAME    manifest to take geometry/recipe from
  --synthetic NAME  serve a seeded synthetic model instead of a
                    checkpoint: tiny | micro (smoke/load-test path)
  --engines N       row-sharded fleet engines (default 1)
  --micro-batch N   scheduler micro-batch (default: artifact batch)
  --workers N       kernel worker threads per engine (default: half
                    the cores)
  --queue-depth N   admission queue bound in images (default 256);
                    arrivals beyond it are rejected with a reason
  --simd LEVEL      kernel dispatch override: auto | off | ssse3 | avx2
                    (default auto; TJ_SIMD env var equivalent)
  --requests N      request count (default 32)
  --request-size N  images per request (default 4)
  --load-test       open-loop Poisson load test (emits BENCH json)
  --rate F          load-test arrival rate, requests/s (default 64)
  --seed N          arrival-schedule + synthetic-model seed (default 0)
  --deadline-ms F   per-request deadline relative to arrival
  --pace MODE       real | virtual (default real); virtual simulates
                    a clock at --service-ms per image, making the
                    whole run deterministic for a given seed
  --service-ms F    virtual-pace per-image service time (default 1.0)
  --bench-out PATH  BENCH json file (default results/BENCH_<pr>.json)
  --bench-pr N      PR number stamped into the BENCH file (default 9)
  --gate-tol F      regression tolerance vs the previous BENCH_*.json
                    (default 0.10 = 10%)
  --strict-gate     exit nonzero when a regression is flagged
  --eval-samples N  also report accuracy on N val samples
                    (default 256; checkpoint mode only)
  --trace-out PATH  write a Chrome trace-event JSONL of every request's
                    admit -> queued -> batched -> shard-forward ->
                    gather -> redeemed lifecycle; byte-identical across
                    runs under --load-test --pace virtual
  --metrics-out PATH  write the final metrics-registry snapshot json
  --metrics-every N print a METRICS {...} snapshot line every N batches
  --metrics-addr A  serve the live registry as text over TCP on A
                    (e.g. 127.0.0.1:9464; port 0 picks a free one)

report options:
  --osclog PATH     OSCLOG01 artifact produced by train --osc-out
  --compare PATH    second artifact; appends a controller-effect table
                    (flip-rate deltas per segment, fraction shift)
  --top N           top-K oscillating segments to list (default 10)
  --json PATH       also write the report as OSCREPORT01 json

obs-validate options:
  --trace PATH      check a --trace-out JSONL: parseable lines, trace
                    schema, nonnegative ts/dur; reprints the digest
  --snapshot PATH   check a --metrics-out snapshot carries the stable
                    scheduler/fleet/kernel/latency metric names
  --osclog PATH     check an OSCLOG01 artifact: header schema, segment
                    tiling, monotone step ids, window counts bounded by
                    segment sizes; reprints the recomputed digest
  --report PATH     check an OSCREPORT01 json carries the stable keys

exp options:
  --quick           reduced steps/eval for smoke runs
  --steps N         override steps per run
  --results DIR     results output dir (default results/)
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_policy(args: &Args) -> Result<Policy> {
    Ok(match args.get_or("policy", "none") {
        "none" => Policy::None,
        "qramping" => {
            let mut p = Policy::qramping_default();
            if let Policy::QRamping { k1, k2, .. } = &mut p {
                *k1 = args.get_f32("k1", *k1)?;
                *k2 = args.get_f32("k2", *k2)?;
            }
            p
        }
        "dampen" => Policy::Dampen { lambda: args.get_f32("dampen-lambda", 1e-4)? },
        "freeze" => Policy::freeze_default(),
        other => bail!("unknown policy {other:?}"),
    })
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        "list-variants" => {
            for v in tetrajet::config::all_variants() {
                println!("{v}");
            }
            Ok(())
        }
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "report" => cmd_report(&args),
        "obs-validate" => cmd_obs_validate(&args),
        "exp" => cmd_exp(&args),
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn base_paths(args: &Args) -> (std::path::PathBuf, String, usize) {
    let root = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts::default_root);
    let model = args.get_or("model", "vit-micro").to_string();
    let batch = args.get_usize("batch", 16).unwrap_or(16);
    (root, model, batch)
}

fn parse_metrics(args: &Args, default_level: &str) -> Result<MetricsCfg> {
    Ok(match args.get_or("metrics", default_level) {
        "off" => MetricsCfg::off(),
        "standard" => MetricsCfg::standard(),
        "full" => MetricsCfg::full(),
        other => bail!("unknown metrics level {other:?}"),
    })
}

/// Write a registry snapshot json (shared by train/serve paths).
fn write_snapshot(reg: &tetrajet::obs::MetricsRegistry, p: &str) -> Result<()> {
    let path = std::path::Path::new(p);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, reg.snapshot_json().to_string() + "\n")?;
    Ok(())
}

/// `train --synthetic NAME`: the no-artifacts observatory path — a
/// seeded random walk through the identical quantize/track/record
/// machinery, producing byte-stable OSCLOG01 + trace artifacts
/// (`make report-smoke` gates on the digests).
fn cmd_train_synthetic(args: &Args, model: &str) -> Result<()> {
    use tetrajet::coordinator::SynthTrainer;
    use tetrajet::obs::osclog::OscLogWriter;

    let variant = args.get_or("variant", "mx").to_string();
    let steps = args.get_usize("steps", 60)?;
    let seed = args.get_u64("seed", 0)?;
    let mut metrics = parse_metrics(args, "standard")?;
    if metrics.osc_window == 0 {
        metrics.osc_window = MetricsCfg::standard().osc_window;
    }
    metrics.osc_window = args.get_usize("osc-window", metrics.osc_window)?;
    let mut tr = SynthTrainer::new(model, &variant, seed, metrics)?;
    if let Some(p) = args.get("osc-out") {
        tr.attach_osclog(OscLogWriter::to_file(std::path::Path::new(p))?);
        loginfo!("oscillation observatory -> {p}");
    }
    if let Some(p) = args.get("trace-out") {
        tr.set_trace(tetrajet::obs::TraceSink::to_file(std::path::Path::new(p), true)?);
        loginfo!("tracing to {p} (deterministic=true)");
    }
    let rep = tr.run(steps)?;
    println!(
        "synthetic[{model}/{variant}]: {} steps over {} quantized weights \
         in {} slices, {} windows closed",
        rep.steps,
        rep.qw_total,
        rep.segments,
        rep.windows.len()
    );
    if let Some((step, count)) = rep.windows.last() {
        println!(
            "window[{step}]: {count} oscillating ({:.6} of the quantized prefix)",
            *count as f64 / rep.qw_total.max(1) as f64
        );
    }
    if let Some((lines, digest)) = &rep.osclog {
        println!("OSCLOG lines={lines} digest={digest}");
    }
    if let Some((events, digest)) = &rep.trace {
        println!("TRACE events={events} digest={digest}");
    }
    if let Some(p) = args.get("metrics-out") {
        write_snapshot(tr.registry(), p)?;
        loginfo!("trainer metrics snapshot written to {p}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    if let Some(name) = args.get("synthetic") {
        let name = name.to_string();
        return cmd_train_synthetic(args, &name);
    }
    let (root, model, batch) = base_paths(args);
    let variant = args.get_or("variant", "tetrajet").to_string();
    let client = tetrajet::runtime::cpu_client()?;
    loginfo!("loading artifacts {model}/b{batch}/{variant}");
    let arts = ModelArtifacts::load(&client, &root, &model, batch, &variant)?;

    let mut cfg = TrainConfig::default_run(&variant);
    cfg.model = model.clone();
    cfg.batch = batch;
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.warmup = (cfg.steps / 10).max(1);
    cfg.base_lr = args.get_f32("lr", cfg.base_lr)?;
    cfg.ema_beta = args.get_f32("ema-beta", cfg.ema_beta)?;
    cfg.eval_every = args.get_usize("eval-every", 0)?;
    cfg.eval_samples = args.get_usize("eval-samples", cfg.eval_samples)?;
    cfg.init_seed = args.get_usize("seed", 0)? as i32;
    cfg.policy = parse_policy(args)?;
    cfg.metrics = parse_metrics(args, "off")?;
    if let Some(w) = args.get("osc-window") {
        cfg.metrics.osc_window = w.parse()?;
    }
    let osc_out = args.get("osc-out").map(std::path::PathBuf::from);
    if osc_out.is_some() && cfg.metrics.osc_window == 0 {
        // --osc-out implies oscillation tracking.
        cfg.metrics.osc_window = MetricsCfg::standard().osc_window;
    }
    loginfo!("config: {}", cfg.to_json().to_string());

    let params = artifacts::run_init(&client, &root, &model, cfg.init_seed)?;
    let ckpt_out = args.get("ckpt-out").map(std::path::PathBuf::from);
    if args.has_flag("ckpt-packed") && ckpt_out.is_none() {
        bail!("--ckpt-packed requires --ckpt-out PATH");
    }
    let seed = args.get_u64("seed", 0)?;
    let mut tr = Trainer::new(&arts, cfg, params)?;
    if let Some(p) = &osc_out {
        tr.make_observatory(tetrajet::obs::osclog::OscLogWriter::to_file(p)?, seed)?;
        loginfo!("oscillation observatory -> {}", p.display());
    }
    if let Some(p) = args.get("trace-out") {
        tr.set_trace(tetrajet::obs::TraceSink::to_file(std::path::Path::new(p), false)?);
        loginfo!("tracing to {p} (deterministic=false)");
    }
    let ev = tr.run()?;
    println!(
        "final: top-1 {:.2}%  val-loss {:.4}  ({} samples)",
        ev.acc_pct, ev.mean_loss, ev.samples
    );
    if let Some(ob) = tr.observatory_mut() {
        ob.finish()?;
        println!("OSCLOG lines={} digest={}", ob.lines(), ob.digest());
    }
    if let Some(t) = tr.trace_mut() {
        let (events, digest) = (t.events(), t.digest());
        t.finish()?;
        println!("TRACE events={events} digest={digest}");
    }
    if let Some(p) = ckpt_out {
        if args.has_flag("ckpt-packed") {
            tr.save_packed_checkpoint(&p)?;
            loginfo!("packed checkpoint (TJCKPT02) saved to {}", p.display());
        } else {
            tr.state.save(&p)?;
            loginfo!("checkpoint saved to {}", p.display());
        }
    }
    if let Some(p) = args.get("metrics-out") {
        write_snapshot(tr.registry(), p)?;
        loginfo!("trainer metrics snapshot written to {p}");
    }
    Ok(())
}

/// Apply a `--simd` dispatch override (process-wide, like `TJ_SIMD`)
/// and log what the kernels will actually run at.
fn apply_simd_override(args: &Args) -> Result<()> {
    use tetrajet::serve::simd;
    if let Some(v) = args.get("simd") {
        if v == "auto" {
            simd::set_override(None);
        } else {
            let Some(level) = tetrajet::serve::SimdLevel::parse(v) else {
                bail!("unknown --simd level {v:?} (auto | off | ssse3 | avx2)");
            };
            simd::set_override(Some(level));
        }
    }
    loginfo!(
        "kernel dispatch: {} (detected {})",
        simd::active().as_str(),
        simd::detected().as_str()
    );
    Ok(())
}

/// Shared serving-config parsing: `serve` and `eval --packed` read the
/// same flag set through the same validating builder, so the two
/// subcommands cannot drift apart.
fn serve_cfg_from_args(args: &Args, default_micro: usize) -> Result<tetrajet::serve::ServeConfig> {
    apply_simd_override(args)?;
    tetrajet::serve::ServeConfig::builder()
        .micro_batch(args.get_usize("micro-batch", default_micro)?)
        .workers(args.get_usize("workers", tetrajet::util::parallel::default_workers())?)
        .engines(args.get_usize("engines", 1)?)
        .queue_depth(args.get_usize("queue-depth", 256)?)
        .build()
}

/// Manifest + checkpoint -> packed serving model; the path shared by
/// `eval --packed` and `serve` (no PJRT client, no HLO compilation).
fn load_packed_model(
    args: &Args,
) -> Result<(tetrajet::runtime::Manifest, tetrajet::serve::PackedVit, usize)> {
    let (root, model, batch) = base_paths(args);
    let variant = args.get_or("variant", "tetrajet").to_string();
    let Some(ckpt) = args.get("ckpt") else { bail!("--ckpt required") };
    let dir = tetrajet::runtime::artifacts::variant_dir(&root, &model, batch, &variant);
    let man = tetrajet::runtime::Manifest::load(&dir.join("manifest.json"))?;
    let (state, packed) =
        tetrajet::coordinator::TrainState::load_with_packed(std::path::Path::new(ckpt))?;
    loginfo!(
        "checkpoint step {}: {} params, {} packed segments",
        state.step,
        state.params.len(),
        packed.len()
    );
    let vit = tetrajet::serve::PackedVit::from_checkpoint(
        &man,
        &state.params,
        Some(&state.ema),
        &packed,
    )?;
    Ok((man, vit, state.step))
}

fn cmd_eval_packed(args: &Args) -> Result<()> {
    let (man, vit, step) = load_packed_model(args)?;
    let cfg = TrainConfig::default_run(&man.variant.name);
    let eval_samples = args.get_usize("eval-samples", 512)?;
    let ds = tetrajet::data::SynthVision::new(
        man.model.img,
        man.model.classes,
        cfg.data_seed,
        cfg.train_size,
        cfg.val_size,
    );
    let evalset = tetrajet::data::EvalSet::new(ds, man.batch, eval_samples);
    let scfg = serve_cfg_from_args(args, man.batch)?;
    if args.has_flag("verify-mirror") {
        // Interleaved per-batch verification: the mirror shares the
        // fused engine's activation-quant cache (its whole Q1 pass
        // replays as hits) and every batch's logits are compared
        // bitwise, not just the aggregate accuracy/loss.
        let mirror_model = vit.to_dense();
        let engine = tetrajet::serve::ServeEngine::new(vit, scfg)?;
        let mut mirror = tetrajet::serve::ServeEngine::new(mirror_model, scfg)?;
        mirror.share_act_cache(&engine);
        let classes = engine.classes();
        let (mut loss_sum, mut correct) = (0.0f64, 0.0f64);
        for b in 0..evalset.num_batches() {
            let (x, y) = evalset.batch(b);
            let fused = engine.eval_logits(&x, y.len());
            let dense = mirror.eval_logits(&x, y.len());
            if fused != dense {
                bail!("batch {b}: fused/packed logits != dequant-mirror logits");
            }
            let (ls, c) = tetrajet::serve::engine::batch_loss_correct(&fused, &y, classes);
            loss_sum += ls as f64;
            correct += c as f64;
        }
        let n = evalset.num_samples().max(1);
        let ev = tetrajet::coordinator::EvalResult {
            acc_pct: 100.0 * correct / n as f64,
            mean_loss: loss_sum / n as f64,
            samples: n,
        };
        let (hits, misses) = mirror.act_cache_stats();
        loginfo!(
            "verify-mirror: fused == dequant-then-matmul logits bit-exact over {} batches \
             (act-quant cache: {hits} hits / {misses} misses)",
            evalset.num_batches()
        );
        print_eval(&ev, step, "packed");
        return Ok(());
    }
    let engine = tetrajet::serve::ServeEngine::new(vit, scfg)?;
    let ev = engine.eval(&evalset);
    loginfo!(
        "resident quantized weights: {} B packed vs {} B f32 mirror",
        engine.resident_weight_bytes(),
        engine.model().f32_mirror_bytes()
    );
    print_eval(&ev, step, "packed");
    Ok(())
}

fn print_eval(ev: &tetrajet::coordinator::EvalResult, step: usize, tag: &str) {
    println!(
        "eval[{tag}]: top-1 {:.2}%  val-loss {:.4}  ({} samples, step {})",
        ev.acc_pct, ev.mean_loss, ev.samples, step
    );
}

fn cmd_eval(args: &Args) -> Result<()> {
    if args.has_flag("packed") {
        return cmd_eval_packed(args);
    }
    let (root, model, batch) = base_paths(args);
    let variant = args.get_or("variant", "tetrajet").to_string();
    let Some(ckpt) = args.get("ckpt") else { bail!("--ckpt required") };
    let client = tetrajet::runtime::cpu_client()?;
    let arts = ModelArtifacts::load(&client, &root, &model, batch, &variant)?;
    let state = tetrajet::coordinator::TrainState::load(std::path::Path::new(ckpt))?;
    let mut cfg = TrainConfig::default_run(&variant);
    cfg.model = model;
    cfg.batch = batch;
    cfg.eval_samples = args.get_usize("eval-samples", 512)?;
    let mut tr = Trainer::new(&arts, cfg, state.params.clone())?;
    tr.state = state;
    let ev = tr.eval()?;
    println!(
        "eval: top-1 {:.2}%  val-loss {:.4}  ({} samples, step {})",
        ev.acc_pct, ev.mean_loss, ev.samples, tr.state.step
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use tetrajet::serve::{
        ActQuant, LoadReport, LoadSpec, Outcome, Pace, PackedVit, ServeFleet, ServeGeom,
        WeightQuant,
    };
    use tetrajet::util::json::{num, obj, s, Json};
    use tetrajet::util::rng::Rng;

    let requests = args.get_usize("requests", 32)?;
    let request_size = args.get_usize("request-size", 4)?;
    if requests == 0 || request_size == 0 {
        bail!("--requests and --request-size must be >= 1");
    }
    let seed = args.get_u64("seed", 0)?;

    // Model: checkpoint-backed, or a seeded synthetic geometry — the
    // no-artifacts path `make loadtest-smoke` exercises.
    let (tag, vit, step, data) = match args.get("synthetic") {
        Some(name) => {
            let geom = match name {
                "tiny" => ServeGeom::new(16, 4, 32, 2, 4, 10, 4),
                "micro" => ServeGeom::new(32, 4, 64, 4, 4, 10, 4),
                other => bail!("unknown synthetic geometry {other:?} (tiny | micro)"),
            };
            let mut rng = Rng::new(seed).fold_in(0x4d4f44); // "MOD"
            let params: Vec<f32> =
                (0..geom.total_params()).map(|_| rng.normal() * 0.05).collect();
            let fmt = tetrajet::quant::e2m1();
            let scaling = tetrajet::quant::Scaling::TruncationFree;
            let vit = PackedVit::build(
                geom,
                &params,
                None,
                WeightQuant::Mx { fmt, scaling },
                ActQuant::Mx { fmt, scaling },
            )?;
            (format!("synthetic-{name}"), vit, 0usize, None)
        }
        None => {
            let (man, vit, step) = load_packed_model(args)?;
            let cfg = TrainConfig::default_run(&man.variant.name);
            let ds = tetrajet::data::SynthVision::new(
                man.model.img,
                man.model.classes,
                cfg.data_seed,
                cfg.train_size,
                cfg.val_size,
            );
            (man.variant.name.clone(), vit, step, Some((ds, cfg.val_size, man.batch)))
        }
    };

    let default_micro = data.as_ref().map_or(8, |(_, _, batch)| *batch);
    let scfg = serve_cfg_from_args(args, default_micro)?;
    let g = vit.geom.clone();
    let px = g.img * g.img * 3;
    let packed_bytes = vit.quantized_weight_bytes();
    let mirror_bytes = vit.f32_mirror_bytes();

    // Accuracy eval needs an unsharded engine; clone before the fleet
    // consumes the model into shards.
    let eval_samples = args.get_usize("eval-samples", if data.is_some() { 256 } else { 0 })?;
    let eval_engine = if eval_samples > 0 && data.is_some() {
        Some(tetrajet::serve::ServeEngine::new(vit.clone(), scfg)?)
    } else {
        None
    };

    let load_test = args.has_flag("load-test");
    let pace_name = args.get_or("pace", "real").to_string();
    let rate_rps = args.get_f32("rate", 64.0)? as f64;

    let mut fleet = ServeFleet::new(vit, scfg)?;
    // Observability wiring: a virtual-pace load test is fully
    // deterministic, so its trace must replay byte-identically — the
    // sink substitutes simulated durations for measured ones.
    let deterministic = load_test && pace_name == "virtual";
    if let Some(p) = args.get("trace-out") {
        fleet.set_trace(tetrajet::obs::TraceSink::to_file(
            std::path::Path::new(p),
            deterministic,
        )?);
        loginfo!("tracing to {p} (deterministic={deterministic})");
    }
    if let Some(every) = args.get("metrics-every") {
        fleet.set_snapshot_every(every.parse::<u64>()?);
    }
    if let Some(addr) = args.get("metrics-addr") {
        let bound = tetrajet::obs::spawn_metrics_endpoint(addr, fleet.registry().clone())?;
        loginfo!("metrics endpoint listening on {bound}");
    }
    loginfo!(
        "serving {tag} (step {step}): {} blocks, dim {}, {} engines x {} workers, \
         micro-batch {}, queue depth {}, {:.1} KiB packed shards ({:.1}x below the f32 mirror)",
        g.depth,
        g.dim,
        scfg.engines,
        scfg.workers,
        scfg.micro_batch,
        scfg.queue_depth,
        packed_bytes as f64 / 1024.0,
        mirror_bytes as f64 / packed_bytes.max(1) as f64
    );

    // Request factory: validation-split images with labels (checkpoint
    // mode) or seeded random pixels (synthetic mode). Either way the
    // i-th request is a pure function of (seed, i).
    let mut make_request: Box<dyn FnMut(usize) -> (Vec<f32>, Vec<i32>)> = match &data {
        Some((ds, val_size, _)) => {
            let val_size = *val_size;
            Box::new(move |i| {
                let mut imgs = vec![0.0f32; request_size * px];
                let mut ls = Vec::with_capacity(request_size);
                for k in 0..request_size {
                    ls.push(ds.sample_into(
                        tetrajet::data::Split::Val,
                        (i * request_size + k) % val_size,
                        &mut imgs[k * px..(k + 1) * px],
                    ));
                }
                (imgs, ls)
            })
        }
        None => {
            let base = Rng::new(seed).fold_in(0x494d47); // "IMG"
            Box::new(move |i| {
                let mut rng = base.fold_in(i as u64);
                let imgs = (0..request_size * px).map(|_| rng.uniform() * 2.0 - 1.0).collect();
                (imgs, Vec::new())
            })
        }
    };

    let report = if load_test {
        let pace = match pace_name.as_str() {
            "real" => Pace::Real,
            "virtual" => {
                Pace::Virtual { ms_per_image: args.get_f32("service-ms", 1.0)? as f64 }
            }
            other => bail!("unknown pace {other:?} (real | virtual)"),
        };
        let spec = LoadSpec {
            seed,
            requests,
            request_size,
            rate_rps,
            deadline_ms: args.get("deadline-ms").map(|v| v.parse::<f64>()).transpose()?,
            pace,
        };
        tetrajet::serve::run_load_test(&mut fleet, &spec, &mut *make_request)?
    } else {
        // Closed-loop replay: submit everything (draining ahead of the
        // bounded queue so nothing is rejected), then run dry.
        if request_size > scfg.queue_depth {
            bail!("--request-size {} exceeds --queue-depth {}", request_size, scfg.queue_depth);
        }
        let mut labels = std::collections::HashMap::new();
        for i in 0..requests {
            while fleet.pending_images() + request_size > scfg.queue_depth {
                fleet.step();
            }
            let (imgs, ls) = make_request(i);
            match fleet.submit(imgs, request_size, None) {
                Ok(t) => {
                    if !ls.is_empty() {
                        labels.insert(t.id, ls);
                    }
                }
                Err(e) => bail!("closed-loop submit failed: {e}"),
            }
        }
        let (mut completed, mut correct, mut labeled) = (0usize, 0usize, 0usize);
        for o in fleet.wait_all() {
            if let Outcome::Done(r) = o {
                completed += 1;
                if let Some(y) = labels.get(&r.id) {
                    labeled += y.len();
                    correct +=
                        r.preds.iter().zip(y).filter(|(&p, &l)| p == l as usize).count();
                }
            }
        }
        LoadReport {
            summary: fleet.stats(),
            accepted: requests,
            rejected: 0,
            expired: 0,
            completed,
            correct,
            labeled,
        }
    };
    drop(make_request);

    let st = &report.summary;
    println!(
        "serve[{tag}]: {} engines  {} requests ({} accepted, {} rejected, {} expired) \
         -> {:.1} imgs/s over {:.1} ms wall",
        scfg.engines,
        requests,
        report.accepted,
        report.rejected,
        report.expired,
        st.imgs_per_sec(),
        st.wall_ms,
    );
    println!(
        "serve[{tag}]: latency p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  max {:.2} ms  \
         ({} images in {} micro-batches, {:.1} ms compute)",
        st.p50_ms, st.p95_ms, st.p99_ms, st.max_ms, st.images, st.batches, st.busy_ms,
    );
    if report.labeled > 0 {
        println!(
            "serve[{tag}]: top-1 {:.2}% over {} labeled request images",
            100.0 * report.correct as f64 / report.labeled as f64,
            report.labeled
        );
    }

    if load_test {
        let mut fields = vec![
            ("case", s("serve-load")),
            ("model", s(&tag)),
            ("engines", num(scfg.engines as f64)),
            ("micro_batch", num(scfg.micro_batch as f64)),
            ("queue_depth", num(scfg.queue_depth as f64)),
            ("request_size", num(request_size as f64)),
            ("rate_rps", num(rate_rps)),
            ("pace", s(&pace_name)),
            ("seed", num(seed as f64)),
            ("accepted", num(report.accepted as f64)),
        ];
        fields.extend(st.fields());
        let entry = obj(fields);
        println!("BENCH {}", entry.to_string());

        let pr = args.get_u64("bench-pr", 9)?;
        let default_out = format!("results/BENCH_{pr}.json");
        let out = std::path::PathBuf::from(args.get_or("bench-out", &default_out));
        let dir = out.parent().map(std::path::Path::to_path_buf).unwrap_or_default();
        let prev = tetrajet::util::benchio::find_previous(&dir, pr);
        tetrajet::util::benchio::merge_bench(&out, pr, vec![entry.clone()])?;
        loginfo!("BENCH json written to {}", out.display());
        if let Some((ppath, pdoc)) = prev {
            let cur = obj(vec![("pr", num(pr as f64)), ("entries", Json::Arr(vec![entry]))]);
            let tol = args.get_f32("gate-tol", 0.10)? as f64;
            let flags = tetrajet::util::benchio::compare(&pdoc, &cur, tol);
            for f in &flags {
                println!("BENCH-REGRESSION: {f} (vs {})", ppath.display());
            }
            if !flags.is_empty() && args.has_flag("strict-gate") {
                bail!(
                    "{} perf regression(s) beyond the {:.0}% gate",
                    flags.len(),
                    tol * 100.0
                );
            }
        }
    }

    if let Some(mut sink) = fleet.take_trace() {
        let events = sink.events();
        let digest = sink.digest();
        sink.finish()?;
        println!("TRACE events={events} digest={digest}");
    }
    if let Some(p) = args.get("metrics-out") {
        let path = std::path::Path::new(p);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, fleet.registry().snapshot_json().to_string() + "\n")?;
        loginfo!("metrics snapshot written to {p}");
    }

    if let (Some(engine), Some((ds, _, batch))) = (eval_engine, data) {
        let evalset = tetrajet::data::EvalSet::new(ds, batch, eval_samples);
        let ev = engine.eval(&evalset);
        print_eval(&ev, step, "serve");
    }
    Ok(())
}

/// `tetrajet report`: replay an OSCLOG01 artifact offline into the
/// paper's per-layer oscillation diagnostics. Pure function of the
/// artifact bytes — markdown to stdout, optional OSCREPORT01 json.
fn cmd_report(args: &Args) -> Result<()> {
    use tetrajet::report;
    let Some(p) = args.get("osclog") else { bail!("report needs --osclog PATH") };
    let top = args.get_usize("top", 10)?;
    let log = report::load_osclog(std::path::Path::new(p))?;
    let rep = report::analyze(&log, top);
    let mut md = rep.to_markdown();
    if let Some(p2) = args.get("compare") {
        let other = report::analyze(&report::load_osclog(std::path::Path::new(p2))?, top);
        md.push('\n');
        md.push_str(&report::compare_markdown(&rep, &other));
    }
    print!("{md}");
    if let Some(out) = args.get("json") {
        let path = std::path::Path::new(out);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        // No loginfo here: stdout is the markdown report (often
        // redirected to a file), so nothing else may land on it.
        std::fs::write(path, rep.to_json().to_string() + "\n")?;
    }
    Ok(())
}

/// Validate observability artifacts written by `serve`/`train`: a
/// Chrome trace-event JSONL (`--trace`), a metrics snapshot json
/// (`--snapshot`), an OSCLOG01 telemetry artifact (`--osclog`) and/or
/// an OSCREPORT01 json (`--report`). Exits nonzero on any schema
/// violation, which is what `make obs-smoke`/`report-smoke` gate on.
fn cmd_obs_validate(args: &Args) -> Result<()> {
    use tetrajet::util::json::Json;

    let mut checked = false;
    if let Some(p) = args.get("trace") {
        checked = true;
        let text = std::fs::read_to_string(p)?;
        let mut digest = tetrajet::obs::TraceDigest::new();
        let mut events = 0usize;
        for (lineno, line) in text.lines().enumerate() {
            if line.is_empty() {
                continue;
            }
            let ev = Json::parse(line)
                .map_err(|e| anyhow::anyhow!("{p}:{}: bad json: {e}", lineno + 1))?;
            let ph = ev
                .get("ph")
                .ok_or_else(|| anyhow::anyhow!("{p}:{}: missing ph", lineno + 1))?
                .as_str()?
                .to_string();
            if ph != "X" && ph != "i" {
                bail!("{p}:{}: unknown phase {ph:?}", lineno + 1);
            }
            for key in ["name", "ts", "pid", "tid"] {
                if ev.get(key).is_none() {
                    bail!("{p}:{}: missing {key}", lineno + 1);
                }
            }
            if ev.get("ts").unwrap().as_f64()? < 0.0 {
                bail!("{p}:{}: negative ts", lineno + 1);
            }
            if ph == "X" {
                let dur = ev
                    .get("dur")
                    .ok_or_else(|| anyhow::anyhow!("{p}:{}: X event missing dur", lineno + 1))?
                    .as_f64()?;
                if dur < 0.0 {
                    bail!("{p}:{}: negative dur", lineno + 1);
                }
            }
            digest.update(line.as_bytes());
            digest.update(b"\n");
            events += 1;
        }
        if events == 0 {
            bail!("{p}: trace contains no events");
        }
        println!("obs-validate[trace]: {events} events, digest {}", digest.hex());
    }
    if let Some(p) = args.get("snapshot") {
        checked = true;
        let doc = Json::parse(&std::fs::read_to_string(p)?)?;
        for section in ["counters", "gauges", "hists", "series", "rings"] {
            if doc.get(section).is_none() {
                bail!("{p}: snapshot missing section {section:?}");
            }
        }
        let require = |section: &str, name: &str| -> Result<()> {
            let sec = doc.get(section).unwrap();
            if sec.get(name).is_none() {
                bail!("{p}: snapshot missing {section}.{name}");
            }
            Ok(())
        };
        for name in [
            "sched.admits",
            "sched.rejects",
            "sched.expiries",
            "serve.images",
            "serve.batches",
            "serve.busy_ms",
            "fleet.steps",
            "fleet.gather_wait_ms",
            "kernel.qkv.calls",
            "kernel.actq.hits",
            "kernel.actq.misses",
        ] {
            require("counters", name)?;
        }
        require("gauges", "sched.queue_depth")?;
        require("gauges", "kernel.dispatch_level")?;
        require("hists", "fleet.batch_images")?;
        require("series", "serve.latency_ms")?;
        println!("obs-validate[snapshot]: schema ok ({p})");
    }
    if let Some(p) = args.get("osclog") {
        checked = true;
        // The loader already enforces header schema, contiguous segment
        // tiling, per-record array lengths and osc-sum consistency.
        let log = tetrajet::report::load_osclog(std::path::Path::new(p))?;
        let mut prev: Option<usize> = None;
        for st in &log.steps {
            if prev.is_some_and(|q| st.t <= q) {
                bail!("{p}: step ids not strictly increasing at t={}", st.t);
            }
            prev = Some(st.t);
        }
        let mut prev_w: Option<usize> = None;
        for w in &log.windows {
            if prev_w.is_some_and(|q| w.step <= q) {
                bail!("{p}: window_end not strictly increasing at {}", w.step);
            }
            prev_w = Some(w.step);
            for (k, seg) in w.osc.iter().zip(&log.segments) {
                if *k as usize > seg.size {
                    bail!("{p}: window at {} counts {k} oscillating in {:?} (size {})",
                        w.step, seg.name, seg.size);
                }
            }
        }
        println!(
            "obs-validate[osclog]: {} segments, {} steps, {} windows, digest {}",
            log.segments.len(),
            log.steps.len(),
            log.windows.len(),
            log.digest
        );
    }
    if let Some(p) = args.get("report") {
        checked = true;
        let doc = Json::parse(&std::fs::read_to_string(p)?)?;
        let fmt = doc.req("format")?.as_str()?;
        if fmt != tetrajet::report::REPORT_FORMAT {
            bail!("{p}: unknown report format {fmt:?}");
        }
        for key in [
            "log_digest",
            "osc_fraction",
            "osc_count",
            "steps",
            "windows",
            "top",
            "by_depth",
            "by_kind",
            "segments",
        ] {
            if doc.get(key).is_none() {
                bail!("{p}: report missing {key:?}");
            }
        }
        println!("obs-validate[report]: schema ok ({p})");
    }
    if !checked {
        bail!("obs-validate needs --trace / --snapshot / --osclog / --report PATH");
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let Some(id) = args.positional.first() else {
        bail!("usage: tetrajet exp <table1..table7|fig2..fig6|all> [--quick]")
    };
    let mut opts = ExpOpts::new(args.has_flag("quick"));
    let (root, model, batch) = base_paths(args);
    opts.root = root;
    opts.model = model;
    opts.batch = batch;
    opts.steps = args.get_usize("steps", opts.steps)?;
    opts.eval_samples = args.get_usize("eval-samples", opts.eval_samples)?;
    if let Some(r) = args.get("results") {
        opts.results = std::path::PathBuf::from(r);
    }
    experiments::run(id, &opts)
}
