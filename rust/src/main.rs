//! `tetrajet` — leader binary: train / eval / experiment harness CLI.
//!
//! The binary is self-contained once `make artifacts` has produced the
//! AOT HLO artifacts; Python never runs on the training path.

use anyhow::{bail, Result};
use tetrajet::config::{MetricsCfg, Policy, TrainConfig};
use tetrajet::coordinator::Trainer;
use tetrajet::experiments::{self, common::ExpOpts};
use tetrajet::loginfo;
use tetrajet::runtime::{artifacts, ModelArtifacts};
use tetrajet::util::cli::Args;

const USAGE: &str = "\
tetrajet — Oscillation-Reduced MXFP4 Training (TetraJet, ICML 2025)

subcommands:
  train          train one configuration
  eval           evaluate a checkpoint
  exp <id>       run an experiment harness (table1..table7, fig2..fig6, all)
  list-variants  print all known method variants
  help           this text

common options:
  --artifacts DIR   artifacts root (default: artifacts/, or $TETRAJET_ARTIFACTS)
  --model NAME      model config (default vit-micro)
  --batch N         batch size baked into the artifacts (default 16)

train options:
  --variant NAME    method variant (default tetrajet)
  --policy NAME     none | qramping | dampen | freeze (default none)
  --steps N         training steps (default 400)
  --lr F            base learning rate (default 1e-3)
  --ema-beta F      Q-EMA momentum (default 0.998)
  --dampen-lambda F Dampen strength (default 1e-4, with --policy dampen)
  --k1 F --k2 F     Q-Ramping coefficients (defaults 16, 5)
  --eval-every N    evaluate every N steps (default 0 = end only)
  --eval-samples N  validation samples (default 512)
  --seed N          init seed (default 0)
  --ckpt-out PATH   save final checkpoint
  --metrics LEVEL   off | standard | full (default off)

eval options:
  --variant NAME    method variant artifact to evaluate with
  --ckpt PATH       checkpoint produced by train --ckpt-out

exp options:
  --quick           reduced steps/eval for smoke runs
  --steps N         override steps per run
  --results DIR     results output dir (default results/)
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_policy(args: &Args) -> Result<Policy> {
    Ok(match args.get_or("policy", "none") {
        "none" => Policy::None,
        "qramping" => {
            let mut p = Policy::qramping_default();
            if let Policy::QRamping { k1, k2, .. } = &mut p {
                *k1 = args.get_f32("k1", *k1)?;
                *k2 = args.get_f32("k2", *k2)?;
            }
            p
        }
        "dampen" => Policy::Dampen { lambda: args.get_f32("dampen-lambda", 1e-4)? },
        "freeze" => Policy::freeze_default(),
        other => bail!("unknown policy {other:?}"),
    })
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        "list-variants" => {
            for v in tetrajet::config::all_variants() {
                println!("{v}");
            }
            Ok(())
        }
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "exp" => cmd_exp(&args),
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn base_paths(args: &Args) -> (std::path::PathBuf, String, usize) {
    let root = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts::default_root);
    let model = args.get_or("model", "vit-micro").to_string();
    let batch = args.get_usize("batch", 16).unwrap_or(16);
    (root, model, batch)
}

fn cmd_train(args: &Args) -> Result<()> {
    let (root, model, batch) = base_paths(args);
    let variant = args.get_or("variant", "tetrajet").to_string();
    let client = tetrajet::runtime::cpu_client()?;
    loginfo!("loading artifacts {model}/b{batch}/{variant}");
    let arts = ModelArtifacts::load(&client, &root, &model, batch, &variant)?;

    let mut cfg = TrainConfig::default_run(&variant);
    cfg.model = model.clone();
    cfg.batch = batch;
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.warmup = (cfg.steps / 10).max(1);
    cfg.base_lr = args.get_f32("lr", cfg.base_lr)?;
    cfg.ema_beta = args.get_f32("ema-beta", cfg.ema_beta)?;
    cfg.eval_every = args.get_usize("eval-every", 0)?;
    cfg.eval_samples = args.get_usize("eval-samples", cfg.eval_samples)?;
    cfg.init_seed = args.get_usize("seed", 0)? as i32;
    cfg.policy = parse_policy(args)?;
    cfg.metrics = match args.get_or("metrics", "off") {
        "off" => MetricsCfg::off(),
        "standard" => MetricsCfg::standard(),
        "full" => MetricsCfg::full(),
        other => bail!("unknown metrics level {other:?}"),
    };
    loginfo!("config: {}", cfg.to_json().to_string());

    let params = artifacts::run_init(&client, &root, &model, cfg.init_seed)?;
    let ckpt_out = args.get("ckpt-out").map(std::path::PathBuf::from);
    let mut tr = Trainer::new(&arts, cfg, params)?;
    let ev = tr.run()?;
    println!(
        "final: top-1 {:.2}%  val-loss {:.4}  ({} samples)",
        ev.acc_pct, ev.mean_loss, ev.samples
    );
    if let Some(p) = ckpt_out {
        tr.state.save(&p)?;
        loginfo!("checkpoint saved to {}", p.display());
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let (root, model, batch) = base_paths(args);
    let variant = args.get_or("variant", "tetrajet").to_string();
    let Some(ckpt) = args.get("ckpt") else { bail!("--ckpt required") };
    let client = tetrajet::runtime::cpu_client()?;
    let arts = ModelArtifacts::load(&client, &root, &model, batch, &variant)?;
    let state = tetrajet::coordinator::TrainState::load(std::path::Path::new(ckpt))?;
    let mut cfg = TrainConfig::default_run(&variant);
    cfg.model = model;
    cfg.batch = batch;
    cfg.eval_samples = args.get_usize("eval-samples", 512)?;
    let mut tr = Trainer::new(&arts, cfg, state.params.clone())?;
    tr.state = state;
    let ev = tr.eval()?;
    println!(
        "eval: top-1 {:.2}%  val-loss {:.4}  ({} samples, step {})",
        ev.acc_pct, ev.mean_loss, ev.samples, tr.state.step
    );
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let Some(id) = args.positional.first() else {
        bail!("usage: tetrajet exp <table1..table7|fig2..fig6|all> [--quick]")
    };
    let mut opts = ExpOpts::new(args.has_flag("quick"));
    let (root, model, batch) = base_paths(args);
    opts.root = root;
    opts.model = model;
    opts.batch = batch;
    opts.steps = args.get_usize("steps", opts.steps)?;
    opts.eval_samples = args.get_usize("eval-samples", opts.eval_samples)?;
    if let Some(r) = args.get("results") {
        opts.results = std::path::PathBuf::from(r);
    }
    experiments::run(id, &opts)
}
