//! `tetrajet` — leader binary: train / eval / experiment harness CLI.
//!
//! The binary is self-contained once `make artifacts` has produced the
//! AOT HLO artifacts; Python never runs on the training path.

use anyhow::{bail, Result};
use tetrajet::config::{MetricsCfg, Policy, TrainConfig};
use tetrajet::coordinator::Trainer;
use tetrajet::experiments::{self, common::ExpOpts};
use tetrajet::loginfo;
use tetrajet::runtime::{artifacts, ModelArtifacts};
use tetrajet::util::cli::Args;

const USAGE: &str = "\
tetrajet — Oscillation-Reduced MXFP4 Training (TetraJet, ICML 2025)

subcommands:
  train          train one configuration
  eval           evaluate a checkpoint
  serve          packed-native inference over a checkpoint (no XLA)
  exp <id>       run an experiment harness (table1..table7, fig2..fig6, all)
  list-variants  print all known method variants
  help           this text

common options:
  --artifacts DIR   artifacts root (default: artifacts/, or $TETRAJET_ARTIFACTS)
  --model NAME      model config (default vit-micro)
  --batch N         batch size baked into the artifacts (default 16)

train options:
  --variant NAME    method variant (default tetrajet)
  --policy NAME     none | qramping | dampen | freeze (default none)
  --steps N         training steps (default 400)
  --lr F            base learning rate (default 1e-3)
  --ema-beta F      Q-EMA momentum (default 0.998)
  --dampen-lambda F Dampen strength (default 1e-4, with --policy dampen)
  --k1 F --k2 F     Q-Ramping coefficients (defaults 16, 5)
  --eval-every N    evaluate every N steps (default 0 = end only)
  --eval-samples N  validation samples (default 512)
  --seed N          init seed (default 0)
  --ckpt-out PATH   save final checkpoint
  --ckpt-packed     write a TJCKPT02 checkpoint carrying the packed
                    4-bit quant mirror (input of `serve`/`eval --packed`)
  --metrics LEVEL   off | standard | full (default off)

eval options:
  --variant NAME    method variant artifact to evaluate with
  --ckpt PATH       checkpoint produced by train --ckpt-out
  --packed          evaluate through the packed serving engine (fused
                    dequant-matmul over codes; needs only the manifest,
                    not the compiled HLO)
  --verify-mirror   with --packed: also run the dequantize-then-matmul
                    mirror and assert bit-identical accuracy/loss

serve options:
  --ckpt PATH       checkpoint (TJCKPT02 serves codes directly;
                    TJCKPT01 re-quantizes the f32 params)
  --variant NAME    manifest to take geometry/recipe from
  --requests N      synthetic request count (default 32)
  --request-size N  images per request (default 4)
  --micro-batch N   engine micro-batch (default: artifact batch)
  --workers N       kernel worker threads (default: half the cores)
  --eval-samples N  also report accuracy on N val samples (default 256)

exp options:
  --quick           reduced steps/eval for smoke runs
  --steps N         override steps per run
  --results DIR     results output dir (default results/)
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_policy(args: &Args) -> Result<Policy> {
    Ok(match args.get_or("policy", "none") {
        "none" => Policy::None,
        "qramping" => {
            let mut p = Policy::qramping_default();
            if let Policy::QRamping { k1, k2, .. } = &mut p {
                *k1 = args.get_f32("k1", *k1)?;
                *k2 = args.get_f32("k2", *k2)?;
            }
            p
        }
        "dampen" => Policy::Dampen { lambda: args.get_f32("dampen-lambda", 1e-4)? },
        "freeze" => Policy::freeze_default(),
        other => bail!("unknown policy {other:?}"),
    })
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        "list-variants" => {
            for v in tetrajet::config::all_variants() {
                println!("{v}");
            }
            Ok(())
        }
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "exp" => cmd_exp(&args),
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn base_paths(args: &Args) -> (std::path::PathBuf, String, usize) {
    let root = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts::default_root);
    let model = args.get_or("model", "vit-micro").to_string();
    let batch = args.get_usize("batch", 16).unwrap_or(16);
    (root, model, batch)
}

fn cmd_train(args: &Args) -> Result<()> {
    let (root, model, batch) = base_paths(args);
    let variant = args.get_or("variant", "tetrajet").to_string();
    let client = tetrajet::runtime::cpu_client()?;
    loginfo!("loading artifacts {model}/b{batch}/{variant}");
    let arts = ModelArtifacts::load(&client, &root, &model, batch, &variant)?;

    let mut cfg = TrainConfig::default_run(&variant);
    cfg.model = model.clone();
    cfg.batch = batch;
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.warmup = (cfg.steps / 10).max(1);
    cfg.base_lr = args.get_f32("lr", cfg.base_lr)?;
    cfg.ema_beta = args.get_f32("ema-beta", cfg.ema_beta)?;
    cfg.eval_every = args.get_usize("eval-every", 0)?;
    cfg.eval_samples = args.get_usize("eval-samples", cfg.eval_samples)?;
    cfg.init_seed = args.get_usize("seed", 0)? as i32;
    cfg.policy = parse_policy(args)?;
    cfg.metrics = match args.get_or("metrics", "off") {
        "off" => MetricsCfg::off(),
        "standard" => MetricsCfg::standard(),
        "full" => MetricsCfg::full(),
        other => bail!("unknown metrics level {other:?}"),
    };
    loginfo!("config: {}", cfg.to_json().to_string());

    let params = artifacts::run_init(&client, &root, &model, cfg.init_seed)?;
    let ckpt_out = args.get("ckpt-out").map(std::path::PathBuf::from);
    if args.has_flag("ckpt-packed") && ckpt_out.is_none() {
        bail!("--ckpt-packed requires --ckpt-out PATH");
    }
    let mut tr = Trainer::new(&arts, cfg, params)?;
    let ev = tr.run()?;
    println!(
        "final: top-1 {:.2}%  val-loss {:.4}  ({} samples)",
        ev.acc_pct, ev.mean_loss, ev.samples
    );
    if let Some(p) = ckpt_out {
        if args.has_flag("ckpt-packed") {
            tr.save_packed_checkpoint(&p)?;
            loginfo!("packed checkpoint (TJCKPT02) saved to {}", p.display());
        } else {
            tr.state.save(&p)?;
            loginfo!("checkpoint saved to {}", p.display());
        }
    }
    Ok(())
}

/// Manifest + checkpoint -> packed serving model; the path shared by
/// `eval --packed` and `serve` (no PJRT client, no HLO compilation).
fn load_packed_model(
    args: &Args,
) -> Result<(tetrajet::runtime::Manifest, tetrajet::serve::PackedVit, usize)> {
    let (root, model, batch) = base_paths(args);
    let variant = args.get_or("variant", "tetrajet").to_string();
    let Some(ckpt) = args.get("ckpt") else { bail!("--ckpt required") };
    let dir = tetrajet::runtime::artifacts::variant_dir(&root, &model, batch, &variant);
    let man = tetrajet::runtime::Manifest::load(&dir.join("manifest.json"))?;
    let (state, packed) =
        tetrajet::coordinator::TrainState::load_with_packed(std::path::Path::new(ckpt))?;
    loginfo!(
        "checkpoint step {}: {} params, {} packed segments",
        state.step,
        state.params.len(),
        packed.len()
    );
    let vit = tetrajet::serve::PackedVit::from_checkpoint(
        &man,
        &state.params,
        Some(&state.ema),
        &packed,
    )?;
    Ok((man, vit, state.step))
}

fn cmd_eval_packed(args: &Args) -> Result<()> {
    let (man, vit, step) = load_packed_model(args)?;
    let cfg = TrainConfig::default_run(&man.variant.name);
    let eval_samples = args.get_usize("eval-samples", 512)?;
    let ds = tetrajet::data::SynthVision::new(
        man.model.img,
        man.model.classes,
        cfg.data_seed,
        cfg.train_size,
        cfg.val_size,
    );
    let evalset = tetrajet::data::EvalSet::new(ds, man.batch, eval_samples);
    let scfg = tetrajet::serve::ServeConfig {
        micro_batch: man.batch,
        workers: args.get_usize("workers", tetrajet::util::parallel::default_workers())?,
    };
    if args.has_flag("verify-mirror") {
        let mirror = tetrajet::serve::ServeEngine::new(vit.to_dense(), scfg)?;
        let em = mirror.eval(&evalset);
        let engine = tetrajet::serve::ServeEngine::new(vit, scfg)?;
        let ev = engine.eval(&evalset);
        if (ev.acc_pct, ev.mean_loss) != (em.acc_pct, em.mean_loss) {
            bail!(
                "fused/packed eval ({:.4}%, {:.6}) != dequant-mirror eval ({:.4}%, {:.6})",
                ev.acc_pct,
                ev.mean_loss,
                em.acc_pct,
                em.mean_loss
            );
        }
        loginfo!("verify-mirror: fused == dequant-then-matmul (bit-exact)");
        print_eval(&ev, step, "packed");
        return Ok(());
    }
    let engine = tetrajet::serve::ServeEngine::new(vit, scfg)?;
    let ev = engine.eval(&evalset);
    loginfo!(
        "resident quantized weights: {} B packed vs {} B f32 mirror",
        engine.resident_weight_bytes(),
        engine.model().f32_mirror_bytes()
    );
    print_eval(&ev, step, "packed");
    Ok(())
}

fn print_eval(ev: &tetrajet::coordinator::EvalResult, step: usize, tag: &str) {
    println!(
        "eval[{tag}]: top-1 {:.2}%  val-loss {:.4}  ({} samples, step {})",
        ev.acc_pct, ev.mean_loss, ev.samples, step
    );
}

fn cmd_eval(args: &Args) -> Result<()> {
    if args.has_flag("packed") {
        return cmd_eval_packed(args);
    }
    let (root, model, batch) = base_paths(args);
    let variant = args.get_or("variant", "tetrajet").to_string();
    let Some(ckpt) = args.get("ckpt") else { bail!("--ckpt required") };
    let client = tetrajet::runtime::cpu_client()?;
    let arts = ModelArtifacts::load(&client, &root, &model, batch, &variant)?;
    let state = tetrajet::coordinator::TrainState::load(std::path::Path::new(ckpt))?;
    let mut cfg = TrainConfig::default_run(&variant);
    cfg.model = model;
    cfg.batch = batch;
    cfg.eval_samples = args.get_usize("eval-samples", 512)?;
    let mut tr = Trainer::new(&arts, cfg, state.params.clone())?;
    tr.state = state;
    let ev = tr.eval()?;
    println!(
        "eval: top-1 {:.2}%  val-loss {:.4}  ({} samples, step {})",
        ev.acc_pct, ev.mean_loss, ev.samples, tr.state.step
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (man, vit, step) = load_packed_model(args)?;
    let requests = args.get_usize("requests", 32)?;
    let request_size = args.get_usize("request-size", 4)?;
    if requests == 0 || request_size == 0 {
        bail!("--requests and --request-size must be >= 1");
    }
    let scfg = tetrajet::serve::ServeConfig {
        micro_batch: args.get_usize("micro-batch", man.batch)?,
        workers: args.get_usize("workers", tetrajet::util::parallel::default_workers())?,
    };
    let packed_bytes = vit.quantized_weight_bytes();
    let mirror_bytes = vit.f32_mirror_bytes();
    let engine = tetrajet::serve::ServeEngine::new(vit, scfg)?;
    loginfo!(
        "serving {} (step {}): {} blocks, dim {}, micro-batch {}, {} workers, \
         {:.1} KiB packed weights ({:.1}x below the f32 mirror)",
        man.variant.name,
        step,
        man.model.depth,
        man.model.dim,
        scfg.micro_batch,
        scfg.workers,
        packed_bytes as f64 / 1024.0,
        mirror_bytes as f64 / packed_bytes.max(1) as f64
    );

    // Synthetic request stream drawn from the validation split.
    let cfg = TrainConfig::default_run(&man.variant.name);
    let ds = tetrajet::data::SynthVision::new(
        man.model.img,
        man.model.classes,
        cfg.data_seed,
        cfg.train_size,
        cfg.val_size,
    );
    let px = engine.pixels_per_image();
    let mut session = tetrajet::serve::ServeSession::new(engine);
    let mut labels: Vec<Vec<i32>> = Vec::with_capacity(requests);
    let mut idx = 0usize;
    for _ in 0..requests {
        let mut imgs = vec![0.0f32; request_size * px];
        let mut ls = Vec::with_capacity(request_size);
        for i in 0..request_size {
            ls.push(ds.sample_into(
                tetrajet::data::Split::Val,
                idx % cfg.val_size,
                &mut imgs[i * px..(i + 1) * px],
            ));
            idx += 1;
        }
        labels.push(ls);
        session.submit(imgs, request_size)?;
    }
    let responses = session.flush();
    let mut correct = 0usize;
    for (r, ls) in responses.iter().zip(&labels) {
        for (&pred, &label) in r.preds.iter().zip(ls.iter()) {
            if pred == label as usize {
                correct += 1;
            }
        }
    }
    let st = session.stats();
    println!(
        "serve: {} requests x {} imgs in {:.1} ms -> {:.1} imgs/s  \
         latency p50 {:.2} ms  p95 {:.2} ms  max {:.2} ms",
        st.requests,
        request_size,
        st.wall_ms,
        st.imgs_per_sec(),
        st.latency_pct_ms(0.5),
        st.latency_pct_ms(0.95),
        st.latency_pct_ms(1.0),
    );
    println!(
        "serve: top-1 {:.2}% over the {} request images ({} micro-batches)",
        100.0 * correct as f64 / st.images.max(1) as f64,
        st.images,
        st.batches
    );
    let eval_samples = args.get_usize("eval-samples", 256)?;
    if eval_samples > 0 {
        let evalset = tetrajet::data::EvalSet::new(ds, man.batch, eval_samples);
        let ev = session.engine().eval(&evalset);
        print_eval(&ev, step, "serve");
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let Some(id) = args.positional.first() else {
        bail!("usage: tetrajet exp <table1..table7|fig2..fig6|all> [--quick]")
    };
    let mut opts = ExpOpts::new(args.has_flag("quick"));
    let (root, model, batch) = base_paths(args);
    opts.root = root;
    opts.model = model;
    opts.batch = batch;
    opts.steps = args.get_usize("steps", opts.steps)?;
    opts.eval_samples = args.get_usize("eval-samples", opts.eval_samples)?;
    if let Some(r) = args.get("results") {
        opts.results = std::path::PathBuf::from(r);
    }
    experiments::run(id, &opts)
}
