//! Typed latency accounting shared by the session, the fleet, and the
//! serve bench — one percentile implementation, one JSON field set.
//!
//! Since PR 7 the accumulator is not a parallel data structure: a
//! [`LatencyRecorder`] is a bundle of [`crate::obs::MetricsRegistry`]
//! handles (`{prefix}.latency_ms`, `{prefix}.images`, …), and
//! [`LatencySummary::from_registry`] derives the end-of-run snapshot
//! from those same cells. Session, fleet, bench harness, and the load
//! generator therefore all emit byte-identical schemas *and* the same
//! numbers a live `--metrics-addr` scrape would show.

use crate::obs::{Counter, FCounter, Gauge, MetricsRegistry, Series};
use crate::util::json::{num, obj, Json};
use crate::util::stats::{mean, percentile};

/// Snapshot of a serving run's request/latency distribution.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LatencySummary {
    /// Completed requests (one latency sample each).
    pub count: usize,
    /// Images inferred across all micro-batches.
    pub images: usize,
    /// Micro-batches executed.
    pub batches: usize,
    /// Requests rejected by queue-depth backpressure.
    pub rejected: usize,
    /// Requests expired by their deadline before any chunk ran.
    pub expired: usize,
    /// Wall-clock span from first arrival to last completion.
    pub wall_ms: f64,
    /// Summed forward compute time (excludes queueing).
    pub busy_ms: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    /// Derive a summary from the `{prefix}.*` metrics of `reg` — the
    /// seam that makes session/fleet/bench stats one view of the
    /// registry. Metrics that were never registered read as zero.
    pub fn from_registry(reg: &MetricsRegistry, prefix: &str) -> LatencySummary {
        let lat = reg.series(&format!("{prefix}.latency_ms"));
        // The series is a bounded ring: `count` is the total ever
        // recorded, the percentiles run over the retained window.
        let xs = lat.values();
        let first = reg.gauge(&format!("{prefix}.first_arrival_ms")).get_opt();
        let last = reg.gauge(&format!("{prefix}.last_done_ms")).get_opt();
        LatencySummary {
            count: lat.count() as usize,
            images: reg.counter(&format!("{prefix}.images")).get() as usize,
            batches: reg.counter(&format!("{prefix}.batches")).get() as usize,
            rejected: reg.counter(&format!("{prefix}.rejected")).get() as usize,
            expired: reg.counter(&format!("{prefix}.expired")).get() as usize,
            wall_ms: match (first, last) {
                (Some(f), Some(l)) => l - f,
                _ => 0.0,
            },
            busy_ms: reg.fcounter(&format!("{prefix}.busy_ms")).get(),
            mean_ms: mean(&xs),
            p50_ms: percentile(&xs, 50.0),
            p95_ms: percentile(&xs, 95.0),
            p99_ms: percentile(&xs, 99.0),
            max_ms: xs.iter().fold(0.0f64, |a, &b| a.max(b)),
        }
    }

    /// Serving throughput over the wall-clock span (0 for empty runs).
    pub fn imgs_per_sec(&self) -> f64 {
        if self.wall_ms > 0.0 {
            self.images as f64 / (self.wall_ms / 1e3)
        } else {
            0.0
        }
    }

    /// The BENCH json fields, in the schema order every emitter shares.
    pub fn fields(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("requests", num(self.count as f64)),
            ("images", num(self.images as f64)),
            ("batches", num(self.batches as f64)),
            ("rejected", num(self.rejected as f64)),
            ("expired", num(self.expired as f64)),
            ("wall_ms", num(self.wall_ms)),
            ("busy_ms", num(self.busy_ms)),
            ("imgs_per_s", num(self.imgs_per_sec())),
            ("mean_ms", num(self.mean_ms)),
            ("p50_ms", num(self.p50_ms)),
            ("p95_ms", num(self.p95_ms)),
            ("p99_ms", num(self.p99_ms)),
            ("max_ms", num(self.max_ms)),
        ]
    }

    pub fn to_json(&self) -> Json {
        obj(self.fields())
    }
}

/// PR 5's stats type, kept as an alias so old callers compile.
#[deprecated(note = "use LatencySummary (the typed percentile snapshot)")]
pub type SessionStats = LatencySummary;

/// Serving-loop accumulator: a bundle of registry handles under one
/// name prefix. Cloning shares the underlying cells.
#[derive(Debug, Clone)]
pub struct LatencyRecorder {
    reg: MetricsRegistry,
    prefix: String,
    latencies: Series,
    images: Counter,
    batches: Counter,
    rejected: Counter,
    expired: Counter,
    busy_ms: FCounter,
    first: Gauge,
    last: Gauge,
}

impl Default for LatencyRecorder {
    /// Standalone recorder over a private registry (bench harness,
    /// tests) under the `serve` prefix.
    fn default() -> LatencyRecorder {
        LatencyRecorder::in_registry(&MetricsRegistry::new(), "serve")
    }
}

impl LatencyRecorder {
    /// Register the recorder's metrics in `reg` under
    /// `{prefix}.latency_ms` / `.images` / `.batches` / `.rejected` /
    /// `.expired` / `.busy_ms` / `.first_arrival_ms` / `.last_done_ms`.
    pub fn in_registry(reg: &MetricsRegistry, prefix: &str) -> LatencyRecorder {
        LatencyRecorder {
            reg: reg.clone(),
            prefix: prefix.to_string(),
            latencies: reg.series(&format!("{prefix}.latency_ms")),
            images: reg.counter(&format!("{prefix}.images")),
            batches: reg.counter(&format!("{prefix}.batches")),
            rejected: reg.counter(&format!("{prefix}.rejected")),
            expired: reg.counter(&format!("{prefix}.expired")),
            busy_ms: reg.fcounter(&format!("{prefix}.busy_ms")),
            first: reg.gauge(&format!("{prefix}.first_arrival_ms")),
            last: reg.gauge(&format!("{prefix}.last_done_ms")),
        }
    }

    /// The registry this recorder writes into.
    pub fn registry(&self) -> &MetricsRegistry {
        &self.reg
    }

    /// Widen the observed wall-clock span to include `ms`.
    fn touch(&mut self, ms: f64) {
        self.first.min_of(ms);
        self.last.max_of(ms);
    }

    /// A request arrived at `ms` (admitted or not) — wall time starts
    /// at the first arrival, not the first completion.
    pub fn note_arrival(&mut self, ms: f64) {
        self.touch(ms);
    }

    /// A micro-batch of `images` finished at `done_ms` after
    /// `compute_ms` of forward time.
    pub fn record_batch(&mut self, images: usize, compute_ms: f64, done_ms: f64) {
        self.images.add(images as u64);
        self.batches.inc();
        self.busy_ms.add(compute_ms);
        self.touch(done_ms);
    }

    /// A request completed with end-to-end latency `ms`.
    pub fn record_latency(&mut self, ms: f64) {
        self.latencies.record(ms);
    }

    pub fn record_reject(&mut self) {
        self.rejected.inc();
    }

    pub fn record_expired(&mut self) {
        self.expired.inc();
    }

    /// Requests completed so far (total, not just the retained window).
    pub fn completed(&self) -> usize {
        self.latencies.count() as usize
    }

    pub fn summary(&self) -> LatencySummary {
        LatencySummary::from_registry(&self.reg, &self.prefix)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn recorder_summarizes_percentiles_and_span() {
        let mut rec = LatencyRecorder::default();
        rec.note_arrival(10.0);
        rec.record_batch(4, 3.0, 15.0);
        rec.record_batch(2, 2.0, 25.0);
        for ms in [1.0, 2.0, 3.0, 4.0] {
            rec.record_latency(ms);
        }
        rec.record_reject();
        rec.record_expired();
        let s = rec.summary();
        assert_eq!(s.count, 4);
        assert_eq!(s.images, 6);
        assert_eq!(s.batches, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.expired, 1);
        assert!((s.wall_ms - 15.0).abs() < 1e-12);
        assert!((s.busy_ms - 5.0).abs() < 1e-12);
        assert!((s.p50_ms - 2.5).abs() < 1e-12);
        assert_eq!(s.max_ms, 4.0);
        // 6 images over 15 ms of wall time.
        assert!((s.imgs_per_sec() - 400.0).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_all_zero() {
        let s = LatencyRecorder::default().summary();
        assert_eq!(s, LatencySummary::default());
        assert_eq!(s.imgs_per_sec(), 0.0);
    }

    #[test]
    fn single_sample_summary() {
        let mut rec = LatencyRecorder::default();
        rec.note_arrival(0.0);
        rec.record_batch(1, 0.5, 3.0);
        rec.record_latency(3.0);
        let s = rec.summary();
        assert_eq!(s.count, 1);
        // Every percentile of a single sample is that sample.
        assert_eq!(s.mean_ms, 3.0);
        assert_eq!(s.p50_ms, 3.0);
        assert_eq!(s.p95_ms, 3.0);
        assert_eq!(s.p99_ms, 3.0);
        assert_eq!(s.max_ms, 3.0);
        assert!((s.wall_ms - 3.0).abs() < 1e-12);
    }

    #[test]
    fn zero_wall_span_means_zero_throughput() {
        // All events at the same instant: wall_ms == 0 must not divide.
        let mut rec = LatencyRecorder::default();
        rec.note_arrival(5.0);
        rec.record_batch(16, 0.0, 5.0);
        rec.record_latency(0.0);
        let s = rec.summary();
        assert_eq!(s.wall_ms, 0.0);
        assert_eq!(s.images, 16);
        assert_eq!(s.imgs_per_sec(), 0.0);
    }

    #[test]
    fn percentiles_are_monotone_under_random_inputs() {
        let mut rng = Rng::new(0xbeef);
        for trial in 0..32 {
            let n = 1 + (rng.next_u64() % 200) as usize;
            let mut rec = LatencyRecorder::default();
            rec.note_arrival(0.0);
            for _ in 0..n {
                rec.record_latency(rng.uniform() as f64 * 100.0);
            }
            let s = rec.summary();
            assert!(
                s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms && s.p99_ms <= s.max_ms,
                "trial {trial}: p50={} p95={} p99={} max={}",
                s.p50_ms,
                s.p95_ms,
                s.p99_ms,
                s.max_ms
            );
            assert!(s.mean_ms <= s.max_ms && s.mean_ms >= 0.0);
        }
    }

    #[test]
    fn from_registry_matches_recorder_summary() {
        let reg = MetricsRegistry::new();
        let mut rec = LatencyRecorder::in_registry(&reg, "serve");
        rec.note_arrival(1.0);
        rec.record_batch(3, 2.0, 4.0);
        rec.record_latency(3.0);
        rec.record_latency(1.0);
        assert_eq!(rec.summary(), LatencySummary::from_registry(&reg, "serve"));
        // A clone shares the same cells.
        let mut rec2 = rec.clone();
        rec2.record_reject();
        assert_eq!(rec.summary().rejected, 1);
    }

    #[test]
    fn json_schema_has_the_bench_fields() {
        let mut rec = LatencyRecorder::default();
        rec.note_arrival(0.0);
        rec.record_batch(8, 1.0, 2.0);
        rec.record_latency(2.0);
        let j = rec.summary().to_json();
        for key in
            ["requests", "images", "batches", "rejected", "expired", "imgs_per_s", "p50_ms",
             "p95_ms", "p99_ms", "max_ms", "wall_ms"]
        {
            assert!(j.get(key).is_some(), "BENCH json missing {key}");
        }
        assert_eq!(j.get("imgs_per_s").unwrap().as_f64().unwrap(), 4000.0);
    }
}
