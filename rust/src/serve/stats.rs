//! Typed latency accounting shared by the session, the fleet, and the
//! serve bench — one percentile implementation, one JSON field set.
//!
//! [`LatencyRecorder`] is the mutable accumulator the serving loops
//! feed (per-request latencies, per-batch compute time, rejections,
//! deadline expiries); [`LatencySummary`] is the immutable snapshot it
//! produces, with the p50/p95/p99 distribution the ROADMAP's serving
//! milestone asks for. The summary serializes itself into the BENCH
//! json (`fields`/`to_json`), so session, fleet, bench harness, and the
//! load generator all emit byte-identical schemas instead of each
//! recomputing percentiles.

use crate::util::json::{num, obj, Json};
use crate::util::stats::{mean, percentile};

/// Snapshot of a serving run's request/latency distribution.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LatencySummary {
    /// Completed requests (one latency sample each).
    pub count: usize,
    /// Images inferred across all micro-batches.
    pub images: usize,
    /// Micro-batches executed.
    pub batches: usize,
    /// Requests rejected by queue-depth backpressure.
    pub rejected: usize,
    /// Requests expired by their deadline before any chunk ran.
    pub expired: usize,
    /// Wall-clock span from first arrival to last completion.
    pub wall_ms: f64,
    /// Summed forward compute time (excludes queueing).
    pub busy_ms: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    /// Serving throughput over the wall-clock span (0 for empty runs).
    pub fn imgs_per_sec(&self) -> f64 {
        if self.wall_ms > 0.0 {
            self.images as f64 / (self.wall_ms / 1e3)
        } else {
            0.0
        }
    }

    /// The BENCH json fields, in the schema order every emitter shares.
    pub fn fields(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("requests", num(self.count as f64)),
            ("images", num(self.images as f64)),
            ("batches", num(self.batches as f64)),
            ("rejected", num(self.rejected as f64)),
            ("expired", num(self.expired as f64)),
            ("wall_ms", num(self.wall_ms)),
            ("busy_ms", num(self.busy_ms)),
            ("imgs_per_s", num(self.imgs_per_sec())),
            ("mean_ms", num(self.mean_ms)),
            ("p50_ms", num(self.p50_ms)),
            ("p95_ms", num(self.p95_ms)),
            ("p99_ms", num(self.p99_ms)),
            ("max_ms", num(self.max_ms)),
        ]
    }

    pub fn to_json(&self) -> Json {
        obj(self.fields())
    }
}

/// PR 5's stats type, kept as an alias so old callers compile.
#[deprecated(note = "use LatencySummary (the typed percentile snapshot)")]
pub type SessionStats = LatencySummary;

/// Mutable accumulator behind [`LatencySummary`].
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    latencies_ms: Vec<f64>,
    images: usize,
    batches: usize,
    rejected: usize,
    expired: usize,
    busy_ms: f64,
    first_ms: Option<f64>,
    last_ms: Option<f64>,
}

impl LatencyRecorder {
    /// Widen the observed wall-clock span to include `ms`.
    fn touch(&mut self, ms: f64) {
        self.first_ms = Some(self.first_ms.map_or(ms, |f| f.min(ms)));
        self.last_ms = Some(self.last_ms.map_or(ms, |l| l.max(ms)));
    }

    /// A request arrived at `ms` (admitted or not) — wall time starts
    /// at the first arrival, not the first completion.
    pub fn note_arrival(&mut self, ms: f64) {
        self.touch(ms);
    }

    /// A micro-batch of `images` finished at `done_ms` after
    /// `compute_ms` of forward time.
    pub fn record_batch(&mut self, images: usize, compute_ms: f64, done_ms: f64) {
        self.images += images;
        self.batches += 1;
        self.busy_ms += compute_ms;
        self.touch(done_ms);
    }

    /// A request completed with end-to-end latency `ms`.
    pub fn record_latency(&mut self, ms: f64) {
        self.latencies_ms.push(ms);
    }

    pub fn record_reject(&mut self) {
        self.rejected += 1;
    }

    pub fn record_expired(&mut self) {
        self.expired += 1;
    }

    /// Requests completed so far.
    pub fn completed(&self) -> usize {
        self.latencies_ms.len()
    }

    pub fn summary(&self) -> LatencySummary {
        let xs = &self.latencies_ms;
        LatencySummary {
            count: xs.len(),
            images: self.images,
            batches: self.batches,
            rejected: self.rejected,
            expired: self.expired,
            wall_ms: match (self.first_ms, self.last_ms) {
                (Some(f), Some(l)) => l - f,
                _ => 0.0,
            },
            busy_ms: self.busy_ms,
            mean_ms: mean(xs),
            p50_ms: percentile(xs, 50.0),
            p95_ms: percentile(xs, 95.0),
            p99_ms: percentile(xs, 99.0),
            max_ms: xs.iter().fold(0.0f64, |a, &b| a.max(b)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_summarizes_percentiles_and_span() {
        let mut rec = LatencyRecorder::default();
        rec.note_arrival(10.0);
        rec.record_batch(4, 3.0, 15.0);
        rec.record_batch(2, 2.0, 25.0);
        for ms in [1.0, 2.0, 3.0, 4.0] {
            rec.record_latency(ms);
        }
        rec.record_reject();
        rec.record_expired();
        let s = rec.summary();
        assert_eq!(s.count, 4);
        assert_eq!(s.images, 6);
        assert_eq!(s.batches, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.expired, 1);
        assert!((s.wall_ms - 15.0).abs() < 1e-12);
        assert!((s.busy_ms - 5.0).abs() < 1e-12);
        assert!((s.p50_ms - 2.5).abs() < 1e-12);
        assert_eq!(s.max_ms, 4.0);
        // 6 images over 15 ms of wall time.
        assert!((s.imgs_per_sec() - 400.0).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_all_zero() {
        let s = LatencyRecorder::default().summary();
        assert_eq!(s, LatencySummary::default());
        assert_eq!(s.imgs_per_sec(), 0.0);
    }

    #[test]
    fn json_schema_has_the_bench_fields() {
        let mut rec = LatencyRecorder::default();
        rec.note_arrival(0.0);
        rec.record_batch(8, 1.0, 2.0);
        rec.record_latency(2.0);
        let j = rec.summary().to_json();
        for key in
            ["requests", "images", "batches", "rejected", "expired", "imgs_per_s", "p50_ms",
             "p95_ms", "p99_ms", "max_ms", "wall_ms"]
        {
            assert!(j.get(key).is_some(), "BENCH json missing {key}");
        }
        assert_eq!(j.get("imgs_per_s").unwrap().as_f64().unwrap(), 4000.0);
    }
}
