//! Request-level serving session over a single [`ServeEngine`].
//!
//! The PR 6 API is ticket-based: [`ServeSession::submit_request`]
//! admits a request through the bounded [`Scheduler`] queue and
//! returns a [`Ticket`]; batches form continuously via
//! [`ServeSession::step`] (each step runs one micro-batch, crossing
//! request boundaries in FIFO order); outcomes are redeemed with
//! [`poll`](ServeSession::poll) / [`wait`](ServeSession::wait) /
//! [`wait_all`](ServeSession::wait_all). Requests may carry a relative
//! deadline — a request whose deadline passes before its first chunk
//! runs resolves to [`Outcome::Expired`] instead of blocking the queue.
//!
//! The deprecated `submit`/`flush` pair from PR 5 survives as a thin
//! shim over the ticket API so existing callers (`eval --packed`, the
//! oscillation-analysis example) compile unchanged.
//!
//! For MX variants the micro-batch segmentation cannot change any
//! logit (activation groups are per token row); the per-tensor INT4
//! baseline is batch-composition dependent, as it already is in the
//! HLO eval path.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::obs::MetricsRegistry;
use crate::serve::engine::ServeEngine;
use crate::serve::scheduler::{
    Completions, Outcome, Reject, Response, SchedMetrics, Scheduler, Ticket,
};
use crate::serve::stats::LatencySummary;

/// Ticket-based serving session.
pub struct ServeSession {
    engine: ServeEngine,
    sched: Scheduler,
    done: Completions,
    clock: Instant,
    reg: MetricsRegistry,
}

impl ServeSession {
    pub fn new(mut engine: ServeEngine) -> ServeSession {
        let reg = MetricsRegistry::new();
        engine.instrument(&reg);
        let sched = Scheduler::with_metrics(
            engine.pixels_per_image(),
            engine.cfg.queue_depth,
            SchedMetrics::in_registry(&reg),
        );
        let done = Completions::in_registry(engine.classes(), &reg);
        ServeSession { engine, sched, done, clock: Instant::now(), reg }
    }

    pub fn engine(&self) -> &ServeEngine {
        &self.engine
    }

    /// The session's metrics registry (`sched.*`, `serve.*`,
    /// `kernel.*` all live here).
    pub fn registry(&self) -> &MetricsRegistry {
        &self.reg
    }

    /// Milliseconds since the session started (the session clock).
    pub fn now_ms(&self) -> f64 {
        self.clock.elapsed().as_secs_f64() * 1e3
    }

    /// Admit an `n`-image request; [`Reject`] carries the reason
    /// (backpressure or malformed shape) when the queue refuses it.
    pub fn submit_request(&mut self, images: Vec<f32>, n: usize) -> Result<Ticket, Reject> {
        self.submit_with_deadline(images, n, None)
    }

    /// Like [`submit_request`](Self::submit_request) with a deadline
    /// relative to now: if it passes before the request's first chunk
    /// runs, the request expires instead of running.
    pub fn submit_with_deadline(
        &mut self,
        images: Vec<f32>,
        n: usize,
        deadline_ms: Option<f64>,
    ) -> Result<Ticket, Reject> {
        let now = self.now_ms();
        self.done.rec.note_arrival(now);
        let r = self.sched.try_admit(images, n, deadline_ms.map(|d| now + d), now);
        if matches!(r, Err(Reject::QueueFull { .. })) {
            self.done.rec.record_reject();
        }
        r
    }

    /// Queued (not yet fully batched) requests.
    pub fn pending(&self) -> usize {
        self.sched.pending_requests()
    }

    /// Form and run one micro-batch (or expire overdue requests).
    /// Returns false when there was nothing to do.
    pub fn step(&mut self) -> bool {
        let now = self.now_ms();
        let (expired, plan) = self.sched.next_batch(self.engine.cfg.micro_batch, now);
        for e in &expired {
            self.done.on_expired(e);
        }
        let Some(plan) = plan else {
            return !expired.is_empty();
        };
        let t0 = Instant::now();
        let logits = self.engine.model().forward_observed(
            &plan.images,
            plan.m,
            self.engine.cfg.workers,
            self.engine.kernel_metrics(),
        );
        let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.done.on_batch(&plan, &logits, self.now_ms(), compute_ms);
        true
    }

    /// Redeem a ticket if its request has resolved (at most once).
    pub fn poll(&mut self, t: Ticket) -> Option<Outcome> {
        self.done.take(t)
    }

    /// Drive the session until `t` resolves.
    pub fn wait(&mut self, t: Ticket) -> Result<Outcome> {
        loop {
            if let Some(o) = self.done.take(t) {
                return Ok(o);
            }
            if !self.step() {
                bail!("ticket {} is not pending in this session", t.id);
            }
        }
    }

    /// Drive the queue dry and drain every resolved outcome, in
    /// ticket order.
    pub fn wait_all(&mut self) -> Vec<Outcome> {
        while self.step() {}
        self.done.take_all()
    }

    /// Aggregate latency/throughput snapshot.
    pub fn stats(&self) -> LatencySummary {
        self.done.rec.summary()
    }

    /// PR 5 shim: enqueue and return the raw id.
    #[deprecated(note = "use submit_request, which returns a Ticket and typed rejections")]
    pub fn submit(&mut self, images: Vec<f32>, n: usize) -> Result<u64> {
        Ok(self.submit_request(images, n)?.id)
    }

    /// PR 5 shim: run everything queued, return completed responses in
    /// submission order (expired requests are silently dropped, as the
    /// old API had no way to express them).
    #[deprecated(note = "use step/poll/wait_all, which expose per-request outcomes")]
    pub fn flush(&mut self) -> Vec<Response> {
        self.wait_all().into_iter().filter_map(Outcome::response).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{e2m1, Scaling};
    use crate::serve::engine::{ServeConfig, ServeEngine};
    use crate::serve::model::{ActQuant, PackedVit, ServeGeom, WeightQuant};
    use crate::util::rng::Rng;

    fn engine_with(micro_batch: usize, queue_depth: usize) -> ServeEngine {
        let geom = ServeGeom::new(8, 4, 32, 2, 4, 3, 4);
        let mut rng = Rng::new(77);
        let params: Vec<f32> = (0..geom.total_params()).map(|_| rng.normal() * 0.05).collect();
        let fmt = e2m1();
        let model = PackedVit::build(
            geom,
            &params,
            None,
            WeightQuant::Mx { fmt, scaling: Scaling::TruncationFree },
            ActQuant::Mx { fmt, scaling: Scaling::TruncationFree },
        )
        .unwrap();
        let cfg = ServeConfig::builder()
            .micro_batch(micro_batch)
            .workers(2)
            .queue_depth(queue_depth)
            .build()
            .unwrap();
        ServeEngine::new(model, cfg).unwrap()
    }

    fn engine(micro_batch: usize) -> ServeEngine {
        engine_with(micro_batch, 64)
    }

    #[test]
    fn wait_all_matches_direct_engine_inference() {
        // Micro-batch 4 over requests of 3 + 2 + 4 images: batches
        // cross request boundaries, results must not change.
        let eng = engine(4);
        let px = eng.pixels_per_image();
        let mut rng = Rng::new(2);
        let mut sess = ServeSession::new(engine(4));
        let mut all = Vec::new();
        let mut sizes = Vec::new();
        for n in [3usize, 2, 4] {
            let imgs: Vec<f32> = (0..n * px).map(|_| rng.normal()).collect();
            all.extend_from_slice(&imgs);
            sizes.push(n);
            sess.submit_request(imgs, n).unwrap();
        }
        assert_eq!(sess.pending(), 3);
        let outs = sess.wait_all();
        assert_eq!(sess.pending(), 0);
        assert_eq!(outs.len(), 3);
        let want = eng.predict(&all, 9);
        let mut got = Vec::new();
        for (o, n) in outs.into_iter().zip(&sizes) {
            let r = o.response().expect("no deadline, so every request completes");
            assert_eq!(r.preds.len(), *n);
            assert!(r.latency_ms >= 0.0);
            got.extend_from_slice(&r.preds);
        }
        assert_eq!(got, want);
        let st = sess.stats();
        assert_eq!((st.count, st.images, st.batches), (3, 9, 3)); // ceil(9/4) batches
        assert!(st.imgs_per_sec() > 0.0);
        assert!(st.p50_ms <= st.max_ms);
    }

    #[test]
    fn poll_is_none_until_step_resolves() {
        let mut sess = ServeSession::new(engine(2));
        let px = sess.engine().pixels_per_image();
        let t = sess.submit_request(vec![0.1; 3 * px], 3).unwrap();
        assert!(sess.poll(t).is_none());
        assert!(sess.step()); // 2 of 3 images
        assert!(sess.poll(t).is_none(), "request still has an image queued");
        assert!(sess.step()); // final image
        let o = sess.poll(t).expect("resolved after the final chunk");
        assert_eq!(o.id(), t.id);
        assert_eq!(o.response().unwrap().preds.len(), 3);
        // Redemption is at-most-once; a drained ticket errors in wait.
        assert!(sess.poll(t).is_none());
        assert!(sess.wait(t).is_err());
    }

    #[test]
    fn submit_validates_shape_and_applies_backpressure() {
        let mut sess = ServeSession::new(engine_with(4, 64));
        assert!(matches!(
            sess.submit_request(vec![0.0; 5], 1),
            Err(Reject::BadRequest(_))
        ));
        let px = sess.engine().pixels_per_image();
        sess.submit_request(vec![0.0; 64 * px], 64).unwrap();
        let r = sess.submit_request(vec![0.0; px], 1);
        assert_eq!(r, Err(Reject::QueueFull { queued_images: 64, limit: 64 }));
        assert_eq!(sess.stats().rejected, 1);
    }

    #[test]
    fn deadline_expires_unstarted_requests() {
        let mut sess = ServeSession::new(engine(4));
        let px = sess.engine().pixels_per_image();
        // A deadline already in the past: expires at first step.
        let t = sess
            .submit_with_deadline(vec![0.2; px], 1, Some(-1.0))
            .unwrap();
        let o = sess.wait(t).unwrap();
        assert!(matches!(o, Outcome::Expired { .. }));
        assert_eq!(sess.stats().expired, 1);
        // A generous deadline completes normally.
        let t2 = sess
            .submit_with_deadline(vec![0.2; px], 1, Some(60_000.0))
            .unwrap();
        assert!(sess.wait(t2).unwrap().response().is_some());
    }

    #[test]
    fn registry_sees_scheduler_kernel_and_latency_metrics() {
        let mut sess = ServeSession::new(engine(2));
        let px = sess.engine().pixels_per_image();
        sess.submit_request(vec![0.1; 4 * px], 4).unwrap();
        let outs = sess.wait_all();
        assert_eq!(outs.len(), 1);
        let reg = sess.registry().clone();
        assert_eq!(reg.counter("sched.admits").get(), 1);
        // 4 images / micro-batch 2 = 2 batches; depth=2 blocks each.
        assert_eq!(reg.counter("serve.batches").get(), 2);
        assert_eq!(reg.counter("serve.images").get(), 4);
        assert_eq!(reg.counter("kernel.qkv.calls").get(), 4);
        // stats() is literally a view over the registry.
        assert_eq!(sess.stats(), LatencySummary::from_registry(&reg, "serve"));
        assert_eq!(sess.stats().count, 1);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shim_still_flushes() {
        let mut sess = ServeSession::new(engine(2));
        let px = sess.engine().pixels_per_image();
        let id = sess.submit(vec![0.3; 2 * px], 2).unwrap();
        let rs = sess.flush();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].id, id);
        assert_eq!(rs[0].preds.len(), 2);
        assert!(sess.flush().is_empty());
    }
}
