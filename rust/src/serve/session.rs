//! Request-level serving session: queue N-image requests, micro-batch
//! them through the engine (crossing request boundaries), and report
//! per-request latency plus aggregate throughput.
//!
//! The session is synchronous and deterministic: [`ServeSession::submit`]
//! enqueues, [`ServeSession::flush`] runs everything queued and
//! attributes to each request the wall-clock time from flush start to
//! the completion of the last micro-batch containing one of its
//! images. For MX variants the micro-batch segmentation cannot change
//! any logit (activation groups are per token row); the per-tensor
//! INT4 baseline is batch-composition dependent, as it already is in
//! the HLO eval path.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::serve::engine::{argmax_rows, ServeEngine};

/// One queued inference request.
#[derive(Debug, Clone)]
struct Request {
    id: u64,
    images: Vec<f32>,
    n: usize,
}

/// Completed request: predicted class per image + logits + latency.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub preds: Vec<usize>,
    pub logits: Vec<f32>,
    pub latency_ms: f64,
}

/// Aggregate serving statistics across all flushes.
#[derive(Debug, Clone, Default)]
pub struct SessionStats {
    pub requests: usize,
    pub images: usize,
    pub batches: usize,
    pub wall_ms: f64,
    latencies_ms: Vec<f64>,
}

impl SessionStats {
    pub fn imgs_per_sec(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            return 0.0;
        }
        self.images as f64 / (self.wall_ms / 1e3)
    }

    /// Latency percentile over completed requests (q in [0, 1]).
    pub fn latency_pct_ms(&self, q: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let i = ((v.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        v[i]
    }
}

/// Batched serving session over a [`ServeEngine`].
pub struct ServeSession {
    engine: ServeEngine,
    queue: Vec<Request>,
    next_id: u64,
    stats: SessionStats,
}

impl ServeSession {
    pub fn new(engine: ServeEngine) -> ServeSession {
        ServeSession { engine, queue: Vec::new(), next_id: 0, stats: SessionStats::default() }
    }

    pub fn engine(&self) -> &ServeEngine {
        &self.engine
    }

    /// Enqueue an `n`-image request; returns its id.
    pub fn submit(&mut self, images: Vec<f32>, n: usize) -> Result<u64> {
        if n == 0 || images.len() != n * self.engine.pixels_per_image() {
            bail!(
                "request must be n x {} pixels, got n={n} len={}",
                self.engine.pixels_per_image(),
                images.len()
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push(Request { id, images, n });
        Ok(id)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Run every queued request through the engine in micro-batches
    /// that cross request boundaries, in submission order. Returns one
    /// [`Response`] per request, in submission order.
    pub fn flush(&mut self) -> Vec<Response> {
        let reqs = std::mem::take(&mut self.queue);
        if reqs.is_empty() {
            return Vec::new();
        }
        let px = self.engine.pixels_per_image();
        let classes = self.engine.classes();
        let total: usize = reqs.iter().map(|r| r.n).sum();
        let mut images = Vec::with_capacity(total * px);
        for r in &reqs {
            images.extend_from_slice(&r.images);
        }

        // Forward in micro-batches, recording each batch's completion
        // time relative to flush start.
        let micro = self.engine.cfg.micro_batch;
        let mut logits = Vec::with_capacity(total * classes);
        let mut done_at_ms = Vec::with_capacity(total); // per image
        let t0 = Instant::now();
        let mut done = 0;
        let mut batches = 0;
        while done < total {
            let m = micro.min(total - done);
            let chunk = &images[done * px..(done + m) * px];
            logits.extend(self.engine.model().forward(chunk, m, self.engine.cfg.workers));
            let at = t0.elapsed().as_secs_f64() * 1e3;
            done_at_ms.extend(std::iter::repeat(at).take(m));
            done += m;
            batches += 1;
        }
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

        // Slice results back per request; latency = completion of the
        // request's last image.
        let mut out = Vec::with_capacity(reqs.len());
        let mut off = 0;
        for r in &reqs {
            let lg = logits[off * classes..(off + r.n) * classes].to_vec();
            let latency_ms = done_at_ms[off + r.n - 1];
            out.push(Response {
                id: r.id,
                preds: argmax_rows(&lg, classes),
                logits: lg,
                latency_ms,
            });
            self.stats.latencies_ms.push(latency_ms);
            off += r.n;
        }
        self.stats.requests += reqs.len();
        self.stats.images += total;
        self.stats.batches += batches;
        self.stats.wall_ms += wall_ms;
        out
    }

    pub fn stats(&self) -> &SessionStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{e2m1, Scaling};
    use crate::serve::engine::{ServeConfig, ServeEngine};
    use crate::serve::model::{ActQuant, PackedVit, ServeGeom, WeightQuant};
    use crate::util::rng::Rng;

    fn engine(micro_batch: usize) -> ServeEngine {
        let geom = ServeGeom::new(8, 4, 32, 2, 4, 3, 4);
        let mut rng = Rng::new(77);
        let params: Vec<f32> = (0..geom.total_params()).map(|_| rng.normal() * 0.05).collect();
        let fmt = e2m1();
        let model = PackedVit::build(
            geom,
            &params,
            None,
            WeightQuant::Mx { fmt, scaling: Scaling::TruncationFree },
            ActQuant::Mx { fmt, scaling: Scaling::TruncationFree },
        )
        .unwrap();
        ServeEngine::new(model, ServeConfig { micro_batch, workers: 2 }).unwrap()
    }

    #[test]
    fn flush_matches_direct_engine_inference() {
        // Micro-batch 4 over requests of 3 + 2 + 4 images: batches
        // cross request boundaries, results must not change.
        let eng = engine(4);
        let px = eng.pixels_per_image();
        let mut rng = Rng::new(2);
        let mut sess = ServeSession::new(engine(4));
        let mut all = Vec::new();
        let mut sizes = Vec::new();
        for n in [3usize, 2, 4] {
            let imgs: Vec<f32> = (0..n * px).map(|_| rng.normal()).collect();
            all.extend_from_slice(&imgs);
            sizes.push(n);
            sess.submit(imgs, n).unwrap();
        }
        assert_eq!(sess.pending(), 3);
        let rs = sess.flush();
        assert_eq!(sess.pending(), 0);
        assert_eq!(rs.len(), 3);
        let want = eng.predict(&all, 9);
        let mut got = Vec::new();
        for (r, n) in rs.iter().zip(&sizes) {
            assert_eq!(r.preds.len(), *n);
            assert!(r.latency_ms >= 0.0);
            got.extend_from_slice(&r.preds);
        }
        assert_eq!(got, want);
        // Later requests cannot finish before earlier ones.
        assert!(rs.windows(2).all(|w| w[0].latency_ms <= w[1].latency_ms));
        let st = sess.stats();
        assert_eq!((st.requests, st.images), (3, 9));
        assert_eq!(st.batches, 3); // ceil(9 / 4)
        assert!(st.imgs_per_sec() > 0.0);
        assert!(st.latency_pct_ms(0.5) <= st.latency_pct_ms(1.0));
    }

    #[test]
    fn submit_validates_shape() {
        let mut sess = ServeSession::new(engine(4));
        assert!(sess.submit(vec![0.0; 5], 1).is_err());
        assert!(sess.submit(Vec::new(), 0).is_err());
        let px = sess.engine().pixels_per_image();
        assert!(sess.submit(vec![0.0; px], 1).is_ok());
    }

    #[test]
    fn empty_flush_is_empty() {
        let mut sess = ServeSession::new(engine(2));
        assert!(sess.flush().is_empty());
        assert_eq!(sess.stats().requests, 0);
    }
}
