//! Packed-native serving: forward-only inference over 4-bit codes.
//!
//! Training (the [`crate::coordinator`]) round-trips quantized weights
//! through an f32 view every step because the controllers need it; a
//! serving process does not. This subsystem keeps the model in the
//! [`crate::quant::PackedMx`] representation end to end:
//!
//! * [`kernel`] — the fused group-wise dequant-matmul: each weight row
//!   decoded once per call (SIMD `pshufb` table lookup or scalar level
//!   lookup, one broadcast multiply per 1x32 group scale), then dotted
//!   against the batch in the canonical lane-strided order,
//!   row-parallel. Bit-exact to dequantize-then-matmul at every
//!   dispatch level.
//! * [`simd`] — runtime kernel dispatch ([`simd::SimdLevel`]:
//!   `off`/`ssse3`/`avx2`, probed via `is_x86_feature_detected!`,
//!   overridable with `TJ_SIMD` or `--simd`) plus the canonical dot
//!   definition and the nibble-decode microkernels themselves.
//! * [`act`] — [`act::ActQuantCache`]: memoizes Q1 activation
//!   quantization (per-group E8M0 scale bytes computed once, then the
//!   rounding pass) keyed on the activation bytes, so a dense
//!   `--verify-mirror` pass or a repeated forward reuses the fused
//!   engine's quantization work bit-exactly.
//! * [`model`] — [`model::PackedVit`]: manifest-derived geometry + the
//!   quantized ViT forward (Eq. 3: `Y = Q1(X) · Q2(W)^T`) over packed
//!   stores, never materializing an f32 weight mirror. The forward's
//!   quantized linears route through the [`model::LinearExec`] seam,
//!   which is also the fleet's sharding boundary.
//! * [`engine`] — [`engine::ServeEngine`]: micro-batched inference +
//!   trainer-parity eval, configured via the validating
//!   [`engine::ServeConfig::builder`].
//! * [`scheduler`] — clock-free continuous-batching core: bounded
//!   admission queue (reject-with-reason backpressure), FIFO
//!   micro-batch formation across request boundaries, deadline expiry,
//!   and completion routing by [`scheduler::Ticket`].
//! * [`session`] — [`session::ServeSession`]: single-engine ticket API
//!   (`submit_request` → `poll`/`wait`/`wait_all`), with the PR 5
//!   `submit`/`flush` pair kept as a deprecated shim.
//! * [`fleet`] — [`fleet::ServeFleet`]: N row-sharded engines behind
//!   mpsc work queues with scatter/gather at the kernel's row-parallel
//!   seam; logits bit-exact to single-engine.
//! * [`load`] — seeded open-loop Poisson load generator with real and
//!   virtual (deterministic) pacing.
//! * [`stats`] — [`stats::LatencySummary`]: the one typed
//!   p50/p95/p99/throughput snapshot session, fleet, load test, and
//!   bench all serialize into BENCH json. Since PR 7 the recorder is a
//!   bundle of [`crate::obs::MetricsRegistry`] handles and the summary
//!   is [`stats::LatencySummary::from_registry`] — one registry backs
//!   live scrapes (`serve --metrics-addr`), periodic `METRICS {...}`
//!   snapshots, request tracing (`serve --trace-out`), and the
//!   end-of-run BENCH lines.
//!
//! Models load from TJCKPT02 packed checkpoints
//! ([`crate::coordinator::TrainState::load_with_packed`]) written by
//! `tetrajet train --ckpt-packed`; a TJCKPT01 (or packed-less) file
//! falls back to re-quantizing the f32 parameters with the variant's
//! forward recipe. CLI entry points: `tetrajet serve` (with
//! `--engines N --load-test`) and `tetrajet eval --packed`.

pub mod act;
pub mod engine;
pub mod fleet;
pub mod kernel;
pub mod load;
pub mod model;
pub mod scheduler;
pub mod session;
pub mod simd;
pub mod stats;

pub use act::ActQuantCache;
pub use engine::{ServeConfig, ServeConfigBuilder, ServeEngine};
pub use fleet::{FleetMetrics, ServeFleet, StepInfo};
pub use kernel::{
    dense_matmul, dense_matmul_at, fused_matmul, fused_matmul_at, matmul_ref, transpose_back,
};
pub use load::{run_load_test, LoadReport, LoadSpec, Pace};
pub use model::{
    shard_ranges, variant_quant, ActQuant, LinearExec, ObservedExec, PackedVit, ServeGeom,
    VitShard, WeightQuant,
};
pub use scheduler::{Outcome, Reject, Response, SchedMetrics, Scheduler, Ticket};
pub use session::ServeSession;
pub use simd::SimdLevel;
pub use stats::{LatencyRecorder, LatencySummary};
#[allow(deprecated)]
pub use stats::SessionStats;
