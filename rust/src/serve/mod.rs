//! Packed-native serving: forward-only inference over 4-bit codes.
//!
//! Training (the [`crate::coordinator`]) round-trips quantized weights
//! through an f32 view every step because the controllers need it; a
//! serving process does not. This subsystem keeps the model in the
//! [`crate::quant::PackedMx`] representation end to end:
//!
//! * [`kernel`] — the fused group-wise dequant-matmul: nibble decode →
//!   level table → one `exp2i` per 1x32 group, FMAed straight into the
//!   output tile, row-parallel. Bit-exact to dequantize-then-matmul.
//! * [`model`] — [`model::PackedVit`]: manifest-derived geometry + the
//!   quantized ViT forward (Eq. 3: `Y = Q1(X) · Q2(W)^T`) over packed
//!   stores, never materializing an f32 weight mirror.
//! * [`engine`] — [`engine::ServeEngine`]: micro-batched inference +
//!   trainer-parity eval.
//! * [`session`] — [`session::ServeSession`]: request queue with
//!   cross-request micro-batching, per-request latency and aggregate
//!   throughput stats.
//!
//! Models load from TJCKPT02 packed checkpoints
//! ([`crate::coordinator::TrainState::load_with_packed`]) written by
//! `tetrajet train --ckpt-packed`; a TJCKPT01 (or packed-less) file
//! falls back to re-quantizing the f32 parameters with the variant's
//! forward recipe. CLI entry points: `tetrajet serve` and
//! `tetrajet eval --packed`.

pub mod engine;
pub mod kernel;
pub mod model;
pub mod session;

pub use engine::{ServeConfig, ServeEngine};
pub use kernel::{dense_matmul, fused_matmul, matmul_ref};
pub use model::{variant_quant, ActQuant, PackedVit, ServeGeom, WeightQuant};
pub use session::{Response, ServeSession, SessionStats};
