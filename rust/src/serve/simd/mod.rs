//! Runtime-dispatched SIMD substrate for the fused dequant-matmul.
//!
//! Two pieces live here, both shared by `serve/kernel.rs`:
//!
//! * **The canonical contraction order.** Every GEMM in the serve
//!   stack ([`matmul_ref`](crate::serve::kernel::matmul_ref), the
//!   dense mirror, the scalar fused kernel, and the SIMD paths)
//!   accumulates a dot product into [`LANES`] = 8 lane accumulators —
//!   element `j` of the contraction goes to lane `j % 8`, ascending
//!   `j` within each lane — and reduces them with the one fixed tree
//!   in [`reduce_lanes`]. That order is exactly what an 8-wide vector
//!   loop over `mul` + `add` computes, so the scalar and SIMD paths
//!   perform *the same f32 operations in the same order* and agree
//!   bit-for-bit. Hardware FMA is deliberately not used: `fmadd`
//!   rounds once where `mul` + `add` round twice, which would break
//!   the cross-path guarantee.
//! * **Nibble decode.** [`NibbleTable`] scales a 16-entry level table
//!   to small integers (`level * 2^k` fits i8 for every registered
//!   table), which a single `pshufb` maps 16 codes through at once;
//!   the group's E8M0 scale is folded back as `2^(e - k)`. Both
//!   `(K·L) · 2^(e-k)` and `L · 2^e` are single correctly-rounded
//!   multiplications of the same real value, so the decoded weights
//!   are bit-identical to the scalar `level(code) * scale` path —
//!   including subnormal/underflow cases (verified by property test).
//!
//! Dispatch: [`detected`] probes the host once
//! (`is_x86_feature_detected!`), [`active`] folds in the `TJ_SIMD`
//! environment variable and the process-wide [`set_override`] (the
//! `--simd` CLI flag), always clamped to what the host supports. The
//! `*_at` kernel entry points take an explicit level so tests and
//! benches can pin a path regardless of the global state.

use crate::quant::formats::exp2i;
use crate::quant::{GroupGeom, PackedMx, GROUP};

#[cfg(target_arch = "x86_64")]
mod x86;

/// Kernel dispatch level, ordered weakest to strongest. `Off` is the
/// portable scalar path; the SIMD levels require the matching x86
/// feature and are clamped to [`detected`] at every entry point.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdLevel {
    /// Portable scalar fallback (the canonical order, one lane loop).
    Off,
    /// SSSE3 `pshufb` decode + SSE2 two-register dot.
    Ssse3,
    /// AVX2 `vpshufb` decode + 8-wide dot.
    Avx2,
}

impl SimdLevel {
    /// Parse a `TJ_SIMD` / `--simd` value; unknown strings yield `None`.
    pub fn parse(s: &str) -> Option<SimdLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "scalar" | "none" => Some(SimdLevel::Off),
            "ssse3" | "sse" => Some(SimdLevel::Ssse3),
            "avx2" => Some(SimdLevel::Avx2),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            SimdLevel::Off => "off",
            SimdLevel::Ssse3 => "ssse3",
            SimdLevel::Avx2 => "avx2",
        }
    }

    /// Stable numeric id (the `kernel.dispatch_level` gauge value).
    pub fn id(self) -> u8 {
        match self {
            SimdLevel::Off => 0,
            SimdLevel::Ssse3 => 1,
            SimdLevel::Avx2 => 2,
        }
    }

    fn from_id(id: u8) -> SimdLevel {
        match id {
            1 => SimdLevel::Ssse3,
            2 => SimdLevel::Avx2,
            _ => SimdLevel::Off,
        }
    }
}

/// Strongest level the host supports, probed once per process.
#[cfg(target_arch = "x86_64")]
pub fn detected() -> SimdLevel {
    static DETECTED: std::sync::OnceLock<SimdLevel> = std::sync::OnceLock::new();
    *DETECTED.get_or_init(|| {
        if std::arch::is_x86_feature_detected!("avx2") {
            SimdLevel::Avx2
        } else if std::arch::is_x86_feature_detected!("ssse3") {
            SimdLevel::Ssse3
        } else {
            SimdLevel::Off
        }
    })
}

/// Strongest level the host supports (non-x86: always `Off`).
#[cfg(not(target_arch = "x86_64"))]
pub fn detected() -> SimdLevel {
    SimdLevel::Off
}

/// `true` when `level` can actually execute on this host.
pub fn available(level: SimdLevel) -> bool {
    level <= detected()
}

/// Process-wide dispatch override: 0 = none, else `id() + 1`.
static OVERRIDE: std::sync::atomic::AtomicU8 = std::sync::atomic::AtomicU8::new(0);

/// Force (or with `None` release) the dispatch level for the whole
/// process — the `--simd` CLI flag. Takes precedence over `TJ_SIMD`;
/// still clamped to [`detected`].
pub fn set_override(level: Option<SimdLevel>) {
    let v = level.map_or(0, |l| l.id() + 1);
    OVERRIDE.store(v, std::sync::atomic::Ordering::Relaxed);
}

/// The `TJ_SIMD` environment override, read once per process.
fn env_level() -> Option<SimdLevel> {
    static ENV: std::sync::OnceLock<Option<SimdLevel>> = std::sync::OnceLock::new();
    *ENV.get_or_init(|| std::env::var("TJ_SIMD").ok().as_deref().and_then(SimdLevel::parse))
}

/// The level the dispatched kernels run at right now:
/// `--simd` override, else `TJ_SIMD`, else [`detected`] — always
/// clamped to [`detected`] (requesting AVX2 on an SSSE3 host serves
/// SSSE3, never undefined behavior).
pub fn active() -> SimdLevel {
    let req = match OVERRIDE.load(std::sync::atomic::Ordering::Relaxed) {
        0 => env_level().unwrap_or_else(detected),
        v => SimdLevel::from_id(v - 1),
    };
    req.min(detected())
}

/// Lane count of the canonical contraction order.
pub const LANES: usize = 8;

/// The one fixed lane-reduction tree, written to match the classic
/// SSE horizontal sum (`extractf128`/`movehl`/`shuffle`): fold lanes
/// 8 -> 4 pairwise, then `(s0 + s2) + (s1 + s3)`.
#[inline(always)]
pub fn reduce_lanes(l: &[f32; LANES]) -> f32 {
    ((l[0] + l[4]) + (l[2] + l[6])) + ((l[1] + l[5]) + (l[3] + l[7]))
}

/// Shared dot epilogue: fold the tail elements (`j >= done`) into
/// their canonical lanes, then reduce. Every dot implementation ends
/// here, which is what makes the paths provably identical.
///
/// `inline(always)` is load-bearing, not a hint: inlined into a
/// `#[target_feature]` caller this compiles to VEX encodings, but as
/// an out-of-line call from AVX2 code it would be a legacy-SSE call
/// with dirty upper YMM state — an SSE<->AVX transition per dot,
/// measured ~18x slower than the inlined strip (see `strip_dots_at`).
#[inline(always)]
pub(crate) fn finish_dot(mut lanes: [f32; LANES], x: &[f32], w: &[f32], done: usize) -> f32 {
    for j in done..x.len() {
        lanes[j % LANES] += x[j] * w[j];
    }
    reduce_lanes(&lanes)
}

/// Canonical dot product, scalar implementation: lane `j % 8`
/// accumulates `x[j] * w[j]` in ascending `j`, reduced by
/// [`reduce_lanes`].
pub fn dot_scalar(x: &[f32], w: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), w.len());
    let mut lanes = [0.0f32; LANES];
    let chunks = x.len() / LANES;
    for c in 0..chunks {
        let xc = &x[c * LANES..c * LANES + LANES];
        let wc = &w[c * LANES..c * LANES + LANES];
        for l in 0..LANES {
            lanes[l] += xc[l] * wc[l];
        }
    }
    finish_dot(lanes, x, w, chunks * LANES)
}

/// Canonical dot product at an explicit dispatch level. All levels
/// return bit-identical results; the level only selects how many
/// elements are processed per instruction.
#[cfg(target_arch = "x86_64")]
#[inline]
pub fn dot_at(level: SimdLevel, x: &[f32], w: &[f32]) -> f32 {
    match level {
        SimdLevel::Off => dot_scalar(x, w),
        SimdLevel::Ssse3 => x86::dot_sse2(x, w),
        // Safety: every caller clamps `level` to `detected()`.
        SimdLevel::Avx2 => unsafe { x86::dot_avx2(x, w) },
    }
}

/// Canonical dot product at an explicit dispatch level (non-x86:
/// always the scalar path).
#[cfg(not(target_arch = "x86_64"))]
#[inline]
pub fn dot_at(_level: SimdLevel, x: &[f32], w: &[f32]) -> f32 {
    dot_scalar(x, w)
}

/// Canonical dots of every row of `x (n, d)` against one weight row,
/// at an explicit dispatch level: `acc[i] = dot(x[i*d..], row) + bias`
/// with `n = acc.len()`. Bit-identical across levels (the bias add is
/// the same single f32 addition the per-dot form performs).
///
/// This whole strip — not one dot — is deliberately the dispatch
/// boundary: a `#[target_feature]` function cannot inline into
/// baseline callers, and on AVX2 each out-of-line call pays an
/// SSE<->VEX transition / `vzeroupper` on entry and exit. Per-dot
/// dispatch paid that ~n*rows times per GEMM and measured ~18x slower
/// than scalar on an AVX2 host; per-strip it is paid once per weight
/// row and the AVX2 path runs ~4.5x faster than scalar.
#[cfg(target_arch = "x86_64")]
pub fn strip_dots_at(
    level: SimdLevel,
    x: &[f32],
    d: usize,
    row: &[f32],
    bias: f32,
    acc: &mut [f32],
) {
    debug_assert_eq!(x.len(), acc.len() * d);
    match level {
        SimdLevel::Off => strip_dots_scalar(x, d, row, bias, acc),
        SimdLevel::Ssse3 => x86::strip_dots_sse2(x, d, row, bias, acc),
        // Safety: every caller clamps `level` to `detected()`.
        SimdLevel::Avx2 => unsafe { x86::strip_dots_avx2(x, d, row, bias, acc) },
    }
}

/// Strip dots at an explicit dispatch level (non-x86: always scalar).
#[cfg(not(target_arch = "x86_64"))]
pub fn strip_dots_at(
    _level: SimdLevel,
    x: &[f32],
    d: usize,
    row: &[f32],
    bias: f32,
    acc: &mut [f32],
) {
    strip_dots_scalar(x, d, row, bias, acc)
}

/// Scalar strip body: the canonical dot per activation row, bias
/// added once per output element.
fn strip_dots_scalar(x: &[f32], d: usize, row: &[f32], bias: f32, acc: &mut [f32]) {
    for (i, av) in acc.iter_mut().enumerate() {
        *av = dot_scalar(&x[i * d..(i + 1) * d], row) + bias;
    }
}

/// A 16-entry level table rescaled to i8 for `pshufb` decode:
/// `i8s[c] = levels[c] * 2^k` exactly, with the smallest such `k`.
/// Entry 15 is 0 for the registered 15-level tables (code 15 is
/// rejected at load, so the slot is never read back).
#[derive(Debug, Clone, Copy)]
pub struct NibbleTable {
    /// `levels[c] = i8s[c] * 2^-k`.
    pub k: i32,
    pub i8s: [i8; 16],
}

impl NibbleTable {
    /// Integerize a level table, or `None` if no `k <= 6` makes every
    /// level an exact i8 (all registered tables qualify: e2m1 k=1,
    /// e3m0 k=2, int4 k=0).
    pub fn for_levels(levels: &[f32]) -> Option<NibbleTable> {
        if levels.len() > 16 {
            return None;
        }
        'outer: for k in 0..=6i32 {
            let mul = exp2i(k);
            let mut i8s = [0i8; 16];
            for (c, &l) in levels.iter().enumerate() {
                let v = l * mul;
                if v != v.trunc() || !(-128.0..=127.0).contains(&v) {
                    continue 'outer;
                }
                i8s[c] = v as i8;
            }
            return Some(NibbleTable { k, i8s });
        }
        None
    }
}

/// Decode one full weight row of `w` (row `r`, `w.cols()` elements)
/// into `out`, bit-identical to `w.level(w.code(j)) * scale` per
/// element, at the tensor's own group geometry. SIMD decode is used
/// per group when the geometry is MX (1x32, E8M0 — `NibbleTable`
/// folds the scale back as a power of two, which E4M3 scales are
/// not), the group is full, starts on an even flat index (whole
/// bytes), and its scale is an in-range power of two; every other
/// group (NVFP4 geometry, ragged tails, rows at odd nibble offsets,
/// E8M0 byte 255, non-power-of-two per-tensor scales) falls back to
/// the scalar decode of exactly that group.
pub fn decode_row(
    level: SimdLevel,
    table: Option<&NibbleTable>,
    w: &PackedMx,
    r: usize,
    pt_simd_scale: Option<f32>,
    out: &mut [f32],
) {
    let d = w.cols();
    debug_assert_eq!(out.len(), d);
    let gpr = w.groups_per_row();
    let gs = w.geom().group_size();
    let mx_geom = w.geom() == GroupGeom::mx();
    let grouped = w.num_groups() > 0;
    let row0 = r * d;
    for k in 0..gpr {
        let a = row0 + k * gs;
        let b = row0 + ((k + 1) * gs).min(d);
        let glen = b - a;
        let (scale, simd_scale) = if grouped {
            let g = r * gpr + k;
            // group_scale_exp is E8M0-only; E4M3 geometries always
            // take the scalar path.
            let ss = if mx_geom {
                let e = w.group_scale_exp(g);
                table.and_then(|t| (e <= 127).then(|| exp2i(e - t.k)))
            } else {
                None
            };
            (w.group_scale(g), ss)
        } else {
            (w.tensor_scale(), pt_simd_scale)
        };
        let dst = &mut out[k * gs..k * gs + glen];
        #[cfg(target_arch = "x86_64")]
        if level != SimdLevel::Off && glen == GROUP && a % 2 == 0 {
            if let (Some(t), Some(ss)) = (table, simd_scale) {
                let codes = w.codes()[a / 2..a / 2 + GROUP / 2].as_ptr();
                // Safety: 16 code bytes in bounds, 32 f32 out slots,
                // and `level` is clamped to `detected()` by callers.
                unsafe {
                    match level {
                        SimdLevel::Avx2 => x86::decode32_avx2(codes, &t.i8s, ss, dst),
                        _ => x86::decode32_ssse3(codes, &t.i8s, ss, dst),
                    }
                }
                continue;
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        let _ = (level, simd_scale);
        for (j, o) in dst.iter_mut().enumerate() {
            *o = w.level(w.code(a + j)) * scale;
        }
    }
}

/// The per-tensor SIMD scale for `decode_row`, or `None` when the
/// integerized decode cannot reproduce `level * tensor_scale`
/// bit-exactly (only possible for hand-built stores: int4, the one
/// per-tensor quantizer, has `k == 0` and is always exact).
pub fn per_tensor_simd_scale(table: Option<&NibbleTable>, w: &PackedMx) -> Option<f32> {
    let t = table?;
    if w.num_groups() > 0 {
        return None;
    }
    let ts = w.tensor_scale();
    if t.k == 0 {
        return Some(ts);
    }
    let s = ts * exp2i(-t.k);
    (s * exp2i(t.k) == ts).then_some(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::int4::INT4_LEVELS;
    use crate::quant::{e2m1, e3m0};

    #[test]
    fn level_order_and_parse() {
        assert!(SimdLevel::Off < SimdLevel::Ssse3 && SimdLevel::Ssse3 < SimdLevel::Avx2);
        assert_eq!(SimdLevel::parse("AVX2"), Some(SimdLevel::Avx2));
        assert_eq!(SimdLevel::parse(" off "), Some(SimdLevel::Off));
        assert_eq!(SimdLevel::parse("ssse3"), Some(SimdLevel::Ssse3));
        assert_eq!(SimdLevel::parse("banana"), None);
        for l in [SimdLevel::Off, SimdLevel::Ssse3, SimdLevel::Avx2] {
            assert_eq!(SimdLevel::from_id(l.id()), l);
            assert_eq!(SimdLevel::parse(l.as_str()), Some(l));
        }
    }

    #[test]
    fn detected_is_stable_and_off_is_always_available() {
        assert_eq!(detected(), detected());
        assert!(available(SimdLevel::Off));
        assert!(active() <= detected(), "active level must be executable");
    }

    #[test]
    fn override_clamps_to_detected() {
        // Numerics are level-independent, so flipping the process-wide
        // override around other tests is observable only as speed.
        set_override(Some(SimdLevel::Off));
        assert_eq!(active(), SimdLevel::Off);
        set_override(Some(SimdLevel::Avx2));
        assert_eq!(active(), SimdLevel::Avx2.min(detected()));
        set_override(None);
        assert!(active() <= detected());
    }

    #[test]
    fn nibble_tables_integerize_all_registered_level_tables() {
        let t = NibbleTable::for_levels(&e2m1().levels).unwrap();
        assert_eq!(t.k, 1, "e2m1 levels * 2 are integers");
        assert_eq!(t.i8s[e2m1().levels.iter().position(|&l| l == 6.0).unwrap()], 12);
        let t = NibbleTable::for_levels(&e3m0().levels).unwrap();
        assert_eq!(t.k, 2, "e3m0 levels * 4 are integers");
        let t = NibbleTable::for_levels(&INT4_LEVELS).unwrap();
        assert_eq!(t.k, 0, "int4 levels are already integers");
        assert_eq!(t.i8s[0], -7);
        assert!(NibbleTable::for_levels(&[0.3]).is_none(), "0.3 never integerizes");
    }

    #[test]
    fn scaled_int_decode_is_bit_exact_for_every_level_and_exponent() {
        // (K*L) * 2^(e-k) == L * 2^e for every level of every table and
        // every representable E8M0 exponent, including deep subnormal
        // results — both sides are one correctly-rounded multiply of
        // the same real value.
        for levels in [&e2m1().levels[..], &e3m0().levels[..], &INT4_LEVELS[..]] {
            let t = NibbleTable::for_levels(levels).unwrap();
            for e in -127..=127i32 {
                let (scale, simd_scale) = (exp2i(e), exp2i(e - t.k));
                for (c, &l) in levels.iter().enumerate() {
                    let want = l * scale;
                    let got = t.i8s[c] as f32 * simd_scale;
                    assert_eq!(got.to_bits(), want.to_bits(), "level {l} e {e} k {}", t.k);
                }
            }
        }
    }

    #[test]
    fn dot_scalar_matches_lane_model() {
        // d = 11: one full 8-chunk + a 3-element tail.
        let x: Vec<f32> = (0..11).map(|i| (i as f32 * 0.37).sin()).collect();
        let w: Vec<f32> = (0..11).map(|i| (i as f32 * 0.81).cos()).collect();
        let mut lanes = [0.0f32; LANES];
        for j in 0..11 {
            lanes[j % LANES] += x[j] * w[j];
        }
        assert_eq!(dot_scalar(&x, &w), reduce_lanes(&lanes));
        assert_eq!(dot_scalar(&[], &[]), 0.0);
    }

    #[test]
    fn dot_at_is_bit_identical_across_available_levels() {
        let x: Vec<f32> = (0..57).map(|i| ((i * 37) % 61) as f32 / 7.0 - 4.0).collect();
        let w: Vec<f32> = (0..57).map(|i| ((i * 17) % 29) as f32 / 3.0 - 4.0).collect();
        let want = dot_scalar(&x, &w);
        for level in [SimdLevel::Ssse3, SimdLevel::Avx2] {
            if available(level) {
                assert_eq!(dot_at(level, &x, &w).to_bits(), want.to_bits(), "{level:?}");
            }
        }
    }

    #[test]
    fn strip_dots_matches_per_dot_form_at_every_level() {
        // d = 57: seven full 8-chunks + a 1-element tail per dot.
        let (n, d) = (5usize, 57usize);
        let x: Vec<f32> = (0..n * d).map(|i| ((i * 37) % 61) as f32 / 7.0 - 4.0).collect();
        let row: Vec<f32> = (0..d).map(|i| ((i * 17) % 29) as f32 / 3.0 - 4.0).collect();
        for bias in [0.0f32, -1.25] {
            let want: Vec<f32> =
                (0..n).map(|i| dot_scalar(&x[i * d..(i + 1) * d], &row) + bias).collect();
            for level in [SimdLevel::Off, SimdLevel::Ssse3, SimdLevel::Avx2] {
                if !available(level) {
                    continue;
                }
                let mut acc = vec![0.0f32; n];
                strip_dots_at(level, &x, d, &row, bias, &mut acc);
                for (g, w) in acc.iter().zip(&want) {
                    assert_eq!(g.to_bits(), w.to_bits(), "level {level:?} bias {bias}");
                }
            }
        }
    }
}
