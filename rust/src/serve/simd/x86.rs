//! x86-64 implementations of the canonical dot and the `pshufb`
//! nibble decode. Every function mirrors the scalar path's f32
//! operation sequence exactly — see the module doc of
//! [`super`](crate::serve::simd) for the order contract and why
//! hardware FMA is not used.

use std::arch::x86_64::*;

use super::{finish_dot, LANES};

/// Canonical dot on SSE2 (baseline x86-64, no runtime probe needed):
/// two 4-lane accumulators hold canonical lanes 0..4 and 4..8, stored
/// out and finished by the shared scalar epilogue.
#[inline]
pub fn dot_sse2(x: &[f32], w: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), w.len());
    let chunks = x.len() / LANES;
    let mut lanes = [0.0f32; LANES];
    unsafe {
        let (xp, wp) = (x.as_ptr(), w.as_ptr());
        let mut lo = _mm_setzero_ps();
        let mut hi = _mm_setzero_ps();
        for c in 0..chunks {
            let o = c * LANES;
            lo = _mm_add_ps(lo, _mm_mul_ps(_mm_loadu_ps(xp.add(o)), _mm_loadu_ps(wp.add(o))));
            hi = _mm_add_ps(
                hi,
                _mm_mul_ps(_mm_loadu_ps(xp.add(o + 4)), _mm_loadu_ps(wp.add(o + 4))),
            );
        }
        _mm_storeu_ps(lanes.as_mut_ptr(), lo);
        _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi);
    }
    finish_dot(lanes, x, w, chunks * LANES)
}

/// Canonical dot on AVX2: one 8-lane accumulator, `mul` + `add` (not
/// `fmadd`), stored out and finished by the shared scalar epilogue.
///
/// # Safety
/// The host must support AVX2 (callers clamp to `detected()`).
#[inline]
#[target_feature(enable = "avx2")]
pub unsafe fn dot_avx2(x: &[f32], w: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), w.len());
    let chunks = x.len() / LANES;
    let mut lanes = [0.0f32; LANES];
    let (xp, wp) = (x.as_ptr(), w.as_ptr());
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let o = c * LANES;
        acc = _mm256_add_ps(
            acc,
            _mm256_mul_ps(_mm256_loadu_ps(xp.add(o)), _mm256_loadu_ps(wp.add(o))),
        );
    }
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    finish_dot(lanes, x, w, chunks * LANES)
}

/// Canonical dots of every row of `x (n, d)` against one weight row
/// on the SSE2 path, bias added once per output. SSE2 is baseline
/// x86-64, so `dot_sse2` inlines here freely.
pub fn strip_dots_sse2(x: &[f32], d: usize, row: &[f32], bias: f32, acc: &mut [f32]) {
    for (i, av) in acc.iter_mut().enumerate() {
        *av = dot_sse2(&x[i * d..(i + 1) * d], row) + bias;
    }
}

/// Canonical dots of every row of `x (n, d)` against one weight row
/// on AVX2. This strip is the dispatch boundary: `dot_avx2` and the
/// shared epilogue inline into this one `#[target_feature]` body, so
/// every f32 op compiles to VEX and the SSE<->AVX transition cost is
/// paid once per strip, not once per dot (the per-dot structure
/// measured ~18x slower — see `super::strip_dots_at`).
///
/// # Safety
/// The host must support AVX2 (callers clamp to `detected()`).
#[target_feature(enable = "avx2")]
pub unsafe fn strip_dots_avx2(x: &[f32], d: usize, row: &[f32], bias: f32, acc: &mut [f32]) {
    for (i, av) in acc.iter_mut().enumerate() {
        *av = dot_avx2(&x[i * d..(i + 1) * d], row) + bias;
    }
}

/// Split 16 packed code bytes into two 16-lane nibble index vectors in
/// flat element order: low nibbles are even elements, high nibbles odd,
/// so `unpack(lo, hi)` interleaves them back to `e0, e1, e2, ...`.
#[inline(always)]
unsafe fn nibble_indices(codes: *const u8) -> (__m128i, __m128i) {
    let raw = _mm_loadu_si128(codes as *const __m128i);
    let mask = _mm_set1_epi8(0x0F);
    let lo = _mm_and_si128(raw, mask);
    let hi = _mm_and_si128(_mm_srli_epi16::<4>(raw), mask);
    (_mm_unpacklo_epi8(lo, hi), _mm_unpackhi_epi8(lo, hi))
}

/// Decode one full 32-element group on SSSE3: `pshufb` maps 16 codes
/// through the integerized level table at once, SSE2 unpack+shift
/// sign-extends i8 -> i32, and one broadcast multiply by
/// `scale * 2^-k` lands the exact dequantized values.
///
/// # Safety
/// `codes` must point at 16 readable bytes, `out` at 32 writable
/// f32s, and the host must support SSSE3.
#[target_feature(enable = "ssse3")]
pub unsafe fn decode32_ssse3(codes: *const u8, table: &[i8; 16], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), 32);
    let t = _mm_loadu_si128(table.as_ptr() as *const __m128i);
    let sv = _mm_set1_ps(scale);
    let (idx_a, idx_b) = nibble_indices(codes);
    let op = out.as_mut_ptr();
    for (half, idx) in [idx_a, idx_b].into_iter().enumerate() {
        let v = _mm_shuffle_epi8(t, idx);
        // i8 -> i16 -> i32 sign extension via duplicate + arithmetic
        // shift (SSE2; _mm_cvtepi8_epi32 would need SSE4.1).
        let w_lo = _mm_srai_epi16::<8>(_mm_unpacklo_epi8(v, v));
        let w_hi = _mm_srai_epi16::<8>(_mm_unpackhi_epi8(v, v));
        let quads = [
            _mm_srai_epi32::<16>(_mm_unpacklo_epi16(w_lo, w_lo)),
            _mm_srai_epi32::<16>(_mm_unpackhi_epi16(w_lo, w_lo)),
            _mm_srai_epi32::<16>(_mm_unpacklo_epi16(w_hi, w_hi)),
            _mm_srai_epi32::<16>(_mm_unpackhi_epi16(w_hi, w_hi)),
        ];
        for (q, ints) in quads.into_iter().enumerate() {
            let vals = _mm_mul_ps(_mm_cvtepi32_ps(ints), sv);
            _mm_storeu_ps(op.add(half * 16 + q * 4), vals);
        }
    }
}

/// Decode one full 32-element group on AVX2: same `vpshufb` table
/// lookup, widened 8 lanes at a time with `vpmovsxbd`.
///
/// # Safety
/// Same contract as [`decode32_ssse3`], host must support AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn decode32_avx2(codes: *const u8, table: &[i8; 16], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), 32);
    let t = _mm_loadu_si128(table.as_ptr() as *const __m128i);
    let sv = _mm256_set1_ps(scale);
    let (idx_a, idx_b) = nibble_indices(codes);
    let op = out.as_mut_ptr();
    for (half, idx) in [idx_a, idx_b].into_iter().enumerate() {
        let v = _mm_shuffle_epi8(t, idx);
        let ints_lo = _mm256_cvtepi8_epi32(v);
        let ints_hi = _mm256_cvtepi8_epi32(_mm_unpackhi_epi64(v, v));
        _mm256_storeu_ps(op.add(half * 16), _mm256_mul_ps(_mm256_cvtepi32_ps(ints_lo), sv));
        _mm256_storeu_ps(op.add(half * 16 + 8), _mm256_mul_ps(_mm256_cvtepi32_ps(ints_hi), sv));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::simd::{available, dot_scalar, NibbleTable, SimdLevel};

    #[test]
    fn sse2_dot_matches_scalar_bitwise() {
        for d in [0usize, 1, 7, 8, 9, 32, 57, 96] {
            let x: Vec<f32> = (0..d).map(|i| ((i * 37) % 61) as f32 / 7.0 - 4.0).collect();
            let w: Vec<f32> = (0..d).map(|i| ((i * 53) % 47) as f32 / 5.0 - 4.0).collect();
            assert_eq!(dot_sse2(&x, &w).to_bits(), dot_scalar(&x, &w).to_bits(), "d={d}");
        }
    }

    #[test]
    fn decoders_match_scalar_table_lookup() {
        let levels = &crate::quant::e2m1().levels;
        let t = NibbleTable::for_levels(levels).unwrap();
        // 32 codes covering every valid nibble 0..=14, packed 2/byte.
        let codes: Vec<u8> = (0..16u8).map(|i| ((i * 2 % 15) << 4) | ((i * 7 + 1) % 15)).collect();
        let flat = |i: usize| (codes[i / 2] >> ((i % 2) * 4)) & 0x0F;
        for e in [-130i32, -8, 0, 9, 127] {
            let scale = crate::quant::formats::exp2i(e);
            let simd_scale = crate::quant::formats::exp2i(e - t.k);
            let want: Vec<f32> = (0..32).map(|i| levels[flat(i) as usize] * scale).collect();
            let mut got = vec![0.0f32; 32];
            if available(SimdLevel::Ssse3) {
                unsafe { decode32_ssse3(codes.as_ptr(), &t.i8s, simd_scale, &mut got) };
                assert_eq!(got, want, "ssse3 e={e}");
            }
            if available(SimdLevel::Avx2) {
                got.fill(0.0);
                unsafe { decode32_avx2(codes.as_ptr(), &t.i8s, simd_scale, &mut got) };
                assert_eq!(got, want, "avx2 e={e}");
            }
        }
    }
}
