//! The batched serving engine: owns a [`PackedVit`], micro-batches
//! incoming images through the fused forward, and exposes the same
//! eval semantics as the trainer so accuracy parity is directly
//! checkable (`tetrajet eval --packed` vs the HLO eval path).

use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::coordinator::EvalResult;
use crate::data::EvalSet;
use crate::obs::{KernelMetrics, MetricsRegistry};
use crate::serve::act::ActQuantCache;
use crate::serve::model::PackedVit;
use crate::util::parallel::default_workers;

/// Serving knobs, shared by the single-engine session, the fleet, and
/// both CLI subcommands (`serve` and `eval --packed` route through the
/// same [`builder`](ServeConfig::builder), so the flag sets cannot
/// diverge). Construct via the builder — it validates at build time
/// instead of panicking mid-serve.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Maximum images per forward call; larger requests are split.
    pub micro_batch: usize,
    /// Threads for the row-parallel fused kernel.
    pub workers: usize,
    /// Row-sharded engines in the fleet (1 = single-engine).
    pub engines: usize,
    /// Admission-queue bound, in images (backpressure beyond it).
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            micro_batch: 16,
            workers: default_workers(),
            engines: 1,
            queue_depth: 256,
        }
    }
}

impl ServeConfig {
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder { cfg: ServeConfig::default() }
    }

    /// Reject zero/contradictory settings up front.
    pub fn validate(&self) -> Result<()> {
        if self.micro_batch == 0 {
            bail!("micro_batch must be >= 1");
        }
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.engines == 0 {
            bail!("engines must be >= 1");
        }
        if self.queue_depth < self.micro_batch {
            bail!(
                "queue_depth {} < micro_batch {}: a full micro-batch could never be admitted",
                self.queue_depth,
                self.micro_batch
            );
        }
        Ok(())
    }
}

/// Chainable, validating constructor for [`ServeConfig`].
#[derive(Debug, Clone)]
pub struct ServeConfigBuilder {
    cfg: ServeConfig,
}

impl ServeConfigBuilder {
    pub fn micro_batch(mut self, n: usize) -> Self {
        self.cfg.micro_batch = n;
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n;
        self
    }

    pub fn engines(mut self, n: usize) -> Self {
        self.cfg.engines = n;
        self
    }

    pub fn queue_depth(mut self, n: usize) -> Self {
        self.cfg.queue_depth = n;
        self
    }

    pub fn build(self) -> Result<ServeConfig> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

/// Forward-only inference engine over packed weights.
pub struct ServeEngine {
    model: PackedVit,
    pub cfg: ServeConfig,
    /// Per-layer fused-GEMM instrumentation; detached until
    /// [`instrument`](Self::instrument) attaches a shared registry.
    kernel: KernelMetrics,
    /// Q1 activation memoization, shareable across engines (the
    /// `--verify-mirror` pair shares one so the mirror pass reuses the
    /// fused engine's quantizations).
    act_cache: Arc<Mutex<ActQuantCache>>,
}

impl ServeEngine {
    pub fn new(model: PackedVit, cfg: ServeConfig) -> Result<ServeEngine> {
        cfg.validate()?;
        let act_cache = Arc::new(Mutex::new(ActQuantCache::new(model.geom.depth * 4)));
        Ok(ServeEngine { model, cfg, kernel: KernelMetrics::detached(), act_cache })
    }

    /// Re-home the engine's kernel metrics into `reg` (the session does
    /// this so `kernel.{layer}.calls/.ms` land in its registry), along
    /// with the activation cache's `kernel.actq.{hits,misses}`.
    pub fn instrument(&mut self, reg: &MetricsRegistry) {
        self.kernel = KernelMetrics::in_registry(reg);
        self.act_cache.lock().unwrap().attach(reg);
    }

    /// Adopt `other`'s activation-quant cache, so bit-identical Q1
    /// inputs seen by either engine hit the same memoized bytes.
    pub fn share_act_cache(&mut self, other: &ServeEngine) {
        self.act_cache = Arc::clone(&other.act_cache);
    }

    /// `(hits, misses)` of the engine's activation-quant cache.
    pub fn act_cache_stats(&self) -> (u64, u64) {
        self.act_cache.lock().unwrap().stats()
    }

    /// The engine's per-layer GEMM instrumentation handles.
    pub fn kernel_metrics(&self) -> &KernelMetrics {
        &self.kernel
    }

    pub fn model(&self) -> &PackedVit {
        &self.model
    }

    /// Pixels per image expected by [`infer_logits`](Self::infer_logits).
    pub fn pixels_per_image(&self) -> usize {
        let g = &self.model.geom;
        g.img * g.img * 3
    }

    pub fn classes(&self) -> usize {
        self.model.geom.classes
    }

    /// Logits for `n` images, micro-batched through the fused forward.
    pub fn infer_logits(&self, images: &[f32], n: usize) -> Vec<f32> {
        let px = self.pixels_per_image();
        assert_eq!(images.len(), n * px, "images must be (n, img, img, 3)");
        let classes = self.classes();
        let mut logits = Vec::with_capacity(n * classes);
        let mut done = 0;
        while done < n {
            let m = self.cfg.micro_batch.min(n - done);
            let chunk = &images[done * px..(done + m) * px];
            logits.extend(self.eval_logits(chunk, m));
            done += m;
        }
        logits
    }

    /// One instrumented forward over `n` images through the engine's
    /// activation cache (no micro-batching — the caller owns the batch
    /// shape). This is the per-batch unit `eval` runs and the hook
    /// `--verify-mirror` uses to compare fused vs mirror logits
    /// bitwise.
    pub fn eval_logits(&self, images: &[f32], n: usize) -> Vec<f32> {
        let mut cache = self.act_cache.lock().unwrap();
        self.model.forward_cached(images, n, self.cfg.workers, &self.kernel, &mut cache)
    }

    /// Predicted class per image (first-max argmax, like jnp.argmax).
    pub fn predict(&self, images: &[f32], n: usize) -> Vec<usize> {
        argmax_rows(&self.infer_logits(images, n), self.classes())
    }

    /// Full validation pass with the trainer's eval semantics: per
    /// batch, sum of cross-entropy losses and count of correct top-1
    /// predictions; aggregated exactly like
    /// [`Trainer::eval`](crate::coordinator::Trainer::eval).
    pub fn eval(&self, evalset: &EvalSet) -> EvalResult {
        let nb = evalset.num_batches();
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for b in 0..nb {
            let (x, y) = evalset.batch(b);
            let batch = y.len();
            let logits = self.eval_logits(&x, batch);
            let (ls, c) = batch_loss_correct(&logits, &y, self.classes());
            loss_sum += ls as f64;
            correct += c as f64;
        }
        let n = evalset.num_samples().max(1);
        EvalResult {
            acc_pct: 100.0 * correct / n as f64,
            mean_loss: loss_sum / n as f64,
            samples: n,
        }
    }

    /// Resident bytes of the engine's quantized weights — codes +
    /// scales when fully packed; the no-f32-mirror guarantee is
    /// asserted against this in tests.
    pub fn resident_weight_bytes(&self) -> usize {
        self.model.quantized_weight_bytes()
    }
}

/// First-max argmax of one logit row (the jnp.argmax tie rule).
fn argmax_row(row: &[f32]) -> usize {
    let mut best = 0;
    for (j, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = j;
        }
    }
    best
}

/// Row-wise first-max argmax over a (n, classes) logit block.
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<usize> {
    logits.chunks_exact(classes).map(argmax_row).collect()
}

/// Sum of cross-entropy losses + correct count for one batch (mirror of
/// the eval_step HLO: log-softmax with max subtraction, f32 sums).
/// Public so `--verify-mirror` can aggregate the trainer-parity eval
/// while comparing per-batch logits itself.
pub fn batch_loss_correct(logits: &[f32], y: &[i32], classes: usize) -> (f32, f32) {
    let mut loss_sum = 0.0f32;
    let mut correct = 0.0f32;
    for (row, &label) in logits.chunks_exact(classes).zip(y) {
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let lse = m + row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln();
        loss_sum += lse - row[label as usize];
        if argmax_row(row) == label as usize {
            correct += 1.0;
        }
    }
    (loss_sum, correct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthVision;
    use crate::quant::{e2m1, Scaling};
    use crate::serve::model::{ActQuant, PackedVit, ServeGeom, WeightQuant};
    use crate::util::rng::Rng;

    fn tiny_engine(micro_batch: usize) -> ServeEngine {
        let geom = ServeGeom::new(8, 4, 32, 2, 4, 3, 4);
        let mut rng = Rng::new(7);
        let params: Vec<f32> = (0..geom.total_params()).map(|_| rng.normal() * 0.05).collect();
        let fmt = e2m1();
        let model = PackedVit::build(
            geom,
            &params,
            None,
            WeightQuant::Mx { fmt, scaling: Scaling::TruncationFree },
            ActQuant::Mx { fmt, scaling: Scaling::TruncationFree },
        )
        .unwrap();
        let cfg = ServeConfig::builder().micro_batch(micro_batch).workers(2).build().unwrap();
        ServeEngine::new(model, cfg).unwrap()
    }

    #[test]
    fn micro_batching_is_transparent_for_mx() {
        // MX activation groups are per token row, so splitting a
        // request across micro-batches cannot change any logit.
        let e1 = tiny_engine(1);
        let e4 = tiny_engine(4);
        let mut rng = Rng::new(1);
        let n = 5;
        let x: Vec<f32> = (0..n * e1.pixels_per_image()).map(|_| rng.normal()).collect();
        assert_eq!(e1.infer_logits(&x, n), e4.infer_logits(&x, n));
        assert_eq!(e1.predict(&x, n).len(), n);
    }

    #[test]
    fn eval_runs_on_synth_data() {
        let e = tiny_engine(4);
        let ds = SynthVision::new(8, 3, 1, 64, 32);
        let ev = crate::data::EvalSet::new(ds, 4, 16);
        let r = e.eval(&ev);
        assert_eq!(r.samples, 16);
        assert!(r.acc_pct >= 0.0 && r.acc_pct <= 100.0);
        assert!(r.mean_loss.is_finite());
    }

    #[test]
    fn kernel_metrics_count_gemms_without_perturbing_logits() {
        let mut e = tiny_engine(4);
        let reg = MetricsRegistry::new();
        e.instrument(&reg);
        let mut rng = Rng::new(9);
        let n = 6;
        let x: Vec<f32> = (0..n * e.pixels_per_image()).map(|_| rng.normal()).collect();
        let observed = e.infer_logits(&x, n);
        // Instrumentation must be a bit-exact passthrough.
        assert_eq!(observed, e.model().forward(&x, n, e.cfg.workers));
        // depth=2 blocks, micro_batch=4 -> 2 forwards -> 4 calls/layer.
        for layer in crate::obs::LAYER_NAMES {
            assert_eq!(
                reg.counter(&format!("kernel.{layer}.calls")).get(),
                4,
                "{layer} call count"
            );
            assert!(reg.fcounter(&format!("kernel.{layer}.ms")).get() >= 0.0);
        }
    }

    #[test]
    fn shared_act_cache_turns_mirror_pass_into_hits() {
        let e = tiny_engine(4);
        let mut mirror = ServeEngine::new(e.model().to_dense(), e.cfg).unwrap();
        mirror.share_act_cache(&e);
        let mut rng = Rng::new(17);
        let n = 4;
        let x: Vec<f32> = (0..n * e.pixels_per_image()).map(|_| rng.normal()).collect();
        let a = e.eval_logits(&x, n);
        // depth=2 blocks x 4 Q1 sites: all cold.
        assert_eq!(e.act_cache_stats(), (0, 8));
        let b = mirror.eval_logits(&x, n);
        assert_eq!(a, b, "mirror logits must be bit-exact to fused");
        // The mirror saw bit-identical Q1 inputs, so its whole
        // quantization pass replayed from the shared cache.
        assert_eq!(mirror.act_cache_stats(), (8, 8));
    }

    #[test]
    fn argmax_is_first_max() {
        assert_eq!(argmax_rows(&[1.0, 3.0, 3.0, 0.0, -1.0, -1.0], 3), vec![1, 0]);
    }

    #[test]
    fn builder_rejects_zero_and_contradictory_settings() {
        assert!(ServeConfig::builder().micro_batch(0).build().is_err());
        assert!(ServeConfig::builder().workers(0).build().is_err());
        assert!(ServeConfig::builder().engines(0).build().is_err());
        // A queue shallower than one micro-batch can never fill one.
        assert!(ServeConfig::builder().micro_batch(8).queue_depth(4).build().is_err());
        let cfg = ServeConfig::builder()
            .micro_batch(8)
            .workers(3)
            .engines(2)
            .queue_depth(32)
            .build()
            .unwrap();
        assert_eq!(
            (cfg.micro_batch, cfg.workers, cfg.engines, cfg.queue_depth),
            (8, 3, 2, 32)
        );
        let e = tiny_engine(4);
        let model = e.model().clone();
        assert!(ServeEngine::new(
            model,
            ServeConfig { micro_batch: 0, ..ServeConfig::default() }
        )
        .is_err());
    }
}
