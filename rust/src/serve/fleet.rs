//! The continuous-batching serve fleet: N row-sharded engines behind
//! mpsc work queues, one scheduler forming micro-batches across
//! request boundaries, and scatter/gather at the fused kernel's
//! row-parallel seam.
//!
//! Sharding: [`PackedVit::into_shards`] splits each depth-stacked
//! quantized weight tensor into contiguous row ranges at the
//! code/scale-byte level, one [`VitShard`] per engine. Each engine is
//! a worker thread looping on an mpsc receiver; for every quantized
//! linear the coordinator broadcasts the activation block ([`Arc`]d,
//! no copies) to the engines whose row range intersects the requested
//! slice, then gathers their output-column blocks and adds the bias
//! once. Because each shard's kernel decodes exactly the bytes the
//! single-engine kernel would, and the gather writes each column slice
//! where the single kernel would have, fleet logits are bit-exact to
//! the single-engine path (property-tested, ragged splits included).
//!
//! Scheduling is clock-free ([`Scheduler`]): the fleet threads time
//! through `*_at` methods, so the open-loop load generator
//! ([`crate::serve::load`]) can drive it on a virtual clock and get a
//! deterministic admission/rejection/latency trace for a given seed.

use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::obs::{
    Counter, FCounter, Histo, KernelMetrics, MetricsRegistry, TraceSink, TsRing,
};
use crate::serve::act::ActQuantCache;
use crate::serve::engine::ServeConfig;
use crate::serve::model::{LinearExec, ObservedExec, PackedVit, ServeGeom, VitShard};
use crate::serve::scheduler::{Completions, Outcome, Reject, SchedMetrics, Scheduler, Ticket};
use crate::serve::stats::LatencySummary;
use crate::util::json::num;

/// Trace thread ids: request/scheduler events vs fleet execution.
const TID_REQUEST: u64 = 0;
const TID_EXEC: u64 = 1;

/// Window of the per-engine busy-ratio rings: recent batches only, so
/// the surface stays O(engines × window) no matter how long the fleet
/// runs.
const BUSY_RING_CAP: usize = 256;

/// Fleet-level instrumentation handles.
#[derive(Debug, Clone)]
pub struct FleetMetrics {
    /// Executed micro-batch sizes (`fleet.batch_images`).
    pub batch_images: Histo,
    /// Coordinator time blocked on engine replies (`fleet.gather_wait_ms`).
    pub gather_wait_ms: FCounter,
    /// Steps that executed a batch (`fleet.steps`).
    pub steps: Counter,
    /// Per-engine forward time (`fleet.engine{e}.busy_ms`).
    pub engine_busy_ms: Vec<FCounter>,
    /// Rolling per-batch busy ratio — the slice of each executed
    /// batch's compute time this engine spent in its kernel
    /// (`fleet.engine{e}.busy_ratio`, last [`BUSY_RING_CAP`] batches).
    pub engine_busy_ratio: Vec<TsRing>,
    /// Per-layer fused-GEMM calls/time (`kernel.{layer}.*`).
    pub kernel: KernelMetrics,
}

impl FleetMetrics {
    fn in_registry(reg: &MetricsRegistry, engines: usize) -> FleetMetrics {
        FleetMetrics {
            batch_images: reg
                .histogram("fleet.batch_images", &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0]),
            gather_wait_ms: reg.fcounter("fleet.gather_wait_ms"),
            steps: reg.counter("fleet.steps"),
            engine_busy_ms: (0..engines)
                .map(|e| reg.fcounter(&format!("fleet.engine{e}.busy_ms")))
                .collect(),
            engine_busy_ratio: (0..engines)
                .map(|e| reg.ring(&format!("fleet.engine{e}.busy_ratio"), BUSY_RING_CAP))
                .collect(),
            kernel: KernelMetrics::in_registry(reg),
        }
    }
}

/// Work item for an engine thread: one row-slice of one quantized
/// linear over a shared activation block.
enum Job {
    Linear {
        store: usize,
        x: Arc<Vec<f32>>,
        n: usize,
        /// Global row range, fully inside the engine's shard.
        grow0: usize,
        rows: usize,
        reply: Sender<(usize, Vec<f32>)>,
    },
    Stop,
}

/// One engine: a worker thread owning a [`VitShard`], fed over mpsc.
struct EngineHandle {
    tx: Sender<Job>,
    /// Global (start, end) row range per store, for intersection.
    ranges: [(usize, usize); 4],
    shard_bytes: usize,
    join: Option<JoinHandle<()>>,
}

/// What one [`ServeFleet::step_at`] did.
#[derive(Debug, Clone, Copy)]
pub struct StepInfo {
    /// Images in the executed batch (0 for an expiry-only step).
    pub m: usize,
    /// Batch completion time on the caller's clock.
    pub done_ms: f64,
    /// Measured forward compute time.
    pub compute_ms: f64,
}

/// N row-sharded engines + scheduler + completion routing.
pub struct ServeFleet {
    trunk: PackedVit,
    engines: Vec<EngineHandle>,
    cfg: ServeConfig,
    sched: Scheduler,
    done: Completions,
    clock: Instant,
    reg: MetricsRegistry,
    obs: FleetMetrics,
    /// Coordinator-side Q1 memoization (`kernel.actq.{hits,misses}`);
    /// the activation quant runs on the trunk before the scatter, so
    /// one cache covers every engine.
    act_cache: ActQuantCache,
    trace: Option<TraceSink>,
    /// Print a one-line `METRICS {...}` snapshot every N executed
    /// batches (0 = off).
    snapshot_every: u64,
    batch_seq: u64,
    /// `fleet.engine{e}.busy_ms` as of the previous executed batch, so
    /// each batch's busy-ratio sample is a delta, not a running total.
    last_busy: Vec<f64>,
}

impl ServeFleet {
    /// Shard `vit` across `cfg.engines` worker threads.
    pub fn new(vit: PackedVit, cfg: ServeConfig) -> Result<ServeFleet> {
        cfg.validate()?;
        let g = &vit.geom;
        let px = g.img * g.img * 3;
        let classes = g.classes;
        let reg = MetricsRegistry::new();
        let obs = FleetMetrics::in_registry(&reg, cfg.engines);
        let (trunk, shards) = vit.into_shards(cfg.engines)?;
        let mut engines = Vec::with_capacity(shards.len());
        for (e, shard) in shards.into_iter().enumerate() {
            let ranges = [shard.range(0), shard.range(1), shard.range(2), shard.range(3)];
            let shard_bytes = shard.bytes();
            let workers = cfg.workers;
            let busy = obs.engine_busy_ms[e].clone();
            let (tx, rx) = channel::<Job>();
            let join = std::thread::Builder::new()
                .name(format!("tj-engine-{e}"))
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        match job {
                            Job::Linear { store, x, n, grow0, rows, reply } => {
                                let t0 = Instant::now();
                                let out = shard.linear(store, &x, n, grow0, rows, workers);
                                busy.add(t0.elapsed().as_secs_f64() * 1e3);
                                // A dropped gather (coordinator gone)
                                // just ends the loop's usefulness.
                                let _ = reply.send((e, out));
                            }
                            Job::Stop => break,
                        }
                    }
                })
                .with_context(|| format!("spawning engine thread {e}"))?;
            engines.push(EngineHandle { tx, ranges, shard_bytes, join: Some(join) });
        }
        let mut act_cache = ActQuantCache::new(trunk.geom.depth * 4);
        act_cache.attach(&reg);
        Ok(ServeFleet {
            trunk,
            engines,
            cfg,
            sched: Scheduler::with_metrics(px, cfg.queue_depth, SchedMetrics::in_registry(&reg)),
            done: Completions::in_registry(classes, &reg),
            clock: Instant::now(),
            reg,
            obs,
            act_cache,
            trace: None,
            snapshot_every: 0,
            batch_seq: 0,
            last_busy: vec![0.0; cfg.engines],
        })
    }

    /// The fleet's metrics registry (`sched.*`, `serve.*`, `fleet.*`,
    /// `kernel.*`). Clone it to share with an exposition endpoint.
    pub fn registry(&self) -> &MetricsRegistry {
        &self.reg
    }

    /// Attach a trace sink; request/batch lifecycle events flow into it
    /// from now on.
    pub fn set_trace(&mut self, sink: TraceSink) {
        self.trace = Some(sink);
    }

    /// Detach and return the trace sink (flush/digest at end of run).
    pub fn take_trace(&mut self) -> Option<TraceSink> {
        self.trace.take()
    }

    /// Digest of the events traced so far, if a sink is attached.
    pub fn trace_digest(&self) -> Option<String> {
        self.trace.as_ref().map(|t| t.digest())
    }

    /// Print a one-line `METRICS {...}` JSON snapshot every `every`
    /// executed batches (0 disables).
    pub fn set_snapshot_every(&mut self, every: u64) {
        self.snapshot_every = every;
    }

    pub fn engines(&self) -> usize {
        self.engines.len()
    }

    pub fn cfg(&self) -> &ServeConfig {
        &self.cfg
    }

    pub fn geom(&self) -> &ServeGeom {
        &self.trunk.geom
    }

    pub fn pixels_per_image(&self) -> usize {
        let g = self.geom();
        g.img * g.img * 3
    }

    pub fn classes(&self) -> usize {
        self.geom().classes
    }

    /// Resident quantized-weight bytes summed over all shards.
    pub fn shard_bytes(&self) -> usize {
        self.engines.iter().map(|e| e.shard_bytes).sum()
    }

    /// Milliseconds since the fleet started (the fleet's real clock).
    pub fn now_ms(&self) -> f64 {
        self.clock.elapsed().as_secs_f64() * 1e3
    }

    /// Admit a request at wall-clock now, deadline relative to now.
    pub fn submit(
        &mut self,
        images: Vec<f32>,
        n: usize,
        deadline_ms: Option<f64>,
    ) -> Result<Ticket, Reject> {
        let now = self.now_ms();
        self.submit_at(images, n, deadline_ms.map(|d| now + d), now)
    }

    /// Admit a request with explicit timestamps (virtual-clock path):
    /// `deadline_ms` is absolute on the same clock as `arrival_ms`.
    pub fn submit_at(
        &mut self,
        images: Vec<f32>,
        n: usize,
        deadline_ms: Option<f64>,
        arrival_ms: f64,
    ) -> Result<Ticket, Reject> {
        self.done.rec.note_arrival(arrival_ms);
        let r = self.sched.try_admit(images, n, deadline_ms, arrival_ms);
        if matches!(r, Err(Reject::QueueFull { .. })) {
            self.done.rec.record_reject();
        }
        if let Some(trace) = &mut self.trace {
            match &r {
                Ok(t) => trace.instant(
                    "admit",
                    arrival_ms,
                    TID_REQUEST,
                    vec![("id", num(t.id as f64)), ("n", num(n as f64))],
                ),
                Err(Reject::QueueFull { queued_images, limit }) => trace.instant(
                    "reject",
                    arrival_ms,
                    TID_REQUEST,
                    vec![
                        ("queued_images", num(*queued_images as f64)),
                        ("limit", num(*limit as f64)),
                    ],
                ),
                Err(Reject::BadRequest(_)) => {
                    trace.instant("reject", arrival_ms, TID_REQUEST, vec![])
                }
            }
        }
        r
    }

    pub fn pending(&self) -> usize {
        self.sched.pending_requests()
    }

    pub fn pending_images(&self) -> usize {
        self.sched.pending_images()
    }

    /// Arrival time of the oldest queued request.
    pub fn earliest_arrival(&self) -> Option<f64> {
        self.sched.earliest_arrival()
    }

    /// Form and run one micro-batch on the real clock. Returns false
    /// when there was nothing to do.
    pub fn step(&mut self) -> bool {
        let now = self.now_ms();
        self.step_at(now, None).is_some()
    }

    /// Form a batch at time `form_ms` on the caller's clock and run it
    /// across the engines. With `virtual_ms_per_image` set, completion
    /// is stamped at `form_ms + m * ms_per_image` (the load generator's
    /// deterministic virtual clock) while the forward still executes
    /// for real; otherwise completion is stamped off the fleet clock.
    /// `None` means nothing was runnable and nothing expired.
    pub fn step_at(
        &mut self,
        form_ms: f64,
        virtual_ms_per_image: Option<f64>,
    ) -> Option<StepInfo> {
        let (expired, plan) = self.sched.next_batch(self.cfg.micro_batch, form_ms);
        for e in &expired {
            self.done.on_expired(e);
            if let Some(trace) = &mut self.trace {
                trace.instant(
                    "expired",
                    form_ms,
                    TID_REQUEST,
                    vec![("id", num(e.id as f64)), ("deadline_ms", num(e.deadline_ms))],
                );
            }
        }
        let Some(plan) = plan else {
            return (!expired.is_empty())
                .then_some(StepInfo { m: 0, done_ms: form_ms, compute_ms: 0.0 });
        };
        let batch = self.batch_seq;
        self.batch_seq += 1;
        let gather0 = self.obs.gather_wait_ms.get();
        let t0 = Instant::now();
        let logits = {
            let exec = FleetExec {
                engines: &self.engines,
                gather_wait: &self.obs.gather_wait_ms,
            };
            let exec = ObservedExec { inner: &exec, kernel: &self.obs.kernel };
            self.trunk.forward_with_cache(&plan.images, plan.m, &exec, Some(&mut self.act_cache))
        };
        let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
        let gather_ms = self.obs.gather_wait_ms.get() - gather0;
        let done_ms = match virtual_ms_per_image {
            Some(mspi) => form_ms + plan.m as f64 * mspi,
            None => self.now_ms(),
        };
        self.done.on_batch(&plan, &logits, done_ms, compute_ms);
        self.obs.steps.inc();
        self.obs.batch_images.observe(plan.m as f64);
        for (e, ring) in self.obs.engine_busy_ratio.iter().enumerate() {
            let busy = self.obs.engine_busy_ms[e].get();
            let ratio =
                if compute_ms > 0.0 { (busy - self.last_busy[e]) / compute_ms } else { 0.0 };
            self.last_busy[e] = busy;
            ring.push(ratio);
        }
        if let Some(trace) = &mut self.trace {
            // Under a virtual clock (deterministic sink) the trace must
            // be a pure function of (seed, config): the shard-forward
            // span takes the simulated service time and the gather
            // collapses to an instant-width span at completion, keeping
            // the real-measured compute_ms/gather_ms out of the bytes.
            let det = trace.deterministic();
            for span in &plan.spans {
                trace.duration(
                    "queued",
                    span.arrival_ms,
                    form_ms - span.arrival_ms,
                    TID_REQUEST,
                    vec![("id", num(span.id as f64)), ("n", num(span.n as f64))],
                );
                trace.instant(
                    "batched",
                    form_ms,
                    TID_REQUEST,
                    vec![("id", num(span.id as f64)), ("batch", num(batch as f64))],
                );
            }
            let fwd_ms = if det { done_ms - form_ms } else { compute_ms };
            trace.duration(
                "shard-forward",
                form_ms,
                fwd_ms,
                TID_EXEC,
                vec![("batch", num(batch as f64)), ("m", num(plan.m as f64))],
            );
            let (gts, gdur) = if det { (done_ms, 0.0) } else { (form_ms + fwd_ms, gather_ms) };
            trace.duration(
                "gather",
                gts,
                gdur,
                TID_EXEC,
                vec![("batch", num(batch as f64))],
            );
            for span in &plan.spans {
                if span.final_chunk {
                    trace.instant(
                        "redeemed",
                        done_ms,
                        TID_REQUEST,
                        vec![
                            ("id", num(span.id as f64)),
                            ("latency_ms", num(done_ms - span.arrival_ms)),
                        ],
                    );
                }
            }
        }
        if self.snapshot_every > 0 && self.batch_seq % self.snapshot_every == 0 {
            println!("METRICS {}", self.reg.snapshot_json().to_string());
        }
        Some(StepInfo { m: plan.m, done_ms, compute_ms })
    }

    /// Redeem a ticket if resolved (at most once).
    pub fn poll(&mut self, t: Ticket) -> Option<Outcome> {
        self.done.take(t)
    }

    /// Drive the fleet until `t` resolves.
    pub fn wait(&mut self, t: Ticket) -> Result<Outcome> {
        loop {
            if let Some(o) = self.done.take(t) {
                return Ok(o);
            }
            if !self.step() {
                bail!("ticket {} is not pending in this fleet", t.id);
            }
        }
    }

    /// Drive the queue dry and drain every resolved outcome.
    pub fn wait_all(&mut self) -> Vec<Outcome> {
        while self.step() {}
        self.done.take_all()
    }

    pub fn stats(&self) -> LatencySummary {
        self.done.rec.summary()
    }

    /// One-shot convenience: submit + wait, returning the raw logits
    /// (bit-exactness tests compare these against the single-engine
    /// forward).
    pub fn infer_logits(&mut self, images: Vec<f32>, n: usize) -> Result<Vec<f32>> {
        let now = self.now_ms();
        let t = self.submit_at(images, n, None, now).map_err(anyhow::Error::from)?;
        match self.wait(t)? {
            Outcome::Done(r) => Ok(r.logits),
            Outcome::Expired { .. } => bail!("deadline-less request cannot expire"),
        }
    }
}

impl Drop for ServeFleet {
    fn drop(&mut self) {
        for e in &self.engines {
            let _ = e.tx.send(Job::Stop);
        }
        for e in &mut self.engines {
            if let Some(j) = e.join.take() {
                let _ = j.join();
            }
        }
    }
}

/// The fleet-side [`LinearExec`]: scatter the activation block to the
/// engines whose row range intersects the requested slice, gather
/// their column blocks, add the bias once.
struct FleetExec<'a> {
    engines: &'a [EngineHandle],
    /// Accumulates coordinator time blocked on engine replies.
    gather_wait: &'a FCounter,
}

impl FleetExec<'_> {
    /// Intersection of engine `h`'s row range for `store` with the
    /// requested global `[row0, row0 + rows)` slice.
    fn intersect(
        h: &EngineHandle,
        store: usize,
        row0: usize,
        rows: usize,
    ) -> Option<(usize, usize)> {
        let (s, e) = h.ranges[store];
        let (a, b) = (row0.max(s), (row0 + rows).min(e));
        (a < b).then_some((a, b))
    }
}

impl LinearExec for FleetExec<'_> {
    fn qlinear(
        &self,
        store: usize,
        x: &[f32],
        n: usize,
        row0: usize,
        rows: usize,
        bias: Option<&[f32]>,
    ) -> Vec<f32> {
        let x = Arc::new(x.to_vec());
        let (rtx, rrx) = channel::<(usize, Vec<f32>)>();
        let mut expected = 0;
        for h in self.engines {
            if let Some((a, b)) = Self::intersect(h, store, row0, rows) {
                h.tx
                    .send(Job::Linear {
                        store,
                        x: Arc::clone(&x),
                        n,
                        grow0: a,
                        rows: b - a,
                        reply: rtx.clone(),
                    })
                    .expect("engine thread hung up mid-serve");
                expected += 1;
            }
        }
        drop(rtx);
        let mut out = vec![0.0f32; n * rows];
        for _ in 0..expected {
            let t0 = Instant::now();
            let (e, part) = rrx.recv().expect("engine thread died mid-batch");
            self.gather_wait.add(t0.elapsed().as_secs_f64() * 1e3);
            let (a, b) = Self::intersect(&self.engines[e], store, row0, rows)
                .expect("reply from a non-intersecting engine");
            let (w, c0) = (b - a, a - row0);
            for i in 0..n {
                out[i * rows + c0..i * rows + c0 + w].copy_from_slice(&part[i * w..(i + 1) * w]);
            }
        }
        if let Some(bias) = bias {
            for i in 0..n {
                for (o, &bv) in out[i * rows..(i + 1) * rows].iter_mut().zip(bias) {
                    *o += bv;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{e2m1, Scaling};
    use crate::serve::model::{ActQuant, ServeGeom, WeightQuant};
    use crate::util::rng::Rng;

    fn tiny_vit(seed: u64) -> PackedVit {
        let geom = ServeGeom::new(8, 4, 32, 2, 4, 3, 4);
        let mut rng = Rng::new(seed);
        let params: Vec<f32> = (0..geom.total_params()).map(|_| rng.normal() * 0.05).collect();
        let fmt = e2m1();
        PackedVit::build(
            geom,
            &params,
            None,
            WeightQuant::Mx { fmt, scaling: Scaling::TruncationFree },
            ActQuant::Mx { fmt, scaling: Scaling::TruncationFree },
        )
        .unwrap()
    }

    fn fleet_cfg(engines: usize) -> ServeConfig {
        ServeConfig::builder()
            .micro_batch(4)
            .workers(1)
            .engines(engines)
            .queue_depth(64)
            .build()
            .unwrap()
    }

    #[test]
    fn two_engine_fleet_matches_single_engine_bit_exact() {
        let vit = tiny_vit(5);
        let mut rng = Rng::new(9);
        let n = 3;
        let px = vit.geom.img * vit.geom.img * 3;
        let x: Vec<f32> = (0..n * px).map(|_| rng.normal()).collect();
        let want = vit.forward(&x, n, 1);
        let mut fleet = ServeFleet::new(vit, fleet_cfg(2)).unwrap();
        assert_eq!(fleet.engines(), 2);
        let got = fleet.infer_logits(x, n).unwrap();
        assert_eq!(got, want, "fleet logits must be bit-exact to single-engine");
    }

    #[test]
    fn fleet_backpressure_and_stats() {
        let vit = tiny_vit(6);
        let px = vit.geom.img * vit.geom.img * 3;
        let mut fleet = ServeFleet::new(vit, fleet_cfg(2)).unwrap();
        fleet.submit(vec![0.1; 60 * px], 60, None).unwrap();
        assert!(matches!(
            fleet.submit(vec![0.1; 8 * px], 8, None),
            Err(Reject::QueueFull { queued_images: 60, limit: 64 })
        ));
        let outs = fleet.wait_all();
        assert_eq!(outs.len(), 1);
        let st = fleet.stats();
        assert_eq!((st.count, st.images, st.rejected), (1, 60, 1));
        assert_eq!(st.batches, 15); // 60 images / micro-batch 4
    }

    #[test]
    fn fleet_metrics_and_trace_cover_the_request_lifecycle() {
        let vit = tiny_vit(8);
        let px = vit.geom.img * vit.geom.img * 3;
        let mut fleet = ServeFleet::new(vit, fleet_cfg(2)).unwrap();
        fleet.set_trace(TraceSink::in_memory(false));
        fleet.submit(vec![0.2; 6 * px], 6, None).unwrap();
        let outs = fleet.wait_all();
        assert_eq!(outs.len(), 1);
        let reg = fleet.registry().clone();
        // 6 images / micro-batch 4 -> 2 executed batches.
        assert_eq!(reg.counter("fleet.steps").get(), 2);
        assert_eq!(reg.histogram("fleet.batch_images", &[]).count(), 2);
        assert_eq!(reg.counter("sched.admits").get(), 1);
        // depth=2 blocks x 2 batches = 4 qkv GEMMs.
        assert_eq!(reg.counter("kernel.qkv.calls").get(), 4);
        // One busy-ratio sample per engine per executed batch, bounded.
        for e in 0..2 {
            let ring = reg.ring(&format!("fleet.engine{e}.busy_ratio"), BUSY_RING_CAP);
            assert_eq!(ring.count(), 2);
            assert!(ring.window().iter().all(|r| r.is_finite() && *r >= 0.0));
        }
        // stats() is a view over the same registry cells.
        assert_eq!(fleet.stats(), LatencySummary::from_registry(&reg, "serve"));
        // Lifecycle: admit + 2x(queued+batched) + 2x(fwd+gather) + redeemed.
        let trace = fleet.take_trace().unwrap();
        assert_eq!(trace.events(), 1 + 4 + 4 + 1);
    }

    #[test]
    fn fleet_drop_joins_engine_threads() {
        let vit = tiny_vit(7);
        let fleet = ServeFleet::new(vit, fleet_cfg(3)).unwrap();
        assert!(fleet.shard_bytes() > 0);
        drop(fleet); // must not hang or panic
    }
}
