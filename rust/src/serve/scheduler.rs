//! Continuous-batching scheduler: bounded admission queue, micro-batch
//! formation that crosses request boundaries, and completion routing.
//!
//! The scheduler is deliberately clock-free — every method takes the
//! caller's notion of "now" in milliseconds. The session and the
//! fleet's real-time path pass wall-clock time; the load generator's
//! virtual pace passes simulated time, which is what makes the seeded
//! load tests deterministic (admission, rejection, expiry and batch
//! formation are pure functions of the arrival schedule and config).
//!
//! Backpressure is reject-with-reason, not silent drop: admission over
//! a full queue returns [`Reject::QueueFull`] with the observed depth,
//! and the queue is bounded in *images* (the unit the engines batch),
//! not requests, so one huge request cannot sneak past the limit.

use std::collections::{BTreeMap, HashMap, VecDeque};

use crate::obs::{Counter, Gauge, MetricsRegistry, TsRing};
use crate::serve::engine::argmax_rows;
use crate::serve::stats::LatencyRecorder;

/// Window of the queue-depth ring: the gauge keeps the instantaneous
/// value, the ring keeps the last N observations for min/mean/max.
const DEPTH_RING_CAP: usize = 256;

/// Scheduler instrumentation handles: queue depth (in images) plus
/// admit/reject/expiry counters.
#[derive(Debug, Clone)]
pub struct SchedMetrics {
    pub queue_depth: Gauge,
    /// Recent queue-depth samples (`sched.queue_depth.recent`), one per
    /// admission or batch-formation event.
    pub queue_depth_recent: TsRing,
    pub admits: Counter,
    pub rejects: Counter,
    pub expiries: Counter,
}

impl SchedMetrics {
    /// Register under `sched.queue_depth` / `sched.admits` /
    /// `sched.rejects` / `sched.expiries`.
    pub fn in_registry(reg: &MetricsRegistry) -> SchedMetrics {
        SchedMetrics {
            queue_depth: reg.gauge("sched.queue_depth"),
            queue_depth_recent: reg.ring("sched.queue_depth.recent", DEPTH_RING_CAP),
            admits: reg.counter("sched.admits"),
            rejects: reg.counter("sched.rejects"),
            expiries: reg.counter("sched.expiries"),
        }
    }

    /// Handles not attached to any shared registry.
    pub fn detached() -> SchedMetrics {
        SchedMetrics::in_registry(&MetricsRegistry::new())
    }
}

/// Handle returned by `submit`; redeem it with `poll`/`wait`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Ticket {
    pub id: u64,
}

/// Why a request was not admitted.
#[derive(Debug, Clone, PartialEq)]
pub enum Reject {
    /// Queue-depth backpressure: admitting `n` more images would push
    /// the queued total past `limit`.
    QueueFull { queued_images: usize, limit: usize },
    /// Malformed request (shape mismatch, zero images).
    BadRequest(String),
}

impl std::fmt::Display for Reject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Reject::QueueFull { queued_images, limit } => write!(
                f,
                "queue full: {queued_images} images queued against a depth limit of {limit}"
            ),
            Reject::BadRequest(msg) => write!(f, "bad request: {msg}"),
        }
    }
}

impl std::error::Error for Reject {}

/// Completed request: predicted class per image + logits + latency.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub preds: Vec<usize>,
    pub logits: Vec<f32>,
    pub latency_ms: f64,
}

/// Terminal state of an admitted request.
#[derive(Debug, Clone)]
pub enum Outcome {
    Done(Response),
    /// The per-request deadline passed before any of its images ran.
    Expired { id: u64, deadline_ms: f64 },
}

impl Outcome {
    pub fn id(&self) -> u64 {
        match self {
            Outcome::Done(r) => r.id,
            Outcome::Expired { id, .. } => *id,
        }
    }

    pub fn response(self) -> Option<Response> {
        match self {
            Outcome::Done(r) => Some(r),
            Outcome::Expired { .. } => None,
        }
    }
}

/// One request's contribution to a formed micro-batch.
#[derive(Debug, Clone)]
pub struct Span {
    pub id: u64,
    pub arrival_ms: f64,
    /// Image offset of this chunk inside the batch.
    pub offset: usize,
    /// Images taken from the request into this batch.
    pub n: usize,
    /// True when this chunk completes the request.
    pub final_chunk: bool,
}

/// A formed micro-batch: a flat pixel block plus the request spans it
/// was assembled from (batches cross request boundaries).
#[derive(Debug, Clone)]
pub struct BatchPlan {
    pub images: Vec<f32>,
    pub m: usize,
    pub spans: Vec<Span>,
}

/// A request dropped at batch-formation time by its deadline.
#[derive(Debug, Clone)]
pub struct Expired {
    pub id: u64,
    pub deadline_ms: f64,
}

#[derive(Debug)]
struct Pending {
    id: u64,
    images: Vec<f32>,
    n: usize,
    /// Images already taken into earlier batches.
    taken: usize,
    arrival_ms: f64,
    /// Absolute deadline; never expires once the first chunk ran.
    deadline_ms: Option<f64>,
}

/// FIFO admission queue + micro-batch former.
#[derive(Debug)]
pub struct Scheduler {
    px: usize,
    limit_images: usize,
    queue: VecDeque<Pending>,
    queued_images: usize,
    next_id: u64,
    obs: SchedMetrics,
}

impl Scheduler {
    /// `px` is pixels per image; `queue_depth` bounds queued images.
    pub fn new(px: usize, queue_depth: usize) -> Scheduler {
        Scheduler::with_metrics(px, queue_depth, SchedMetrics::detached())
    }

    /// Like [`Scheduler::new`], with instrumentation handles from a
    /// shared registry.
    pub fn with_metrics(px: usize, queue_depth: usize, obs: SchedMetrics) -> Scheduler {
        Scheduler {
            px,
            limit_images: queue_depth,
            queue: VecDeque::new(),
            queued_images: 0,
            next_id: 0,
            obs,
        }
    }

    pub fn pending_requests(&self) -> usize {
        self.queue.len()
    }

    /// Images currently queued (the backpressure unit).
    pub fn pending_images(&self) -> usize {
        self.queued_images
    }

    /// Arrival time of the oldest queued request, if any.
    pub fn earliest_arrival(&self) -> Option<f64> {
        self.queue.front().map(|p| p.arrival_ms)
    }

    /// Admit an `n`-image request arriving at `arrival_ms` with an
    /// optional *absolute* deadline, or reject it with a reason.
    pub fn try_admit(
        &mut self,
        images: Vec<f32>,
        n: usize,
        deadline_ms: Option<f64>,
        arrival_ms: f64,
    ) -> Result<Ticket, Reject> {
        if n == 0 || images.len() != n * self.px {
            return Err(Reject::BadRequest(format!(
                "request must be n x {} pixels, got n={n} len={}",
                self.px,
                images.len()
            )));
        }
        if self.queued_images + n > self.limit_images {
            self.obs.rejects.inc();
            return Err(Reject::QueueFull {
                queued_images: self.queued_images,
                limit: self.limit_images,
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queued_images += n;
        self.queue.push_back(Pending {
            id,
            images,
            n,
            taken: 0,
            arrival_ms,
            deadline_ms,
        });
        self.obs.admits.inc();
        self.obs.queue_depth.set(self.queued_images as f64);
        self.obs.queue_depth_recent.push(self.queued_images as f64);
        Ok(Ticket { id })
    }

    /// Form the next micro-batch of up to `micro` images at time
    /// `now_ms`, in FIFO order across request boundaries. Requests
    /// whose deadline has passed and which have not started are expired
    /// here (started requests always run to completion). Returns the
    /// expired set plus the plan, `None` when nothing is runnable.
    pub fn next_batch(&mut self, micro: usize, now_ms: f64) -> (Vec<Expired>, Option<BatchPlan>) {
        assert!(micro > 0, "micro-batch size must be >= 1");
        let mut expired = Vec::new();
        let mut images = Vec::new();
        let mut spans = Vec::new();
        let mut m = 0;
        while m < micro {
            let Some(front) = self.queue.front_mut() else { break };
            if front.taken == 0 {
                if let Some(d) = front.deadline_ms {
                    if now_ms > d {
                        // front_mut just matched, so the pop cannot
                        // miss; if that invariant ever breaks, skip
                        // batch formation instead of panicking the
                        // serve loop.
                        let Some(p) = self.queue.pop_front() else {
                            debug_assert!(false, "queue emptied under next_batch");
                            break;
                        };
                        self.queued_images -= p.n;
                        expired.push(Expired { id: p.id, deadline_ms: d });
                        continue;
                    }
                }
            }
            let take = (front.n - front.taken).min(micro - m);
            images.extend_from_slice(
                &front.images[front.taken * self.px..(front.taken + take) * self.px],
            );
            spans.push(Span {
                id: front.id,
                arrival_ms: front.arrival_ms,
                offset: m,
                n: take,
                final_chunk: front.taken + take == front.n,
            });
            front.taken += take;
            self.queued_images -= take;
            m += take;
            if front.taken == front.n {
                self.queue.pop_front();
            }
        }
        self.obs.expiries.add(expired.len() as u64);
        self.obs.queue_depth.set(self.queued_images as f64);
        self.obs.queue_depth_recent.push(self.queued_images as f64);
        let plan = (m > 0).then_some(BatchPlan { images, m, spans });
        (expired, plan)
    }
}

/// Completion side: reassembles per-request logits from batch spans,
/// computes latencies, and holds finished [`Outcome`]s for redemption
/// by ticket.
#[derive(Debug, Default)]
pub struct Completions {
    classes: usize,
    /// Partially-served requests' accumulated logits.
    partial: HashMap<u64, Vec<f32>>,
    /// Finished outcomes awaiting `take` (BTreeMap: id-ordered drain).
    done: BTreeMap<u64, Outcome>,
    pub rec: LatencyRecorder,
}

impl Completions {
    pub fn new(classes: usize) -> Completions {
        Completions { classes, ..Default::default() }
    }

    /// Like [`Completions::new`], with the latency recorder registered
    /// under `serve.*` in a shared registry.
    pub fn in_registry(classes: usize, reg: &MetricsRegistry) -> Completions {
        Completions {
            classes,
            rec: LatencyRecorder::in_registry(reg, "serve"),
            ..Default::default()
        }
    }

    pub fn on_expired(&mut self, e: &Expired) {
        self.rec.record_expired();
        self.done
            .insert(e.id, Outcome::Expired { id: e.id, deadline_ms: e.deadline_ms });
    }

    /// Route one executed batch's logits back to its requests.
    /// `done_ms` is the batch completion time on the caller's clock;
    /// `compute_ms` the forward time it took.
    pub fn on_batch(&mut self, plan: &BatchPlan, logits: &[f32], done_ms: f64, compute_ms: f64) {
        assert_eq!(logits.len(), plan.m * self.classes, "logit block mismatches plan");
        self.rec.record_batch(plan.m, compute_ms, done_ms);
        for span in &plan.spans {
            let chunk = &logits[span.offset * self.classes..(span.offset + span.n) * self.classes];
            if !span.final_chunk {
                self.partial.entry(span.id).or_default().extend_from_slice(chunk);
                continue;
            }
            // Final chunk: drain the accumulated prefix, if any. A
            // single-chunk request (the common case) never touches the
            // partial map, so there is legitimately nothing to remove —
            // the old `remove().unwrap()` here conflated that with the
            // corrupt-plan case and panicked the completion loop.
            let mut lg = self.partial.remove(&span.id).unwrap_or_default();
            lg.extend_from_slice(chunk);
            let latency_ms = done_ms - span.arrival_ms;
            self.rec.record_latency(latency_ms);
            self.done.insert(
                span.id,
                Outcome::Done(Response {
                    id: span.id,
                    preds: argmax_rows(&lg, self.classes),
                    logits: lg,
                    latency_ms,
                }),
            );
        }
    }

    /// Redeem a ticket (at most once).
    pub fn take(&mut self, t: Ticket) -> Option<Outcome> {
        self.done.remove(&t.id)
    }

    /// Drain every finished outcome, in ticket-id order.
    pub fn take_all(&mut self) -> Vec<Outcome> {
        std::mem::take(&mut self.done).into_values().collect()
    }

    /// Requests with some but not all chunks executed.
    pub fn in_flight(&self) -> usize {
        self.partial.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PX: usize = 4;

    fn imgs(n: usize, fill: f32) -> Vec<f32> {
        vec![fill; n * PX]
    }

    #[test]
    fn admit_validates_and_bounds_in_images() {
        let mut s = Scheduler::new(PX, 8);
        assert!(matches!(
            s.try_admit(vec![0.0; 3], 1, None, 0.0),
            Err(Reject::BadRequest(_))
        ));
        assert!(matches!(s.try_admit(Vec::new(), 0, None, 0.0), Err(Reject::BadRequest(_))));
        let t = s.try_admit(imgs(5, 1.0), 5, None, 0.0).unwrap();
        assert_eq!(t.id, 0);
        assert_eq!(s.pending_images(), 5);
        // 5 queued + 4 > 8 -> backpressure with the observed depth.
        assert_eq!(
            s.try_admit(imgs(4, 2.0), 4, None, 1.0),
            Err(Reject::QueueFull { queued_images: 5, limit: 8 })
        );
        // 5 + 3 == 8 still fits.
        assert!(s.try_admit(imgs(3, 3.0), 3, None, 1.0).is_ok());
    }

    #[test]
    fn batches_cross_request_boundaries_fifo() {
        let mut s = Scheduler::new(PX, 64);
        s.try_admit(imgs(3, 1.0), 3, None, 0.0).unwrap();
        s.try_admit(imgs(2, 2.0), 2, None, 1.0).unwrap();
        let (exp, plan) = s.next_batch(4, 5.0);
        assert!(exp.is_empty());
        let plan = plan.unwrap();
        assert_eq!(plan.m, 4);
        assert_eq!(plan.spans.len(), 2);
        assert!(plan.spans[0].final_chunk && !plan.spans[1].final_chunk);
        assert_eq!((plan.spans[1].offset, plan.spans[1].n), (3, 1));
        assert_eq!(s.pending_images(), 1);
        // Remainder of request 1 comes alone.
        let (_, plan2) = s.next_batch(4, 6.0);
        let plan2 = plan2.unwrap();
        assert_eq!(plan2.m, 1);
        assert!(plan2.spans[0].final_chunk);
        assert_eq!(s.pending_images(), 0);
        assert!(s.next_batch(4, 7.0).1.is_none());
    }

    #[test]
    fn deadlines_expire_only_unstarted_requests() {
        let mut s = Scheduler::new(PX, 64);
        s.try_admit(imgs(3, 1.0), 3, Some(10.0), 0.0).unwrap();
        s.try_admit(imgs(2, 2.0), 2, Some(4.0), 1.0).unwrap();
        // Request 0 starts before its deadline; only its first 2 images fit.
        let (exp, plan) = s.next_batch(2, 5.0);
        assert!(exp.is_empty());
        assert_eq!(plan.unwrap().spans[0].id, 0);
        // Far past both deadlines: request 0 already started, so it
        // finishes; request 1 never started, so it expires.
        let (exp, plan) = s.next_batch(2, 100.0);
        let plan = plan.unwrap();
        assert_eq!(plan.spans[0].id, 0);
        assert!(plan.spans[0].final_chunk);
        assert_eq!(exp.len(), 1);
        assert_eq!(exp[0].id, 1);
        assert_eq!(s.pending_images(), 0);
    }

    #[test]
    fn metrics_track_admits_rejects_expiries_and_depth() {
        let reg = MetricsRegistry::new();
        let mut s = Scheduler::with_metrics(PX, 4, SchedMetrics::in_registry(&reg));
        s.try_admit(imgs(2, 1.0), 2, Some(5.0), 0.0).unwrap();
        s.try_admit(imgs(2, 2.0), 2, None, 1.0).unwrap();
        assert_eq!(reg.gauge("sched.queue_depth").get_opt(), Some(4.0));
        // Over the image bound: counted as a reject.
        assert!(s.try_admit(imgs(1, 3.0), 1, None, 2.0).is_err());
        // Past request 0's deadline: it expires, request 1 forms a batch.
        let (exp, plan) = s.next_batch(8, 10.0);
        assert_eq!(exp.len(), 1);
        assert!(plan.is_some());
        assert_eq!(reg.counter("sched.admits").get(), 2);
        assert_eq!(reg.counter("sched.rejects").get(), 1);
        assert_eq!(reg.counter("sched.expiries").get(), 1);
        assert_eq!(reg.gauge("sched.queue_depth").get_opt(), Some(0.0));
        // The recent-depth ring saw both admits and the batch formation
        // (rejects don't change the depth, so they don't sample it).
        let ring = reg.ring("sched.queue_depth.recent", DEPTH_RING_CAP);
        assert_eq!(ring.window(), vec![2.0, 4.0, 0.0]);
        assert_eq!(ring.agg().max, 4.0);
    }

    #[test]
    fn single_chunk_requests_never_touch_partial_map() {
        // Regression: the final-chunk path used to insert into the
        // partial map and immediately `remove().unwrap()` — single-chunk
        // requests must complete without the map round-trip (and without
        // any panic opportunity on the completion loop).
        let classes = 2;
        let mut s = Scheduler::new(PX, 64);
        let mut c = Completions::new(classes);
        let t = s.try_admit(imgs(2, 1.0), 2, None, 0.0).unwrap();
        let (_, plan) = s.next_batch(4, 1.0);
        let plan = plan.unwrap();
        assert!(plan.spans[0].final_chunk);
        c.on_batch(&plan, &[1.0, 0.0, 0.0, 1.0], 2.0, 1.0);
        assert_eq!(c.in_flight(), 0, "single-chunk span must not linger in partial");
        let Some(Outcome::Done(r)) = c.take(t) else { panic!("should be done") };
        assert_eq!(r.preds, vec![0, 1]);
    }

    #[test]
    fn multi_chunk_partials_drain_exactly_on_final_chunk() {
        // Regression for the partial-map removal path: a request split
        // across three micro-batches accumulates, then drains exactly
        // when its final chunk lands.
        let classes = 1;
        let mut s = Scheduler::new(PX, 64);
        let mut c = Completions::new(classes);
        let t = s.try_admit(imgs(3, 1.0), 3, None, 0.0).unwrap();
        for step in 0..3 {
            let (_, plan) = s.next_batch(1, step as f64 + 1.0);
            c.on_batch(&plan.unwrap(), &[step as f32], step as f64 + 2.0, 0.5);
            let expect_in_flight = if step < 2 { 1 } else { 0 };
            assert_eq!(c.in_flight(), expect_in_flight, "after chunk {step}");
        }
        let Some(Outcome::Done(r)) = c.take(t) else { panic!("should be done") };
        assert_eq!(r.logits, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn consecutive_expiries_drain_in_one_next_batch_pass() {
        let mut s = Scheduler::new(PX, 64);
        s.try_admit(imgs(1, 1.0), 1, Some(1.0), 0.0).unwrap();
        s.try_admit(imgs(1, 2.0), 1, Some(1.5), 0.0).unwrap();
        s.try_admit(imgs(1, 3.0), 1, None, 0.0).unwrap();
        let (exp, plan) = s.next_batch(4, 10.0);
        assert_eq!(exp.iter().map(|e| e.id).collect::<Vec<_>>(), vec![0, 1]);
        let plan = plan.unwrap();
        assert_eq!(plan.spans[0].id, 2);
        assert_eq!(s.pending_images(), 0);
    }

    #[test]
    fn completions_reassemble_split_requests() {
        let classes = 2;
        let mut s = Scheduler::new(PX, 64);
        let mut c = Completions::new(classes);
        let t = s.try_admit(imgs(3, 1.0), 3, None, 10.0).unwrap();
        let (_, plan) = s.next_batch(2, 11.0);
        let plan = plan.unwrap();
        // Fake logits: image i gets [i, -i].
        c.on_batch(&plan, &[0.0, 0.0, 1.0, -1.0], 20.0, 5.0);
        assert_eq!(c.in_flight(), 1);
        assert!(c.take(t).is_none());
        let (_, plan2) = s.next_batch(2, 21.0);
        c.on_batch(&plan2.unwrap(), &[2.0, -2.0], 30.0, 4.0);
        assert_eq!(c.in_flight(), 0);
        let Some(Outcome::Done(r)) = c.take(t) else { panic!("request should be done") };
        assert_eq!(r.id, 0);
        assert_eq!(r.preds, vec![0, 0, 0]);
        assert_eq!(r.logits, vec![0.0, 0.0, 1.0, -1.0, 2.0, -2.0]);
        // Latency: arrival 10, last chunk done 30.
        assert!((r.latency_ms - 20.0).abs() < 1e-12);
        // Ticket redemption is at-most-once.
        assert!(c.take(t).is_none());
        let sum = c.rec.summary();
        assert_eq!((sum.batches, sum.images, sum.count), (2, 3, 1));
        assert!((sum.busy_ms - 9.0).abs() < 1e-12);
    }
}
