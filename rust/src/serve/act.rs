//! Memoizing cache for Q1 activation quantization.
//!
//! Every quantized linear in the [`super::model::PackedVit`] forward
//! quantizes its input activation block first (Eq. 3's `Q1(X)`), which
//! costs a max-abs + frexp scan per 1x32 group and a rounding pass per
//! element. Two serving patterns repeat that work on bit-identical
//! inputs:
//!
//! * `eval --packed --verify-mirror` runs the fused engine and the
//!   dense-mirror engine over the same batches — the mirror's Q1 inputs
//!   are bit-identical to the fused pass's (the forwards are bit-exact
//!   by construction), so every mirror quantization is a repeat;
//! * repeated forwards over the same images (steady-state benches,
//!   golden replays) re-quantize the same blocks each time.
//!
//! [`ActQuantCache`] keys each of a model's Q1 sites (4 per transformer
//! block: qkv, proj, fc1, fc2 inputs) by slot and memoizes
//! `(raw activation bytes) -> (quantized activation, scale bytes)`. A
//! hit is detected by bitwise comparison of the raw input — no
//! hashing, no false positives — so cached == uncached is exact by
//! construction (and still parity-tested in `model.rs`). On a miss the
//! MX path runs the split quantizer
//! ([`crate::quant::mx_scale_bytes`] then
//! [`crate::quant::mx_quantize_cols_with_scales`]), persisting the
//! per-group E8M0 scale bytes alongside the values; INT4 memoizes the
//! per-tensor pass. `ActQuant::None` bypasses the cache entirely.

use crate::obs::{Counter, MetricsRegistry};
use crate::quant::{
    int4_quantize, mx_quantize_cols_with_scales, mx_scale_bytes, nvfp4_quantize_cols,
};
use crate::serve::model::ActQuant;

/// One memoized Q1 site: the raw input it was computed from, the
/// quantized output, and (MX only) the per-group E8M0 scale bytes.
#[derive(Debug, Clone)]
struct Slot {
    raw: Vec<f32>,
    q: Vec<f32>,
    scale_bytes: Vec<u8>,
}

/// Per-model activation-quantization cache; see the module doc. One
/// slot per Q1 site (`depth * 4` for a ViT). Not thread-safe by itself
/// — share across engines behind a mutex
/// ([`crate::serve::ServeEngine::share_act_cache`]).
#[derive(Debug)]
pub struct ActQuantCache {
    slots: Vec<Option<Slot>>,
    hits: Counter,
    misses: Counter,
}

impl ActQuantCache {
    /// A cache with `slots` Q1 sites and detached hit/miss counters.
    pub fn new(slots: usize) -> ActQuantCache {
        let reg = MetricsRegistry::new();
        ActQuantCache {
            slots: vec![None; slots],
            hits: reg.counter("kernel.actq.hits"),
            misses: reg.counter("kernel.actq.misses"),
        }
    }

    /// Swap in registry-attached hit/miss counters (see
    /// [`MetricsRegistry::counter`] names `kernel.actq.{hits,misses}`).
    pub fn attach(&mut self, reg: &MetricsRegistry) {
        self.hits = reg.counter("kernel.actq.hits");
        self.misses = reg.counter("kernel.actq.misses");
    }

    /// `(hits, misses)` so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.get(), self.misses.get())
    }

    /// Number of Q1 sites this cache covers.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True if the cache covers no sites.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Quantize `x` (a `(n, cols)` activation block) in place per `aq`,
    /// reusing the slot's memoized result when the input is bitwise
    /// identical to the previous call at this site.
    pub fn quantize(&mut self, slot: usize, aq: &ActQuant, x: &mut Vec<f32>, cols: usize) {
        if matches!(aq, ActQuant::None) {
            return;
        }
        if let Some(s) = &self.slots[slot] {
            let hit = s.raw.len() == x.len()
                && s.raw.iter().zip(x.iter()).all(|(a, b)| a.to_bits() == b.to_bits());
            if hit {
                self.hits.inc();
                x.copy_from_slice(&s.q);
                return;
            }
        }
        self.misses.inc();
        let raw = x.clone();
        let mut scale_bytes = Vec::new();
        match *aq {
            ActQuant::None => unreachable!(),
            ActQuant::Mx { fmt, scaling } => {
                mx_scale_bytes(&raw, cols, fmt, scaling, &mut scale_bytes);
                mx_quantize_cols_with_scales(&raw, cols, fmt, &scale_bytes, x);
            }
            ActQuant::Int4 => *x = int4_quantize(&raw, None),
            // NVFP4's outlier clamp is a whole-tensor pre-pass, so the
            // split scale-bytes-then-values form doesn't apply; memoize
            // the full pass like INT4 (scale_bytes stays empty).
            ActQuant::Nvfp4 => *x = nvfp4_quantize_cols(&raw, cols),
        }
        self.slots[slot] = Some(Slot { raw, q: x.clone(), scale_bytes });
    }

    /// The memoized per-group E8M0 scale bytes at `slot` (empty for
    /// INT4 sites or before the first miss).
    pub fn scale_bytes(&self, slot: usize) -> &[u8] {
        self.slots[slot].as_ref().map_or(&[], |s| &s.scale_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{e2m1, mx_quantize_cols, Scaling};

    fn mx() -> ActQuant {
        ActQuant::Mx { fmt: e2m1(), scaling: Scaling::TruncationFree }
    }

    #[test]
    fn miss_then_hit_returns_identical_bytes() {
        let mut c = ActQuantCache::new(1);
        let x0: Vec<f32> = (0..96).map(|i| (i as f32 * 0.7).sin() * 4.0).collect();
        let want = mx_quantize_cols(&x0, 48, e2m1(), Scaling::TruncationFree);
        let mut x = x0.clone();
        c.quantize(0, &mx(), &mut x, 48);
        assert_eq!(x, want);
        assert_eq!(c.stats(), (0, 1));
        assert!(!c.scale_bytes(0).is_empty());
        let mut x = x0.clone();
        c.quantize(0, &mx(), &mut x, 48);
        assert_eq!(c.stats(), (1, 1));
        let same = x.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "cached result must be bit-identical");
    }

    #[test]
    fn changed_input_misses_and_recomputes() {
        let mut c = ActQuantCache::new(2);
        let a0: Vec<f32> = (0..32).map(|i| i as f32 / 7.0).collect();
        let b0: Vec<f32> = (0..32).map(|i| i as f32 / 5.0).collect();
        let mut a = a0.clone();
        let mut b = b0.clone();
        c.quantize(0, &mx(), &mut a, 32);
        c.quantize(0, &mx(), &mut b, 32);
        assert_eq!(c.stats(), (0, 2));
        assert_eq!(b, mx_quantize_cols(&b0, 32, e2m1(), Scaling::TruncationFree));
        // Distinct slots never cross-talk even on identical inputs.
        let mut a2 = a0.clone();
        c.quantize(1, &mx(), &mut a2, 32);
        assert_eq!(c.stats(), (0, 3));
    }

    #[test]
    fn int4_sites_memoize_per_tensor_pass() {
        let mut c = ActQuantCache::new(1);
        let x0: Vec<f32> = (0..20).map(|i| (i as f32 - 10.0) * 1.3).collect();
        let want = int4_quantize(&x0, None);
        let mut x = x0.clone();
        c.quantize(0, &ActQuant::Int4, &mut x, 20);
        assert_eq!(x, want);
        let mut x = x0;
        c.quantize(0, &ActQuant::Int4, &mut x, 20);
        assert_eq!(c.stats(), (1, 1));
        assert_eq!(x, want);
        assert!(c.scale_bytes(0).is_empty());
    }

    #[test]
    fn nvfp4_sites_memoize_full_pass() {
        let mut c = ActQuantCache::new(1);
        let x0: Vec<f32> = (0..96).map(|i| (i as f32 * 0.9).cos() * 3.0).collect();
        let want = nvfp4_quantize_cols(&x0, 48);
        let mut x = x0.clone();
        c.quantize(0, &ActQuant::Nvfp4, &mut x, 48);
        assert_eq!(x, want);
        assert_eq!(c.stats(), (0, 1));
        assert!(c.scale_bytes(0).is_empty());
        let mut x = x0;
        c.quantize(0, &ActQuant::Nvfp4, &mut x, 48);
        assert_eq!(c.stats(), (1, 1));
        let same = x.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "cached nvfp4 result must be bit-identical");
    }

    #[test]
    fn none_bypasses_cache() {
        let mut c = ActQuantCache::new(1);
        let mut x = vec![1.5f32; 8];
        c.quantize(0, &ActQuant::None, &mut x, 8);
        assert_eq!(x, vec![1.5f32; 8]);
        assert_eq!(c.stats(), (0, 0));
    }
}
