//! Packed ViT model for serving: geometry, weight stores, forward pass.
//!
//! [`ServeGeom`] re-derives the flat parameter layout of
//! `python/compile/vit.py::param_spec` from manifest segment shapes and
//! cross-validates every name/shape/offset, so the serving forward and
//! the AOT HLO can never silently disagree about where a tensor lives.
//!
//! [`PackedVit`] holds the four depth-stacked quantized weight tensors
//! (qkv / proj / fc1 / fc2) as [`PackedMx`] codes + scales — never as a
//! full f32 matrix — plus the small full-precision tail (patch embed,
//! layernorms, biases, classifier head). Its forward mirrors
//! `vit.py::forward` exactly: pre-LN attention + MLP blocks with the
//! paper's Eq. 3 quantized linears `Y = Q1(X) · Q2(W)^T`, tanh-GELU
//! (JAX's default), and max-subtracted softmax. The quantized matmuls
//! run through [`fused_matmul`]; [`PackedVit::to_dense`] swaps every
//! store for its dequantized f32 form behind the same forward code,
//! which is how the fused path's bit-exactness is asserted end-to-end.
//!
//! Faithfulness note: MX activation/weight groups are per-row 1x32, so
//! quantizing a depth-stacked weight in one call is identical to
//! quantizing each block's matrix separately. The INT4 baseline is
//! per-*tensor* scaled; like the trainer's mirror we scale per stacked
//! segment, while the HLO scales per block matrix — MX variants (the
//! paper's subject) are exact, INT4 is the same approximation the
//! trainer already makes.

use anyhow::{bail, Context, Result};

use crate::coordinator::PackedSeg;
use crate::obs::KernelMetrics;
use crate::quant::{
    fp4_format, int4_quantize, mx_quantize_cols, nvfp4_quantize_cols, Fp4Format,
    GroupGeom, Int4Quantizer, MxQuantizer, NvQuantizer, PackedMx, QemaQuantizer,
    Quantizer, Scaling,
};
use crate::runtime::Manifest;
use crate::serve::act::ActQuantCache;
use crate::serve::kernel::{dense_matmul, fused_matmul, matmul_ref};

/// One entry of the flat parameter layout (mirror of vit.py ParamSeg).
#[derive(Debug, Clone)]
pub struct SegSpec {
    pub name: &'static str,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub quantized: bool,
}

impl SegSpec {
    pub fn cols(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }

    pub fn range(&self) -> std::ops::Range<usize> {
        self.offset..self.offset + self.size
    }
}

/// ViT geometry for the serving path. Constructible directly (tests,
/// benches, synthetic models) or from an artifact [`Manifest`] with
/// full layout cross-validation.
#[derive(Debug, Clone)]
pub struct ServeGeom {
    pub img: usize,
    pub patch: usize,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub classes: usize,
    pub hidden: usize,
    pub seq: usize,
    pub patch_dim: usize,
    pub head_dim: usize,
}

impl ServeGeom {
    pub fn new(
        img: usize,
        patch: usize,
        dim: usize,
        depth: usize,
        heads: usize,
        classes: usize,
        mlp_ratio: usize,
    ) -> ServeGeom {
        assert!(patch > 0 && img % patch == 0, "img must tile into patches");
        assert!(heads > 0 && dim % heads == 0, "dim must split into heads");
        let hp = img / patch;
        ServeGeom {
            img,
            patch,
            dim,
            depth,
            heads,
            classes,
            hidden: dim * mlp_ratio,
            seq: hp * hp + 1,
            patch_dim: patch * patch * 3,
            head_dim: dim / heads,
        }
    }

    /// The flat parameter layout, quantized weight matrices first —
    /// byte-for-byte the order of `vit.py::param_spec`.
    pub fn param_spec(&self) -> Vec<SegSpec> {
        let (d, dim, hidden) = (self.depth, self.dim, self.hidden);
        let entries: Vec<(&'static str, Vec<usize>, bool)> = vec![
            ("blocks.qkv_w", vec![d, 3 * dim, dim], true),
            ("blocks.proj_w", vec![d, dim, dim], true),
            ("blocks.fc1_w", vec![d, hidden, dim], true),
            ("blocks.fc2_w", vec![d, dim, hidden], true),
            ("patch_embed.w", vec![dim, self.patch_dim], false),
            ("patch_embed.b", vec![dim], false),
            ("cls", vec![dim], false),
            ("pos", vec![self.seq, dim], false),
            ("blocks.ln1.g", vec![d, dim], false),
            ("blocks.ln1.b", vec![d, dim], false),
            ("blocks.qkv_b", vec![d, 3 * dim], false),
            ("blocks.proj_b", vec![d, dim], false),
            ("blocks.ln2.g", vec![d, dim], false),
            ("blocks.ln2.b", vec![d, dim], false),
            ("blocks.fc1_b", vec![d, hidden], false),
            ("blocks.fc2_b", vec![d, dim], false),
            ("ln_f.g", vec![dim], false),
            ("ln_f.b", vec![dim], false),
            ("head.w", vec![self.classes, dim], false),
            ("head.b", vec![self.classes], false),
        ];
        let mut out = Vec::with_capacity(entries.len());
        let mut off = 0;
        for (name, shape, quantized) in entries {
            let size = shape.iter().product();
            out.push(SegSpec { name, shape, offset: off, size, quantized });
            off += size;
        }
        out
    }

    pub fn total_params(&self) -> usize {
        self.param_spec().iter().map(|s| s.size).sum()
    }

    pub fn qw_total(&self) -> usize {
        self.param_spec().iter().filter(|s| s.quantized).map(|s| s.size).sum()
    }

    /// Derive the geometry from a manifest and validate the full layout
    /// against it: every segment name, shape, offset and quantized flag
    /// must match, i.e. the manifest segment shapes *are* the layer
    /// geometry of the serving forward.
    pub fn from_manifest(man: &Manifest) -> Result<ServeGeom> {
        let m = &man.model;
        let fc1 = man
            .segment("blocks.fc1_w")
            .context("manifest has no blocks.fc1_w segment")?;
        if fc1.shape.len() != 3 || fc1.shape[2] != m.dim || fc1.shape[1] % m.dim != 0 {
            bail!("blocks.fc1_w shape {:?} incompatible with dim {}", fc1.shape, m.dim);
        }
        let mlp_ratio = fc1.shape[1] / m.dim;
        if m.patch == 0 || m.img % m.patch != 0 || m.heads == 0 || m.dim % m.heads != 0 {
            bail!("implausible model geometry {m:?}");
        }
        let geom = ServeGeom::new(m.img, m.patch, m.dim, m.depth, m.heads, m.classes, mlp_ratio);
        if geom.seq != m.seq {
            bail!("derived seq {} != manifest seq {}", geom.seq, m.seq);
        }
        for spec in geom.param_spec() {
            let seg = man
                .segment(spec.name)
                .with_context(|| format!("manifest missing segment {:?}", spec.name))?;
            if seg.shape != spec.shape
                || seg.offset != spec.offset
                || seg.size != spec.size
                || seg.quantized != spec.quantized
            {
                bail!(
                    "segment {:?} layout mismatch: manifest {:?}@{} vs serve {:?}@{}",
                    spec.name,
                    seg.shape,
                    seg.offset,
                    spec.shape,
                    spec.offset
                );
            }
        }
        if man.total_params != geom.total_params() || man.qw_total != geom.qw_total() {
            bail!(
                "manifest totals ({}, {}) != serve layout ({}, {})",
                man.total_params,
                man.qw_total,
                geom.total_params(),
                geom.qw_total()
            );
        }
        Ok(geom)
    }
}

/// Forward weight quantizer Q^(2) used when building a model from f32
/// parameters (matches the trainer's mirror selection).
#[derive(Debug, Clone, Copy)]
pub enum WeightQuant {
    /// Full-precision weights (fp32 variant, or Q2 toggled off).
    Dense,
    Mx { fmt: &'static Fp4Format, scaling: Scaling },
    Qema { fmt: &'static Fp4Format, scaling: Scaling },
    Int4,
    /// NVFP4 recipe (TetraJet-v2): E2M1 elements, 16-element groups,
    /// E4M3 scale bytes, outlier clamp — [`NvQuantizer::nvfp4`].
    Nvfp4,
}

/// Activation quantizer Q^(1) applied to every quantized linear's input.
#[derive(Debug, Clone, Copy)]
pub enum ActQuant {
    None,
    Mx { fmt: &'static Fp4Format, scaling: Scaling },
    Int4,
    /// NVFP4 recipe, same geometry as the weight side.
    Nvfp4,
}

/// Map a manifest variant to its forward quantization recipe (mirror of
/// `model.py::VariantCfg.linear_cfg`, forward quantizers only).
pub fn variant_quant(man: &Manifest) -> (WeightQuant, ActQuant) {
    let v = &man.variant;
    let q1_on = v.enabled.first().copied().unwrap_or(true);
    let q2_on = v.enabled.get(1).copied().unwrap_or(true);
    if v.kind == "fp32" {
        return (WeightQuant::Dense, ActQuant::None);
    }
    if v.kind == "int4" {
        return (
            if q2_on { WeightQuant::Int4 } else { WeightQuant::Dense },
            if q1_on { ActQuant::Int4 } else { ActQuant::None },
        );
    }
    if v.kind == "nvfp4" {
        return (
            if q2_on { WeightQuant::Nvfp4 } else { WeightQuant::Dense },
            if q1_on { ActQuant::Nvfp4 } else { ActQuant::None },
        );
    }
    let fmt = fp4_format(&v.fwd_fmt).unwrap_or_else(crate::quant::e2m1);
    let scaling = Scaling::parse(&v.scaling).unwrap_or(Scaling::TruncationFree);
    let wq = if !q2_on {
        WeightQuant::Dense
    } else if v.qema {
        WeightQuant::Qema { fmt, scaling }
    } else {
        WeightQuant::Mx { fmt, scaling }
    };
    let aq = if q1_on { ActQuant::Mx { fmt, scaling } } else { ActQuant::None };
    (wq, aq)
}

/// One quantized weight tensor's storage: packed codes (the serving
/// path) or a dense f32 matrix (fp32 variants and the mirror used to
/// verify the fused kernel).
#[derive(Debug, Clone)]
enum Store {
    Packed(PackedMx),
    Dense { w: Vec<f32>, cols: usize },
}

impl Store {
    fn linear(
        &self,
        x: &[f32],
        n: usize,
        row0: usize,
        rows: usize,
        bias: Option<&[f32]>,
        workers: usize,
    ) -> Vec<f32> {
        match self {
            Store::Packed(p) => fused_matmul(x, n, p, row0, rows, bias, workers),
            Store::Dense { w, cols } => dense_matmul(
                x,
                n,
                *cols,
                &w[row0 * cols..(row0 + rows) * cols],
                rows,
                bias,
                workers,
            ),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            Store::Packed(p) => p.bytes(),
            Store::Dense { w, .. } => w.len() * std::mem::size_of::<f32>(),
        }
    }

    fn is_packed(&self) -> bool {
        matches!(self, Store::Packed(_))
    }

    fn to_dense(&self) -> Store {
        match self {
            Store::Packed(p) => Store::Dense { w: p.dequantize(), cols: p.cols() },
            d => d.clone(),
        }
    }

    /// Rows `[row0, row0 + nrows)` as a standalone store, values
    /// bit-identical (see [`PackedMx::slice_rows`]).
    fn slice_rows(&self, row0: usize, nrows: usize) -> Result<Store> {
        Ok(match self {
            Store::Packed(p) => Store::Packed(p.slice_rows(row0, nrows)?),
            Store::Dense { w, cols } => {
                if *cols == 0 || (row0 + nrows) * cols > w.len() {
                    bail!("rows [{row0}, {}) exceed the dense store", row0 + nrows);
                }
                Store::Dense { w: w[row0 * cols..(row0 + nrows) * cols].to_vec(), cols: *cols }
            }
        })
    }

    /// Stored weight rows.
    fn rows(&self) -> usize {
        match self {
            Store::Packed(p) => p.len() / p.cols().max(1),
            Store::Dense { w, cols } => w.len() / cols.max(1),
        }
    }

    /// Placeholder for a trunk whose quantized stores moved into
    /// shards: zero resident bytes, and any accidental `linear` call
    /// trips the kernel's shape assert instead of computing garbage.
    fn vacated() -> Store {
        Store::Dense { w: Vec::new(), cols: 0 }
    }
}

/// Names of the four quantized stacked weight tensors, in layout order.
const QW_NAMES: [&str; 4] = ["blocks.qkv_w", "blocks.proj_w", "blocks.fc1_w", "blocks.fc2_w"];

/// Executor of the quantized stacked linears inside
/// [`PackedVit::forward_with`] — the row-parallel seam of the fused
/// kernel, and the sharding boundary of the serve fleet.
///
/// The forward calls back through this trait at each of its four
/// quantized matmuls, so the exact same forward code serves both the
/// in-process path ([`PackedVit::forward`], which dispatches to the
/// model's own stores) and the row-sharded fleet
/// (`serve::fleet::ServeFleet`, which scatters the activation block to
/// its engines and gathers their output-column slices here).
///
/// `store` indexes the qkv/proj/fc1/fc2 stacked tensors in layout
/// order; `row0`/`rows` select the calling block's row range of the
/// depth-stacked tensor. Implementations must be bit-exact to
/// [`fused_matmul`] over the full store: the canonical lane-strided
/// contraction order per output element (see the accumulation-order
/// contract in `serve/kernel.rs`), bias added once after accumulation.
pub trait LinearExec {
    fn qlinear(
        &self,
        store: usize,
        x: &[f32],
        n: usize,
        row0: usize,
        rows: usize,
        bias: Option<&[f32]>,
    ) -> Vec<f32>;
}

/// The in-process executor: each linear runs on the model's own store.
struct LocalExec<'a> {
    vit: &'a PackedVit,
    workers: usize,
}

impl LinearExec for LocalExec<'_> {
    fn qlinear(
        &self,
        store: usize,
        x: &[f32],
        n: usize,
        row0: usize,
        rows: usize,
        bias: Option<&[f32]>,
    ) -> Vec<f32> {
        self.vit.stores[store].linear(x, n, row0, rows, bias, self.workers)
    }
}

/// Instrumentation passthrough for any [`LinearExec`]: counts each
/// fused-GEMM call and accumulates its wall time into per-layer
/// [`KernelMetrics`], then delegates unchanged — the returned block is
/// bit-identical to the inner executor's, so observing a forward never
/// perturbs its numerics.
pub struct ObservedExec<'a> {
    pub inner: &'a dyn LinearExec,
    pub kernel: &'a KernelMetrics,
}

impl LinearExec for ObservedExec<'_> {
    fn qlinear(
        &self,
        store: usize,
        x: &[f32],
        n: usize,
        row0: usize,
        rows: usize,
        bias: Option<&[f32]>,
    ) -> Vec<f32> {
        let t0 = std::time::Instant::now();
        let out = self.inner.qlinear(store, x, n, row0, rows, bias);
        self.kernel.calls[store].inc();
        self.kernel.ms[store].add(t0.elapsed().as_secs_f64() * 1e3);
        self.kernel.dispatch.set(crate::serve::simd::active().id() as f64);
        out
    }
}

/// Split `total` rows into `n` near-even contiguous `(start, end)`
/// ranges; the first `total % n` ranges get one extra row. Ragged by
/// design — the fleet's bit-exactness property is tested on
/// non-divisible splits too.
pub fn shard_ranges(total: usize, n: usize) -> Vec<(usize, usize)> {
    assert!(n > 0, "shard count must be >= 1");
    let base = total / n;
    let extra = total % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// One engine's contiguous row-slice of the four depth-stacked
/// quantized weight tensors, produced by [`PackedVit::into_shards`].
/// The slice is taken at the code/scale-byte level
/// ([`PackedMx::slice_rows`]), so each shard's kernel decodes exactly
/// the bytes the single-engine kernel would for those rows.
#[derive(Debug, Clone)]
pub struct VitShard {
    stores: [Store; 4],
    row0: [usize; 4],
}

impl VitShard {
    /// Global `(start, end)` row range this shard owns of `store`.
    pub fn range(&self, store: usize) -> (usize, usize) {
        (self.row0[store], self.row0[store] + self.stores[store].rows())
    }

    /// Rows `[grow0, grow0 + rows)` — global coordinates, fully inside
    /// this shard's range — of store `store` applied to `x (n, d)`.
    /// Computed WITHOUT bias: the fleet coordinator adds the bias once
    /// after gathering, which keeps the final per-element operation
    /// identical to the single-engine kernel's `acc + bias[c]`.
    pub fn linear(
        &self,
        store: usize,
        x: &[f32],
        n: usize,
        grow0: usize,
        rows: usize,
        workers: usize,
    ) -> Vec<f32> {
        self.stores[store].linear(x, n, grow0 - self.row0[store], rows, None, workers)
    }

    /// Resident bytes of this shard's stores.
    pub fn bytes(&self) -> usize {
        self.stores.iter().map(Store::bytes).sum()
    }
}

/// A forward-only ViT whose quantized weights stay packed.
#[derive(Debug, Clone)]
pub struct PackedVit {
    pub geom: ServeGeom,
    /// qkv / proj / fc1 / fc2, depth-stacked, in [`QW_NAMES`] order.
    stores: [Store; 4],
    /// Non-quantized parameters `[qw_total, total_params)`.
    rest: Vec<f32>,
    /// Name -> range into `rest`, precomputed so the forward's tensor
    /// lookups never rebuild the spec on the hot path.
    rest_spec: Vec<(&'static str, std::ops::Range<usize>)>,
    act_quant: ActQuant,
}

fn rest_ranges(geom: &ServeGeom) -> Vec<(&'static str, std::ops::Range<usize>)> {
    let qw = geom.qw_total();
    geom.param_spec()
        .iter()
        .filter(|s| !s.quantized)
        .map(|s| (s.name, s.offset - qw..s.offset + s.size - qw))
        .collect()
}

impl PackedVit {
    /// Build from a flat f32 parameter vector, quantizing the four
    /// weight groups with `wq` (the trainer-mirror recipe). `ema` is
    /// required for [`WeightQuant::Qema`].
    pub fn build(
        geom: ServeGeom,
        params: &[f32],
        ema: Option<&[f32]>,
        wq: WeightQuant,
        act: ActQuant,
    ) -> Result<PackedVit> {
        if params.len() != geom.total_params() {
            bail!("params {} != layout total {}", params.len(), geom.total_params());
        }
        let spec = geom.param_spec();
        let qw_total = geom.qw_total();
        let mut stores = Vec::with_capacity(4);
        for name in QW_NAMES {
            let seg = spec.iter().find(|s| s.name == name).unwrap();
            let w = &params[seg.range()];
            let cols = seg.cols();
            let store = match wq {
                WeightQuant::Dense => Store::Dense { w: w.to_vec(), cols },
                WeightQuant::Mx { fmt, scaling } => {
                    let mut p = PackedMx::default();
                    MxQuantizer { fmt, scaling }.quantize_packed(w, cols, &mut p);
                    Store::Packed(p)
                }
                WeightQuant::Qema { fmt, scaling } => {
                    let ema = ema.context("Q-EMA weight quantizer needs the EMA state")?;
                    if ema.len() < qw_total {
                        bail!("ema {} shorter than quantized prefix {qw_total}", ema.len());
                    }
                    let mut p = PackedMx::default();
                    QemaQuantizer { fmt, scaling, ema: &ema[seg.range()] }
                        .quantize_packed(w, cols, &mut p);
                    Store::Packed(p)
                }
                WeightQuant::Int4 => {
                    let mut p = PackedMx::default();
                    Int4Quantizer.quantize_packed(w, cols, &mut p);
                    Store::Packed(p)
                }
                WeightQuant::Nvfp4 => {
                    let mut p = PackedMx::default();
                    NvQuantizer::nvfp4().quantize_packed(w, cols, &mut p);
                    Store::Packed(p)
                }
            };
            stores.push(store);
        }
        let stores: [Store; 4] = stores.try_into().expect("four quantized stores");
        Ok(PackedVit {
            rest_spec: rest_ranges(&geom),
            geom,
            stores,
            rest: params[qw_total..].to_vec(),
            act_quant: act,
        })
    }

    /// Load a model for serving from a checkpoint: packed segments when
    /// the TJCKPT02 section is present (no dequantization anywhere on
    /// this path), otherwise re-quantize the f32 parameters with the
    /// variant's forward recipe.
    pub fn from_checkpoint(
        man: &Manifest,
        params: &[f32],
        ema: Option<&[f32]>,
        packed: &[PackedSeg],
    ) -> Result<PackedVit> {
        let geom = ServeGeom::from_manifest(man)?;
        let (wq, act) = variant_quant(man);
        if packed.is_empty() {
            return PackedVit::build(geom, params, ema, wq, act);
        }
        if params.len() != geom.total_params() {
            bail!("params {} != layout total {}", params.len(), geom.total_params());
        }
        // The codes are only meaningful under the variant's own level
        // table: a checkpoint served with the wrong --variant must fail
        // loudly here, not report silently wrong accuracy.
        let want_levels: &[f32] = match wq {
            WeightQuant::Dense => bail!(
                "variant {:?} has no packed weight form but the checkpoint \
                 carries {} packed segments — checkpoint/variant mismatch",
                man.variant.name,
                packed.len()
            ),
            WeightQuant::Mx { fmt, .. } | WeightQuant::Qema { fmt, .. } => &fmt.levels[..],
            WeightQuant::Int4 => &crate::quant::int4::INT4_LEVELS[..],
            WeightQuant::Nvfp4 => &NvQuantizer::nvfp4().fmt.levels[..],
        };
        // Likewise the group geometry: an NVFP4 checkpoint's 16-element
        // E4M3 groups decode to garbage under MX's 32-element E8M0
        // layout (and vice versa), so the geometry must match too.
        let want_geom = match wq {
            WeightQuant::Nvfp4 => GroupGeom::nvfp4(),
            _ => GroupGeom::mx(),
        };
        for ps in packed {
            if ps.packed.levels() != want_levels {
                bail!(
                    "packed segment {:?} was quantized with a different level \
                     table than variant {:?} expects — wrong --variant for this \
                     checkpoint",
                    ps.name,
                    man.variant.name
                );
            }
            if ps.packed.geom() != want_geom {
                bail!(
                    "packed segment {:?} has group geometry {:?} but variant \
                     {:?} expects {:?} — wrong --variant for this checkpoint",
                    ps.name,
                    ps.packed.geom(),
                    man.variant.name,
                    want_geom
                );
            }
        }
        let spec = geom.param_spec();
        let mut stores = Vec::with_capacity(4);
        for name in QW_NAMES {
            let seg = spec.iter().find(|s| s.name == name).unwrap();
            let ps = packed
                .iter()
                .find(|p| p.name == name)
                .with_context(|| format!("checkpoint packed section missing {name:?}"))?;
            if ps.offset != seg.offset
                || ps.packed.len() != seg.size
                || ps.packed.cols() != seg.cols()
            {
                bail!(
                    "packed segment {name:?}: ({}, {}, cols {}) != manifest ({}, {}, cols {})",
                    ps.offset,
                    ps.packed.len(),
                    ps.packed.cols(),
                    seg.offset,
                    seg.size,
                    seg.cols()
                );
            }
            stores.push(Store::Packed(ps.packed.clone()));
        }
        let stores: [Store; 4] = stores.try_into().expect("four quantized stores");
        Ok(PackedVit {
            rest_spec: rest_ranges(&geom),
            rest: params[geom.qw_total()..].to_vec(),
            geom,
            stores,
            act_quant: act,
        })
    }

    /// The same model with every packed store dequantized to f32 — the
    /// "dequantize-then-matmul" mirror used to verify the fused path.
    pub fn to_dense(&self) -> PackedVit {
        PackedVit {
            geom: self.geom.clone(),
            stores: [
                self.stores[0].to_dense(),
                self.stores[1].to_dense(),
                self.stores[2].to_dense(),
                self.stores[3].to_dense(),
            ],
            rest: self.rest.clone(),
            rest_spec: self.rest_spec.clone(),
            act_quant: self.act_quant,
        }
    }

    /// True when all four quantized weight tensors are held as codes.
    pub fn is_fully_packed(&self) -> bool {
        self.stores.iter().all(Store::is_packed)
    }

    /// Row-shard the quantized stores across `engines`: consumes the
    /// model and returns the trunk (geometry + full-precision tail,
    /// quantized stores vacated so an accidental local `forward` fails
    /// fast instead of computing garbage) plus one [`VitShard`] per
    /// engine holding near-even contiguous row ranges of each store
    /// ([`shard_ranges`]). The trunk drives the shared forward via
    /// [`forward_with`](Self::forward_with) with a scatter/gather
    /// executor.
    pub fn into_shards(self, engines: usize) -> Result<(PackedVit, Vec<VitShard>)> {
        if engines == 0 {
            bail!("fleet needs at least one engine");
        }
        let spec = self.geom.param_spec();
        let mut per_engine: Vec<Vec<Store>> =
            (0..engines).map(|_| Vec::with_capacity(4)).collect();
        let mut row0s: Vec<[usize; 4]> = vec![[0; 4]; engines];
        for (k, name) in QW_NAMES.iter().enumerate() {
            let seg = spec.iter().find(|s| s.name == *name).unwrap();
            let rows_total = seg.size / seg.cols();
            if rows_total < engines {
                bail!(
                    "store {name:?} has {rows_total} rows — cannot shard across {engines} engines"
                );
            }
            for (e, (r0, r1)) in shard_ranges(rows_total, engines).into_iter().enumerate() {
                per_engine[e].push(self.stores[k].slice_rows(r0, r1 - r0)?);
                row0s[e][k] = r0;
            }
        }
        let shards: Vec<VitShard> = per_engine
            .into_iter()
            .zip(row0s)
            .map(|(stores, row0)| VitShard {
                stores: stores.try_into().expect("four stores per shard"),
                row0,
            })
            .collect();
        let trunk = PackedVit {
            stores: [Store::vacated(), Store::vacated(), Store::vacated(), Store::vacated()],
            ..self
        };
        Ok((trunk, shards))
    }

    /// Resident bytes of the quantized weight tensors (codes + scales
    /// for packed stores; f32 bytes for dense ones). The packed serving
    /// path keeps this at ~0.53 bytes/element vs 4 for an f32 mirror.
    pub fn quantized_weight_bytes(&self) -> usize {
        self.stores.iter().map(Store::bytes).sum()
    }

    /// What an f32 mirror of the quantized weights would occupy.
    pub fn f32_mirror_bytes(&self) -> usize {
        self.geom.qw_total() * std::mem::size_of::<f32>()
    }

    /// Non-quantized parameter tensor by spec name (precomputed ranges;
    /// no spec rebuild on the forward hot path).
    fn p(&self, name: &str) -> &[f32] {
        let (_, range) = self
            .rest_spec
            .iter()
            .find(|(n, _)| *n == name)
            .unwrap_or_else(|| panic!("unknown full-precision tensor {name:?}"));
        &self.rest[range.clone()]
    }

    /// Q^(1): quantize a (n, cols) activation matrix in place.
    fn act_q(&self, x: &mut Vec<f32>, cols: usize) {
        match self.act_quant {
            ActQuant::None => {}
            ActQuant::Mx { fmt, scaling } => *x = mx_quantize_cols(x, cols, fmt, scaling),
            ActQuant::Int4 => *x = int4_quantize(x, None),
            ActQuant::Nvfp4 => *x = nvfp4_quantize_cols(x, cols),
        }
    }

    /// [`act_q`](Self::act_q) through an optional memoizing
    /// [`ActQuantCache`] (slot = `blk * 4 + linear index`); bit-exact
    /// to the direct path either way (the cache recomputes via the
    /// split quantizer on a miss and replays stored bytes on a hit).
    fn act_q_cached(
        &self,
        cache: &mut Option<&mut ActQuantCache>,
        slot: usize,
        x: &mut Vec<f32>,
        cols: usize,
    ) {
        match cache {
            Some(c) => c.quantize(slot, &self.act_quant, x, cols),
            None => self.act_q(x, cols),
        }
    }

    /// Forward pass: `x` is a (batch, img, img, 3) HWC pixel block; the
    /// result is (batch, classes) logits. Deterministic; the quantized
    /// linears run fused over packed codes (or dense f32 for
    /// [`to_dense`](Self::to_dense) mirrors) with identical numerics.
    pub fn forward(&self, x: &[f32], batch: usize, workers: usize) -> Vec<f32> {
        self.forward_with(x, batch, &LocalExec { vit: self, workers })
    }

    /// [`forward`](Self::forward) with per-layer kernel instrumentation:
    /// each quantized linear bumps `kernel.{layer}.calls` / `.ms` on the
    /// way through. Numerically identical to the uninstrumented path.
    pub fn forward_observed(
        &self,
        x: &[f32],
        batch: usize,
        workers: usize,
        kernel: &KernelMetrics,
    ) -> Vec<f32> {
        let local = LocalExec { vit: self, workers };
        self.forward_with(x, batch, &ObservedExec { inner: &local, kernel })
    }

    /// [`forward_observed`](Self::forward_observed) with Q1 activation
    /// quantization routed through a memoizing [`ActQuantCache`]
    /// (slot = `blk * 4 + linear index`). Logits are bit-identical to
    /// the uncached forward whether each site hits or misses.
    pub fn forward_cached(
        &self,
        x: &[f32],
        batch: usize,
        workers: usize,
        kernel: &KernelMetrics,
        cache: &mut ActQuantCache,
    ) -> Vec<f32> {
        let local = LocalExec { vit: self, workers };
        let exec = ObservedExec { inner: &local, kernel };
        self.forward_with_cache(x, batch, &exec, Some(cache))
    }

    /// The forward pass with the quantized linears delegated to `exec`
    /// (the [`LinearExec`] seam). [`forward`](Self::forward) routes
    /// here with the in-process executor; the serve fleet routes here
    /// with its scatter/gather executor — one forward, two execution
    /// substrates, bit-exact by the trait's contract.
    pub fn forward_with(&self, x: &[f32], batch: usize, exec: &dyn LinearExec) -> Vec<f32> {
        self.forward_with_cache(x, batch, exec, None)
    }

    /// [`forward_with`](Self::forward_with) plus an optional
    /// [`ActQuantCache`]: each of the 4-per-block Q1 sites quantizes
    /// through its cache slot when one is supplied, replaying the
    /// memoized bytes when the activation block is bitwise unchanged.
    pub fn forward_with_cache(
        &self,
        x: &[f32],
        batch: usize,
        exec: &dyn LinearExec,
        mut cache: Option<&mut ActQuantCache>,
    ) -> Vec<f32> {
        let g = &self.geom;
        assert_eq!(x.len(), batch * g.img * g.img * 3, "x must be (batch, img, img, 3)");
        let (dim, seq, heads, hd) = (g.dim, g.seq, g.heads, g.head_dim);
        let np = seq - 1;
        let hp = g.img / g.patch;

        // Patchify (B, H, W, 3) -> (B*np, patch_dim), matching the
        // reshape/transpose in vit.py::_patchify.
        let mut patches = vec![0.0f32; batch * np * g.patch_dim];
        for b in 0..batch {
            for py in 0..hp {
                for px in 0..hp {
                    let t = py * hp + px;
                    let dst = (b * np + t) * g.patch_dim;
                    for iy in 0..g.patch {
                        for ix in 0..g.patch {
                            let src = ((b * g.img + py * g.patch + iy) * g.img
                                + px * g.patch
                                + ix)
                                * 3;
                            let f = (iy * g.patch + ix) * 3;
                            patches[dst + f..dst + f + 3].copy_from_slice(&x[src..src + 3]);
                        }
                    }
                }
            }
        }

        // tok = patches @ patch_embed.w^T + b (full precision).
        let tok = matmul_ref(
            &patches,
            batch * np,
            g.patch_dim,
            self.p("patch_embed.w"),
            dim,
            Some(self.p("patch_embed.b")),
        );

        // h = concat(cls, tok) + pos, per batch row.
        let (cls, pos) = (self.p("cls"), self.p("pos"));
        let mut h = vec![0.0f32; batch * seq * dim];
        for b in 0..batch {
            let row = &mut h[b * seq * dim..b * seq * dim + dim];
            for (o, (&c, &p)) in row.iter_mut().zip(cls.iter().zip(&pos[..dim])) {
                *o = c + p;
            }
            for t in 0..np {
                let dst = (b * seq + t + 1) * dim;
                let src = (b * np + t) * dim;
                for e in 0..dim {
                    h[dst + e] = tok[src + e] + pos[(t + 1) * dim + e];
                }
            }
        }

        let n = batch * seq;
        for blk in 0..g.depth {
            // --- attention ---
            let mut hn = layer_norm(
                &h,
                n,
                dim,
                &self.p("blocks.ln1.g")[blk * dim..(blk + 1) * dim],
                &self.p("blocks.ln1.b")[blk * dim..(blk + 1) * dim],
            );
            self.act_q_cached(&mut cache, blk * 4, &mut hn, dim);
            let qkv = exec.qlinear(
                0,
                &hn,
                n,
                blk * 3 * dim,
                3 * dim,
                Some(&self.p("blocks.qkv_b")[blk * 3 * dim..(blk + 1) * 3 * dim]),
            );
            let mut att_out = vec![0.0f32; n * dim];
            let inv_sqrt = 1.0 / (hd as f32).sqrt();
            let mut scores = vec![0.0f32; seq * seq];
            for b in 0..batch {
                for hh in 0..heads {
                    let at = |j: usize, t: usize, e: usize| {
                        qkv[(b * seq + t) * 3 * dim + j * dim + hh * hd + e]
                    };
                    for s in 0..seq {
                        for t in 0..seq {
                            let mut acc = 0.0f32;
                            for e in 0..hd {
                                acc += at(0, s, e) * at(1, t, e);
                            }
                            scores[s * seq + t] = acc * inv_sqrt;
                        }
                        softmax_row(&mut scores[s * seq..(s + 1) * seq]);
                    }
                    for s in 0..seq {
                        let dst = (b * seq + s) * dim + hh * hd;
                        for e in 0..hd {
                            let mut acc = 0.0f32;
                            for t in 0..seq {
                                acc += scores[s * seq + t] * at(2, t, e);
                            }
                            att_out[dst + e] = acc;
                        }
                    }
                }
            }
            self.act_q_cached(&mut cache, blk * 4 + 1, &mut att_out, dim);
            let proj = exec.qlinear(
                1,
                &att_out,
                n,
                blk * dim,
                dim,
                Some(&self.p("blocks.proj_b")[blk * dim..(blk + 1) * dim]),
            );
            for (hv, &pv) in h.iter_mut().zip(&proj) {
                *hv += pv;
            }
            // --- mlp ---
            let mut hn = layer_norm(
                &h,
                n,
                dim,
                &self.p("blocks.ln2.g")[blk * dim..(blk + 1) * dim],
                &self.p("blocks.ln2.b")[blk * dim..(blk + 1) * dim],
            );
            self.act_q_cached(&mut cache, blk * 4 + 2, &mut hn, dim);
            let mut z = exec.qlinear(
                2,
                &hn,
                n,
                blk * g.hidden,
                g.hidden,
                Some(&self.p("blocks.fc1_b")[blk * g.hidden..(blk + 1) * g.hidden]),
            );
            for v in z.iter_mut() {
                *v = gelu_tanh(*v);
            }
            self.act_q_cached(&mut cache, blk * 4 + 3, &mut z, g.hidden);
            let mlp = exec.qlinear(
                3,
                &z,
                n,
                blk * dim,
                dim,
                Some(&self.p("blocks.fc2_b")[blk * dim..(blk + 1) * dim]),
            );
            for (hv, &mv) in h.iter_mut().zip(&mlp) {
                *hv += mv;
            }
        }

        let hn = layer_norm(&h, n, dim, self.p("ln_f.g"), self.p("ln_f.b"));
        // Classifier over the cls token only.
        let mut cls_rows = vec![0.0f32; batch * dim];
        for b in 0..batch {
            cls_rows[b * dim..(b + 1) * dim]
                .copy_from_slice(&hn[b * seq * dim..b * seq * dim + dim]);
        }
        matmul_ref(&cls_rows, batch, dim, self.p("head.w"), g.classes, Some(self.p("head.b")))
    }
}

/// Pre-LN layer norm over the trailing `dim` axis (eps 1e-6, matching
/// vit.py::_layer_norm with biased variance).
fn layer_norm(x: &[f32], n: usize, dim: usize, gain: &[f32], bias: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), n * dim);
    let mut out = vec![0.0f32; n * dim];
    for i in 0..n {
        let row = &x[i * dim..(i + 1) * dim];
        let mu = row.iter().sum::<f32>() / dim as f32;
        let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / dim as f32;
        let inv = 1.0 / (var + 1e-6).sqrt();
        let o = &mut out[i * dim..(i + 1) * dim];
        for (j, (ov, &v)) in o.iter_mut().zip(row).enumerate() {
            *ov = (v - mu) * inv * gain[j] + bias[j];
        }
    }
    out
}

/// Numerically-stable softmax in place (max-subtracted, like
/// jax.nn.softmax).
fn softmax_row(row: &mut [f32]) {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// JAX's default (approximate) GELU: 0.5x(1 + tanh(√(2/π)(x + 0.044715x³))).
fn gelu_tanh(x: f32) -> f32 {
    const C: f32 = 0.797_884_56;
    0.5 * x * (1.0 + (C * (x + 0.044_715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_geom() -> ServeGeom {
        ServeGeom::new(8, 4, 32, 2, 4, 3, 4)
    }

    fn random_params(geom: &ServeGeom, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let spec = geom.param_spec();
        let mut p = vec![0.0f32; geom.total_params()];
        for s in &spec {
            for v in &mut p[s.range()] {
                *v = match s.name {
                    n if n.ends_with(".g") => 1.0 + rng.normal() * 0.02,
                    n if n.ends_with(".b") || n == "head.b" => rng.normal() * 0.01,
                    _ => rng.normal() * 0.08,
                };
            }
        }
        p
    }

    #[test]
    fn layout_matches_vit_micro_totals() {
        // vit-micro: ~0.22M params, 196,608 of them quantized.
        let g = ServeGeom::new(32, 4, 64, 4, 4, 10, 4);
        assert_eq!(g.qw_total(), 196_608);
        assert_eq!(g.seq, 65);
        assert_eq!(g.patch_dim, 48);
        let spec = g.param_spec();
        assert_eq!(spec.len(), 20);
        assert_eq!(spec[0].name, "blocks.qkv_w");
        assert_eq!(spec[0].shape, vec![4, 192, 64]);
        // Quantized prefix is contiguous from zero.
        let mut off = 0;
        for s in spec.iter().filter(|s| s.quantized) {
            assert_eq!(s.offset, off);
            off += s.size;
        }
        assert_eq!(off, g.qw_total());
        assert_eq!(g.total_params(), spec.last().map(|s| s.offset + s.size).unwrap());
    }

    #[test]
    fn fused_forward_matches_dense_mirror_bit_exact() {
        let geom = tiny_geom();
        let params = random_params(&geom, 3);
        let fmt = crate::quant::e2m1();
        let packed = PackedVit::build(
            geom.clone(),
            &params,
            None,
            WeightQuant::Mx { fmt, scaling: Scaling::TruncationFree },
            ActQuant::Mx { fmt, scaling: Scaling::TruncationFree },
        )
        .unwrap();
        assert!(packed.is_fully_packed());
        let mirror = packed.to_dense();
        assert!(!mirror.is_fully_packed());
        let mut rng = Rng::new(11);
        let batch = 3;
        let x: Vec<f32> = (0..batch * geom.img * geom.img * 3).map(|_| rng.normal()).collect();
        let a = packed.forward(&x, batch, 1);
        let b = mirror.forward(&x, batch, 4);
        assert_eq!(a, b, "fused and dequant-mirror forwards must agree bit-for-bit");
        assert_eq!(a.len(), batch * geom.classes);
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn cached_forward_matches_uncached_bit_exact() {
        let geom = tiny_geom();
        let params = random_params(&geom, 14);
        let fmt = crate::quant::e2m1();
        let packed = PackedVit::build(
            geom.clone(),
            &params,
            None,
            WeightQuant::Mx { fmt, scaling: Scaling::TruncationFree },
            ActQuant::Mx { fmt, scaling: Scaling::TruncationFree },
        )
        .unwrap();
        let mut rng = Rng::new(15);
        let batch = 2;
        let x: Vec<f32> = (0..batch * geom.img * geom.img * 3).map(|_| rng.normal()).collect();
        let want = packed.forward(&x, batch, 1);
        let kernel = KernelMetrics::detached();
        let mut cache = ActQuantCache::new(geom.depth * 4);
        let cold = packed.forward_cached(&x, batch, 2, &kernel, &mut cache);
        let same = want.iter().zip(&cold).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "cold cached forward must equal uncached bit-for-bit");
        assert_eq!(cache.stats(), (0, geom.depth as u64 * 4));
        // Same images again: every Q1 site hits, logits unchanged.
        let warm = packed.forward_cached(&x, batch, 2, &kernel, &mut cache);
        assert_eq!(warm, cold);
        assert_eq!(cache.stats(), (geom.depth as u64 * 4, geom.depth as u64 * 4));
        // The dense mirror sees the same Q1 inputs, so a shared cache
        // turns its whole quantization pass into hits.
        let mirror = packed.to_dense();
        let m = mirror.forward_cached(&x, batch, 2, &kernel, &mut cache);
        assert_eq!(m, want);
        assert_eq!(cache.stats().0, geom.depth as u64 * 8);
    }

    #[test]
    fn packed_model_never_holds_f32_weights() {
        let geom = tiny_geom();
        let params = random_params(&geom, 4);
        let fmt = crate::quant::e2m1();
        let m = PackedVit::build(
            geom.clone(),
            &params,
            None,
            WeightQuant::Mx { fmt, scaling: Scaling::TruncationFree },
            ActQuant::None,
        )
        .unwrap();
        // codes: 0.5 B/elem; scales: one byte per 32 elements (dim and
        // hidden are multiples of 32 here, so no ragged groups).
        let qw = geom.qw_total();
        assert_eq!(m.quantized_weight_bytes(), qw / 2 + qw / 32);
        assert!(m.quantized_weight_bytes() * 7 < m.f32_mirror_bytes());
    }

    #[test]
    fn dense_weight_quant_keeps_fp32() {
        let geom = tiny_geom();
        let params = random_params(&geom, 5);
        let m = PackedVit::build(geom, &params, None, WeightQuant::Dense, ActQuant::None).unwrap();
        assert!(!m.is_fully_packed());
        assert_eq!(m.quantized_weight_bytes(), m.f32_mirror_bytes());
        // fp32 forward is just the reference ViT; finite logits.
        let x = vec![0.1f32; 8 * 8 * 3];
        assert!(m.forward(&x, 1, 1).iter().all(|v| v.is_finite()));
    }

    #[test]
    fn shard_ranges_cover_contiguously() {
        assert_eq!(shard_ranges(10, 3), vec![(0, 4), (4, 7), (7, 10)]);
        assert_eq!(shard_ranges(4, 4), vec![(0, 1), (1, 2), (2, 3), (3, 4)]);
        let r = shard_ranges(192, 5);
        assert_eq!(r.first().unwrap().0, 0);
        assert_eq!(r.last().unwrap().1, 192);
        for w in r.windows(2) {
            assert_eq!(w[0].1, w[1].0, "ranges must tile without gaps");
        }
    }

    /// In-test gather executor: the same scatter/gather the fleet does,
    /// minus the threads — isolates the sharding math from mpsc.
    struct GatherExec<'a> {
        shards: &'a [VitShard],
    }

    impl LinearExec for GatherExec<'_> {
        fn qlinear(
            &self,
            store: usize,
            x: &[f32],
            n: usize,
            row0: usize,
            rows: usize,
            bias: Option<&[f32]>,
        ) -> Vec<f32> {
            let mut out = vec![0.0f32; n * rows];
            for sh in self.shards {
                let (s, e) = sh.range(store);
                let (a, b) = (row0.max(s), (row0 + rows).min(e));
                if a >= b {
                    continue;
                }
                let part = sh.linear(store, x, n, a, b - a, 1);
                let (w, c0) = (b - a, a - row0);
                for i in 0..n {
                    out[i * rows + c0..i * rows + c0 + w]
                        .copy_from_slice(&part[i * w..(i + 1) * w]);
                }
            }
            if let Some(bias) = bias {
                for i in 0..n {
                    for (o, &bv) in out[i * rows..(i + 1) * rows].iter_mut().zip(bias) {
                        *o += bv;
                    }
                }
            }
            out
        }
    }

    #[test]
    fn sharded_forward_is_bit_exact_including_ragged_splits() {
        let geom = tiny_geom();
        let params = random_params(&geom, 7);
        let fmt = crate::quant::e2m1();
        let vit = PackedVit::build(
            geom.clone(),
            &params,
            None,
            WeightQuant::Mx { fmt, scaling: Scaling::TruncationFree },
            ActQuant::Mx { fmt, scaling: Scaling::TruncationFree },
        )
        .unwrap();
        let mut rng = Rng::new(21);
        let batch = 2;
        let x: Vec<f32> = (0..batch * geom.img * geom.img * 3).map(|_| rng.normal()).collect();
        let want = vit.forward(&x, batch, 1);
        let qw_bytes = vit.quantized_weight_bytes();
        // 3 and 5 do not divide the per-store row counts evenly here.
        for engines in [1usize, 2, 3, 5] {
            let (trunk, shards) = vit.clone().into_shards(engines).unwrap();
            assert_eq!(shards.len(), engines);
            assert_eq!(
                shards.iter().map(VitShard::bytes).sum::<usize>(),
                qw_bytes,
                "shards must hold exactly the original code/scale bytes"
            );
            let got = trunk.forward_with(&x, batch, &GatherExec { shards: &shards });
            assert_eq!(got, want, "{engines}-way sharded logits must be bit-exact");
        }
    }

    #[test]
    fn into_shards_rejects_impossible_splits() {
        let geom = tiny_geom();
        let params = random_params(&geom, 8);
        let fmt = crate::quant::e2m1();
        let build = || {
            PackedVit::build(
                geom.clone(),
                &params,
                None,
                WeightQuant::Mx { fmt, scaling: Scaling::TruncationFree },
                ActQuant::None,
            )
            .unwrap()
        };
        assert!(build().into_shards(0).is_err());
        // proj/fc2 have depth*dim = 64 rows in the tiny geometry.
        assert!(build().into_shards(65).is_err());
        assert!(build().into_shards(64).is_ok());
    }

    #[test]
    fn nvfp4_fused_forward_matches_dense_mirror_bit_exact() {
        let geom = tiny_geom();
        let params = random_params(&geom, 31);
        let packed =
            PackedVit::build(geom.clone(), &params, None, WeightQuant::Nvfp4, ActQuant::Nvfp4)
                .unwrap();
        assert!(packed.is_fully_packed());
        for s in &packed.stores {
            if let Store::Packed(p) = s {
                assert_eq!(p.geom(), GroupGeom::nvfp4());
            }
        }
        // 16-element groups: one scale byte per 16 elements.
        let qw = geom.qw_total();
        assert_eq!(packed.quantized_weight_bytes(), qw / 2 + qw / 16);
        let mirror = packed.to_dense();
        let mut rng = Rng::new(33);
        let batch = 3;
        let x: Vec<f32> = (0..batch * geom.img * geom.img * 3).map(|_| rng.normal()).collect();
        let a = packed.forward(&x, batch, 1);
        let b = mirror.forward(&x, batch, 4);
        assert_eq!(a, b, "nvfp4 fused and dequant-mirror forwards must agree bit-for-bit");
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn nvfp4_sharded_forward_is_bit_exact_including_ragged_splits() {
        let geom = tiny_geom();
        let params = random_params(&geom, 37);
        let vit =
            PackedVit::build(geom.clone(), &params, None, WeightQuant::Nvfp4, ActQuant::Nvfp4)
                .unwrap();
        let mut rng = Rng::new(41);
        let batch = 2;
        let x: Vec<f32> = (0..batch * geom.img * geom.img * 3).map(|_| rng.normal()).collect();
        let want = vit.forward(&x, batch, 1);
        let qw_bytes = vit.quantized_weight_bytes();
        for engines in [1usize, 2, 3, 5] {
            let (trunk, shards) = vit.clone().into_shards(engines).unwrap();
            assert_eq!(
                shards.iter().map(VitShard::bytes).sum::<usize>(),
                qw_bytes,
                "nvfp4 shards must hold exactly the original code/scale bytes"
            );
            let got = trunk.forward_with(&x, batch, &GatherExec { shards: &shards });
            assert_eq!(got, want, "{engines}-way nvfp4 sharded logits must be bit-exact");
        }
    }

    #[test]
    fn qema_build_requires_ema() {
        let geom = tiny_geom();
        let params = random_params(&geom, 6);
        let fmt = crate::quant::e2m1();
        let wq = WeightQuant::Qema { fmt, scaling: Scaling::TruncationFree };
        assert!(PackedVit::build(geom.clone(), &params, None, wq, ActQuant::None).is_err());
        let ema: Vec<f32> = params[..geom.qw_total()].iter().map(|v| v * 0.9).collect();
        assert!(PackedVit::build(geom, &params, Some(&ema), wq, ActQuant::None).is_ok());
    }
}
