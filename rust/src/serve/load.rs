//! Seeded open-loop load generator for the serve fleet.
//!
//! Open-loop means arrivals do not wait for completions: a Poisson
//! process (exponential inter-arrival gaps from the repo's
//! deterministic [`Rng`]) fires requests at the configured rate no
//! matter how far behind the fleet falls — the regime where tail
//! latency and queue-depth backpressure actually show up, unlike
//! closed-loop batch replay.
//!
//! Two pacing modes:
//!
//! * [`Pace::Real`] — arrivals are replayed on the wall clock (sleeps
//!   between arrivals), latencies are measured. Honest numbers, but
//!   machine-dependent.
//! * [`Pace::Virtual`] — the event loop interleaves arrivals and batch
//!   completions on a simulated clock where every image costs a fixed
//!   `ms_per_image`. The forwards still execute for real (logits and
//!   accuracy are genuine), but admission, rejection, expiry, batch
//!   formation and every latency number are pure functions of
//!   (seed, config) — the determinism property the load tests pin.
//!
//! The same property extends to observability: with a deterministic
//! [`crate::obs::TraceSink`] attached (`serve --trace-out` under
//! `--pace virtual`), every trace timestamp comes from the simulated
//! clock and the emitted JSONL is byte-identical across runs — the
//! trace digest is asserted in `tests/obs.rs`. Real-measured compute
//! times never enter the trace in that mode.

use anyhow::{bail, Result};
use std::collections::HashMap;

use crate::serve::fleet::ServeFleet;
use crate::serve::scheduler::{Outcome, Reject};
use crate::serve::stats::LatencySummary;
use crate::util::rng::Rng;

/// How the load generator advances time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pace {
    /// Replay arrivals on the wall clock; measure real latencies.
    Real,
    /// Simulated clock: each image costs `ms_per_image` of service
    /// time. Fully deterministic for a given seed + config.
    Virtual { ms_per_image: f64 },
}

/// Load-test shape: seeded Poisson arrivals of fixed-size requests.
#[derive(Debug, Clone, Copy)]
pub struct LoadSpec {
    pub seed: u64,
    /// Total requests to fire.
    pub requests: usize,
    /// Images per request.
    pub request_size: usize,
    /// Mean arrival rate, requests per second.
    pub rate_rps: f64,
    /// Optional per-request deadline, relative to its arrival.
    pub deadline_ms: Option<f64>,
    pub pace: Pace,
}

impl LoadSpec {
    /// The arrival schedule in milliseconds: cumulative exponential
    /// gaps with mean `1/rate_rps`, from a stream folded off the seed
    /// (tag "LOAD") so it is independent of any model/data stream.
    pub fn schedule(&self) -> Vec<f64> {
        assert!(self.rate_rps > 0.0, "arrival rate must be positive");
        let mut rng = Rng::new(self.seed).fold_in(0x4c4f4144); // "LOAD"
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(self.requests);
        for _ in 0..self.requests {
            let u = rng.uniform() as f64; // [0, 1) -> 1-u in (0, 1]
            t += -(1.0 - u).ln() / self.rate_rps * 1e3;
            out.push(t);
        }
        out
    }
}

/// Outcome tally of one load-test run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub summary: LatencySummary,
    pub accepted: usize,
    pub rejected: usize,
    pub expired: usize,
    pub completed: usize,
    /// Top-1 correct predictions among labeled completed requests.
    pub correct: usize,
    /// Images with labels (0 for synthetic/unlabeled runs).
    pub labeled: usize,
}

/// Drive `fleet` with the open-loop arrival process described by
/// `spec`. `make_request(i)` supplies the i-th request's pixel block
/// plus per-image labels (empty when unlabeled).
pub fn run_load_test<F>(
    fleet: &mut ServeFleet,
    spec: &LoadSpec,
    mut make_request: F,
) -> Result<LoadReport>
where
    F: FnMut(usize) -> (Vec<f32>, Vec<i32>),
{
    let sched = spec.schedule();
    let mut labels: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut accepted = 0usize;
    let mut rejected = 0usize;
    let mut i = 0usize;

    let mut admit = |fleet: &mut ServeFleet,
                     i: usize,
                     arrival_ms: f64,
                     labels: &mut HashMap<u64, Vec<i32>>|
     -> Result<bool> {
        let (images, y) = make_request(i);
        let deadline = spec.deadline_ms.map(|d| arrival_ms + d);
        match fleet.submit_at(images, spec.request_size, deadline, arrival_ms) {
            Ok(t) => {
                if !y.is_empty() {
                    labels.insert(t.id, y);
                }
                Ok(true)
            }
            Err(Reject::QueueFull { .. }) => Ok(false),
            Err(e @ Reject::BadRequest(_)) => bail!("load generator built a bad request: {e}"),
        }
    };

    match spec.pace {
        Pace::Virtual { ms_per_image } => {
            // Event loop on the simulated clock: the fleet serves the
            // moment it is free and has work; an arrival earlier than
            // the next service point is admitted first.
            let mut free = 0.0f64;
            loop {
                let next_arr = sched.get(i).copied().unwrap_or(f64::INFINITY);
                let serve_at = fleet.earliest_arrival().map(|a| a.max(free));
                match serve_at {
                    Some(s) if s <= next_arr => {
                        match fleet.step_at(s, Some(ms_per_image)) {
                            Some(info) if info.m > 0 => free = free.max(info.done_ms),
                            // Expiry-only or empty step: service point
                            // consumed no simulated time.
                            _ => free = free.max(s),
                        }
                    }
                    _ if i < sched.len() => {
                        if admit(fleet, i, next_arr, &mut labels)? {
                            accepted += 1;
                        } else {
                            rejected += 1;
                        }
                        i += 1;
                    }
                    _ => break,
                }
            }
        }
        Pace::Real => {
            let base = fleet.now_ms();
            while i < sched.len() || fleet.pending() > 0 {
                // Admit everything that has arrived by now.
                while i < sched.len() && base + sched[i] <= fleet.now_ms() {
                    let arrival = fleet.now_ms();
                    if admit(fleet, i, arrival, &mut labels)? {
                        accepted += 1;
                    } else {
                        rejected += 1;
                    }
                    i += 1;
                }
                if !fleet.step() && i < sched.len() {
                    let wait_ms = (base + sched[i] - fleet.now_ms()).max(0.0);
                    std::thread::sleep(std::time::Duration::from_micros((wait_ms * 1e3) as u64));
                }
            }
        }
    }

    // Queue is dry; drain outcomes and tally.
    let outcomes = fleet.wait_all();
    let mut completed = 0usize;
    let mut expired = 0usize;
    let mut correct = 0usize;
    let mut labeled = 0usize;
    for o in outcomes {
        match o {
            Outcome::Done(r) => {
                completed += 1;
                if let Some(y) = labels.get(&r.id) {
                    labeled += y.len();
                    correct += r
                        .preds
                        .iter()
                        .zip(y)
                        .filter(|(&p, &l)| p == l as usize)
                        .count();
                }
            }
            Outcome::Expired { .. } => expired += 1,
        }
    }
    Ok(LoadReport {
        summary: fleet.stats(),
        accepted,
        rejected,
        expired,
        completed,
        correct,
        labeled,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_seed_deterministic_and_rate_scaled() {
        let spec = |seed, rate| LoadSpec {
            seed,
            requests: 500,
            request_size: 2,
            rate_rps: rate,
            deadline_ms: None,
            pace: Pace::Virtual { ms_per_image: 1.0 },
        };
        let a = spec(7, 100.0).schedule();
        let b = spec(7, 100.0).schedule();
        assert_eq!(a, b, "same seed must give the same arrival schedule");
        assert_ne!(a, spec(8, 100.0).schedule());
        assert!(a.windows(2).all(|w| w[1] > w[0]), "arrival times strictly increase");
        // Mean gap ~ 10ms at 100 rps (loose 3-sigma-ish bound).
        let mean_gap = a.last().unwrap() / a.len() as f64;
        assert!((mean_gap - 10.0).abs() < 2.0, "mean gap {mean_gap}ms");
        // Doubling the rate halves the horizon for the same seed.
        let fast = spec(7, 200.0).schedule();
        assert!((fast.last().unwrap() * 2.0 - a.last().unwrap()).abs() < 1e-6);
    }
}
