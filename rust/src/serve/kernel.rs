//! Fused group-wise dequant-matmul: the serving GEMM that consumes
//! [`PackedMx`] codes directly.
//!
//! `Y = X · W_Q^T` with `X: (n, d)` activations and `W_Q` a packed
//! quantized weight whose rows live in a [`PackedMx`] (optionally a row
//! range of a depth-stacked tensor). The kernel walks the codes one
//! 1x32 group at a time: the E8M0 scale is decoded once per group (one
//! `exp2i`), the group's nibbles are expanded through the level table
//! into a 32-wide stack tile, and that tile is FMAed against every
//! activation row before the next group is touched. No full f32 weight
//! matrix ever exists.
//!
//! **Bit-exactness guarantee:** for every output element the fused
//! kernel performs *the same f32 operations in the same order* as
//! [`matmul_ref`] over [`PackedMx::dequantize_into`]'s output —
//! per-element products against `level * scale` values accumulated in
//! ascending contraction order, bias added once at the end. The two
//! paths therefore agree bit-for-bit (property-tested in
//! `tests/serve.rs`, including ragged non-multiple-of-32 columns).
//!
//! Parallelism: output rows of the internal `(rows, n)` transposed tile
//! (i.e. the rows of `W_Q`) are distributed over a scoped thread pool
//! ([`crate::util::parallel`]), so decode work is done exactly once per
//! weight row regardless of batch size.
//!
//! The same row axis is the fleet's sharding seam: because each output
//! element depends on exactly one weight row, a contiguous row range
//! computed on another engine from a byte-sliced shard
//! ([`PackedMx::slice_rows`]) is bit-identical to the same rows of a
//! single-engine call, and gathering per-engine column blocks then
//! adding the bias once reproduces this kernel's output exactly
//! (`serve/fleet.rs`).

use crate::quant::{PackedMx, GROUP};
use crate::util::parallel::parallel_for_each_mut;

/// Reference GEMM over an already-dequantized weight: `x (n, d)` times
/// `wq (rows, d)` transposed, accumulating the contraction axis in
/// ascending order, plus an optional per-output-column bias. This is
/// the "dequantize-then-matmul" baseline the fused kernel is measured
/// and verified against.
pub fn matmul_ref(
    x: &[f32],
    n: usize,
    d: usize,
    wq: &[f32],
    rows: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    assert_eq!(x.len(), n * d, "x must be (n, d)");
    assert_eq!(wq.len(), rows * d, "wq must be (rows, d)");
    if let Some(b) = bias {
        assert_eq!(b.len(), rows);
    }
    let mut out = vec![0.0f32; n * rows];
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        let oi = &mut out[i * rows..(i + 1) * rows];
        for (c, o) in oi.iter_mut().enumerate() {
            let wr = &wq[c * d..(c + 1) * d];
            let mut acc = 0.0f32;
            for j in 0..d {
                acc += xi[j] * wr[j];
            }
            *o = acc + bias.map_or(0.0, |b| b[c]);
        }
    }
    out
}

/// Row-parallel dense GEMM with [`matmul_ref`]'s exact per-element
/// accumulation order (ascending contraction index, bias last), so the
/// dense mirror of a packed model stays bit-exact to the serial
/// reference while sharing the fused kernel's strip parallelism.
/// `wq` is the `(rows, d)` row range already sliced by the caller.
pub fn dense_matmul(
    x: &[f32],
    n: usize,
    d: usize,
    wq: &[f32],
    rows: usize,
    bias: Option<&[f32]>,
    workers: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), n * d, "x must be (n, d)");
    assert_eq!(wq.len(), rows * d, "wq must be (rows, d)");
    if let Some(b) = bias {
        assert_eq!(b.len(), rows);
    }
    if n == 0 || rows == 0 {
        return Vec::new();
    }
    let mut out_t = vec![0.0f32; rows * n];
    let mut strips: Vec<&mut [f32]> = out_t.chunks_mut(n).collect();
    let workers = workers.max(1).min(rows);
    parallel_for_each_mut(&mut strips, workers, |c, acc| {
        let wr = &wq[c * d..(c + 1) * d];
        for (i, av) in acc.iter_mut().enumerate() {
            let xi = &x[i * d..(i + 1) * d];
            let mut s = 0.0f32;
            for (xv, wv) in xi.iter().zip(wr) {
                s += xv * wv;
            }
            *av = s + bias.map_or(0.0, |b| b[c]);
        }
    });
    let mut out = vec![0.0f32; n * rows];
    for c in 0..rows {
        let strip = &out_t[c * n..(c + 1) * n];
        for (i, &v) in strip.iter().enumerate() {
            out[i * rows + c] = v;
        }
    }
    out
}

/// Fused dequant-matmul over a row range of a packed weight:
/// `out (n, rows)` with `out[i][c] = x[i] · dequant(w.row(row0 + c)) +
/// bias[c]`, without materializing the dequantized weight. `w.cols()`
/// is the contraction dimension; `row0`/`rows` select a block of a
/// depth-stacked tensor (e.g. one transformer block's slice of
/// `blocks.fc1_w`). Bit-exact to [`matmul_ref`] over the dequantized
/// rows.
pub fn fused_matmul(
    x: &[f32],
    n: usize,
    w: &PackedMx,
    row0: usize,
    rows: usize,
    bias: Option<&[f32]>,
    workers: usize,
) -> Vec<f32> {
    let d = w.cols();
    assert!(d > 0 && w.len() % d == 0, "packed weight must be rectangular");
    assert!((row0 + rows) * d <= w.len(), "row range exceeds packed weight");
    assert_eq!(x.len(), n * d, "x must be (n, d)");
    if let Some(b) = bias {
        assert_eq!(b.len(), rows);
    }
    if n == 0 || rows == 0 {
        return Vec::new();
    }
    let gpr = w.groups_per_row();
    let grouped = w.num_groups() > 0;

    // Transposed output tile (rows, n): each weight row owns a
    // contiguous strip, so the row-parallel workers never share cache
    // lines and the codes of a row are decoded exactly once.
    let mut out_t = vec![0.0f32; rows * n];
    let mut strips: Vec<&mut [f32]> = out_t.chunks_mut(n).collect();
    let workers = workers.max(1).min(rows);
    parallel_for_each_mut(&mut strips, workers, |c, acc| {
        let r = row0 + c;
        let mut tile = [0.0f32; GROUP];
        for k in 0..gpr {
            let a = r * d + k * GROUP;
            let b = r * d + ((k + 1) * GROUP).min(d);
            let glen = b - a;
            // One scale decode (exp2i) per group, hoisted out of the
            // element loop; per-tensor (INT4) weights share one scale.
            let scale = if grouped { w.group_scale(r * gpr + k) } else { w.tensor_scale() };
            for (j, t) in tile[..glen].iter_mut().enumerate() {
                *t = w.level(w.code(a + j)) * scale;
            }
            let col0 = k * GROUP;
            for (i, av) in acc.iter_mut().enumerate() {
                let xg = &x[i * d + col0..i * d + col0 + glen];
                let mut s = *av;
                for (xv, tv) in xg.iter().zip(&tile[..glen]) {
                    s += xv * tv;
                }
                *av = s;
            }
        }
        if let Some(bias) = bias {
            let bv = bias[c];
            for av in acc.iter_mut() {
                *av += bv;
            }
        }
    });

    // Back to the caller's (n, rows) layout.
    let mut out = vec![0.0f32; n * rows];
    for c in 0..rows {
        let strip = &out_t[c * n..(c + 1) * n];
        for (i, &v) in strip.iter().enumerate() {
            out[i * rows + c] = v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{e2m1, Int4Quantizer, MxQuantizer, Quantizer, Scaling};
    use crate::util::rng::Rng;

    fn fused_vs_ref(n: usize, d: usize, rows: usize, bias: bool, seed: u64) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * d).map(|_| rng.normal() * 0.2).collect();
        let b: Vec<f32> = (0..rows).map(|_| rng.normal() * 0.1).collect();
        let bias = bias.then_some(&b[..]);
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&w, d, &mut p);
        let wq = p.dequantize();
        let want = matmul_ref(&x, n, d, &wq, rows, bias);
        for workers in [1, 4] {
            let got = fused_matmul(&x, n, &p, 0, rows, bias, workers);
            assert_eq!(got, want, "n={n} d={d} rows={rows} workers={workers}");
        }
    }

    #[test]
    fn fused_matches_dequant_matmul_exact() {
        fused_vs_ref(1, 32, 4, false, 1);
        fused_vs_ref(3, 64, 8, true, 2);
        // Ragged contraction dims: 48 = 32 + 16, 57 = 32 + 25.
        fused_vs_ref(5, 48, 7, true, 3);
        fused_vs_ref(2, 57, 3, false, 4);
    }

    #[test]
    fn fused_row_range_selects_block() {
        let mut rng = Rng::new(9);
        let (d, rows) = (32usize, 12usize);
        let w: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..2 * d).map(|_| rng.normal()).collect();
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&w, d, &mut p);
        let wq = p.dequantize();
        // Rows 4..8 only — a "block 1 of 3" slice of a stacked weight.
        let want = matmul_ref(&x, 2, d, &wq[4 * d..8 * d], 4, None);
        assert_eq!(fused_matmul(&x, 2, &p, 4, 4, None, 2), want);
    }

    #[test]
    fn fused_handles_per_tensor_int4() {
        let mut rng = Rng::new(5);
        let (n, d, rows) = (3usize, 40usize, 6usize);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * d).map(|_| rng.normal() * 3.0).collect();
        let mut p = PackedMx::default();
        Int4Quantizer.quantize_packed(&w, d, &mut p);
        assert_eq!(p.num_groups(), 0, "per-tensor mode");
        let want = matmul_ref(&x, n, d, &p.dequantize(), rows, None);
        assert_eq!(fused_matmul(&x, n, &p, 0, rows, None, 3), want);
    }

    #[test]
    fn dense_matmul_matches_ref_exact() {
        let mut rng = Rng::new(21);
        for (n, d, rows, bias) in [(1usize, 32usize, 4usize, false), (3, 57, 7, true)] {
            let x: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
            let w: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..rows).map(|_| rng.normal()).collect();
            let bias = bias.then_some(&b[..]);
            let want = matmul_ref(&x, n, d, &w, rows, bias);
            for workers in [1, 4] {
                assert_eq!(dense_matmul(&x, n, d, &w, rows, bias, workers), want);
            }
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&[1.0; 32], 32, &mut p);
        assert!(fused_matmul(&[], 0, &p, 0, 1, None, 4).is_empty());
    }
}
