//! Fused group-wise dequant-matmul: the serving GEMM that consumes
//! [`PackedMx`] codes directly, with runtime SIMD dispatch.
//!
//! `Y = X · W_Q^T` with `X: (n, d)` activations and `W_Q` a packed
//! quantized weight whose rows live in a [`PackedMx`] (optionally a row
//! range of a depth-stacked tensor). Per weight row the kernel decodes
//! the codes group-by-group into a d-element row buffer
//! ([`crate::serve::simd::decode_row`]: `pshufb` table lookup on the
//! SIMD levels, scalar `level * scale` otherwise), then dots the buffer
//! against every activation row — so decode work is paid once per
//! weight row regardless of batch size, and no full f32 weight matrix
//! ever exists.
//!
//! **Bit-exactness guarantee / accumulation-order decision:** the
//! canonical contraction order is *defined* as the 8-lane lane-strided
//! reduction of [`crate::serve::simd`] — element `j` accumulates into
//! lane `j % 8` in ascending `j`, lanes reduced by the one fixed tree
//! in [`crate::serve::simd::reduce_lanes`], bias added once at the
//! end. It was redefined from PR 5's single-accumulator ascending
//! order so one order can be implemented *identically* by the scalar
//! loop, SSE2, and AVX2 (`mul` + `add`, never hardware FMA — `fmadd`
//! rounds once and would diverge). [`matmul_ref`], [`dense_matmul`],
//! and [`fused_matmul`] at every dispatch level all perform the same
//! f32 operations in the same order per output element, so fused ==
//! ref, dense mirror == packed, fleet == single-engine, and SIMD ==
//! scalar all hold bit-for-bit (property-tested in `tests/serve.rs`
//! across ragged columns, row ranges, MX + INT4, and every available
//! dispatch level).
//!
//! Dispatch: [`fused_matmul`]/[`dense_matmul`] run at
//! [`crate::serve::simd::active`] (feature probe, `TJ_SIMD`, `--simd`
//! override); the `*_at` variants take an explicit [`SimdLevel`] for
//! tests and benches, clamped to what the host supports. The dispatch
//! *boundary* is one [`crate::serve::simd::strip_dots_at`] call per
//! decoded weight row, never per dot: `#[target_feature]` functions
//! can't inline into baseline callers, and per-dot calls into AVX2
//! code pay an SSE<->VEX transition / `vzeroupper` per output element
//! — measured ~18x slower than the per-strip form on an AVX2 host.
//!
//! Parallelism: output rows of the internal `(rows, n)` transposed tile
//! (i.e. the rows of `W_Q`) are distributed over a scoped thread pool
//! ([`crate::util::parallel`]); [`transpose_back`] returns the tile to
//! the caller's `(n, rows)` layout in cache-sized blocks.
//!
//! The same row axis is the fleet's sharding seam: because each output
//! element depends on exactly one weight row, a contiguous row range
//! computed on another engine from a byte-sliced shard
//! ([`PackedMx::slice_rows`]) is bit-identical to the same rows of a
//! single-engine call, and gathering per-engine column blocks then
//! adding the bias once reproduces this kernel's output exactly
//! (`serve/fleet.rs`).

use crate::quant::PackedMx;
use crate::serve::simd::{self, NibbleTable, SimdLevel};
use crate::util::parallel::parallel_for_each_mut;

/// Row buffers up to this many columns live on the worker's stack; the
/// ViT stores cap at `d = hidden = 256` for vit-micro, so serving
/// never pays a per-row allocation.
const STACK_COLS: usize = 512;

/// Reference GEMM over an already-dequantized weight: `x (n, d)` times
/// `wq (rows, d)` transposed, each output element one canonical
/// lane-strided dot ([`crate::serve::simd::dot_scalar`]) plus an
/// optional per-output-column bias. This is the serial
/// "dequantize-then-matmul" baseline the fused kernel is measured and
/// verified against.
pub fn matmul_ref(
    x: &[f32],
    n: usize,
    d: usize,
    wq: &[f32],
    rows: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    assert_eq!(x.len(), n * d, "x must be (n, d)");
    assert_eq!(wq.len(), rows * d, "wq must be (rows, d)");
    if let Some(b) = bias {
        assert_eq!(b.len(), rows);
    }
    let mut out = vec![0.0f32; n * rows];
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        let oi = &mut out[i * rows..(i + 1) * rows];
        for (c, o) in oi.iter_mut().enumerate() {
            let wr = &wq[c * d..(c + 1) * d];
            *o = simd::dot_scalar(xi, wr) + bias.map_or(0.0, |b| b[c]);
        }
    }
    out
}

/// Row-parallel dense GEMM at the process's active dispatch level,
/// bit-exact to [`matmul_ref`] (canonical order at every level). `wq`
/// is the `(rows, d)` row range already sliced by the caller.
pub fn dense_matmul(
    x: &[f32],
    n: usize,
    d: usize,
    wq: &[f32],
    rows: usize,
    bias: Option<&[f32]>,
    workers: usize,
) -> Vec<f32> {
    dense_matmul_at(simd::active(), x, n, d, wq, rows, bias, workers)
}

/// [`dense_matmul`] pinned to an explicit dispatch level (clamped to
/// the host's capabilities).
#[allow(clippy::too_many_arguments)]
pub fn dense_matmul_at(
    level: SimdLevel,
    x: &[f32],
    n: usize,
    d: usize,
    wq: &[f32],
    rows: usize,
    bias: Option<&[f32]>,
    workers: usize,
) -> Vec<f32> {
    let level = level.min(simd::detected());
    assert_eq!(x.len(), n * d, "x must be (n, d)");
    assert_eq!(wq.len(), rows * d, "wq must be (rows, d)");
    if let Some(b) = bias {
        assert_eq!(b.len(), rows);
    }
    if n == 0 || rows == 0 {
        return Vec::new();
    }
    let mut out_t = vec![0.0f32; rows * n];
    let mut strips: Vec<&mut [f32]> = out_t.chunks_mut(n).collect();
    let workers = workers.max(1).min(rows);
    parallel_for_each_mut(&mut strips, workers, |c, acc| {
        let wr = &wq[c * d..(c + 1) * d];
        let bias_c = bias.map_or(0.0, |b| b[c]);
        simd::strip_dots_at(level, x, d, wr, bias_c, acc);
    });
    transpose_back(&out_t, rows, n)
}

/// Fused dequant-matmul over a row range of a packed weight:
/// `out (n, rows)` with `out[i][c] = x[i] · dequant(w.row(row0 + c)) +
/// bias[c]`, without materializing the dequantized weight. `w.cols()`
/// is the contraction dimension; `row0`/`rows` select a block of a
/// depth-stacked tensor (e.g. one transformer block's slice of
/// `blocks.fc1_w`). Runs at the process's active dispatch level;
/// bit-exact to [`matmul_ref`] over the dequantized rows at any level.
pub fn fused_matmul(
    x: &[f32],
    n: usize,
    w: &PackedMx,
    row0: usize,
    rows: usize,
    bias: Option<&[f32]>,
    workers: usize,
) -> Vec<f32> {
    fused_matmul_at(simd::active(), x, n, w, row0, rows, bias, workers)
}

/// [`fused_matmul`] pinned to an explicit dispatch level (clamped to
/// the host's capabilities) — the entry point the dispatch property
/// tests and the scalar-vs-SIMD benches drive.
#[allow(clippy::too_many_arguments)]
pub fn fused_matmul_at(
    level: SimdLevel,
    x: &[f32],
    n: usize,
    w: &PackedMx,
    row0: usize,
    rows: usize,
    bias: Option<&[f32]>,
    workers: usize,
) -> Vec<f32> {
    let level = level.min(simd::detected());
    let d = w.cols();
    assert!(d > 0 && w.len() % d == 0, "packed weight must be rectangular");
    assert!((row0 + rows) * d <= w.len(), "row range exceeds packed weight");
    assert_eq!(x.len(), n * d, "x must be (n, d)");
    if let Some(b) = bias {
        assert_eq!(b.len(), rows);
    }
    if n == 0 || rows == 0 {
        return Vec::new();
    }
    let table = if level == SimdLevel::Off { None } else { NibbleTable::for_levels(w.levels()) };
    let pt_simd_scale = simd::per_tensor_simd_scale(table.as_ref(), w);

    // Transposed output tile (rows, n): each weight row owns a
    // contiguous strip, so the row-parallel workers never share cache
    // lines and the codes of a row are decoded exactly once.
    let mut out_t = vec![0.0f32; rows * n];
    let mut strips: Vec<&mut [f32]> = out_t.chunks_mut(n).collect();
    let workers = workers.max(1).min(rows);
    parallel_for_each_mut(&mut strips, workers, |c, acc| {
        let mut stack = [0.0f32; STACK_COLS];
        let mut heap = Vec::new();
        let row: &mut [f32] = if d <= STACK_COLS {
            &mut stack[..d]
        } else {
            heap.resize(d, 0.0);
            &mut heap
        };
        simd::decode_row(level, table.as_ref(), w, row0 + c, pt_simd_scale, row);
        let bias_c = bias.map_or(0.0, |b| b[c]);
        simd::strip_dots_at(level, x, d, row, bias_c, acc);
    });
    transpose_back(&out_t, rows, n)
}

/// Return a `(rows, n)` strip tile to the caller's `(n, rows)` layout,
/// walking both axes in cache-sized blocks so neither side streams the
/// whole matrix per line. Shared by the dense and fused kernels (it
/// was duplicated verbatim at both tails before).
pub fn transpose_back(out_t: &[f32], rows: usize, n: usize) -> Vec<f32> {
    const B: usize = 32;
    debug_assert_eq!(out_t.len(), rows * n);
    let mut out = vec![0.0f32; n * rows];
    for c0 in (0..rows).step_by(B) {
        let c1 = (c0 + B).min(rows);
        for i0 in (0..n).step_by(B) {
            let i1 = (i0 + B).min(n);
            for c in c0..c1 {
                let strip = &out_t[c * n..(c + 1) * n];
                for i in i0..i1 {
                    out[i * rows + c] = strip[i];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{e2m1, Int4Quantizer, MxQuantizer, Quantizer, Scaling};
    use crate::util::rng::Rng;

    fn fused_vs_ref(n: usize, d: usize, rows: usize, bias: bool, seed: u64) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * d).map(|_| rng.normal() * 0.2).collect();
        let b: Vec<f32> = (0..rows).map(|_| rng.normal() * 0.1).collect();
        let bias = bias.then_some(&b[..]);
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&w, d, &mut p);
        let wq = p.dequantize();
        let want = matmul_ref(&x, n, d, &wq, rows, bias);
        for workers in [1, 4] {
            let got = fused_matmul(&x, n, &p, 0, rows, bias, workers);
            assert_eq!(got, want, "n={n} d={d} rows={rows} workers={workers}");
        }
    }

    #[test]
    fn fused_matches_dequant_matmul_exact() {
        fused_vs_ref(1, 32, 4, false, 1);
        fused_vs_ref(3, 64, 8, true, 2);
        // Ragged contraction dims: 48 = 32 + 16, 57 = 32 + 25.
        fused_vs_ref(5, 48, 7, true, 3);
        fused_vs_ref(2, 57, 3, false, 4);
    }

    #[test]
    fn fused_row_range_selects_block() {
        let mut rng = Rng::new(9);
        let (d, rows) = (32usize, 12usize);
        let w: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..2 * d).map(|_| rng.normal()).collect();
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&w, d, &mut p);
        let wq = p.dequantize();
        // Rows 4..8 only — a "block 1 of 3" slice of a stacked weight.
        let want = matmul_ref(&x, 2, d, &wq[4 * d..8 * d], 4, None);
        assert_eq!(fused_matmul(&x, 2, &p, 4, 4, None, 2), want);
    }

    #[test]
    fn fused_handles_per_tensor_int4() {
        let mut rng = Rng::new(5);
        let (n, d, rows) = (3usize, 40usize, 6usize);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * d).map(|_| rng.normal() * 3.0).collect();
        let mut p = PackedMx::default();
        Int4Quantizer.quantize_packed(&w, d, &mut p);
        assert_eq!(p.num_groups(), 0, "per-tensor mode");
        let want = matmul_ref(&x, n, d, &p.dequantize(), rows, None);
        assert_eq!(fused_matmul(&x, n, &p, 0, rows, None, 3), want);
    }

    #[test]
    fn fused_matches_dequant_matmul_at_nvfp4_geometry() {
        use crate::quant::NvQuantizer;
        let q = NvQuantizer::nvfp4();
        let mut rng = Rng::new(17);
        // d = 24 has a ragged 8-tail per 16-group; d = 57 adds odd-row
        // nibble offsets; d = 64 is fully 16-aligned.
        for (n, d, rows) in [(3usize, 24usize, 5usize), (2, 57, 4), (4, 64, 6)] {
            let x: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
            let w: Vec<f32> = (0..rows * d).map(|_| rng.normal() * 0.2).collect();
            let mut p = PackedMx::default();
            q.quantize_packed(&w, d, &mut p);
            let want = matmul_ref(&x, n, d, &p.dequantize(), rows, None);
            for workers in [1, 3] {
                assert_eq!(
                    fused_matmul(&x, n, &p, 0, rows, None, workers),
                    want,
                    "n={n} d={d} rows={rows} workers={workers}"
                );
            }
            // Every dispatch level agrees (NVFP4 groups take the
            // scalar decode inside the SIMD-dispatched kernel).
            let base = fused_matmul_at(SimdLevel::Off, &x, n, &p, 0, rows, None, 1);
            for level in [SimdLevel::Ssse3, SimdLevel::Avx2] {
                if crate::serve::simd::available(level) {
                    assert_eq!(
                        fused_matmul_at(level, &x, n, &p, 0, rows, None, 2),
                        base,
                        "level {level:?} d {d}"
                    );
                }
            }
        }
    }

    #[test]
    fn dense_matmul_matches_ref_exact() {
        let mut rng = Rng::new(21);
        for (n, d, rows, bias) in [(1usize, 32usize, 4usize, false), (3, 57, 7, true)] {
            let x: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
            let w: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..rows).map(|_| rng.normal()).collect();
            let bias = bias.then_some(&b[..]);
            let want = matmul_ref(&x, n, d, &w, rows, bias);
            for workers in [1, 4] {
                assert_eq!(dense_matmul(&x, n, d, &w, rows, bias, workers), want);
            }
        }
    }

    #[test]
    fn every_dispatch_level_is_bit_identical() {
        let mut rng = Rng::new(33);
        // d = 57 exercises ragged groups AND odd-row nibble offsets
        // (row * 57 is odd for odd rows), d = 64 the all-SIMD path.
        for d in [57usize, 64] {
            let (n, rows) = (3usize, 9usize);
            let x: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
            let w: Vec<f32> = (0..rows * d).map(|_| rng.normal() * 0.3).collect();
            let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
            let mut p = PackedMx::default();
            q.quantize_packed(&w, d, &mut p);
            let want = fused_matmul_at(SimdLevel::Off, &x, n, &p, 0, rows, None, 1);
            for level in [SimdLevel::Ssse3, SimdLevel::Avx2] {
                if !crate::serve::simd::available(level) {
                    continue;
                }
                let got = fused_matmul_at(level, &x, n, &p, 0, rows, None, 2);
                assert_eq!(got, want, "level {level:?} d {d}");
            }
        }
    }

    #[test]
    fn transpose_back_round_trips() {
        // 37 x 23 exercises partial blocks on both axes.
        let (rows, n) = (37usize, 23usize);
        let t: Vec<f32> = (0..rows * n).map(|i| i as f32).collect();
        let out = transpose_back(&t, rows, n);
        for c in 0..rows {
            for i in 0..n {
                assert_eq!(out[i * rows + c], t[c * n + i]);
            }
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&[1.0; 32], 32, &mut p);
        assert!(fused_matmul(&[], 0, &p, 0, 1, None, 4).is_empty());
    }
}
