//! Offline OSCLOG01 analyzer (`tetrajet report`): replays an
//! oscillation-telemetry artifact and reproduces the paper's
//! per-layer diagnostics as deterministic markdown + `OSCREPORT01`
//! JSON.
//!
//! Everything is a pure function of the artifact bytes: the loader
//! recomputes the FNV-1a digest while parsing (the same fold the
//! writer applied), aggregation is serial f64 arithmetic in segment
//! order, and floats are printed with fixed precision — two `report`
//! runs over one OSCLOG are byte-identical.
//!
//! The headline number, `osc_fraction`, is recovered from the last
//! window's `osc_total` with the *same* expression the trainer uses
//! for its `train.osc.ratio` gauge (`count as f64 / total as f64`),
//! so artifact and live gauge agree bit-exactly.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::obs::osclog::{OscSegment, OSCLOG_FORMAT};
use crate::obs::TraceDigest;
use crate::util::json::{num, s, Json};

/// Format tag of the JSON report.
pub const REPORT_FORMAT: &str = "OSCREPORT01";

/// One per-step telemetry record.
#[derive(Debug, Clone)]
pub struct StepRec {
    pub t: usize,
    pub flips: Vec<u64>,
    pub conf: Vec<f64>,
    pub wdist: Vec<f64>,
}

/// One window-close record.
#[derive(Debug, Clone)]
pub struct WindowRec {
    pub step: usize,
    pub len: usize,
    pub osc: Vec<u64>,
    pub osc_total: usize,
}

/// A fully parsed OSCLOG01 artifact.
#[derive(Debug, Clone)]
pub struct OscLog {
    pub variant: String,
    pub mirror: String,
    pub group_size: usize,
    pub scale_enc: String,
    pub threshold: f64,
    pub osc_window: usize,
    pub seed: u64,
    pub total: usize,
    pub segments: Vec<OscSegment>,
    pub steps: Vec<StepRec>,
    pub windows: Vec<WindowRec>,
    /// Recomputed FNV-1a digest over the file bytes.
    pub digest: String,
    pub lines: u64,
}

fn f64_or_nan(j: &Json) -> f64 {
    match j {
        Json::Null => f64::NAN,
        _ => j.as_f64().unwrap_or(f64::NAN),
    }
}

fn u64_arr(j: &Json, key: &str) -> Result<Vec<u64>> {
    j.req(key)?.as_arr()?.iter().map(|v| v.as_usize().map(|x| x as u64)).collect()
}

fn parse_segment(j: &Json) -> Result<OscSegment> {
    Ok(OscSegment {
        name: j.req("name")?.as_str()?.to_string(),
        kind: j.req("kind")?.as_str()?.to_string(),
        depth: j.req("depth")?.as_i64()?,
        offset: j.req("offset")?.as_usize()?,
        size: j.req("size")?.as_usize()?,
        cols: j.req("cols")?.as_usize()?,
    })
}

/// Parse `path` as OSCLOG01, recomputing the content digest. Validates
/// the header schema, the contiguous segment tiling, and that every
/// record's arrays match the segment count.
pub fn load_osclog(path: &Path) -> Result<OscLog> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading osclog {}", path.display()))?;
    let mut digest = TraceDigest::new();
    let mut lines_it = text.lines();
    let header_line = lines_it.next().context("osclog is empty")?;
    digest.update(header_line.as_bytes());
    digest.update(b"\n");
    let header = Json::parse(header_line).context("parsing osclog header")?;
    let fmt = header.req("format")?.as_str()?;
    if fmt != OSCLOG_FORMAT {
        bail!("unsupported osclog format {fmt:?} (want {OSCLOG_FORMAT:?})");
    }
    let total = header.req("total")?.as_usize()?;
    let segments: Vec<OscSegment> = header
        .req("segments")?
        .as_arr()?
        .iter()
        .map(parse_segment)
        .collect::<Result<_>>()?;
    let mut covered = 0usize;
    for seg in &segments {
        if seg.offset != covered {
            bail!("segment {:?} breaks the contiguous tiling at {}", seg.name, covered);
        }
        covered += seg.size;
    }
    if covered != total {
        bail!("segments cover {covered} elements, header total is {total}");
    }
    let n = segments.len();

    let mut steps = Vec::new();
    let mut windows = Vec::new();
    let mut lines = 1u64;
    for line in lines_it {
        digest.update(line.as_bytes());
        digest.update(b"\n");
        lines += 1;
        let j = Json::parse(line).with_context(|| format!("parsing osclog line {lines}"))?;
        if let Some(t) = j.get("t") {
            let flips = u64_arr(&j, "flips")?;
            let conf: Vec<f64> = j.req("conf")?.as_arr()?.iter().map(f64_or_nan).collect();
            let wdist: Vec<f64> = j.req("wdist")?.as_arr()?.iter().map(f64_or_nan).collect();
            if flips.len() != n || conf.len() != n || wdist.len() != n {
                bail!("step line {lines}: array lengths != {n} segments");
            }
            steps.push(StepRec { t: t.as_usize()?, flips, conf, wdist });
        } else if let Some(we) = j.get("window_end") {
            let osc = u64_arr(&j, "osc")?;
            if osc.len() != n {
                bail!("window line {lines}: osc length != {n} segments");
            }
            let osc_total = j.req("osc_total")?.as_usize()?;
            if osc.iter().map(|&x| x as usize).sum::<usize>() != osc_total {
                bail!("window line {lines}: osc array does not sum to osc_total");
            }
            windows.push(WindowRec {
                step: we.as_usize()?,
                len: j.req("len")?.as_usize()?,
                osc,
                osc_total,
            });
        } else {
            bail!("osclog line {lines} is neither a step nor a window record");
        }
    }

    Ok(OscLog {
        variant: header.req("variant")?.as_str()?.to_string(),
        mirror: header.req("mirror")?.as_str()?.to_string(),
        group_size: header.req("group_size")?.as_usize()?,
        scale_enc: header.req("scale_enc")?.as_str()?.to_string(),
        threshold: header.req("threshold")?.as_f64()?,
        osc_window: header.req("osc_window")?.as_usize()?,
        seed: header.req("seed")?.as_usize()? as u64,
        total,
        segments,
        steps,
        windows,
        digest: digest.hex(),
        lines,
    })
}

/// Per-segment aggregates over a whole log.
#[derive(Debug, Clone)]
pub struct SegStats {
    pub seg: OscSegment,
    /// Flips per element per step.
    pub flip_rate: f64,
    pub total_flips: u64,
    pub mean_conf: f64,
    pub mean_wdist: f64,
    /// Oscillating-element fraction of the last closed window (NaN if
    /// no window closed).
    pub osc_frac: f64,
}

/// The analyzed report.
#[derive(Debug, Clone)]
pub struct Report {
    pub log_digest: String,
    pub variant: String,
    pub mirror: String,
    pub threshold: f64,
    pub osc_window: usize,
    pub steps: usize,
    pub windows: usize,
    pub total: usize,
    /// Aggregate oscillating fraction of the last closed window —
    /// bit-exact to the trainer's `train.osc.ratio` gauge.
    pub osc_fraction: f64,
    pub osc_count: usize,
    /// All segments in artifact order.
    pub segs: Vec<SegStats>,
    /// Indices into `segs`, sorted by flip rate descending (top-K).
    pub top: Vec<usize>,
    /// (depth, weighted flip rate) — depth −1 collects non-stacked segs.
    pub by_depth: Vec<(i64, f64)>,
    /// (kind, weighted flip rate) in qkv/proj/fc1/fc2/other order.
    pub by_kind: Vec<(String, f64)>,
}

/// Aggregate `log` into per-segment, per-depth and per-kind flip-rate
/// views plus the headline oscillation fraction.
pub fn analyze(log: &OscLog, top_k: usize) -> Report {
    let nsteps = log.steps.len();
    let denom_steps = nsteps.max(1) as f64;
    let mut segs = Vec::with_capacity(log.segments.len());
    for (i, seg) in log.segments.iter().enumerate() {
        let total_flips: u64 = log.steps.iter().map(|st| st.flips[i]).sum();
        let mean = |f: &dyn Fn(&StepRec) -> f64| -> f64 {
            if nsteps == 0 {
                f64::NAN
            } else {
                log.steps.iter().map(|st| f(st)).sum::<f64>() / denom_steps
            }
        };
        let mean_conf = mean(&|st: &StepRec| st.conf[i]);
        let mean_wdist = mean(&|st: &StepRec| st.wdist[i]);
        let osc_frac = match log.windows.last() {
            Some(w) => w.osc[i] as f64 / seg.size.max(1) as f64,
            None => f64::NAN,
        };
        segs.push(SegStats {
            seg: seg.clone(),
            flip_rate: total_flips as f64 / (denom_steps * seg.size.max(1) as f64),
            total_flips,
            mean_conf,
            mean_wdist,
            osc_frac,
        });
    }

    let mut top: Vec<usize> = (0..segs.len()).collect();
    // Deterministic order: rate descending, then artifact order.
    top.sort_by(|&a, &b| {
        let ord = segs[b].flip_rate.partial_cmp(&segs[a].flip_rate);
        ord.unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    top.truncate(top_k);

    // Size-weighted flip-rate distributions.
    let weighted = |key: &dyn Fn(&SegStats) -> bool| -> f64 {
        let (mut flips, mut elems) = (0u64, 0u64);
        for st in segs.iter().filter(|st| key(st)) {
            flips += st.total_flips;
            elems += st.seg.size as u64;
        }
        flips as f64 / (denom_steps * (elems.max(1)) as f64)
    };
    let mut depths: Vec<i64> = segs.iter().map(|s| s.seg.depth).collect();
    depths.sort_unstable();
    depths.dedup();
    let by_depth: Vec<(i64, f64)> =
        depths.into_iter().map(|d| (d, weighted(&|s: &SegStats| s.seg.depth == d))).collect();
    let mut kinds: Vec<String> = Vec::new();
    for k in ["qkv", "proj", "fc1", "fc2", "other"] {
        if segs.iter().any(|s| s.seg.kind == k) {
            kinds.push(k.to_string());
        }
    }
    let by_kind: Vec<(String, f64)> =
        kinds.into_iter().map(|k| (k.clone(), weighted(&|s: &SegStats| s.seg.kind == k))).collect();

    let (osc_count, osc_fraction) = match log.windows.last() {
        // The trainer's gauge expression, verbatim: count / total.
        Some(w) => (w.osc_total, w.osc_total as f64 / log.total.max(1) as f64),
        None => (0, f64::NAN),
    };

    Report {
        log_digest: log.digest.clone(),
        variant: log.variant.clone(),
        mirror: log.mirror.clone(),
        threshold: log.threshold,
        osc_window: log.osc_window,
        steps: nsteps,
        windows: log.windows.len(),
        total: log.total,
        osc_fraction,
        osc_count,
        segs,
        top,
        by_depth,
        by_kind,
    }
}

fn seg_json(st: &SegStats) -> Json {
    Json::Obj(vec![
        ("name".to_string(), s(&st.seg.name)),
        ("kind".to_string(), s(&st.seg.kind)),
        ("depth".to_string(), num(st.seg.depth as f64)),
        ("size".to_string(), num(st.seg.size as f64)),
        ("flip_rate".to_string(), num(st.flip_rate)),
        ("total_flips".to_string(), num(st.total_flips as f64)),
        ("mean_conf".to_string(), num(st.mean_conf)),
        ("mean_wdist".to_string(), num(st.mean_wdist)),
        ("osc_frac".to_string(), num(st.osc_frac)),
    ])
}

impl Report {
    /// Stable OSCREPORT01 JSON.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("format".to_string(), s(REPORT_FORMAT)),
            ("log_digest".to_string(), s(&self.log_digest)),
            ("variant".to_string(), s(&self.variant)),
            ("mirror".to_string(), s(&self.mirror)),
            ("threshold".to_string(), num(self.threshold)),
            ("osc_window".to_string(), num(self.osc_window as f64)),
            ("steps".to_string(), num(self.steps as f64)),
            ("windows".to_string(), num(self.windows as f64)),
            ("total".to_string(), num(self.total as f64)),
            ("osc_count".to_string(), num(self.osc_count as f64)),
            ("osc_fraction".to_string(), num(self.osc_fraction)),
            (
                "top".to_string(),
                Json::Arr(self.top.iter().map(|&i| seg_json(&self.segs[i])).collect()),
            ),
            (
                "by_depth".to_string(),
                Json::Obj(
                    self.by_depth.iter().map(|(d, r)| (format!("{d}"), num(*r))).collect(),
                ),
            ),
            (
                "by_kind".to_string(),
                Json::Obj(self.by_kind.iter().map(|(k, r)| (k.clone(), num(*r))).collect()),
            ),
            (
                "segments".to_string(),
                Json::Arr(self.segs.iter().map(seg_json).collect()),
            ),
        ])
    }

    /// Deterministic markdown rendering (fixed float precision).
    pub fn to_markdown(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "# Oscillation report — {} ({})", self.variant, self.mirror);
        let _ = writeln!(out);
        let _ = writeln!(
            out,
            "- steps: {} · windows: {} (len {}) · threshold R_w > {}",
            self.steps, self.windows, self.osc_window, self.threshold
        );
        let _ = writeln!(
            out,
            "- oscillating: {} / {} weights ({:.6} of the quantized prefix, last window)",
            self.osc_count, self.total, self.osc_fraction
        );
        let _ = writeln!(out, "- artifact digest: `{}`", self.log_digest);
        let _ = writeln!(out);
        let _ = writeln!(out, "## Top oscillating segments");
        let _ = writeln!(out);
        let _ = writeln!(out, "| segment | kind | depth | flip rate | osc frac | conf | |W−Wq| |");
        let _ = writeln!(out, "|---|---|---:|---:|---:|---:|---:|");
        for &i in &self.top {
            let st = &self.segs[i];
            let _ = writeln!(
                out,
                "| {} | {} | {} | {:.6} | {:.6} | {:.6} | {:.6} |",
                st.seg.name,
                st.seg.kind,
                st.seg.depth,
                st.flip_rate,
                st.osc_frac,
                st.mean_conf,
                st.mean_wdist
            );
        }
        let _ = writeln!(out);
        let _ = writeln!(out, "## Flip rate by depth");
        let _ = writeln!(out);
        let _ = writeln!(out, "| depth | flip rate |");
        let _ = writeln!(out, "|---:|---:|");
        for (d, r) in &self.by_depth {
            let _ = writeln!(out, "| {d} | {r:.6} |");
        }
        let _ = writeln!(out);
        let _ = writeln!(out, "## Flip rate by layer kind");
        let _ = writeln!(out);
        let _ = writeln!(out, "| kind | flip rate |");
        let _ = writeln!(out, "|---|---:|");
        for (k, r) in &self.by_kind {
            let _ = writeln!(out, "| {k} | {r:.6} |");
        }
        out
    }
}

/// Controller-effect comparison of two logs (e.g. `mx_baseline` vs
/// `tetrajet`): segments aligned by name, flip-rate deltas, and the
/// aggregate fraction shift. Deterministic markdown table.
pub fn compare_markdown(a: &Report, b: &Report) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "## Controller effect — {} vs {}", a.variant, b.variant);
    let _ = writeln!(out);
    let _ = writeln!(
        out,
        "- oscillating fraction: {:.6} → {:.6} (Δ {:+.6})",
        a.osc_fraction,
        b.osc_fraction,
        b.osc_fraction - a.osc_fraction
    );
    let _ = writeln!(out);
    let _ = writeln!(out, "| segment | {} flip rate | {} flip rate | Δ |", a.variant, b.variant);
    let _ = writeln!(out, "|---|---:|---:|---:|");
    for sa in &a.segs {
        let Some(sb) = b.segs.iter().find(|s| s.seg.name == sa.seg.name) else {
            continue;
        };
        let _ = writeln!(
            out,
            "| {} | {:.6} | {:.6} | {:+.6} |",
            sa.seg.name,
            sa.flip_rate,
            sb.flip_rate,
            sb.flip_rate - sa.flip_rate
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MetricsCfg;
    use crate::coordinator::SynthTrainer;
    use crate::obs::osclog::OscLogWriter;

    fn write_log(variant: &str, seed: u64, steps: usize, path: &Path) -> (u64, String) {
        let metrics = MetricsCfg {
            rate_window: 0,
            probe_every: 0,
            osc_window: 10,
            rw_threshold: 16.0,
            conf_every: 0,
        };
        let mut t = SynthTrainer::new("tiny", variant, seed, metrics).unwrap();
        t.attach_osclog(OscLogWriter::to_file(path).unwrap());
        t.run(steps).unwrap().osclog.unwrap()
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("tj-report-{}-{name}", std::process::id()))
    }

    #[test]
    fn loader_recovers_writer_digest_and_structure() {
        let path = tmp("load.osclog");
        let (lines, digest) = write_log("mx", 5, 25, &path);
        let log = load_osclog(&path).unwrap();
        assert_eq!(log.lines, lines);
        assert_eq!(log.digest, digest, "recomputed digest must match the writer's");
        assert_eq!(log.variant, "synthetic-tiny");
        assert_eq!(log.mirror, "mx");
        assert_eq!(log.osc_window, 10);
        // 25 steps: first creates the tracker, 24 record; 2 windows.
        assert_eq!(log.steps.len(), 24);
        assert_eq!(log.windows.len(), 2);
        assert_eq!(log.segments.len(), 8, "tiny = 4 tensors x depth 2");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn report_fraction_matches_the_gauge_expression() {
        let path = tmp("frac.osclog");
        write_log("nvfp4", 9, 25, &path);
        let log = load_osclog(&path).unwrap();
        let rep = analyze(&log, 5);
        let w = log.windows.last().unwrap();
        assert_eq!(rep.osc_fraction, w.osc_total as f64 / log.total.max(1) as f64);
        assert_eq!(rep.top.len(), 5);
        // Markdown and JSON are deterministic for one artifact.
        let rep2 = analyze(&load_osclog(&path).unwrap(), 5);
        assert_eq!(rep.to_markdown(), rep2.to_markdown());
        assert_eq!(rep.to_json().to_string(), rep2.to_json().to_string());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compare_lists_aligned_segments() {
        let (pa, pb) = (tmp("cmp-a.osclog"), tmp("cmp-b.osclog"));
        write_log("mx", 11, 22, &pa);
        write_log("nvfp4", 11, 22, &pb);
        let ra = analyze(&load_osclog(&pa).unwrap(), 3);
        let rb = analyze(&load_osclog(&pb).unwrap(), 3);
        let md = compare_markdown(&ra, &rb);
        assert!(md.contains("Controller effect"), "{md}");
        assert!(md.contains("blocks.qkv_w.d0"), "{md}");
        let _ = std::fs::remove_file(&pa);
        let _ = std::fs::remove_file(&pb);
    }
}
