//! Minimal property-testing driver (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` inputs drawn
//! from `gen` over seeded RNG streams; on failure it reports the seed
//! and a shrunk-ish description (the failing case index is re-derivable
//! from the seed, so failures are exactly reproducible).

use crate::quant::{GroupGeom, ScaleEnc};
use crate::util::rng::Rng;

/// Run a property over `cases` generated inputs; panics with the seed
/// on the first violation.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let base = Rng::new(0xC0FFEE ^ name.len() as u64);
    for i in 0..cases {
        let mut rng = base.fold_in(i as u64);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property {name:?} failed on case {i}/{cases}: {input:?}\n\
                 (deterministic: base seed 0xC0FFEE^{}, fold_in({i}))",
                name.len()
            );
        }
    }
}

/// The group geometries property tests sweep over. Always includes the
/// two shipped geometries (MX 1x32/E8M0, NVFP4 1x16/E4M3); with
/// `TJ_GEOM_SWEEP=1` in the environment (the `make tier1` second test
/// pass) it adds off-registry combinations — small E8M0 groups and
/// E4M3 at MX group size — to exercise the parameterization itself,
/// not just the two products built on it.
pub fn geom_sweep() -> Vec<GroupGeom> {
    let mut geoms = vec![GroupGeom::mx(), GroupGeom::nvfp4()];
    if std::env::var("TJ_GEOM_SWEEP").map_or(false, |v| v == "1") {
        for (gs, enc) in [(8, ScaleEnc::E8m0), (16, ScaleEnc::E8m0), (32, ScaleEnc::E4m3)] {
            geoms.push(GroupGeom::new(gs, enc).expect("sweep geometry"));
        }
    }
    geoms
}

/// Generate a random f32 vector with interesting magnitude spread:
/// mixes normal values, powers of two, grid-ish values and extremes.
pub fn gen_f32_vec(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len)
        .map(|_| match rng.below(10) {
            0 => 0.0,
            1 => {
                // exact power of two in a moderate range
                let e = rng.below(16) as i32 - 8;
                let s = if rng.below(2) == 0 { 1.0 } else { -1.0 };
                s * (2.0f32).powi(e)
            }
            2 => {
                // half-integer grid-ish value
                (rng.below(25) as f32 / 2.0 - 6.0) * scale
            }
            3 => rng.normal() * scale * 100.0, // outlier
            4 => rng.normal() * 1e-6,          // tiny
            _ => rng.normal() * scale,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_valid_property() {
        check("squares nonneg", 200, |r| r.normal(), |x| x * x >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn check_fails_invalid_property() {
        check("always positive", 200, |r| r.normal(), |&x| x > 0.0);
    }

    #[test]
    fn gen_vec_has_variety() {
        let mut r = Rng::new(1);
        let v = gen_f32_vec(&mut r, 1000, 1.0);
        assert_eq!(v.len(), 1000);
        assert!(v.iter().any(|&x| x == 0.0));
        assert!(v.iter().any(|&x| x.abs() > 10.0));
        assert!(v.iter().any(|&x| x != 0.0 && x.abs() < 1e-4));
    }
}
