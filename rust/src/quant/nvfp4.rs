//! NVFP4 fake-quantization: the TetraJet-v2 recipe transplanted onto
//! the shared packed substrate.
//!
//! NVFP4 keeps the E2M1 element grid but swaps the group geometry:
//! 16-element groups with an E4M3 scale byte per group (vs MX's
//! 32-element groups with E8M0 power-of-two bytes), preceded by a
//! per-tensor outlier clamp at `NVFP4_CLAMP_K * RMS` that stops a
//! single outlier from washing out its group's resolution. Scale bytes
//! are chosen truncation-free: the smallest E4M3 value `>= amax / Qp`,
//! so the group max is always representable (the paper's M=31
//! argument, carried to a non-power-of-two scale grid).
//!
//! [`NvQuantizer`] is geometry-parameterized: at the MX geometry with
//! the clamp disabled it reproduces [`MxQuantizer`](super::mx::MxQuantizer)
//! bit-exactly (property-tested), which pins the two pipelines
//! together. `dequantize(quantize_packed(x)) == quantize_f32(x)` holds
//! at every geometry by the same argument as the MX path: `round_det`
//! lands exactly on a level, and the code indexes that same level.

use super::formats::{e2m1, round_det, Fp4Format, GroupGeom, Scaling};
use super::packed::{group_ranges, PackedMx, Quantizer};

/// Outlier-clamp multiplier of the NVFP4 recipe: values are clamped to
/// `+-NVFP4_CLAMP_K * RMS(x)` before scales are computed. TetraJet-v2
/// reports the recipe is insensitive in 8..16; 12 is the midpoint.
pub const NVFP4_CLAMP_K: f32 = 12.0;

/// NVFP4 (and generally geometry-parameterized) fake quantizer.
#[derive(Debug, Clone, Copy)]
pub struct NvQuantizer {
    pub fmt: &'static Fp4Format,
    /// Used only by E8M0 geometries (power-of-two scale selection);
    /// E4M3 scale bytes are always truncation-free.
    pub scaling: Scaling,
    pub geom: GroupGeom,
    /// Clamp multiplier; `f32::INFINITY` disables the outlier clamp.
    pub clamp_k: f32,
}

impl NvQuantizer {
    /// The NVFP4 recipe: E2M1 elements, 16-element groups, E4M3
    /// scales, outlier clamp at [`NVFP4_CLAMP_K`] * RMS.
    pub fn nvfp4() -> NvQuantizer {
        NvQuantizer {
            fmt: e2m1(),
            scaling: Scaling::TruncationFree,
            geom: GroupGeom::nvfp4(),
            clamp_k: NVFP4_CLAMP_K,
        }
    }

    /// Arbitrary-geometry instance with the clamp disabled; at
    /// `GroupGeom::mx()` this is bit-exact to `MxQuantizer`.
    pub fn with_geom(fmt: &'static Fp4Format, scaling: Scaling, geom: GroupGeom) -> NvQuantizer {
        NvQuantizer { fmt, scaling, geom, clamp_k: f32::INFINITY }
    }

    /// Per-tensor clamp threshold: `clamp_k * RMS(x)`, or infinity when
    /// the clamp is disabled or the tensor is all-zero (clamping at 0
    /// would erase the tensor).
    pub fn clamp_threshold(&self, x: &[f32]) -> f32 {
        if !self.clamp_k.is_finite() || x.is_empty() {
            return f32::INFINITY;
        }
        let ss: f64 = x.iter().map(|&v| v as f64 * v as f64).sum();
        let rms = (ss / x.len() as f64).sqrt() as f32;
        if rms > 0.0 && rms.is_finite() {
            self.clamp_k * rms
        } else {
            f32::INFINITY
        }
    }

    /// Shared group loop: clamp, per-group amax, scale byte, then the
    /// per-element clamp/round closure. The scale byte is encoded then
    /// decoded so both faces round against the *representable* scale
    /// (an E4M3 byte is not the real-valued `amax / Qp`).
    fn for_each_group_nv<F>(&self, x: &[f32], cols: usize, mut f: F)
    where
        F: FnMut(std::ops::Range<usize>, u8, f32, f32),
    {
        assert_eq!(x.len() % cols.max(1), 0);
        let t = self.clamp_threshold(x);
        group_ranges(x.len(), cols, self.geom.group_size(), |_g, a, b| {
            let amax = x[a..b].iter().fold(0.0f32, |m, &v| m.max(v.clamp(-t, t).abs()));
            let byte = self.geom.encode_scale(amax, self.fmt, self.scaling);
            let scale = self.geom.decode_scale(byte);
            f(a..b, byte, scale, t);
        });
    }
}

impl Quantizer for NvQuantizer {
    fn name(&self) -> &'static str {
        "nvfp4"
    }

    fn quantize_f32(&self, x: &[f32], cols: usize, out: &mut [f32]) {
        assert_eq!(out.len(), x.len());
        let fmt = self.fmt;
        self.for_each_group_nv(x, cols, |rng, _byte, scale, t| {
            // scale == 0 only for an all-zero group (E4M3 byte 0): map
            // everything to exact zero instead of dividing by zero.
            let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
            for i in rng {
                let y = (x[i].clamp(-t, t) * inv).clamp(fmt.qn(), fmt.qp());
                out[i] = round_det(y, fmt) * scale;
            }
        });
    }

    fn quantize_packed(&self, x: &[f32], cols: usize, out: &mut PackedMx) {
        let fmt = self.fmt;
        out.begin_grouped_geom(x.len(), cols, &fmt.levels, self.geom);
        self.for_each_group_nv(x, cols, |rng, byte, scale, t| {
            out.push_group_scale_byte(byte);
            let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
            for i in rng {
                let y = (x[i].clamp(-t, t) * inv).clamp(fmt.qn(), fmt.qp());
                // round_det lands exactly on a level, so the code
                // recovers the identical value on dequant.
                out.set_code(i, fmt.level_index(round_det(y, fmt)) as u8);
            }
        });
    }
}

/// Allocating NVFP4 fake-quantization at the default recipe.
pub fn nvfp4_quantize_cols(x: &[f32], cols: usize) -> Vec<f32> {
    let mut out = vec![0.0; x.len()];
    NvQuantizer::nvfp4().quantize_f32(x, cols, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::formats::{e3m0, e4m3_decode, E4M3_MAX_BYTE};
    use crate::quant::mx::MxQuantizer;

    fn sample(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i * 37) % 113) as f32 / 9.0 - 6.0).collect()
    }

    #[test]
    fn packed_dequant_matches_fake_quant() {
        let q = NvQuantizer::nvfp4();
        // 16-aligned, ragged-tail, and sub-group col counts.
        for cols in [16usize, 24, 48, 7] {
            let x = sample(cols * 4);
            let mut want = vec![0.0; x.len()];
            q.quantize_f32(&x, cols, &mut want);
            let mut p = PackedMx::default();
            q.quantize_packed(&x, cols, &mut p);
            assert_eq!(p.geom(), GroupGeom::nvfp4());
            let got = p.dequantize();
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(g.to_bits() == w.to_bits(), "cols={cols} i={i}: {g:?} != {w:?}");
            }
        }
    }

    #[test]
    fn mx_geometry_with_clamp_off_equals_mx_quantizer_bit_exact() {
        let x = sample(192);
        for fmt in [e2m1(), e3m0()] {
            for scaling in [Scaling::TruncationFree, Scaling::Floor] {
                for cols in [32usize, 48] {
                    let nv = NvQuantizer::with_geom(fmt, scaling, GroupGeom::mx());
                    let mx = MxQuantizer { fmt, scaling };
                    let (mut a, mut b) = (vec![0.0; x.len()], vec![0.0; x.len()]);
                    nv.quantize_f32(&x, cols, &mut a);
                    mx.quantize_f32(&x, cols, &mut b);
                    let same = a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits());
                    assert!(same, "fmt={} scaling={scaling:?} cols={cols}", fmt.name);
                    let (mut pa, mut pb) = (PackedMx::default(), PackedMx::default());
                    nv.quantize_packed(&x, cols, &mut pa);
                    mx.quantize_packed(&x, cols, &mut pb);
                    assert_eq!(pa.codes(), pb.codes());
                    assert_eq!(pa.scale_bytes(), pb.scale_bytes());
                    assert_eq!(pa.geom(), pb.geom());
                }
            }
        }
    }

    #[test]
    fn scale_bytes_are_truncation_free_e4m3() {
        let q = NvQuantizer::nvfp4();
        let x = sample(160);
        let mut p = PackedMx::default();
        q.quantize_packed(&x, 32, &mut p);
        let t = q.clamp_threshold(&x);
        p.for_each_group(|g, a, b| {
            let amax = x[a..b].iter().fold(0.0f32, |m, &v| m.max(v.clamp(-t, t).abs()));
            let byte = p.scale_byte(g);
            assert!(byte <= E4M3_MAX_BYTE);
            let scale = e4m3_decode(byte);
            assert_eq!(scale, p.group_scale(g));
            if amax > 0.0 {
                assert!(
                    scale >= amax / q.fmt.qp(),
                    "group {g}: scale {scale} truncates amax {amax}"
                );
            } else {
                assert_eq!(byte, 0, "all-zero group gets the zero scale byte");
            }
        });
    }

    #[test]
    fn all_zero_group_and_tensor_roundtrip() {
        let q = NvQuantizer::nvfp4();
        let mut x = vec![0.0f32; 32];
        x[20] = 3.0; // second 16-group non-zero, first all-zero
        let mut p = PackedMx::default();
        q.quantize_packed(&x, 32, &mut p);
        assert_eq!(p.scale_byte(0), 0);
        let d = p.dequantize();
        assert!(d[..16].iter().all(|&v| v == 0.0));
        assert!(d[16..].iter().any(|&v| v != 0.0));
        // All-zero tensor: rms 0 disables the clamp, everything stays 0.
        let z = vec![0.0f32; 48];
        assert_eq!(nvfp4_quantize_cols(&z, 16), z);
    }

    #[test]
    fn outlier_clamp_preserves_group_resolution() {
        // One outlier in a tensor of small values. The clamp threshold
        // is 12 * RMS over the whole tensor, so the tensor must be
        // large enough for the RMS to sit well below the outlier:
        // here RMS ~= 0.90, threshold ~= 10.8 < 24.
        let mut x = vec![0.5f32; 1024];
        x[0] = 24.0;
        let t = NvQuantizer::nvfp4().clamp_threshold(&x);
        assert!(t < 24.0, "clamp must bite the outlier (t = {t})");
        let clamped = nvfp4_quantize_cols(&x, 1024);
        assert!(
            clamped[1..16].iter().all(|&v| v != 0.0),
            "clamped recipe keeps small-value resolution: {:?}",
            &clamped[..4]
        );
        // Without the clamp the outlier's group scale (>= 24/6 = 4)
        // puts 0.5 below the rounding threshold and flushes it.
        let q = NvQuantizer { clamp_k: f32::INFINITY, ..NvQuantizer::nvfp4() };
        let mut unclamped = vec![0.0; x.len()];
        q.quantize_f32(&x, 1024, &mut unclamped);
        assert!(
            unclamped[1..16].iter().all(|&v| v == 0.0),
            "without the clamp the outlier flushes its group"
        );
        // The outlier itself lands near the clamp threshold, not its
        // raw value.
        assert!(clamped[0] <= t * 1.5 && clamped[0] < 24.0);
        // An outlier-free group is untouched by the clamp.
        assert_eq!(&clamped[16..32], &nvfp4_quantize_cols(&vec![0.5f32; 16], 16)[..]);
    }

    #[test]
    fn packed_parts_roundtrip_keeps_geometry() {
        // Serialize-shaped roundtrip: rebuilding from raw parts at the
        // NVFP4 geometry (the TJCKPT02 path) reproduces the tensor.
        let x = sample(96);
        let q = NvQuantizer::nvfp4();
        let mut p = PackedMx::default();
        q.quantize_packed(&x, 48, &mut p);
        let back = PackedMx::from_parts_geom(
            p.geom(),
            p.len(),
            p.cols(),
            p.codes().to_vec(),
            p.scale_bytes().to_vec(),
            p.tensor_scale(),
            &q.fmt.levels,
        )
        .unwrap();
        assert_eq!(back.dequantize(), p.dequantize());
        assert_eq!(back.flip_count(&p), 0);
        // The same bytes misread at MX geometry must be rejected (3
        // groups/row at gs16 vs 2 at gs32 -> scale-count mismatch).
        assert!(PackedMx::from_parts(
            p.len(),
            p.cols(),
            p.codes().to_vec(),
            p.scale_bytes().to_vec(),
            p.tensor_scale(),
            &q.fmt.levels,
        )
        .is_err());
    }
}
