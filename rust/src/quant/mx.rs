//! MXFP4 fake-quantization over row-major matrices (mirror of ref.py).
//!
//! `*_cols` quantizes with 1x32 groups along the last (contiguous) axis
//! — the layout of Q^(2) over a (C, D) weight matrix, which is what all
//! coordinator-side metrics track. Ragged tails (cols % 32 != 0) are
//! handled as partial groups, equivalent to the zero-padding the L2
//! wrapper applies.
//!
//! All variants (deterministic, stochastic, Q-EMA in `qema.rs`, and the
//! packed-code path in `packed.rs`) share one group loop,
//! [`for_each_group`], so the shared-scale computation is written once.
//! [`MxQuantizer`] is the [`Quantizer`](super::packed::Quantizer)-trait
//! face of the deterministic path.

use super::formats::{bracket, exp2i, round_det, scale_exponent, Fp4Format, Scaling, GROUP};
use super::packed::{PackedMx, Quantizer, E8M0_BIAS};

/// Iterate the 1x32 groups of a row-major `(rows, cols)` matrix,
/// computing the shared-scale exponent of each group once. The closure
/// receives the flat element range, the scale exponent `s`, and the
/// scale `2^s`. Ragged tails (`cols % 32 != 0`) become partial groups.
/// Group order comes from the shared [`packed::group_ranges`] layout
/// definition, so scales pushed in this order decode correctly.
pub(crate) fn for_each_group<F>(
    x: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
    mut f: F,
) where
    F: FnMut(std::ops::Range<usize>, i32, f32),
{
    assert_eq!(x.len() % cols.max(1), 0);
    super::packed::group_ranges(x.len(), cols, GROUP, |_g, a, b| {
        let max_abs = x[a..b].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s = scale_exponent(max_abs, fmt, scaling);
        f(a..b, s, exp2i(s));
    });
}

/// Deterministic MXFP4 fake-quantization, allocating variant.
pub fn mx_quantize_cols(
    x: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
) -> Vec<f32> {
    let mut out = vec![0.0; x.len()];
    mx_quantize_cols_into(x, cols, fmt, scaling, &mut out);
    out
}

/// Deterministic MXFP4 fake-quantization into a caller-owned buffer
/// (no allocation on the per-step metric path).
pub fn mx_quantize_cols_into(
    x: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
    out: &mut [f32],
) {
    assert_eq!(out.len(), x.len());
    for_each_group(x, cols, fmt, scaling, |rng, _s, scale| {
        let inv = 1.0 / scale;
        for i in rng {
            let y = (x[i] * inv).clamp(fmt.qn(), fmt.qp());
            out[i] = round_det(y, fmt) * scale;
        }
    });
}

/// Stochastic MXFP4 fake-quantization with explicit uniforms (used by
/// the golden tests; the training path's stochastic rounding runs in
/// the AOT HLO, not here). Allocating variant.
pub fn mx_quantize_stoch_cols(
    x: &[f32],
    u: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
) -> Vec<f32> {
    let mut out = vec![0.0; x.len()];
    mx_quantize_stoch_cols_into(x, u, cols, fmt, scaling, &mut out);
    out
}

/// Stochastic MXFP4 fake-quantization into a caller-owned buffer.
pub fn mx_quantize_stoch_cols_into(
    x: &[f32],
    u: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
    out: &mut [f32],
) {
    assert_eq!(x.len(), u.len());
    assert_eq!(out.len(), x.len());
    for_each_group(x, cols, fmt, scaling, |rng, _s, scale| {
        let inv = 1.0 / scale;
        for i in rng {
            let y = (x[i] * inv).clamp(fmt.qn(), fmt.qp());
            let (q1, q2) = bracket(y, fmt);
            let q = if (y - q1) > u[i] * (q2 - q1) { q2 } else { q1 };
            out[i] = q * scale;
        }
    });
}

/// Stage 1 of the split deterministic quantizer: the per-group E8M0
/// scale bytes (`scale_exponent + E8M0_BIAS`) of a 1x32-grouped matrix,
/// without touching the values. [`mx_quantize_cols_with_scales`] is the
/// matching stage 2; together they are bit-exact to
/// [`mx_quantize_cols_into`] (tested below). The serving activation
/// cache ([`crate::serve::act`]) persists these bytes so a mirror pass
/// or repeated forward skips the max-abs/frexp scan.
pub fn mx_scale_bytes(
    x: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
    out: &mut Vec<u8>,
) {
    out.clear();
    for_each_group(x, cols, fmt, scaling, |_rng, s, _scale| {
        // scale_exponent clamps to +-E8M0_BIAS, so the byte is 0..=254.
        out.push((s + E8M0_BIAS) as u8);
    });
}

/// Stage 2 of the split deterministic quantizer: round onto the grid
/// using previously computed E8M0 scale bytes (one per 1x32 group, in
/// [`mx_scale_bytes`] order). Same clamp/round loop as
/// [`mx_quantize_cols_into`], so the pair is bit-exact to the fused
/// single pass.
pub fn mx_quantize_cols_with_scales(
    x: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scales: &[u8],
    out: &mut [f32],
) {
    assert_eq!(out.len(), x.len());
    let mut g = 0usize;
    super::packed::group_ranges(x.len(), cols, GROUP, |_gi, a, b| {
        let scale = exp2i(scales[g] as i32 - E8M0_BIAS);
        g += 1;
        let inv = 1.0 / scale;
        for i in a..b {
            let y = (x[i] * inv).clamp(fmt.qn(), fmt.qp());
            out[i] = round_det(y, fmt) * scale;
        }
    });
    assert_eq!(g, scales.len(), "one scale byte per group");
}

/// Per-group scale exponents for a 1x32-grouped matrix; used by the
/// metric code to derive latent weights (w / S).
pub fn group_scales(
    x: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
    out: &mut Vec<f32>,
) {
    out.clear();
    for_each_group(x, cols, fmt, scaling, |_rng, _s, scale| out.push(scale));
}

/// Deterministic MXFP4 as a [`Quantizer`]: the forward-weight quantizer
/// Q^(2) of the TetraJet variants without Q-EMA.
#[derive(Debug, Clone, Copy)]
pub struct MxQuantizer {
    pub fmt: &'static Fp4Format,
    pub scaling: Scaling,
}

impl Quantizer for MxQuantizer {
    fn name(&self) -> &'static str {
        "mx"
    }

    fn quantize_f32(&self, x: &[f32], cols: usize, out: &mut [f32]) {
        mx_quantize_cols_into(x, cols, self.fmt, self.scaling, out);
    }

    fn quantize_packed(&self, x: &[f32], cols: usize, out: &mut PackedMx) {
        let fmt = self.fmt;
        out.begin_grouped(x.len(), cols, &fmt.levels);
        for_each_group(x, cols, fmt, self.scaling, |rng, s, scale| {
            out.push_group_scale(s);
            let inv = 1.0 / scale;
            for i in rng {
                let y = (x[i] * inv).clamp(fmt.qn(), fmt.qp());
                // round_det lands exactly on a level (golden-tested), so
                // its index recovers the identical value on dequant.
                let q = round_det(y, fmt);
                out.set_code(i, fmt.level_index(q) as u8);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::formats::{e2m1, e3m0, GROUP};

    #[test]
    fn values_land_on_scaled_grid() {
        let fmt = e2m1();
        let x: Vec<f32> = (0..128).map(|i| ((i * 37) % 61) as f32 / 7.0 - 4.0).collect();
        let q = mx_quantize_cols(&x, 64, fmt, Scaling::TruncationFree);
        for (g, qg) in x.chunks(GROUP).zip(q.chunks(GROUP)) {
            let max_abs = g.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let s = exp2i(scale_exponent(max_abs, fmt, Scaling::TruncationFree));
            for &v in qg {
                let latent = v / s;
                assert!(
                    fmt.levels.iter().any(|&l| l == latent),
                    "latent {latent} not on grid"
                );
            }
        }
    }

    #[test]
    fn truncation_free_never_truncates() {
        // The paper's M=31 example: floor scaling truncates to 24,
        // truncation-free represents 31 as 32.
        let mut x = vec![0.0f32; 32];
        x[0] = 31.0;
        let q = mx_quantize_cols(&x, 32, e2m1(), Scaling::TruncationFree);
        assert_eq!(q[0], 32.0);
        let q = mx_quantize_cols(&x, 32, e2m1(), Scaling::Floor);
        assert_eq!(q[0], 24.0);
    }

    #[test]
    fn idempotent() {
        let x: Vec<f32> = (0..256).map(|i| ((i * 97) % 89) as f32 / 11.0 - 4.0).collect();
        for fmt in [e2m1(), e3m0()] {
            let q = mx_quantize_cols(&x, 64, fmt, Scaling::TruncationFree);
            let q2 = mx_quantize_cols(&q, 64, fmt, Scaling::TruncationFree);
            assert_eq!(q, q2, "fmt {}", fmt.name);
        }
    }

    #[test]
    fn stochastic_matches_det_at_grid_points() {
        let fmt = e2m1();
        let x: Vec<f32> = vec![1.0, -0.5, 6.0, 0.0, 2.0, -6.0, 4.0, 3.0]
            .into_iter()
            .cycle()
            .take(32)
            .collect();
        let u = vec![0.7f32; 32];
        let qd = mx_quantize_cols(&x, 32, fmt, Scaling::TruncationFree);
        let qs = mx_quantize_stoch_cols(&x, &u, 32, fmt, Scaling::TruncationFree);
        assert_eq!(qd, qs); // exact grid points don't move
        assert_eq!(qd, x);
    }

    #[test]
    fn partial_group_equals_zero_padding() {
        let fmt = e2m1();
        let x: Vec<f32> = (0..48).map(|i| (i as f32 - 24.0) / 5.0).collect();
        let q = mx_quantize_cols(&x, 48, fmt, Scaling::TruncationFree);
        let mut padded = x.clone();
        padded.resize(64, 0.0);
        let qp = mx_quantize_cols(&padded, 64, fmt, Scaling::TruncationFree);
        assert_eq!(&q[..48], &qp[..48]);
    }

    #[test]
    fn into_variant_matches() {
        let x: Vec<f32> = (0..96).map(|i| (i as f32).sin() * 3.0).collect();
        let a = mx_quantize_cols(&x, 32, e2m1(), Scaling::Floor);
        let mut b = vec![0.0; 96];
        mx_quantize_cols_into(&x, 32, e2m1(), Scaling::Floor, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn stoch_into_variant_matches() {
        let x: Vec<f32> = (0..96).map(|i| (i as f32 * 0.77).cos() * 4.0).collect();
        let u: Vec<f32> = (0..96).map(|i| ((i * 31) % 17) as f32 / 17.0).collect();
        let a = mx_quantize_stoch_cols(&x, &u, 48, e2m1(), Scaling::TruncationFree);
        let mut b = vec![0.0; 96];
        mx_quantize_stoch_cols_into(&x, &u, 48, e2m1(), Scaling::TruncationFree, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn split_scale_then_round_matches_fused_pass_bit_exact() {
        let x: Vec<f32> = (0..240).map(|i| (i as f32 * 0.61).sin() * 5.0).collect();
        // Ragged (48) and aligned (80) rows, both formats and scalings.
        for cols in [48usize, 80] {
            for fmt in [e2m1(), e3m0()] {
                for scaling in [Scaling::TruncationFree, Scaling::Floor] {
                    let mut want = vec![0.0f32; x.len()];
                    mx_quantize_cols_into(&x, cols, fmt, scaling, &mut want);
                    let mut bytes = Vec::new();
                    mx_scale_bytes(&x, cols, fmt, scaling, &mut bytes);
                    let groups_per_row = (cols + GROUP - 1) / GROUP;
                    assert_eq!(bytes.len(), (x.len() / cols) * groups_per_row);
                    let mut got = vec![0.0f32; x.len()];
                    mx_quantize_cols_with_scales(&x, cols, fmt, &bytes, &mut got);
                    let same = want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(same, "cols {cols} fmt {} {scaling:?}", fmt.name);
                }
            }
        }
    }

    #[test]
    fn group_scales_match_shared_loop() {
        let x: Vec<f32> = (0..96).map(|i| (i as f32).sin() * 2.0).collect();
        let mut s = Vec::new();
        group_scales(&x, 48, e2m1(), Scaling::TruncationFree, &mut s);
        // 2 rows x 2 groups (32 + ragged 16) per row.
        assert_eq!(s.len(), 4);
        for (g, x48) in s.chunks(2).zip(x.chunks(48)) {
            let m0 = x48[..32].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            assert_eq!(g[0], exp2i(scale_exponent(m0, e2m1(), Scaling::TruncationFree)));
        }
    }
}
