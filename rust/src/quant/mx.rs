//! MXFP4 fake-quantization over row-major matrices (mirror of ref.py).
//!
//! `*_cols` quantizes with 1x32 groups along the last (contiguous) axis
//! — the layout of Q^(2) over a (C, D) weight matrix, which is what all
//! coordinator-side metrics track. Ragged tails (cols % 32 != 0) are
//! handled as partial groups, equivalent to the zero-padding the L2
//! wrapper applies.

use super::formats::{
    bracket, exp2i, round_det, scale_exponent, Fp4Format, Scaling, GROUP,
};

/// Deterministic MXFP4 fake-quantization, allocating variant.
pub fn mx_quantize_cols(
    x: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
) -> Vec<f32> {
    let mut out = vec![0.0; x.len()];
    mx_quantize_cols_into(x, cols, fmt, scaling, &mut out);
    out
}

/// Deterministic MXFP4 fake-quantization into a caller-owned buffer
/// (no allocation on the per-step metric path).
pub fn mx_quantize_cols_into(
    x: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
    out: &mut [f32],
) {
    assert_eq!(x.len() % cols.max(1), 0);
    assert_eq!(out.len(), x.len());
    for (row, orow) in x.chunks_exact(cols).zip(out.chunks_exact_mut(cols)) {
        for (g, og) in row.chunks(GROUP).zip(orow.chunks_mut(GROUP)) {
            let max_abs = g.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let s = scale_exponent(max_abs, fmt, scaling);
            let scale = exp2i(s);
            let inv = 1.0 / scale;
            for (&v, o) in g.iter().zip(og.iter_mut()) {
                let y = (v * inv).clamp(fmt.qn(), fmt.qp());
                *o = round_det(y, fmt) * scale;
            }
        }
    }
}

/// Stochastic MXFP4 fake-quantization with explicit uniforms (used by
/// the golden tests; the training path's stochastic rounding runs in
/// the AOT HLO, not here).
pub fn mx_quantize_stoch_cols(
    x: &[f32],
    u: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
) -> Vec<f32> {
    assert_eq!(x.len(), u.len());
    let mut out = vec![0.0; x.len()];
    for r in 0..x.len() / cols {
        let row = &x[r * cols..(r + 1) * cols];
        let urow = &u[r * cols..(r + 1) * cols];
        for g0 in (0..cols).step_by(GROUP) {
            let g1 = (g0 + GROUP).min(cols);
            let max_abs = row[g0..g1].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let s = scale_exponent(max_abs, fmt, scaling);
            let scale = exp2i(s);
            let inv = 1.0 / scale;
            for i in g0..g1 {
                let y = (row[i] * inv).clamp(fmt.qn(), fmt.qp());
                let (q1, q2) = bracket(y, fmt);
                let q = if (y - q1) > urow[i] * (q2 - q1) { q2 } else { q1 };
                out[r * cols + i] = q * scale;
            }
        }
    }
    out
}

/// Per-group scale exponents for a 1x32-grouped matrix; used by the
/// metric code to derive latent weights (w / S).
pub fn group_scales(
    x: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
    out: &mut Vec<f32>,
) {
    out.clear();
    for row in x.chunks_exact(cols) {
        for g in row.chunks(GROUP) {
            let max_abs = g.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            out.push(exp2i(scale_exponent(max_abs, fmt, scaling)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::formats::{e2m1, e3m0};

    #[test]
    fn values_land_on_scaled_grid() {
        let fmt = e2m1();
        let x: Vec<f32> = (0..128).map(|i| ((i * 37) % 61) as f32 / 7.0 - 4.0).collect();
        let q = mx_quantize_cols(&x, 64, fmt, Scaling::TruncationFree);
        for (g, qg) in x.chunks(GROUP).zip(q.chunks(GROUP)) {
            let max_abs = g.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let s = exp2i(scale_exponent(max_abs, fmt, Scaling::TruncationFree));
            for &v in qg {
                let latent = v / s;
                assert!(
                    fmt.levels.iter().any(|&l| l == latent),
                    "latent {latent} not on grid"
                );
            }
        }
    }

    #[test]
    fn truncation_free_never_truncates() {
        // The paper's M=31 example: floor scaling truncates to 24,
        // truncation-free represents 31 as 32.
        let mut x = vec![0.0f32; 32];
        x[0] = 31.0;
        let q = mx_quantize_cols(&x, 32, e2m1(), Scaling::TruncationFree);
        assert_eq!(q[0], 32.0);
        let q = mx_quantize_cols(&x, 32, e2m1(), Scaling::Floor);
        assert_eq!(q[0], 24.0);
    }

    #[test]
    fn idempotent() {
        let x: Vec<f32> = (0..256).map(|i| ((i * 97) % 89) as f32 / 11.0 - 4.0).collect();
        for fmt in [e2m1(), e3m0()] {
            let q = mx_quantize_cols(&x, 64, fmt, Scaling::TruncationFree);
            let q2 = mx_quantize_cols(&q, 64, fmt, Scaling::TruncationFree);
            assert_eq!(q, q2, "fmt {}", fmt.name);
        }
    }

    #[test]
    fn stochastic_matches_det_at_grid_points() {
        let fmt = e2m1();
        let x: Vec<f32> = vec![1.0, -0.5, 6.0, 0.0, 2.0, -6.0, 4.0, 3.0]
            .into_iter()
            .cycle()
            .take(32)
            .collect();
        let u = vec![0.7f32; 32];
        let qd = mx_quantize_cols(&x, 32, fmt, Scaling::TruncationFree);
        let qs = mx_quantize_stoch_cols(&x, &u, 32, fmt, Scaling::TruncationFree);
        assert_eq!(qd, qs); // exact grid points don't move
        assert_eq!(qd, x);
    }

    #[test]
    fn partial_group_equals_zero_padding() {
        let fmt = e2m1();
        let x: Vec<f32> = (0..48).map(|i| (i as f32 - 24.0) / 5.0).collect();
        let q = mx_quantize_cols(&x, 48, fmt, Scaling::TruncationFree);
        let mut padded = x.clone();
        padded.resize(64, 0.0);
        let qp = mx_quantize_cols(&padded, 64, fmt, Scaling::TruncationFree);
        assert_eq!(&q[..48], &qp[..48]);
    }

    #[test]
    fn into_variant_matches() {
        let x: Vec<f32> = (0..96).map(|i| (i as f32).sin() * 3.0).collect();
        let a = mx_quantize_cols(&x, 32, e2m1(), Scaling::Floor);
        let mut b = vec![0.0; 96];
        mx_quantize_cols_into(&x, 32, e2m1(), Scaling::Floor, &mut b);
        assert_eq!(a, b);
    }
}
