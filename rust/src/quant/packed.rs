//! Packed MXFP4 core: the [`Quantizer`] trait and the [`PackedMx`]
//! representation the coordinator mirrors weights into.
//!
//! The fake-quant mirror (`mx.rs`/`qema.rs`/`int4.rs`) simulates FP4 by
//! round-tripping every weight through f32, which costs 4 bytes of
//! state per element and an f32 compare per flip test. `PackedMx`
//! instead stores the *codes*: two 4-bit level indices per byte plus
//! one E8M0 scale byte per 32-element group (~0.53 bytes/element, 7.5x
//! smaller). Flip detection degenerates to byte compares, and the f32
//! view is recovered bit-exactly on demand via [`PackedMx::dequantize_into`]
//! — `dequantize(quantize_packed(x))` equals the fake-quant output
//! exactly (property-tested in `tests/properties.rs` and golden-pinned
//! through the trainer mirror).
//!
//! The same packed layout is the substrate for packed checkpoints and a
//! native FP4 serving path (see ROADMAP.md).

use anyhow::{bail, Result};

use super::formats::{e2m1, e3m0, exp2i, GROUP};

/// Stable on-disk identifiers for the `'static` level-decode tables a
/// [`PackedMx`] can carry (TJCKPT02 packed-checkpoint interchange).
/// Codes are nibble indices into these tables, so a checkpoint only
/// needs this one byte to reconstruct the decode side.
pub fn level_table_id(levels: &[f32]) -> Option<u8> {
    if levels == &e2m1().levels[..] {
        Some(0)
    } else if levels == &e3m0().levels[..] {
        Some(1)
    } else if levels == &super::int4::INT4_LEVELS[..] {
        Some(2)
    } else {
        None
    }
}

/// Inverse of [`level_table_id`].
pub fn level_table_from_id(id: u8) -> Option<&'static [f32]> {
    match id {
        0 => Some(&e2m1().levels),
        1 => Some(&e3m0().levels),
        2 => Some(&super::int4::INT4_LEVELS),
        _ => None,
    }
}

/// Iterate `(group_index, flat_start, flat_end)` of the row-major 1x32
/// group layout of a `(len/cols, cols)` matrix, ragged tails included.
/// This is THE definition of the group order: the encode side
/// (`mx::for_each_group`, which drives `push_group_scale`) and the
/// decode side ([`PackedMx::for_each_group`], which drives scale-byte
/// consumption) both delegate here, so they cannot desynchronize.
#[inline]
pub(crate) fn group_ranges<F: FnMut(usize, usize, usize)>(len: usize, cols: usize, mut f: F) {
    let cols = cols.max(1);
    let mut g = 0;
    for r0 in (0..len).step_by(cols) {
        for g0 in (0..cols).step_by(GROUP) {
            f(g, r0 + g0, r0 + (g0 + GROUP).min(cols));
            g += 1;
        }
    }
}

/// Bias of the E8M0 scale byte: `byte = scale_exponent + 127`, covering
/// the clamped exponent range [-127, 127] in 0..=254 (255 unused/NaN,
/// matching the OCP MX E8M0 encoding).
pub const E8M0_BIAS: i32 = 127;

/// Largest scale byte for which "same scale + same code <=> same value"
/// is exact: past 2^121 the `level * scale` product can overflow to inf
/// (collapsing distinct codes) for Qp up to 16, so comparisons above
/// this fall back to dequantized values.
const CODE_CMP_MAX_SCALE_BYTE: u8 = (121 + E8M0_BIAS) as u8;

/// A quantizer with both the legacy fake-quant (f32 in, f32 grid values
/// out) path and the packed-code path. Implementations must keep the
/// two bit-exact: `dequantize(quantize_packed(x)) == quantize_f32(x)`.
pub trait Quantizer {
    /// Short name for logs and benches.
    fn name(&self) -> &'static str;

    /// Fake-quantize `x` (row-major, trailing dim `cols`) into `out`.
    fn quantize_f32(&self, x: &[f32], cols: usize, out: &mut [f32]);

    /// Quantize `x` into packed 4-bit codes + shared scales, reusing
    /// `out`'s buffers (no steady-state allocation).
    fn quantize_packed(&self, x: &[f32], cols: usize, out: &mut PackedMx);

    /// Expand packed codes back to f32 grid values; bit-exact to
    /// `quantize_f32` on the tensor the codes came from.
    fn dequantize(&self, p: &PackedMx, out: &mut [f32]) {
        p.dequantize_into(out);
    }
}

/// Packed 4-bit quantized tensor: level codes (two per byte, low nibble
/// = even flat index) plus either one E8M0 scale byte per 1x32 group
/// (MX formats) or a single per-tensor f32 scale (INT4). Carries its
/// decode table, so it dequantizes without knowing which quantizer
/// produced it.
#[derive(Debug, Clone, Default)]
pub struct PackedMx {
    codes: Vec<u8>,
    /// E8M0 scale byte per group, row-major; empty for per-tensor mode.
    scales: Vec<u8>,
    /// Per-tensor scale (INT4); 1.0 and unused in grouped mode.
    tensor_scale: f32,
    /// Level-decode table: `value(i) = levels[code(i)] * scale`.
    levels: &'static [f32],
    len: usize,
    cols: usize,
}

impl PackedMx {
    /// Elements represented (not bytes).
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Trailing (group-axis) dimension.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of 1x32 groups (0 in per-tensor mode).
    #[inline]
    pub fn num_groups(&self) -> usize {
        self.scales.len()
    }

    /// Groups per row, including a ragged tail group.
    #[inline]
    pub fn groups_per_row(&self) -> usize {
        (self.cols + GROUP - 1) / GROUP.max(1)
    }

    /// Packed state footprint in bytes (codes + scales).
    pub fn bytes(&self) -> usize {
        self.codes.len() + self.scales.len()
    }

    /// Decode table for this tensor's codes.
    #[inline]
    pub fn levels(&self) -> &'static [f32] {
        self.levels
    }

    /// Raw packed code bytes (two 4-bit level indices per byte, low
    /// nibble = even flat index). Serving kernels and the TJCKPT02
    /// checkpoint writer read this directly.
    #[inline]
    pub fn codes(&self) -> &[u8] {
        &self.codes
    }

    /// Raw E8M0 scale bytes, one per 1x32 group in storage order
    /// (empty in per-tensor mode).
    #[inline]
    pub fn scale_bytes(&self) -> &[u8] {
        &self.scales
    }

    /// Per-tensor scale (INT4 mode; 1.0 and unused in grouped mode).
    #[inline]
    pub fn tensor_scale(&self) -> f32 {
        self.tensor_scale
    }

    /// Reassemble a packed tensor from serialized parts (TJCKPT02
    /// load path). Validates the byte counts against the geometry so a
    /// corrupt checkpoint fails here, not deep inside a serving kernel.
    pub fn from_parts(
        len: usize,
        cols: usize,
        codes: Vec<u8>,
        scales: Vec<u8>,
        tensor_scale: f32,
        levels: &'static [f32],
    ) -> Result<PackedMx> {
        if codes.len() != (len + 1) / 2 {
            bail!("packed codes: {} bytes for {len} elements", codes.len());
        }
        if levels.is_empty() || levels.len() > 16 {
            bail!("packed level table has {} entries", levels.len());
        }
        if len > 0 && (cols == 0 || len % cols != 0) {
            bail!("packed tensor: len {len} not a multiple of cols {cols}");
        }
        if !scales.is_empty() {
            if len == 0 {
                bail!("packed scales: {} bytes for an empty tensor", scales.len());
            }
            let groups = (len / cols) * ((cols + GROUP - 1) / GROUP);
            if scales.len() != groups {
                bail!("packed scales: {} bytes for {groups} groups", scales.len());
            }
        }
        if !tensor_scale.is_finite() {
            bail!("packed tensor scale {tensor_scale} not finite");
        }
        if levels.len() < 16 {
            // All registered tables have 15 entries, leaving nibble 15
            // unmapped; the pad nibble of an odd-length tensor is
            // exempt.
            let max = (levels.len() - 1) as u8;
            for (i, &b) in codes.iter().enumerate() {
                if (b & 0x0F) > max || ((b >> 4) > max && 2 * i + 1 < len) {
                    bail!(
                        "packed code byte {i} indexes past the {}-entry level table",
                        levels.len()
                    );
                }
            }
        }
        Ok(PackedMx { codes, scales, tensor_scale, levels, len, cols })
    }

    /// A standalone packed tensor holding rows `[row0, row0 + nrows)`
    /// of this one (row-major, trailing dim `cols`). Codes and scale
    /// bytes are carried over bit-for-bit — every sliced element
    /// dequantizes to exactly the value it has in the full tensor —
    /// which is what makes the row-sharded serve fleet bit-exact to the
    /// single-engine path. Scale bytes slice directly because 1x32
    /// groups never cross rows; codes byte-slice when the start index
    /// is even and are repacked nibble-by-nibble otherwise (odd
    /// `row0 * cols`). Per-tensor (INT4) mode carries the tensor scale.
    pub fn slice_rows(&self, row0: usize, nrows: usize) -> Result<PackedMx> {
        if self.cols == 0 || self.len % self.cols != 0 {
            bail!("slice_rows needs a rectangular tensor, got len {} cols {}", self.len, self.cols);
        }
        let total_rows = self.len / self.cols;
        if row0 + nrows > total_rows {
            bail!("rows [{row0}, {}) exceed the {total_rows} stored rows", row0 + nrows);
        }
        let a = row0 * self.cols;
        let len = nrows * self.cols;
        let codes = if a % 2 == 0 {
            self.codes[a / 2..(a + len + 1) / 2].to_vec()
        } else {
            let mut out = vec![0u8; (len + 1) / 2];
            for i in 0..len {
                out[i / 2] |= self.code(a + i) << ((i % 2) * 4);
            }
            out
        };
        let scales = if self.scales.is_empty() {
            Vec::new()
        } else {
            let gpr = self.groups_per_row();
            self.scales[row0 * gpr..(row0 + nrows) * gpr].to_vec()
        };
        PackedMx::from_parts(len, self.cols, codes, scales, self.tensor_scale, self.levels)
    }

    /// The 4-bit level code of flat element `i`.
    #[inline]
    pub fn code(&self, i: usize) -> u8 {
        (self.codes[i / 2] >> ((i % 2) * 4)) & 0x0F
    }

    /// Level value of a code.
    #[inline]
    pub fn level(&self, code: u8) -> f32 {
        self.levels[code as usize]
    }

    /// Raw E8M0 byte of group `g`.
    #[inline]
    pub fn scale_byte(&self, g: usize) -> u8 {
        self.scales[g]
    }

    /// Shared-scale exponent of group `g`.
    #[inline]
    pub fn group_scale_exp(&self, g: usize) -> i32 {
        self.scales[g] as i32 - E8M0_BIAS
    }

    /// Shared scale of group `g` (or the per-tensor scale).
    #[inline]
    pub fn group_scale(&self, g: usize) -> f32 {
        if self.scales.is_empty() {
            self.tensor_scale
        } else {
            exp2i(self.group_scale_exp(g))
        }
    }

    /// Group index of flat element `i`.
    #[inline]
    pub fn group_of(&self, i: usize) -> usize {
        if self.scales.is_empty() {
            return 0;
        }
        let row = i / self.cols;
        let col = i % self.cols;
        row * self.groups_per_row() + col / GROUP
    }

    /// Dequantized value of flat element `i` (random access; use
    /// [`dequantize_into`](Self::dequantize_into) for bulk decode).
    #[inline]
    pub fn value(&self, i: usize) -> f32 {
        self.level(self.code(i)) * self.group_scale(self.group_of(i))
    }

    /// The byte slice covering codes of flat range `[a, b)`. Boundary
    /// bytes may include a neighboring element's nibble, so equality of
    /// these slices implies (but is not implied by) equality of the
    /// range's codes — a conservative fast path for flip scans.
    #[inline]
    pub fn code_bytes(&self, a: usize, b: usize) -> &[u8] {
        &self.codes[a / 2..(b + 1) / 2]
    }

    /// Start a grouped (MX) tensor: zeroed codes, scales to be pushed
    /// row-major via [`push_group_scale`](Self::push_group_scale).
    pub(crate) fn begin_grouped(&mut self, len: usize, cols: usize, levels: &'static [f32]) {
        self.reset(len, cols, levels);
    }

    /// Start a per-tensor-scaled (INT4) tensor.
    pub(crate) fn begin_per_tensor(
        &mut self,
        len: usize,
        cols: usize,
        levels: &'static [f32],
        scale: f32,
    ) {
        self.reset(len, cols, levels);
        self.tensor_scale = scale;
    }

    fn reset(&mut self, len: usize, cols: usize, levels: &'static [f32]) {
        self.codes.clear();
        self.codes.resize((len + 1) / 2, 0);
        self.scales.clear();
        self.tensor_scale = 1.0;
        self.levels = levels;
        self.len = len;
        self.cols = cols;
    }

    pub(crate) fn push_group_scale(&mut self, s: i32) {
        debug_assert!((-E8M0_BIAS..=E8M0_BIAS).contains(&s));
        self.scales.push((s + E8M0_BIAS) as u8);
    }

    #[inline]
    pub(crate) fn set_code(&mut self, i: usize, c: u8) {
        debug_assert!(c < 16);
        let b = &mut self.codes[i / 2];
        if i % 2 == 0 {
            *b = (*b & 0xF0) | c;
        } else {
            *b = (*b & 0x0F) | (c << 4);
        }
    }

    /// Iterate `(group_index, flat_start, flat_end)` over this tensor's
    /// 1x32 groups in storage order (delegates to the shared
    /// [`group_ranges`] layout definition).
    #[inline]
    pub fn for_each_group<F: FnMut(usize, usize, usize)>(&self, f: F) {
        group_ranges(self.len, self.cols, f);
    }

    /// Bulk decode into a caller-owned buffer; bit-exact to the
    /// producing quantizer's fake-quant output.
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        if self.scales.is_empty() {
            for (i, o) in out.iter_mut().enumerate() {
                *o = self.level(self.code(i)) * self.tensor_scale;
            }
            return;
        }
        self.for_each_group(|g, a, b| {
            let scale = self.group_scale(g);
            for i in a..b {
                out[i] = self.level(self.code(i)) * scale;
            }
        });
    }

    /// Allocating decode.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.len];
        self.dequantize_into(&mut out);
        out
    }

    /// Count elements whose dequantized value differs from `prev`'s —
    /// the flip count of the step `prev -> self`. Groups with an
    /// unchanged scale byte compare codes (a 16-byte memcmp per full
    /// group when nothing flipped); groups whose scale moved compare
    /// dequantized values, which keeps the count exactly equal to an
    /// f32-mirror comparison even when a scale shift renumbers codes.
    pub fn flip_count(&self, prev: &PackedMx) -> usize {
        assert_eq!(self.len, prev.len);
        assert_eq!(self.cols, prev.cols);
        let mut flips = 0usize;
        if self.scales.is_empty() || prev.scales.is_empty() {
            for i in 0..self.len {
                if self.value(i) != prev.value(i) {
                    flips += 1;
                }
            }
            return flips;
        }
        self.for_each_group(|g, a, b| {
            flips += self.group_flips(prev, g, a, b, |_, _| ());
        });
        flips
    }

    /// Shared group-scan core for flip counting: returns the number of
    /// flips in flat range `[a, b)` of group `g` and invokes
    /// `on_flip(i, |delta|)` for each flipped element. The
    /// equal-scale-byte fast path is only trusted below the overflow
    /// threshold where code equality is equivalent to value equality.
    #[inline]
    pub(crate) fn group_flips<F: FnMut(usize, f32)>(
        &self,
        prev: &PackedMx,
        g: usize,
        a: usize,
        b: usize,
        mut on_flip: F,
    ) -> usize {
        let sb = self.scale_byte(g);
        let exact_codes = sb == prev.scale_byte(g) && sb <= CODE_CMP_MAX_SCALE_BYTE;
        if exact_codes && self.code_bytes(a, b) == prev.code_bytes(a, b) {
            return 0;
        }
        let (sa, sp) = (self.group_scale(g), prev.group_scale(g));
        let mut flips = 0;
        for i in a..b {
            let (ca, cp) = (self.code(i), prev.code(i));
            if exact_codes && ca == cp {
                continue;
            }
            let va = self.level(ca) * sa;
            let vp = prev.level(cp) * sp;
            if va != vp {
                flips += 1;
                on_flip(i, (va - vp).abs());
            }
        }
        flips
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::formats::{e2m1, e3m0, Scaling};
    use crate::quant::int4::{int4_quantize, Int4Quantizer};
    use crate::quant::mx::{mx_quantize_cols, MxQuantizer};
    use crate::quant::qema::{qema_quantize_cols, QemaQuantizer};

    fn sample(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i * 37) % 113) as f32 / 9.0 - 6.0).collect()
    }

    #[test]
    fn roundtrip_matches_fake_quant_all_formats_and_scalings() {
        for fmt in [e2m1(), e3m0()] {
            for scaling in [Scaling::TruncationFree, Scaling::Floor] {
                // Ragged tail: 48 cols -> 32 + 16 per row.
                for cols in [32usize, 48, 64] {
                    let x = sample(cols * 3);
                    let q = MxQuantizer { fmt, scaling };
                    let mut p = PackedMx::default();
                    q.quantize_packed(&x, cols, &mut p);
                    let want = mx_quantize_cols(&x, cols, fmt, scaling);
                    assert_eq!(
                        p.dequantize(),
                        want,
                        "fmt={} scaling={scaling:?} cols={cols}",
                        fmt.name
                    );
                    // Trait-default dequantize is the same decode.
                    let mut out = vec![0.0; x.len()];
                    q.dequantize(&p, &mut out);
                    assert_eq!(out, want);
                }
            }
        }
    }

    #[test]
    fn all_zero_group_roundtrips() {
        let mut x = vec![0.0f32; 64];
        x[40] = 3.0; // second group non-zero, first all-zero
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&x, 64, &mut p);
        assert_eq!(p.dequantize(), mx_quantize_cols(&x, 64, e2m1(), Scaling::TruncationFree));
        assert!(p.dequantize()[..32].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn qema_roundtrip_matches_fake_quant() {
        let w = sample(96);
        let ema: Vec<f32> = w.iter().map(|&v| v * 0.9 + 0.03).collect();
        let fmt = e2m1();
        let q = QemaQuantizer { fmt, scaling: Scaling::TruncationFree, ema: &ema };
        let mut p = PackedMx::default();
        q.quantize_packed(&w, 48, &mut p);
        assert_eq!(
            p.dequantize(),
            qema_quantize_cols(&w, &ema, 48, fmt, Scaling::TruncationFree)
        );
    }

    #[test]
    fn int4_roundtrip_matches_fake_quant() {
        let x = sample(37);
        let mut p = PackedMx::default();
        Int4Quantizer.quantize_packed(&x, 37, &mut p);
        let want = int4_quantize(&x, None);
        let got = p.dequantize();
        assert_eq!(got.len(), want.len());
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            // modulo -0.0 == 0.0 (sign of zero is not representable in codes)
            assert!(g == w, "i={i}: {g:?} != {w:?}");
        }
        assert_eq!(p.num_groups(), 0, "int4 is per-tensor scaled");
    }

    #[test]
    fn packed_layout_and_footprint() {
        let x = sample(96);
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&x, 48, &mut p);
        assert_eq!(p.len(), 96);
        assert_eq!(p.cols(), 48);
        assert_eq!(p.groups_per_row(), 2);
        assert_eq!(p.num_groups(), 4);
        // 48 code bytes + 4 scale bytes vs 384 f32 bytes.
        assert_eq!(p.bytes(), 96 / 2 + 4);
        for i in 0..p.len() {
            assert!(p.code(i) < 15, "4-bit level index");
            assert_eq!(p.value(i), p.dequantize()[i]);
        }
    }

    #[test]
    fn scale_bytes_are_biased_exponents() {
        let mut x = vec![0.0f32; 32];
        x[0] = 6.0; // max 6 with Qp 6 -> scale exponent 0
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&x, 32, &mut p);
        assert_eq!(p.scale_byte(0), E8M0_BIAS as u8);
        assert_eq!(p.group_scale_exp(0), 0);
        assert_eq!(p.group_scale(0), 1.0);
    }

    #[test]
    fn flip_count_matches_value_compare() {
        let x = sample(128);
        // Perturb a few elements across grid thresholds.
        let mut y = x.clone();
        for i in (0..128).step_by(11) {
            y[i] = y[i] * 1.3 + 0.21;
        }
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let (mut pa, mut pb) = (PackedMx::default(), PackedMx::default());
        q.quantize_packed(&x, 64, &mut pa);
        q.quantize_packed(&y, 64, &mut pb);
        let (da, db) = (pa.dequantize(), pb.dequantize());
        let want = da.iter().zip(&db).filter(|(a, b)| a != b).count();
        assert_eq!(pb.flip_count(&pa), want);
        assert_eq!(pa.flip_count(&pa), 0);
    }

    #[test]
    fn from_parts_roundtrips_serialized_tensor() {
        let x = sample(96);
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&x, 48, &mut p);
        let id = level_table_id(p.levels()).expect("e2m1 table registered");
        let back = PackedMx::from_parts(
            p.len(),
            p.cols(),
            p.codes().to_vec(),
            p.scale_bytes().to_vec(),
            p.tensor_scale(),
            level_table_from_id(id).unwrap(),
        )
        .unwrap();
        assert_eq!(back.dequantize(), p.dequantize());
        assert_eq!(back.flip_count(&p), 0);
        // Geometry mismatches are rejected.
        let lv = &e2m1().levels;
        assert!(PackedMx::from_parts(96, 48, vec![0; 3], Vec::new(), 1.0, lv).is_err());
        assert!(PackedMx::from_parts(96, 48, vec![0; 48], vec![0; 3], 1.0, lv).is_err());
        assert!(PackedMx::from_parts(95, 48, vec![0; 48], vec![0; 4], 1.0, lv).is_err());
    }

    #[test]
    fn from_parts_rejects_codes_past_level_table() {
        // Every registered table has 15 entries (7 negatives + zero +
        // 7 positives, or INT4's 15 grid points), so nibble 15 is
        // unmapped; a corrupt checkpoint must fail at load, not panic
        // in a kernel.
        let iv = &crate::quant::int4::INT4_LEVELS[..];
        assert!(PackedMx::from_parts(4, 4, vec![0x00, 0x0F], Vec::new(), 1.0, iv).is_err());
        assert!(PackedMx::from_parts(4, 4, vec![0x00, 0xF0], Vec::new(), 1.0, iv).is_err());
        // The pad nibble of an odd-length tensor is exempt.
        assert!(PackedMx::from_parts(3, 3, vec![0x00, 0xF0], Vec::new(), 1.0, iv).is_ok());
        // e2m1's table is 15 entries too: code 14 is the top level,
        // nibble 15 is invalid.
        assert_eq!(e2m1().levels.len(), 15);
        assert!(PackedMx::from_parts(4, 4, vec![0xEE, 0xEE], Vec::new(), 1.0, &e2m1().levels)
            .is_ok());
        assert!(PackedMx::from_parts(4, 4, vec![0xFF, 0xFF], Vec::new(), 1.0, &e2m1().levels)
            .is_err());
    }

    #[test]
    fn level_table_ids_cover_all_formats() {
        use crate::quant::int4::INT4_LEVELS;
        assert_eq!(level_table_id(&e2m1().levels), Some(0));
        assert_eq!(level_table_id(&e3m0().levels), Some(1));
        assert_eq!(level_table_id(&INT4_LEVELS), Some(2));
        assert_eq!(level_table_id(&[1.0, 2.0]), None);
        for id in 0..3u8 {
            assert_eq!(level_table_id(level_table_from_id(id).unwrap()), Some(id));
        }
        assert!(level_table_from_id(9).is_none());
    }

    #[test]
    fn slice_rows_preserves_values_even_and_odd_alignment() {
        // cols 57 is odd, so any odd row0 starts mid-byte and exercises
        // the nibble repack path; cols 32 stays byte-aligned.
        for cols in [32usize, 57] {
            let rows = 5;
            let x = sample(rows * cols);
            let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
            let mut p = PackedMx::default();
            q.quantize_packed(&x, cols, &mut p);
            let full = p.dequantize();
            for (r0, nr) in [(0usize, 2usize), (1, 3), (3, 2), (2, 0), (0, 5)] {
                let s = p.slice_rows(r0, nr).unwrap();
                assert_eq!(s.len(), nr * cols, "cols={cols} r0={r0} nr={nr}");
                assert_eq!(s.cols(), cols);
                assert_eq!(s.levels(), p.levels());
                assert_eq!(s.dequantize(), full[r0 * cols..(r0 + nr) * cols].to_vec());
            }
            assert!(p.slice_rows(4, 2).is_err(), "out-of-range rows rejected");
        }
    }

    #[test]
    fn slice_rows_per_tensor_keeps_scale() {
        let x = sample(6 * 37);
        let mut p = PackedMx::default();
        Int4Quantizer.quantize_packed(&x, 37, &mut p);
        assert_eq!(p.num_groups(), 0, "per-tensor mode");
        let s = p.slice_rows(1, 4).unwrap();
        assert_eq!(s.tensor_scale(), p.tensor_scale());
        assert_eq!(s.num_groups(), 0);
        assert_eq!(s.dequantize(), p.dequantize()[37..5 * 37].to_vec());
    }

    #[test]
    fn flip_count_exact_across_scale_shift() {
        // Doubling every element doubles the group scale but keeps all
        // codes identical: every non-zero element flips, zeros don't.
        let x = sample(64);
        let y: Vec<f32> = x.iter().map(|&v| v * 2.0).collect();
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let (mut pa, mut pb) = (PackedMx::default(), PackedMx::default());
        q.quantize_packed(&x, 32, &mut pa);
        q.quantize_packed(&y, 32, &mut pb);
        for i in 0..64 {
            assert_eq!(pa.code(i), pb.code(i), "codes invariant under x2");
        }
        let nonzero = pa.dequantize().iter().filter(|&&v| v != 0.0).count();
        assert_eq!(pb.flip_count(&pa), nonzero);
    }
}
