//! Packed MXFP4 core: the [`Quantizer`] trait and the [`PackedMx`]
//! representation the coordinator mirrors weights into.
//!
//! The fake-quant mirror (`mx.rs`/`qema.rs`/`int4.rs`) simulates FP4 by
//! round-tripping every weight through f32, which costs 4 bytes of
//! state per element and an f32 compare per flip test. `PackedMx`
//! instead stores the *codes*: two 4-bit level indices per byte plus
//! one scale byte per group (~0.53 bytes/element at MX geometry, 7.5x
//! smaller). The group layout and scale encoding are carried by a
//! [`GroupGeom`]: MX (32-element groups, E8M0 power-of-two bytes) or
//! NVFP4 (16-element groups, E4M3 bytes) — see `quant/formats.rs`.
//! Flip detection degenerates to byte compares, and the f32
//! view is recovered bit-exactly on demand via [`PackedMx::dequantize_into`]
//! — `dequantize(quantize_packed(x))` equals the fake-quant output
//! exactly (property-tested in `tests/properties.rs` and golden-pinned
//! through the trainer mirror).
//!
//! The same packed layout is the substrate for packed checkpoints and a
//! native FP4 serving path (see ROADMAP.md).

use anyhow::{bail, Result};

use super::formats::{e2m1, e3m0, GroupGeom, ScaleEnc};

/// Stable on-disk identifiers for the `'static` level-decode tables a
/// [`PackedMx`] can carry (TJCKPT02 packed-checkpoint interchange).
/// Codes are nibble indices into these tables, so a checkpoint only
/// needs this one byte to reconstruct the decode side.
pub fn level_table_id(levels: &[f32]) -> Option<u8> {
    if levels == &e2m1().levels[..] {
        Some(0)
    } else if levels == &e3m0().levels[..] {
        Some(1)
    } else if levels == &super::int4::INT4_LEVELS[..] {
        Some(2)
    } else {
        None
    }
}

/// Inverse of [`level_table_id`].
pub fn level_table_from_id(id: u8) -> Option<&'static [f32]> {
    match id {
        0 => Some(&e2m1().levels),
        1 => Some(&e3m0().levels),
        2 => Some(&super::int4::INT4_LEVELS),
        _ => None,
    }
}

/// Iterate `(group_index, flat_start, flat_end)` of the row-major
/// 1x`group` layout of a `(len/cols, cols)` matrix, ragged tails
/// included. Groups never cross rows at any group size, which is what
/// keeps [`PackedMx::slice_rows`] valid for every geometry.
/// This is THE definition of the group order: the encode side
/// (`mx::for_each_group`, which drives `push_group_scale`) and the
/// decode side ([`PackedMx::for_each_group`], which drives scale-byte
/// consumption) both delegate here, so they cannot desynchronize.
#[inline]
pub fn group_ranges<F: FnMut(usize, usize, usize)>(
    len: usize,
    cols: usize,
    group: usize,
    mut f: F,
) {
    // GroupGeom::new enforces group_size >= 1; the .max(1) keeps a
    // hand-rolled 0 from panicking step_by in release builds.
    debug_assert!(group >= 1, "group_ranges with group size 0");
    let group = group.max(1);
    let cols = cols.max(1);
    let mut g = 0;
    for r0 in (0..len).step_by(cols) {
        for g0 in (0..cols).step_by(group) {
            f(g, r0 + g0, r0 + (g0 + group).min(cols));
            g += 1;
        }
    }
}

/// Bias of the E8M0 scale byte: `byte = scale_exponent + 127`, covering
/// the clamped exponent range [-127, 127] in 0..=254 (255 unused/NaN,
/// matching the OCP MX E8M0 encoding).
pub const E8M0_BIAS: i32 = 127;

/// Largest scale byte for which "same scale + same code <=> same value"
/// is exact: past 2^121 the `level * scale` product can overflow to inf
/// (collapsing distinct codes) for Qp up to 16, so comparisons above
/// this fall back to dequantized values.
const CODE_CMP_MAX_SCALE_BYTE: u8 = (121 + E8M0_BIAS) as u8;

/// A quantizer with both the legacy fake-quant (f32 in, f32 grid values
/// out) path and the packed-code path. Implementations must keep the
/// two bit-exact: `dequantize(quantize_packed(x)) == quantize_f32(x)`.
pub trait Quantizer {
    /// Short name for logs and benches.
    fn name(&self) -> &'static str;

    /// Fake-quantize `x` (row-major, trailing dim `cols`) into `out`.
    fn quantize_f32(&self, x: &[f32], cols: usize, out: &mut [f32]);

    /// Quantize `x` into packed 4-bit codes + shared scales, reusing
    /// `out`'s buffers (no steady-state allocation).
    fn quantize_packed(&self, x: &[f32], cols: usize, out: &mut PackedMx);

    /// Expand packed codes back to f32 grid values; bit-exact to
    /// `quantize_f32` on the tensor the codes came from.
    fn dequantize(&self, p: &PackedMx, out: &mut [f32]) {
        p.dequantize_into(out);
    }
}

/// Packed 4-bit quantized tensor: level codes (two per byte, low nibble
/// = even flat index) plus either one scale byte per group (grouped
/// formats) or a single per-tensor f32 scale (INT4). Carries its
/// decode table and its [`GroupGeom`] (group size + scale-byte
/// encoding), so it dequantizes without knowing which quantizer
/// produced it.
#[derive(Debug, Clone, Default)]
pub struct PackedMx {
    codes: Vec<u8>,
    /// Scale byte per group, row-major; empty for per-tensor mode.
    /// Decoded per `geom.scale_enc()` (E8M0 or E4M3).
    scales: Vec<u8>,
    /// Per-tensor scale (INT4); 1.0 and unused in grouped mode.
    tensor_scale: f32,
    /// Level-decode table: `value(i) = levels[code(i)] * scale`.
    levels: &'static [f32],
    /// Group size + scale-byte encoding; defaults to MX (1x32, E8M0).
    geom: GroupGeom,
    len: usize,
    cols: usize,
}

impl PackedMx {
    /// Elements represented (not bytes).
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Trailing (group-axis) dimension.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of scale groups (0 in per-tensor mode).
    #[inline]
    pub fn num_groups(&self) -> usize {
        self.scales.len()
    }

    /// Group size + scale encoding of this tensor.
    #[inline]
    pub fn geom(&self) -> GroupGeom {
        self.geom
    }

    /// Groups per row, including a ragged tail group. Division is safe:
    /// `GroupGeom::new` rejects `group_size == 0` at construction (the
    /// former `(cols + GROUP - 1) / GROUP.max(1)` guarded only the
    /// divisor, leaving the `+ GROUP - 1` numerator to underflow).
    #[inline]
    pub fn groups_per_row(&self) -> usize {
        self.geom.groups_per_row(self.cols)
    }

    /// Packed state footprint in bytes (codes + scales).
    pub fn bytes(&self) -> usize {
        self.codes.len() + self.scales.len()
    }

    /// Decode table for this tensor's codes.
    #[inline]
    pub fn levels(&self) -> &'static [f32] {
        self.levels
    }

    /// Raw packed code bytes (two 4-bit level indices per byte, low
    /// nibble = even flat index). Serving kernels and the TJCKPT02
    /// checkpoint writer read this directly.
    #[inline]
    pub fn codes(&self) -> &[u8] {
        &self.codes
    }

    /// Raw scale bytes, one per group in storage order (empty in
    /// per-tensor mode). Encoding per [`Self::geom`].
    #[inline]
    pub fn scale_bytes(&self) -> &[u8] {
        &self.scales
    }

    /// Per-tensor scale (INT4 mode; 1.0 and unused in grouped mode).
    #[inline]
    pub fn tensor_scale(&self) -> f32 {
        self.tensor_scale
    }

    /// Reassemble a packed tensor from serialized parts at the default
    /// MX geometry (TJCKPT02 load path for sections without a geometry
    /// byte). See [`Self::from_parts_geom`].
    pub fn from_parts(
        len: usize,
        cols: usize,
        codes: Vec<u8>,
        scales: Vec<u8>,
        tensor_scale: f32,
        levels: &'static [f32],
    ) -> Result<PackedMx> {
        PackedMx::from_parts_geom(GroupGeom::mx(), len, cols, codes, scales, tensor_scale, levels)
    }

    /// Reassemble a packed tensor from serialized parts (TJCKPT02
    /// load path). Validates the byte counts against the geometry and
    /// every scale byte against the geometry's encoding (the E8M0 NaN
    /// byte 255 and non-finite/negative E4M3 bytes are rejected) so a
    /// corrupt checkpoint fails here, not deep inside a serving kernel.
    pub fn from_parts_geom(
        geom: GroupGeom,
        len: usize,
        cols: usize,
        codes: Vec<u8>,
        scales: Vec<u8>,
        tensor_scale: f32,
        levels: &'static [f32],
    ) -> Result<PackedMx> {
        if codes.len() != (len + 1) / 2 {
            bail!("packed codes: {} bytes for {len} elements", codes.len());
        }
        if levels.is_empty() || levels.len() > 16 {
            bail!("packed level table has {} entries", levels.len());
        }
        if len > 0 && (cols == 0 || len % cols != 0) {
            bail!("packed tensor: len {len} not a multiple of cols {cols}");
        }
        if !scales.is_empty() {
            if len == 0 {
                bail!("packed scales: {} bytes for an empty tensor", scales.len());
            }
            let groups = (len / cols) * geom.groups_per_row(cols);
            if scales.len() != groups {
                bail!("packed scales: {} bytes for {groups} groups", scales.len());
            }
            for (g, &b) in scales.iter().enumerate() {
                if !geom.scale_byte_valid(b) {
                    bail!(
                        "packed scale byte {b:#04x} of group {g} is not a valid {} scale",
                        geom.scale_enc().as_str()
                    );
                }
            }
        }
        if !tensor_scale.is_finite() {
            bail!("packed tensor scale {tensor_scale} not finite");
        }
        if levels.len() < 16 {
            // All registered tables have 15 entries, leaving nibble 15
            // unmapped; the pad nibble of an odd-length tensor is
            // exempt.
            let max = (levels.len() - 1) as u8;
            for (i, &b) in codes.iter().enumerate() {
                if (b & 0x0F) > max || ((b >> 4) > max && 2 * i + 1 < len) {
                    bail!(
                        "packed code byte {i} indexes past the {}-entry level table",
                        levels.len()
                    );
                }
            }
        }
        Ok(PackedMx { codes, scales, tensor_scale, levels, geom, len, cols })
    }

    /// A standalone packed tensor holding rows `[row0, row0 + nrows)`
    /// of this one (row-major, trailing dim `cols`). Codes and scale
    /// bytes are carried over bit-for-bit — every sliced element
    /// dequantizes to exactly the value it has in the full tensor —
    /// which is what makes the row-sharded serve fleet bit-exact to the
    /// single-engine path. Scale bytes slice directly because groups
    /// never cross rows at any group size; codes byte-slice when the start index
    /// is even and are repacked nibble-by-nibble otherwise (odd
    /// `row0 * cols`). Per-tensor (INT4) mode carries the tensor scale.
    pub fn slice_rows(&self, row0: usize, nrows: usize) -> Result<PackedMx> {
        if self.cols == 0 || self.len % self.cols != 0 {
            bail!("slice_rows needs a rectangular tensor, got len {} cols {}", self.len, self.cols);
        }
        let total_rows = self.len / self.cols;
        if row0 + nrows > total_rows {
            bail!("rows [{row0}, {}) exceed the {total_rows} stored rows", row0 + nrows);
        }
        let a = row0 * self.cols;
        let len = nrows * self.cols;
        let codes = if a % 2 == 0 {
            self.codes[a / 2..(a + len + 1) / 2].to_vec()
        } else {
            let mut out = vec![0u8; (len + 1) / 2];
            for i in 0..len {
                out[i / 2] |= self.code(a + i) << ((i % 2) * 4);
            }
            out
        };
        let scales = if self.scales.is_empty() {
            Vec::new()
        } else {
            let gpr = self.groups_per_row();
            self.scales[row0 * gpr..(row0 + nrows) * gpr].to_vec()
        };
        PackedMx::from_parts_geom(
            self.geom,
            len,
            self.cols,
            codes,
            scales,
            self.tensor_scale,
            self.levels,
        )
    }

    /// The 4-bit level code of flat element `i`.
    #[inline]
    pub fn code(&self, i: usize) -> u8 {
        (self.codes[i / 2] >> ((i % 2) * 4)) & 0x0F
    }

    /// Level value of a code.
    #[inline]
    pub fn level(&self, code: u8) -> f32 {
        self.levels[code as usize]
    }

    /// Raw scale byte of group `g` (encoding per [`Self::geom`]).
    #[inline]
    pub fn scale_byte(&self, g: usize) -> u8 {
        self.scales[g]
    }

    /// Shared-scale exponent of group `g`. Only meaningful for E8M0
    /// geometries (the SIMD fused kernel reads it); E4M3 scales are not
    /// powers of two.
    #[inline]
    pub fn group_scale_exp(&self, g: usize) -> i32 {
        debug_assert_eq!(self.geom.scale_enc(), ScaleEnc::E8m0);
        self.scales[g] as i32 - E8M0_BIAS
    }

    /// Shared scale of group `g` (or the per-tensor scale), decoded per
    /// the geometry's scale encoding.
    #[inline]
    pub fn group_scale(&self, g: usize) -> f32 {
        if self.scales.is_empty() {
            self.tensor_scale
        } else {
            self.geom.decode_scale(self.scales[g])
        }
    }

    /// Group index of flat element `i`.
    #[inline]
    pub fn group_of(&self, i: usize) -> usize {
        if self.scales.is_empty() {
            return 0;
        }
        let row = i / self.cols;
        let col = i % self.cols;
        row * self.groups_per_row() + col / self.geom.group_size()
    }

    /// Dequantized value of flat element `i` (random access; use
    /// [`dequantize_into`](Self::dequantize_into) for bulk decode).
    #[inline]
    pub fn value(&self, i: usize) -> f32 {
        self.level(self.code(i)) * self.group_scale(self.group_of(i))
    }

    /// The byte slice covering codes of flat range `[a, b)`. Boundary
    /// bytes may include a neighboring element's nibble, so equality of
    /// these slices implies (but is not implied by) equality of the
    /// range's codes — a conservative fast path for flip scans.
    #[inline]
    pub fn code_bytes(&self, a: usize, b: usize) -> &[u8] {
        &self.codes[a / 2..(b + 1) / 2]
    }

    /// Start a grouped MX-geometry tensor: zeroed codes, scales to be
    /// pushed row-major via [`push_group_scale`](Self::push_group_scale).
    pub(crate) fn begin_grouped(&mut self, len: usize, cols: usize, levels: &'static [f32]) {
        self.reset(len, cols, levels, GroupGeom::mx());
    }

    /// Start a grouped tensor at an explicit geometry (NVFP4 etc.);
    /// scales are pushed row-major via
    /// [`push_group_scale_byte`](Self::push_group_scale_byte).
    pub(crate) fn begin_grouped_geom(
        &mut self,
        len: usize,
        cols: usize,
        levels: &'static [f32],
        geom: GroupGeom,
    ) {
        self.reset(len, cols, levels, geom);
    }

    /// Start a per-tensor-scaled (INT4) tensor.
    pub(crate) fn begin_per_tensor(
        &mut self,
        len: usize,
        cols: usize,
        levels: &'static [f32],
        scale: f32,
    ) {
        self.reset(len, cols, levels, GroupGeom::mx());
        self.tensor_scale = scale;
    }

    fn reset(&mut self, len: usize, cols: usize, levels: &'static [f32], geom: GroupGeom) {
        self.codes.clear();
        self.codes.resize((len + 1) / 2, 0);
        self.scales.clear();
        self.tensor_scale = 1.0;
        self.levels = levels;
        self.geom = geom;
        self.len = len;
        self.cols = cols;
    }

    /// Push an E8M0 scale exponent (MX encode path).
    pub(crate) fn push_group_scale(&mut self, s: i32) {
        debug_assert_eq!(self.geom.scale_enc(), ScaleEnc::E8m0);
        debug_assert!((-E8M0_BIAS..=E8M0_BIAS).contains(&s));
        self.scales.push((s + E8M0_BIAS) as u8);
    }

    /// Push an already-encoded scale byte (geometry-generic encode
    /// path, e.g. NVFP4's E4M3 bytes).
    pub(crate) fn push_group_scale_byte(&mut self, b: u8) {
        debug_assert!(self.geom.scale_byte_valid(b), "scale byte {b:#04x}");
        self.scales.push(b);
    }

    #[inline]
    pub(crate) fn set_code(&mut self, i: usize, c: u8) {
        debug_assert!(c < 16);
        let b = &mut self.codes[i / 2];
        if i % 2 == 0 {
            *b = (*b & 0xF0) | c;
        } else {
            *b = (*b & 0x0F) | (c << 4);
        }
    }

    /// Iterate `(group_index, flat_start, flat_end)` over this tensor's
    /// groups in storage order (delegates to the shared
    /// [`group_ranges`] layout definition at this tensor's group size).
    #[inline]
    pub fn for_each_group<F: FnMut(usize, usize, usize)>(&self, f: F) {
        group_ranges(self.len, self.cols, self.geom.group_size(), f);
    }

    /// Bulk decode into a caller-owned buffer; bit-exact to the
    /// producing quantizer's fake-quant output.
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        if self.scales.is_empty() {
            for (i, o) in out.iter_mut().enumerate() {
                *o = self.level(self.code(i)) * self.tensor_scale;
            }
            return;
        }
        self.for_each_group(|g, a, b| {
            let scale = self.group_scale(g);
            for i in a..b {
                out[i] = self.level(self.code(i)) * scale;
            }
        });
    }

    /// Allocating decode.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.len];
        self.dequantize_into(&mut out);
        out
    }

    /// Count elements whose dequantized value differs from `prev`'s —
    /// the flip count of the step `prev -> self`. Groups with an
    /// unchanged scale byte compare codes (a 16-byte memcmp per full
    /// group when nothing flipped); groups whose scale moved compare
    /// dequantized values, which keeps the count exactly equal to an
    /// f32-mirror comparison even when a scale shift renumbers codes.
    pub fn flip_count(&self, prev: &PackedMx) -> usize {
        assert_eq!(self.len, prev.len);
        assert_eq!(self.cols, prev.cols);
        assert_eq!(self.geom, prev.geom, "flip_count across geometries");
        let mut flips = 0usize;
        if self.scales.is_empty() || prev.scales.is_empty() {
            for i in 0..self.len {
                if self.value(i) != prev.value(i) {
                    flips += 1;
                }
            }
            return flips;
        }
        self.for_each_group(|g, a, b| {
            flips += self.group_flips(prev, g, a, b, |_, _| ());
        });
        flips
    }

    /// Shared group-scan core for flip counting: returns the number of
    /// flips in flat range `[a, b)` of group `g` and invokes
    /// `on_flip(i, |delta|)` for each flipped element. The
    /// equal-scale-byte fast path is only trusted below the overflow
    /// threshold where code equality is equivalent to value equality.
    #[inline]
    pub(crate) fn group_flips<F: FnMut(usize, f32)>(
        &self,
        prev: &PackedMx,
        g: usize,
        a: usize,
        b: usize,
        mut on_flip: F,
    ) -> usize {
        let sb = self.scale_byte(g);
        // Equal scale bytes make code equality equivalent to value
        // equality only when `level * scale` cannot overflow: E8M0
        // scales reach 2^127, so cap the byte; E4M3 tops out at 448,
        // where no finite level can overflow, so equality always holds.
        let exact_codes = sb == prev.scale_byte(g)
            && match self.geom.scale_enc() {
                ScaleEnc::E8m0 => sb <= CODE_CMP_MAX_SCALE_BYTE,
                ScaleEnc::E4m3 => true,
            };
        if exact_codes && self.code_bytes(a, b) == prev.code_bytes(a, b) {
            return 0;
        }
        let (sa, sp) = (self.group_scale(g), prev.group_scale(g));
        let mut flips = 0;
        for i in a..b {
            let (ca, cp) = (self.code(i), prev.code(i));
            if exact_codes && ca == cp {
                continue;
            }
            let va = self.level(ca) * sa;
            let vp = prev.level(cp) * sp;
            if va != vp {
                flips += 1;
                on_flip(i, (va - vp).abs());
            }
        }
        flips
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::formats::{e2m1, e3m0, Scaling};
    use crate::quant::int4::{int4_quantize, Int4Quantizer};
    use crate::quant::mx::{mx_quantize_cols, MxQuantizer};
    use crate::quant::qema::{qema_quantize_cols, QemaQuantizer};

    fn sample(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i * 37) % 113) as f32 / 9.0 - 6.0).collect()
    }

    #[test]
    fn roundtrip_matches_fake_quant_all_formats_and_scalings() {
        for fmt in [e2m1(), e3m0()] {
            for scaling in [Scaling::TruncationFree, Scaling::Floor] {
                // Ragged tail: 48 cols -> 32 + 16 per row.
                for cols in [32usize, 48, 64] {
                    let x = sample(cols * 3);
                    let q = MxQuantizer { fmt, scaling };
                    let mut p = PackedMx::default();
                    q.quantize_packed(&x, cols, &mut p);
                    let want = mx_quantize_cols(&x, cols, fmt, scaling);
                    assert_eq!(
                        p.dequantize(),
                        want,
                        "fmt={} scaling={scaling:?} cols={cols}",
                        fmt.name
                    );
                    // Trait-default dequantize is the same decode.
                    let mut out = vec![0.0; x.len()];
                    q.dequantize(&p, &mut out);
                    assert_eq!(out, want);
                }
            }
        }
    }

    #[test]
    fn all_zero_group_roundtrips() {
        let mut x = vec![0.0f32; 64];
        x[40] = 3.0; // second group non-zero, first all-zero
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&x, 64, &mut p);
        assert_eq!(p.dequantize(), mx_quantize_cols(&x, 64, e2m1(), Scaling::TruncationFree));
        assert!(p.dequantize()[..32].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn qema_roundtrip_matches_fake_quant() {
        let w = sample(96);
        let ema: Vec<f32> = w.iter().map(|&v| v * 0.9 + 0.03).collect();
        let fmt = e2m1();
        let q = QemaQuantizer { fmt, scaling: Scaling::TruncationFree, ema: &ema };
        let mut p = PackedMx::default();
        q.quantize_packed(&w, 48, &mut p);
        assert_eq!(
            p.dequantize(),
            qema_quantize_cols(&w, &ema, 48, fmt, Scaling::TruncationFree)
        );
    }

    #[test]
    fn int4_roundtrip_matches_fake_quant() {
        let x = sample(37);
        let mut p = PackedMx::default();
        Int4Quantizer.quantize_packed(&x, 37, &mut p);
        let want = int4_quantize(&x, None);
        let got = p.dequantize();
        assert_eq!(got.len(), want.len());
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            // modulo -0.0 == 0.0 (sign of zero is not representable in codes)
            assert!(g == w, "i={i}: {g:?} != {w:?}");
        }
        assert_eq!(p.num_groups(), 0, "int4 is per-tensor scaled");
    }

    #[test]
    fn packed_layout_and_footprint() {
        let x = sample(96);
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&x, 48, &mut p);
        assert_eq!(p.len(), 96);
        assert_eq!(p.cols(), 48);
        assert_eq!(p.groups_per_row(), 2);
        assert_eq!(p.num_groups(), 4);
        // 48 code bytes + 4 scale bytes vs 384 f32 bytes.
        assert_eq!(p.bytes(), 96 / 2 + 4);
        for i in 0..p.len() {
            assert!(p.code(i) < 15, "4-bit level index");
            assert_eq!(p.value(i), p.dequantize()[i]);
        }
    }

    #[test]
    fn scale_bytes_are_biased_exponents() {
        let mut x = vec![0.0f32; 32];
        x[0] = 6.0; // max 6 with Qp 6 -> scale exponent 0
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&x, 32, &mut p);
        assert_eq!(p.scale_byte(0), E8M0_BIAS as u8);
        assert_eq!(p.group_scale_exp(0), 0);
        assert_eq!(p.group_scale(0), 1.0);
    }

    #[test]
    fn flip_count_matches_value_compare() {
        let x = sample(128);
        // Perturb a few elements across grid thresholds.
        let mut y = x.clone();
        for i in (0..128).step_by(11) {
            y[i] = y[i] * 1.3 + 0.21;
        }
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let (mut pa, mut pb) = (PackedMx::default(), PackedMx::default());
        q.quantize_packed(&x, 64, &mut pa);
        q.quantize_packed(&y, 64, &mut pb);
        let (da, db) = (pa.dequantize(), pb.dequantize());
        let want = da.iter().zip(&db).filter(|(a, b)| a != b).count();
        assert_eq!(pb.flip_count(&pa), want);
        assert_eq!(pa.flip_count(&pa), 0);
    }

    #[test]
    fn from_parts_roundtrips_serialized_tensor() {
        let x = sample(96);
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&x, 48, &mut p);
        let id = level_table_id(p.levels()).expect("e2m1 table registered");
        let back = PackedMx::from_parts(
            p.len(),
            p.cols(),
            p.codes().to_vec(),
            p.scale_bytes().to_vec(),
            p.tensor_scale(),
            level_table_from_id(id).unwrap(),
        )
        .unwrap();
        assert_eq!(back.dequantize(), p.dequantize());
        assert_eq!(back.flip_count(&p), 0);
        // Geometry mismatches are rejected.
        let lv = &e2m1().levels;
        assert!(PackedMx::from_parts(96, 48, vec![0; 3], Vec::new(), 1.0, lv).is_err());
        assert!(PackedMx::from_parts(96, 48, vec![0; 48], vec![0; 3], 1.0, lv).is_err());
        assert!(PackedMx::from_parts(95, 48, vec![0; 48], vec![0; 4], 1.0, lv).is_err());
    }

    #[test]
    fn from_parts_rejects_codes_past_level_table() {
        // Every registered table has 15 entries (7 negatives + zero +
        // 7 positives, or INT4's 15 grid points), so nibble 15 is
        // unmapped; a corrupt checkpoint must fail at load, not panic
        // in a kernel.
        let iv = &crate::quant::int4::INT4_LEVELS[..];
        assert!(PackedMx::from_parts(4, 4, vec![0x00, 0x0F], Vec::new(), 1.0, iv).is_err());
        assert!(PackedMx::from_parts(4, 4, vec![0x00, 0xF0], Vec::new(), 1.0, iv).is_err());
        // The pad nibble of an odd-length tensor is exempt.
        assert!(PackedMx::from_parts(3, 3, vec![0x00, 0xF0], Vec::new(), 1.0, iv).is_ok());
        // e2m1's table is 15 entries too: code 14 is the top level,
        // nibble 15 is invalid.
        assert_eq!(e2m1().levels.len(), 15);
        assert!(PackedMx::from_parts(4, 4, vec![0xEE, 0xEE], Vec::new(), 1.0, &e2m1().levels)
            .is_ok());
        assert!(PackedMx::from_parts(4, 4, vec![0xFF, 0xFF], Vec::new(), 1.0, &e2m1().levels)
            .is_err());
    }

    #[test]
    fn from_parts_rejects_invalid_scale_bytes() {
        let lv = &e2m1().levels[..];
        // E8M0: byte 255 is the NaN encoding — the SIMD path already
        // treats it as ineligible; loading it must fail, not serve
        // NaN-scaled garbage.
        assert!(PackedMx::from_parts(32, 32, vec![0; 16], vec![255], 1.0, lv).is_err());
        assert!(PackedMx::from_parts(32, 32, vec![0; 16], vec![254], 1.0, lv).is_ok());
        // E4M3 (NVFP4 geometry): NaN byte 0x7F and sign-bit bytes are
        // invalid scales.
        let nv = GroupGeom::nvfp4();
        for bad in [0x7Fu8, 0x80, 0xFF] {
            assert!(
                PackedMx::from_parts_geom(nv, 16, 16, vec![0; 8], vec![bad], 1.0, lv).is_err(),
                "E4M3 scale byte {bad:#04x} accepted"
            );
        }
        assert!(PackedMx::from_parts_geom(nv, 16, 16, vec![0; 8], vec![0x7E], 1.0, lv).is_ok());
    }

    #[test]
    fn from_parts_geom_roundtrips_nvfp4_geometry() {
        // 3 rows x 24 cols at group size 16 -> 2 groups/row (16 + 8
        // ragged tail), 6 scale bytes.
        let nv = GroupGeom::nvfp4();
        let codes: Vec<u8> = (0..36).map(|i| ((i * 7) % 15) as u8 | ((((i * 11) % 15) as u8) << 4)).collect();
        let scales: Vec<u8> = (0..6).map(|g| 0x30 + g as u8).collect();
        let p = PackedMx::from_parts_geom(nv, 72, 24, codes, scales, 1.0, &e2m1().levels)
            .unwrap();
        assert_eq!(p.geom(), nv);
        assert_eq!(p.groups_per_row(), 2);
        assert_eq!(p.num_groups(), 6);
        // group_of honors the 16-element group size.
        assert_eq!(p.group_of(0), 0);
        assert_eq!(p.group_of(15), 0);
        assert_eq!(p.group_of(16), 1);
        assert_eq!(p.group_of(24), 2, "second row starts a new group");
        // Scales decode through E4M3, not E8M0.
        use crate::quant::formats::e4m3_decode;
        for g in 0..6 {
            assert_eq!(p.group_scale(g), e4m3_decode(p.scale_byte(g)));
        }
        // Dequant agrees with the random-access view everywhere.
        let d = p.dequantize();
        for i in 0..p.len() {
            assert_eq!(d[i], p.value(i));
        }
        // Wrong scale count for the geometry is rejected (6 groups at
        // gs16, but only 3 at gs32).
        assert!(PackedMx::from_parts_geom(
            nv,
            72,
            24,
            vec![0; 36],
            vec![0x30; 3],
            1.0,
            &e2m1().levels
        )
        .is_err());
    }

    #[test]
    fn slice_rows_nvfp4_odd_nibble_and_ragged_tail() {
        // Group size 16 doubles odd-offset incidence: cols 21 makes
        // every odd row0 start mid-byte, and each row carries a ragged
        // 5-element tail group (21 = 16 + 5).
        let nv = GroupGeom::nvfp4();
        let (rows, cols) = (5usize, 21usize);
        let len = rows * cols;
        let codes: Vec<u8> =
            (0..(len + 1) / 2).map(|i| ((i * 3) % 15) as u8 | ((((i * 5) % 15) as u8) << 4)).collect();
        let gpr = nv.groups_per_row(cols);
        assert_eq!(gpr, 2);
        let scales: Vec<u8> = (0..rows * gpr).map(|g| 0x20 + (g as u8) * 3).collect();
        let p =
            PackedMx::from_parts_geom(nv, len, cols, codes, scales, 1.0, &e2m1().levels).unwrap();
        let full = p.dequantize();
        for (r0, nr) in [(0usize, 2usize), (1, 3), (2, 2), (3, 1), (4, 1), (0, 5), (2, 0)] {
            let s = p.slice_rows(r0, nr).unwrap();
            assert_eq!(s.geom(), nv, "slice keeps the geometry");
            assert_eq!(s.groups_per_row(), gpr);
            assert_eq!(
                s.dequantize(),
                full[r0 * cols..(r0 + nr) * cols].to_vec(),
                "r0={r0} nr={nr}"
            );
            // Scale bytes of the slice are the original rows' bytes.
            assert_eq!(s.scale_bytes(), &p.scale_bytes()[r0 * gpr..(r0 + nr) * gpr]);
        }
        assert!(p.slice_rows(4, 2).is_err());
    }

    #[test]
    fn e4m3_flip_fast_path_is_exact_at_max_scale() {
        // At the E4M3 max scale (448) equal codes always mean equal
        // values — no overflow collapse like E8M0's 2^127 scales — so
        // the memcmp fast path must report zero flips.
        let nv = GroupGeom::nvfp4();
        let codes = vec![0x21u8; 8];
        let p = PackedMx::from_parts_geom(nv, 16, 16, codes.clone(), vec![0x7E], 1.0, &e2m1().levels)
            .unwrap();
        let q = PackedMx::from_parts_geom(nv, 16, 16, codes, vec![0x7E], 1.0, &e2m1().levels)
            .unwrap();
        assert_eq!(p.flip_count(&q), 0);
        // And a genuinely different code at the same scale is counted.
        let mut codes2 = vec![0x21u8; 8];
        codes2[3] = 0x25;
        let r = PackedMx::from_parts_geom(nv, 16, 16, codes2, vec![0x7E], 1.0, &e2m1().levels)
            .unwrap();
        assert_eq!(r.flip_count(&p), 1);
    }

    #[test]
    fn level_table_ids_cover_all_formats() {
        use crate::quant::int4::INT4_LEVELS;
        assert_eq!(level_table_id(&e2m1().levels), Some(0));
        assert_eq!(level_table_id(&e3m0().levels), Some(1));
        assert_eq!(level_table_id(&INT4_LEVELS), Some(2));
        assert_eq!(level_table_id(&[1.0, 2.0]), None);
        for id in 0..3u8 {
            assert_eq!(level_table_id(level_table_from_id(id).unwrap()), Some(id));
        }
        assert!(level_table_from_id(9).is_none());
    }

    #[test]
    fn slice_rows_preserves_values_even_and_odd_alignment() {
        // cols 57 is odd, so any odd row0 starts mid-byte and exercises
        // the nibble repack path; cols 32 stays byte-aligned.
        for cols in [32usize, 57] {
            let rows = 5;
            let x = sample(rows * cols);
            let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
            let mut p = PackedMx::default();
            q.quantize_packed(&x, cols, &mut p);
            let full = p.dequantize();
            for (r0, nr) in [(0usize, 2usize), (1, 3), (3, 2), (2, 0), (0, 5)] {
                let s = p.slice_rows(r0, nr).unwrap();
                assert_eq!(s.len(), nr * cols, "cols={cols} r0={r0} nr={nr}");
                assert_eq!(s.cols(), cols);
                assert_eq!(s.levels(), p.levels());
                assert_eq!(s.dequantize(), full[r0 * cols..(r0 + nr) * cols].to_vec());
            }
            assert!(p.slice_rows(4, 2).is_err(), "out-of-range rows rejected");
        }
    }

    #[test]
    fn slice_rows_per_tensor_keeps_scale() {
        let x = sample(6 * 37);
        let mut p = PackedMx::default();
        Int4Quantizer.quantize_packed(&x, 37, &mut p);
        assert_eq!(p.num_groups(), 0, "per-tensor mode");
        let s = p.slice_rows(1, 4).unwrap();
        assert_eq!(s.tensor_scale(), p.tensor_scale());
        assert_eq!(s.num_groups(), 0);
        assert_eq!(s.dequantize(), p.dequantize()[37..5 * 37].to_vec());
    }

    #[test]
    fn flip_count_exact_across_scale_shift() {
        // Doubling every element doubles the group scale but keeps all
        // codes identical: every non-zero element flips, zeros don't.
        let x = sample(64);
        let y: Vec<f32> = x.iter().map(|&v| v * 2.0).collect();
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let (mut pa, mut pb) = (PackedMx::default(), PackedMx::default());
        q.quantize_packed(&x, 32, &mut pa);
        q.quantize_packed(&y, 32, &mut pb);
        for i in 0..64 {
            assert_eq!(pa.code(i), pb.code(i), "codes invariant under x2");
        }
        let nonzero = pa.dequantize().iter().filter(|&&v| v != 0.0).count();
        assert_eq!(pb.flip_count(&pa), nonzero);
    }
}
