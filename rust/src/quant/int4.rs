//! Per-tensor symmetric INT4 fake quantization (baseline; ref.int4_quantize_ref).
//!
//! [`Int4Quantizer`] adapts the baseline to the
//! [`Quantizer`](super::packed::Quantizer) trait; its packed form uses
//! the same nibble codes as the MX formats (level index into
//! [`INT4_LEVELS`], zero at code 7) with a single per-tensor f32 scale
//! instead of per-group E8M0 bytes.

use super::packed::{PackedMx, Quantizer};

pub const INT4_QMAX: f32 = 7.0;

/// Symmetric INT4 grid -7..=7; code = level + 7.
pub static INT4_LEVELS: [f32; 15] = [
    -7.0, -6.0, -5.0, -4.0, -3.0, -2.0, -1.0, 0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0,
];

#[inline]
fn tensor_scale(x: &[f32]) -> f32 {
    let m = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if m == 0.0 {
        1.0
    } else {
        m / INT4_QMAX
    }
}

/// round half away from zero (ref: sign(y)*floor(|y|+0.5)), clamped.
#[inline]
fn round_half_away(y: f32) -> f32 {
    (y.abs() + 0.5).floor().copysign(y).clamp(-INT4_QMAX, INT4_QMAX)
}

/// Deterministic (u = None) or stochastic INT4 fake quantization into a
/// caller-owned buffer (no allocation on the per-step metric path).
pub fn int4_quantize_into(x: &[f32], u: Option<&[f32]>, out: &mut [f32]) {
    assert_eq!(out.len(), x.len());
    let scale = tensor_scale(x);
    let inv = 1.0 / scale;
    match u {
        None => {
            for (o, &v) in out.iter_mut().zip(x) {
                *o = round_half_away(v * inv) * scale;
            }
        }
        Some(u) => {
            assert_eq!(u.len(), x.len());
            for ((o, &v), &uu) in out.iter_mut().zip(x).zip(u) {
                let y = v * inv;
                let lo = y.floor();
                let q = if (y - lo) > uu { lo + 1.0 } else { lo };
                *o = q.clamp(-INT4_QMAX, INT4_QMAX) * scale;
            }
        }
    }
}

/// Deterministic (u = None) or stochastic INT4 fake quantization,
/// allocating variant.
pub fn int4_quantize(x: &[f32], u: Option<&[f32]>) -> Vec<f32> {
    let mut out = vec![0.0; x.len()];
    int4_quantize_into(x, u, &mut out);
    out
}

/// Deterministic INT4 baseline as a [`Quantizer`]. `cols` is carried
/// for shape bookkeeping only; scaling is per tensor.
#[derive(Debug, Clone, Copy)]
pub struct Int4Quantizer;

impl Quantizer for Int4Quantizer {
    fn name(&self) -> &'static str {
        "int4"
    }

    fn quantize_f32(&self, x: &[f32], _cols: usize, out: &mut [f32]) {
        int4_quantize_into(x, None, out);
    }

    fn quantize_packed(&self, x: &[f32], cols: usize, out: &mut PackedMx) {
        let scale = tensor_scale(x);
        out.begin_per_tensor(x.len(), cols, &INT4_LEVELS, scale);
        let inv = 1.0 / scale;
        for (i, &v) in x.iter().enumerate() {
            // q is integral in [-7, 7]; +7 is the INT4_LEVELS index.
            // (-0.0 + 7.0 == 7.0, so signed zeros collapse to code 7.)
            out.set_code(i, (round_half_away(v * inv) + INT4_QMAX) as u8);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn det_round_half_away_from_zero() {
        // max 7 -> scale 1: values round on the integer grid.
        let x = vec![7.0, 3.5, -3.5, 2.4, -2.4, 0.0, 6.9];
        let q = int4_quantize(&x, None);
        assert_eq!(q, vec![7.0, 4.0, -4.0, 2.0, -2.0, 0.0, 7.0]);
    }

    #[test]
    fn scale_from_tensor_max() {
        let x = vec![14.0, 7.0, -14.0, 3.0];
        let q = int4_quantize(&x, None);
        // scale = 2
        assert_eq!(q, vec![14.0, 8.0, -14.0, 4.0]);
    }

    #[test]
    fn zero_tensor() {
        assert_eq!(int4_quantize(&[0.0, 0.0], None), vec![0.0, 0.0]);
    }

    #[test]
    fn stochastic_brackets() {
        let x = vec![7.0, 2.5, 2.5];
        let q = int4_quantize(&x, Some(&[0.5, 0.9, 0.1]));
        // 2.5: frac 0.5 > 0.9? no -> 2; > 0.1? yes -> 3.
        assert_eq!(q, vec![7.0, 2.0, 3.0]);
    }

    #[test]
    fn into_variant_matches() {
        let x: Vec<f32> = (0..33).map(|i| (i as f32 * 0.37).sin() * 9.0).collect();
        let u: Vec<f32> = (0..33).map(|i| ((i * 7) % 13) as f32 / 13.0).collect();
        for uu in [None, Some(&u[..])] {
            let a = int4_quantize(&x, uu);
            let mut b = vec![0.0; x.len()];
            int4_quantize_into(&x, uu, &mut b);
            assert_eq!(a, b);
        }
    }
}
