//! Per-tensor symmetric INT4 fake quantization (baseline; ref.int4_quantize_ref).

pub const INT4_QMAX: f32 = 7.0;

/// Deterministic (u = None) or stochastic INT4 fake quantization.
pub fn int4_quantize(x: &[f32], u: Option<&[f32]>) -> Vec<f32> {
    let m = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = if m == 0.0 { 1.0 } else { m / INT4_QMAX };
    let inv = 1.0 / scale;
    match u {
        None => x
            .iter()
            .map(|&v| {
                let y = v * inv;
                // round half away from zero (ref: sign(y)*floor(|y|+0.5))
                let q = (y.abs() + 0.5).floor().copysign(y);
                q.clamp(-INT4_QMAX, INT4_QMAX) * scale
            })
            .collect(),
        Some(u) => {
            assert_eq!(u.len(), x.len());
            x.iter()
                .zip(u)
                .map(|(&v, &uu)| {
                    let y = v * inv;
                    let lo = y.floor();
                    let q = if (y - lo) > uu { lo + 1.0 } else { lo };
                    q.clamp(-INT4_QMAX, INT4_QMAX) * scale
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn det_round_half_away_from_zero() {
        // max 7 -> scale 1: values round on the integer grid.
        let x = vec![7.0, 3.5, -3.5, 2.4, -2.4, 0.0, 6.9];
        let q = int4_quantize(&x, None);
        assert_eq!(q, vec![7.0, 4.0, -4.0, 2.0, -2.0, 0.0, 7.0]);
    }

    #[test]
    fn scale_from_tensor_max() {
        let x = vec![14.0, 7.0, -14.0, 3.0];
        let q = int4_quantize(&x, None);
        // scale = 2
        assert_eq!(q, vec![14.0, 8.0, -14.0, 4.0]);
    }

    #[test]
    fn zero_tensor() {
        assert_eq!(int4_quantize(&[0.0, 0.0], None), vec![0.0, 0.0]);
    }

    #[test]
    fn stochastic_brackets() {
        let x = vec![7.0, 2.5, 2.5];
        let q = int4_quantize(&x, Some(&[0.5, 0.9, 0.1]));
        // 2.5: frac 0.5 > 0.9? no -> 2; > 0.1? yes -> 3.
        assert_eq!(q, vec![7.0, 2.0, 3.0]);
    }
}
