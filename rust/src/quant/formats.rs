//! FP4 format tables + exact binary helpers (mirror of formats.py),
//! plus the group geometry ([`GroupGeom`]) the packed substrate is
//! parameterized over: MX (32-element groups, E8M0 power-of-two scale
//! bytes) and NVFP4 (16-element groups, E4M3 scale bytes).

use std::sync::OnceLock;

use anyhow::{bail, Result};

/// MX group size (1x32 / 32x1) — the default [`GroupGeom`].
pub const GROUP: usize = 32;

/// NVFP4 group size (TetraJet-v2 recipe).
pub const NVFP4_GROUP: usize = 16;

pub const SCALE_EXP_MIN: i32 = -127;
pub const SCALE_EXP_MAX: i32 = 127;

/// Epsilon substituted for an all-zero group's max (paper §3.2).
pub const ZERO_GROUP_EPS: f32 = 1e-8;

/// Shared-scale computation rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scaling {
    /// TetraJet truncation-free: s = ceil(log2(M / Qp)).
    TruncationFree,
    /// Microscaling: s = floor(log2(M)) - Emax (values may truncate).
    Floor,
}

impl Scaling {
    pub fn parse(s: &str) -> Option<Scaling> {
        match s {
            "tf" => Some(Scaling::TruncationFree),
            "floor" => Some(Scaling::Floor),
            _ => None,
        }
    }
}

/// One FP4 format: full representable grid plus closed-form parameters.
#[derive(Debug, Clone)]
pub struct Fp4Format {
    pub name: &'static str,
    pub levels: Vec<f32>,
    pub boundaries: Vec<f32>,
    /// MaxDist(level): max possible distance to the nearest threshold
    /// among latents quantizing to this level (paper §4.2).
    pub maxdist: Vec<f32>,
    pub emax: i32,
    pub mbits: i32,
    pub delta_min: f32,
}

impl Fp4Format {
    fn new(name: &'static str, pos: &[f32], emax: i32, mbits: i32, delta_min: f32) -> Fp4Format {
        let mut levels: Vec<f32> = pos.iter().rev().map(|v| -v).collect();
        levels.push(0.0);
        levels.extend_from_slice(pos);
        let boundaries: Vec<f32> = levels
            .windows(2)
            .map(|w| (w[0] + w[1]) / 2.0)
            .collect();
        let n = levels.len();
        let mut maxdist = vec![0.0f32; n];
        for j in 0..n {
            maxdist[j] = if j == 0 {
                (levels[0] - boundaries[0]).abs()
            } else if j == n - 1 {
                (levels[n - 1] - boundaries[n - 2]).abs()
            } else {
                (boundaries[j] - boundaries[j - 1]) / 2.0
            };
        }
        Fp4Format { name, levels, boundaries, maxdist, emax, mbits, delta_min }
    }

    #[inline]
    pub fn qp(&self) -> f32 {
        *self.levels.last().unwrap()
    }

    #[inline]
    pub fn qn(&self) -> f32 {
        self.levels[0]
    }

    /// Index of the level a latent deterministically rounds to.
    /// Boundaries are sorted and the predicate `y >= b` is monotone, so
    /// the filter-count is a partition point (binary search).
    #[inline]
    pub fn level_index(&self, y: f32) -> usize {
        self.boundaries.partition_point(|&b| y >= b)
    }
}

/// E2M1: positives 0.5, 1, 1.5, 2, 3, 4, 6 (Qp = 6).
pub fn e2m1() -> &'static Fp4Format {
    static F: OnceLock<Fp4Format> = OnceLock::new();
    F.get_or_init(|| Fp4Format::new("e2m1", &[0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], 2, 1, 0.5))
}

/// E3M0: positives 0.25 .. 16 (powers of two; Qp = 16).
pub fn e3m0() -> &'static Fp4Format {
    static F: OnceLock<Fp4Format> = OnceLock::new();
    F.get_or_init(|| Fp4Format::new("e3m0", &[0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0], 4, 0, 0.25))
}

pub fn fp4_format(name: &str) -> Option<&'static Fp4Format> {
    match name {
        "e2m1" => Some(e2m1()),
        "e3m0" => Some(e3m0()),
        _ => None,
    }
}

/// frexp: x = m * 2^e with m in [0.5, 1) for finite x > 0. Exact (bit
/// manipulation), matching XLA's decomposition of jnp.frexp.
#[inline]
pub fn frexp(x: f32) -> (f32, i32) {
    if x == 0.0 {
        return (0.0, 0);
    }
    let bits = x.to_bits();
    let exp = ((bits >> 23) & 0xff) as i32;
    if exp == 0 {
        // Subnormal: renormalize by an exact power of two.
        let (m, e) = frexp(x * f32::from_bits(((64 + 127) as u32) << 23));
        return (m, e - 64);
    }
    let m = f32::from_bits((bits & 0x807f_ffff) | (126 << 23));
    (m, exp - 126)
}

/// Exact 2^s for s in [-149, 127].
#[inline]
pub fn exp2i(s: i32) -> f32 {
    debug_assert!((-149..=127).contains(&s));
    if s >= -126 {
        f32::from_bits(((s + 127) as u32) << 23)
    } else {
        // Subnormal result.
        f32::from_bits(1u32 << (s + 149) as u32)
    }
}

/// Exact decode of an E4M3 (FP8, bias 7) byte. Subnormals (`exp == 0`)
/// decode as `m/8 * 2^-6`; the all-ones mantissa at `exp == 15` is NaN
/// (no infinities in this encoding), everything else is a normal
/// `(1 + m/8) * 2^(exp - 7)` up to the 448 maximum. Every finite E4M3
/// value is exactly representable in f32, so this is the E4M3 analogue
/// of [`exp2i`] for scale-byte decoding.
#[inline]
pub fn e4m3_decode(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = ((b >> 3) & 0x0F) as i32;
    let m = (b & 0x07) as f32;
    if e == 15 && m == 7.0 {
        return f32::NAN;
    }
    let mag = if e == 0 { m * exp2i(-9) } else { (1.0 + m / 8.0) * exp2i(e - 7) };
    sign * mag
}

/// Largest finite E4M3 byte (448.0); `0x7F` is the NaN encoding.
pub const E4M3_MAX_BYTE: u8 = 0x7E;

/// Smallest non-negative E4M3 byte whose decoded value is `>= v`
/// (truncation-free "ceiling" encode for group scales: the encoded
/// scale never undershoots `max/Qp`, so the group max never clips).
/// Saturates at the 448 maximum; exact zero encodes as byte 0.
#[inline]
pub fn e4m3_encode_ceil(v: f32) -> u8 {
    debug_assert!(v >= 0.0 && v.is_finite(), "e4m3_encode_ceil({v})");
    if v <= 0.0 {
        return 0;
    }
    if v >= e4m3_decode(E4M3_MAX_BYTE) {
        return E4M3_MAX_BYTE;
    }
    // Non-negative E4M3 bytes decode monotonically, so the smallest
    // byte with decode >= v is a partition point.
    let (mut lo, mut hi) = (0u8, E4M3_MAX_BYTE);
    while lo < hi {
        let mid = (lo + hi) / 2;
        if e4m3_decode(mid) >= v {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    lo
}

/// Scale-byte encoding of a group geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleEnc {
    /// OCP MX E8M0: `byte = scale_exponent + 127`, power-of-two scales,
    /// byte 255 reserved (NaN).
    E8m0,
    /// FP8 E4M3 scale bytes (NVFP4): non-power-of-two magnitudes up to
    /// 448; sign bit and the NaN encoding are invalid for scales.
    E4m3,
}

impl ScaleEnc {
    pub fn as_str(&self) -> &'static str {
        match self {
            ScaleEnc::E8m0 => "e8m0",
            ScaleEnc::E4m3 => "e4m3",
        }
    }
}

/// Group geometry of a packed tensor: how many elements share one scale
/// byte, and how that byte is encoded. Construction validates
/// `group_size >= 1`, so downstream `groups_per_row` arithmetic can
/// divide by the group size without re-guarding (the old hardcoded
/// `GROUP.max(1)` guard sat uselessly on a constant divisor).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupGeom {
    group_size: usize,
    scale_enc: ScaleEnc,
}

impl Default for GroupGeom {
    fn default() -> GroupGeom {
        GroupGeom::mx()
    }
}

impl GroupGeom {
    /// The source paper's MXFP4 geometry: 32-element groups, E8M0.
    pub const fn mx() -> GroupGeom {
        GroupGeom { group_size: GROUP, scale_enc: ScaleEnc::E8m0 }
    }

    /// TetraJet-v2's NVFP4 geometry: 16-element groups, E4M3.
    pub const fn nvfp4() -> GroupGeom {
        GroupGeom { group_size: NVFP4_GROUP, scale_enc: ScaleEnc::E4m3 }
    }

    /// Arbitrary geometry with the `group_size >= 1` invariant checked
    /// here, once, instead of guarded at every division site.
    pub fn new(group_size: usize, scale_enc: ScaleEnc) -> Result<GroupGeom> {
        if group_size == 0 {
            bail!("group geometry needs group_size >= 1");
        }
        Ok(GroupGeom { group_size, scale_enc })
    }

    #[inline]
    pub fn group_size(&self) -> usize {
        self.group_size
    }

    #[inline]
    pub fn scale_enc(&self) -> ScaleEnc {
        self.scale_enc
    }

    /// Groups per row of a `cols`-wide matrix, ragged tail included.
    /// `group_size >= 1` is a construction invariant, so the division
    /// needs no runtime guard.
    #[inline]
    pub fn groups_per_row(&self, cols: usize) -> usize {
        (cols + self.group_size - 1) / self.group_size
    }

    /// Whether `b` is a valid scale byte under this encoding: E8M0
    /// reserves 255 (NaN); E4M3 scales must be non-negative and finite
    /// (no sign bit, not the NaN encoding).
    #[inline]
    pub fn scale_byte_valid(&self, b: u8) -> bool {
        match self.scale_enc {
            ScaleEnc::E8m0 => b != 255,
            ScaleEnc::E4m3 => b <= E4M3_MAX_BYTE,
        }
    }

    /// Decode a (valid) scale byte to its exact f32 scale.
    #[inline]
    pub fn decode_scale(&self, b: u8) -> f32 {
        match self.scale_enc {
            ScaleEnc::E8m0 => exp2i(b as i32 - 127),
            ScaleEnc::E4m3 => e4m3_decode(b),
        }
    }

    /// Scale byte for a group with max-abs `amax`: E8M0 delegates to the
    /// paper's [`scale_exponent`] rule; E4M3 ceiling-encodes `amax/Qp`
    /// (truncation-free by construction; zero groups encode byte 0).
    #[inline]
    pub fn encode_scale(&self, amax: f32, fmt: &Fp4Format, scaling: Scaling) -> u8 {
        match self.scale_enc {
            ScaleEnc::E8m0 => (scale_exponent(amax, fmt, scaling) + 127) as u8,
            ScaleEnc::E4m3 => {
                if amax == 0.0 {
                    0
                } else {
                    e4m3_encode_ceil(amax / fmt.qp())
                }
            }
        }
    }

    /// Stable on-disk identifier for the checkpoint geometry byte
    /// (TJCKPT02 packed sections). Only registered geometries serialize.
    pub fn id(&self) -> Option<u8> {
        if *self == GroupGeom::mx() {
            Some(0)
        } else if *self == GroupGeom::nvfp4() {
            Some(1)
        } else {
            None
        }
    }

    /// Inverse of [`GroupGeom::id`].
    pub fn from_id(id: u8) -> Option<GroupGeom> {
        match id {
            0 => Some(GroupGeom::mx()),
            1 => Some(GroupGeom::nvfp4()),
            _ => None,
        }
    }
}

/// Shared-scale exponent for a group with max-abs `max_abs` (mirror of
/// ref.scale_exponent).
#[inline]
pub fn scale_exponent(max_abs: f32, fmt: &Fp4Format, scaling: Scaling) -> i32 {
    let m_t = if max_abs == 0.0 { ZERO_GROUP_EPS } else { max_abs };
    let s = match scaling {
        Scaling::TruncationFree => {
            let (m, e) = frexp(m_t / fmt.qp());
            if m == 0.5 {
                e - 1
            } else {
                e
            }
        }
        Scaling::Floor => {
            let (_, e) = frexp(m_t);
            (e - 1) - fmt.emax
        }
    };
    s.clamp(SCALE_EXP_MIN, SCALE_EXP_MAX)
}

/// Grid spacing at magnitude `a` (closed form; see kernels/mxfp4.py).
#[inline]
pub fn grid_spacing_mag(a: f32, fmt: &Fp4Format) -> f32 {
    let (_, e) = frexp(a);
    let delta = exp2i((e - 1 - fmt.mbits).clamp(-149, 127));
    delta.max(fmt.delta_min)
}

/// Deterministic round-to-nearest on the grid, ties toward +inf.
#[inline]
pub fn round_det(y: f32, fmt: &Fp4Format) -> f32 {
    let delta = grid_spacing_mag(y.abs(), fmt);
    (y / delta + 0.5).floor() * delta
}

/// Gap between a grid `level` and the next level above it.
#[inline]
pub fn spacing_above(level: f32, fmt: &Fp4Format) -> f32 {
    let a = level.abs();
    if a == 0.0 {
        return fmt.delta_min;
    }
    let (m, e) = frexp(a);
    let mut delta = exp2i((e - 1 - fmt.mbits).clamp(-149, 127));
    if level < 0.0 && m == 0.5 {
        delta *= 0.5;
    }
    delta.max(fmt.delta_min)
}

/// Bracketing grid values (q1, q2) with q1 <= y <= q2; q1 clamped to the
/// second-highest level so q2 never exceeds Qp (table-oracle semantics).
#[inline]
pub fn bracket(y: f32, fmt: &Fp4Format) -> (f32, f32) {
    let a = y.abs();
    let delta = grid_spacing_mag(a, fmt);
    let q1 = if y >= 0.0 {
        (a / delta).floor() * delta
    } else {
        -((a / delta).ceil() * delta)
    };
    let q1 = q1.min(fmt.levels[fmt.levels.len() - 2]);
    (q1, q1 + spacing_above(q1, fmt))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frexp_matches_definition() {
        for &x in &[1.0f32, 0.5, 2.0, 3.7, 6.0, 1e-8, 1e30, 1.5e-42] {
            let (m, e) = frexp(x);
            assert!((0.5..1.0).contains(&m), "m={m} for x={x}");
            assert_eq!(m * exp2i(e.clamp(-149, 127)), x, "x={x}");
        }
        assert_eq!(frexp(0.0), (0.0, 0));
    }

    #[test]
    fn exp2i_exact() {
        assert_eq!(exp2i(0), 1.0);
        assert_eq!(exp2i(3), 8.0);
        assert_eq!(exp2i(-3), 0.125);
        assert_eq!(exp2i(-127), f32::from_bits(1 << 22));
        assert_eq!(exp2i(127), 2.0f32.powi(127));
    }

    #[test]
    fn paper_scaling_example() {
        // Paper §3.2: M = 31 -> truncation-free S = 8, floor S = 4.
        assert_eq!(scale_exponent(31.0, e2m1(), Scaling::TruncationFree), 3);
        assert_eq!(scale_exponent(31.0, e2m1(), Scaling::Floor), 2);
    }

    #[test]
    fn zero_group_uses_eps() {
        let s = scale_exponent(0.0, e2m1(), Scaling::TruncationFree);
        assert!(s < -20, "eps scale, got {s}");
    }

    #[test]
    fn round_det_against_table() {
        for fmt in [e2m1(), e3m0()] {
            let n = 40013;
            for i in 0..n {
                let y = fmt.qn() + (fmt.qp() - fmt.qn()) * (i as f32 / (n - 1) as f32);
                // Table oracle: boundaries count, ties toward larger.
                let idx = fmt.level_index(y);
                let want = fmt.levels[idx];
                assert_eq!(round_det(y, fmt), want, "y={y} fmt={}", fmt.name);
            }
        }
    }

    #[test]
    fn bracket_against_table() {
        for fmt in [e2m1(), e3m0()] {
            let mut ys: Vec<f32> = (0..40013)
                .map(|i| fmt.qn() + (fmt.qp() - fmt.qn()) * (i as f32 / 40012.0))
                .collect();
            ys.extend_from_slice(&fmt.levels);
            ys.extend_from_slice(&fmt.boundaries);
            for &y in &ys {
                let i = (fmt.levels.iter().filter(|&&l| y >= l).count() as i64 - 1)
                    .clamp(0, fmt.levels.len() as i64 - 2) as usize;
                let (w1, w2) = (fmt.levels[i], fmt.levels[i + 1]);
                let (q1, q2) = bracket(y, fmt);
                assert_eq!((q1, q2), (w1, w2), "y={y} fmt={}", fmt.name);
            }
        }
    }

    #[test]
    fn e4m3_decode_spot_values() {
        assert_eq!(e4m3_decode(0x00), 0.0);
        assert_eq!(e4m3_decode(0x01), 0.001953125); // smallest subnormal 2^-9
        assert_eq!(e4m3_decode(0x07), 7.0 * 0.001953125); // largest subnormal
        assert_eq!(e4m3_decode(0x08), 0.015625); // smallest normal 2^-6
        assert_eq!(e4m3_decode(0x38), 1.0); // exp 7 (bias) mantissa 0
        assert_eq!(e4m3_decode(0x39), 1.125);
        assert_eq!(e4m3_decode(E4M3_MAX_BYTE), 448.0);
        assert!(e4m3_decode(0x7F).is_nan(), "S.1111.111 is NaN");
        assert_eq!(e4m3_decode(0xB8), -1.0, "sign bit negates");
    }

    #[test]
    fn e4m3_positive_bytes_decode_monotonically() {
        for b in 0..E4M3_MAX_BYTE {
            assert!(
                e4m3_decode(b) < e4m3_decode(b + 1),
                "byte {b} not strictly below byte {}",
                b + 1
            );
        }
    }

    #[test]
    fn e4m3_encode_ceil_is_smallest_not_below() {
        // Every grid value encodes to itself...
        for b in 0..=E4M3_MAX_BYTE {
            assert_eq!(e4m3_encode_ceil(e4m3_decode(b)), b);
        }
        // ...and off-grid values round up, never down (truncation-free).
        for b in 0..E4M3_MAX_BYTE {
            let mid = (e4m3_decode(b) + e4m3_decode(b + 1)) / 2.0;
            let got = e4m3_encode_ceil(mid);
            assert_eq!(got, b + 1, "midpoint {mid} must encode upward");
            assert!(e4m3_decode(got) >= mid);
        }
        // Saturation at the max finite value.
        assert_eq!(e4m3_encode_ceil(1e6), E4M3_MAX_BYTE);
        assert_eq!(e4m3_encode_ceil(0.0), 0);
        // Positive inputs never encode to the zero byte.
        assert_eq!(e4m3_decode(e4m3_encode_ceil(1e-9)), 0.001953125);
    }

    #[test]
    fn group_geom_construction_and_ids() {
        assert_eq!(GroupGeom::default(), GroupGeom::mx());
        assert_eq!(GroupGeom::mx().group_size(), 32);
        assert_eq!(GroupGeom::nvfp4().group_size(), 16);
        assert_eq!(GroupGeom::nvfp4().scale_enc(), ScaleEnc::E4m3);
        assert!(GroupGeom::new(0, ScaleEnc::E8m0).is_err(), "group_size 0 rejected");
        let g8 = GroupGeom::new(8, ScaleEnc::E8m0).unwrap();
        assert_eq!(g8.groups_per_row(20), 3);
        assert_eq!(g8.id(), None, "unregistered geometry has no checkpoint id");
        for id in [0u8, 1] {
            assert_eq!(GroupGeom::from_id(id).unwrap().id(), Some(id));
        }
        assert!(GroupGeom::from_id(7).is_none());
    }

    #[test]
    fn group_geom_scale_byte_validity() {
        let mx = GroupGeom::mx();
        assert!(mx.scale_byte_valid(0) && mx.scale_byte_valid(254));
        assert!(!mx.scale_byte_valid(255), "E8M0 NaN byte rejected");
        let nv = GroupGeom::nvfp4();
        assert!(nv.scale_byte_valid(0) && nv.scale_byte_valid(E4M3_MAX_BYTE));
        assert!(!nv.scale_byte_valid(0x7F), "E4M3 NaN byte rejected");
        assert!(!nv.scale_byte_valid(0x80), "negative E4M3 scale rejected");
    }

    #[test]
    fn group_geom_encode_decode_roundtrip() {
        let mx = GroupGeom::mx();
        // E8M0 matches the legacy scale_exponent + exp2i pipeline.
        let b = mx.encode_scale(31.0, e2m1(), Scaling::TruncationFree);
        assert_eq!(b as i32 - 127, 3);
        assert_eq!(mx.decode_scale(b), 8.0);
        // E4M3 never undershoots amax/Qp (no truncation of the max).
        let nv = GroupGeom::nvfp4();
        for amax in [0.001f32, 0.3, 1.0, 5.7, 31.0, 2000.0] {
            let b = nv.encode_scale(amax, e2m1(), Scaling::TruncationFree);
            let s = nv.decode_scale(b);
            if amax / 6.0 <= 448.0 {
                assert!(s >= amax / 6.0, "amax={amax}: scale {s} truncates");
            }
        }
        assert_eq!(nv.encode_scale(0.0, e2m1(), Scaling::TruncationFree), 0);
    }

    #[test]
    fn maxdist_tables() {
        let f = e2m1();
        // level 6 (last): distance to threshold 5 is 1.
        assert_eq!(f.maxdist[f.levels.len() - 1], 1.0);
        // level 0: thresholds ±0.25 -> maxdist 0.25.
        assert_eq!(f.maxdist[7], 0.25);
        let g = e3m0();
        assert_eq!(g.maxdist[g.levels.len() - 1], 4.0);
    }
}
