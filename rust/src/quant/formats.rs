//! FP4 format tables + exact binary helpers (mirror of formats.py).

use std::sync::OnceLock;

/// MX group size (1x32 / 32x1).
pub const GROUP: usize = 32;

pub const SCALE_EXP_MIN: i32 = -127;
pub const SCALE_EXP_MAX: i32 = 127;

/// Epsilon substituted for an all-zero group's max (paper §3.2).
pub const ZERO_GROUP_EPS: f32 = 1e-8;

/// Shared-scale computation rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scaling {
    /// TetraJet truncation-free: s = ceil(log2(M / Qp)).
    TruncationFree,
    /// Microscaling: s = floor(log2(M)) - Emax (values may truncate).
    Floor,
}

impl Scaling {
    pub fn parse(s: &str) -> Option<Scaling> {
        match s {
            "tf" => Some(Scaling::TruncationFree),
            "floor" => Some(Scaling::Floor),
            _ => None,
        }
    }
}

/// One FP4 format: full representable grid plus closed-form parameters.
#[derive(Debug, Clone)]
pub struct Fp4Format {
    pub name: &'static str,
    pub levels: Vec<f32>,
    pub boundaries: Vec<f32>,
    /// MaxDist(level): max possible distance to the nearest threshold
    /// among latents quantizing to this level (paper §4.2).
    pub maxdist: Vec<f32>,
    pub emax: i32,
    pub mbits: i32,
    pub delta_min: f32,
}

impl Fp4Format {
    fn new(name: &'static str, pos: &[f32], emax: i32, mbits: i32, delta_min: f32) -> Fp4Format {
        let mut levels: Vec<f32> = pos.iter().rev().map(|v| -v).collect();
        levels.push(0.0);
        levels.extend_from_slice(pos);
        let boundaries: Vec<f32> = levels
            .windows(2)
            .map(|w| (w[0] + w[1]) / 2.0)
            .collect();
        let n = levels.len();
        let mut maxdist = vec![0.0f32; n];
        for j in 0..n {
            maxdist[j] = if j == 0 {
                (levels[0] - boundaries[0]).abs()
            } else if j == n - 1 {
                (levels[n - 1] - boundaries[n - 2]).abs()
            } else {
                (boundaries[j] - boundaries[j - 1]) / 2.0
            };
        }
        Fp4Format { name, levels, boundaries, maxdist, emax, mbits, delta_min }
    }

    #[inline]
    pub fn qp(&self) -> f32 {
        *self.levels.last().unwrap()
    }

    #[inline]
    pub fn qn(&self) -> f32 {
        self.levels[0]
    }

    /// Index of the level a latent deterministically rounds to.
    /// Boundaries are sorted and the predicate `y >= b` is monotone, so
    /// the filter-count is a partition point (binary search).
    #[inline]
    pub fn level_index(&self, y: f32) -> usize {
        self.boundaries.partition_point(|&b| y >= b)
    }
}

/// E2M1: positives 0.5, 1, 1.5, 2, 3, 4, 6 (Qp = 6).
pub fn e2m1() -> &'static Fp4Format {
    static F: OnceLock<Fp4Format> = OnceLock::new();
    F.get_or_init(|| Fp4Format::new("e2m1", &[0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], 2, 1, 0.5))
}

/// E3M0: positives 0.25 .. 16 (powers of two; Qp = 16).
pub fn e3m0() -> &'static Fp4Format {
    static F: OnceLock<Fp4Format> = OnceLock::new();
    F.get_or_init(|| Fp4Format::new("e3m0", &[0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0], 4, 0, 0.25))
}

pub fn fp4_format(name: &str) -> Option<&'static Fp4Format> {
    match name {
        "e2m1" => Some(e2m1()),
        "e3m0" => Some(e3m0()),
        _ => None,
    }
}

/// frexp: x = m * 2^e with m in [0.5, 1) for finite x > 0. Exact (bit
/// manipulation), matching XLA's decomposition of jnp.frexp.
#[inline]
pub fn frexp(x: f32) -> (f32, i32) {
    if x == 0.0 {
        return (0.0, 0);
    }
    let bits = x.to_bits();
    let exp = ((bits >> 23) & 0xff) as i32;
    if exp == 0 {
        // Subnormal: renormalize by an exact power of two.
        let (m, e) = frexp(x * f32::from_bits(((64 + 127) as u32) << 23));
        return (m, e - 64);
    }
    let m = f32::from_bits((bits & 0x807f_ffff) | (126 << 23));
    (m, exp - 126)
}

/// Exact 2^s for s in [-149, 127].
#[inline]
pub fn exp2i(s: i32) -> f32 {
    debug_assert!((-149..=127).contains(&s));
    if s >= -126 {
        f32::from_bits(((s + 127) as u32) << 23)
    } else {
        // Subnormal result.
        f32::from_bits(1u32 << (s + 149) as u32)
    }
}

/// Shared-scale exponent for a group with max-abs `max_abs` (mirror of
/// ref.scale_exponent).
#[inline]
pub fn scale_exponent(max_abs: f32, fmt: &Fp4Format, scaling: Scaling) -> i32 {
    let m_t = if max_abs == 0.0 { ZERO_GROUP_EPS } else { max_abs };
    let s = match scaling {
        Scaling::TruncationFree => {
            let (m, e) = frexp(m_t / fmt.qp());
            if m == 0.5 {
                e - 1
            } else {
                e
            }
        }
        Scaling::Floor => {
            let (_, e) = frexp(m_t);
            (e - 1) - fmt.emax
        }
    };
    s.clamp(SCALE_EXP_MIN, SCALE_EXP_MAX)
}

/// Grid spacing at magnitude `a` (closed form; see kernels/mxfp4.py).
#[inline]
pub fn grid_spacing_mag(a: f32, fmt: &Fp4Format) -> f32 {
    let (_, e) = frexp(a);
    let delta = exp2i((e - 1 - fmt.mbits).clamp(-149, 127));
    delta.max(fmt.delta_min)
}

/// Deterministic round-to-nearest on the grid, ties toward +inf.
#[inline]
pub fn round_det(y: f32, fmt: &Fp4Format) -> f32 {
    let delta = grid_spacing_mag(y.abs(), fmt);
    (y / delta + 0.5).floor() * delta
}

/// Gap between a grid `level` and the next level above it.
#[inline]
pub fn spacing_above(level: f32, fmt: &Fp4Format) -> f32 {
    let a = level.abs();
    if a == 0.0 {
        return fmt.delta_min;
    }
    let (m, e) = frexp(a);
    let mut delta = exp2i((e - 1 - fmt.mbits).clamp(-149, 127));
    if level < 0.0 && m == 0.5 {
        delta *= 0.5;
    }
    delta.max(fmt.delta_min)
}

/// Bracketing grid values (q1, q2) with q1 <= y <= q2; q1 clamped to the
/// second-highest level so q2 never exceeds Qp (table-oracle semantics).
#[inline]
pub fn bracket(y: f32, fmt: &Fp4Format) -> (f32, f32) {
    let a = y.abs();
    let delta = grid_spacing_mag(a, fmt);
    let q1 = if y >= 0.0 {
        (a / delta).floor() * delta
    } else {
        -((a / delta).ceil() * delta)
    };
    let q1 = q1.min(fmt.levels[fmt.levels.len() - 2]);
    (q1, q1 + spacing_above(q1, fmt))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frexp_matches_definition() {
        for &x in &[1.0f32, 0.5, 2.0, 3.7, 6.0, 1e-8, 1e30, 1.5e-42] {
            let (m, e) = frexp(x);
            assert!((0.5..1.0).contains(&m), "m={m} for x={x}");
            assert_eq!(m * exp2i(e.clamp(-149, 127)), x, "x={x}");
        }
        assert_eq!(frexp(0.0), (0.0, 0));
    }

    #[test]
    fn exp2i_exact() {
        assert_eq!(exp2i(0), 1.0);
        assert_eq!(exp2i(3), 8.0);
        assert_eq!(exp2i(-3), 0.125);
        assert_eq!(exp2i(-127), f32::from_bits(1 << 22));
        assert_eq!(exp2i(127), 2.0f32.powi(127));
    }

    #[test]
    fn paper_scaling_example() {
        // Paper §3.2: M = 31 -> truncation-free S = 8, floor S = 4.
        assert_eq!(scale_exponent(31.0, e2m1(), Scaling::TruncationFree), 3);
        assert_eq!(scale_exponent(31.0, e2m1(), Scaling::Floor), 2);
    }

    #[test]
    fn zero_group_uses_eps() {
        let s = scale_exponent(0.0, e2m1(), Scaling::TruncationFree);
        assert!(s < -20, "eps scale, got {s}");
    }

    #[test]
    fn round_det_against_table() {
        for fmt in [e2m1(), e3m0()] {
            let n = 40013;
            for i in 0..n {
                let y = fmt.qn() + (fmt.qp() - fmt.qn()) * (i as f32 / (n - 1) as f32);
                // Table oracle: boundaries count, ties toward larger.
                let idx = fmt.level_index(y);
                let want = fmt.levels[idx];
                assert_eq!(round_det(y, fmt), want, "y={y} fmt={}", fmt.name);
            }
        }
    }

    #[test]
    fn bracket_against_table() {
        for fmt in [e2m1(), e3m0()] {
            let mut ys: Vec<f32> = (0..40013)
                .map(|i| fmt.qn() + (fmt.qp() - fmt.qn()) * (i as f32 / 40012.0))
                .collect();
            ys.extend_from_slice(&fmt.levels);
            ys.extend_from_slice(&fmt.boundaries);
            for &y in &ys {
                let i = (fmt.levels.iter().filter(|&&l| y >= l).count() as i64 - 1)
                    .clamp(0, fmt.levels.len() as i64 - 2) as usize;
                let (w1, w2) = (fmt.levels[i], fmt.levels[i + 1]);
                let (q1, q2) = bracket(y, fmt);
                assert_eq!((q1, q2), (w1, w2), "y={y} fmt={}", fmt.name);
            }
        }
    }

    #[test]
    fn maxdist_tables() {
        let f = e2m1();
        // level 6 (last): distance to threshold 5 is 1.
        assert_eq!(f.maxdist[f.levels.len() - 1], 1.0);
        // level 0: thresholds ±0.25 -> maxdist 0.25.
        assert_eq!(f.maxdist[7], 0.25);
        let g = e3m0();
        assert_eq!(g.maxdist[g.levels.len() - 1], 4.0);
    }
}
