//! Bit-exact pure-Rust mirror of the L1/L2 quantizers, in two faces.
//!
//! The coordinator needs the quantized-weight trajectory every step
//! (oscillation ratio R_w, quantization confidence, rate-of-change,
//! flipping frequency) without bouncing through XLA. This module
//! re-implements the exact numerics of `python/compile/kernels/ref.py`
//! — same frexp-based scale exponents, same closed-form grid rounding —
//! and is golden-tested against vectors exported by `aot.py`
//! (`artifacts/golden/quant_vectors.json`, rust/tests/golden_quant.rs).
//!
//! Structure:
//!
//! * [`formats`] — FP4 format tables (E2M1/E3M0) + exact binary helpers
//!   (frexp, exp2i, shared-scale exponents, grid rounding/bracketing).
//! * [`packed`] — the [`Quantizer`] trait and [`PackedMx`], the packed
//!   4-bit representation: two level codes per byte + one E8M0 scale
//!   byte per 1x32 group (~7.5x smaller than the f32 fake-quant
//!   mirror). `dequantize(quantize_packed(x))` is bit-exact to the
//!   fake-quant output, so every consumer can pick codes or floats.
//! * [`mx`] / [`qema`] / [`int4`] / [`nvfp4`] — the concrete
//!   quantizers, each offering free functions (allocating + `_into`)
//!   and a `Quantizer` impl ([`MxQuantizer`], [`QemaQuantizer`],
//!   [`Int4Quantizer`], [`NvQuantizer`]); all grouped variants share
//!   one group loop built on `packed::group_ranges`.
//!
//! Group geometry (group size + scale-byte encoding) is a runtime
//! parameter, [`GroupGeom`]: MX (1x32, E8M0) is the default; NVFP4
//! (1x16, E4M3, outlier clamp) rides the same substrate.

pub mod formats;
pub mod int4;
pub mod mx;
pub mod nvfp4;
pub mod packed;
pub mod qema;

pub use formats::{
    bracket, e2m1, e3m0, e4m3_decode, e4m3_encode_ceil, fp4_format, round_det,
    scale_exponent, Fp4Format, GroupGeom, ScaleEnc, Scaling, E4M3_MAX_BYTE,
    GROUP, NVFP4_GROUP,
};
pub use int4::{int4_quantize, int4_quantize_into, Int4Quantizer};
pub use mx::{
    group_scales, mx_quantize_cols, mx_quantize_cols_into,
    mx_quantize_cols_with_scales, mx_quantize_stoch_cols,
    mx_quantize_stoch_cols_into, mx_scale_bytes, MxQuantizer,
};
pub use nvfp4::{nvfp4_quantize_cols, NvQuantizer, NVFP4_CLAMP_K};
pub use packed::{
    group_ranges, level_table_from_id, level_table_id, PackedMx, Quantizer,
    E8M0_BIAS,
};
pub use qema::{qema_quantize_cols, qema_quantize_cols_into, QemaQuantizer};
