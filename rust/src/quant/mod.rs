//! Bit-exact pure-Rust mirror of the L1/L2 quantizers.
//!
//! The coordinator needs the quantized-weight trajectory every step
//! (oscillation ratio R_w, quantization confidence, rate-of-change,
//! flipping frequency) without bouncing through XLA. This module
//! re-implements the exact numerics of `python/compile/kernels/ref.py`
//! — same frexp-based scale exponents, same closed-form grid rounding —
//! and is golden-tested against vectors exported by `aot.py`
//! (`artifacts/golden/quant_vectors.json`, rust/tests/golden_quant.rs).

pub mod formats;
pub mod int4;
pub mod mx;
pub mod qema;

pub use formats::{
    bracket, e2m1, e3m0, fp4_format, round_det, scale_exponent, Fp4Format,
    Scaling, GROUP,
};
pub use int4::int4_quantize;
pub use mx::{
    group_scales, mx_quantize_cols, mx_quantize_cols_into,
    mx_quantize_stoch_cols,
};
pub use qema::{qema_quantize_cols, qema_quantize_cols_into};
