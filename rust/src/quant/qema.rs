//! Q-EMA quantizer mirror (paper §5 / Alg. 1; ref.qema_quantize_ref).
//!
//! Scale and bracketing candidates [q1, q2] from the *current* weight
//! block; the choice between them from the EMA latent weight. Used by
//! the coordinator to track the forward quantized weights of the
//! `tetrajet_qema` variant. [`QemaQuantizer`] binds the EMA slice so
//! the selection rule fits the [`Quantizer`](super::packed::Quantizer)
//! trait's `(x, cols)` signature.

use super::formats::{bracket, Fp4Format, Scaling};
use super::mx::for_each_group;
use super::packed::{PackedMx, Quantizer};

pub fn qema_quantize_cols(
    w: &[f32],
    ema: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
) -> Vec<f32> {
    let mut out = vec![0.0; w.len()];
    qema_quantize_cols_into(w, ema, cols, fmt, scaling, &mut out);
    out
}

pub fn qema_quantize_cols_into(
    w: &[f32],
    ema: &[f32],
    cols: usize,
    fmt: &Fp4Format,
    scaling: Scaling,
    out: &mut [f32],
) {
    assert_eq!(w.len(), ema.len());
    assert_eq!(w.len(), out.len());
    for_each_group(w, cols, fmt, scaling, |rng, _s, scale| {
        let inv = 1.0 / scale;
        for i in rng {
            let q = qema_pick(w[i], ema[i], inv, fmt);
            out[i] = q * scale;
        }
    });
}

/// The Alg. 1 selection for one element: bracket the current latent,
/// let the EMA latent choose between the candidates (strictly-nearer ->
/// q1; ties -> q2, matching ref).
#[inline]
fn qema_pick(w: f32, ema: f32, inv: f32, fmt: &Fp4Format) -> f32 {
    let y = (w * inv).clamp(fmt.qn(), fmt.qp());
    let ye = ema * inv;
    let (q1, q2) = bracket(y, fmt);
    if (ye - q1).abs() < (ye - q2).abs() {
        q1
    } else {
        q2
    }
}

/// Q-EMA as a [`Quantizer`]: the EMA slice rides in the struct and must
/// be element-aligned with every `x` passed in.
#[derive(Debug, Clone, Copy)]
pub struct QemaQuantizer<'e> {
    pub fmt: &'static Fp4Format,
    pub scaling: Scaling,
    pub ema: &'e [f32],
}

impl Quantizer for QemaQuantizer<'_> {
    fn name(&self) -> &'static str {
        "qema"
    }

    fn quantize_f32(&self, x: &[f32], cols: usize, out: &mut [f32]) {
        qema_quantize_cols_into(x, self.ema, cols, self.fmt, self.scaling, out);
    }

    fn quantize_packed(&self, x: &[f32], cols: usize, out: &mut PackedMx) {
        assert_eq!(x.len(), self.ema.len());
        let fmt = self.fmt;
        out.begin_grouped(x.len(), cols, &fmt.levels);
        for_each_group(x, cols, fmt, self.scaling, |rng, s, scale| {
            out.push_group_scale(s);
            let inv = 1.0 / scale;
            for i in rng {
                // The picked candidate is exactly a grid level, so its
                // index decodes to the identical value.
                let q = qema_pick(x[i], self.ema[i], inv, fmt);
                out.set_code(i, fmt.level_index(q) as u8);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::formats::e2m1;
    use crate::quant::mx::mx_quantize_cols;

    #[test]
    fn ema_breaks_the_tie_toward_history() {
        let fmt = e2m1();
        // Latent weight just above a threshold; EMA far below it.
        // Group max 6 -> scale 1. Element 0.76 brackets (0.5, 1.0);
        // plain det rounds to 1.0, EMA at 0.3 pulls it to 0.5.
        let mut w = vec![0.0f32; 32];
        w[0] = 6.0;
        w[1] = 0.76;
        let mut ema = w.clone();
        ema[1] = 0.3;
        let q = qema_quantize_cols(&w, &ema, 32, fmt, Scaling::TruncationFree);
        assert_eq!(q[1], 0.5);
        let qd = mx_quantize_cols(&w, 32, fmt, Scaling::TruncationFree);
        assert_eq!(qd[1], 1.0);
    }

    #[test]
    fn ema_equal_to_weight_matches_det_rounding_off_threshold() {
        // When EMA == W and W is not exactly at a threshold, Q-EMA picks
        // the same nearest value as deterministic rounding.
        let fmt = e2m1();
        let w: Vec<f32> = (0..64)
            .map(|i| ((i * 31) % 23) as f32 / 4.0 - 2.5)
            // keep off thresholds
            .map(|v| if (v * 4.0).fract() == 0.0 { v + 0.01 } else { v })
            .collect();
        let q = qema_quantize_cols(&w, &w, 32, fmt, Scaling::TruncationFree);
        let qd = mx_quantize_cols(&w, 32, fmt, Scaling::TruncationFree);
        for i in 0..w.len() {
            let latent_is_midpoint = false; // construction avoids midpoints
            if !latent_is_midpoint {
                assert_eq!(q[i], qd[i], "i={i} w={}", w[i]);
            }
        }
    }

    #[test]
    fn output_stays_on_scaled_grid() {
        let fmt = e2m1();
        let w: Vec<f32> = (0..96).map(|i| ((i * 13) % 41) as f32 / 6.0 - 3.0).collect();
        let ema: Vec<f32> = w.iter().map(|v| v * 0.9).collect();
        let q = qema_quantize_cols(&w, &ema, 32, fmt, Scaling::TruncationFree);
        let q2 = mx_quantize_cols(&q, 32, fmt, Scaling::TruncationFree);
        assert_eq!(q, q2);
    }
}
