//! Synthetic no-HLO training driver for the oscillation observatory
//! (`train --synthetic tiny|micro`).
//!
//! The real trainer needs AOT HLO artifacts; this driver replaces the
//! optimizer step with a seeded random walk over the quantized weight
//! prefix of a [`ServeGeom`] layout and runs the *identical* metric
//! machinery — packed quantize mirror, [`PackedOscTracker`] code
//! compare, [`OscObservatory`] per-segment recording, the
//! `train.osc.*` gauge arithmetic — so OSCLOG artifacts, `tetrajet
//! report`, and the digest-stability gate (`make report-smoke`) are
//! exercisable on machines with no artifacts at all.
//!
//! Everything is a pure function of (model, variant, seed, steps,
//! window): weights evolve as `w += 0.02 · N(0,1)` from a per-step
//! `fold_in` stream, quantization is serial per segment, and the
//! observatory means are serial f64 sums — two runs with the same
//! inputs produce byte-identical OSCLOG files.

use anyhow::{bail, Result};

use crate::config::MetricsCfg;
use crate::coordinator::observatory::OscObservatory;
use crate::coordinator::trainer::{TrainerObs, TRAIN_PHASES, TRAIN_TRACE_TID};
use crate::metrics::PackedOscTracker;
use crate::obs::osclog::{split_segments, OscLogWriter, OscSegment};
use crate::obs::{MetricsRegistry, TraceSink};
use crate::quant::{e2m1, GroupGeom, MxQuantizer, NvQuantizer, PackedMx, Quantizer, Scaling};
use crate::serve::ServeGeom;
use crate::util::json::{num, s};
use crate::util::rng::Rng;

/// Synthetic geometry by name — the same pair `serve --synthetic` uses.
pub fn synth_geom(name: &str) -> Option<ServeGeom> {
    match name {
        "tiny" => Some(ServeGeom::new(16, 4, 32, 2, 4, 10, 4)),
        "micro" => Some(ServeGeom::new(32, 4, 64, 4, 4, 10, 4)),
        _ => None,
    }
}

/// Which packed mirror the synthetic walk quantizes through.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SynthMirror {
    Mx,
    Nvfp4,
}

/// End-of-run summary: window closes plus artifact witnesses.
#[derive(Debug, Clone)]
pub struct SynthTrainReport {
    pub steps: usize,
    pub qw_total: usize,
    pub segments: usize,
    /// `(step, oscillating_count)` at each window close.
    pub windows: Vec<(usize, usize)>,
    /// `(lines, digest)` of the OSCLOG artifact, when one was attached.
    pub osclog: Option<(u64, String)>,
    /// `(events, digest)` of the trace, when one was attached.
    pub trace: Option<(u64, String)>,
}

/// Seeded random-walk trainer over a synthetic quantized layout.
pub struct SynthTrainer {
    mirror: SynthMirror,
    /// Quantized entries of the layout: (name, shape, offset, size, cols).
    qsegs: Vec<(&'static str, Vec<usize>, usize, usize, usize)>,
    w: Vec<f32>,
    packed: Vec<PackedMx>,
    wq: Vec<f32>,
    tracker: Option<PackedOscTracker>,
    observatory: Option<OscObservatory>,
    trace: Option<TraceSink>,
    obs: TrainerObs,
    base_rng: Rng,
    step: usize,
    metrics: MetricsCfg,
    windows: Vec<(usize, usize)>,
    model: String,
    seed: u64,
}

impl SynthTrainer {
    /// `variant` selects the mirror recipe: `mx` (default training
    /// recipe) or `nvfp4`. `metrics.osc_window` must be > 0.
    pub fn new(model: &str, variant: &str, seed: u64, metrics: MetricsCfg) -> Result<SynthTrainer> {
        let Some(geom) = synth_geom(model) else {
            bail!("unknown synthetic geometry {model:?} (tiny | micro)");
        };
        if metrics.osc_window == 0 {
            bail!("synthetic training requires metrics.osc_window > 0");
        }
        let mirror = match variant {
            "mx" | "" => SynthMirror::Mx,
            "nvfp4" => SynthMirror::Nvfp4,
            other => bail!("unknown synthetic variant {other:?} (mx | nvfp4)"),
        };
        let qsegs: Vec<_> = geom
            .param_spec()
            .into_iter()
            .filter(|sp| sp.quantized)
            .map(|sp| {
                let (cols, size) = (sp.cols(), sp.size);
                (sp.name, sp.shape, sp.offset, size, cols)
            })
            .collect();
        let qw_total = geom.qw_total();
        // Same init stream as `serve --synthetic` ("MOD"), so the
        // synthetic trainer walks the model serving smoke-tests load.
        let mut rng = Rng::new(seed).fold_in(0x4d4f44);
        let w: Vec<f32> = (0..qw_total).map(|_| rng.normal() * 0.05).collect();
        let n = qsegs.len();
        Ok(SynthTrainer {
            mirror,
            qsegs,
            w,
            packed: vec![PackedMx::default(); n],
            wq: vec![0.0; qw_total],
            tracker: None,
            observatory: None,
            trace: None,
            obs: TrainerObs::new(),
            base_rng: Rng::new(seed).fold_in(0x535445), // "STE"
            step: 0,
            metrics,
            windows: Vec::new(),
            model: model.to_string(),
            seed,
        })
    }

    /// The registry behind `train.*` (shared shape with the real
    /// trainer's).
    pub fn registry(&self) -> &MetricsRegistry {
        &self.obs.reg
    }

    fn geom_q(&self) -> GroupGeom {
        match self.mirror {
            SynthMirror::Mx => GroupGeom::mx(),
            SynthMirror::Nvfp4 => GroupGeom::nvfp4(),
        }
    }

    fn mirror_name(&self) -> &'static str {
        match self.mirror {
            SynthMirror::Mx => "mx",
            SynthMirror::Nvfp4 => "nvfp4",
        }
    }

    /// Observatory slices of the synthetic layout, in artifact order.
    pub fn slices(&self) -> Vec<OscSegment> {
        let mut segs = Vec::new();
        for (name, shape, offset, _, _) in &self.qsegs {
            segs.extend(split_segments(name, shape, *offset));
        }
        segs
    }

    /// Attach an OSCLOG01 observatory writing to `writer`.
    pub fn attach_osclog(&mut self, writer: OscLogWriter) {
        let meta = vec![
            ("variant".to_string(), s(&format!("synthetic-{}", self.model))),
            ("mirror".to_string(), s(self.mirror_name())),
            ("seed".to_string(), num(self.seed as f64)),
        ];
        self.observatory = Some(OscObservatory::new(
            self.slices(),
            self.w.len(),
            e2m1(),
            Scaling::TruncationFree,
            self.geom_q(),
            self.metrics.rw_threshold,
            self.metrics.osc_window,
            meta,
            writer,
        ));
    }

    /// Attach a Chrome trace sink (same `train.<phase>` spans / tid as
    /// the real trainer; the synthetic timeline is always simulated).
    pub fn set_trace(&mut self, sink: TraceSink) {
        self.trace = Some(sink);
    }

    fn mirror_wq(&mut self) {
        for ((_, _, offset, size, cols), p) in self.qsegs.iter().zip(&mut self.packed) {
            let seg = &self.w[*offset..*offset + *size];
            match self.mirror {
                SynthMirror::Mx => MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree }
                    .quantize_packed(seg, *cols, p),
                SynthMirror::Nvfp4 => NvQuantizer::nvfp4().quantize_packed(seg, *cols, p),
            }
        }
        let mut base = 0usize;
        for p in &self.packed {
            p.dequantize_into(&mut self.wq[base..base + p.len()]);
            base += p.len();
        }
    }

    /// One synthetic step: random-walk the weights, refresh the packed
    /// mirror, feed tracker + observatory, close windows.
    pub fn step(&mut self) {
        let step = self.step;
        let mut rng = self.base_rng.fold_in(step as u64);
        for v in &mut self.w {
            *v += 0.02 * rng.normal();
        }
        self.mirror_wq();
        match &mut self.tracker {
            None => {
                self.tracker = Some(PackedOscTracker::new(&self.w, &self.packed));
            }
            Some(t) => {
                t.observe(&self.w, &self.packed);
                if let Some(ob) = &mut self.observatory {
                    let flips = ob.record_step(step + 1, &self.w, &self.wq, t.window());
                    self.obs.step_flips.push(flips as f64);
                }
                if t.steps() >= self.metrics.osc_window {
                    let count = t.oscillating_count(self.metrics.rw_threshold);
                    if let Some(ob) = &mut self.observatory {
                        let total = ob.record_window_end(step + 1, t.window());
                        debug_assert_eq!(total, count);
                    }
                    self.obs.osc_flips.set(count as f64);
                    // Identical arithmetic to Trainer::after_step, so
                    // `report` can match `train.osc.ratio` bit-exactly.
                    self.obs.osc_ratio.set(count as f64 / self.wq.len().max(1) as f64);
                    self.windows.push((step + 1, count));
                    t.reset_window();
                    if let Some(ob) = &mut self.observatory {
                        ob.note_reset();
                    }
                }
            }
        }
        if let Some(tr) = &mut self.trace {
            let base = step as f64 * TRAIN_PHASES.len() as f64;
            for (i, name) in TRAIN_PHASES.iter().enumerate() {
                tr.duration(
                    &format!("train.{name}"),
                    base + i as f64,
                    1.0,
                    TRAIN_TRACE_TID,
                    vec![("step", num(step as f64))],
                );
            }
        }
        self.step += 1;
        self.obs.steps.inc();
    }

    /// Run `steps` steps and flush the artifacts.
    pub fn run(&mut self, steps: usize) -> Result<SynthTrainReport> {
        for _ in 0..steps {
            self.step();
        }
        let osclog = match &mut self.observatory {
            Some(ob) => {
                ob.finish()?;
                Some((ob.lines(), ob.digest()))
            }
            None => None,
        };
        let trace = match &mut self.trace {
            Some(tr) => {
                tr.finish()?;
                Some((tr.events(), tr.digest()))
            }
            None => None,
        };
        Ok(SynthTrainReport {
            steps: self.step,
            qw_total: self.w.len(),
            segments: self.slices().len(),
            windows: self.windows.clone(),
            osclog,
            trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics(window: usize) -> MetricsCfg {
        MetricsCfg {
            rate_window: 0,
            probe_every: 0,
            osc_window: window,
            rw_threshold: 16.0,
            conf_every: 0,
        }
    }

    fn digest_of(model: &str, variant: &str, seed: u64, steps: usize) -> (u64, String) {
        let mut t = SynthTrainer::new(model, variant, seed, metrics(10)).unwrap();
        t.attach_osclog(OscLogWriter::in_memory());
        t.run(steps).unwrap().osclog.unwrap()
    }

    #[test]
    fn osclog_digest_is_a_pure_function_of_seed_and_config() {
        for variant in ["mx", "nvfp4"] {
            let (l1, d1) = digest_of("tiny", variant, 7, 25);
            let (l2, d2) = digest_of("tiny", variant, 7, 25);
            assert_eq!((l1, &d1), (l2, &d2), "{variant} reruns must be byte-identical");
            let (_, d3) = digest_of("tiny", variant, 8, 25);
            assert_ne!(d1, d3, "{variant} seed must move the digest");
        }
        // The two mirrors see different flip patterns.
        assert_ne!(digest_of("tiny", "mx", 7, 25).1, digest_of("tiny", "nvfp4", 7, 25).1);
    }

    #[test]
    fn window_ratio_matches_gauge_arithmetic() {
        let mut t = SynthTrainer::new("tiny", "mx", 3, metrics(8)).unwrap();
        t.attach_osclog(OscLogWriter::in_memory());
        let rep = t.run(20).unwrap();
        assert!(!rep.windows.is_empty(), "20 steps at window 8 must close a window");
        let (_, count) = *rep.windows.last().unwrap();
        let gauge = t.registry().gauge("train.osc.ratio").get();
        assert_eq!(gauge, count as f64 / rep.qw_total.max(1) as f64, "bit-exact ratio");
    }

    #[test]
    fn trace_spans_are_deterministic() {
        let run = || {
            let mut t = SynthTrainer::new("tiny", "mx", 1, metrics(10)).unwrap();
            t.set_trace(TraceSink::in_memory(true));
            let rep = t.run(6).unwrap();
            rep.trace.unwrap()
        };
        let (e1, d1) = run();
        let (e2, d2) = run();
        assert_eq!(e1, 6 * TRAIN_PHASES.len() as u64);
        assert_eq!((e1, d1), (e2, d2));
    }
}
