//! The oscillation observatory: per-segment training telemetry
//! streamed to an `OSCLOG01` artifact (`train --osc-out`).
//!
//! The trainer already tracks per-element flip counts and R_w
//! accumulators ([`OscWindow`]) over the whole quantized prefix; the
//! observatory projects that window onto the manifest's segment
//! structure — one slice per depth of each quantized tensor
//! ([`split_segments`]) — and records, per step and per slice:
//!
//! * `flips`  — quantized-value flips this step (delta of the window's
//!   cumulative per-element counts, summed over the slice),
//! * `conf`   — mean quantization confidence of the master weights
//!   under the active mirror's group geometry,
//! * `wdist`  — mean |W − W_q| distance to the dequantized mirror.
//!
//! At each window close it records per-slice oscillating-element
//! counts via [`OscWindow::oscillating_count_in`]; because the slices
//! tile the prefix exactly, their sum equals the trainer's global
//! `oscillating_count` *bit-exactly* — `tetrajet report` recovers
//! `train.osc.ratio` from the artifact without rounding drift.
//!
//! Everything is serial, allocation is O(segments × window), and each
//! line folds into the writer's FNV-1a digest, so a fixed (seed,
//! config) run yields a byte-identical artifact.

use crate::metrics::{quant_confidence_geom, OscWindow};
use crate::obs::osclog::{OscLogWriter, OscSegment, OSCLOG_FORMAT};
use crate::quant::{Fp4Format, GroupGeom, Scaling};
use crate::util::json::{num, s, Json};

pub struct OscObservatory {
    segs: Vec<OscSegment>,
    fmt: &'static Fp4Format,
    scaling: Scaling,
    geom: GroupGeom,
    threshold: f32,
    window: usize,
    /// Cumulative window flips per slice at the previous step, so each
    /// step line carries deltas (flips *this* step).
    prev_flips: Vec<u64>,
    writer: OscLogWriter,
    scratch: Vec<f32>,
}

impl OscObservatory {
    /// Build an observatory over `segs` (which must tile `total`
    /// elements contiguously from offset 0) and write the OSCLOG01
    /// header. `meta` carries run identity (variant, mirror, seed) into
    /// the header verbatim.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        segs: Vec<OscSegment>,
        total: usize,
        fmt: &'static Fp4Format,
        scaling: Scaling,
        geom: GroupGeom,
        threshold: f32,
        window: usize,
        meta: Vec<(String, Json)>,
        mut writer: OscLogWriter,
    ) -> OscObservatory {
        let mut covered = 0usize;
        for seg in &segs {
            assert_eq!(seg.offset, covered, "observatory slices must tile contiguously");
            covered += seg.size;
        }
        assert_eq!(covered, total, "observatory slices must cover the quantized prefix");
        let mut fields = vec![("format".to_string(), s(OSCLOG_FORMAT))];
        fields.extend(meta);
        fields.push(("group_size".to_string(), num(geom.group_size() as f64)));
        fields.push(("scale_enc".to_string(), s(geom.scale_enc().as_str())));
        fields.push(("threshold".to_string(), num(threshold as f64)));
        fields.push(("osc_window".to_string(), num(window as f64)));
        fields.push(("total".to_string(), num(total as f64)));
        fields.push((
            "segments".to_string(),
            Json::Arr(segs.iter().map(|g| g.to_json()).collect()),
        ));
        writer.line(&Json::Obj(fields));
        let n = segs.len();
        OscObservatory {
            segs,
            fmt,
            scaling,
            geom,
            threshold,
            window,
            prev_flips: vec![0; n],
            writer,
            scratch: Vec::new(),
        }
    }

    /// The slices being observed, in artifact order.
    pub fn segments(&self) -> &[OscSegment] {
        &self.segs
    }

    /// Record one post-observe step: `w` is the master quantized
    /// prefix, `wq` its dequantized mirror view, `win` the tracker
    /// window *after* this step's observe. Returns the global flip
    /// count of this step (for the `train.osc.step_flips` ring).
    pub fn record_step(&mut self, step: usize, w: &[f32], wq: &[f32], win: &OscWindow) -> u64 {
        let flips = win.flips();
        let mut flip_arr = Vec::with_capacity(self.segs.len());
        let mut conf_arr = Vec::with_capacity(self.segs.len());
        let mut dist_arr = Vec::with_capacity(self.segs.len());
        let mut step_total = 0u64;
        for (i, seg) in self.segs.iter().enumerate() {
            let r = seg.offset..seg.offset + seg.size;
            let cum: u64 = flips[r.clone()].iter().map(|&f| u64::from(f)).sum();
            let delta = cum - self.prev_flips[i];
            self.prev_flips[i] = cum;
            step_total += delta;
            flip_arr.push(num(delta as f64));

            quant_confidence_geom(
                &w[r.clone()],
                seg.cols,
                self.fmt,
                self.scaling,
                self.geom,
                &mut self.scratch,
            );
            let conf: f64 =
                self.scratch.iter().map(|&c| c as f64).sum::<f64>() / seg.size.max(1) as f64;
            conf_arr.push(num(conf));

            let dist: f64 = w[r.clone()]
                .iter()
                .zip(&wq[r])
                .map(|(&a, &b)| (a as f64 - b as f64).abs())
                .sum::<f64>()
                / seg.size.max(1) as f64;
            dist_arr.push(num(dist));
        }
        self.writer.line(&Json::Obj(vec![
            ("t".to_string(), num(step as f64)),
            ("flips".to_string(), Json::Arr(flip_arr)),
            ("conf".to_string(), Json::Arr(conf_arr)),
            ("wdist".to_string(), Json::Arr(dist_arr)),
        ]));
        step_total
    }

    /// Record a window close (call *before* the tracker resets).
    /// Returns the summed oscillating-element count, which equals the
    /// tracker's global `oscillating_count(threshold)` exactly.
    pub fn record_window_end(&mut self, step: usize, win: &OscWindow) -> usize {
        let mut osc_arr = Vec::with_capacity(self.segs.len());
        let mut total = 0usize;
        for seg in &self.segs {
            let k = win.oscillating_count_in(self.threshold, seg.offset, seg.offset + seg.size);
            total += k;
            osc_arr.push(num(k as f64));
        }
        self.writer.line(&Json::Obj(vec![
            ("window_end".to_string(), num(step as f64)),
            ("len".to_string(), num(self.window as f64)),
            ("osc".to_string(), Json::Arr(osc_arr)),
            ("osc_total".to_string(), num(total as f64)),
        ]));
        total
    }

    /// Tell the observatory the tracker window was reset: the
    /// cumulative flip baseline restarts at zero.
    pub fn note_reset(&mut self) {
        self.prev_flips.iter_mut().for_each(|f| *f = 0);
    }

    pub fn lines(&self) -> u64 {
        self.writer.lines()
    }

    pub fn digest(&self) -> String {
        self.writer.digest()
    }

    /// Flush the artifact (call once training ends).
    pub fn finish(&mut self) -> anyhow::Result<()> {
        self.writer.finish()
    }
}
