//! Freeze baseline (Nagel et al. 2022; paper §2 & Table 4).
//!
//! Tracks each quantized element's flipping frequency f over detection
//! windows; elements with f > f_th are *permanently* frozen to the
//! running average of their master weight. The paper shows this
//! behaves catastrophically in pre-training (frozen weights can never
//! recover) — we reproduce the mechanism faithfully to reproduce the
//! failure.

use crate::config::Policy;
use crate::metrics::OscTracker;

#[derive(Debug)]
pub struct FreezeController {
    f_th: f32,
    t0: usize,
    t_update: usize,
    window: Option<OscTracker>,
    pub mask: Vec<f32>,
    pub value: Vec<f32>,
    scratch: Vec<f32>,
    pub frozen_count: usize,
}

impl FreezeController {
    pub fn new(policy: &Policy, qw_total: usize) -> FreezeController {
        let (f_th, t0, t_update) = match policy {
            Policy::Freeze { f_th, t0, t_update } => (*f_th, *t0, *t_update),
            _ => panic!("FreezeController needs Policy::Freeze"),
        };
        assert!(t0 < t_update);
        FreezeController {
            f_th,
            t0,
            t_update,
            window: None,
            mask: vec![0.0; qw_total],
            value: vec![0.0; qw_total],
            scratch: Vec::new(),
            frozen_count: 0,
        }
    }

    fn in_detection(&self, step: usize) -> bool {
        step % self.t_update < self.t0
    }

    /// Observe the post-step snapshot; updates mask/value at window ends.
    pub fn observe(&mut self, step: usize, w: &[f32], wq: &[f32]) {
        if !self.in_detection(step) {
            self.window = None;
            return;
        }
        match &mut self.window {
            None => self.window = Some(OscTracker::new(w, wq)),
            Some(t) => t.observe(w, wq),
        }
        if step % self.t_update == self.t0 - 1 {
            if let Some(t) = self.window.take() {
                if t.steps() > 0 {
                    t.flip_freq_into(&mut self.scratch);
                    let avg = t.running_avg();
                    for i in 0..self.mask.len() {
                        if self.mask[i] == 0.0 && self.scratch[i] > self.f_th {
                            self.mask[i] = 1.0;
                            self.value[i] = avg[i];
                        }
                    }
                    self.frozen_count =
                        self.mask.iter().filter(|&&x| x > 0.0).count();
                }
            }
        }
    }

    pub fn frozen_fraction(&self) -> f64 {
        self.frozen_count as f64 / self.mask.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> Policy {
        Policy::Freeze { f_th: 0.4, t0: 4, t_update: 10 }
    }

    #[test]
    fn freezes_flippers_permanently() {
        let mut c = FreezeController::new(&policy(), 2);
        // Element 0 flips every step (f = 1); element 1 static (f = 0).
        let q = [[0.5f32, 0.0], [1.0, 0.0], [0.5, 0.0], [1.0, 0.0], [0.5, 0.0]];
        for i in 0..5 {
            c.observe(i, &[0.75, 0.2], &q[i.min(4)]);
        }
        assert_eq!(c.frozen_count, 1);
        assert_eq!(c.mask, vec![1.0, 0.0]);
        assert!((c.value[0] - 0.75).abs() < 1e-6);
        // Next window: even if element 0 stops flipping it stays frozen.
        for i in 10..15 {
            c.observe(i, &[0.75, 0.2], &[0.5, 0.0]);
        }
        assert_eq!(c.mask, vec![1.0, 0.0]);
    }

    #[test]
    fn no_freeze_below_threshold() {
        let mut c = FreezeController::new(&policy(), 1);
        // One flip over 4 steps -> f = 0.25 < 0.4.
        let q = [[0.5f32], [0.5], [1.0], [1.0], [1.0]];
        for i in 0..5 {
            c.observe(i, &[0.7], &q[i]);
        }
        assert_eq!(c.frozen_count, 0);
    }
}
