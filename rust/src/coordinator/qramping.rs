//! Q-Ramping controller (paper §6 / Alg. 2, coordinator side).
//!
//! Periodically (every `t_update` steps) the controller opens a
//! detection window: ramping is suspended (N_w := 1, matching the
//! paper's "without Q-Ramping" calibration), and for `t0` steps it
//! records every quantized element's master/quantized trajectory via
//! the quant mirror. At the window end it converts oscillation ratios
//! into new amplification factors
//!
//!   N_w = min(k2 * floor(R_w / k1) + 1, N_max)
//!
//! which the train step consumes as per-element gradient-accumulation
//! lengths with proportionally scaled learning rates.

use crate::config::Policy;
use crate::metrics::OscTracker;

#[derive(Debug)]
pub struct QRampingController {
    k1: f32,
    k2: f32,
    n_max: f32,
    t0: usize,
    t_update: usize,
    window: Option<OscTracker>,
    /// N_w values applied outside detection windows.
    applied_nw: Vec<f32>,
    /// Scratch for ratio extraction.
    ratios: Vec<f32>,
    pub windows_completed: usize,
}

impl QRampingController {
    pub fn new(policy: &Policy, qw_total: usize) -> QRampingController {
        let (k1, k2, n_max, t0, t_update) = match policy {
            Policy::QRamping { k1, k2, n_max, t0, t_update } => {
                (*k1, *k2, *n_max, *t0, *t_update)
            }
            _ => panic!("QRampingController needs Policy::QRamping"),
        };
        assert!(t0 < t_update, "detection window must fit inside t_update");
        QRampingController {
            k1,
            k2,
            n_max,
            t0,
            t_update,
            window: None,
            applied_nw: vec![1.0; qw_total],
            ratios: Vec::new(),
            windows_completed: 0,
        }
    }

    /// N_w vector the *next* train step should use, given its step index.
    /// Detection windows run at the start of each t_update period with
    /// ramping suspended.
    pub fn nw_for_step(&self, step: usize) -> Vec<f32> {
        if self.in_detection(step) {
            vec![1.0; self.applied_nw.len()]
        } else {
            self.applied_nw.clone()
        }
    }

    fn in_detection(&self, step: usize) -> bool {
        step % self.t_update < self.t0
    }

    /// Observe the post-step snapshot (master qw, mirrored quantized qw).
    pub fn observe(&mut self, step: usize, w: &[f32], wq: &[f32]) {
        if !self.in_detection(step) {
            self.window = None;
            return;
        }
        match &mut self.window {
            None => self.window = Some(OscTracker::new(w, wq)),
            Some(t) => t.observe(w, wq),
        }
        let done = step % self.t_update == self.t0 - 1;
        if done {
            if let Some(t) = self.window.take() {
                if t.steps() > 0 {
                    t.ratios_into(&mut self.ratios);
                    for (nw, &r) in self.applied_nw.iter_mut().zip(&self.ratios) {
                        let amp = if r.is_finite() {
                            self.k2 * (r / self.k1).floor() + 1.0
                        } else {
                            self.n_max
                        };
                        *nw = amp.clamp(1.0, self.n_max);
                    }
                    self.windows_completed += 1;
                }
            }
        }
    }

    /// Fraction of elements currently ramped (N_w > 1); for logging.
    pub fn ramped_fraction(&self) -> f64 {
        let n = self.applied_nw.len().max(1);
        self.applied_nw.iter().filter(|&&x| x > 1.0).count() as f64 / n as f64
    }

    pub fn applied_nw(&self) -> &[f32] {
        &self.applied_nw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> Policy {
        Policy::QRamping { k1: 16.0, k2: 5.0, n_max: 16.0, t0: 4, t_update: 10 }
    }

    #[test]
    fn detection_then_apply() {
        let mut c = QRampingController::new(&policy(), 2);
        // During detection (steps 0..4) nw must be all-ones.
        assert_eq!(c.nw_for_step(0), vec![1.0, 1.0]);
        // Element 0 oscillates hard (tiny master moves, big q flips);
        // element 1 walks smoothly.
        let w_seq = [
            [0.7501f32, 0.10],
            [0.7499, 0.20],
            [0.7501, 0.30],
            [0.7499, 0.40],
            [0.7501, 0.50],
        ];
        let q_seq = [[1.0f32, 0.0], [0.5, 0.0], [1.0, 0.5], [0.5, 0.5], [1.0, 0.5]];
        for (i, (w, q)) in w_seq.iter().zip(&q_seq).enumerate() {
            c.observe(i, w, q);
        }
        assert_eq!(c.windows_completed, 1);
        let nw = c.nw_for_step(5);
        assert!(nw[0] > 1.0, "oscillating element ramped, got {}", nw[0]);
        assert_eq!(nw[1], 1.0, "smooth element not ramped");
        // R_w for elem 0: dist_q = 4 * 0.5 = 2, dist_w ~ 0.0008 -> huge
        // ratio -> clamped to n_max.
        assert_eq!(nw[0], 16.0);
        assert!(c.ramped_fraction() > 0.0);
    }

    #[test]
    fn next_window_resets_to_ones_during_detection() {
        let mut c = QRampingController::new(&policy(), 1);
        for i in 0..4 {
            c.observe(i, &[0.1 * i as f32], &[0.0]);
        }
        assert_eq!(c.windows_completed, 1);
        // Step 10 starts the next detection window.
        assert_eq!(c.nw_for_step(10), vec![1.0]);
        assert_eq!(c.nw_for_step(4), c.applied_nw().to_vec());
    }

    #[test]
    fn infinite_ratio_maps_to_nmax() {
        let mut c = QRampingController::new(&policy(), 1);
        // Master frozen, quantized flipping: dist_w = 0, dist_q > 0.
        c.observe(0, &[0.5], &[0.5]);
        c.observe(1, &[0.5], &[1.0]);
        c.observe(2, &[0.5], &[0.5]);
        c.observe(3, &[0.5], &[1.0]);
        assert_eq!(c.applied_nw()[0], 16.0);
    }
}
