//! Layer-3 coordinator: the training loop and the paper's
//! coordination-level contributions.
//!
//! All training state lives here between steps (the AOT HLO step is a
//! pure function). On top of the plain loop sit the oscillation
//! controllers:
//!
//! * [`qramping`] — Adaptive Ramping Optimizer (paper §6/Alg. 2): the
//!   coordinator watches each quantized weight element's (w, w_Q)
//!   trajectory with the quant mirror, computes R_w over detection
//!   windows and feeds per-element amplification factors N_w back into
//!   the next steps.
//! * [`freeze`] — Nagel et al.'s Freeze baseline on flipping frequency.
//! * Dampen is a pure scalar input (`dampen_lambda`), no controller.
//!
//! Q-EMA lives in L1/L2 (the `tetrajet_qema` artifact); the coordinator
//! only routes `ema_beta` and the EMA state.

pub mod freeze;
pub mod observatory;
pub mod qramping;
pub mod recorder;
pub mod state;
pub mod synthtrain;
pub mod trainer;

pub use freeze::FreezeController;
pub use observatory::OscObservatory;
pub use qramping::QRampingController;
pub use recorder::Recorder;
pub use state::{PackedSeg, TrainState};
pub use synthtrain::{SynthTrainReport, SynthTrainer};
pub use trainer::{EvalResult, Trainer};
