//! Run-level metric recorder + CSV/JSON export.
//!
//! Collects the series every experiment harness consumes: loss curve,
//! eval points, oscillating-weight counts (Fig. 6), rate-of-change
//! windows (Fig. 2 / Table 3), and confidence/latent snapshots
//! (Fig. 4/5).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{arr_f32, num, obj, Json};
use crate::util::stats::Histogram;

#[derive(Debug, Clone)]
pub struct ConfSnap {
    pub step: usize,
    pub mean_conf: f64,
    /// 20-bin histogram fractions of QuantConf over [0, 1].
    pub conf_hist: Vec<f64>,
    /// 48-bin histogram fractions of latent weights over [Qn, Qp].
    pub latent_hist: Vec<f64>,
}

#[derive(Debug, Clone, Default)]
pub struct Recorder {
    /// (step, train loss, train batch accuracy)
    pub loss_curve: Vec<(usize, f32, f32)>,
    /// (step, val accuracy %, val mean loss)
    pub evals: Vec<(usize, f64, f64)>,
    /// (step, #oscillating elements (R_w > threshold), window length)
    pub osc_series: Vec<(usize, usize, usize)>,
    /// (step, r(W), r(W_Q), r(Y)); r(Y) is NaN when the probe is off.
    pub rate_series: Vec<(usize, f64, f64, f64)>,
    pub conf_snaps: Vec<ConfSnap>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn final_eval(&self) -> Option<(usize, f64, f64)> {
        self.evals.last().copied()
    }

    /// Best validation accuracy over the run (the paper reports final /
    /// best top-1; our runs are short so we report both).
    pub fn best_eval_acc(&self) -> Option<f64> {
        self.evals
            .iter()
            .map(|&(_, acc, _)| acc)
            .max_by(|a, b| a.partial_cmp(b).unwrap())
    }

    pub fn push_conf_snapshot(
        &mut self,
        step: usize,
        confs: &[f32],
        latents: &[f32],
        qn: f32,
        qp: f32,
    ) {
        let mut ch = Histogram::new(0.0, 1.0 + 1e-9, 20);
        confs.iter().for_each(|&c| ch.add(c as f64));
        let mut lh = Histogram::new(qn as f64, qp as f64 + 1e-9, 48);
        latents.iter().for_each(|&l| lh.add(l as f64));
        self.conf_snaps.push(ConfSnap {
            step,
            mean_conf: crate::util::stats::mean_f32(confs),
            conf_hist: ch.fractions(),
            latent_hist: lh.fractions(),
        });
    }

    /// Serialize everything for the results/ directory.
    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "loss_curve",
                Json::Arr(
                    self.loss_curve
                        .iter()
                        .map(|&(s, l, a)| {
                            Json::Arr(vec![num(s as f64), num(l as f64), num(a as f64)])
                        })
                        .collect(),
                ),
            ),
            (
                "evals",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|&(s, a, l)| Json::Arr(vec![num(s as f64), num(a), num(l)]))
                        .collect(),
                ),
            ),
            (
                "osc_series",
                Json::Arr(
                    self.osc_series
                        .iter()
                        .map(|&(s, c, w)| {
                            Json::Arr(vec![num(s as f64), num(c as f64), num(w as f64)])
                        })
                        .collect(),
                ),
            ),
            (
                "rate_series",
                Json::Arr(
                    self.rate_series
                        .iter()
                        .map(|&(s, w, q, y)| {
                            Json::Arr(vec![num(s as f64), num(w), num(q), num(y)])
                        })
                        .collect(),
                ),
            ),
            (
                "conf_snaps",
                Json::Arr(
                    self.conf_snaps
                        .iter()
                        .map(|c| {
                            obj(vec![
                                ("step", num(c.step as f64)),
                                ("mean_conf", num(c.mean_conf)),
                                (
                                    "conf_hist",
                                    arr_f32(
                                        &c.conf_hist.iter().map(|&x| x as f32).collect::<Vec<_>>(),
                                    ),
                                ),
                                (
                                    "latent_hist",
                                    arr_f32(
                                        &c.latent_hist
                                            .iter()
                                            .map(|&x| x as f32)
                                            .collect::<Vec<_>>(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn save_json(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Loss curve as CSV (step,loss,acc).
    pub fn loss_csv(&self) -> String {
        let mut s = String::from("step,loss,batch_acc\n");
        for &(st, l, a) in &self.loss_curve {
            s.push_str(&format!("{st},{l},{a}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_histograms_normalized() {
        let mut r = Recorder::new();
        let confs: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        let lats: Vec<f32> = (0..100).map(|i| i as f32 / 10.0 - 5.0).collect();
        r.push_conf_snapshot(10, &confs, &lats, -6.0, 6.0);
        let s = &r.conf_snaps[0];
        assert!((s.conf_hist.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!((s.latent_hist.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!((s.mean_conf - 0.495).abs() < 1e-3);
    }

    #[test]
    fn json_roundtrips() {
        let mut r = Recorder::new();
        r.loss_curve.push((0, 2.3, 0.1));
        r.evals.push((10, 55.5, 1.2));
        r.osc_series.push((20, 7, 50));
        r.rate_series.push((30, 0.01, 0.02, f64::NAN));
        let j = r.to_json().to_string();
        assert!(j.contains("loss_curve"));
        // NaN serializes as a number token rust->rust parse may reject;
        // ensure we can at least parse the non-NaN members by replacing.
        let j = j.replace("NaN", "0");
        assert!(Json::parse(&j).is_ok());
    }

    #[test]
    fn best_eval() {
        let mut r = Recorder::new();
        assert!(r.best_eval_acc().is_none());
        r.evals.push((1, 10.0, 0.0));
        r.evals.push((2, 30.0, 0.0));
        r.evals.push((3, 20.0, 0.0));
        assert_eq!(r.best_eval_acc(), Some(30.0));
    }
}
