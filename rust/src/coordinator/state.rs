//! Training state container + binary checkpoints.
//!
//! The coordinator owns every tensor between steps; the HLO step maps
//! (state, batch, scalars) -> state'. Checkpoints are a simple
//! versioned little-endian binary: good enough for resumable runs and
//! the analysis examples, with no external dependencies.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 8] = b"TJCKPT01";

#[derive(Debug, Clone)]
pub struct TrainState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// EMA of the quantized segment (Q-EMA input / analysis).
    pub ema: Vec<f32>,
    /// Q-Ramping gradient accumulators (quantized segment).
    pub accum: Vec<f32>,
    /// Q-Ramping per-element amplification factors N_w.
    pub nw: Vec<f32>,
    /// Freeze baseline: 0/1 mask + pinned values.
    pub freeze_mask: Vec<f32>,
    pub freeze_value: Vec<f32>,
    pub step: usize,
}

impl TrainState {
    pub fn new(params: Vec<f32>, qw_total: usize) -> TrainState {
        assert!(qw_total <= params.len());
        let p = params.len();
        let ema = params[..qw_total].to_vec();
        TrainState {
            params,
            m: vec![0.0; p],
            v: vec![0.0; p],
            ema,
            accum: vec![0.0; qw_total],
            nw: vec![1.0; qw_total],
            freeze_mask: vec![0.0; qw_total],
            freeze_value: vec![0.0; qw_total],
            step: 0,
        }
    }

    pub fn qw_total(&self) -> usize {
        self.ema.len()
    }

    /// The quantized-weight prefix of the flat parameter vector.
    pub fn qw(&self) -> &[f32] {
        &self.params[..self.qw_total()]
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating checkpoint {}", path.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&(self.step as u64).to_le_bytes())?;
        f.write_all(&(self.params.len() as u64).to_le_bytes())?;
        f.write_all(&(self.qw_total() as u64).to_le_bytes())?;
        for buf in [
            &self.params,
            &self.m,
            &self.v,
            &self.ema,
            &self.accum,
            &self.nw,
            &self.freeze_mask,
            &self.freeze_value,
        ] {
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(buf.as_ptr() as *const u8, buf.len() * 4)
            };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<TrainState> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad checkpoint magic in {}", path.display());
        }
        let mut u64buf = [0u8; 8];
        f.read_exact(&mut u64buf)?;
        let step = u64::from_le_bytes(u64buf) as usize;
        f.read_exact(&mut u64buf)?;
        let p = u64::from_le_bytes(u64buf) as usize;
        f.read_exact(&mut u64buf)?;
        let qw = u64::from_le_bytes(u64buf) as usize;
        if qw > p || p > (1 << 33) {
            bail!("implausible checkpoint sizes p={p} qw={qw}");
        }
        let mut read_vec = |n: usize| -> Result<Vec<f32>> {
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };
        Ok(TrainState {
            params: read_vec(p)?,
            m: read_vec(p)?,
            v: read_vec(p)?,
            ema: read_vec(qw)?,
            accum: read_vec(qw)?,
            nw: read_vec(qw)?,
            freeze_mask: read_vec(qw)?,
            freeze_value: read_vec(qw)?,
            step,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_state_invariants() {
        let s = TrainState::new(vec![1.0, 2.0, 3.0, 4.0], 2);
        assert_eq!(s.qw_total(), 2);
        assert_eq!(s.qw(), &[1.0, 2.0]);
        assert_eq!(s.ema, vec![1.0, 2.0]);
        assert_eq!(s.nw, vec![1.0, 1.0]);
        assert_eq!(s.m.len(), 4);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join("tj_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.ckpt");
        let mut s = TrainState::new((0..10).map(|i| i as f32 * 0.5).collect(), 4);
        s.step = 77;
        s.nw[1] = 6.0;
        s.ema[0] = -1.25;
        s.save(&path).unwrap();
        let t = TrainState::load(&path).unwrap();
        assert_eq!(t.step, 77);
        assert_eq!(t.params, s.params);
        assert_eq!(t.nw, s.nw);
        assert_eq!(t.ema, s.ema);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("tj_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(TrainState::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
