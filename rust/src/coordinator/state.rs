//! Training state container + binary checkpoints.
//!
//! The coordinator owns every tensor between steps; the HLO step maps
//! (state, batch, scalars) -> state'. Checkpoints are a simple
//! versioned little-endian binary: good enough for resumable runs and
//! the analysis examples, with no external dependencies.
//!
//! Two on-disk versions exist:
//!
//! * **TJCKPT01** — the original format: header + eight little-endian
//!   f32 sections (params, opt moments, EMA, Q-Ramping, Freeze).
//! * **TJCKPT02** — TJCKPT01 plus an optional *packed-weights* section:
//!   per quantized manifest segment, the 4-bit level codes and E8M0
//!   scale bytes of the trainer's [`PackedMx`] mirror (written via
//!   `train --ckpt-packed`). The serving subsystem ([`crate::serve`])
//!   loads this section directly and never re-materializes the f32
//!   quantized weights. [`TrainState::load`] accepts both versions.
//!
//! TJCKPT02 packed-section layout (all integers little-endian):
//!
//! ```text
//! u32 nseg
//! per segment:
//!   u16 name_len, name bytes (utf-8, the manifest segment name)
//!   u64 offset   (flat element offset into the quantized prefix)
//!   u64 len      (elements)
//!   u64 cols     (trailing group axis)
//!   u8  table_id (level-decode table: 0=e2m1, 1=e3m0, 2=int4).
//!       Bit 7 (0x80) flags a non-MX group geometry: when set, one
//!       geometry-id byte follows (0=MX 1x32/E8M0, 1=NVFP4 1x16/E4M3).
//!       MX sections write the plain table id — byte-identical to the
//!       original TJCKPT02 — so old files load unchanged (geometry
//!       defaults to MX) and old readers fail loudly on new NVFP4
//!       files ("unknown level table id") instead of misdecoding.
//!   f32 tensor_scale (per-tensor mode; 1.0 in grouped mode)
//!   u64 nscales, scale bytes (one per group in the section's
//!                geometry; 0 = per-tensor)
//!   u64 ncodes,  code bytes  (two 4-bit level indices per byte)
//! ```

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant::{level_table_from_id, level_table_id, GroupGeom, PackedMx};

const MAGIC_V1: &[u8; 8] = b"TJCKPT01";
const MAGIC_V2: &[u8; 8] = b"TJCKPT02";

/// High bit of the packed-section table-id byte: set when a geometry-id
/// byte follows (see the module doc's layout). Registered table ids are
/// tiny, so the bit is always free.
const GEOM_FLAG: u8 = 0x80;

/// One quantized manifest segment in packed form, as stored in a
/// TJCKPT02 checkpoint: the segment's name, its flat offset into the
/// quantized prefix, and the codes + scales themselves.
#[derive(Debug, Clone)]
pub struct PackedSeg {
    pub name: String,
    pub offset: usize,
    pub packed: PackedMx,
}

#[derive(Debug, Clone)]
pub struct TrainState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// EMA of the quantized segment (Q-EMA input / analysis).
    pub ema: Vec<f32>,
    /// Q-Ramping gradient accumulators (quantized segment).
    pub accum: Vec<f32>,
    /// Q-Ramping per-element amplification factors N_w.
    pub nw: Vec<f32>,
    /// Freeze baseline: 0/1 mask + pinned values.
    pub freeze_mask: Vec<f32>,
    pub freeze_value: Vec<f32>,
    pub step: usize,
}

fn write_f32s<W: Write>(w: &mut W, buf: &[f32]) -> Result<()> {
    // Chunked so a 100M-param vector doesn't double resident memory.
    let mut bytes = Vec::with_capacity(4 * buf.len().min(1 << 16));
    for chunk in buf.chunks(1 << 16) {
        bytes.clear();
        for &v in chunk {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&bytes)?;
    }
    Ok(())
}

fn write_u64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Bound all length prefixes read from a checkpoint so a corrupt file
/// fails with a clear error instead of a giant allocation.
const MAX_SECTION: u64 = 1 << 33;

fn read_len<R: Read>(r: &mut R, what: &str) -> Result<usize> {
    let n = read_u64(r)?;
    if n > MAX_SECTION {
        bail!("implausible {what} length {n}");
    }
    Ok(n as usize)
}

impl TrainState {
    pub fn new(params: Vec<f32>, qw_total: usize) -> TrainState {
        assert!(qw_total <= params.len());
        let p = params.len();
        let ema = params[..qw_total].to_vec();
        TrainState {
            params,
            m: vec![0.0; p],
            v: vec![0.0; p],
            ema,
            accum: vec![0.0; qw_total],
            nw: vec![1.0; qw_total],
            freeze_mask: vec![0.0; qw_total],
            freeze_value: vec![0.0; qw_total],
            step: 0,
        }
    }

    pub fn qw_total(&self) -> usize {
        self.ema.len()
    }

    /// The quantized-weight prefix of the flat parameter vector.
    pub fn qw(&self) -> &[f32] {
        &self.params[..self.qw_total()]
    }

    fn sections(&self) -> [&Vec<f32>; 8] {
        [
            &self.params,
            &self.m,
            &self.v,
            &self.ema,
            &self.accum,
            &self.nw,
            &self.freeze_mask,
            &self.freeze_value,
        ]
    }

    fn write_header_and_sections<W: Write>(&self, f: &mut W, magic: &[u8; 8]) -> Result<()> {
        f.write_all(magic)?;
        write_u64(f, self.step as u64)?;
        write_u64(f, self.params.len() as u64)?;
        write_u64(f, self.qw_total() as u64)?;
        for buf in self.sections() {
            write_f32s(f, buf)?;
        }
        Ok(())
    }

    /// Plain TJCKPT01 checkpoint (no packed section) — loadable by any
    /// version of the tooling.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating checkpoint {}", path.display()))?;
        self.write_header_and_sections(&mut f, MAGIC_V1)
    }

    /// TJCKPT02 checkpoint carrying the packed quantized-weight mirror
    /// alongside the f32 training state. `segs` normally comes from
    /// [`Trainer::packed_segments`](crate::coordinator::Trainer::packed_segments);
    /// an empty slice writes a valid TJCKPT02 with zero packed segments
    /// (e.g. the fp32 variant, which has no quant mirror).
    pub fn save_packed(&self, path: &Path, segs: &[PackedSeg]) -> Result<()> {
        for seg in segs {
            if level_table_id(seg.packed.levels()).is_none() {
                bail!("segment {:?} uses an unregistered level table", seg.name);
            }
            if seg.packed.geom().id().is_none() {
                bail!("segment {:?} uses an unregistered group geometry", seg.name);
            }
            if seg.offset + seg.packed.len() > self.qw_total() {
                bail!(
                    "segment {:?} [{}..{}) exceeds quantized prefix {}",
                    seg.name,
                    seg.offset,
                    seg.offset + seg.packed.len(),
                    self.qw_total()
                );
            }
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating checkpoint {}", path.display()))?;
        self.write_header_and_sections(&mut f, MAGIC_V2)?;
        f.write_all(&(segs.len() as u32).to_le_bytes())?;
        for seg in segs {
            let name = seg.name.as_bytes();
            if name.len() > u16::MAX as usize {
                bail!("segment name too long: {} bytes", name.len());
            }
            f.write_all(&(name.len() as u16).to_le_bytes())?;
            f.write_all(name)?;
            write_u64(&mut f, seg.offset as u64)?;
            write_u64(&mut f, seg.packed.len() as u64)?;
            write_u64(&mut f, seg.packed.cols() as u64)?;
            let tid = level_table_id(seg.packed.levels()).unwrap();
            let geom = seg.packed.geom();
            if geom == GroupGeom::mx() {
                f.write_all(&[tid])?;
            } else {
                f.write_all(&[tid | GEOM_FLAG, geom.id().unwrap()])?;
            }
            f.write_all(&seg.packed.tensor_scale().to_le_bytes())?;
            write_u64(&mut f, seg.packed.scale_bytes().len() as u64)?;
            f.write_all(seg.packed.scale_bytes())?;
            write_u64(&mut f, seg.packed.codes().len() as u64)?;
            f.write_all(seg.packed.codes())?;
        }
        Ok(())
    }

    /// Load either checkpoint version, discarding any packed section.
    pub fn load(path: &Path) -> Result<TrainState> {
        Ok(TrainState::load_with_packed(path)?.0)
    }

    /// Load either checkpoint version; TJCKPT02 also yields the packed
    /// quantized-weight segments (empty for TJCKPT01). Errors on
    /// truncated files and on trailing bytes after the last section, so
    /// concatenated or partially-written checkpoints fail loudly.
    pub fn load_with_packed(path: &Path) -> Result<(TrainState, Vec<PackedSeg>)> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        let v2 = match &magic {
            m if m == MAGIC_V1 => false,
            m if m == MAGIC_V2 => true,
            _ => bail!("bad checkpoint magic in {}", path.display()),
        };
        let step = read_u64(&mut f)? as usize;
        let p = read_len(&mut f, "params")?;
        let qw = read_len(&mut f, "qw")?;
        if qw > p {
            bail!("implausible checkpoint sizes p={p} qw={qw}");
        }
        let mut read_vec = |n: usize| -> Result<Vec<f32>> {
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };
        let state = TrainState {
            params: read_vec(p)?,
            m: read_vec(p)?,
            v: read_vec(p)?,
            ema: read_vec(qw)?,
            accum: read_vec(qw)?,
            nw: read_vec(qw)?,
            freeze_mask: read_vec(qw)?,
            freeze_value: read_vec(qw)?,
            step,
        };
        let mut segs = Vec::new();
        if v2 {
            let mut b4 = [0u8; 4];
            f.read_exact(&mut b4).context("packed section count")?;
            let nseg = u32::from_le_bytes(b4);
            for _ in 0..nseg {
                let mut b2 = [0u8; 2];
                f.read_exact(&mut b2)?;
                let mut name = vec![0u8; u16::from_le_bytes(b2) as usize];
                f.read_exact(&mut name)?;
                let name = String::from_utf8(name).context("packed segment name")?;
                let offset = read_len(&mut f, "segment offset")?;
                let len = read_len(&mut f, "segment len")?;
                let cols = read_len(&mut f, "segment cols")?;
                // Geometry gates the allocations below: a corrupt
                // length prefix must fail here, not as a giant vec.
                if offset + len > qw {
                    bail!("segment {name:?} [{offset}..{}) exceeds qw {qw}", offset + len);
                }
                let mut b1 = [0u8; 1];
                f.read_exact(&mut b1)?;
                let has_geom = b1[0] & GEOM_FLAG != 0;
                let tid = b1[0] & !GEOM_FLAG;
                let Some(levels) = level_table_from_id(tid) else {
                    bail!("segment {name:?}: unknown level table id {tid}");
                };
                let geom = if has_geom {
                    f.read_exact(&mut b1)?;
                    let Some(g) = GroupGeom::from_id(b1[0]) else {
                        bail!("segment {name:?}: unknown group geometry id {}", b1[0]);
                    };
                    g
                } else {
                    GroupGeom::mx()
                };
                f.read_exact(&mut b4)?;
                let tensor_scale = f32::from_le_bytes(b4);
                let nscales = read_len(&mut f, "segment scales")?;
                if nscales > len {
                    bail!("segment {name:?}: {nscales} scale bytes for {len} elements");
                }
                let mut scales = vec![0u8; nscales];
                f.read_exact(&mut scales)?;
                let ncodes = read_len(&mut f, "segment codes")?;
                if ncodes != (len + 1) / 2 {
                    bail!("segment {name:?}: {ncodes} code bytes for {len} elements");
                }
                let mut codes = vec![0u8; ncodes];
                f.read_exact(&mut codes)?;
                // from_parts_geom re-validates byte counts against the
                // geometry and rejects invalid scale bytes (E8M0 NaN
                // 255, out-of-range E4M3), so a corrupt section fails
                // here with context instead of inside a serve kernel.
                let packed = PackedMx::from_parts_geom(
                    geom,
                    len,
                    cols,
                    codes,
                    scales,
                    tensor_scale,
                    levels,
                )
                .with_context(|| format!("packed segment {name:?}"))?;
                segs.push(PackedSeg { name, offset, packed });
            }
        }
        // Harden against truncated/concatenated files: the format is
        // self-delimiting, so any trailing byte means corruption.
        let mut extra = [0u8; 1];
        match f.read(&mut extra)? {
            0 => Ok((state, segs)),
            _ => bail!("trailing bytes after last section in {}", path.display()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{e2m1, MxQuantizer, NvQuantizer, Quantizer, Scaling};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("tj_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// File offset of the first packed segment's table-id byte (module
    /// doc layout: header, 8 f32 sections, nseg, name, offset/len/cols).
    fn tid_offset(p_len: usize, qw: usize, name_len: usize) -> usize {
        8 + 24 + 4 * (3 * p_len + 5 * qw) + 4 + 2 + name_len + 24
    }

    #[test]
    fn new_state_invariants() {
        let s = TrainState::new(vec![1.0, 2.0, 3.0, 4.0], 2);
        assert_eq!(s.qw_total(), 2);
        assert_eq!(s.qw(), &[1.0, 2.0]);
        assert_eq!(s.ema, vec![1.0, 2.0]);
        assert_eq!(s.nw, vec![1.0, 1.0]);
        assert_eq!(s.m.len(), 4);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let path = tmp("s.ckpt");
        let mut s = TrainState::new((0..10).map(|i| i as f32 * 0.5).collect(), 4);
        s.step = 77;
        s.nw[1] = 6.0;
        s.ema[0] = -1.25;
        s.save(&path).unwrap();
        let t = TrainState::load(&path).unwrap();
        assert_eq!(t.step, 77);
        assert_eq!(t.params, s.params);
        assert_eq!(t.nw, s.nw);
        assert_eq!(t.ema, s.ema);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_writes_explicit_little_endian() {
        // The header is followed by params[0]; byte order must be LE
        // regardless of host endianness (the old unsafe cast was not).
        let path = tmp("le.ckpt");
        let mut s = TrainState::new(vec![0.0; 2], 1);
        s.params[0] = 1.5f32;
        s.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], MAGIC_V1);
        assert_eq!(&bytes[32..36], &1.5f32.to_le_bytes());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let path = tmp("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(TrainState::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_trailing_bytes() {
        let path = tmp("trail.ckpt");
        let s = TrainState::new(vec![1.0; 6], 2);
        s.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.push(0xAB);
        std::fs::write(&path, &bytes).unwrap();
        let err = TrainState::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_truncated_packed_section() {
        let path = tmp("trunc.ckpt");
        let s = TrainState::new(vec![0.25; 64], 64);
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(s.qw(), 32, &mut p);
        let segs = vec![PackedSeg { name: "w".into(), offset: 0, packed: p }];
        s.save_packed(&path, &segs).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        assert!(TrainState::load_with_packed(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn packed_checkpoint_roundtrip_bit_exact() {
        let path = tmp("packed.ckpt");
        let n = 96;
        let params: Vec<f32> = (0..n).map(|i| ((i * 37) % 113) as f32 / 9.0 - 6.0).collect();
        let mut s = TrainState::new(params, 64);
        s.step = 5;
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&s.qw()[..64], 32, &mut p);
        let segs = vec![PackedSeg { name: "blocks.qkv_w".into(), offset: 0, packed: p.clone() }];
        s.save_packed(&path, &segs).unwrap();

        let (t, back) = TrainState::load_with_packed(&path).unwrap();
        assert_eq!(t.params, s.params);
        assert_eq!(t.step, 5);
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].name, "blocks.qkv_w");
        assert_eq!(back[0].offset, 0);
        assert_eq!(back[0].packed.codes(), p.codes());
        assert_eq!(back[0].packed.scale_bytes(), p.scale_bytes());
        assert_eq!(back[0].packed.dequantize(), p.dequantize());
        // `load` (v1 API) still works on v2 files, dropping the section.
        assert_eq!(TrainState::load(&path).unwrap().params, s.params);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_files_still_load_after_v2() {
        let path = tmp("v1.ckpt");
        let s = TrainState::new(vec![0.5; 10], 4);
        s.save(&path).unwrap();
        let (t, segs) = TrainState::load_with_packed(&path).unwrap();
        assert_eq!(t.params, s.params);
        assert!(segs.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn nvfp4_packed_checkpoint_roundtrips_geometry() {
        let path = tmp("nv.ckpt");
        let params: Vec<f32> = (0..64).map(|i| ((i * 41) % 89) as f32 / 7.0 - 5.0).collect();
        let s = TrainState::new(params, 64);
        let mut p = PackedMx::default();
        NvQuantizer::nvfp4().quantize_packed(s.qw(), 32, &mut p);
        assert_eq!(p.geom(), GroupGeom::nvfp4());
        let segs = vec![PackedSeg { name: "w".into(), offset: 0, packed: p.clone() }];
        s.save_packed(&path, &segs).unwrap();

        // The table-id byte carries the geometry flag, so a pre-NVFP4
        // reader fails loudly ("unknown level table id") on this file.
        let bytes = std::fs::read(&path).unwrap();
        let tid = tid_offset(64, 64, 1);
        assert_eq!(bytes[tid] & GEOM_FLAG, GEOM_FLAG);
        assert_eq!(bytes[tid + 1], GroupGeom::nvfp4().id().unwrap());

        let (_, back) = TrainState::load_with_packed(&path).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].packed.geom(), GroupGeom::nvfp4());
        assert_eq!(back[0].packed.codes(), p.codes());
        assert_eq!(back[0].packed.scale_bytes(), p.scale_bytes());
        assert_eq!(back[0].packed.dequantize(), p.dequantize());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_corrupt_scale_bytes_both_geometries() {
        // MX section with the E8M0 NaN byte 255 injected.
        let path = tmp("corrupt_mx.ckpt");
        let s = TrainState::new(vec![0.75; 64], 64);
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(s.qw(), 32, &mut p);
        s.save_packed(&path, &[PackedSeg { name: "w".into(), offset: 0, packed: p }])
            .unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // tid(1) + tensor_scale(4) + nscales(8) precede the scale bytes.
        let scales_at = tid_offset(64, 64, 1) + 1 + 4 + 8;
        bytes[scales_at] = 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = TrainState::load_with_packed(&path).unwrap_err();
        assert!(format!("{err:#}").contains("not a valid"), "{err:#}");

        // NVFP4 section with the E4M3 NaN byte 0x7F injected.
        let path2 = tmp("corrupt_nv.ckpt");
        let mut p = PackedMx::default();
        NvQuantizer::nvfp4().quantize_packed(s.qw(), 32, &mut p);
        s.save_packed(&path2, &[PackedSeg { name: "w".into(), offset: 0, packed: p }])
            .unwrap();
        let mut bytes = std::fs::read(&path2).unwrap();
        let scales_at = tid_offset(64, 64, 1) + 2 + 4 + 8;
        bytes[scales_at] = 0x7F;
        std::fs::write(&path2, &bytes).unwrap();
        let err = TrainState::load_with_packed(&path2).unwrap_err();
        assert!(format!("{err:#}").contains("not a valid"), "{err:#}");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&path2).ok();
    }

    #[test]
    fn load_rejects_unknown_geometry_id() {
        let path = tmp("badgeom.ckpt");
        let s = TrainState::new(vec![0.5; 64], 64);
        let mut p = PackedMx::default();
        NvQuantizer::nvfp4().quantize_packed(s.qw(), 32, &mut p);
        s.save_packed(&path, &[PackedSeg { name: "w".into(), offset: 0, packed: p }])
            .unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[tid_offset(64, 64, 1) + 1] = 9;
        std::fs::write(&path, &bytes).unwrap();
        let err = TrainState::load_with_packed(&path).unwrap_err();
        assert!(format!("{err:#}").contains("unknown group geometry"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_packed_rejects_out_of_range_segment() {
        let path = tmp("oob.ckpt");
        let s = TrainState::new(vec![0.5; 40], 32);
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&s.qw()[..32], 32, &mut p);
        let segs = vec![PackedSeg { name: "w".into(), offset: 8, packed: p }];
        assert!(s.save_packed(&path, &segs).is_err());
        std::fs::remove_file(&path).ok();
    }
}
