//! The training driver: owns state, data, policies and metrics; calls
//! the AOT HLO step functions. Python is never involved at run time.
//!
//! The per-step quant mirror runs on the packed 4-bit core: each
//! quantized manifest segment is quantized to [`PackedMx`] codes in
//! parallel, the oscillation tracker compares codes, and controllers
//! (Q-Ramping / Freeze) observe an f32 dequant view that is bit-exact
//! to the old fake-quant mirror.

use anyhow::{bail, Result};

use crate::config::{Policy, TrainConfig};
use crate::coordinator::freeze::FreezeController;
use crate::coordinator::observatory::OscObservatory;
use crate::coordinator::qramping::QRampingController;
use crate::coordinator::recorder::Recorder;
use crate::coordinator::state::{PackedSeg, TrainState};
use crate::data::{Batcher, EvalSet, SynthVision};
use crate::metrics::{
    latents_geom, quant_confidence_geom, OscTracker, OscWindow, PackedOscTracker, RateTracker,
};
use crate::obs::osclog::{split_segments, OscLogWriter};
use crate::obs::{Counter, FCounter, Gauge, MetricsRegistry, TraceSink, TsRing};
use crate::util::json::{num, s};
use crate::quant::{
    fp4_format, Fp4Format, GroupGeom, Int4Quantizer, MxQuantizer, NvQuantizer,
    PackedMx, QemaQuantizer, Quantizer, Scaling,
};
use crate::runtime::{Arg, ModelArtifacts};
use crate::util::parallel::{default_workers, parallel_for_each_mut};

#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub acc_pct: f64,
    pub mean_loss: f64,
    pub samples: usize,
}

/// How the forward weight quantizer is mirrored on the host.
#[derive(Debug, Clone, Copy, PartialEq)]
enum WqMirror {
    Identity,
    Mx,
    Qema,
    Int4,
    Nvfp4,
}

/// One quantized manifest segment, pre-validated at construction to
/// tile the [0, qw_total) prefix contiguously.
#[derive(Debug, Clone, Copy)]
struct SegMeta {
    offset: usize,
    size: usize,
    cols: usize,
}

impl SegMeta {
    fn range(&self) -> std::ops::Range<usize> {
        self.offset..self.offset + self.size
    }
}

/// The metric oscillation window: code-compare over the packed mirror
/// when one exists, f32 compare for the identity (fp32) mirror.
enum OscState {
    F32(OscTracker),
    Packed(PackedOscTracker),
}

impl OscState {
    fn steps(&self) -> usize {
        match self {
            OscState::F32(t) => t.steps(),
            OscState::Packed(t) => t.steps(),
        }
    }

    fn oscillating_count(&self, threshold: f32) -> usize {
        match self {
            OscState::F32(t) => t.oscillating_count(threshold),
            OscState::Packed(t) => t.oscillating_count(threshold),
        }
    }

    fn reset_window(&mut self) {
        match self {
            OscState::F32(t) => t.reset_window(),
            OscState::Packed(t) => t.reset_window(),
        }
    }

    fn window(&self) -> &OscWindow {
        match self {
            OscState::F32(t) => t.window(),
            OscState::Packed(t) => t.window(),
        }
    }
}

/// Per-step phase names, in emission order, shared by the phase
/// fcounters and the trainer's Chrome trace spans (`train.<phase>`).
pub(crate) const TRAIN_PHASES: [&str; 5] = ["hlo", "mirror", "controllers", "metrics", "eval"];

/// Trace `tid` for trainer spans (serve uses 0 = scheduler, 1 = fleet).
pub(crate) const TRAIN_TRACE_TID: u64 = 2;

/// Retained window of the trainer's per-step rings.
pub(crate) const TRAIN_RING_CAP: usize = 256;

/// Trainer instrumentation: per-step phase timing plus the oscillation
/// flip-rate / rate-of-change metrics re-exported as registry gauges so
/// one snapshot surface covers serving and training alike. Shared with
/// the synthetic (no-HLO) trainer so both populate identical names.
pub(crate) struct TrainerObs {
    pub(crate) reg: MetricsRegistry,
    pub(crate) steps: Counter,
    pub(crate) hlo_ms: FCounter,
    pub(crate) mirror_ms: FCounter,
    pub(crate) controllers_ms: FCounter,
    pub(crate) metrics_ms: FCounter,
    pub(crate) eval_ms: FCounter,
    pub(crate) osc_flips: Gauge,
    pub(crate) osc_ratio: Gauge,
    pub(crate) rate_w: Gauge,
    pub(crate) rate_wq: Gauge,
    pub(crate) rate_y: Gauge,
    /// Rolling wall-clock per step (`train.step_ms`).
    pub(crate) step_ms: TsRing,
    /// Rolling global flip count per step (`train.osc.step_flips`).
    pub(crate) step_flips: TsRing,
}

impl TrainerObs {
    pub(crate) fn new() -> TrainerObs {
        let reg = MetricsRegistry::new();
        TrainerObs {
            steps: reg.counter("train.steps"),
            hlo_ms: reg.fcounter("train.phase.hlo_ms"),
            mirror_ms: reg.fcounter("train.phase.mirror_ms"),
            controllers_ms: reg.fcounter("train.phase.controllers_ms"),
            metrics_ms: reg.fcounter("train.phase.metrics_ms"),
            eval_ms: reg.fcounter("train.phase.eval_ms"),
            osc_flips: reg.gauge("train.osc.flips"),
            osc_ratio: reg.gauge("train.osc.ratio"),
            rate_w: reg.gauge("train.rate.w"),
            rate_wq: reg.gauge("train.rate.wq"),
            rate_y: reg.gauge("train.rate.y"),
            step_ms: reg.ring("train.step_ms", TRAIN_RING_CAP),
            step_flips: reg.ring("train.osc.step_flips", TRAIN_RING_CAP),
            reg,
        }
    }
}

pub struct Trainer<'a> {
    pub arts: &'a ModelArtifacts,
    pub cfg: TrainConfig,
    pub state: TrainState,
    pub rec: Recorder,
    batcher: Batcher,
    evalset: EvalSet,
    probe_x: Vec<f32>,
    qramp: Option<QRampingController>,
    freeze: Option<FreezeController>,
    dampen_lambda: f32,
    // --- metric machinery ---
    mirror: WqMirror,
    fmt: &'static Fp4Format,
    scaling: Scaling,
    seg_meta: Vec<SegMeta>,
    /// Packed quant mirror, one buffer per quantized segment.
    packed: Vec<PackedMx>,
    /// f32 dequant view of `packed` (bit-exact to the fake-quant mirror).
    wq_buf: Vec<f32>,
    rate_w: RateTracker,
    rate_wq: RateTracker,
    rate_y: RateTracker,
    osc: Option<OscState>,
    scratch_conf: Vec<f32>,
    scratch_lat: Vec<f32>,
    obs: TrainerObs,
    observatory: Option<OscObservatory>,
    trace: Option<TraceSink>,
    /// Running virtual/wall timeline for non-deterministic trace spans.
    trace_clock: f64,
}

impl<'a> Trainer<'a> {
    pub fn new(arts: &'a ModelArtifacts, cfg: TrainConfig, params: Vec<f32>) -> Result<Trainer<'a>> {
        let man = &arts.manifest;
        if params.len() != man.total_params {
            bail!("param vector {} != manifest {}", params.len(), man.total_params);
        }
        if cfg.batch != man.batch {
            bail!("config batch {} != artifact batch {}", cfg.batch, man.batch);
        }
        // The packed mirror and wq_buf slicing assume the quantized
        // segments tile [0, qw_total) contiguously. Manifest::validate
        // enforces this at load time; re-assert it cheaply here so a
        // manifest that bypassed validation fails loudly, not silently.
        let mut seg_meta = Vec::new();
        let mut covered = 0usize;
        for seg in man.quantized_segments() {
            assert_eq!(
                seg.offset, covered,
                "quantized segment {:?} breaks the contiguous quantized prefix",
                seg.name
            );
            seg_meta.push(SegMeta { offset: seg.offset, size: seg.size, cols: seg.cols() });
            covered += seg.size;
        }
        assert_eq!(covered, man.qw_total, "quantized segments must cover qw_total");
        let state = TrainState::new(params, man.qw_total);
        let ds = SynthVision::new(
            man.model.img,
            man.model.classes,
            cfg.data_seed,
            cfg.train_size,
            cfg.val_size,
        );
        let batcher = Batcher::new(ds.clone(), cfg.batch, cfg.train_seed);
        let evalset = EvalSet::new(ds, cfg.batch, cfg.eval_samples);
        let (probe_x, _) = batcher.fixed_batch(cfg.train_seed);

        let mirror = if man.variant.kind == "fp32"
            || !man.variant.enabled.get(1).copied().unwrap_or(true)
        {
            WqMirror::Identity
        } else if man.variant.kind == "int4" {
            WqMirror::Int4
        } else if man.variant.kind == "nvfp4" {
            WqMirror::Nvfp4
        } else if man.variant.qema {
            WqMirror::Qema
        } else {
            WqMirror::Mx
        };
        let fmt = fp4_format(&man.variant.fwd_fmt)
            .unwrap_or_else(|| crate::quant::e2m1());
        let scaling = Scaling::parse(&man.variant.scaling).unwrap_or(Scaling::TruncationFree);

        let qramp = match &cfg.policy {
            Policy::QRamping { .. } => Some(QRampingController::new(&cfg.policy, man.qw_total)),
            _ => None,
        };
        let freeze = match &cfg.policy {
            Policy::Freeze { .. } => Some(FreezeController::new(&cfg.policy, man.qw_total)),
            _ => None,
        };
        let dampen_lambda = match &cfg.policy {
            Policy::Dampen { lambda } => *lambda,
            _ => 0.0,
        };
        let qw = man.qw_total;
        let packed = vec![PackedMx::default(); seg_meta.len()];
        Ok(Trainer {
            arts,
            cfg,
            state,
            rec: Recorder::new(),
            batcher,
            evalset,
            probe_x,
            qramp,
            freeze,
            dampen_lambda,
            mirror,
            fmt,
            scaling,
            seg_meta,
            packed,
            wq_buf: vec![0.0; qw],
            rate_w: RateTracker::new(),
            rate_wq: RateTracker::new(),
            rate_y: RateTracker::new(),
            osc: None,
            scratch_conf: Vec::new(),
            scratch_lat: Vec::new(),
            obs: TrainerObs::new(),
            observatory: None,
            trace: None,
            trace_clock: 0.0,
        })
    }

    /// Short name of the active forward-quantizer mirror.
    pub fn mirror_name(&self) -> &'static str {
        match self.mirror {
            WqMirror::Identity => "identity",
            WqMirror::Mx => "mx",
            WqMirror::Qema => "qema",
            WqMirror::Int4 => "int4",
            WqMirror::Nvfp4 => "nvfp4",
        }
    }

    /// Attach an oscillation observatory writing OSCLOG01 telemetry to
    /// `writer`: one slice per depth of each quantized manifest segment,
    /// recorded every step under the active mirror's group geometry.
    /// Requires an oscillation window (`metrics.osc_window > 0`).
    pub fn make_observatory(&mut self, writer: OscLogWriter, seed: u64) -> Result<()> {
        if self.cfg.metrics.osc_window == 0 {
            bail!("observatory requires metrics.osc_window > 0");
        }
        let man = &self.arts.manifest;
        let mut segs = Vec::new();
        for seg in man.quantized_segments() {
            segs.extend(split_segments(&seg.name, &seg.shape, seg.offset));
        }
        let meta = vec![
            ("variant".to_string(), s(&self.cfg.variant)),
            ("mirror".to_string(), s(self.mirror_name())),
            ("seed".to_string(), num(seed as f64)),
        ];
        self.observatory = Some(OscObservatory::new(
            segs,
            man.qw_total,
            self.fmt,
            self.scaling,
            self.metric_geom(),
            self.cfg.metrics.rw_threshold,
            self.cfg.metrics.osc_window,
            meta,
            writer,
        ));
        Ok(())
    }

    /// The attached observatory, if any.
    pub fn observatory(&self) -> Option<&OscObservatory> {
        self.observatory.as_ref()
    }

    /// Mutable access (flush/finish at end of run).
    pub fn observatory_mut(&mut self) -> Option<&mut OscObservatory> {
        self.observatory.as_mut()
    }

    /// Attach a Chrome trace sink: every step emits one `train.<phase>`
    /// span per phase ([`TRAIN_PHASES`]) at tid 2. A deterministic sink
    /// gets a simulated timeline (1 ms per phase) instead of wall time,
    /// so fixed (seed, config) runs produce byte-identical traces.
    pub fn set_trace(&mut self, sink: TraceSink) {
        self.trace = Some(sink);
    }

    /// The attached trace sink, if any.
    pub fn trace_mut(&mut self) -> Option<&mut TraceSink> {
        self.trace.as_mut()
    }

    /// The trainer's metrics registry: `train.steps`,
    /// `train.phase.{hlo,mirror,controllers,metrics,eval}_ms`, and the
    /// `train.osc.*` / `train.rate.*` gauges mirroring the Recorder's
    /// oscillation and rate-of-change series.
    pub fn registry(&self) -> &MetricsRegistry {
        &self.obs.reg
    }

    fn metrics_enabled(&self) -> bool {
        let m = &self.cfg.metrics;
        m.rate_window > 0 || m.osc_window > 0 || m.conf_every > 0
    }

    /// Mirror the forward quantized weights of the whole quantized
    /// segment (pure Rust; bit-identical to the HLO): quantize each
    /// manifest segment to packed codes in parallel and refresh the
    /// f32 dequant view in `wq_buf` for the controllers.
    pub fn mirror_wq(&mut self) {
        self.mirror_wq_inner(true);
    }

    /// One fused parallel pass over the quantized segments: quantize to
    /// packed codes and, when something consumes the f32 view this step
    /// (controllers, rate trackers, external callers), immediately
    /// dequantize each segment into its `wq_buf` slice.
    fn mirror_wq_inner(&mut self, refresh_view: bool) {
        if self.mirror == WqMirror::Identity {
            self.wq_buf.copy_from_slice(self.state.qw());
            return;
        }
        let segs = &self.seg_meta;
        let params = &self.state.params;
        let ema = &self.state.ema;
        let (mirror, fmt, scaling) = (self.mirror, self.fmt, self.scaling);
        let workers = default_workers().min(segs.len().max(1));
        let quantize = |i: usize, p: &mut PackedMx| {
            let seg = segs[i];
            let w = &params[seg.range()];
            match mirror {
                WqMirror::Mx => {
                    MxQuantizer { fmt, scaling }.quantize_packed(w, seg.cols, p)
                }
                WqMirror::Qema => QemaQuantizer { fmt, scaling, ema: &ema[seg.range()] }
                    .quantize_packed(w, seg.cols, p),
                WqMirror::Int4 => Int4Quantizer.quantize_packed(w, seg.cols, p),
                WqMirror::Nvfp4 => NvQuantizer::nvfp4().quantize_packed(w, seg.cols, p),
                WqMirror::Identity => unreachable!(),
            }
        };
        if !refresh_view {
            parallel_for_each_mut(&mut self.packed, workers, |i, p| quantize(i, p));
            return;
        }
        let mut pairs: Vec<(&mut PackedMx, &mut [f32])> = Vec::with_capacity(segs.len());
        let mut rest: &mut [f32] = &mut self.wq_buf;
        for (seg, p) in segs.iter().zip(&mut self.packed) {
            let (head, tail) = rest.split_at_mut(seg.size);
            pairs.push((p, head));
            rest = tail;
        }
        debug_assert!(rest.is_empty(), "segments tile the quantized prefix");
        parallel_for_each_mut(&mut pairs, workers, |i, (p, out)| {
            quantize(i, p);
            p.dequantize_into(out);
        });
    }

    /// Latest mirrored quantized weights (call `mirror_wq` first).
    pub fn wq(&self) -> &[f32] {
        &self.wq_buf
    }

    /// Latest packed quant mirror, one [`PackedMx`] per quantized
    /// manifest segment (empty buffers for the identity mirror).
    pub fn packed_wq(&self) -> &[PackedMx] {
        &self.packed
    }

    /// Refresh the packed mirror and snapshot it as named checkpoint
    /// segments (the TJCKPT02 packed section). Empty for the identity
    /// (fp32) mirror, which has no packed form.
    pub fn packed_segments(&mut self) -> Vec<PackedSeg> {
        if self.mirror == WqMirror::Identity {
            return Vec::new();
        }
        self.mirror_wq_inner(false);
        self.arts
            .manifest
            .quantized_segments()
            .zip(&self.packed)
            .map(|(seg, p)| PackedSeg {
                name: seg.name.clone(),
                offset: seg.offset,
                packed: p.clone(),
            })
            .collect()
    }

    /// Write a TJCKPT02 checkpoint carrying the packed quantized-weight
    /// mirror, the input of the native serving path (`tetrajet serve`).
    pub fn save_packed_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        let segs = self.packed_segments();
        self.state.save_packed(path, &segs)
    }

    /// The group geometry the confidence/latent metrics evaluate under
    /// — the NVFP4 mirror's 16-element E4M3 groups, MX for everything
    /// else (identity included: the fp32 variant's hypothetical
    /// quantizer is the MX one).
    fn metric_geom(&self) -> GroupGeom {
        match self.mirror {
            WqMirror::Nvfp4 => GroupGeom::nvfp4(),
            _ => GroupGeom::mx(),
        }
    }

    /// Latent weights / confidences over all quantized segments.
    pub fn snapshot_latents(&mut self) -> (Vec<f32>, Vec<f32>) {
        let arts = self.arts;
        let man = &arts.manifest;
        let geom = self.metric_geom();
        let mut lat = Vec::with_capacity(man.qw_total);
        let mut conf = Vec::with_capacity(man.qw_total);
        let mut seg_buf = Vec::new();
        for seg in man.quantized_segments() {
            let w = &self.state.params[seg.range()];
            latents_geom(w, seg.cols(), self.fmt, self.scaling, geom, &mut seg_buf);
            lat.extend_from_slice(&seg_buf);
            quant_confidence_geom(w, seg.cols(), self.fmt, self.scaling, geom, &mut seg_buf);
            conf.extend_from_slice(&seg_buf);
        }
        (lat, conf)
    }

    /// Cumulative per-phase milliseconds, in [`TRAIN_PHASES`] order.
    fn phase_totals(&self) -> [f64; 5] {
        [
            self.obs.hlo_ms.get(),
            self.obs.mirror_ms.get(),
            self.obs.controllers_ms.get(),
            self.obs.metrics_ms.get(),
            self.obs.eval_ms.get(),
        ]
    }

    /// Emit this step's phase spans from the fcounter deltas. The
    /// deterministic timeline is simulated (1 ms per phase, 5 ms per
    /// step); otherwise measured deltas advance a running clock.
    fn emit_step_trace(&mut self, step: usize, before: [f64; 5]) {
        let after = self.phase_totals();
        let Some(tr) = &mut self.trace else { return };
        if tr.deterministic() {
            let base = step as f64 * TRAIN_PHASES.len() as f64;
            for (i, name) in TRAIN_PHASES.iter().enumerate() {
                tr.duration(
                    &format!("train.{name}"),
                    base + i as f64,
                    1.0,
                    TRAIN_TRACE_TID,
                    vec![("step", num(step as f64))],
                );
            }
        } else {
            for (i, name) in TRAIN_PHASES.iter().enumerate() {
                let d = (after[i] - before[i]).max(0.0);
                tr.duration(
                    &format!("train.{name}"),
                    self.trace_clock,
                    d,
                    TRAIN_TRACE_TID,
                    vec![("step", num(step as f64))],
                );
                self.trace_clock += d;
            }
        }
    }

    /// Run one optimization step; returns (train loss, batch accuracy).
    pub fn step(&mut self) -> Result<(f32, f32)> {
        let step = self.state.step;
        let t_step = std::time::Instant::now();
        let phases_before = self.trace.is_some().then(|| self.phase_totals());
        // Policy inputs for this step.
        if let Some(q) = &self.qramp {
            self.state.nw = q.nw_for_step(step);
        }
        if let Some(f) = &self.freeze {
            self.state.freeze_mask.copy_from_slice(&f.mask);
            self.state.freeze_value.copy_from_slice(&f.value);
        }
        let lr = self.cfg.lr_at(step);
        let (x, y) = self.batcher.next_batch();
        let t_hlo = std::time::Instant::now();
        let outs = self.arts.train_step.call(&[
            Arg::F32(&self.state.params),
            Arg::F32(&self.state.m),
            Arg::F32(&self.state.v),
            Arg::F32(&self.state.ema),
            Arg::F32(&self.state.accum),
            Arg::F32(&self.state.nw),
            Arg::F32(&self.state.freeze_mask),
            Arg::F32(&self.state.freeze_value),
            Arg::ScalarF32(lr),
            Arg::ScalarF32(self.cfg.weight_decay),
            Arg::ScalarF32(self.cfg.ema_beta),
            Arg::ScalarF32(self.dampen_lambda),
            Arg::ScalarI32(step as i32),
            Arg::ScalarI32(self.cfg.train_seed as i32),
            Arg::F32(&x),
            Arg::I32(&y),
        ])?;
        self.obs.hlo_ms.add(t_hlo.elapsed().as_secs_f64() * 1e3);
        let mut it = outs.into_iter();
        self.state.params = it.next().unwrap().data;
        self.state.m = it.next().unwrap().data;
        self.state.v = it.next().unwrap().data;
        self.state.ema = it.next().unwrap().data;
        self.state.accum = it.next().unwrap().data;
        let loss = it.next().unwrap().item()?;
        let acc = it.next().unwrap().item()?;
        self.state.step += 1;
        self.obs.steps.inc();

        self.after_step(step, loss, acc)?;
        self.obs.step_ms.push(t_step.elapsed().as_secs_f64() * 1e3);
        if let Some(before) = phases_before {
            self.emit_step_trace(step, before);
        }
        Ok((loss, acc))
    }

    /// Post-step bookkeeping: controllers + metric trackers.
    fn after_step(&mut self, step: usize, loss: f32, acc: f32) -> Result<()> {
        self.rec.loss_curve.push((step, loss, acc));

        let need_wq = self.qramp.is_some() || self.freeze.is_some() || self.metrics_enabled();
        if need_wq {
            // The osc tracker reads packed codes directly; the
            // controllers, the rate tracker and the observatory's
            // W−Wq distance consume the f32 view.
            let need_view = self.qramp.is_some()
                || self.freeze.is_some()
                || self.cfg.metrics.rate_window > 0
                || self.observatory.is_some();
            let t_mirror = std::time::Instant::now();
            self.mirror_wq_inner(need_view);
            self.obs.mirror_ms.add(t_mirror.elapsed().as_secs_f64() * 1e3);
        }
        let t_ctrl = std::time::Instant::now();
        if let Some(q) = &mut self.qramp {
            q.observe(step, self.state.qw(), &self.wq_buf);
        }
        if let Some(f) = &mut self.freeze {
            f.observe(step, self.state.qw(), &self.wq_buf);
        }
        self.obs.controllers_ms.add(t_ctrl.elapsed().as_secs_f64() * 1e3);

        let t_metrics = std::time::Instant::now();
        let m = self.cfg.metrics.clone();
        if m.rate_window > 0 {
            self.rate_w.observe(self.state.qw());
            self.rate_wq.observe(&self.wq_buf);
            if m.probe_every > 0 && (step + 1) % m.probe_every == 0 {
                let act = self.probe_activation()?;
                self.rate_y.observe(&act);
            }
            if (step + 1) % m.rate_window == 0 {
                let ry = if self.rate_y.steps() > 0 { self.rate_y.rate() } else { f64::NAN };
                self.obs.rate_w.set(self.rate_w.rate());
                self.obs.rate_wq.set(self.rate_wq.rate());
                self.obs.rate_y.set(ry);
                self.rec
                    .rate_series
                    .push((step + 1, self.rate_w.rate(), self.rate_wq.rate(), ry));
                self.rate_w.reset_window();
                self.rate_wq.reset_window();
                self.rate_y.reset_window();
            }
        }
        if m.osc_window > 0 {
            match &mut self.osc {
                None => {
                    self.osc = Some(if self.mirror == WqMirror::Identity {
                        OscState::F32(OscTracker::new(self.state.qw(), &self.wq_buf))
                    } else {
                        OscState::Packed(PackedOscTracker::new(
                            self.state.qw(),
                            &self.packed,
                        ))
                    });
                }
                Some(t) => {
                    match t {
                        OscState::F32(t) => t.observe(self.state.qw(), &self.wq_buf),
                        OscState::Packed(t) => t.observe(self.state.qw(), &self.packed),
                    }
                    if let Some(ob) = &mut self.observatory {
                        let flips =
                            ob.record_step(step + 1, self.state.qw(), &self.wq_buf, t.window());
                        self.obs.step_flips.push(flips as f64);
                    }
                    if t.steps() >= m.osc_window {
                        let count = t.oscillating_count(m.rw_threshold);
                        if let Some(ob) = &mut self.observatory {
                            let total = ob.record_window_end(step + 1, t.window());
                            debug_assert_eq!(
                                total, count,
                                "per-segment partition must sum to the global count"
                            );
                        }
                        self.obs.osc_flips.set(count as f64);
                        self.obs
                            .osc_ratio
                            .set(count as f64 / self.wq_buf.len().max(1) as f64);
                        self.rec.osc_series.push((step + 1, count, m.osc_window));
                        t.reset_window();
                        if let Some(ob) = &mut self.observatory {
                            ob.note_reset();
                        }
                    }
                }
            }
        }
        if m.conf_every > 0 && (step + 1) % m.conf_every == 0 {
            self.conf_snapshot(step + 1);
        }
        self.obs.metrics_ms.add(t_metrics.elapsed().as_secs_f64() * 1e3);
        if self.cfg.eval_every > 0 && (step + 1) % self.cfg.eval_every == 0 {
            let t_eval = std::time::Instant::now();
            let ev = self.eval()?;
            self.obs.eval_ms.add(t_eval.elapsed().as_secs_f64() * 1e3);
            self.rec.evals.push((step + 1, ev.acc_pct, ev.mean_loss));
        }
        Ok(())
    }

    pub fn conf_snapshot(&mut self, step: usize) {
        let arts = self.arts;
        let man = &arts.manifest;
        let (qn, qp) = (self.fmt.qn(), self.fmt.qp());
        let geom = self.metric_geom();
        let mut all_lat = Vec::with_capacity(man.qw_total);
        let mut all_conf = Vec::with_capacity(man.qw_total);
        for seg in man.quantized_segments() {
            let w = &self.state.params[seg.range()];
            latents_geom(w, seg.cols(), self.fmt, self.scaling, geom, &mut self.scratch_lat);
            all_lat.extend_from_slice(&self.scratch_lat);
            quant_confidence_geom(
                w,
                seg.cols(),
                self.fmt,
                self.scaling,
                geom,
                &mut self.scratch_conf,
            );
            all_conf.extend_from_slice(&self.scratch_conf);
        }
        self.rec.push_conf_snapshot(step, &all_conf, &all_lat, qn, qp);
    }

    /// Fixed-input activation probe (r(Y) metric).
    pub fn probe_activation(&self) -> Result<Vec<f32>> {
        let outs = self.arts.probe.call(&[
            Arg::F32(&self.state.params),
            Arg::F32(&self.state.ema),
            Arg::F32(&self.probe_x),
        ])?;
        Ok(outs.into_iter().next().unwrap().data)
    }

    /// Full validation pass.
    pub fn eval(&self) -> Result<EvalResult> {
        let nb = self.evalset.num_batches();
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for b in 0..nb {
            let (x, y) = self.evalset.batch(b);
            let outs = self.arts.eval_step.call(&[
                Arg::F32(&self.state.params),
                Arg::F32(&self.state.ema),
                Arg::F32(&x),
                Arg::I32(&y),
            ])?;
            loss_sum += outs[0].item()? as f64;
            correct += outs[1].item()? as f64;
        }
        let n = self.evalset.num_samples().max(1);
        Ok(EvalResult {
            acc_pct: 100.0 * correct / n as f64,
            mean_loss: loss_sum / n as f64,
            samples: n,
        })
    }

    /// Train for the configured number of steps, logging progress.
    pub fn run(&mut self) -> Result<EvalResult> {
        let total = self.cfg.steps;
        let log_every = (total / 10).max(1);
        while self.state.step < total {
            let (loss, acc) = self.step()?;
            if self.state.step % log_every == 0 || self.state.step == total {
                let extra = match (&self.qramp, &self.freeze) {
                    (Some(q), _) => format!(" ramped={:.1}%", 100.0 * q.ramped_fraction()),
                    (_, Some(f)) => format!(" frozen={:.1}%", 100.0 * f.frozen_fraction()),
                    _ => String::new(),
                };
                crate::loginfo!(
                    "[{}/{}] {} loss={loss:.4} batch_acc={acc:.3}{extra}",
                    self.state.step,
                    total,
                    self.cfg.variant
                );
            }
        }
        let ev = self.eval()?;
        self.rec.evals.push((self.state.step, ev.acc_pct, ev.mean_loss));
        Ok(ev)
    }

    pub fn qramping_ref(&self) -> Option<&QRampingController> {
        self.qramp.as_ref()
    }

    pub fn freeze_ref(&self) -> Option<&FreezeController> {
        self.freeze.as_ref()
    }
}
