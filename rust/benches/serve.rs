//! Serving-path benchmarks: the fused packed GEMM against the
//! dequantize-then-matmul baseline on ViT-block-sized layers, plus
//! end-to-end engine throughput at batch 1/16/64.
//!
//! Engine results are also emitted as machine-readable `BENCH {...}`
//! JSON lines (one per batch size) so CI can track throughput/latency.

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use harness::Bench;
use tetrajet::quant::{e2m1, MxQuantizer, PackedMx, Quantizer, Scaling};
use tetrajet::serve::{
    fused_matmul, fused_matmul_at, matmul_ref, simd, ActQuant, LatencyRecorder, PackedVit,
    ServeConfig, ServeEngine, ServeFleet, ServeGeom, SimdLevel, WeightQuant,
};
use tetrajet::util::json::{num, obj, s};
use tetrajet::util::rng::Rng;

fn main() {
    let b = Bench::new("serve");
    let mut rng = Rng::new(42);
    let workers = 4;

    // --- fused GEMM vs dequant + matmul ---
    // vit-micro block shapes at batch 16: n = 16 * 65 tokens.
    let n = 16 * 65;
    for (label, rows, d) in
        [("qkv 192x64", 192usize, 64usize), ("fc1 256x64", 256, 64), ("fc2 64x256", 64, 256)]
    {
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * d).map(|_| rng.normal() * 0.1).collect();
        let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
        let mut p = PackedMx::default();
        q.quantize_packed(&w, d, &mut p);
        let mut wbuf = vec![0.0f32; rows * d];
        // Bit-exactness of the two paths, re-asserted where measured.
        p.dequantize_into(&mut wbuf);
        assert_eq!(
            fused_matmul(&x, n, &p, 0, rows, None, workers),
            matmul_ref(&x, n, d, &wbuf, rows, None),
            "fused must match dequant+matmul ({label})"
        );
        let items = (n * rows * d) as u64;
        b.case(&format!("fused_packed {label} (n={n})"), items, || {
            std::hint::black_box(fused_matmul(&x, n, &p, 0, rows, None, workers));
        });
        b.case(&format!("dequant+matmul {label} (n={n})"), items, || {
            p.dequantize_into(&mut wbuf);
            std::hint::black_box(matmul_ref(&x, n, d, &wbuf, rows, None));
        });
        // Scalar vs SIMD fused GEMM at each dispatch level the host
        // has (the AVX2-vs-scalar ratio is the ISSUE 8 acceptance
        // number; single worker isolates the kernel from threading).
        for level in [SimdLevel::Off, SimdLevel::Ssse3, SimdLevel::Avx2] {
            if !simd::available(level) {
                continue;
            }
            b.case(&format!("fused_{} {label} (n={n})", level.as_str()), items, || {
                std::hint::black_box(fused_matmul_at(level, &x, n, &p, 0, rows, None, 1));
            });
        }
    }

    // --- engine throughput at batch 1 / 16 / 64 ---
    let geom = ServeGeom::new(32, 4, 64, 4, 4, 10, 4); // vit-micro
    let params: Vec<f32> = (0..geom.total_params()).map(|_| rng.normal() * 0.05).collect();
    let fmt = e2m1();
    let model = PackedVit::build(
        geom.clone(),
        &params,
        None,
        WeightQuant::Mx { fmt, scaling: Scaling::TruncationFree },
        ActQuant::Mx { fmt, scaling: Scaling::TruncationFree },
    )
    .expect("synthetic vit-micro");
    println!(
        "engine: {} B packed weights ({:.1}x below f32 mirror)",
        model.quantized_weight_bytes(),
        model.f32_mirror_bytes() as f64 / model.quantized_weight_bytes() as f64
    );
    let px = geom.img * geom.img * 3;
    for batch in [1usize, 16, 64] {
        let cfg = ServeConfig::builder()
            .micro_batch(batch.min(16))
            .workers(workers)
            .queue_depth(256)
            .build()
            .unwrap();
        let engine = ServeEngine::new(model.clone(), cfg).unwrap();
        let x: Vec<f32> = (0..batch * px).map(|_| rng.normal()).collect();
        // Warmup + timed samples, funneled through the shared
        // LatencyRecorder so the JSON schema matches serve/fleet/load.
        std::hint::black_box(engine.infer_logits(&x, batch));
        let iters = (64 / batch).clamp(3, 32);
        let mut rec = LatencyRecorder::default();
        rec.note_arrival(0.0);
        let t0 = Instant::now();
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(engine.infer_logits(&x, batch));
            let ms = t.elapsed().as_secs_f64() * 1e3;
            let at = t0.elapsed().as_secs_f64() * 1e3;
            rec.record_batch(batch, ms, at);
            rec.record_latency(ms);
        }
        let st = rec.summary();
        b.case(&format!("engine vit-micro batch {batch}"), batch as u64, || {
            std::hint::black_box(engine.infer_logits(&x, batch));
        });
        let mut fields = vec![
            ("bench", s("serve")),
            ("case", s("engine_throughput")),
            ("model", s("vit-micro")),
            ("batch", num(batch as f64)),
            ("packed_weight_bytes", num(model.quantized_weight_bytes() as f64)),
        ];
        fields.extend(st.fields());
        let entry = obj(fields);
        println!("BENCH {}", entry.to_string());
        b.note(entry);
    }

    // --- 2-engine row-sharded fleet vs single engine, batch 16 ---
    let batch = 16usize;
    let x: Vec<f32> = (0..batch * px).map(|_| rng.normal()).collect();
    for engines in [1usize, 2] {
        let cfg = ServeConfig::builder()
            .micro_batch(batch)
            .workers((workers / engines).max(1))
            .engines(engines)
            .queue_depth(256)
            .build()
            .unwrap();
        let mut fleet = ServeFleet::new(model.clone(), cfg).unwrap();
        std::hint::black_box(fleet.infer_logits(x.clone(), batch).unwrap());
        b.case(&format!("fleet vit-micro {engines} engines batch {batch}"), batch as u64, || {
            std::hint::black_box(fleet.infer_logits(x.clone(), batch).unwrap());
        });
        let mut fields = vec![
            ("bench", s("serve")),
            ("case", s("fleet_throughput")),
            ("model", s("vit-micro")),
            ("engines", num(engines as f64)),
            ("batch", num(batch as f64)),
        ];
        fields.extend(fleet.stats().fields());
        let entry = obj(fields);
        println!("BENCH {}", entry.to_string());
        b.note(entry);
    }

    b.persist();
}
