//! Metric-tracker throughput: the oscillation/confidence machinery the
//! coordinator runs every step over all quantized weights.

#[path = "harness.rs"]
mod harness;

use harness::Bench;
use tetrajet::metrics::confidence::latents;
use tetrajet::metrics::{quant_confidence, OscTracker, RateTracker};
use tetrajet::quant::{e2m1, Scaling};
use tetrajet::util::rng::Rng;

fn main() {
    let b = Bench::new("metrics");
    let mut rng = Rng::new(2);
    let n = 196_608;
    let cols = 64;
    let w: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
    let w2: Vec<f32> = w.iter().map(|&v| v + 1e-4).collect();
    let q: Vec<f32> = w.iter().map(|&v| (v * 16.0).round() / 16.0).collect();
    let q2: Vec<f32> = w2.iter().map(|&v| (v * 16.0).round() / 16.0).collect();
    let mut buf = Vec::new();

    b.case("osc_tracker_new+observe", n as u64, || {
        let mut t = OscTracker::new(&w, &q);
        t.observe(&w2, &q2);
        std::hint::black_box(t.steps());
    });
    let mut t = OscTracker::new(&w, &q);
    t.observe(&w2, &q2);
    b.case("osc_observe_steady", n as u64, || {
        t.observe(&w2, &q2);
        std::hint::black_box(t.steps());
    });
    b.case("osc_ratios_into", n as u64, || {
        t.ratios_into(&mut buf);
        std::hint::black_box(&buf);
    });
    b.case("osc_count_threshold", n as u64, || {
        std::hint::black_box(t.oscillating_count(16.0));
    });
    b.case("rate_tracker_observe", n as u64, || {
        let mut r = RateTracker::new();
        r.observe(&w);
        r.observe(&w2);
        std::hint::black_box(r.rate());
    });
    b.case("quant_confidence", n as u64, || {
        quant_confidence(&w, cols, e2m1(), Scaling::TruncationFree, &mut buf);
        std::hint::black_box(&buf);
    });
    b.case("latents", n as u64, || {
        latents(&w, cols, e2m1(), Scaling::TruncationFree, &mut buf);
        std::hint::black_box(&buf);
    });

    b.persist();
}
