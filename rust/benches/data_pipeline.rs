//! Synthetic data-pipeline throughput: per-sample synthesis and batch
//! assembly (the coordinator must keep the XLA step fed; on this 1-core
//! testbed data gen shares the core with the step itself).

#[path = "harness.rs"]
mod harness;

use harness::Bench;
use tetrajet::data::{Batcher, EvalSet, SynthVision};

fn main() {
    let b = Bench::new("data_pipeline");
    let ds = SynthVision::default_cfg(7);
    let mut buf = vec![0.0f32; 32 * 32 * 3];

    b.case("sample_into (1 img 32x32x3)", (32 * 32 * 3) as u64, || {
        std::hint::black_box(ds.sample_into(tetrajet::data::Split::Train, 123, &mut buf));
    });
    let mut batcher = Batcher::new(ds.clone(), 16, 0);
    b.case("train_batch_16", (16 * 32 * 32 * 3) as u64, || {
        std::hint::black_box(batcher.next_batch());
    });
    let ev = EvalSet::new(ds.clone(), 16, 512);
    b.case("eval_batch_16", (16 * 32 * 32 * 3) as u64, || {
        std::hint::black_box(ev.batch(0));
    });

    b.persist();
}
