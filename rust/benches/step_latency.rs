//! End-to-end step latency through the PJRT runtime — the numbers every
//! Table/Figure regeneration cost is built from. Skips gracefully when
//! `make artifacts` hasn't been run.
//!
//! Covers: train/eval/probe execution for the core variants plus the
//! host-side marshalling overhead (literal creation + tuple decompose),
//! isolated by comparing against a no-op-sized eval call.

#[path = "harness.rs"]
mod harness;

use harness::Bench;
use tetrajet::config::TrainConfig;
use tetrajet::coordinator::Trainer;
use tetrajet::runtime::{artifacts, cpu_client, ModelArtifacts};

fn main() -> anyhow::Result<()> {
    let root = artifacts::default_root();
    if !root.join("vit-micro/b16/tetrajet/manifest.json").exists() {
        println!("step_latency: artifacts missing — run `make artifacts` first (skipping)");
        return Ok(());
    }
    let b = Bench::new("step_latency");
    let client = cpu_client()?;
    for variant in ["fp32", "tetrajet", "tetrajet_qema"] {
        let arts = ModelArtifacts::load(&client, &root, "vit-micro", 16, variant)?;
        let mut cfg = TrainConfig::default_run(variant);
        cfg.steps = 1_000_000; // schedule horizon; we step manually
        cfg.eval_samples = 64;
        let params = artifacts::run_init(&client, &root, "vit-micro", 0)?;
        let mut tr = Trainer::new(&arts, cfg, params)?;
        tr.step()?; // warm caches
        b.case(&format!("{variant}/train_step(B=16)"), 16, || {
            tr.step().unwrap();
        });
        b.case(&format!("{variant}/eval(64 samples)"), 64, || {
            std::hint::black_box(tr.eval().unwrap());
        });
        b.case(&format!("{variant}/probe_fwd(B=16)"), 16, || {
            std::hint::black_box(tr.probe_activation().unwrap());
        });
        b.case(&format!("{variant}/mirror_wq(196k)"), 196_608, || {
            tr.mirror_wq();
        });
    }

    b.persist();
    Ok(())
}
