//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Reports min/median/mean over timed iterations after warmup, with
//! auto-scaled iteration counts targeting a fixed per-case budget.

use std::time::Instant;

pub struct Bench {
    name: String,
    budget_ms: f64,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        let budget_ms = std::env::var("TJ_BENCH_BUDGET_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(300.0);
        println!("\n=== bench suite: {name} (budget {budget_ms:.0} ms/case) ===");
        Bench { name: name.to_string(), budget_ms }
    }

    /// Time `f`, which processes `items` logical items per call.
    pub fn case<F: FnMut()>(&self, label: &str, items: u64, mut f: F) {
        // Warmup + calibration.
        let t0 = Instant::now();
        f();
        let per_call = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.budget_ms / 1000.0 / per_call) as usize).clamp(3, 10_000);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let min = samples[0];
        let med = samples[samples.len() / 2];
        let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
        let thr = items as f64 / med;
        println!(
            "{:<44} min {:>9.3} ms  med {:>9.3} ms  mean {:>9.3} ms  ({} iters{})",
            format!("{}/{label}", self.name),
            min * 1e3,
            med * 1e3,
            mean * 1e3,
            samples.len(),
            if items > 1 { format!(", {:.2} Melem/s", thr / 1e6) } else { String::new() },
        );
    }
}
