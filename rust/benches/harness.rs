//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Reports min/median/mean over timed iterations after warmup, with
//! auto-scaled iteration counts targeting a fixed per-case budget.
//! Every case is also accumulated as a BENCH json entry; [`Bench::persist`]
//! merges them into `results/BENCH_<pr>.json` through
//! [`tetrajet::util::benchio::merge_bench`], the same file and schema
//! the serve load test writes, so `compare` gates cover the whole
//! bench suite (env: `TJ_BENCH_PR`, `TJ_BENCH_DIR`).

use std::cell::RefCell;
use std::time::Instant;

use tetrajet::util::json::{num, obj, s, Json};

pub struct Bench {
    name: String,
    budget_ms: f64,
    entries: RefCell<Vec<Json>>,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        let budget_ms = std::env::var("TJ_BENCH_BUDGET_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(300.0);
        println!("\n=== bench suite: {name} (budget {budget_ms:.0} ms/case) ===");
        Bench { name: name.to_string(), budget_ms, entries: RefCell::new(Vec::new()) }
    }

    /// Time `f`, which processes `items` logical items per call.
    pub fn case<F: FnMut()>(&self, label: &str, items: u64, mut f: F) {
        // Warmup + calibration.
        let t0 = Instant::now();
        f();
        let per_call = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.budget_ms / 1000.0 / per_call) as usize).clamp(3, 10_000);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let min = samples[0];
        let med = samples[samples.len() / 2];
        let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
        let thr = items as f64 / med;
        println!(
            "{:<44} min {:>9.3} ms  med {:>9.3} ms  mean {:>9.3} ms  ({} iters{})",
            format!("{}/{label}", self.name),
            min * 1e3,
            med * 1e3,
            mean * 1e3,
            samples.len(),
            if items > 1 { format!(", {:.2} Melem/s", thr / 1e6) } else { String::new() },
        );
        self.entries.borrow_mut().push(obj(vec![
            ("bench", s(&self.name)),
            ("case", s(&format!("{}/{label}", self.name))),
            ("items", num(items as f64)),
            ("min_ms", num(min * 1e3)),
            ("med_ms", num(med * 1e3)),
            ("mean_ms", num(mean * 1e3)),
            ("melem_per_s", num(thr / 1e6)),
        ]));
    }

    /// Queue a hand-built BENCH entry (e.g. serve's engine-throughput
    /// objects, which carry the LatencySummary schema) for [`persist`].
    #[allow(dead_code)]
    pub fn note(&self, entry: Json) {
        self.entries.borrow_mut().push(entry);
    }

    /// Merge the accumulated entries into `TJ_BENCH_DIR/BENCH_<TJ_BENCH_PR>.json`.
    pub fn persist(&self) {
        let pr = std::env::var("TJ_BENCH_PR").ok().and_then(|s| s.parse().ok()).unwrap_or(8u64);
        let dir = std::env::var("TJ_BENCH_DIR").unwrap_or_else(|_| "results".to_string());
        let path = std::path::Path::new(&dir).join(format!("BENCH_{pr}.json"));
        let entries = self.entries.borrow().clone();
        let n = entries.len();
        match tetrajet::util::benchio::merge_bench(&path, pr, entries) {
            Ok(()) => println!("BENCH persisted: {n} entries -> {}", path.display()),
            Err(e) => eprintln!("BENCH persist failed ({}): {e:#}", path.display()),
        }
    }
}
