//! L3 quant-mirror throughput (the per-step metric hot path): MXFP4
//! deterministic/stochastic, Q-EMA, INT4 over vit-micro-sized weights,
//! plus the packed-code mirror (quantize / dequantize / flip-count)
//! against the f32 fake-quant baseline on a >= 1M-element segment.

#[path = "harness.rs"]
mod harness;

use harness::Bench;
use tetrajet::quant::{
    e2m1, e3m0, int4_quantize, mx_quantize_cols, mx_quantize_cols_into,
    mx_quantize_stoch_cols, qema_quantize_cols_into, MxQuantizer, PackedMx,
    Quantizer, Scaling,
};
use tetrajet::util::rng::Rng;

fn main() {
    let b = Bench::new("quantizer");
    let mut rng = Rng::new(1);
    // The full vit-micro quantized segment: 196,608 weights, cols = 64.
    let n = 196_608;
    let cols = 64;
    let x: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
    let ema: Vec<f32> = x.iter().map(|&v| v * 0.97).collect();
    let u: Vec<f32> = (0..n).map(|_| rng.uniform()).collect();
    let mut out = vec![0.0f32; n];

    b.case("mx_det_tf_e2m1 (alloc)", n as u64, || {
        std::hint::black_box(mx_quantize_cols(&x, cols, e2m1(), Scaling::TruncationFree));
    });
    b.case("mx_det_tf_e2m1 (into)", n as u64, || {
        mx_quantize_cols_into(&x, cols, e2m1(), Scaling::TruncationFree, &mut out);
        std::hint::black_box(&out);
    });
    b.case("mx_det_floor_e2m1 (into)", n as u64, || {
        mx_quantize_cols_into(&x, cols, e2m1(), Scaling::Floor, &mut out);
        std::hint::black_box(&out);
    });
    b.case("mx_det_tf_e3m0 (into)", n as u64, || {
        mx_quantize_cols_into(&x, cols, e3m0(), Scaling::TruncationFree, &mut out);
        std::hint::black_box(&out);
    });
    b.case("mx_stoch_tf_e2m1", n as u64, || {
        std::hint::black_box(mx_quantize_stoch_cols(&x, &u, cols, e2m1(), Scaling::TruncationFree));
    });
    b.case("qema_tf_e2m1 (into)", n as u64, || {
        qema_quantize_cols_into(&x, &ema, cols, e2m1(), Scaling::TruncationFree, &mut out);
        std::hint::black_box(&out);
    });
    b.case("int4_per_tensor", n as u64, || {
        std::hint::black_box(int4_quantize(&x, None));
    });

    // --- packed core on a >= 1M-element segment (2^21 weights) ---
    // Two consecutive training-step snapshots: xb2 perturbs ~1% of the
    // elements hard enough to flip, the realistic sparse-flip regime the
    // oscillation tracker sees every step.
    let nb = 2_097_152usize;
    let colsb = 256;
    let xb: Vec<f32> = (0..nb).map(|_| rng.normal() * 0.1).collect();
    let xb2: Vec<f32> = xb
        .iter()
        .enumerate()
        .map(|(i, &v)| if i % 97 == 0 { v * 1.4 + 0.01 } else { v })
        .collect();
    let q = MxQuantizer { fmt: e2m1(), scaling: Scaling::TruncationFree };
    let mut outb = vec![0.0f32; nb];
    let (mut pb, mut pb2) = (PackedMx::default(), PackedMx::default());
    q.quantize_packed(&xb, colsb, &mut pb);
    q.quantize_packed(&xb2, colsb, &mut pb2);
    let qa = mx_quantize_cols(&xb, colsb, e2m1(), Scaling::TruncationFree);
    let qb = mx_quantize_cols(&xb2, colsb, e2m1(), Scaling::TruncationFree);
    assert_eq!(
        pb2.flip_count(&pb),
        qa.iter().zip(&qb).filter(|(a, b)| a != b).count(),
        "packed and f32 flip counts must agree"
    );

    b.case("mx_f32_mirror 2M (into)", nb as u64, || {
        mx_quantize_cols_into(&xb, colsb, e2m1(), Scaling::TruncationFree, &mut outb);
        std::hint::black_box(&outb);
    });
    b.case("mx_packed_quantize 2M", nb as u64, || {
        q.quantize_packed(&xb, colsb, &mut pb);
        std::hint::black_box(&pb);
    });
    b.case("mx_packed_dequantize 2M", nb as u64, || {
        pb.dequantize_into(&mut outb);
        std::hint::black_box(&outb);
    });
    b.case("flip_count_f32 2M", nb as u64, || {
        std::hint::black_box(qa.iter().zip(&qb).filter(|(a, b)| a != b).count());
    });
    b.case("flip_count_packed 2M", nb as u64, || {
        std::hint::black_box(pb2.flip_count(&pb));
    });

    b.persist();
}
