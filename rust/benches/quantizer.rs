//! L3 quant-mirror throughput (the per-step metric hot path): MXFP4
//! deterministic/stochastic, Q-EMA, INT4 over vit-micro-sized weights.

#[path = "harness.rs"]
mod harness;

use harness::Bench;
use tetrajet::quant::{
    e2m1, e3m0, int4_quantize, mx_quantize_cols, mx_quantize_cols_into,
    mx_quantize_stoch_cols, qema_quantize_cols_into, Scaling,
};
use tetrajet::util::rng::Rng;

fn main() {
    let b = Bench::new("quantizer");
    let mut rng = Rng::new(1);
    // The full vit-micro quantized segment: 196,608 weights, cols = 64.
    let n = 196_608;
    let cols = 64;
    let x: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
    let ema: Vec<f32> = x.iter().map(|&v| v * 0.97).collect();
    let u: Vec<f32> = (0..n).map(|_| rng.uniform()).collect();
    let mut out = vec![0.0f32; n];

    b.case("mx_det_tf_e2m1 (alloc)", n as u64, || {
        std::hint::black_box(mx_quantize_cols(&x, cols, e2m1(), Scaling::TruncationFree));
    });
    b.case("mx_det_tf_e2m1 (into)", n as u64, || {
        mx_quantize_cols_into(&x, cols, e2m1(), Scaling::TruncationFree, &mut out);
        std::hint::black_box(&out);
    });
    b.case("mx_det_floor_e2m1 (into)", n as u64, || {
        mx_quantize_cols_into(&x, cols, e2m1(), Scaling::Floor, &mut out);
        std::hint::black_box(&out);
    });
    b.case("mx_det_tf_e3m0 (into)", n as u64, || {
        mx_quantize_cols_into(&x, cols, e3m0(), Scaling::TruncationFree, &mut out);
        std::hint::black_box(&out);
    });
    b.case("mx_stoch_tf_e2m1", n as u64, || {
        std::hint::black_box(mx_quantize_stoch_cols(&x, &u, cols, e2m1(), Scaling::TruncationFree));
    });
    b.case("qema_tf_e2m1 (into)", n as u64, || {
        qema_quantize_cols_into(&x, &ema, cols, e2m1(), Scaling::TruncationFree, &mut out);
        std::hint::black_box(&out);
    });
    b.case("int4_per_tensor", n as u64, || {
        std::hint::black_box(int4_quantize(&x, None));
    });
}
