//! Minimal offline stand-in for the `anyhow` crate (the build image
//! cannot reach crates.io). Implements exactly the surface this repo
//! uses: [`Error`], [`Result`], `anyhow!`, `bail!`,
//! [`Context::context`]/[`Context::with_context`] on both plain
//! `Result<_, E: std::error::Error>` and `anyhow::Result`, `{:#}`
//! cause-chain formatting, and a `Debug` impl with a "Caused by" list.

use std::error::Error as StdError;
use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error with a message and an optional boxed cause chain. Like the
/// real `anyhow::Error`, it deliberately does NOT implement
/// `std::error::Error`, so the blanket `From<E: std::error::Error>`
/// impl stays coherent.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a display-able message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Construct from a concrete error, preserving it as the cause.
    pub fn new<E: StdError + Send + Sync + 'static>(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }

    /// Wrap with an outer context message; `self` becomes the cause.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(Chained(self))) }
    }

    fn source_dyn(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_ref().map(|b| &**b as &(dyn StdError + 'static))
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut src = self.source_dyn();
            while let Some(e) = src {
                write!(f, ": {e}")?;
                src = e.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source_dyn();
        if src.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = src {
            write!(f, "\n    {e}")?;
            src = e.source();
        }
        Ok(())
    }
}

/// Adapter that lets an [`Error`] sit inside a `dyn std::error::Error`
/// cause chain without `Error` itself implementing the trait.
struct Chained(Error);

impl fmt::Display for Chained {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0.msg)
    }
}

impl fmt::Debug for Chained {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}

impl StdError for Chained {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.0.source_dyn()
    }
}

/// `.context(...)` / `.with_context(...)` on results. Mirrors anyhow's
/// trick: one generic impl over an internal `IntoError` bound that both
/// std errors and `Error` itself satisfy.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E: StdError + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::new(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

impl<T, E: IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chains_and_alternate_formats() {
        let r: Result<()> = Err(io_err()).context("loading manifest");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing file");
        let e2 = Err::<(), Error>(e).with_context(|| "opening artifacts").unwrap_err();
        assert_eq!(format!("{e2:#}"), "opening artifacts: loading manifest: missing file");
        assert!(format!("{e2:?}").contains("Caused by:"));
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("bad value {}", 7);
            }
            let parsed: u32 = "42".parse()?; // From<ParseIntError>
            Ok(parsed)
        }
        assert_eq!(inner(false).unwrap(), 42);
        assert_eq!(format!("{}", inner(true).unwrap_err()), "bad value 7");
        let e = anyhow!("x = {}", 1);
        assert_eq!(e.to_string(), "x = 1");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing here").unwrap_err();
        assert_eq!(e.to_string(), "nothing here");
    }
}
