//! Offline stub of the `xla` crate API surface used by `runtime/`.
//!
//! Everything that does not touch the device (manifest parsing, the
//! quant mirror, metrics, data pipeline, checkpoints, benches, unit and
//! property tests) builds and runs against this stub; the entry points
//! that would execute HLO return a clear [`Error`] instead. Client
//! construction succeeds so artifact discovery still reports its own,
//! more useful, "run `make artifacts`" errors first.

use std::fmt;
use std::path::Path;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA runtime unavailable — this binary was built against the \
         vendored no-op `xla` stub (rust/vendor/xla); point rust/Cargo.toml \
         at the real xla_extension bindings to execute HLO"
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "loading HLO text {}",
            path.as_ref().display()
        )))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling HLO"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing HLO"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device->host transfer"))
    }
}

pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("reading literal data"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("destructuring tuple literal"))
    }
}
