"""Variant registry: every training method the paper evaluates.

A *variant* fixes the quantization recipe baked into one AOT artifact.
The names here are the interchange contract with the Rust coordinator
(rust/src/config must list the same names; asserted by the cross-layer
manifest test).

Coordinator-side policies (Q-Ramping, Dampen, Freeze) are NOT variants:
they reuse the ``tetrajet`` artifact, whose train step takes ``nw`` /
``dampen_lambda`` / ``freeze_mask`` inputs (identity values = plain
TetraJet). See DESIGN.md §7.
"""

from dataclasses import dataclass, replace
from typing import Dict, Tuple

from .linear import LinearQuantCfg
from .quantizer import IDENTITY, QuantizerCfg
from .vit import MODELS, ModelCfg  # re-export for aot.py  # noqa: F401


@dataclass(frozen=True)
class VariantCfg:
    """One AOT-compiled training method."""

    name: str
    kind: str = "mx"  # 'fp32' | 'mx' | 'int4'
    fwd_fmt: str = "e2m1"
    bwd_fmt: str = "e2m1"
    scaling: str = "tf"  # 'tf' | 'floor'
    bwd_rounding: str = "stoch"  # 'stoch' | 'det'
    flow: str = "double"  # 'double' | 'naive'
    qema: bool = False
    enabled: Tuple[bool, ...] = (True,) * 6  # per-quantizer toggles Q1..Q6
    impl: str = "pallas"  # 'pallas' | 'ref' (bit-identical; see DESIGN.md)

    def linear_cfg(self) -> LinearQuantCfg:
        if self.kind == "fp32":
            return LinearQuantCfg()
        if self.kind == "int4":
            qf = QuantizerCfg(kind="int4", rounding="det")
            qb = QuantizerCfg(kind="int4", rounding=self.bwd_rounding)
            qs = (qf, qf, qb, qb, qb, qb)
        else:
            qf = QuantizerCfg(kind="mx", fmt=self.fwd_fmt, scaling=self.scaling,
                              rounding="det")
            qb = QuantizerCfg(kind="mx", fmt=self.bwd_fmt, scaling=self.scaling,
                              rounding=self.bwd_rounding)
            qs = (qf, qf, qb, qb, qb, qb)
        qs = tuple(q if on else IDENTITY for q, on in zip(qs, self.enabled))
        return LinearQuantCfg(q=qs, flow=self.flow, qema=self.qema,
                              impl=self.impl)


def _registry() -> Dict[str, VariantCfg]:
    v: Dict[str, VariantCfg] = {}

    def add(cfg: VariantCfg):
        assert cfg.name not in v, cfg.name
        v[cfg.name] = cfg

    tj = VariantCfg(name="tetrajet")
    add(VariantCfg(name="fp32", kind="fp32"))
    # Rouhani et al. 2023b: floor scaling, deterministic rounding,
    # fresh-tensor ("naive") backward quantization.
    add(VariantCfg(name="microscaling", scaling="floor", bwd_rounding="det",
                   flow="naive"))
    add(tj)
    add(replace(tj, name="tetrajet_qema", qema=True))
    add(VariantCfg(name="int4", kind="int4", flow="naive"))
    # Table 1: activate a single quantizer Q^(i), TetraJet settings.
    for i in range(6):
        onehot = tuple(j == i for j in range(6))
        add(replace(tj, name=f"q{i + 1}", enabled=onehot, impl="ref"))
    # Table 5: rounding x gradient-flow x scaling ablation (8 combos).
    for rnd in ("stoch", "det"):
        for flow in ("double", "naive"):
            for sc in ("tf", "floor"):
                add(VariantCfg(name=f"abl_{rnd}_{flow}_{sc}",
                               bwd_rounding=rnd, flow=flow, scaling=sc,
                               impl="ref"))
    # Table 7: FP4 format selection for forward (A&W) and backward (grad).
    for ff in ("e2m1", "e3m0"):
        for bf in ("e2m1", "e3m0"):
            add(replace(tj, name=f"fmt_{ff}_{bf}", fwd_fmt=ff, bwd_fmt=bf,
                        impl="ref"))
    # Table 6: stability ablations (forward quantizers as identity).
    add(replace(tj, name="tj_no_wq", enabled=(True, False) + (True,) * 4,
                impl="ref"))
    add(replace(tj, name="tj_no_wq_aq", enabled=(False, False) + (True,) * 4,
                impl="ref"))
    return v


VARIANTS = _registry()

# Variants used by the quickstart / integration tests / main experiments;
# `make artifacts` builds exactly these plus init + golden vectors.
CORE_VARIANTS = ("fp32", "microscaling", "tetrajet", "tetrajet_qema", "int4")


def variant(name: str) -> VariantCfg:
    try:
        return VARIANTS[name]
    except KeyError:  # pragma: no cover - config error
        raise ValueError(f"unknown variant {name!r}; known: {sorted(VARIANTS)}")
