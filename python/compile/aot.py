"""AOT exporter: lower the L2 steps to HLO **text** + JSON manifests.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids, so
text round-trips cleanly (see /opt/xla-example/README.md).

Usage (run from python/, e.g. via `make artifacts`):

  python -m compile.aot --out ../artifacts --core          # core set
  python -m compile.aot --out ../artifacts --full          # + ablations
  python -m compile.aot --out ../artifacts --variant q1    # one variant
  python -m compile.aot --out ../artifacts --golden-only
  python -m compile.aot --list

Outputs per (model, variant):
  artifacts/<model>/b<batch>/<variant>/{train_step,eval_step,probe}.hlo.txt
  artifacts/<model>/b<batch>/<variant>/manifest.json
plus per model: artifacts/<model>/init.hlo.txt + init_manifest.json
and once:       artifacts/golden/quant_vectors.json
"""

import argparse
import hashlib
import json
import os
from dataclasses import asdict

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .formats import E2M1, E3M0
from .kernels import ref as kref
from .model import CORE_VARIANTS, MODELS, VARIANTS, variant
from .train import (
    build_eval_step,
    build_probe,
    build_train_step,
    eval_io_spec,
    probe_block_index,
    probe_io_spec,
    train_io_spec,
)
from .vit import init_params, param_spec, qw_total, total_params

_DTYPES = {"f32": np.float32, "i32": np.int32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _specs(io_list):
    return [
        jax.ShapeDtypeStruct(tuple(e["shape"]), _DTYPES[e["dtype"]])
        for e in io_list
    ]


def _write(path: str, text: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")


def _input_fingerprint() -> str:
    """Hash of the compile-path sources, recorded in every manifest so the
    Makefile/coordinator can detect stale artifacts."""
    h = hashlib.sha256()
    base = os.path.dirname(__file__)
    for root, _, files in sorted(os.walk(base)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(root, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def export_variant(model_name: str, vname: str, batch: int, out: str):
    mcfg = MODELS[model_name]
    vcfg = variant(vname)
    d = os.path.join(out, model_name, f"b{batch}", vname)
    print(f"[aot] {model_name}/b{batch}/{vname}")
    tspec = train_io_spec(mcfg, batch)
    espec = eval_io_spec(mcfg, batch)
    pspec = probe_io_spec(mcfg, batch)

    lowered = jax.jit(build_train_step(mcfg, vcfg, batch), keep_unused=True).lower(
        *_specs(tspec.inputs)
    )
    _write(os.path.join(d, "train_step.hlo.txt"), to_hlo_text(lowered))
    lowered = jax.jit(build_eval_step(mcfg, vcfg, batch), keep_unused=True).lower(
        *_specs(espec.inputs)
    )
    _write(os.path.join(d, "eval_step.hlo.txt"), to_hlo_text(lowered))
    lowered = jax.jit(build_probe(mcfg, vcfg, batch), keep_unused=True).lower(
        *_specs(pspec.inputs)
    )
    _write(os.path.join(d, "probe.hlo.txt"), to_hlo_text(lowered))

    manifest = {
        "schema": 1,
        "fingerprint": _input_fingerprint(),
        "model": {**asdict(mcfg), "seq": mcfg.seq, "patch_dim": mcfg.patch_dim},
        "variant": {**asdict(vcfg), "enabled": list(vcfg.enabled)},
        "batch": batch,
        "probe_block": probe_block_index(mcfg),
        "params": {
            "total": total_params(mcfg),
            "qw_total": qw_total(mcfg),
            "segments": [
                {
                    "name": s.name,
                    "shape": list(s.shape),
                    "offset": s.offset,
                    "size": s.size,
                    "quantized": s.quantized,
                    "weight_decay": s.weight_decay,
                }
                for s in param_spec(mcfg)
            ],
        },
        "train_step": {"inputs": tspec.inputs, "outputs": tspec.outputs},
        "eval_step": {"inputs": espec.inputs, "outputs": espec.outputs},
        "probe": {"inputs": pspec.inputs, "outputs": pspec.outputs},
    }
    _write(os.path.join(d, "manifest.json"), json.dumps(manifest, indent=1))


def export_init(model_name: str, out: str):
    mcfg = MODELS[model_name]
    d = os.path.join(out, model_name)
    print(f"[aot] {model_name}/init")
    lowered = jax.jit(lambda seed: (init_params(seed, mcfg),)).lower(
        jax.ShapeDtypeStruct((), np.int32)
    )
    _write(os.path.join(d, "init.hlo.txt"), to_hlo_text(lowered))
    manifest = {
        "schema": 1,
        "model": {**asdict(mcfg), "seq": mcfg.seq, "patch_dim": mcfg.patch_dim},
        "inputs": [{"name": "seed", "dtype": "i32", "shape": []}],
        "outputs": [
            {"name": "params", "dtype": "f32", "shape": [total_params(mcfg)]}
        ],
    }
    _write(os.path.join(d, "init_manifest.json"), json.dumps(manifest, indent=1))


def export_golden(out: str, seed: int = 1234):
    """Golden vectors for the Rust quant mirror (rust/tests/golden.rs)."""
    rng = np.random.default_rng(seed)
    cases = []

    def edge_values(fmt):
        lv = np.asarray(fmt.levels, np.float32)
        bd = fmt.boundaries_np()
        vals = np.concatenate(
            [lv, bd, lv * 4.0, bd * 0.25, np.float32([0, 1e-30, -1e-30, 1e30, -1e30, 31.0])]
        )
        pad = (-len(vals)) % 32
        return np.concatenate([vals, np.zeros(pad, np.float32)]).reshape(1, -1)

    for fmt in (E2M1, E3M0):
        for scaling in ("tf", "floor"):
            for rounding in ("det", "stoch"):
                for tag, x in (
                    ("normal", (rng.standard_normal((4, 64)) * 2.5).astype(np.float32)),
                    ("edge", edge_values(fmt)),
                ):
                    u = rng.random(x.shape).astype(np.float32)
                    q = kref.mx_quantize_ref(
                        x, fmt, scaling, rounding, u if rounding == "stoch" else None
                    )
                    cases.append(
                        {
                            "kind": "mx",
                            "fmt": fmt.name,
                            "scaling": scaling,
                            "rounding": rounding,
                            "tag": tag,
                            "shape": list(x.shape),
                            "x": x.flatten().tolist(),
                            "u": u.flatten().tolist() if rounding == "stoch" else [],
                            "q": np.asarray(q).flatten().tolist(),
                        }
                    )
        # Q-EMA cases (always det, tf scaling).
        x = (rng.standard_normal((4, 64)) * 2.5).astype(np.float32)
        ema = (x + rng.standard_normal(x.shape) * 0.2).astype(np.float32)
        q = kref.qema_quantize_ref(x, ema, fmt)
        cases.append(
            {
                "kind": "qema",
                "fmt": fmt.name,
                "scaling": "tf",
                "rounding": "det",
                "tag": "normal",
                "shape": list(x.shape),
                "x": x.flatten().tolist(),
                "u": ema.flatten().tolist(),  # 'u' slot carries the EMA
                "q": np.asarray(q).flatten().tolist(),
            }
        )
    # INT4 per-tensor.
    x = (rng.standard_normal((4, 64)) * 3.0).astype(np.float32)
    u = rng.random(x.shape).astype(np.float32)
    for rounding, uu in (("det", None), ("stoch", u)):
        q = kref.int4_quantize_ref(x, uu)
        cases.append(
            {
                "kind": "int4",
                "fmt": "int4",
                "scaling": "per-tensor",
                "rounding": rounding,
                "tag": "normal",
                "shape": list(x.shape),
                "x": x.flatten().tolist(),
                "u": u.flatten().tolist() if uu is not None else [],
                "q": np.asarray(q).flatten().tolist(),
            }
        )
    _write(
        os.path.join(out, "golden", "quant_vectors.json"),
        json.dumps({"schema": 1, "seed": seed, "cases": cases}),
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="vit-micro", choices=sorted(MODELS))
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--variant", action="append", default=[])
    ap.add_argument("--core", action="store_true", help="export core set")
    ap.add_argument("--full", action="store_true", help="export all variants")
    ap.add_argument("--golden-only", action="store_true")
    ap.add_argument("--no-golden", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for name in sorted(VARIANTS):
            print(name)
        return
    if args.golden_only:
        export_golden(args.out)
        return

    names = list(args.variant)
    if args.core:
        names += [n for n in CORE_VARIANTS if n not in names]
    if args.full:
        names += [n for n in sorted(VARIANTS) if n not in names]
    if not names:
        names = [n for n in CORE_VARIANTS]

    export_init(args.model, args.out)
    for n in names:
        export_variant(args.model, n, args.batch, args.out)
    if not args.no_golden:
        export_golden(args.out)


if __name__ == "__main__":
    main()
