"""DeiT-style Vision Transformer with MXFP4-quantized linear layers.

Functional definition over a *flat* f32 parameter vector. Following the
paper (§7.1), only the linear layers inside the Attention and MLP
modules of the transformer blocks are quantized (qkv / proj / fc1 /
fc2); patch embedding, layernorms, and the classifier head stay in full
precision. The flat layout places all quantized weight matrices first,
`[0, qw_total)`, so the Rust coordinator can address the oscillation-
tracked segment with a single slice (see train.py and DESIGN.md §2).
"""

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .linear import LinearQuantCfg, make_qlinear


@dataclass(frozen=True)
class ModelCfg:
    """Down-scaled DeiT configuration (DESIGN.md §Substitutions)."""

    name: str = "vit-micro"
    img: int = 32
    patch: int = 4
    dim: int = 64
    depth: int = 4
    heads: int = 4
    mlp_ratio: int = 4
    classes: int = 10

    @property
    def n_patches(self) -> int:
        return (self.img // self.patch) ** 2

    @property
    def seq(self) -> int:
        return self.n_patches + 1  # + cls token

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * 3

    @property
    def hidden(self) -> int:
        return self.dim * self.mlp_ratio

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads


MODELS = {
    # ~0.22M params; the experiment-suite proxy for DeiT-T.
    "vit-micro": ModelCfg(),
    # ~0.8M params; proxy for the larger DeiT variants.
    "vit-tiny": ModelCfg(name="vit-tiny", dim=128, depth=6, heads=4),
    # ~103M params; the e2e-scale config (examples/train_vit_e2e.rs).
    "vit-100m": ModelCfg(
        name="vit-100m", img=32, patch=4, dim=768, depth=14, heads=12,
        classes=10,
    ),
}


@dataclass(frozen=True)
class ParamSeg:
    name: str
    shape: Tuple[int, ...]
    offset: int
    quantized: bool
    weight_decay: bool
    init: str  # 'trunc_normal' | 'zeros' | 'ones'

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


def param_spec(cfg: ModelCfg) -> List[ParamSeg]:
    """Ordered flat-layout spec: quantized weight matrices first.

    Per-block parameters are *stacked* along a leading depth axis so the
    forward can run as a single `lax.scan` over blocks — this keeps the
    lowered HLO size independent of depth (one block body in a loop),
    which is what makes AOT compilation on xla_extension 0.5.1 fast
    (see DESIGN.md §Perf). The 1x32 quantization group axis is still the
    trailing (contiguous) dimension of each stacked weight.
    """
    d = cfg.depth
    segs: List[Tuple[str, Tuple[int, ...], bool, bool, str]] = [
        ("blocks.qkv_w", (d, 3 * cfg.dim, cfg.dim), True, True, "trunc_normal"),
        ("blocks.proj_w", (d, cfg.dim, cfg.dim), True, True, "trunc_normal"),
        ("blocks.fc1_w", (d, cfg.hidden, cfg.dim), True, True, "trunc_normal"),
        ("blocks.fc2_w", (d, cfg.dim, cfg.hidden), True, True, "trunc_normal"),
        ("patch_embed.w", (cfg.dim, cfg.patch_dim), False, True, "trunc_normal"),
        ("patch_embed.b", (cfg.dim,), False, False, "zeros"),
        ("cls", (cfg.dim,), False, False, "trunc_normal"),
        ("pos", (cfg.seq, cfg.dim), False, False, "trunc_normal"),
        ("blocks.ln1.g", (d, cfg.dim), False, False, "ones"),
        ("blocks.ln1.b", (d, cfg.dim), False, False, "zeros"),
        ("blocks.qkv_b", (d, 3 * cfg.dim), False, False, "zeros"),
        ("blocks.proj_b", (d, cfg.dim), False, False, "zeros"),
        ("blocks.ln2.g", (d, cfg.dim), False, False, "ones"),
        ("blocks.ln2.b", (d, cfg.dim), False, False, "zeros"),
        ("blocks.fc1_b", (d, cfg.hidden), False, False, "zeros"),
        ("blocks.fc2_b", (d, cfg.dim), False, False, "zeros"),
        ("ln_f.g", (cfg.dim,), False, False, "ones"),
        ("ln_f.b", (cfg.dim,), False, False, "zeros"),
        ("head.w", (cfg.classes, cfg.dim), False, True, "trunc_normal"),
        ("head.b", (cfg.classes,), False, False, "zeros"),
    ]
    out: List[ParamSeg] = []
    off = 0
    for name, shape, q, wd, init in segs:
        seg = ParamSeg(name, shape, off, q, wd, init)
        out.append(seg)
        off += seg.size
    return out


def total_params(cfg: ModelCfg) -> int:
    spec = param_spec(cfg)
    return spec[-1].offset + spec[-1].size


def qw_total(cfg: ModelCfg) -> int:
    return sum(s.size for s in param_spec(cfg) if s.quantized)


def unflatten(flat, cfg: ModelCfg) -> Dict[str, jnp.ndarray]:
    return {
        s.name: jax.lax.slice(flat, (s.offset,), (s.offset + s.size,)).reshape(s.shape)
        for s in param_spec(cfg)
    }


def _clipped_normal(key, n):
    """Box-Muller standard normal clipped to [-2, 2].

    jax.random.normal / truncated_normal lower to the `erf`/`erf-inv`
    HLO opcodes, which the xla_extension 0.5.1 text parser rejects; a
    manual Box-Muller uses only log/sqrt/cos and stays loadable. The
    clip makes it a (slightly mass-concentrated) stand-in for DeiT's
    2-sigma truncated normal — immaterial at std 0.02.
    """
    k1, k2 = jax.random.split(key)
    u1 = jax.random.uniform(k1, (n,), jnp.float32, minval=1e-7, maxval=1.0)
    u2 = jax.random.uniform(k2, (n,), jnp.float32)
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
    return jnp.clip(z, -2.0, 2.0)


def init_params(seed, cfg: ModelCfg):
    """Flat parameter vector from an int32 seed (DeiT-style init:
    clipped normal std 0.02 for matrices/embeddings, ones for LN gains,
    zeros for biases)."""
    key = jax.random.PRNGKey(seed)
    parts = []
    for i, s in enumerate(param_spec(cfg)):
        if s.init == "zeros":
            parts.append(jnp.zeros((s.size,), jnp.float32))
        elif s.init == "ones":
            parts.append(jnp.ones((s.size,), jnp.float32))
        else:
            sub = jax.random.fold_in(key, i)
            parts.append(_clipped_normal(sub, s.size) * 0.02)
    return jnp.concatenate(parts)


def wd_mask(cfg: ModelCfg):
    """Static 0/1 weight-decay mask over the flat parameter vector."""
    parts = []
    for s in param_spec(cfg):
        parts.append(jnp.full((s.size,), 1.0 if s.weight_decay else 0.0))
    return jnp.concatenate(parts)


def _layer_norm(x, g, b, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _patchify(x, cfg: ModelCfg):
    """(B, H, W, 3) -> (B, N, patch*patch*3)."""
    b = x.shape[0]
    hp = cfg.img // cfg.patch
    x = x.reshape(b, hp, cfg.patch, hp, cfg.patch, 3)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, hp * hp, cfg.patch_dim)


def forward(
    flat,
    x,
    key,
    cfg: ModelCfg,
    qcfg: LinearQuantCfg,
    ema_flat=None,
    probe_block: int = -1,
):
    """ViT forward as one `lax.scan` over the stacked blocks.

    Returns (logits, probe_activation). ``key`` seeds the stochastic
    backward quantizers (split per block); the forward is deterministic.
    ``ema_flat`` supplies EMA values for the quantized segment when
    qcfg.qema (same flat layout prefix). ``probe_block`` indexes the
    block whose output the instability probe reports; -1 = last.
    """
    p = unflatten(flat, cfg)
    qlinear = make_qlinear(qcfg)
    spec = {s.name: s for s in param_spec(cfg)}

    def ema_of(name):
        if ema_flat is None:
            return p[name]
        sg = spec[name]
        return jax.lax.slice(ema_flat, (sg.offset,), (sg.offset + sg.size,)).reshape(
            sg.shape
        )

    bsz = x.shape[0]
    tok = _patchify(x, cfg) @ p["patch_embed.w"].T + p["patch_embed.b"]
    cls = jnp.broadcast_to(p["cls"], (bsz, 1, cfg.dim))
    h0 = jnp.concatenate([cls, tok], axis=1) + p["pos"]

    keys = jax.random.split(key, cfg.depth)
    xs = (
        p["blocks.qkv_w"], ema_of("blocks.qkv_w"),
        p["blocks.proj_w"], ema_of("blocks.proj_w"),
        p["blocks.fc1_w"], ema_of("blocks.fc1_w"),
        p["blocks.fc2_w"], ema_of("blocks.fc2_w"),
        p["blocks.ln1.g"], p["blocks.ln1.b"],
        p["blocks.qkv_b"], p["blocks.proj_b"],
        p["blocks.ln2.g"], p["blocks.ln2.b"],
        p["blocks.fc1_b"], p["blocks.fc2_b"],
        keys,
    )

    def block(h, xs_b):
        (qkv_w, qkv_e, proj_w, proj_e, fc1_w, fc1_e, fc2_w, fc2_e,
         ln1g, ln1b, qkv_b, proj_b, ln2g, ln2b, fc1_b, fc2_b, kb) = xs_b
        # --- attention ---
        hn = _layer_norm(h, ln1g, ln1b)
        flat2 = hn.reshape(bsz * cfg.seq, cfg.dim)
        qkv = qlinear(flat2, qkv_w, qkv_e, jax.random.fold_in(kb, 0)) + qkv_b
        qkv = qkv.reshape(bsz, cfg.seq, 3, cfg.heads, cfg.head_dim)
        q, k, v = (qkv[:, :, j].transpose(0, 2, 1, 3) for j in range(3))
        att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(cfg.head_dim)
        att = jax.nn.softmax(att, axis=-1)
        out = (att @ v).transpose(0, 2, 1, 3).reshape(bsz * cfg.seq, cfg.dim)
        out = qlinear(out, proj_w, proj_e, jax.random.fold_in(kb, 1)) + proj_b
        h = h + out.reshape(bsz, cfg.seq, cfg.dim)
        # --- mlp ---
        hn = _layer_norm(h, ln2g, ln2b)
        flat2 = hn.reshape(bsz * cfg.seq, cfg.dim)
        z = qlinear(flat2, fc1_w, fc1_e, jax.random.fold_in(kb, 2)) + fc1_b
        z = jax.nn.gelu(z)
        z = qlinear(z, fc2_w, fc2_e, jax.random.fold_in(kb, 3)) + fc2_b
        h = h + z.reshape(bsz, cfg.seq, cfg.dim)
        return h, h

    h, ys = jax.lax.scan(block, h0, xs)
    if probe_block < 0:
        probe_block = cfg.depth - 1
    probe = ys[probe_block]

    h = _layer_norm(h, p["ln_f.g"], p["ln_f.b"])
    logits = h[:, 0] @ p["head.w"].T + p["head.b"]
    return logits, probe
