"""Layer-2 quantizer dispatch: layout (1x32 vs 32x1), padding, RNG, impl.

The MX block-format constraint (paper §3.3) says the *first* operand of a
matmul is quantized in 1x32 groups and the *second* in 32x1 groups, i.e.
both along the contraction axis. This module maps that onto the L1
kernels, which always group along the last axis of a 2-D array:

  * ``axis=1`` — groups along columns (the 1x32 layout), direct call;
  * ``axis=0`` — groups along rows (the 32x1 layout), via transpose.

Dimensions that are not multiples of 32 are zero-padded to the next
multiple (zeros never win the group max and are sliced away afterwards),
matching how MX hardware handles ragged tails.

``impl`` selects the Pallas kernel ('pallas') or the pure-jnp oracle
('ref'). Both are bit-identical (tests/test_kernels.py); 'ref' lowers to
a smaller HLO and is used for the wide experiment sweeps, 'pallas' is
the default for the core artifacts (DESIGN.md §Substitutions).
"""

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from .formats import GROUP, fp4_format
from .kernels import ref as kref
from .kernels.int4 import int4_quantize_pallas
from .kernels.mxfp4 import mx_quantize_pallas
from .kernels.qema import qema_quantize_pallas


@dataclass(frozen=True)
class QuantizerCfg:
    """Configuration of one of the six linear-layer quantizers Q^(i)."""

    kind: str = "mx"  # 'mx' | 'int4' | 'none'
    fmt: str = "e2m1"  # 'e2m1' | 'e3m0'
    scaling: str = "tf"  # 'tf' (truncation-free) | 'floor' (Microscaling)
    rounding: str = "det"  # 'det' | 'stoch'

    @property
    def stochastic(self) -> bool:
        return self.kind != "none" and self.rounding == "stoch"


IDENTITY = QuantizerCfg(kind="none")


def _pad_cols(x):
    """Zero-pad the last axis of (R, C) to a multiple of GROUP."""
    r, c = x.shape
    pad = (-c) % GROUP
    if pad:
        x = jnp.concatenate([x, jnp.zeros((r, pad), x.dtype)], axis=1)
    return x, c


def _mx_call(x2d, cfg: QuantizerCfg, key, impl: str):
    xp, c0 = _pad_cols(x2d)
    u = None
    if cfg.stochastic:
        assert key is not None, "stochastic quantizer needs a PRNG key"
        u = jax.random.uniform(key, xp.shape, jnp.float32)
    fmt = fp4_format(cfg.fmt)
    if impl == "pallas":
        q = mx_quantize_pallas(
            xp, u, fmt=fmt, scaling=cfg.scaling, rounding=cfg.rounding
        )
    else:
        q = kref.mx_quantize_ref(xp, fmt, cfg.scaling, cfg.rounding, u)
    return q[:, :c0]


def quantize_2d(x, axis: int, cfg: QuantizerCfg, key=None, impl: str = "pallas"):
    """Fake-quantize a 2-D array with groups along ``axis``.

    axis=1: 1x32 groups (first-operand layout); axis=0: 32x1 groups
    (second-operand layout). Identity for cfg.kind == 'none'.
    """
    assert x.ndim == 2 and axis in (0, 1)
    if cfg.kind == "none":
        return x
    if cfg.kind == "int4":
        # Per-tensor: group layout is irrelevant.
        u = None
        if cfg.stochastic:
            assert key is not None
            u = jax.random.uniform(key, x.shape, jnp.float32)
        if impl == "pallas":
            return int4_quantize_pallas(x, u)
        return kref.int4_quantize_ref(x, u)
    if axis == 0:
        return _mx_call(x.T, cfg, key, impl).T
    return _mx_call(x, cfg, key, impl)


def qema_quantize_2d(
    w,
    ema,
    axis: int,
    cfg: QuantizerCfg,
    impl: str = "pallas",
):
    """Q-EMA fake-quantization (always deterministic; paper Alg. 1)."""
    assert w.ndim == 2 and axis in (0, 1) and ema.shape == w.shape
    fmt = fp4_format(cfg.fmt)
    if axis == 0:
        return qema_quantize_2d(w.T, ema.T, 1, cfg, impl).T
    wp, c0 = _pad_cols(w)
    ep, _ = _pad_cols(ema)
    if impl == "pallas":
        q = qema_quantize_pallas(wp, ep, fmt=fmt, scaling=cfg.scaling)
    else:
        q = kref.qema_quantize_ref(wp, ep, fmt, cfg.scaling)
    return q[:, :c0]
