"""Layer-2 training/eval/probe step builders.

Each builder returns a pure jax function over *flat* f32 state vectors —
the Rust coordinator owns all state between steps and passes it back in
(DESIGN.md §2). The train step implements:

  * cross-entropy loss over the quantized ViT forward,
  * the optional Dampen regulariser  λ·Σ‖W − sg(Q^(2)(W))‖²  (Nagel et
    al. 2022 baseline, Table 4),
  * AdamW with per-element **Q-Ramping** (paper §6 / Alg. 2) on the
    quantized segment: each quantized weight element has an amplification
    factor N_w; its gradient is accumulated for N_w steps and applied
    with learning rate N_w·lr — exactly "batch size and LR scaled by
    N_w". N_w ≡ 1 reduces to standard AdamW,
  * the **Freeze** baseline: elements with freeze_mask > 0 are pinned to
    freeze_value after the update,
  * an EMA of the quantized segment (consumed by the Q-EMA forward
    quantizer and by Freeze's running average).

Input/output orders here are the manifest contract with rust/src/runtime.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .linear import forward_weight_quant
from .model import VariantCfg
from .vit import (
    ModelCfg,
    forward,
    param_spec,
    qw_total,
    total_params,
    unflatten,
    wd_mask,
)

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


class StepSpec(NamedTuple):
    """Name/dtype/shape triplets describing one HLO entry point."""

    inputs: list
    outputs: list


def _io(name, dtype, shape):
    return {"name": name, "dtype": dtype, "shape": list(shape)}


def train_io_spec(mcfg: ModelCfg, batch: int) -> StepSpec:
    p = total_params(mcfg)
    qw = qw_total(mcfg)
    ins = [
        _io("params", "f32", (p,)),
        _io("opt_m", "f32", (p,)),
        _io("opt_v", "f32", (p,)),
        _io("ema", "f32", (qw,)),
        _io("accum", "f32", (qw,)),
        _io("nw", "f32", (qw,)),
        _io("freeze_mask", "f32", (qw,)),
        _io("freeze_value", "f32", (qw,)),
        _io("lr", "f32", ()),
        _io("wd", "f32", ()),
        _io("ema_beta", "f32", ()),
        _io("dampen_lambda", "f32", ()),
        _io("step", "i32", ()),
        _io("seed", "i32", ()),
        _io("batch_x", "f32", (batch, mcfg.img, mcfg.img, 3)),
        _io("batch_y", "i32", (batch,)),
    ]
    outs = [
        _io("params", "f32", (p,)),
        _io("opt_m", "f32", (p,)),
        _io("opt_v", "f32", (p,)),
        _io("ema", "f32", (qw,)),
        _io("accum", "f32", (qw,)),
        _io("loss", "f32", ()),
        _io("acc", "f32", ()),
    ]
    return StepSpec(ins, outs)


def eval_io_spec(mcfg: ModelCfg, batch: int) -> StepSpec:
    p = total_params(mcfg)
    qw = qw_total(mcfg)
    ins = [
        _io("params", "f32", (p,)),
        _io("ema", "f32", (qw,)),
        _io("batch_x", "f32", (batch, mcfg.img, mcfg.img, 3)),
        _io("batch_y", "i32", (batch,)),
    ]
    outs = [_io("loss_sum", "f32", ()), _io("correct", "f32", ())]
    return StepSpec(ins, outs)


def probe_io_spec(mcfg: ModelCfg, batch: int) -> StepSpec:
    p = total_params(mcfg)
    qw = qw_total(mcfg)
    ins = [
        _io("params", "f32", (p,)),
        _io("ema", "f32", (qw,)),
        _io("batch_x", "f32", (batch, mcfg.img, mcfg.img, 3)),
    ]
    outs = [_io("probe", "f32", (batch, mcfg.seq, mcfg.dim))]
    return StepSpec(ins, outs)


def probe_block_index(mcfg: ModelCfg) -> int:
    """Block whose output activation the instability probe reports.

    The paper probes the 9th of DeiT-T's 12 blocks (~3/4 depth).
    """
    return max(0, (3 * mcfg.depth) // 4 - 1)


def _loss(params, ema, key, x, y, dampen_lambda, mcfg, qcfg, vcfg):
    logits, _ = forward(
        params, x, key, mcfg, qcfg, ema_flat=ema if vcfg.qema else None
    )
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    # Dampen regulariser over the quantized weights; the quantized value
    # is treated as a fixed bin centre (stop_gradient), so d/dW of each
    # term is 2(W - Q(W)) as in Nagel et al. 2022.
    dampen = jnp.float32(0.0)
    if vcfg.kind != "fp32":
        p = unflatten(params, mcfg)
        e = unflatten(jnp.pad(ema, (0, total_params(mcfg) - ema.shape[0])), mcfg)
        for s in param_spec(mcfg):
            if not s.quantized:
                continue
            # Stacked (depth, C, D) -> (depth*C, D): the 1x32 group axis
            # is the trailing dim either way.
            w = p[s.name].reshape(-1, s.shape[-1])
            ema_seg = e[s.name].reshape(-1, s.shape[-1])
            # stop_gradient on the *inputs*: the quantized value is a
            # fixed bin centre for the regulariser, and Pallas calls do
            # not support linearization of their primals.
            wq = forward_weight_quant(
                jax.lax.stop_gradient(w),
                jax.lax.stop_gradient(ema_seg),
                qcfg,
            )
            dampen = dampen + jnp.sum((w - wq.reshape(w.shape)) ** 2)
    loss = ce + dampen_lambda * dampen
    return loss, (ce, acc)


def build_train_step(mcfg: ModelCfg, vcfg: VariantCfg, batch: int):
    """The AOT-exported train step; signature per ``train_io_spec``."""
    qcfg = vcfg.linear_cfg()
    qw = qw_total(mcfg)
    wdm = wd_mask(mcfg)

    def train_step(
        params, m, v, ema, accum, nw, freeze_mask, freeze_value,
        lr, wd, ema_beta, dampen_lambda, step, seed, batch_x, batch_y,
    ):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        loss_fn = functools.partial(_loss, mcfg=mcfg, qcfg=qcfg, vcfg=vcfg)
        grad_fn = jax.value_and_grad(loss_fn, argnums=0, has_aux=True)
        (_, (ce, acc)), g = grad_fn(
            params, ema, key, batch_x, batch_y, dampen_lambda
        )
        t1 = (step + 1).astype(jnp.float32)

        # ---- quantized segment: Q-Ramping AdamW (elementwise N_w) ----
        pq, pr = params[:qw], params[qw:]
        gq, gr = g[:qw], g[qw:]
        mq, mr = m[:qw], m[qw:]
        vq, vr = v[:qw], v[qw:]
        accum1 = accum + gq
        upd = jnp.floor_divide(t1, nw) * nw == t1  # (t+1) mod N_w == 0
        geff = accum1 / nw
        mq1 = jnp.where(upd, ADAM_B1 * mq + (1 - ADAM_B1) * geff, mq)
        vq1 = jnp.where(upd, ADAM_B2 * vq + (1 - ADAM_B2) * geff * geff, vq)
        nupd = jnp.maximum(jnp.floor(t1 / nw), 1.0)  # updates so far
        mhat = mq1 / (1.0 - ADAM_B1**nupd)
        vhat = vq1 / (1.0 - ADAM_B2**nupd)
        stepv = mhat / (jnp.sqrt(vhat) + ADAM_EPS) + wd * pq
        pq1 = jnp.where(upd, pq - nw * lr * stepv, pq)
        accum1 = jnp.where(upd, 0.0, accum1)
        # Freeze baseline: pin flagged elements to the running average.
        pq1 = jnp.where(freeze_mask > 0.5, freeze_value, pq1)
        ema1 = ema_beta * ema + (1.0 - ema_beta) * pq1

        # ---- remaining parameters: plain AdamW ----
        mr1 = ADAM_B1 * mr + (1 - ADAM_B1) * gr
        vr1 = ADAM_B2 * vr + (1 - ADAM_B2) * gr * gr
        mrh = mr1 / (1.0 - ADAM_B1**t1)
        vrh = vr1 / (1.0 - ADAM_B2**t1)
        pr1 = pr - lr * (mrh / (jnp.sqrt(vrh) + ADAM_EPS) + wd * wdm[qw:] * pr)

        return (
            jnp.concatenate([pq1, pr1]),
            jnp.concatenate([mq1, mr1]),
            jnp.concatenate([vq1, vr1]),
            ema1,
            accum1,
            ce,
            acc,
        )

    return train_step


def build_eval_step(mcfg: ModelCfg, vcfg: VariantCfg, batch: int):
    """Deterministic eval forward; signature per ``eval_io_spec``."""
    qcfg = vcfg.linear_cfg()

    def eval_step(params, ema, batch_x, batch_y):
        key = jax.random.PRNGKey(0)  # forward is deterministic; key unused
        logits, _ = forward(
            params, batch_x, key, mcfg, qcfg,
            ema_flat=ema if vcfg.qema else None,
        )
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss_sum = -jnp.sum(jnp.take_along_axis(logp, batch_y[:, None], axis=1))
        correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == batch_y).astype(jnp.float32)
        )
        return loss_sum, correct

    return eval_step


def build_probe(mcfg: ModelCfg, vcfg: VariantCfg, batch: int):
    """Activation probe: output of the ~3/4-depth block for a fixed batch
    (used for the paper's r(Y) instability metric, Fig. 2 / Table 3)."""
    qcfg = vcfg.linear_cfg()
    pb = probe_block_index(mcfg)

    def probe(params, ema, batch_x):
        key = jax.random.PRNGKey(0)
        _, act = forward(
            params, batch_x, key, mcfg, qcfg,
            ema_flat=ema if vcfg.qema else None, probe_block=pb,
        )
        return (act,)

    return probe
