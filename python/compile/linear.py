"""TetraJet / Microscaling MXFP4 linear layer (paper §3.3–3.4).

Forward (Eq. 3):            Y = Q_D^(1)(X) · Q_D^(2)(W^T)
Backward, TetraJet (4, 5):  ∇X = Q_S^(3)(∇Y) · Q_S^(4)(Q_D^(2)(W^T)^T)
                            ∇W = Q_S^(5)(∇Y^T) · Q_S^(6)(Q_D^(1)(X))
Backward, Microscaling (6,7): same shapes but deterministic rounding and
the *fresh full-precision* X / W as quantizer inputs (flow='naive'),
which makes the gradient biased — it is the gradient of a different
network whose operands are quantized along the wrong axes (§3.4).

Group layouts follow the MX block-format rule: the first operand of each
matmul is quantized 1x32, the second 32x1 — both along the contraction
axis (handled by quantize_2d's ``axis`` argument).

The layer is a ``jax.custom_vjp``: the forward applies the straight-
through estimator (STE) through Q^(1)/Q^(2); the backward implements the
papers' exact quantized-gradient recipes above.
"""

from dataclasses import dataclass, field
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .quantizer import IDENTITY, QuantizerCfg, qema_quantize_2d, quantize_2d


@dataclass(frozen=True)
class LinearQuantCfg:
    """Full quantization recipe for one linear layer (all six Q^(i))."""

    q: Tuple[QuantizerCfg, ...] = field(default_factory=lambda: (IDENTITY,) * 6)
    flow: str = "double"  # 'double' (TetraJet) | 'naive' (Microscaling)
    qema: bool = False  # use the EMA quantizer for Q^(2)
    impl: str = "pallas"  # 'pallas' | 'ref'

    def __post_init__(self):
        assert len(self.q) == 6 and self.flow in ("double", "naive")


def forward_weight_quant(w, ema_w, cfg: LinearQuantCfg):
    """Q^(2) as used in the forward pass — the quantized weight the paper's
    oscillation metrics track (also used by the Dampen regulariser)."""
    if cfg.qema:
        return qema_quantize_2d(w, ema_w, 1, cfg.q[1], impl=cfg.impl)
    return quantize_2d(w, 1, cfg.q[1], impl=cfg.impl)


def _float0_zeros(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


def make_qlinear(cfg: LinearQuantCfg):
    """Build the quantized linear primitive ``qlinear(x, w, ema_w, key)``.

    x: (N, D) activations; w: (C, D) weight; ema_w: (C, D) EMA weight
    (only read when cfg.qema); key: PRNG key consumed by the stochastic
    backward quantizers. Returns (N, C).
    """

    def _fwd_operands(x, w, ema_w):
        xq = quantize_2d(x, 1, cfg.q[0], impl=cfg.impl)
        wq = forward_weight_quant(w, ema_w, cfg)
        return xq, wq

    @jax.custom_vjp
    def qlinear(x, w, ema_w, key):
        xq, wq = _fwd_operands(x, w, ema_w)
        return xq @ wq.T

    def vjp_fwd(x, w, ema_w, key):
        xq, wq = _fwd_operands(x, w, ema_w)
        return xq @ wq.T, (x, w, ema_w, xq, wq, key)

    def vjp_bwd(res, gy):
        x, w, ema_w, xq, wq, key = res
        k3, k4, k5, k6 = jax.random.split(key, 4)
        if cfg.flow == "double":
            # Double quantization: requantize the *already quantized*
            # forward operands along the transposed group axis (Eq. 4-5).
            w_src, x_src = wq, xq
        else:
            # Microscaling: quantize the fresh full-precision tensors
            # (wrong-axis operands; biased gradient, Eq. 6-7).
            w_src, x_src = w, x
        # ∇X = Q3(∇Y)[1x32 along C] · Q4(w_src)[32x1 along C]
        gq = quantize_2d(gy, 1, cfg.q[2], key=k3, impl=cfg.impl)
        wq4 = quantize_2d(w_src, 0, cfg.q[3], key=k4, impl=cfg.impl)
        dx = gq @ wq4
        # ∇W = Q5(∇Y^T)[1x32 along N] · Q6(x_src)[32x1 along N]
        gq5 = quantize_2d(gy.T, 1, cfg.q[4], key=k5, impl=cfg.impl)
        xq6 = quantize_2d(x_src, 0, cfg.q[5], key=k6, impl=cfg.impl)
        dw = gq5 @ xq6
        return dx, dw, jnp.zeros_like(ema_w), _float0_zeros(key)

    qlinear.defvjp(vjp_fwd, vjp_bwd)
    return qlinear
