"""Layer-1 Pallas kernel: EMA Quantizer (Q-EMA, paper §5 / Alg. 1).

Same tile schedule as ``mxfp4.py`` but with a second VMEM input stream
carrying the EMA weights: the scale and bracketing candidates [q1, q2]
come from the *current* weight tile, the choice between them from the
EMA tile. Numerics defined by ``ref.qema_quantize_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..formats import GROUP, FP4Format
from .ref import exp2i
from .mxfp4 import DEFAULT_BLOCK_ROWS, _block_rows, _bracket_cf, _scale_exponent_k


def _qema_kernel(w_ref, e_ref, o_ref, *, fmt, scaling):
    w = w_ref[...]
    ema = e_ref[...]
    r, c = w.shape
    g = c // GROUP
    wg = w.reshape(r, g, GROUP)
    eg = ema.reshape(r, g, GROUP)
    max_abs = jnp.max(jnp.abs(wg), axis=-1)
    s = _scale_exponent_k(max_abs, fmt, scaling)
    scale = exp2i(s)[..., None]
    y = jnp.clip(wg / scale, fmt.qn, fmt.qp)
    ye = eg / scale
    q1, q2 = _bracket_cf(y, fmt)
    q = jnp.where(jnp.abs(ye - q1) < jnp.abs(ye - q2), q1, q2)
    o_ref[...] = (q * scale).reshape(r, c)


@functools.partial(jax.jit, static_argnames=("fmt", "scaling", "block_rows"))
def qema_quantize_pallas(
    w,
    ema,
    *,
    fmt: FP4Format,
    scaling: str = "tf",
    block_rows: int = DEFAULT_BLOCK_ROWS,
):
    """Pallas Q-EMA fake-quantizer over ``w``/``ema`` (R, C), 1x32 groups."""
    r, c = w.shape
    assert c % GROUP == 0 and ema.shape == w.shape
    br = _block_rows(r, block_rows)
    spec = pl.BlockSpec((br, c), lambda i: (i, 0))
    kernel = functools.partial(_qema_kernel, fmt=fmt, scaling=scaling)
    return pl.pallas_call(
        kernel,
        grid=(r // br,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=True,
    )(w, ema)
