"""Layer-1 Pallas kernel: per-tensor symmetric INT4 fake quantization.

Baseline quantizer for Table 2 ("INT4 / per-tensor" row, Xi et al. 2023
simplified — see DESIGN.md §Substitutions). Per-tensor scaling needs a
global max, so the kernel runs as a single grid cell over the whole
tensor (on TPU this would be a two-pass reduce + scale kernel; the
tensors involved are small enough that a single VMEM-resident pass is
also realistic for the reference models).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..formats import INT4_QMAX


def _int4_det_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(jnp.abs(x))
    scale = jnp.where(m == 0.0, jnp.float32(1.0), m / jnp.float32(INT4_QMAX))
    y = x / scale
    q = jnp.clip(jnp.sign(y) * jnp.floor(jnp.abs(y) + 0.5), -INT4_QMAX, INT4_QMAX)
    o_ref[...] = q * scale


def _int4_stoch_kernel(x_ref, u_ref, o_ref):
    x = x_ref[...]
    u = u_ref[...]
    m = jnp.max(jnp.abs(x))
    scale = jnp.where(m == 0.0, jnp.float32(1.0), m / jnp.float32(INT4_QMAX))
    y = x / scale
    lo = jnp.floor(y)
    q = jnp.clip(
        jnp.where((y - lo) > u, lo + 1.0, lo), -INT4_QMAX, INT4_QMAX
    )
    o_ref[...] = q * scale


@functools.partial(jax.jit, static_argnames=())
def int4_quantize_pallas(x, u=None):
    """Per-tensor INT4 fake-quantizer; stochastic when ``u`` is given."""
    out_shape = jax.ShapeDtypeStruct(x.shape, jnp.float32)
    if u is None:
        return pl.pallas_call(
            _int4_det_kernel, out_shape=out_shape, interpret=True
        )(x)
    assert u.shape == x.shape
    return pl.pallas_call(
        _int4_stoch_kernel, out_shape=out_shape, interpret=True
    )(x, u)
