"""Layer-1 Pallas kernels: MXFP4 fake-quantization.

One fused pass per tile: load a ``(block_rows, C)`` tile into VMEM,
compute the per-1x32-group max, derive the shared E8M0 scale exponent,
round onto the FP4 grid and write the dequantized tile back. On a real
TPU this is exactly the HBM->VMEM schedule expressed by the BlockSpec
(the per-group reduction and rounding are VPU element-wise work; the
consumer matmul then feeds the MXU); here the kernels are lowered with
``interpret=True`` because the CPU PJRT plugin cannot execute Mosaic
custom-calls (see DESIGN.md §Hardware-Adaptation).

Numerics are defined by ``ref.py``; ``python/tests/test_kernels.py``
asserts bit-exact agreement, and hypothesis sweeps shapes/dtypes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import exp2i
from ..formats import (
    GROUP,
    SCALE_EXP_MAX,
    SCALE_EXP_MIN,
    ZERO_GROUP_EPS,
    FP4Format,
)

# Default tile height. The tile is (DEFAULT_BLOCK_ROWS, C) f32; with the
# largest activation width in the reference models (C = 1024) this is
# 256*1024*4 B = 1 MiB in + 1 MiB out, comfortably inside the ~16 MiB
# VMEM budget of a TPU core while amortising grid overhead.
DEFAULT_BLOCK_ROWS = 256


def _scale_exponent_k(max_abs, fmt: FP4Format, scaling: str):
    """In-kernel shared-scale exponent; mirrors ref.scale_exponent."""
    m_t = jnp.where(max_abs == 0.0, jnp.float32(ZERO_GROUP_EPS), max_abs)
    if scaling == "tf":
        m, e = jnp.frexp(m_t / jnp.float32(fmt.qp))
        s = jnp.where(m == 0.5, e - 1, e)
    else:  # 'floor'
        _, e = jnp.frexp(m_t)
        s = (e - 1) - fmt.emax
    return jnp.clip(s, SCALE_EXP_MIN, SCALE_EXP_MAX)


def _grid_spacing_mag(a, fmt: FP4Format):
    """Closed-form FP4 grid spacing at magnitude ``a`` (table-free).

    Within the binade [2^(e-1), 2^e) of ``a`` the representable grid has
    uniform spacing 2^(e-1-mbits); below the first normal binade the
    subnormal spacing ``delta_min`` applies. Spacings are exact powers
    of two, so the divisions/floors downstream are exact in f32.
    """
    _, e = jnp.frexp(a)
    delta = exp2i(jnp.clip(e - 1 - fmt.mbits, -127, 127))
    return jnp.maximum(delta, jnp.float32(fmt.delta_min))


def _round_det_cf(y, fmt: FP4Format):
    """Deterministic round-to-nearest, ties toward +inf (== table oracle).

    All midpoints of a bracket (q1, q2) are exact multiples of the
    spacing of |y|'s binade, so a single fused floor reproduces the
    table-based round_D including its tie rule.
    """
    delta = _grid_spacing_mag(jnp.abs(y), fmt)
    return jnp.floor(y / delta + 0.5) * delta


def _spacing_above(level, fmt: FP4Format):
    """Gap between grid ``level`` and the next level above it.

    For a negative level whose magnitude starts a binade (e.g. -2 in
    E2M1), moving up (toward zero) leaves the binade, so the gap is
    halved; the subnormal clamp then restores ``delta_min`` near zero.
    """
    a = jnp.abs(level)
    m, e = jnp.frexp(a)
    delta = exp2i(jnp.clip(e - 1 - fmt.mbits, -127, 127))
    delta = jnp.where((level < 0) & (m == 0.5), delta * 0.5, delta)
    # frexp(0) reports e == 0; the gap above level 0 is the subnormal one.
    delta = jnp.where(a == 0.0, jnp.float32(fmt.delta_min), delta)
    return jnp.maximum(delta, jnp.float32(fmt.delta_min))


def _bracket_cf(y, fmt: FP4Format):
    """Bracketing grid values (q1, q2), q1 <= y <= q2, matching the table
    oracle's semantics exactly: q1 is the largest level <= y, clamped to
    the second-highest level so q2 never exceeds Qp."""
    a = jnp.abs(y)
    delta = _grid_spacing_mag(a, fmt)
    q1 = jnp.where(y >= 0.0, jnp.floor(a / delta), -jnp.ceil(a / delta)) * delta
    q1 = jnp.minimum(q1, jnp.float32(fmt.levels[-2]))
    return q1, q1 + _spacing_above(q1, fmt)


def _quantize_tile(x, fmt: FP4Format, scaling: str, rounding: str, u=None):
    """Fake-quantize one (rows, C) tile; groups along the last axis."""
    r, c = x.shape
    g = c // GROUP
    xg = x.reshape(r, g, GROUP)
    max_abs = jnp.max(jnp.abs(xg), axis=-1)
    s = _scale_exponent_k(max_abs, fmt, scaling)
    scale = exp2i(s)[..., None]
    y = jnp.clip(xg / scale, fmt.qn, fmt.qp)
    if rounding == "det":
        q = _round_det_cf(y, fmt)
    else:  # 'stoch'
        q1, q2 = _bracket_cf(y, fmt)
        ug = u.reshape(r, g, GROUP)
        q = jnp.where((y - q1) > ug * (q2 - q1), q2, q1)
    return (q * scale).reshape(r, c)


def _det_kernel(x_ref, o_ref, *, fmt, scaling):
    o_ref[...] = _quantize_tile(x_ref[...], fmt, scaling, "det")


def _stoch_kernel(x_ref, u_ref, o_ref, *, fmt, scaling):
    o_ref[...] = _quantize_tile(x_ref[...], fmt, scaling, "stoch", u_ref[...])


def _block_rows(rows: int, block_rows: int) -> int:
    """Largest divisor of ``rows`` not exceeding ``block_rows``."""
    b = min(rows, block_rows)
    while rows % b != 0:
        b -= 1
    return b


@functools.partial(
    jax.jit, static_argnames=("fmt", "scaling", "rounding", "block_rows")
)
def mx_quantize_pallas(
    x,
    u=None,
    *,
    fmt: FP4Format,
    scaling: str,
    rounding: str,
    block_rows: int = DEFAULT_BLOCK_ROWS,
):
    """Pallas MXFP4 fake-quantizer over ``x`` (R, C), 1x32 groups along C.

    ``u``: Uniform[0,1) samples, required iff ``rounding == 'stoch'``.
    """
    r, c = x.shape
    assert c % GROUP == 0, f"last dim {c} not a multiple of {GROUP}"
    br = _block_rows(r, block_rows)
    grid = (r // br,)
    spec = pl.BlockSpec((br, c), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((r, c), jnp.float32)
    if rounding == "det":
        kernel = functools.partial(_det_kernel, fmt=fmt, scaling=scaling)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[spec],
            out_specs=spec,
            out_shape=out_shape,
            interpret=True,
        )(x)
    assert u is not None and u.shape == x.shape
    kernel = functools.partial(_stoch_kernel, fmt=fmt, scaling=scaling)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=out_shape,
        interpret=True,
    )(x, u)
