"""Pure-jnp oracle for every quantizer (the CORE correctness signal).

These functions define the exact numerics of the system. The Pallas
kernels in ``mxfp4.py`` / ``qema.py`` / ``int4.py`` must match them
bit-for-bit (asserted by ``python/tests/test_kernels.py``), and the Rust
mirror (rust/src/quant/) is golden-tested against vectors generated from
these functions.

All quantizers here are *fake-quantizers*: they return f32 values lying
exactly on the (scaled) MXFP4 grid. See DESIGN.md §Hardware-Adaptation.

Shape convention: ``x`` is ``(R, C)`` with ``C % 32 == 0``; quantization
groups are the 32-element runs along the last axis (the 1x32 layout).
The 32x1 layout is obtained by the callers via transpose (quantizer.py).
"""

import jax
import jax.numpy as jnp

from ..formats import (
    GROUP,
    INT4_QMAX,
    SCALE_EXP_MAX,
    SCALE_EXP_MIN,
    ZERO_GROUP_EPS,
    FP4Format,
)


def exp2i(s):
    """Exact 2^s for integer s in [-127, 127], built by bit manipulation.

    XLA lowers exp2 as exp(s * ln 2), which is off by ulps at large |s|
    (e.g. exp2(98) != 2^98 in f32) — enough to break bit-exactness with
    the Rust mirror. IEEE bit construction is exact; s = -127 needs the
    subnormal encoding.
    """
    s = s.astype(jnp.int32)
    normal = ((s + 127) << 23).astype(jnp.uint32)
    sub = jnp.uint32(1 << 22)  # 2^-127
    bits = jnp.where(s >= -126, normal, sub)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def _group(x):
    r, c = x.shape
    assert c % GROUP == 0, f"last dim {c} not a multiple of {GROUP}"
    return x.reshape(r, c // GROUP, GROUP)


def _ceil_log2(r):
    """Exact ceil(log2(r)) for r > 0 via frexp (no transcendental error).

    frexp: r = m * 2^e with m in [0.5, 1). ceil(log2 r) = e-1 iff m == 0.5
    (r is an exact power of two) else e.
    """
    m, e = jnp.frexp(r)
    return jnp.where(m == 0.5, e - 1, e)


def _floor_log2(r):
    """Exact floor(log2(r)) for r > 0: frexp exponent minus one."""
    _, e = jnp.frexp(r)
    return e - 1


def scale_exponent(max_abs, fmt: FP4Format, scaling: str):
    """Shared-scale exponent s (int32) for a group with max-abs ``max_abs``.

    scaling='tf'   : TetraJet truncation-free  s = ceil(log2(2M/(Qp-Qn)))
                     = ceil(log2(M/Qp))       (paper §3.2; M=0 -> eps)
    scaling='floor': Microscaling              s = floor(log2(M)) - Emax
    """
    m_t = jnp.where(max_abs == 0.0, jnp.float32(ZERO_GROUP_EPS), max_abs)
    if scaling == "tf":
        s = _ceil_log2(m_t / jnp.float32(fmt.qp))
    elif scaling == "floor":
        s = _floor_log2(m_t) - fmt.emax
    else:  # pragma: no cover - config error
        raise ValueError(f"unknown scaling {scaling!r}")
    return jnp.clip(s, SCALE_EXP_MIN, SCALE_EXP_MAX)


def round_det(y, fmt: FP4Format):
    """Deterministic round-to-nearest on the FP4 grid (ties toward the
    larger value, matching the paper's round_D definition)."""
    b = jnp.asarray(fmt.boundaries_np())
    levels = jnp.asarray(fmt.levels_np())
    idx = jnp.sum(y[..., None] >= b, axis=-1)
    return levels[idx]


def _bracket(y, fmt: FP4Format):
    """The two consecutive grid values q1 <= y <= q2 (clipped at the ends)."""
    levels = jnp.asarray(fmt.levels_np())
    i = jnp.clip(
        jnp.sum(y[..., None] >= levels, axis=-1) - 1, 0, len(fmt.levels) - 2
    )
    return levels[i], levels[i + 1]


def round_stoch(y, u, fmt: FP4Format):
    """Stochastic rounding: E[round_S(y)] = y for y inside the grid.

    ``u`` are i.i.d. Uniform[0,1) samples of the same shape as ``y``.
    P(q2) = (y - q1) / (q2 - q1).
    """
    q1, q2 = _bracket(y, fmt)
    take_up = (y - q1) > u * (q2 - q1)
    return jnp.where(take_up, q2, q1)


def mx_quantize_ref(x, fmt: FP4Format, scaling: str, rounding: str, u=None):
    """Fake-quantize ``x`` (R, C) to MXFP4 with 1x32 groups on the last axis.

    Returns f32 values on the scaled FP4 grid. With scaling='floor' the
    scaled values can exceed [Qn, Qp] and are truncated (clipped), which is
    exactly the Microscaling behaviour the paper criticises; with 'tf' the
    clip is a mathematical no-op.
    """
    xg = _group(x)
    max_abs = jnp.max(jnp.abs(xg), axis=-1)
    s = scale_exponent(max_abs, fmt, scaling)
    scale = exp2i(s)[..., None]
    y = jnp.clip(xg / scale, fmt.qn, fmt.qp)
    if rounding == "det":
        q = round_det(y, fmt)
    elif rounding == "stoch":
        assert u is not None, "stochastic rounding needs uniforms"
        q = round_stoch(y, _group(u), fmt)
    else:  # pragma: no cover - config error
        raise ValueError(f"unknown rounding {rounding!r}")
    return (q * scale).reshape(x.shape)


def qema_quantize_ref(w, ema, fmt: FP4Format, scaling: str = "tf"):
    """EMA Quantizer (paper Alg. 1): scale from the *current* weight block,
    bracket [q1, q2] from the current latent weight, but pick the candidate
    nearer to the EMA latent weight (strictly-nearer -> q1, ties -> q2)."""
    wg = _group(w)
    eg = _group(ema)
    max_abs = jnp.max(jnp.abs(wg), axis=-1)
    s = scale_exponent(max_abs, fmt, scaling)
    scale = exp2i(s)[..., None]
    y = jnp.clip(wg / scale, fmt.qn, fmt.qp)
    ye = eg / scale
    q1, q2 = _bracket(y, fmt)
    q = jnp.where(jnp.abs(ye - q1) < jnp.abs(ye - q2), q1, q2)
    return (q * scale).reshape(w.shape)


def int4_quantize_ref(x, u=None):
    """Per-tensor symmetric INT4 fake quantization (baseline, Table 2).

    scale = max|x| / 7; deterministic round-half-away-from-zero, or
    stochastic when ``u`` is given.
    """
    m = jnp.max(jnp.abs(x))
    scale = jnp.where(m == 0.0, jnp.float32(1.0), m / jnp.float32(INT4_QMAX))
    y = x / scale
    if u is None:
        q = jnp.sign(y) * jnp.floor(jnp.abs(y) + 0.5)
    else:
        lo = jnp.floor(y)
        q = jnp.where((y - lo) > u, lo + 1.0, lo)
    q = jnp.clip(q, -INT4_QMAX, INT4_QMAX)
    return q * scale
