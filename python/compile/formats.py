"""FP4 / INT4 numeric-format definitions shared by all quantizers.

This is the single source of truth for the representable grids. The Rust
coordinator carries a bit-exact mirror (rust/src/quant/formats.rs) that is
golden-tested against this module via vectors exported by `aot.py`.

Paper references (TetraJet, ICML 2025):
  - §3.1: MXFP4 = E2M1 payload + shared E8M0 scale over groups of 32.
    E2M1: Qp = 6, Qn = -6.
  - §3.2: truncation-free scaling  s = ceil(log2(2*M / (Qp - Qn)))
          vs. Microscaling's       s = floor(log2(M)) - Emax.
  - Table 7: E3M0 is the alternative FP4 format (no mantissa bit).
"""

from dataclasses import dataclass
from typing import Tuple

import numpy as np

GROUP = 32  # MX group size (1x32 / 32x1)

# E8M0 scale-exponent clamp (8-bit biased exponent).
SCALE_EXP_MIN = -127
SCALE_EXP_MAX = 127

# Epsilon substituted for M when a group is all-zero (paper §3.2).
ZERO_GROUP_EPS = 1e-8


@dataclass(frozen=True)
class FP4Format:
    """A 4-bit floating-point format described by its representable grid.

    ``levels`` is the full ascending grid of representable values
    (negatives, zero, positives). ``emax`` is the largest exponent, used
    by Microscaling's floor-based shared-scale rule. ``mbits`` /
    ``delta_min`` parameterise the closed-form rounding used by the
    Pallas kernels: within the binade [2^(e-1), 2^e) the grid spacing is
    ``2^(e-1-mbits)``, clamped below by the subnormal spacing
    ``delta_min``.
    """

    name: str
    levels: Tuple[float, ...]
    emax: int
    mbits: int
    delta_min: float

    @property
    def qp(self) -> float:
        return self.levels[-1]

    @property
    def qn(self) -> float:
        return self.levels[0]

    @property
    def boundaries(self) -> Tuple[float, ...]:
        """Midpoints between consecutive levels (decision thresholds)."""
        ls = self.levels
        return tuple((ls[i] + ls[i + 1]) / 2.0 for i in range(len(ls) - 1))

    def levels_np(self) -> np.ndarray:
        return np.asarray(self.levels, dtype=np.float32)

    def boundaries_np(self) -> np.ndarray:
        return np.asarray(self.boundaries, dtype=np.float32)


def _sym(pos):
    return tuple([-v for v in reversed(pos)] + [0.0] + list(pos))


# E2M1: 1 sign, 2 exponent, 1 mantissa. Positives: 0.5,1,1.5,2,3,4,6.
E2M1 = FP4Format(
    "e2m1", _sym([0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]), emax=2, mbits=1,
    delta_min=0.5,
)

# E3M0: 1 sign, 3 exponent, 0 mantissa (bias 3, exponent-0 encodes zero).
# Positives: 2^-2 .. 2^4.
E3M0 = FP4Format(
    "e3m0", _sym([0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0]), emax=4, mbits=0,
    delta_min=0.25,
)

FORMATS = {"e2m1": E2M1, "e3m0": E3M0}

# INT4 per-tensor baseline (Xi et al. 2023, simplified): symmetric grid
# {-7..7} scaled by per-tensor max/7.
INT4_QMAX = 7


def fp4_format(name: str) -> FP4Format:
    try:
        return FORMATS[name]
    except KeyError:  # pragma: no cover - config error
        raise ValueError(f"unknown FP4 format {name!r}; known: {sorted(FORMATS)}")
