"""Model/variant registry + flat-layout invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import CORE_VARIANTS, VARIANTS, variant
from compile.vit import (
    MODELS,
    forward,
    init_params,
    param_spec,
    qw_total,
    total_params,
    unflatten,
    wd_mask,
)


def test_registry_covers_paper_sets():
    assert len(VARIANTS) == 5 + 6 + 8 + 4 + 2
    for v in CORE_VARIANTS:
        assert v in VARIANTS
    # Table 5 corner identities (modulo `impl`, which is bit-identical
    # by test_kernels; the Rust run cache aliases these variants).
    from dataclasses import replace

    tj = variant("tetrajet")
    abl = variant("abl_stoch_double_tf")
    assert replace(tj.linear_cfg(), impl="x") == replace(abl.linear_cfg(), impl="x")
    ms = variant("microscaling")
    abl_ms = variant("abl_det_naive_floor")
    assert replace(ms.linear_cfg(), impl="x") == replace(abl_ms.linear_cfg(), impl="x")
    fmtv = variant("fmt_e2m1_e2m1")
    assert replace(tj.linear_cfg(), impl="x") == replace(fmtv.linear_cfg(), impl="x")


def test_variant_lookup_error():
    with pytest.raises(ValueError):
        variant("nope")


@pytest.mark.parametrize("name", ["vit-micro", "vit-tiny"])
def test_param_layout_invariants(name):
    cfg = MODELS[name]
    spec = param_spec(cfg)
    off = 0
    seen_nonq = False
    for s in spec:
        assert s.offset == off
        if s.quantized:
            assert not seen_nonq, "quantized segments must form a prefix"
            assert s.shape[-1] % 32 == 0 or s.shape[-1] > 0
        else:
            seen_nonq = True
        off += s.size
    assert off == total_params(cfg)
    assert qw_total(cfg) == sum(s.size for s in spec if s.quantized)
    assert wd_mask(cfg).shape == (total_params(cfg),)


def test_vit_100m_is_about_100m():
    p = total_params(MODELS["vit-100m"])
    assert 80e6 < p < 130e6, p


def test_init_statistics():
    cfg = MODELS["vit-micro"]
    flat = init_params(0, cfg)
    p = unflatten(flat, cfg)
    w = np.asarray(p["blocks.qkv_w"])
    assert abs(w.mean()) < 2e-3
    assert 0.015 < w.std() < 0.025
    assert np.asarray(p["blocks.ln1.g"]).min() == 1.0
    assert np.abs(np.asarray(p["blocks.qkv_b"])).max() == 0.0


def test_forward_shapes_and_probe():
    cfg = MODELS["vit-micro"]
    flat = init_params(1, cfg)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 32, 3))
    logits, probe = forward(
        flat, x, jax.random.PRNGKey(1), cfg, variant("tetrajet").linear_cfg()
    )
    assert logits.shape == (4, cfg.classes)
    assert probe.shape == (4, cfg.seq, cfg.dim)
    # Probe of block k differs from the last block's output.
    _, probe0 = forward(
        flat, x, jax.random.PRNGKey(1), cfg, variant("tetrajet").linear_cfg(),
        probe_block=0,
    )
    assert not np.array_equal(np.asarray(probe), np.asarray(probe0))


def test_forward_batch_consistency():
    # Per-sample outputs must be independent of the rest of the batch
    # (no cross-sample leakage through quantizers: forward quantization
    # of X groups along channels only).
    cfg = MODELS["vit-micro"]
    flat = init_params(2, cfg)
    qcfg = variant("tetrajet").linear_cfg()
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 32, 32, 3))
    full, _ = forward(flat, x, key, cfg, qcfg)
    half, _ = forward(flat, x[:2], key, cfg, qcfg)
    np.testing.assert_allclose(np.asarray(full[:2]), np.asarray(half), rtol=2e-5, atol=1e-5)
