"""Pallas kernels vs the pure-jnp oracle: bit-exact agreement, plus
hypothesis sweeps over shapes and value distributions (the task brief's
L1 correctness requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.formats import E2M1, E3M0
from compile.kernels import ref
from compile.kernels.int4 import int4_quantize_pallas
from compile.kernels.mxfp4 import mx_quantize_pallas
from compile.kernels.qema import qema_quantize_pallas

FMTS = [E2M1, E3M0]


def rnd(shape, seed=0, scale=3.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


def uni(shape, seed=1):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape)


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
@pytest.mark.parametrize("scaling", ["tf", "floor"])
def test_det_bit_exact(fmt, scaling):
    x = rnd((48, 96))
    a = ref.mx_quantize_ref(x, fmt, scaling, "det")
    b = mx_quantize_pallas(x, fmt=fmt, scaling=scaling, rounding="det")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
@pytest.mark.parametrize("scaling", ["tf", "floor"])
def test_stoch_bit_exact(fmt, scaling):
    x = rnd((48, 96), seed=2)
    u = uni(x.shape, seed=3)
    a = ref.mx_quantize_ref(x, fmt, scaling, "stoch", u)
    b = mx_quantize_pallas(x, u, fmt=fmt, scaling=scaling, rounding="stoch")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
def test_qema_bit_exact(fmt):
    w = rnd((32, 64), seed=4)
    ema = w + rnd(w.shape, seed=5, scale=0.15)
    a = ref.qema_quantize_ref(w, ema, fmt)
    b = qema_quantize_pallas(w, ema, fmt=fmt)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int4_bit_exact():
    x = rnd((16, 64), seed=6)
    u = uni(x.shape, seed=7)
    np.testing.assert_array_equal(
        np.asarray(ref.int4_quantize_ref(x)), np.asarray(int4_quantize_pallas(x))
    )
    np.testing.assert_array_equal(
        np.asarray(ref.int4_quantize_ref(x, u)),
        np.asarray(int4_quantize_pallas(x, u)),
    )


def test_block_rows_variants_agree():
    # Different tile heights must not change results (pure data parallel).
    x = rnd((64, 64), seed=8)
    a = mx_quantize_pallas(x, fmt=E2M1, scaling="tf", rounding="det", block_rows=64)
    b = mx_quantize_pallas(x, fmt=E2M1, scaling="tf", rounding="det", block_rows=8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_truncation_free_paper_example():
    # §3.2: M = 31 -> floor scale 4 truncates to 24; tf scale 8 -> 32.
    x = jnp.zeros((1, 32)).at[0, 0].set(31.0)
    assert float(ref.mx_quantize_ref(x, E2M1, "floor", "det")[0, 0]) == 24.0
    assert float(ref.mx_quantize_ref(x, E2M1, "tf", "det")[0, 0]) == 32.0


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
def test_tf_scaled_values_in_range(fmt):
    x = rnd((16, 64), seed=9, scale=100.0)
    xg = np.asarray(x).reshape(16, 2, 32)
    m = np.abs(xg).max(-1)
    s = np.asarray(ref.scale_exponent(jnp.asarray(m), fmt, "tf"))
    latent = xg / (2.0**s)[..., None]
    assert np.all(np.abs(latent) <= fmt.qp + 1e-6)


def test_stochastic_unbiased():
    x = rnd((64, 32), seed=10, scale=2.0)
    n = 400
    us = jax.random.uniform(jax.random.PRNGKey(11), (n, *x.shape))
    import functools

    f = jax.jit(
        functools.partial(ref.mx_quantize_ref, fmt=E2M1, scaling="tf", rounding="stoch")
    )
    acc = np.zeros(x.shape, np.float64)
    for i in range(n):
        acc += np.asarray(f(x, u=us[i]), np.float64)
    bias = np.abs(acc / n - np.asarray(x)).mean()
    det_err = np.abs(
        np.asarray(ref.mx_quantize_ref(x, E2M1, "tf", "det")) - np.asarray(x)
    ).mean()
    assert bias < det_err / 4, f"stochastic bias {bias} vs det err {det_err}"


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
def test_idempotent(fmt):
    x = rnd((8, 64), seed=12)
    q1 = ref.mx_quantize_ref(x, fmt, "tf", "det")
    q2 = ref.mx_quantize_ref(q1, fmt, "tf", "det")
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


def test_zero_group_is_stable():
    x = jnp.zeros((2, 32))
    q = ref.mx_quantize_ref(x, E2M1, "tf", "det")
    np.testing.assert_array_equal(np.asarray(q), np.zeros((2, 32)))
    q = mx_quantize_pallas(x, fmt=E2M1, scaling="tf", rounding="det")
    np.testing.assert_array_equal(np.asarray(q), np.zeros((2, 32)))


# ---------------- hypothesis sweeps ----------------

shape_st = st.tuples(
    st.integers(min_value=1, max_value=9),
    st.integers(min_value=1, max_value=4).map(lambda g: g * 32),
)


@settings(max_examples=25, deadline=None)
@given(
    shape=shape_st,
    seed=st.integers(0, 2**30),
    scale=st.sampled_from([1e-6, 0.1, 1.0, 30.0, 1e4]),
    fmt=st.sampled_from(FMTS),
    scaling=st.sampled_from(["tf", "floor"]),
)
def test_hypothesis_det_matches_ref(shape, seed, scale, fmt, scaling):
    x = rnd(shape, seed=seed, scale=scale)
    a = ref.mx_quantize_ref(x, fmt, scaling, "det")
    b = mx_quantize_pallas(x, fmt=fmt, scaling=scaling, rounding="det")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=20, deadline=None)
@given(
    shape=shape_st,
    seed=st.integers(0, 2**30),
    fmt=st.sampled_from(FMTS),
)
def test_hypothesis_stoch_matches_ref(shape, seed, fmt):
    x = rnd(shape, seed=seed)
    u = uni(shape, seed=seed + 1)
    a = ref.mx_quantize_ref(x, fmt, "tf", "stoch", u)
    b = mx_quantize_pallas(x, u, fmt=fmt, scaling="tf", rounding="stoch")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=20, deadline=None)
@given(shape=shape_st, seed=st.integers(0, 2**30), fmt=st.sampled_from(FMTS))
def test_hypothesis_outputs_on_grid(shape, seed, fmt):
    x = rnd(shape, seed=seed, scale=5.0)
    q = np.asarray(mx_quantize_pallas(x, fmt=fmt, scaling="tf", rounding="det"))
    xg = np.asarray(x).reshape(shape[0], -1, 32)
    m = np.abs(xg).max(-1)
    s = np.asarray(ref.scale_exponent(jnp.asarray(m), fmt, "tf"), np.int32)
    latent = q.reshape(shape[0], -1, 32) / (2.0**s)[..., None].astype(np.float32)
    grid = np.asarray(fmt.levels, np.float32)
    assert np.isin(latent, grid).all()


@settings(max_examples=15, deadline=None)
@given(shape=shape_st, seed=st.integers(0, 2**30), fmt=st.sampled_from(FMTS))
def test_hypothesis_qema_matches_ref(shape, seed, fmt):
    w = rnd(shape, seed=seed)
    ema = w + rnd(shape, seed=seed + 9, scale=0.2)
    a = ref.qema_quantize_ref(w, ema, fmt)
    b = qema_quantize_pallas(w, ema, fmt=fmt)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
