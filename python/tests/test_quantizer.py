"""Layer-2 quantizer dispatch: axes, padding, impl equivalence, RNG."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.quantizer import IDENTITY, QuantizerCfg, qema_quantize_2d, quantize_2d

DET = QuantizerCfg(kind="mx", fmt="e2m1", scaling="tf", rounding="det")
STOCH = QuantizerCfg(kind="mx", fmt="e2m1", scaling="tf", rounding="stoch")


def rnd(shape, seed=0, scale=2.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


def test_identity_passthrough():
    x = rnd((8, 48))
    np.testing.assert_array_equal(np.asarray(quantize_2d(x, 1, IDENTITY)), np.asarray(x))


def test_axis0_is_transpose_of_axis1():
    x = rnd((64, 40), seed=1)
    a = quantize_2d(x, 0, DET)
    b = quantize_2d(x.T, 1, DET).T
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_padding_matches_manual_zero_pad():
    x = rnd((4, 48), seed=2)  # 48 % 32 != 0
    q = quantize_2d(x, 1, DET)
    xp = jnp.concatenate([x, jnp.zeros((4, 16))], axis=1)
    qp = quantize_2d(xp, 1, DET)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qp)[:, :48])


def test_impl_pallas_equals_ref():
    x = rnd((16, 96), seed=3)
    key = jax.random.PRNGKey(7)
    for cfg in (DET, STOCH):
        a = quantize_2d(x, 1, cfg, key=key, impl="pallas")
        b = quantize_2d(x, 1, cfg, key=key, impl="ref")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stochastic_key_determinism_and_sensitivity():
    x = rnd((16, 64), seed=4)
    k1, k2 = jax.random.PRNGKey(0), jax.random.PRNGKey(1)
    a = quantize_2d(x, 1, STOCH, key=k1)
    b = quantize_2d(x, 1, STOCH, key=k1)
    c = quantize_2d(x, 1, STOCH, key=k2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_stochastic_requires_key():
    x = rnd((4, 32))
    with pytest.raises(AssertionError):
        quantize_2d(x, 1, STOCH, key=None)


def test_int4_is_per_tensor():
    cfg = QuantizerCfg(kind="int4", rounding="det")
    x = rnd((8, 48), seed=5)
    q = np.asarray(quantize_2d(x, 1, cfg))
    m = np.abs(np.asarray(x)).max()
    scale = m / 7.0
    assert np.allclose(q / scale, np.round(q / scale), atol=1e-5)
    # axis is irrelevant for per-tensor quantization
    q0 = np.asarray(quantize_2d(x, 0, cfg))
    np.testing.assert_array_equal(q, q0)


def test_qema_axis0():
    w = rnd((64, 40), seed=6)
    ema = w * 0.95
    a = qema_quantize_2d(w, ema, 0, DET)
    b = qema_quantize_2d(w.T, ema.T, 1, DET).T
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
