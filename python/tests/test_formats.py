"""Format-table sanity: grids, boundaries, paper constants."""

import numpy as np
import pytest

from compile.formats import E2M1, E3M0, fp4_format


def test_e2m1_grid_matches_paper():
    # §3.1: E2M1 has Qp = 6, Qn = -6.
    assert E2M1.qp == 6.0
    assert E2M1.qn == -6.0
    assert E2M1.emax == 2
    pos = [v for v in E2M1.levels if v > 0]
    assert pos == [0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]
    assert len(E2M1.levels) == 15  # sign-symmetric + zero


def test_e3m0_grid():
    assert E3M0.qp == 16.0
    assert E3M0.emax == 4
    pos = [v for v in E3M0.levels if v > 0]
    assert pos == [0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0]


@pytest.mark.parametrize("fmt", [E2M1, E3M0])
def test_levels_sorted_and_symmetric(fmt):
    lv = list(fmt.levels)
    assert lv == sorted(lv)
    assert all(-a == b for a, b in zip(lv, reversed(lv)))


@pytest.mark.parametrize("fmt", [E2M1, E3M0])
def test_boundaries_are_midpoints(fmt):
    b = fmt.boundaries
    for i, x in enumerate(b):
        assert x == (fmt.levels[i] + fmt.levels[i + 1]) / 2


def test_paper_threshold_example():
    # Fig. 3: thrd = -0.75 is the midpoint of q1=-1, q2=-0.5.
    assert -0.75 in E2M1.boundaries


def test_format_lookup():
    assert fp4_format("e2m1") is E2M1
    assert fp4_format("e3m0") is E3M0
    with pytest.raises(ValueError):
        fp4_format("e4m3")


@pytest.mark.parametrize("fmt", [E2M1, E3M0])
def test_spacing_parameters_consistent(fmt):
    # delta_min is the gap between 0 and the smallest positive level.
    pos = [v for v in fmt.levels if v > 0]
    assert fmt.delta_min == pos[0]
    # mbits reproduces the within-binade spacing: in [1, 2) the grid
    # step is 2^-mbits.
    in_binade = [v for v in pos if 1.0 <= v < 2.0] + [2.0]
    gaps = {b - a for a, b in zip(in_binade, in_binade[1:])}
    assert gaps == {2.0 ** -fmt.mbits}
