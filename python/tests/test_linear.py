"""TetraJet linear layer: STE forward, gradient recipes, unbiasedness."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.linear import LinearQuantCfg, forward_weight_quant, make_qlinear
from compile.model import variant
from compile.quantizer import IDENTITY, QuantizerCfg, quantize_2d


def rnd(shape, seed, scale=0.5):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


def make(vname):
    return make_qlinear(variant(vname).linear_cfg()), variant(vname).linear_cfg()


def test_fp32_variant_is_exact_linear():
    ql, _ = make("fp32")
    x = rnd((64, 32), 0)
    w = rnd((16, 32), 1)
    y = ql(x, w, w, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.T), rtol=1e-5)


def test_forward_uses_quantized_operands():
    ql, cfg = make("tetrajet")
    x = rnd((64, 32), 2, scale=2.0)
    w = rnd((16, 32), 3, scale=0.2)
    y = ql(x, w, w, jax.random.PRNGKey(0))
    xq = quantize_2d(x, 1, cfg.q[0])
    wq = forward_weight_quant(w, w, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(xq @ wq.T), rtol=1e-5)


def test_gradients_flow_and_shapes():
    ql, _ = make("tetrajet")
    x = rnd((64, 32), 4)
    w = rnd((16, 32), 5, scale=0.2)
    key = jax.random.PRNGKey(1)

    def f(x, w):
        return jnp.sum(ql(x, w, w, key) ** 2)

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    assert gx.shape == x.shape and gw.shape == w.shape
    assert float(jnp.abs(gx).sum()) > 0 and float(jnp.abs(gw).sum()) > 0


def test_tetrajet_gradient_unbiased_vs_ste_target():
    """E[grad] over stochastic-rounding draws must match the exact STE
    gradient dX = dY @ Q2(W), dW = dY^T @ Q1(X) (paper Eq. 8-9)."""
    cfg = variant("tetrajet").linear_cfg()
    ql = make_qlinear(cfg)
    x = rnd((32, 32), 6, scale=1.0)
    w = rnd((16, 32), 7, scale=0.3)
    gy = rnd((32, 16), 8, scale=1.0)

    def loss(x, w, key):
        return jnp.sum(ql(x, w, w, key) * gy)

    xq = quantize_2d(x, 1, cfg.q[0])
    wq = forward_weight_quant(w, w, cfg)
    want_gx = gy @ wq
    want_gw = gy.T @ xq

    n = 300
    gx_acc = np.zeros(x.shape, np.float64)
    gw_acc = np.zeros(w.shape, np.float64)
    g = jax.jit(jax.grad(loss, argnums=(0, 1)))
    for i in range(n):
        gx, gw = g(x, w, jax.random.PRNGKey(i))
        gx_acc += np.asarray(gx, np.float64)
        gw_acc += np.asarray(gw, np.float64)
    gx_err = np.abs(gx_acc / n - np.asarray(want_gx)).mean() / np.abs(want_gx).mean()
    gw_err = np.abs(gw_acc / n - np.asarray(want_gw)).mean() / np.abs(want_gw).mean()
    assert gx_err < 0.05, f"dX bias {gx_err}"
    assert gw_err < 0.05, f"dW bias {gw_err}"


def test_microscaling_gradient_biased():
    """The naive-flow deterministic backward (Microscaling) does NOT
    converge to the STE target — the bias the paper analyzes in §3.4."""
    cfg = variant("microscaling").linear_cfg()
    ql = make_qlinear(cfg)
    x = rnd((32, 32), 9, scale=1.0)
    w = rnd((16, 32), 10, scale=0.3)
    gy = rnd((32, 16), 11, scale=1.0)

    def loss(x, w, key):
        return jnp.sum(ql(x, w, w, key) * gy)

    xq = quantize_2d(x, 1, cfg.q[0])
    wq = forward_weight_quant(w, w, cfg)
    want_gx = gy @ wq
    # Deterministic: a single draw IS the expectation.
    gx, _ = jax.grad(loss, argnums=(0, 1))(x, w, jax.random.PRNGKey(0))
    rel = np.abs(np.asarray(gx) - np.asarray(want_gx)).mean() / np.abs(want_gx).mean()
    assert rel > 0.01, f"expected visible bias, got {rel}"


def test_single_quantizer_toggles():
    # q3 variant: only the gradient quantizer Q3 active -> forward exact.
    ql, _ = make("q3")
    x = rnd((64, 32), 12)
    w = rnd((16, 32), 13, scale=0.2)
    y = ql(x, w, w, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.T), rtol=1e-5)
    # q1: only activation quantizer -> forward differs from exact.
    ql1, _ = make("q1")
    y1 = ql1(x, w, w, jax.random.PRNGKey(0))
    assert not np.allclose(np.asarray(y1), np.asarray(x @ w.T), rtol=1e-6, atol=0)


def test_qema_variant_uses_ema_argument():
    ql, cfg = make("tetrajet_qema")
    x = rnd((64, 32), 14)
    w = rnd((16, 32), 15, scale=0.2)
    ema1 = w
    ema2 = w + rnd(w.shape, 16, scale=0.3)
    y1 = ql(x, w, ema1, jax.random.PRNGKey(0))
    y2 = ql(x, w, ema2, jax.random.PRNGKey(0))
    assert not np.array_equal(np.asarray(y1), np.asarray(y2))
