"""Train/eval/probe step semantics: AdamW, Q-Ramping masks, Freeze,
EMA, dampen — the manifest contract the Rust coordinator relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import MODELS, variant
from compile.train import (
    build_eval_step,
    build_probe,
    build_train_step,
    eval_io_spec,
    train_io_spec,
)
from compile.vit import init_params, qw_total, total_params

MCFG = MODELS["vit-micro"]
B = 8
P = total_params(MCFG)
QW = qw_total(MCFG)


def base_inputs(seed=0):
    params = init_params(seed, MCFG)
    x = jax.random.normal(jax.random.PRNGKey(100), (B, 32, 32, 3))
    y = jax.random.randint(jax.random.PRNGKey(101), (B,), 0, 10)
    return dict(
        params=params,
        m=jnp.zeros(P),
        v=jnp.zeros(P),
        ema=params[:QW],
        accum=jnp.zeros(QW),
        nw=jnp.ones(QW),
        freeze_mask=jnp.zeros(QW),
        freeze_value=jnp.zeros(QW),
        lr=jnp.float32(1e-3),
        wd=jnp.float32(0.05),
        ema_beta=jnp.float32(0.998),
        dampen_lambda=jnp.float32(0.0),
        step=jnp.int32(0),
        seed=jnp.int32(42),
        x=x,
        y=y,
    )


def call(step_fn, d):
    return step_fn(
        d["params"], d["m"], d["v"], d["ema"], d["accum"], d["nw"],
        d["freeze_mask"], d["freeze_value"], d["lr"], d["wd"], d["ema_beta"],
        d["dampen_lambda"], d["step"], d["seed"], d["x"], d["y"],
    )


@pytest.fixture(scope="module")
def tj_step():
    return jax.jit(build_train_step(MCFG, variant("tetrajet"), B))


def test_shapes_match_io_spec(tj_step):
    d = base_inputs()
    outs = call(tj_step, d)
    spec = train_io_spec(MCFG, B)
    assert len(outs) == len(spec.outputs)
    for o, s in zip(outs, spec.outputs):
        assert tuple(o.shape) == tuple(s["shape"]), s["name"]
    assert np.isfinite(float(outs[5]))


def test_step_is_deterministic(tj_step):
    d = base_inputs()
    a = call(tj_step, d)
    b = call(tj_step, d)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_loss_decreases_over_repeated_steps(tj_step):
    d = base_inputs()
    losses = []
    for t in range(12):
        outs = call(tj_step, d)
        d["params"], d["m"], d["v"], d["ema"], d["accum"] = outs[:5]
        d["step"] = jnp.int32(t + 1)
        losses.append(float(outs[5]))
    assert losses[-1] < losses[0], losses


def test_nw_slows_down_updates(tj_step):
    d = base_inputs()
    d["nw"] = jnp.full(QW, 3.0)
    p0 = d["params"]
    # step 0: (0+1) % 3 != 0 -> no quantized update
    outs = call(tj_step, d)
    np.testing.assert_array_equal(np.asarray(outs[0][:QW]), np.asarray(p0[:QW]))
    # accum accumulated the gradient
    assert float(jnp.abs(outs[4]).sum()) > 0
    # non-quantized tail still updates every step
    assert not np.array_equal(np.asarray(outs[0][QW:]), np.asarray(p0[QW:]))
    # step 2: (2+1) % 3 == 0 -> update fires and accum resets
    d["params"], d["m"], d["v"], d["ema"], d["accum"] = outs[:5]
    d["step"] = jnp.int32(1)
    outs = call(tj_step, d)
    d["params"], d["m"], d["v"], d["ema"], d["accum"] = outs[:5]
    d["step"] = jnp.int32(2)
    outs = call(tj_step, d)
    assert not np.array_equal(np.asarray(outs[0][:QW]), np.asarray(p0[:QW]))
    np.testing.assert_array_equal(np.asarray(outs[4]), np.zeros(QW))


def test_freeze_mask_pins_values(tj_step):
    d = base_inputs()
    mask = jnp.zeros(QW).at[:50].set(1.0)
    val = jnp.zeros(QW).at[:50].set(0.321)
    d["freeze_mask"], d["freeze_value"] = mask, val
    outs = call(tj_step, d)
    np.testing.assert_array_equal(
        np.asarray(outs[0][:50]), np.full(50, np.float32(0.321))
    )


def test_ema_recurrence(tj_step):
    d = base_inputs()
    d["ema_beta"] = jnp.float32(0.9)
    outs = call(tj_step, d)
    want = 0.9 * np.asarray(d["ema"]) + 0.1 * np.asarray(outs[0][:QW])
    np.testing.assert_allclose(np.asarray(outs[3]), want, rtol=1e-6, atol=1e-8)


def test_dampen_changes_gradient():
    step = jax.jit(build_train_step(MCFG, variant("tetrajet"), B))
    d = base_inputs()
    out0 = call(step, d)
    d["dampen_lambda"] = jnp.float32(1e-2)
    out1 = call(step, d)
    assert not np.array_equal(np.asarray(out0[0]), np.asarray(out1[0]))


def test_adamw_matches_reference_for_plain_segment(tj_step):
    """The non-quantized tail follows textbook AdamW at step 0."""
    d = base_inputs()
    outs = call(tj_step, d)
    # Recompute expected update from the returned m/v (which are fresh
    # first-moment estimates at t=1).
    m1 = np.asarray(outs[1][QW:], np.float64)
    v1 = np.asarray(outs[2][QW:], np.float64)
    p0 = np.asarray(d["params"][QW:], np.float64)
    p1 = np.asarray(outs[0][QW:], np.float64)
    mhat = m1 / (1 - 0.9)
    vhat = v1 / (1 - 0.999)
    from compile.vit import wd_mask

    wdm = np.asarray(wd_mask(MCFG))[QW:]
    want = p0 - 1e-3 * (mhat / (np.sqrt(vhat) + 1e-8) + 0.05 * wdm * p0)
    np.testing.assert_allclose(p1, want, rtol=2e-4, atol=1e-7)


def test_eval_and_probe_steps():
    ev = jax.jit(build_eval_step(MCFG, variant("tetrajet"), B))
    pr = jax.jit(build_probe(MCFG, variant("tetrajet"), B))
    d = base_inputs()
    loss_sum, correct = ev(d["params"], d["ema"], d["x"], d["y"])
    assert loss_sum.shape == () and correct.shape == ()
    assert 0 <= float(correct) <= B
    (act,) = pr(d["params"], d["ema"], d["x"])
    assert act.shape == (B, MCFG.seq, MCFG.dim)
    # Probe is a pure function of (params, x).
    (act2,) = pr(d["params"], d["ema"], d["x"])
    np.testing.assert_array_equal(np.asarray(act), np.asarray(act2))


def test_fp32_variant_has_no_quantization_error_in_eval():
    ev_fp = jax.jit(build_eval_step(MCFG, variant("fp32"), B))
    ev_tj = jax.jit(build_eval_step(MCFG, variant("tetrajet"), B))
    d = base_inputs()
    l_fp, _ = ev_fp(d["params"], d["ema"], d["x"], d["y"])
    l_tj, _ = ev_tj(d["params"], d["ema"], d["x"], d["y"])
    assert not np.isclose(float(l_fp), float(l_tj), rtol=1e-6)
